"""Strategy objects for the hypothesis stub: deterministic, boundary-biased.

Each strategy exposes ``example(rng)``; ~15% of draws hit a range boundary so
edge cases surface even without real hypothesis's coverage-guided search.
"""
from __future__ import annotations

import string


class SearchStrategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng):
        return self._draw(rng)

    def map(self, f):
        return SearchStrategy(lambda rng: f(self._draw(rng)))

    def filter(self, pred):
        def draw(rng):
            for _ in range(100):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise ValueError("filter_too_much: predicate rarely satisfied")

        return SearchStrategy(draw)


def integers(min_value=0, max_value=2**31 - 1):
    lo, hi = int(min_value), int(max_value)

    def draw(rng):
        r = rng.random()
        if r < 0.08:
            return lo
        if r < 0.15:
            return hi
        return rng.randint(lo, hi)

    return SearchStrategy(draw)


def floats(min_value=0.0, max_value=1.0, **_ignored):
    lo, hi = float(min_value), float(max_value)

    def draw(rng):
        r = rng.random()
        if r < 0.08:
            return lo
        if r < 0.15:
            return hi
        return rng.uniform(lo, hi)

    return SearchStrategy(draw)


def booleans():
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements):
    pool = list(elements)
    if not pool:
        raise ValueError("sampled_from requires a non-empty collection")
    return SearchStrategy(lambda rng: pool[rng.randrange(len(pool))])


def lists(elements, min_size=0, max_size=10, **_ignored):
    def draw(rng):
        size = rng.randint(int(min_size), int(max_size))
        return [elements.example(rng) for _ in range(size)]

    return SearchStrategy(draw)


def text(alphabet=string.ascii_lowercase, min_size=0, max_size=10, **_ignored):
    pool = list(alphabet)

    def draw(rng):
        size = rng.randint(int(min_size), int(max_size))
        return "".join(pool[rng.randrange(len(pool))] for _ in range(size))

    return SearchStrategy(draw)


def tuples(*strategies):
    return SearchStrategy(lambda rng: tuple(s.example(rng) for s in strategies))


def just(value):
    return SearchStrategy(lambda rng: value)


def sets(elements, min_size=0, max_size=10, **_ignored):
    def draw(rng):
        size = rng.randint(int(min_size), int(max_size))
        out = set()
        for _ in range(100 * (size + 1)):
            if len(out) >= size:
                break
            out.add(elements.example(rng))
        if len(out) < int(min_size):
            raise ValueError("sets: element strategy too narrow for min_size")
        return out

    return SearchStrategy(draw)


class DataObject:
    """Interactive-draw handle (the real library's ``st.data()`` surface):
    every ``draw`` pulls from the SAME per-test deterministic stream, so
    dependent draws (e.g. cut points bounded by an earlier size draw) stay
    reproducible."""

    def __init__(self, rng):
        self._rng = rng

    def draw(self, strategy, label=None):
        return strategy.example(self._rng)


def data():
    return SearchStrategy(DataObject)
