"""Minimal deterministic stand-in for ``hypothesis``.

The real hypothesis is a declared test dependency (``pip install -e
.[test]`` — what CI does). Environments without it (e.g. hermetic
containers) still need the suite to *collect and pass*, so
``tests/conftest.py`` appends this stub directory to ``sys.path`` only when
the real import fails. It implements exactly the surface this repo's tests
use:

  @given over positional/keyword strategies, @settings(max_examples,
  deadline) in either decorator order, assume(), and
  strategies.{integers, floats, sampled_from, lists, sets, text, data}.

Draws are deterministic (seeded per test function) so failures reproduce;
there is no shrinking — the real library remains the CI gate.
"""
from __future__ import annotations

import random

__version__ = "0.0.0-repro-stub"


class UnsatisfiedAssumption(Exception):
    pass


def assume(condition):
    if not condition:
        raise UnsatisfiedAssumption()
    return True


class settings:
    """Decorator/record: only max_examples and deadline are honored."""

    _profiles: dict = {}
    _active: dict = {}

    def __init__(self, max_examples=None, deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._stub_settings = self
        return fn

    @classmethod
    def register_profile(cls, name, **kwargs):
        cls._profiles[name] = kwargs

    @classmethod
    def load_profile(cls, name):
        cls._active = dict(cls._profiles.get(name, {}))


class HealthCheck:
    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"

    @classmethod
    def all(cls):
        return [cls.too_slow, cls.data_too_large, cls.filter_too_much]


def given(*pos_strategies, **kw_strategies):
    def decorate(fn):
        # NOT functools.wraps: pytest must see a (*args, **kwargs) signature,
        # otherwise it tries to resolve the strategy parameters as fixtures.
        def wrapper(*args, **kwargs):
            conf = getattr(wrapper, "_stub_settings", None) or getattr(
                fn, "_stub_settings", None
            )
            n = None if conf is None else conf.max_examples
            if n is None:
                n = settings._active.get("max_examples", 20)
            n = max(1, int(n))
            rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
            ran = attempts = 0
            while ran < n and attempts < 10 * n:
                attempts += 1
                pos = [s.example(rng) for s in pos_strategies]
                kw = {k: s.example(rng) for k, s in kw_strategies.items()}
                try:
                    fn(*args, *pos, **kw, **kwargs)
                except UnsatisfiedAssumption:
                    continue
                ran += 1
            if ran == 0:
                # Mirror real hypothesis's filter_too_much health check: a
                # property that never executed must not report green.
                raise AssertionError(
                    f"{fn.__qualname__}: assume()/filter satisfied 0 of "
                    f"{attempts} draws — property never executed"
                )
            return None

        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__module__ = fn.__module__
        wrapper.__doc__ = fn.__doc__
        wrapper._stub_settings = getattr(fn, "_stub_settings", None)
        wrapper.is_hypothesis_test = True
        return wrapper

    return decorate


from . import strategies  # noqa: E402  (needs given/settings defined first)

__all__ = ["given", "settings", "strategies", "assume", "HealthCheck"]
