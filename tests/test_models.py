"""Per-arch smoke tests (reduced configs) + numerical invariants:
prefill/decode state-carry exactness, MoE dispatch vs dense reference,
chunked linear recurrence vs step-by-step recurrence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import base, moe as moe_lib, ssm, transformer, xlstm
from repro.models.config import SHAPES, ShapeConfig, shape_applicable


@pytest.mark.parametrize("name", configs.ARCH_NAMES)
def test_arch_forward_smoke(name):
    """One forward step on CPU: output shapes + no NaNs (assignment req)."""
    cfg = configs.get_reduced(name)
    params = base.init_params(jax.random.PRNGKey(0), transformer.model_defs(cfg))
    B, S = 2, 64
    batch = configs.input_specs(cfg, ShapeConfig("smoke", S, B, "train"),
                                abstract=False)["batch"]
    logits, aux = jax.jit(lambda p, b: transformer.forward(p, b, cfg))(params, batch)
    assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("name", [n for n in configs.ARCH_NAMES
                                  if configs.get(n).family != "audio"])
def test_arch_decode_smoke(name):
    cfg = configs.get_reduced(name)
    params = base.init_params(jax.random.PRNGKey(0), transformer.model_defs(cfg))
    B, S = 2, 32
    state = transformer.init_state(cfg, B, S)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, state2 = jax.jit(
        lambda p, t, s, l: transformer.decode_step(p, t, s, l, cfg)
    )(params, tok, state, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert jax.tree.structure(state) == jax.tree.structure(state2)


@pytest.mark.parametrize("name", ["stablelm-3b", "qwen1.5-0.5b", "granite-34b"])
def test_decode_matches_forward(name):
    """Prefill-by-decode must reproduce full-forward logits (KV cache is
    exact, RoPE positions consistent, MQA/GQA cache layouts correct)."""
    cfg = configs.get_reduced(name)
    params = base.init_params(jax.random.PRNGKey(1), transformer.model_defs(cfg))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full_logits, _ = transformer.forward(params, {"tokens": toks}, cfg)

    state = transformer.init_state(cfg, B, S)
    step = jax.jit(lambda p, t, s, l: transformer.decode_step(p, t, s, l, cfg))
    outs = []
    for t in range(S):
        lg, state = step(params, toks[:, t : t + 1], state, jnp.int32(t))
        outs.append(lg[:, 0])
    dec_logits = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(full_logits, np.float32), np.asarray(dec_logits, np.float32),
        rtol=0.15, atol=0.15,  # bf16 activations; chunked vs direct softmax
    )
    # rank agreement on the argmax is the semantic bar
    agree = (np.asarray(full_logits.argmax(-1)) == np.asarray(dec_logits.argmax(-1))).mean()
    assert agree > 0.95, agree


@pytest.mark.parametrize("name", ["zamba2-2.7b", "xlstm-1.3b"])
def test_ssm_decode_matches_forward(name):
    cfg = configs.get_reduced(name)
    params = base.init_params(jax.random.PRNGKey(1), transformer.model_defs(cfg))
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    full_logits, _ = transformer.forward(params, {"tokens": toks}, cfg)
    state = transformer.init_state(cfg, B, S)
    step = jax.jit(lambda p, t, s, l: transformer.decode_step(p, t, s, l, cfg))
    outs = []
    for t in range(S):
        lg, state = step(params, toks[:, t : t + 1], state, jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    agree = (np.asarray(full_logits.argmax(-1)) == np.asarray(dec.argmax(-1))).mean()
    assert agree > 0.9, agree


def test_chunked_recurrence_matches_stepwise(rng):
    """The SSD dual form equals the O(S) recurrence exactly."""
    B, S, H, dk, dv = 2, 32, 3, 4, 5
    q = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, dk)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, dv)), jnp.float32)
    log_a = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.3, jnp.float32)

    y_chunk, state_chunk = ssm.chunked_linear_recurrence(q, k, v, log_a, chunk=8)

    state = jnp.zeros((B, H, dk, dv))
    ys = []
    for t in range(S):
        yt, state = ssm.linear_recurrence_step(
            state, q[:, t], k[:, t], v[:, t], log_a[:, t]
        )
        ys.append(yt)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(state_chunk), np.asarray(state),
                               rtol=2e-4, atol=2e-4)


def test_chunked_recurrence_chunk_invariance(rng):
    B, S, H, dk, dv = 1, 64, 2, 4, 4
    q, k, v = (jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
               for d in (dk, dk, dv))
    log_a = jnp.asarray(-np.abs(rng.normal(size=(B, S, H))) * 0.2, jnp.float32)
    y8, _ = ssm.chunked_linear_recurrence(q, k, v, log_a, chunk=8)
    y16, _ = ssm.chunked_linear_recurrence(q, k, v, log_a, chunk=16)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y16), rtol=1e-4, atol=1e-4)


def test_moe_matches_dense_reference(rng):
    """With capacity_factor high enough for zero drops, the sparse dispatch
    must equal the dense 'compute every expert, weighted-sum' reference."""
    cfg = dataclasses.replace(
        configs.get_reduced("deepseek-moe-16b"),
        n_experts=4, top_k=2, n_shared_experts=0, capacity_factor=8.0,
    )
    defs = moe_lib.moe_defs(cfg)
    params = base.init_params(jax.random.PRNGKey(0), defs)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)), jnp.float32)
    y, aux = moe_lib.moe_block(params, x, cfg, group_size=16)

    # dense reference
    logits = (x @ params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, -1)
    w, idx = jax.lax.top_k(probs, cfg.top_k)
    w = w / w.sum(-1, keepdims=True)
    outs = []
    for e in range(cfg.n_experts):
        g = x @ params["gate"][e]
        u = x @ params["up"][e]
        outs.append((jax.nn.silu(g) * u) @ params["down"][e])
    dense = jnp.stack(outs, axis=2)  # (B, S, E, d)
    sel = jnp.take_along_axis(dense, idx[..., None], axis=2)
    want = (sel * w[..., None]).sum(2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_counted(rng):
    cfg = dataclasses.replace(
        configs.get_reduced("deepseek-moe-16b"),
        n_experts=4, top_k=2, n_shared_experts=0, capacity_factor=0.25,
    )
    params = base.init_params(jax.random.PRNGKey(0), moe_lib.moe_defs(cfg))
    x = jnp.asarray(rng.normal(size=(1, 64, cfg.d_model)), jnp.float32)
    y, _ = moe_lib.moe_block(params, x, cfg, group_size=64)
    assert bool(jnp.isfinite(y).all())  # dropped tokens pass through as zeros


def test_attention_rect_equals_blocklist(rng):
    from repro.models import attention
    B, S, H, hd = 2, 64, 4, 16
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, 2, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, 2, hd)), jnp.float32)
    a = attention.chunked_attention(q, k, v, causal=True, q_chunk=16,
                                    kv_chunk=16, causal_mode="rect")
    b = attention.chunked_attention(q, k, v, causal=True, q_chunk=16,
                                    kv_chunk=16, causal_mode="blocklist")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-3, atol=2e-3)


def test_attention_matches_naive_softmax(rng):
    from repro.models import attention
    B, S, H, hd = 1, 32, 2, 8
    q = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, H, hd)), jnp.float32)
    got = attention.chunked_attention(q, k, v, causal=True, q_chunk=8, kv_chunk=8)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k) / np.sqrt(hd)
    mask = jnp.tril(jnp.ones((S, S), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    want = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(logits, -1), v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-3, atol=1e-3)


def test_n_params_estimates_are_sane():
    expect = {
        "qwen1.5-0.5b": (0.3e9, 0.8e9),
        "stablelm-3b": (2e9, 4e9),
        "phi3-mini-3.8b": (3e9, 4.5e9),
        "granite-34b": (30e9, 40e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "llama4-scout-17b-a16e": (80e9, 120e9),  # total (incl. all experts)
        "xlstm-1.3b": (0.8e9, 2e9),
        "zamba2-2.7b": (2e9, 3.5e9),
        "llava-next-34b": (30e9, 40e9),
        "hubert-xlarge": (0.7e9, 1.3e9),
    }
    for name, (lo, hi) in expect.items():
        total, active = configs.get(name).n_params_active
        assert lo <= total <= hi, (name, total / 1e9)
        assert active <= total


def test_all_cells_accounting():
    cells = configs.all_cells()
    assert len(cells) == 40
    runnable = [c for c in cells if c[2]]
    assert len(runnable) == 31  # 40 - hubert decode/long (2) - 7 long_500k
