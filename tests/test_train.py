"""Training substrate: optimizer math, EF compression invariant,
microbatch-equivalence, loss descent, checkpoint round trips."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import base, transformer
from repro.models.config import ShapeConfig
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts


def _setup(name="qwen1.5-0.5b", compress=False, n_micro=1):
    cfg = configs.get_reduced(name)
    params = base.init_params(jax.random.PRNGKey(0), transformer.model_defs(cfg))
    ocfg = opt_lib.OptConfig(total_steps=50, warmup_steps=2, compress_grads=compress)
    opt = opt_lib.init_opt_state(params, ocfg)
    scfg = ts.StepConfig(n_micro=n_micro)
    step = jax.jit(ts.make_train_step(cfg, ocfg, scfg))
    batch = configs.input_specs(cfg, ShapeConfig("s", 64, 4, "train"),
                                abstract=False)["batch"]
    return cfg, params, ocfg, opt, step, batch


def test_loss_decreases_on_fixed_batch():
    _, params, _, opt, step, batch = _setup()
    losses = []
    for _ in range(6):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["total"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_compressed_training_still_descends():
    _, params, _, opt, step, batch = _setup(compress=True)
    losses = []
    for _ in range(6):
        params, opt, m = step(params, opt, batch)
        losses.append(float(m["total"]))
    assert losses[-1] < losses[0]


def test_microbatch_grad_equivalence():
    """The scan-accumulated microbatch gradient must equal the full-batch
    gradient (compared pre-optimizer: Adam turns fp-noise sign flips of
    near-zero grads into full ±lr update differences, so comparing params
    post-update is ill-conditioned by construction)."""
    cfg, params, ocfg, opt, _, batch = _setup(n_micro=1)
    loss_fn = ts.make_loss_fn(cfg, ts.StepConfig())
    g_full = jax.grad(lambda p: loss_fn(p, batch)[0])(params)

    n_micro = 4
    micro = jax.tree.map(
        lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]), batch
    )
    g_acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    for i in range(n_micro):
        mb = jax.tree.map(lambda x: x[i], micro)
        g = jax.grad(lambda p: loss_fn(p, mb)[0])(params)
        g_acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32) / n_micro,
                             g_acc, g)
    # bf16 activations: different batch shapes change reduction order and
    # intermediate rounding; measured noise is ~1% of each leaf's max-grad
    # (diagnosed elementwise — no leaf-structure or scaling error).
    for a, b in zip(jax.tree.leaves(g_full), jax.tree.leaves(g_acc)):
        scale = max(float(jnp.abs(a).max()), 1e-6)
        an = np.asarray(a, np.float32) / scale
        bn = np.asarray(b, np.float32) / scale
        np.testing.assert_allclose(an, bn, atol=2.5e-2)
        corr = np.corrcoef(an.ravel(), bn.ravel())[0, 1]
        assert corr > 0.999, corr


def test_int8_ef_compression_invariant(rng):
    """Error feedback: sum of dequantized stream + final residual equals the
    sum of the true gradient stream exactly."""
    g_stream = [jnp.asarray(rng.normal(size=(64,)), jnp.float32) for _ in range(10)]
    residual = {"w": jnp.zeros((64,), jnp.float32)}
    sent_total = jnp.zeros((64,))
    for g in g_stream:
        deq, residual = opt_lib.compress_int8_ef({"w": g}, residual)
        sent_total = sent_total + deq["w"]
    true_total = sum(g_stream)
    np.testing.assert_allclose(
        np.asarray(sent_total + residual["w"]), np.asarray(true_total),
        rtol=1e-5, atol=1e-5,
    )
    # pointwise error of a single step bounded by one quantization bucket
    deq1, r1 = opt_lib.compress_int8_ef({"w": g_stream[0]},
                                        {"w": jnp.zeros((64,))})
    scale = float(jnp.max(jnp.abs(g_stream[0]))) / 127.0
    assert float(jnp.abs(r1["w"]).max()) <= scale / 2 + 1e-7


def test_lr_schedule_shape():
    ocfg = opt_lib.OptConfig(lr=1.0, warmup_steps=10, total_steps=100,
                             min_lr_ratio=0.1)
    lrs = [float(opt_lib.lr_at(jnp.asarray(s), ocfg)) for s in range(101)]
    assert lrs[0] == 0.0 and abs(lrs[10] - 1.0) < 1e-6
    assert lrs[100] == pytest.approx(0.1, abs=1e-3)
    assert all(lrs[i] >= lrs[i + 1] - 1e-9 for i in range(10, 100))


def test_grad_clip_bounds_update():
    g = {"w": jnp.full((4,), 100.0)}
    p = {"w": jnp.zeros((4,))}
    ocfg = opt_lib.OptConfig(clip_norm=1.0, lr=1.0, weight_decay=0.0)
    st = opt_lib.init_opt_state(p, ocfg)
    _, _, m = opt_lib.apply_updates(p, g, st, ocfg)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_cross_entropy_masking():
    logits = jnp.zeros((1, 4, 8))
    labels = jnp.asarray([[1, -1, 2, 3]])
    loss, n = ts.cross_entropy(logits, labels, shift=False)
    assert int(n) == 3
    assert float(loss) == pytest.approx(np.log(8), rel=1e-5)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    cfg, params, ocfg, opt, step, batch = _setup()
    params, opt, _ = step(params, opt, batch)
    st = ckpt_lib.TrainState(params, opt, step=7, data_cursor=28, rng_seed=3)
    ckpt_lib.save(str(tmp_path), st)
    like = ckpt_lib.TrainState(
        jax.tree.map(jnp.zeros_like, params), jax.tree.map(jnp.zeros_like, opt),
        0, 0, 0,
    )
    back = ckpt_lib.restore(str(tmp_path), like)
    assert back.step == 7 and back.data_cursor == 28 and back.rng_seed == 3
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back.params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(opt), jax.tree.leaves(back.opt_state)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_atomicity(tmp_path):
    """A stale tmp dir (crash artifact) is invisible to latest_step."""
    os.makedirs(tmp_path / "step_000000099.tmp")
    assert ckpt_lib.latest_step(str(tmp_path)) is None
    p = {"w": jnp.ones((3,))}
    ckpt_lib.save(str(tmp_path), ckpt_lib.TrainState(p, {"s": p}, 5, 0, 0))
    assert ckpt_lib.latest_step(str(tmp_path)) == 5
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_checkpoint_keep_k(tmp_path):
    p = {"w": jnp.ones((3,))}
    for s in range(6):
        ckpt_lib.save(str(tmp_path), ckpt_lib.TrainState(p, {}, s, 0, 0), keep_k=3)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(steps) == 3
    assert ckpt_lib.latest_step(str(tmp_path)) == 5


def test_resume_reproduces_uninterrupted_run(tmp_path):
    """Crash/restart must land on the same loss curve as a straight run."""
    cfg, params0, ocfg, opt0, step, batch = _setup()
    # straight run: 4 steps
    p, o = params0, opt0
    for _ in range(4):
        p, o, m = step(p, o, batch)
    loss_straight = float(m["total"])

    # interrupted run: 2 steps, checkpoint, restore, 2 more
    p, o = params0, opt0
    for _ in range(2):
        p, o, _ = step(p, o, batch)
    ckpt_lib.save(str(tmp_path), ckpt_lib.TrainState(p, o, 2, 8, 0))
    like = ckpt_lib.TrainState(
        jax.tree.map(jnp.zeros_like, p), jax.tree.map(jnp.zeros_like, o), 0, 0, 0
    )
    back = ckpt_lib.restore(str(tmp_path), like)
    p, o = back.params, back.opt_state
    for _ in range(2):
        p, o, m = step(p, o, batch)
    assert float(m["total"]) == pytest.approx(loss_straight, rel=1e-5)
