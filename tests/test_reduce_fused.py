"""Fused single-kernel reduce phase: on-device pair compaction certified by
an overflow/parity property suite.

Three layers, mirroring the dispatch triad:

* kernel contract — ``ref.compact_mask`` / ``ops.verify_compact``: prefix-sum
  compaction equals order-normalized ``np.nonzero``, the overflow sentinel
  reports the exact count, edge tiles (empty, all-pruned, exactly-full,
  single hit at the first/last flat cell) behave.
* engine parity — ``emit="compact"`` is byte-identical to ``emit="mask"``
  across the exact-metric set × backends × tile sizes × prune modes, and the
  verification/hit/prune telemetry is emission-invariant.
* overflow ladder — an undersized capacity prior (monkeypatched knobs) walks
  sentinel -> retry -> mask fallback, emits the identical pair set, and
  increments ``VerifyStats.n_overflow_retries`` (counter-regression style);
  same contract through the distributed executor
  (``DistJoinResult.n_overflow_retries``).
"""
import json
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import mapping, verify
from repro.kernels import ops as kops
from repro.kernels import ref as kref

TILE_SIZES = [(32, 64), (128, 128), (512, 512)]


def _norm(pairs: np.ndarray) -> np.ndarray:
    """Order-normalize a pair buffer (emission order is backend-dependent)."""
    pairs = np.asarray(pairs)
    if pairs.size == 0:
        return pairs.reshape(0, 2)
    return pairs[np.lexsort((pairs[:, 1], pairs[:, 0]))]


def _setup(metric, rng, n=240, m=6, p=5):
    """Clustered dataset + a random cell structure with overlap membership."""
    data = np.concatenate(
        [rng.normal(loc=c, scale=1.0, size=(n // 3, m)) for c in (0.0, 3.0, 7.0)]
    ).astype(np.float32)
    n = data.shape[0]
    d = np.asarray(kref.pairdist(jnp.asarray(data), jnp.asarray(data), metric))
    delta = float(np.quantile(d[np.triu_indices(n, 1)], 0.05))
    cells = rng.integers(0, p, n)
    member = np.zeros((n, p), bool)
    member[np.arange(n), cells] = True
    member[np.arange(n), rng.integers(0, p, n)] = True
    return data, cells, member, delta


# ---------------------------------------------------------------------------
# Kernel contract: prefix-sum compaction == order-normalized nonzero
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(0, 2**31 - 1),
    a=st.integers(1, 9),
    b=st.integers(1, 9),
    density=st.floats(0.0, 1.0),
    slack=st.integers(0, 3),
)
@settings(deadline=None)
def test_compaction_matches_nonzero_order_normalized(seed, a, b, density, slack):
    """Property: compacting a random hit mask through the exclusive
    prefix-sum kernel yields exactly the ``np.nonzero`` pair set (order
    normalized), with -1 padding past the true count."""
    r = np.random.default_rng(seed)
    mask = r.random((a, b)) < density
    vids = r.permutation(64)[:a].astype(np.int32)
    wids = (64 + r.permutation(64)[:b]).astype(np.int32)
    count = int(mask.sum())
    capacity = max(count + slack, 1)
    pairs, cnt = kref.compact_mask(
        jnp.asarray(mask), jnp.asarray(vids), jnp.asarray(wids), capacity
    )
    pairs, cnt = np.asarray(pairs), int(cnt)
    assert cnt == count
    vi, wi = np.nonzero(mask)
    want = np.stack([vids[vi], wids[wi]], axis=1)
    assert np.array_equal(_norm(pairs[:count]), _norm(want))
    assert (pairs[count:] == -1).all()


@given(seed=st.integers(0, 2**31 - 1), capacity=st.integers(1, 4))
@settings(deadline=None)
def test_overflow_sentinel_reports_exact_count(seed, capacity):
    """count > capacity is the overflow sentinel: the buffer contents are
    unspecified but the count is exact, so one retry sizes the next bucket."""
    r = np.random.default_rng(seed)
    mask = r.random((6, 6)) < 0.8
    count = int(mask.sum())
    if count <= capacity:
        mask[:, :] = True
        count = mask.size
    ids = np.arange(6, dtype=np.int32)
    _, cnt = kref.compact_mask(
        jnp.asarray(mask), jnp.asarray(ids), jnp.asarray(ids), capacity
    )
    assert int(cnt) == count


def test_compaction_edge_tiles():
    """Edge tiles: empty, all-pruned, exactly-full, and a single hit at flat
    index 0 / at the last flat cell landing in buffer slot 0 / capacity-1."""
    ids4 = np.arange(4, dtype=np.int32)
    # Empty tile (either side zero-width): count 0, all padding.
    for shape in [(0, 4), (4, 0)]:
        pairs, cnt = kref.compact_mask(
            jnp.zeros(shape, bool), jnp.asarray(ids4[: shape[0]]),
            jnp.asarray(ids4[: shape[1]]), 3
        )
        assert int(cnt) == 0 and (np.asarray(pairs) == -1).all()
    # All-pruned tile (mask present but all False).
    pairs, cnt = kref.compact_mask(
        jnp.zeros((4, 4), bool), jnp.asarray(ids4), jnp.asarray(ids4), 3
    )
    assert int(cnt) == 0 and (np.asarray(pairs) == -1).all()
    # Exactly-full buffer: capacity == count, no sentinel, no padding.
    mask = np.zeros((4, 4), bool)
    mask[0, 1] = mask[2, 3] = mask[3, 0] = True
    pairs, cnt = kref.compact_mask(
        jnp.asarray(mask), jnp.asarray(ids4), jnp.asarray(ids4), 3
    )
    pairs = np.asarray(pairs)
    assert int(cnt) == 3
    assert np.array_equal(_norm(pairs), _norm(np.array([[0, 1], [2, 3], [3, 0]])))
    # Single pair at flat index 0 -> buffer slot 0.
    mask = np.zeros((4, 4), bool)
    mask[0, 0] = True
    pairs, cnt = kref.compact_mask(
        jnp.asarray(mask), jnp.asarray(ids4), jnp.asarray(ids4), 2
    )
    assert int(cnt) == 1 and tuple(np.asarray(pairs)[0]) == (0, 0)
    # Single pair at the LAST flat cell: the searchsorted inversion must not
    # clamp it away; with capacity 1 it lands in slot capacity-1 == 0.
    mask = np.zeros((4, 4), bool)
    mask[3, 3] = True
    pairs, cnt = kref.compact_mask(
        jnp.asarray(mask), jnp.asarray(ids4), jnp.asarray(ids4), 1
    )
    assert int(cnt) == 1 and tuple(np.asarray(pairs)[0]) == (3, 3)


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_dispatch_triad_verify_compact_matches_mask(backend, rng):
    """ops.verify_compact (both backends) returns the same pair set as the
    mask path on a real tile, including the in-band candidate count."""
    x = rng.normal(size=(17, 5)).astype(np.float32)
    y = rng.normal(size=(23, 5)).astype(np.float32)
    vids = jnp.arange(17)
    wids = jnp.arange(100, 123)
    wcells = jnp.zeros((23,), jnp.int32)
    pairs, count, n_cand = kops.verify_compact(
        jnp.asarray(x), jnp.asarray(y), vids, wids, wcells, 0,
        delta=2.0, metric="l2", capacity=512, cross=True, backend=backend,
    )
    mask = np.asarray(
        kref.pairdist_mask(jnp.asarray(x), jnp.asarray(y), 2.0, "l2")
    )
    vi, wi = np.nonzero(mask)
    want = np.stack([vi, 100 + wi], axis=1)
    assert int(count) == vi.size
    assert int(n_cand) == 17 * 23
    assert np.array_equal(_norm(np.asarray(pairs)[: int(count)]), _norm(want))


# ---------------------------------------------------------------------------
# Engine parity: emit="compact" == emit="mask", metrics x backends x tiles
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("tile_v,tile_w", TILE_SIZES)
@pytest.mark.parametrize("backend", ["numpy", "pallas"])
@pytest.mark.parametrize("metric", list(kref.METRICS))
def test_engine_compact_mask_identity(metric, backend, tile_v, tile_w, rng):
    """Fixed-seed pair sets are byte-identical between the emission paths on
    every exact metric, backend, and tile size; the verification and hit
    telemetry is emission-invariant."""
    data, cells, member, delta = _setup(metric, rng)
    base = base_stats = None
    for emit in ["mask", "compact"]:
        cfg = verify.EngineConfig(
            backend=backend, tile_v=tile_v, tile_w=tile_w, emit=emit
        )
        pairs, stats = verify.verify_pairs(
            data, cells, member, delta, metric, config=cfg
        )
        assert stats.emit == emit  # exact metrics: no capability fallback
        if base is None:
            base, base_stats = pairs, stats
        else:
            assert pairs.tobytes() == base.tobytes(), (metric, backend, tile_v)
            assert stats.n_hits == base_stats.n_hits
            assert stats.n_verifications == base_stats.n_verifications


@pytest.mark.parametrize("prune", ["pivot", "window"])
@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_engine_compact_mask_identity_pruned(prune, backend, rng):
    """Same identity with the pivot filter / host-side windows engaged: the
    pruned compacted pair set matches the unpruned mask run byte for byte."""
    metric = "l1"
    data, cells, member, delta = _setup(metric, rng)
    anchors = data[rng.choice(data.shape[0], 4, replace=False)]
    coords = np.asarray(mapping.SpaceMap(anchors, metric)(data), np.float32)
    ref_cfg = verify.EngineConfig(backend=backend, tile_v=64, tile_w=128)
    base, base_stats = verify.verify_pairs(
        data, cells, member, delta, metric, config=ref_cfg
    )
    for emit in ["mask", "compact"]:
        cfg = verify.EngineConfig(
            backend=backend, tile_v=64, tile_w=128, prune=prune, emit=emit
        )
        pairs, stats = verify.verify_pairs(
            data, cells, member, delta, metric, config=cfg, coords=coords
        )
        assert pairs.tobytes() == base.tobytes(), (prune, backend, emit)
        assert stats.n_hits == base_stats.n_hits
        assert stats.n_verifications == base_stats.n_verifications
        assert stats.n_exact + stats.n_pruned == stats.n_verifications


def test_engine_compact_empty_and_degenerate_cells(rng):
    """Compact emission through degenerate cell structures: empty V or W
    lists, singleton cells, and a cell whose window prunes everything."""
    data = rng.normal(size=(40, 4)).astype(np.float32)
    anchors = data[:3]
    coords = np.asarray(mapping.SpaceMap(anchors, "l2")(data), np.float32)
    cells = np.zeros((40,), np.int64)
    v_lists = [np.arange(20), np.array([], np.int64), np.array([39])]
    w_lists = [np.arange(20, 40), np.arange(5), np.array([], np.int64)]
    for prune in ["none", "window"]:
        base = None
        for emit in ["mask", "compact"]:
            cfg = verify.EngineConfig(backend="numpy", tile_v=8, tile_w=8,
                                      prune=prune, emit=emit)
            pairs, stats = verify.verify_cell_lists(
                data, cells, v_lists, w_lists, 0.9, "l2", config=cfg,
                coords=coords if prune != "none" else None,
            )
            if base is None:
                base = pairs
            else:
                assert pairs.tobytes() == base.tobytes()


# ---------------------------------------------------------------------------
# Overflow ladder: sentinel -> retry -> fallback, counted
# ---------------------------------------------------------------------------


def _force_undercapacity(monkeypatch):
    """Shrink the capacity prior so the first bucket always overflows."""
    monkeypatch.setattr(verify, "DEFAULT_EMIT_RATE", 1e-9)
    monkeypatch.setattr(verify, "EMIT_SLACK", 1e-9)
    monkeypatch.setattr(verify, "_EMIT_FLOOR", 1)
    monkeypatch.setattr(verify, "_estimate_emit_rate", lambda *a, **k: 1e-9)


@pytest.mark.parametrize(
    "backend,prune", [("numpy", "pivot"), ("pallas", "none")]
)
def test_overflow_retry_ladder_engine(backend, prune, rng, monkeypatch):
    """Counter-regression: an undersized prior forces the sentinel->retry
    ladder on every buffered tile; the emitted pairs stay identical and
    n_overflow_retries records the walk. (Buffered tiles only: the jnp
    window/none path lowers compact emission to the mask dispatch and can
    never overflow.)"""
    metric = "l1"
    data, cells, member, delta = _setup(metric, rng, n=90)
    coords = None
    if prune == "pivot":
        anchors = data[rng.choice(data.shape[0], 4, replace=False)]
        coords = np.asarray(mapping.SpaceMap(anchors, metric)(data), np.float32)
    cfg_m = verify.EngineConfig(backend=backend, tile_v=32, tile_w=32,
                                prune=prune, emit="mask")
    base, _ = verify.verify_pairs(
        data, cells, member, delta, metric, config=cfg_m, coords=coords
    )
    _force_undercapacity(monkeypatch)
    cfg_c = verify.EngineConfig(backend=backend, tile_v=32, tile_w=32,
                                prune=prune, emit="compact")
    pairs, stats = verify.verify_pairs(
        data, cells, member, delta, metric, config=cfg_c, coords=coords
    )
    assert pairs.tobytes() == base.tobytes()
    assert stats.n_overflow_retries >= 1


def test_overflow_fallback_to_mask_is_identical(rng, monkeypatch):
    """Exhausting the bounded retries lands on the mask-path rung: still the
    identical pair set, retries still counted."""
    metric = "l2"
    data, cells, member, delta = _setup(metric, rng, n=90)
    anchors = data[rng.choice(data.shape[0], 4, replace=False)]
    coords = np.asarray(mapping.SpaceMap(anchors, metric)(data), np.float32)
    cfg_m = verify.EngineConfig(backend="numpy", tile_v=32, tile_w=32,
                                prune="pivot", emit="mask")
    base, _ = verify.verify_pairs(
        data, cells, member, delta, metric, config=cfg_m, coords=coords
    )
    _force_undercapacity(monkeypatch)
    monkeypatch.setattr(verify, "_MAX_OVERFLOW_RETRIES", 0)
    cfg_c = verify.EngineConfig(backend="numpy", tile_v=32, tile_w=32,
                                prune="pivot", emit="compact")
    pairs, stats = verify.verify_pairs(
        data, cells, member, delta, metric, config=cfg_c, coords=coords
    )
    assert pairs.tobytes() == base.tobytes()
    assert stats.n_overflow_retries >= 1


def test_overflow_retry_grows_capacity_monotonically(rng, monkeypatch):
    """The retry ladder sizes the next bucket from the sentinel's exact
    count: one retry should suffice (no second overflow on the same tile)."""
    metric = "l1"
    data, cells, member, delta = _setup(metric, rng, n=90)
    anchors = data[rng.choice(data.shape[0], 4, replace=False)]
    coords = np.asarray(mapping.SpaceMap(anchors, metric)(data), np.float32)
    _force_undercapacity(monkeypatch)
    calls = []
    orig = verify.bucket_size

    def spy(n, cap, floor=8):
        out = orig(n, cap, floor)
        calls.append((n, out))
        return out

    monkeypatch.setattr(verify, "bucket_size", spy)
    cfg = verify.EngineConfig(backend="numpy", tile_v=32, tile_w=32,
                              prune="pivot", emit="compact")
    _, stats = verify.verify_pairs(
        data, cells, member, delta, metric, config=cfg, coords=coords
    )
    # Every dispatched tile overflowed exactly once: retries == tiles that
    # had any hit, never more than one walk per tile.
    assert 1 <= stats.n_overflow_retries <= stats.n_tiles


# ---------------------------------------------------------------------------
# Distributed executor: compacted pairs ride the existing exchange
# ---------------------------------------------------------------------------


def test_distributed_compact_identity_single_device():
    """1-device mesh in-process: distributed emit="compact" returns the same
    pairs as emit="mask", self-join and RxS, and the overflow counter rides
    the result."""
    import jax
    from jax.sharding import Mesh

    from repro.core import distributed as D

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    rng = np.random.default_rng(1)
    data = rng.normal(size=(240, 6)).astype(np.float32)
    s = rng.normal(size=(130, 6)).astype(np.float32)
    for cross in [False, True]:
        kw = dict(mesh=mesh, delta=4.0, metric="l1", k=96, n_dims=4,
                  emit_pairs=True, backend="numpy", seed=3,
                  s=s if cross else None)
        r_mask = D.distributed_join(np.asarray(data), emit="mask", **kw)
        r_comp = D.distributed_join(np.asarray(data), emit="compact", **kw)
        assert r_comp.emit == "compact"
        assert r_comp.pairs.tobytes() == r_mask.pairs.tobytes()
        assert r_comp.n_hits == r_mask.n_hits
        assert r_comp.n_overflow_retries == 0


def test_distributed_overflow_retry_counter(monkeypatch):
    """Forced undercapacity through the distributed stage: identical pairs,
    DistJoinResult.n_overflow_retries >= 1 (counter-regression)."""
    import jax
    from jax.sharding import Mesh

    from repro.core import distributed as D

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    rng = np.random.default_rng(1)
    data = rng.normal(size=(240, 6)).astype(np.float32)
    kw = dict(mesh=mesh, delta=4.0, metric="l1", k=96, n_dims=4,
              emit_pairs=True, backend="numpy", seed=3)
    r_ref = D.distributed_join(np.asarray(data), emit="mask", **kw)
    _force_undercapacity(monkeypatch)
    r_of = D.distributed_join(np.asarray(data), emit="compact", **kw)
    assert r_of.pairs.tobytes() == r_ref.pairs.tobytes()
    assert r_of.n_overflow_retries >= 1


@pytest.mark.slow
def test_distributed_compact_identity_8dev():
    """8 simulated devices (subprocess, test_distributed harness): compact
    emission through the real shard_map exchange is byte-identical to mask
    emission, including under a forced-overflow prior."""
    prog = (
        "import os\n"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n"
        + textwrap.dedent("""
        import json, numpy as np, jax, jax.numpy as jnp
        mesh = jax.make_mesh((8,), ("data",))
        from repro.core import distributed, verify
        rng = np.random.default_rng(0)
        data = np.concatenate([
            rng.normal(loc=c, scale=1.0, size=(200, 8)) for c in (0., 4., 9., 14.)
        ]).astype(np.float32)
        kw = dict(mesh=mesh, delta=6.0, metric="l1", k=128, p=16, n_dims=4,
                  emit_pairs=True, seed=0)
        r_mask = distributed.distributed_join(jnp.asarray(data), emit="mask", **kw)
        r_comp = distributed.distributed_join(jnp.asarray(data), emit="compact", **kw)
        verify.DEFAULT_EMIT_RATE = 1e-9
        verify.EMIT_SLACK = 1e-9
        verify._EMIT_FLOOR = 1
        r_of = distributed.distributed_join(jnp.asarray(data), emit="compact", **kw)
        print(json.dumps(dict(
            identical=bool(r_mask.pairs.tobytes() == r_comp.pairs.tobytes()),
            of_identical=bool(r_mask.pairs.tobytes() == r_of.pairs.tobytes()),
            emit=r_comp.emit,
            n_pairs=int(r_comp.pairs.shape[0]),
            hits_match=bool(r_comp.n_hits == r_mask.n_hits),
            of_retries=int(r_of.n_overflow_retries),
        )))
        """)
    )
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-4000:]
    res = json.loads(out.stdout.splitlines()[-1])
    assert res["identical"] and res["of_identical"]
    assert res["emit"] == "compact" and res["hits_match"]
    assert res["n_pairs"] > 0
    assert res["of_retries"] >= 1
