"""Hypothesis property tests for the partition invariants the fused
map-phase kernel relies on (previously only exercised indirectly through
end-to-end joins):

  * kernel boxes tile ℝⁿ — exactly one half-open cell contains any point,
    for any plan ``build_partition`` can produce (either strategy, any p);
  * whole ⊇ kernel — box-wise (lo/hi dominance) and object-wise (every
    object is a whole-member of its own kernel cell);
  * ``tighten`` preserves both — kernel boxes untouched, every object still
    whole-member of its own cell after the MBB shrink + δ re-expansion.
"""
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import distances, mapping, partition


def _make_plan(seed, p, n, strategy, delta=0.7, k=48, m=5):
    rng = np.random.default_rng(seed)
    pivots = rng.normal(size=(k, m)).astype(np.float32)
    smap = mapping.select_anchors(
        jax.random.PRNGKey(seed % 1000), jnp.asarray(pivots), n, "l1"
    )
    mapped = np.asarray(smap(jnp.asarray(pivots)))
    labels = None
    if strategy == "learning":
        d = np.asarray(
            distances.pairwise(jnp.asarray(pivots), jnp.asarray(pivots), "l1")
        )
        labels = partition.single_linkage_labels(d, min(2 * p, k))
    plan = partition.build_partition(mapped, p, delta, strategy, labels, seed)
    return plan, mapped, rng


def _probe_points(plan, mapped, rng, scale):
    """Random points, the mapped pivots themselves, and on-edge corners —
    half-open boxes make box edges the interesting inputs."""
    n = plan.n_dims
    pts = [rng.normal(scale=scale, size=(120, n)).astype(np.float32), mapped[:, :n].astype(np.float32)]
    corners = np.where(np.abs(np.asarray(plan.kernel_lo)) < 1e30, np.asarray(plan.kernel_lo), 0.0)
    pts.append(corners.astype(np.float32))
    return np.concatenate(pts)


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    p=st.integers(1, 40),
    n=st.integers(1, 5),
    strategy=st.sampled_from(["iterative", "learning"]),
    scale=st.floats(0.5, 30.0),
)
def test_kernel_boxes_tile_space(seed, p, n, strategy, scale):
    """Lemma 3 (1) as a property: exactly ONE kernel cell per ℝⁿ point —
    including points far outside the pivot hull and points ON box edges."""
    plan, mapped, rng = _make_plan(seed, p, n, strategy)
    pts = _probe_points(plan, mapped, rng, scale)
    inside = (pts[:, None, :] >= np.asarray(plan.kernel_lo)[None]) & (
        pts[:, None, :] < np.asarray(plan.kernel_hi)[None]
    )
    counts = inside.all(-1).sum(1)
    assert (counts == 1).all(), np.unique(counts)
    # assign_kernel agrees with the containment mask it summarizes
    cells = np.asarray(partition.assign_kernel(plan, jnp.asarray(pts)))
    assert inside.all(-1)[np.arange(len(pts)), cells].all()


@settings(max_examples=15, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    p=st.integers(1, 24),
    n=st.integers(1, 5),
    strategy=st.sampled_from(["iterative", "learning"]),
)
def test_whole_contains_kernel_and_tighten_preserves(seed, p, n, strategy):
    plan, mapped, rng = _make_plan(seed, p, n, strategy)
    # Box-wise dominance (pre-tighten: whole = kernel ± δ by construction).
    assert (np.asarray(plan.whole_lo) <= np.asarray(plan.kernel_lo)).all()
    assert (np.asarray(plan.whole_hi) >= np.asarray(plan.kernel_hi)).all()

    pts = jnp.asarray(_probe_points(plan, mapped, rng, scale=3.0))
    cells = partition.assign_kernel(plan, pts)
    member = np.asarray(partition.whole_membership(plan, pts))
    idx = np.arange(pts.shape[0])
    # Object-wise: whole ⊇ kernel — every object is W-member of its own cell.
    assert member[idx, np.asarray(cells)].all()

    tplan = partition.tighten(plan, pts, cells)
    # Kernel boxes (hence cell assignment) are untouched by tightening...
    np.testing.assert_array_equal(
        np.asarray(tplan.kernel_lo), np.asarray(plan.kernel_lo)
    )
    np.testing.assert_array_equal(
        np.asarray(tplan.kernel_hi), np.asarray(plan.kernel_hi)
    )
    tmember = np.asarray(partition.whole_membership(tplan, pts))
    # ...and the shrunk-then-δ-expanded whole boxes still cover every
    # object's own cell (the Lemma 4 precondition tighten must preserve).
    assert tmember[idx, np.asarray(cells)].all()
    # Tightening only ever removes members.
    assert (tmember <= member).all()
