"""String/set support (paper §6.2): q-gram filter completeness + MinHash."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import spjoin
from repro.data import synthetic, vectorize


@given(st.text("abcd", min_size=1, max_size=15), st.text("abcd", min_size=1, max_size=15))
@settings(max_examples=100, deadline=None)
def test_qgram_l1_lower_bounds_edit(a, b):
    """L1 on q-gram profiles <= 2q * edit distance (the classic filter), so
    joining profiles at 2q*delta is a COMPLETE candidate filter."""
    q = 2
    prof = vectorize.qgram_profile([a, b], q=q, dim=128)
    l1 = float(np.abs(prof[0] - prof[1]).sum())
    ed = vectorize.edit_distance(a, b)
    assert l1 <= 2 * q * ed + 1e-6


def test_edit_join_via_qgram_filter_is_complete():
    strs = synthetic.strings(150, seed=5)
    q, delta = 2, 2
    prof = vectorize.qgram_profile(strs, q=q, dim=96)
    cfg = spjoin.JoinConfig(delta=float(2 * q * delta), metric="l1",
                            k=64, p=4, n_dims=4)
    cand = spjoin.join(prof, cfg).pairs
    cand_set = {tuple(p) for p in cand.tolist()}
    # every true edit pair must be among the filtered candidates
    for i in range(len(strs)):
        for j in range(i + 1, len(strs)):
            if vectorize.edit_distance(strs[i], strs[j]) <= delta:
                assert (i, j) in cand_set, (strs[i], strs[j])


def test_minhash_estimates_jaccard():
    rng = np.random.default_rng(0)
    strs = synthetic.strings(60, seed=1)
    sets = vectorize.shingle_sets(strs, q=3)
    sigs = vectorize.minhash(sets, k=128)
    errs = []
    for _ in range(100):
        i, j = rng.integers(0, len(strs), 2)
        true = vectorize.jaccard_distance(sets[i], sets[j])
        est = float((sigs[i] != sigs[j]).mean())
        errs.append(abs(true - est))
    assert np.mean(errs) < 0.06, np.mean(errs)


def test_minhash_join_finds_near_duplicate_strings():
    strs = synthetic.strings(120, mutate=0.05, seed=2)
    sets = vectorize.shingle_sets(strs, q=3)
    sigs = vectorize.minhash(sets, k=64).astype(np.float32)
    cfg = spjoin.JoinConfig(delta=0.4, metric="jaccard_minhash", k=48, p=4, n_dims=4)
    res = spjoin.join(sigs, cfg)
    truth = spjoin.brute_force_pairs(sigs, 0.4, "jaccard_minhash")
    assert np.array_equal(res.pairs, truth)
    assert res.n_pairs > 0  # template corpus must contain near-dups
