"""ISSUE-8: incremental streaming joins, certified byte-identical.

The contract under test: for a fixed seed and ANY split of R into insertion
batches, the accumulated pair set (batch-0 build pairs ∪ every
``insert_batch`` return) is BYTE-IDENTICAL to a from-scratch join over the
concatenated rows — property-tested over random batch splits (1, 2, k,
per-row) × every exact metric × both executors (host ``MetricIndex``; the
kernel-metric subset additionally through ``DistIndex`` on a 1-device mesh
inline and an 8-device mesh under the ``slow`` marker, subprocess-isolated).

Also covered: the drift monitor's decision table (below-threshold → nothing;
re-plan → static permutation, pairs unchanged, balance improves; re-sample →
full rebuild, still exact), the no-build-reentry regression (module-attribute
call counters prove ``insert_batch`` never calls sampling / anchor selection /
partitioning unless re-sample fired), and the delta-radius / empty-delta /
single-row edge cases.
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cost_model, index as index_lib, mapping, partition, spjoin
from repro.core import placement as placement_lib
from repro.data.pipeline import StreamSource
from repro.kernels import ops as kops

EXACT_METRICS = ["l1", "l2", "linf", "angular", "jaccard_minhash"]
KERNEL_METRICS = [m for m in EXACT_METRICS if kops.supports_kernel(m)]
DELTAS = {"l1": 2.0, "l2": 1.0, "linf": 0.6, "angular": 0.15,
          "jaccard_minhash": 0.4}


def _rows(seed, metric, n):
    """Perturbed copies of a small base pool — within-base pairs sit well
    inside δ for every metric (angular included, where iid normals in 4-d
    almost never fall within 0.15), so the oracle is non-degenerate at any
    n down to the per-row arm's 18 rows."""
    rng = np.random.default_rng(seed)
    if metric == "jaccard_minhash":
        # within-base pairs differ in ≤ 2/16 signature slots (distance
        # 0.125 ≤ δ); cross-base signatures almost never collide
        base = rng.integers(0, 20, size=(max(n // 3, 1), 16)).astype(np.float32)
        r = base[rng.integers(0, base.shape[0], size=n)]
        flip = rng.integers(0, 16, size=n)
        r[np.arange(n), flip] = rng.integers(20, 40, size=n)
        return r.astype(np.float32)
    base = rng.normal(size=(max(n // 3, 1), 4))
    r = base[rng.integers(0, base.shape[0], size=n)]
    r = r + 0.05 * rng.normal(size=r.shape)
    return r.astype(np.float32)


def _cfg(metric, **kw):
    return spjoin.JoinConfig(delta=DELTAS[metric], metric=metric, k=48, p=8,
                             n_dims=3, **kw)


def _split(x, cuts):
    """Chop (n, m) rows at the given sorted cut points."""
    return [x[a:b] for a, b in zip([0, *cuts], [*cuts, x.shape[0]])]


def _oracle(full, cfg):
    return spjoin.brute_force_pairs(full, cfg.delta, cfg.metric)


# ---------------------------------------------------------------------------
# The exactness property: ANY batch split, host executor, every exact metric
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(data=st.data())
def test_any_split_byte_identical_host(data):
    metric = data.draw(st.sampled_from(EXACT_METRICS))
    seed = data.draw(st.integers(0, 10_000))
    shape = data.draw(st.sampled_from(["one", "two", "k", "per_row"]))
    n = 18 if shape == "per_row" else 48
    full = _rows(seed, metric, n)
    if shape == "one":
        cuts = []
    elif shape == "two":
        cuts = [data.draw(st.integers(1, n - 1))]
    elif shape == "k":
        cuts = sorted(data.draw(st.sets(st.integers(1, n - 1), min_size=2,
                                        max_size=5)))
    else:
        cuts = list(range(1, n))
    cfg = _cfg(metric)
    sess = spjoin.join_incremental(_split(full, cuts), cfg)
    ref = _oracle(full, cfg)
    assert ref.shape[0] > 0, "degenerate dataset: oracle found nothing"
    assert sess.pairs.tobytes() == ref.tobytes(), (
        f"split {cuts} diverged from from-scratch ({sess.pairs.shape} vs "
        f"{ref.shape})"
    )
    assert sess.stats[0].action == "build"
    assert sess.n_rows == n


@pytest.mark.parametrize("metric", EXACT_METRICS)
def test_incremental_matches_one_shot_join(metric, rng):
    """The session is also byte-identical to ``spjoin.join`` itself (not
    just the quadratic oracle) — the two executors share one answer."""
    full = _rows(3, metric, 60)
    cfg = _cfg(metric)
    sess = spjoin.join_incremental(_split(full, [25, 40]), cfg)
    one_shot = spjoin.join(full, cfg).pairs
    assert sess.pairs.tobytes() == one_shot.tobytes()


# ---------------------------------------------------------------------------
# Distributed executor: delta rides the serve stage, V buffers stay pinned
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", KERNEL_METRICS)
@pytest.mark.parametrize("cuts", [[30], [24, 40], [20, 30, 42]])
def test_any_split_byte_identical_distributed(metric, cuts):
    full = _rows(11, metric, 52)
    cfg = _cfg(metric)
    ref = _oracle(full, cfg)
    assert ref.shape[0] > 0
    batches = _split(full, cuts)
    sess = spjoin.IncrementalJoin(cfg)
    sess.insert(batches[0])
    di = sess.index.to_distributed(jax.make_mesh((1,), ("data",)))
    acc = [sess.pairs]
    for b in batches[1:]:
        pairs, stats = di.insert_batch(b)
        acc.append(pairs)
        assert stats.n_delta == b.shape[0]
    got = np.unique(np.concatenate(acc), axis=0)
    assert got.tobytes() == ref.tobytes()
    # serving after growth answers over the FULL accumulated set
    q = _rows(99, metric, 7)
    truth = index_lib.brute_force_query(full, q, cfg.delta, metric)
    assert di.query_batch(q).tobytes() == truth.tobytes()


def test_distributed_and_host_streams_agree(rng):
    """Same batches through both executors: identical per-batch returns,
    identical drift telemetry (the dist mirror shares the host control
    flow)."""
    full = _rows(5, "l2", 50)
    cfg = _cfg("l2")
    batches = _split(full, [20, 35])
    host = spjoin.IncrementalJoin(cfg)
    host.insert(batches[0])
    dist = spjoin.IncrementalJoin(cfg)
    dist.insert(batches[0])
    di = dist.index.to_distributed(jax.make_mesh((1,), ("data",)))
    for b in batches[1:]:
        hp, hs = host.index.insert_batch(b)
        dp, ds = di.insert_batch(b)
        assert hp.tobytes() == dp.tobytes()
        assert (hs.action, hs.n_cross_pairs, hs.n_self_pairs) == (
            ds.action, ds.n_cross_pairs, ds.n_self_pairs)
        assert np.isclose(hs.drift, ds.drift)


# ---------------------------------------------------------------------------
# Edge cases
# ---------------------------------------------------------------------------


def test_empty_delta_is_a_no_op(rng):
    full = _rows(0, "l2", 40)
    cfg = _cfg("l2")
    sess = spjoin.join_incremental([full], cfg)
    idx = sess.index
    before = (idx.n_rows, idx.data.tobytes(), idx.placement)
    pairs, stats = idx.insert_batch(np.zeros((0, 4), np.float32))
    assert pairs.shape == (0, 2) and pairs.dtype == np.int64
    assert stats.action == "none" and stats.n_delta == 0
    assert (idx.n_rows, idx.data.tobytes()) == before[:2]
    assert idx.placement is before[2]  # not even a re-plan
    assert idx.n_batches == 0  # empty deltas don't count as batches


def test_single_row_deltas_accumulate_exactly(rng):
    full = _rows(7, "l1", 30)
    cfg = _cfg("l1")
    sess = spjoin.IncrementalJoin(cfg)
    sess.insert(full[:20])
    for i in range(20, 30):
        pairs, stats = sess.insert(full[i : i + 1])
        assert stats.n_delta == 1
        # a single delta row can only create cross pairs, never ΔΔ ones
        assert stats.n_self_pairs == 0
    assert sess.pairs.tobytes() == _oracle(full, cfg).tobytes()


def test_pairs_exactly_at_delta_radius_survive_the_stream(rng):
    """D(x, y) == δ pairs (the ≤ boundary) must be found whether the two
    rows arrive in one batch or are split across the stream."""
    delta = 0.5  # exactly representable: no fp slop in the oracle either
    base = rng.normal(size=(24, 4)).astype(np.float32)
    probe = base[0].copy()
    probe[0] += np.float32(delta)  # L∞ and L1 distance exactly δ from base[0]
    full = np.concatenate([base, probe[None]])
    for metric in ("l1", "linf"):
        cfg = spjoin.JoinConfig(delta=delta, metric=metric, k=32, p=4, n_dims=3)
        ref = spjoin.brute_force_pairs(full, delta, metric)
        assert (ref == [0, 24]).all(1).any(), "boundary pair missing from oracle"
        together = spjoin.join_incremental([full], cfg)
        split = spjoin.join_incremental([full[:24], full[24:]], cfg)
        assert together.pairs.tobytes() == ref.tobytes()
        assert split.pairs.tobytes() == ref.tobytes()


def test_insert_batch_validates_shapes(rng):
    sess = spjoin.join_incremental([_rows(1, "l2", 30)], _cfg("l2"))
    with pytest.raises(ValueError, match="insert_batch"):
        sess.index.insert_batch(np.zeros((3, 9), np.float32))
    with pytest.raises(ValueError, match="insert_batch"):
        sess.index.insert_batch(np.zeros(4, np.float32))


def test_stream_source_split_invariance():
    src = StreamSource(4, seed=13, dist="clustered")
    full = src.prefix(40)
    chopped = np.concatenate([src.batch(0, 7), src.batch(7, 13), src.batch(20, 20)])
    assert chopped.tobytes() == full.tobytes()
    assert src.batch(5, 0).shape == (0, 4)
    with pytest.raises(ValueError, match="dist"):
        StreamSource(4, dist="cauchy")


# ---------------------------------------------------------------------------
# Regression: insert_batch never re-enters the build control plane
# ---------------------------------------------------------------------------


def _count_build_calls(monkeypatch):
    counts = {"fit": 0, "draw": 0, "anchors": 0, "partition": 0}
    wrap = lambda key, fn: (lambda *a, **k: (counts.__setitem__(key, counts[key] + 1), fn(*a, **k))[1])
    monkeypatch.setattr(spjoin, "fit_node_stats", wrap("fit", spjoin.fit_node_stats))
    monkeypatch.setattr(spjoin, "draw_pivots", wrap("draw", spjoin.draw_pivots))
    monkeypatch.setattr(mapping, "select_anchors", wrap("anchors", mapping.select_anchors))
    monkeypatch.setattr(partition, "build_partition", wrap("partition", partition.build_partition))
    return counts


def test_insert_batch_performs_no_sampling_or_partitioning(rng, monkeypatch):
    counts = _count_build_calls(monkeypatch)
    full = _rows(2, "l2", 45)
    cfg = _cfg("l2")
    sess = spjoin.IncrementalJoin(cfg)
    sess.insert(full[:20])
    after_build = dict(counts)
    assert all(v == 1 for v in after_build.values()), after_build

    # Thresholds pinned above any possible drift (TV distance ≤ 1): every
    # insert — including ones that would naturally trip a re-plan — must
    # stay entirely out of the build control plane.
    sess2 = spjoin.IncrementalJoin(cfg, replan_drift=1.5, resample_drift=2.0)
    sess2.index = sess.index
    sess2._pairs = sess.pairs
    sess2.insert(full[20:30])
    sess2.insert(full[30:])
    assert counts == after_build, f"insert_batch re-entered the build: {counts}"
    assert sess2.pairs.tobytes() == _oracle(full, cfg).tobytes()


def test_resample_is_the_only_path_back_into_the_build(rng, monkeypatch):
    counts = _count_build_calls(monkeypatch)
    full = _rows(4, "l2", 40)
    cfg = _cfg("l2")
    idx = index_lib.build_index(full[:25], cfg)
    assert counts["draw"] == 1

    # Forced re-sample (thresholds at 0 ⇒ any drift fires) WITH a rebuild
    # config: the control plane runs exactly once more, and the stream stays
    # exact afterwards.
    pairs1, stats = idx.insert_batch(
        full[25:], replan_drift=0.0, resample_drift=0.0, rebuild_cfg=cfg
    )
    assert stats.action == "resample" and not stats.resample_due
    assert counts["draw"] == 2 and counts["partition"] == 2
    base_pairs = spjoin.brute_force_pairs(full[:25], cfg.delta, cfg.metric)
    got = np.unique(np.concatenate([base_pairs, pairs1]), axis=0)
    assert got.tobytes() == _oracle(full, cfg).tobytes()
    # the rebuilt index answers queries over the full set exactly
    q = _rows(77, "l2", 9)
    truth = index_lib.brute_force_query(full, q, cfg.delta, "l2")
    assert idx.query_batch(q).tobytes() == truth.tobytes()


# ---------------------------------------------------------------------------
# Drift monitor: decision table + balance improvement
# ---------------------------------------------------------------------------


def test_drift_action_decision_table():
    assert placement_lib.drift_action(0.0) == "none"
    assert placement_lib.drift_action(placement_lib.REPLAN_DRIFT) == "replan"
    assert placement_lib.drift_action(placement_lib.RESAMPLE_DRIFT) == "resample"
    assert placement_lib.drift_action(0.3, 0.1, 0.5) == "replan"
    assert placement_lib.drift_action(0.6, 0.1, 0.5) == "resample"
    with pytest.raises(ValueError):
        placement_lib.drift_action(0.2, replan_threshold=0.5, resample_threshold=0.1)


def test_load_drift_metric_properties():
    p = np.array([1.0, 2.0, 3.0])
    assert cost_model.load_drift(p, p) == 0.0
    assert cost_model.load_drift(p, 10 * p) == 0.0  # scale-free
    assert cost_model.load_drift(np.array([1.0, 0.0]), np.array([0.0, 1.0])) == 1.0
    assert cost_model.load_drift(np.zeros(3), np.zeros(3)) == 0.0
    assert cost_model.load_drift(np.zeros(3), p) == 1.0
    with pytest.raises(ValueError):
        cost_model.load_drift(p, np.ones(4))


def test_device_loads_under_matches_plan_on_its_own_loads():
    loads = np.array([5.0, 1.0, 9.0, 2.0, 4.0, 7.0, 1.0, 3.0])
    pl = placement_lib.plan_placement(loads, 4, strategy="lpt")
    np.testing.assert_allclose(
        placement_lib.device_loads_under(pl, loads), pl.device_loads
    )


def test_below_threshold_drift_fires_nothing(rng):
    full = _rows(21, "l2", 44)
    cfg = _cfg("l2")
    sess = spjoin.IncrementalJoin(cfg, replan_drift=0.999, resample_drift=1.0)
    sess.insert(full[:30])
    plan_before = sess.index.placement
    _, stats = sess.index.insert_batch(full[30:], replan_drift=0.999,
                                       resample_drift=1.0)
    assert stats.action == "none" and not stats.resample_due
    assert sess.index.placement is plan_before  # untouched, not even rebuilt
    assert 0.0 <= stats.drift < 0.999


def test_replan_fires_on_drift_improves_balance_and_keeps_pairs(rng):
    """The skew arm: the stream starts in one cluster and drifts into
    another — observed loads leave the build-time prediction, the cheap
    action fires, per-device balance improves, and the pair set is the
    byte-identical from-scratch answer (a re-plan is a static permutation;
    it can never touch WHICH pairs exist)."""
    src = StreamSource(4, seed=3, dist="clustered", n_clusters=3)
    head = src.prefix(60)
    drift_rng = np.random.default_rng(17)
    # the shifted tail: everything lands far from the head's mass
    tail = (head[:30] + np.float32(4.0)).astype(np.float32)
    tail += drift_rng.normal(scale=0.05, size=tail.shape).astype(np.float32)
    full = np.concatenate([head, tail])
    cfg = spjoin.JoinConfig(delta=1.0, metric="l2", k=64, p=8, n_dims=3)
    sess = spjoin.IncrementalJoin(cfg)
    sess.insert(head)
    pairs, stats = sess.index.insert_batch(tail)  # default thresholds
    sess._pairs = np.unique(np.concatenate([sess.pairs, pairs]), axis=0)
    assert stats.drift >= placement_lib.REPLAN_DRIFT, stats.drift
    assert stats.action in ("replan", "resample")
    if stats.action == "replan":
        assert stats.balance_std_after <= stats.balance_std_before
    assert sess.pairs.tobytes() == _oracle(full, cfg).tobytes()


def test_resample_worthy_drift_without_config_downgrades_to_replan(rng):
    full = _rows(9, "l2", 40)
    cfg = _cfg("l2")
    sess = spjoin.IncrementalJoin(cfg)
    sess.insert(full[:25])
    _, stats = sess.index.insert_batch(full[25:], replan_drift=0.0,
                                       resample_drift=0.0)  # no rebuild_cfg
    assert stats.action == "replan" and stats.resample_due
    # a re-plan re-scored the placement on the observed loads
    assert stats.balance_std_after <= stats.balance_std_before + 1e-9


# ---------------------------------------------------------------------------
# 8-device incremental identity (slow tier, subprocess-isolated)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_incremental_identity_8dev_subprocess():
    """The full streaming loop on an 8-device mesh: build 4-dev index, pin
    on 8 (cheap re-plan), stream three deltas through the serve-stage cross
    path, accumulated pairs byte-identical to the quadratic oracle."""
    prog = "import os\nos.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n" + textwrap.dedent("""
    import json, numpy as np, jax
    from repro.core import index as index_lib, spjoin
    rng = np.random.default_rng(0)
    full = rng.normal(size=(700, 6)).astype(np.float32)
    cfg = spjoin.JoinConfig(delta=1.0, metric="l2", k=128, p=16, n_dims=4)
    idx = index_lib.build_index(full[:400], cfg, n_devices=4)
    base = spjoin.brute_force_pairs(full[:400], cfg.delta, cfg.metric)
    mesh = jax.make_mesh((8,), ("data",))
    di = idx.to_distributed(mesh)
    acc = [base]
    actions = []
    for lo, hi in ((400, 550), (550, 650), (650, 700)):
        pairs, stats = di.insert_batch(full[lo:hi])
        acc.append(pairs)
        actions.append(stats.action)
    got = np.unique(np.concatenate(acc), axis=0)
    ref = spjoin.brute_force_pairs(full, cfg.delta, cfg.metric)
    q = rng.normal(size=(120, 6)).astype(np.float32)
    truth = index_lib.brute_force_query(full, q, cfg.delta, "l2")
    print(json.dumps({
        "identical": bool(np.array_equal(got, ref)),
        "serve_exact": bool(np.array_equal(di.query_batch(q), truth)),
        "n_pairs": int(ref.shape[0]),
        "actions": actions,
    }))
    """)
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-4000:]
    res = json.loads(out.stdout.splitlines()[-1])
    assert res["identical"] and res["serve_exact"]
    assert res["n_pairs"] > 0
