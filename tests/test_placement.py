"""Placement planner (core.placement) + executor threading tests.

Three layers:

1. Planner properties — pure host logic on synthetic loads: the certified
   makespan bound, load conservation (splitting included), determinism,
   V-slab coverage, and the hot-cell skew regression.
2. Executor byte-identity — fixed-seed pair sets must be IDENTICAL with
   placement "lpt" vs "contiguous" on both executors (self-join and R×S);
   placement moves work between devices, never changes results.
3. Multi-device (slow) — 8 simulated devices in a subprocess: identity +
   exactness vs the brute-force oracle, and the balance claim (LPT's
   measured per-device load std beats contiguous on skewed data).
"""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import placement, spjoin


# ---------------------------------------------------------------------------
# 1. Planner properties
# ---------------------------------------------------------------------------


def _plans_equal(a: placement.PlacementPlan, b: placement.PlacementPlan) -> bool:
    for f in dataclasses.fields(placement.PlacementPlan):
        va, vb = getattr(a, f.name), getattr(b, f.name)
        if isinstance(va, np.ndarray):
            if not np.array_equal(va, vb):
                return False
        elif va != vb:
            return False
    return True


def test_lpt_certified_bound_and_conservation():
    """Random load vectors: makespan ≤ the plan's certified bound, device
    loads conserve the input loads (slabs partition their cell's load), and
    the same loads always produce the identical plan."""
    rng = np.random.default_rng(7)
    for trial in range(60):
        d = int(rng.choice([2, 3, 4, 8]))
        p = int(rng.choice([4, 8, 16, 32]))
        kind = trial % 3
        if kind == 0:
            loads = rng.uniform(0.0, 100.0, p)
        elif kind == 1:  # heavy-tailed — the regime that matters
            loads = rng.pareto(1.5, p) * 10.0
        else:  # ties + zeros
            loads = rng.choice([0.0, 1.0, 5.0, 5.0, 50.0], p)
        for split in (True, False):
            pl = placement.plan_placement(loads, d, "lpt", split=split)
            assert pl.makespan <= pl.certified_bound * (1 + 1e-9), (
                trial, split, pl.makespan, pl.certified_bound)
            assert pl.makespan_ratio >= 1.0 - 1e-9
            np.testing.assert_allclose(
                pl.device_loads.sum(), loads.sum(), rtol=1e-9)
            np.testing.assert_allclose(
                pl.slot_load.sum(), loads.sum(), rtol=1e-9)
            # determinism: same loads in, byte-identical plan out
            again = placement.plan_placement(loads, d, "lpt", split=split)
            assert _plans_equal(pl, again)


def test_contiguous_is_identity():
    """The contiguous strategy reproduces the historical layout: slot == cell,
    identity permutation, no slabs — the executor's byte-compat baseline."""
    loads = np.array([5.0, 1.0, 9.0, 2.0, 0.0, 3.0, 7.0, 1.0])
    pl = placement.plan_placement(loads, 4, "contiguous")
    assert pl.n_slots == 8 and pl.n_split_cells == 0
    np.testing.assert_array_equal(pl.dispatch_of_slot, np.arange(8))
    np.testing.assert_array_equal(pl.slot_cell, np.arange(8))
    np.testing.assert_array_equal(pl.cell_of_dispatch, np.arange(8))
    # device d gets cells [2d, 2d+1] — h // (p/D)
    np.testing.assert_array_equal(pl.device_of_slot, np.arange(8) // 2)


def test_padding_slots_round_up_to_device_multiple():
    pl = placement.plan_placement(np.ones(5), 4, "lpt", split=False)
    assert pl.n_slots == 8 and pl.n_slots % 4 == 0
    assert (pl.slot_cell == -1).sum() == 3
    assert pl.slot_load[pl.slot_cell == -1].sum() == 0.0


def test_split_slabs_cover_v_exactly_once():
    """Heavy-cell splitting partitions V: summed over a cell's slabs, the
    per-(shard, slab) exact counts reproduce the per-(shard, cell) counts —
    no row lost, none duplicated (W is replicated by design)."""
    rng = np.random.default_rng(3)
    loads = np.array([400.0, 10.0, 5.0, 1.0, 80.0, 2.0, 0.0, 3.0])
    pl = placement.plan_placement(loads, 4, "lpt")
    assert pl.n_split_cells >= 1 and int(pl.cell_n_slabs.max()) > 1
    v_cnt = rng.integers(0, 50, size=(8, 8))  # (shards, cells)
    w_cnt = rng.integers(0, 70, size=(8, 8))
    v_slot, w_slot = placement.slot_exact_counts(pl, v_cnt, w_cnt)
    per_cell = np.zeros_like(v_cnt)
    for slot in range(pl.n_slots):
        h = pl.slot_cell[slot]
        if h >= 0:
            per_cell[:, h] += v_slot[:, slot]
    np.testing.assert_array_equal(per_cell, v_cnt)  # V covered exactly once
    # W replicates into every slab of its cell
    for slot in range(pl.n_slots):
        h = pl.slot_cell[slot]
        expect = 0 if h < 0 else w_cnt[:, h]
        np.testing.assert_array_equal(w_slot[:, slot], expect)
    # splitting caps the worst slot strictly below the worst cell here
    assert v_slot.max() <= v_cnt.max()


def test_skew_regression_hot_cell_not_with_heavy_partner():
    """One 10× hot cell: LPT must isolate it — no other heavy cell may share
    its device (contiguous pairs it with a neighbour and straggles)."""
    loads = np.array([100.0, 10.0, 10.0, 10.0, 10.0, 10.0, 1.0, 1.0])
    lpt = placement.plan_placement(loads, 4, "lpt", split=False)
    ctg = placement.plan_placement(loads, 4, "contiguous")
    hot_dev = int(lpt.device_of_slot[lpt.slot_cell.tolist().index(0)])
    mates = lpt.slot_cell[(lpt.device_of_slot == hot_dev) & (lpt.slot_cell != 0)]
    assert all(loads[h] < 10.0 for h in mates if h >= 0), mates
    assert lpt.makespan < ctg.makespan  # 101 vs 110 here
    assert lpt.balance_std < ctg.balance_std
    # With splitting the hot cell sheds slabs instead; bound still certified.
    lpt_split = placement.plan_placement(loads, 4, "lpt", split=True)
    assert lpt_split.makespan <= lpt.makespan + 1e-9
    assert lpt_split.makespan <= lpt_split.certified_bound * (1 + 1e-9)


def test_planner_input_validation():
    with pytest.raises(ValueError, match="strategy"):
        placement.plan_placement(np.ones(4), 2, "round_robin")
    with pytest.raises(ValueError, match="finite"):
        placement.plan_placement(np.array([1.0, np.nan]), 2)
    with pytest.raises(ValueError, match="finite"):
        placement.plan_placement(np.array([1.0, -2.0]), 2)


# ---------------------------------------------------------------------------
# 2. Executor byte-identity (1 device / single host — fast tier)
# ---------------------------------------------------------------------------


def _skewed(n, m, seed=3):
    from repro.data import synthetic

    return synthetic.mixture(n, m, n_clusters=4, skew=0.7, seed=seed)


def test_reference_executor_placement_report_and_identity(rng):
    data = _skewed(400, 6)
    cfg = spjoin.JoinConfig(delta=2.0, metric="l1", k=128, p=8, n_dims=4)
    r_lpt = spjoin.join(data, cfg)
    r_ctg = spjoin.join(data, dataclasses.replace(cfg, placement="contiguous"))
    assert r_lpt.pairs.tobytes() == r_ctg.pairs.tobytes()
    np.testing.assert_array_equal(r_lpt.pairs, spjoin.brute_force_pairs(data, 2.0, "l1"))
    # telemetry populated: plan over n_nodes=4 simulated devices
    for r in (r_lpt, r_ctg):
        assert r.placement_plan is not None and r.device_loads.shape == (4,)
        assert r.makespan_ratio >= 1.0 - 1e-9
        assert int(r.per_cell_verified.sum()) == r.n_verifications
    assert r_lpt.placement_plan.strategy == "lpt"
    assert r_lpt.balance_std <= r_ctg.balance_std + 1e-9
    # same loads -> same plan: the two executors share one planner, so plan
    # parity reduces to planner determinism on the cost-model loads
    replay = placement.plan_placement(
        r_lpt.placement_plan.cell_loads, 4, strategy="lpt"
    )
    assert _plans_equal(replay, r_lpt.placement_plan)


def test_distributed_placement_on_off_byte_identical_1dev(rng):
    from repro.core import distributed

    mesh = jax.make_mesh((1,), ("data",))
    data = jnp.asarray(_skewed(260, 5), jnp.float32)
    rs = {}
    for strategy in ("lpt", "contiguous"):
        res = distributed.distributed_join(
            data, mesh=mesh, delta=2.0, metric="l1", k=96, p=8, n_dims=3,
            emit_pairs=True, placement=strategy, seed=0,
        )
        rs[strategy] = res
        assert res.overflow == 0
        assert res.device_loads.shape == (1,)
        np.testing.assert_allclose(
            res.device_loads.sum(), res.n_verifications, rtol=1e-6)
        np.testing.assert_allclose(
            res.per_cell_verified.sum(), res.n_verifications, rtol=1e-6)
    assert rs["lpt"].pairs.tobytes() == rs["contiguous"].pairs.tobytes()
    assert rs["lpt"].n_verifications == rs["contiguous"].n_verifications
    np.testing.assert_array_equal(
        rs["lpt"].per_cell_verified, rs["contiguous"].per_cell_verified)


def test_distributed_placement_rs_byte_identical_1dev(rng):
    from repro.core import distributed
    from repro.data import synthetic

    mesh = jax.make_mesh((1,), ("data",))
    r, s = synthetic.rs_mixture(120, 300, 5, n_clusters=4, skew=0.6, seed=1)
    truth = spjoin.brute_force_pairs(r, 3.0, "l1", s=s)
    rs = {}
    for strategy in ("lpt", "contiguous"):
        res = distributed.distributed_join(
            jnp.asarray(r), s=jnp.asarray(s), mesh=mesh, delta=3.0,
            metric="l1", k=96, p=8, n_dims=3, emit_pairs=True,
            placement=strategy, seed=0,
        )
        rs[strategy] = res
        np.testing.assert_array_equal(res.pairs, truth)
    assert rs["lpt"].pairs.tobytes() == rs["contiguous"].pairs.tobytes()


# ---------------------------------------------------------------------------
# 3. Multi-device (slow): 8 simulated devices in a subprocess
# ---------------------------------------------------------------------------


def _run_sub(code: str) -> dict:
    prog = (
        "import os\n"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n"
        + textwrap.dedent(code)
    )
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.splitlines()[-1])


@pytest.mark.slow
def test_distributed_placement_8dev_identity_exact_and_balanced():
    """Self-join on skewed data, 8 devices: LPT vs contiguous pair sets are
    byte-identical AND exact, splitting engages, no overflow, and the
    measured per-device balance improves."""
    res = _run_sub("""
    import json, numpy as np, jax, jax.numpy as jnp
    mesh = jax.make_mesh((8,), ("data",))
    from repro.core import distributed, spjoin
    from repro.data import synthetic
    data = synthetic.mixture(1200, 8, n_clusters=5, skew=0.85, seed=3)
    truth = spjoin.brute_force_pairs(data, 2.0, "l1")
    out = {}
    pair_bytes = {}
    for strategy in ("contiguous", "lpt"):
        r = distributed.distributed_join(
            jnp.asarray(data), mesh=mesh, delta=2.0, metric="l1", k=256,
            p=16, n_dims=4, emit_pairs=True, placement=strategy, seed=0)
        pair_bytes[strategy] = r.pairs.tobytes()
        out[strategy] = dict(
            exact=bool(np.array_equal(r.pairs, truth)),
            overflow=int(r.overflow),
            balance_std=float(r.balance_std),
            makespan_ratio=float(r.makespan_ratio),
            n_split=int(r.placement_plan.n_split_cells),
            certified_ok=bool(
                r.placement_plan.makespan
                <= r.placement_plan.certified_bound * (1 + 1e-9)),
            verif=int(r.n_verifications))
    out["identical"] = pair_bytes["contiguous"] == pair_bytes["lpt"]
    print(json.dumps(out))
    """)
    assert res["identical"], res
    for strategy in ("contiguous", "lpt"):
        assert res[strategy]["exact"], res
        assert res[strategy]["overflow"] == 0, res
        assert res[strategy]["certified_ok"], res
    assert res["lpt"]["verif"] == res["contiguous"]["verif"]
    assert res["lpt"]["n_split"] >= 1, res  # skew must trigger splitting
    assert res["lpt"]["balance_std"] < res["contiguous"]["balance_std"], res
    assert res["lpt"]["makespan_ratio"] < res["contiguous"]["makespan_ratio"], res


@pytest.mark.slow
def test_distributed_placement_rs_8dev_identity_and_exact():
    res = _run_sub("""
    import json, numpy as np, jax, jax.numpy as jnp
    mesh = jax.make_mesh((8,), ("data",))
    from repro.core import distributed, spjoin
    from repro.data import synthetic
    r, s = synthetic.rs_mixture(200, 900, 8, n_clusters=5, skew=0.7, seed=1)
    truth = spjoin.brute_force_pairs(r, 3.0, "l1", s=s)
    out = {}
    pair_bytes = {}
    for strategy in ("contiguous", "lpt"):
        rr = distributed.distributed_join(
            jnp.asarray(r), s=jnp.asarray(s), mesh=mesh, delta=3.0,
            metric="l1", k=192, p=16, n_dims=4, emit_pairs=True,
            placement=strategy, seed=0)
        pair_bytes[strategy] = rr.pairs.tobytes()
        out[strategy] = dict(exact=bool(np.array_equal(rr.pairs, truth)),
                             overflow=int(rr.overflow))
    out["identical"] = pair_bytes["contiguous"] == pair_bytes["lpt"]
    print(json.dumps(out))
    """)
    assert res["identical"], res
    for strategy in ("contiguous", "lpt"):
        assert res[strategy]["exact"] and res[strategy]["overflow"] == 0, res
