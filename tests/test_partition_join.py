"""Partitioning invariants (Lemma 3/4) + end-to-end join exactness —
the system's central property, swept with hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import baselines, distances, mapping, partition, spjoin
from repro.data import dedup as dedup_lib


def _plan(rng, k=64, p=7, n=3, delta=1.5, strategy="iterative"):
    pivots = rng.normal(size=(k, 4)).astype(np.float32)
    smap = mapping.select_anchors(jax.random.PRNGKey(0), jnp.asarray(pivots), n, "l1")
    mapped = np.asarray(smap(jnp.asarray(pivots)))
    labels = partition.single_linkage_labels(
        np.asarray(distances.pairwise(jnp.asarray(pivots), jnp.asarray(pivots), "l1")), 8
    ) if strategy == "learning" else None
    return partition.build_partition(mapped, p, delta, strategy, labels), smap


def test_kernel_cells_tile_space(rng):
    """Lemma 3 (1): every point belongs to exactly ONE kernel cell."""
    plan, smap = _plan(rng)
    x = jnp.asarray(rng.normal(scale=3.0, size=(500, 4)), jnp.float32)
    xm = smap(x)
    inside = (np.asarray(xm)[:, None, :] >= np.asarray(plan.kernel_lo)[None]) & (
        np.asarray(xm)[:, None, :] < np.asarray(plan.kernel_hi)[None]
    )
    counts = inside.all(-1).sum(1)
    assert (counts == 1).all(), np.unique(counts)


def test_whole_contains_kernel(rng):
    plan, smap = _plan(rng)
    assert (np.asarray(plan.whole_lo) <= np.asarray(plan.kernel_lo)).all()
    assert (np.asarray(plan.whole_hi) >= np.asarray(plan.kernel_hi)).all()


def test_iterative_balances_kernel_sizes(rng):
    pivots = rng.normal(size=(512, 4)).astype(np.float32)
    smap = mapping.select_anchors(jax.random.PRNGKey(0), jnp.asarray(pivots), 4, "l1")
    mapped = np.asarray(smap(jnp.asarray(pivots)))
    plan = partition.build_partition(mapped, 8, 0.5, "iterative")
    cells = np.asarray(partition.assign_kernel(plan, jnp.asarray(mapped)))
    sizes = np.bincount(cells, minlength=8)
    assert sizes.max() <= 2 * sizes.min() + 8, sizes  # equi-depth splits


def test_fft_anchors_distinct_under_duplicate_pivots(rng):
    """Duplicate pivots (generative pivots on near-discrete data) must not
    collapse target-space dimensions: with enough distinct values the FFT
    anchors are all distinct; with fewer than n the distinct set is exhausted
    first and the residual falls back to random fill (no crash, no row-0
    collapse)."""
    base = rng.normal(size=(4, 3)).astype(np.float32)
    pivots = jnp.asarray(np.repeat(base, 8, axis=0))  # 32 rows, 4 distinct
    smap = mapping.select_anchors(jax.random.PRNGKey(0), pivots, 4, "l1")
    assert np.unique(np.asarray(smap.anchors), axis=0).shape[0] == 4
    smap6 = mapping.select_anchors(jax.random.PRNGKey(0), pivots, 6, "l1")
    a6 = np.asarray(smap6.anchors)
    assert a6.shape == (6, 3)
    assert np.unique(a6, axis=0).shape[0] == 4  # every distinct value chosen


def test_fft_anchors_pseudo_metric_zero_distance_twins(rng):
    """Scaled copies are value-distinct but angular-distance 0: the distinct
    count must be metric-aware, so the residual falls back to random fill
    instead of silently collapsing every mapped dimension."""
    v = rng.normal(size=(1, 3)).astype(np.float32)
    pivots = jnp.asarray(np.concatenate([v * c for c in (1.0, 2.0, 3.0, 4.0)]))
    smap = mapping.select_anchors(jax.random.PRNGKey(0), pivots, 3, "angular")
    assert np.asarray(smap.anchors).shape == (3, 3)  # no crash, full shape


def test_mapping_is_lipschitz(rng):
    """|o^n_x[i] - o^n_y[i]| <= D(x, y) — the Lemma 4 precondition."""
    x = jnp.asarray(rng.normal(size=(50, 6)), jnp.float32)
    piv = jnp.asarray(rng.normal(size=(16, 6)), jnp.float32)
    smap = mapping.select_anchors(jax.random.PRNGKey(0), piv, 5, "l1")
    xm = np.asarray(smap(x))
    d = np.asarray(distances.pairwise(x, x, "l1"))
    for i in range(10):
        for j in range(10):
            assert (np.abs(xm[i] - xm[j]) <= d[i, j] + 1e-4).all()


@pytest.mark.slow  # long property sweep (~30s): nightly tier; the fast tier
# covers the same invariant via tests/test_verify_engine.py parity tests
@settings(max_examples=12, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    metric=st.sampled_from(["l1", "l2", "linf"]),
    sampler=st.sampled_from(["random", "distribution", "generative"]),
    partitioner=st.sampled_from(["iterative", "learning"]),
    delta_q=st.floats(0.005, 0.05),
)
def test_join_equals_brute_force(seed, metric, sampler, partitioner, delta_q):
    """THE paper invariant: SP-Join output == brute-force join, for any
    sampler/partitioner/metric/threshold."""
    rng = np.random.default_rng(seed)
    data = np.concatenate([
        rng.normal(loc=c, scale=1.0, size=(120, 5)) for c in (0.0, 4.0, 9.0)
    ]).astype(np.float32)
    d = np.asarray(distances.pairwise(jnp.asarray(data), jnp.asarray(data), metric))
    delta = float(np.quantile(d[np.triu_indices(len(data), 1)], delta_q))
    cfg = spjoin.JoinConfig(
        delta=delta, metric=metric, sampler=sampler, partitioner=partitioner,
        k=96, p=6, n_dims=3, seed=seed,
    )
    res = spjoin.join(data, cfg)
    truth = spjoin.brute_force_pairs(data, delta, metric)
    assert np.array_equal(res.pairs, truth), (res.pairs.shape, truth.shape)


def test_join_on_minhash_metric(rng):
    sigs = rng.integers(0, 50, size=(150, 32)).astype(np.float32)
    cfg = spjoin.JoinConfig(delta=0.5, metric="jaccard_minhash", k=64, p=4, n_dims=3)
    res = spjoin.join(sigs, cfg)
    truth = spjoin.brute_force_pairs(sigs, 0.5, "jaccard_minhash")
    assert np.array_equal(res.pairs, truth)


def test_tighten_preserves_exactness(rng):
    data = rng.normal(size=(300, 4)).astype(np.float32)
    for tighten in (False, True):
        cfg = spjoin.JoinConfig(delta=1.0, metric="l2", k=64, p=8, n_dims=3,
                                tighten=tighten)
        res = spjoin.join(data, cfg)
        truth = spjoin.brute_force_pairs(data, 1.0, "l2")
        assert np.array_equal(res.pairs, truth)


def test_tighten_reduces_verifications(rng):
    data = np.concatenate([
        rng.normal(loc=c, scale=0.5, size=(250, 4)) for c in (0, 6, 12, 18)
    ]).astype(np.float32)
    r_loose = spjoin.join(data, spjoin.JoinConfig(delta=1.0, metric="l1", k=128,
                                                  p=8, n_dims=4, tighten=False))
    r_tight = spjoin.join(data, spjoin.JoinConfig(delta=1.0, metric="l1", k=128,
                                                  p=8, n_dims=4, tighten=True))
    assert r_tight.n_verifications <= r_loose.n_verifications


def test_ball_join_baseline_exact(rng):
    data = rng.normal(size=(250, 5)).astype(np.float32)
    res = baselines.ball_join(data, 1.2, "l2", n_pivots=10)
    truth = spjoin.brute_force_pairs(data, 1.2, "l2")
    assert np.array_equal(res.pairs, truth)


def test_dedup_removes_near_duplicates(rng):
    base = rng.normal(size=(60, 8)).astype(np.float32)
    dups = base[:20] + rng.normal(scale=1e-3, size=(20, 8)).astype(np.float32)
    data = np.concatenate([base, dups])
    res = dedup_lib.dedup(data, delta=0.05, metric="l2")
    assert res.n_duplicates == 20, res.n_duplicates
    # representatives keep one copy of each duplicated row
    kept = data[res.keep_mask]
    assert kept.shape[0] == 60


def test_cost_model_lower_bound(rng):
    from repro.core import cost_model
    v = rng.integers(1, 100, size=16)
    w = v + rng.integers(0, 50, size=16)
    c = cost_model.partition_cost(v, w)
    assert c.inner >= cost_model.lower_bound_inner(int(v.sum()), 16) - 1e-6
    assert c.total == pytest.approx(c.inner + c.outer)
