"""Parity & regression suite for the fused map-phase kernel (map + assign +
membership — ``kernels/mapassign.py`` via ``kernels.ops.map_assign``).

The fused op must be a pure optimization: cells / membership / mapped
coordinates agree across numpy|pallas|auto backends, tile sizes, metrics and
padded (invalid-row) shards, and fixed-seed end-to-end pair sets are
byte-identical on both executors with the fused map on and off."""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mapping, partition, spjoin
from repro.kernels import ops, ref

# Join-level metrics (the 6 of core.distances); the first four have a Pallas
# kernel — angular / jaccard_minhash exercise the two-pass fallback gate.
JOIN_METRICS = ("l1", "l2", "linf", "cosine", "angular", "jaccard_minhash")


def _plan(rng, metric="l1", k=96, p=11, n=5, m=7, delta=0.8, seed=0):
    """A partition plan + space map over random pivots (anchor metric falls
    back to l2 for 'dot', which is a kernel metric but not a join metric)."""
    anchor_metric = metric if metric in ("l1", "l2", "linf", "cosine") else "l2"
    pivots = rng.normal(size=(k, m)).astype(np.float32)
    smap = mapping.select_anchors(
        jax.random.PRNGKey(seed), jnp.asarray(pivots), n, anchor_metric
    )
    mapped = np.asarray(smap(jnp.asarray(pivots)))
    plan = partition.build_partition(mapped, p, delta, "iterative", seed=seed)
    return plan, smap


def _boxes(plan):
    return plan.kernel_lo, plan.kernel_hi, plan.whole_lo, plan.whole_hi


# ---------------------------------------------------------------------------
# Backend / tile-size / shape parity of the fused op itself
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ops.METRICS)
def test_map_assign_backends_agree(metric, rng):
    plan, smap = _plan(rng, metric)
    x = jnp.asarray(rng.normal(size=(137, 7)), jnp.float32)
    want_xm, want_cells, want_bits = ref.map_assign(
        x, smap.anchors, *_boxes(plan), metric
    )
    for backend in ("numpy", "pallas", "auto"):
        xm, cells, bits = ops.map_assign(
            x, smap.anchors, *_boxes(plan), metric, backend=backend
        )
        np.testing.assert_allclose(xm, want_xm, rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(cells, want_cells)
        np.testing.assert_array_equal(bits, want_bits)


@pytest.mark.parametrize("bn,bp", [(32, 32), (64, 64), (128, 32), (256, 128)])
def test_map_assign_tile_size_invariance(bn, bp, rng):
    """Block sizes are a scheduling choice — results cannot depend on them."""
    plan, smap = _plan(rng, "l2", p=40)  # p=40: multi-word membership packing
    x = jnp.asarray(rng.normal(size=(137, 7)), jnp.float32)
    want = ref.map_assign(x, smap.anchors, *_boxes(plan), "l2")
    xm, cells, bits = ops.map_assign(
        x, smap.anchors, *_boxes(plan), "l2", bn=bn, bp=bp, backend="pallas"
    )
    np.testing.assert_allclose(xm, want[0], rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(cells, want[1])
    np.testing.assert_array_equal(bits, want[2])


def test_map_assign_bad_block_size(rng):
    plan, smap = _plan(rng, "l1")
    x = jnp.asarray(rng.normal(size=(16, 7)), jnp.float32)
    with pytest.raises(ValueError, match="multiple of 32"):
        ops.map_assign(x, smap.anchors, *_boxes(plan), "l1", bp=48, backend="pallas")


def test_map_assign_padded_invalid_rows(rng):
    """Static-shape shards carry zero-padding rows: the fused kernel must
    assign the real prefix identically whether or not padding rides along
    (padded rows get *defined* garbage, masked by validity downstream)."""
    plan, smap = _plan(rng, "l1")
    x = rng.normal(size=(100, 7)).astype(np.float32)
    xp = np.concatenate([x, np.zeros((29, 7), np.float32)])  # padded shard
    for backend in ("numpy", "pallas"):
        xm_a, cells_a, bits_a = ops.map_assign(
            jnp.asarray(x), smap.anchors, *_boxes(plan), "l1", backend=backend
        )
        xm_b, cells_b, bits_b = ops.map_assign(
            jnp.asarray(xp), smap.anchors, *_boxes(plan), "l1", backend=backend
        )
        np.testing.assert_allclose(xm_b[:100], xm_a, rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(cells_b[:100], cells_a)
        np.testing.assert_array_equal(bits_b[:100], bits_a)


def test_map_assign_empty_shard(rng):
    plan, smap = _plan(rng, "l1")
    x = jnp.zeros((0, 7), jnp.float32)
    for backend in ("numpy", "pallas"):
        xm, cells, bits = ops.map_assign(
            x, smap.anchors, *_boxes(plan), "l1", backend=backend
        )
        assert xm.shape == (0, 5) and cells.shape == (0,) and bits.shape == (0, 1)


def test_map_assign_unsupported_metric_raises(rng):
    plan, smap = _plan(rng, "angular")
    x = jnp.asarray(rng.normal(size=(8, 7)), jnp.float32)
    with pytest.raises(ValueError):
        ops.map_assign(x, smap.anchors, *_boxes(plan), "angular", backend="pallas")


@pytest.mark.parametrize("n_dims", [3, 8, 12, 20])
def test_assign_membership_odd_anchor_counts(n_dims, rng):
    """Regression: the assign-only Pallas path used the metric-default
    feature chunk (16), which does not divide a coordinate width padded to a
    multiple of 8 only — e.g. 20 anchors pad to 24 and tripped the shape
    assert."""
    plan, smap = _plan(rng, "l1", n=n_dims, m=max(n_dims + 2, 7))
    xm = smap(jnp.asarray(rng.normal(size=(50, max(n_dims + 2, 7))), jnp.float32))
    want = ref.assign_membership(xm, *_boxes(plan))
    got = ops.assign_membership(xm, *_boxes(plan), backend="pallas")
    np.testing.assert_array_equal(got[0], want[0])
    np.testing.assert_array_equal(got[1], want[1])


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_want_variants_match_both(backend, rng):
    """want="cells"/"member" skip one containment sweep; the produced side
    must equal the "both" run and the skipped side must be zero-filled."""
    plan, smap = _plan(rng, "l2", p=40)
    x = jnp.asarray(rng.normal(size=(70, 7)), jnp.float32)
    xm_b, cells_b, bits_b = ops.map_assign(
        x, smap.anchors, *_boxes(plan), "l2", backend=backend, want="both"
    )
    xm_c, cells_c, bits_c = ops.map_assign(
        x, smap.anchors, *_boxes(plan), "l2", backend=backend, want="cells"
    )
    xm_m, cells_m, bits_m = ops.map_assign(
        x, smap.anchors, *_boxes(plan), "l2", backend=backend, want="member"
    )
    np.testing.assert_array_equal(xm_c, xm_b)
    np.testing.assert_array_equal(xm_m, xm_b)
    np.testing.assert_array_equal(cells_c, cells_b)
    np.testing.assert_array_equal(bits_m, bits_b)
    assert not np.asarray(bits_c).any() and not np.asarray(cells_m).any()
    with pytest.raises(ValueError, match="unknown want"):
        ops.map_assign(
            x, smap.anchors, *_boxes(plan), "l2", backend=backend, want="all"
        )


def test_rs_join_fused_on_off_byte_identical(rng):
    """Cross-join map phase: fused S-side membership (same kernel as R) must
    reproduce the legacy path's pair set exactly."""
    r = rng.normal(size=(120, 5)).astype(np.float32)
    s = rng.normal(loc=0.5, size=(300, 5)).astype(np.float32)
    cfg = spjoin.JoinConfig(delta=1.5, metric="l1", k=48, p=6, n_dims=3)
    res_on = spjoin.join(r, cfg, s=s)
    res_off = spjoin.join(r, dataclasses.replace(cfg, map_fused=False), s=s)
    assert res_on.pairs.tobytes() == res_off.pairs.tobytes()
    np.testing.assert_array_equal(
        res_on.pairs, spjoin.brute_force_pairs(r, 1.5, "l1", s=s)
    )


# ---------------------------------------------------------------------------
# Membership bit packing
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("p", [1, 31, 32, 33, 40, 64, 95])
def test_pack_unpack_membership_roundtrip(p, rng):
    member = jnp.asarray(rng.integers(0, 2, size=(57, p)).astype(bool))
    bits = ref.pack_membership(member)
    assert bits.shape == (57, -(-p // 32)) and bits.dtype == jnp.uint32
    np.testing.assert_array_equal(ops.unpack_membership(bits, p), member)


def test_pack_membership_bit31():
    """The sign-bit word position must pack exactly (uint32, no overflow)."""
    member = jnp.zeros((3, 32), bool).at[:, 31].set(True)
    bits = np.asarray(ref.pack_membership(member))
    assert (bits[:, 0] == np.uint32(1) << np.uint32(31)).all()


# ---------------------------------------------------------------------------
# partition.assign_kernel / whole_membership backend= path
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "pallas", "auto"])
def test_partition_backend_path_matches_inline(backend, rng):
    plan, smap = _plan(rng, "l1", p=13)
    xm = smap(jnp.asarray(rng.normal(size=(200, 7)), jnp.float32))
    np.testing.assert_array_equal(
        partition.assign_kernel(plan, xm, backend=backend),
        partition.assign_kernel(plan, xm),
    )
    np.testing.assert_array_equal(
        partition.whole_membership(plan, xm, backend=backend),
        partition.whole_membership(plan, xm),
    )


# ---------------------------------------------------------------------------
# End-to-end byte-identity: fused on vs off, both executors, fixed seeds
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", JOIN_METRICS)
@pytest.mark.parametrize("tighten", [True, False])
def test_join_fused_on_off_byte_identical(metric, tighten, rng):
    if metric == "jaccard_minhash":
        data = rng.integers(0, 30, size=(250, 16)).astype(np.float32)
        delta = 0.4
    else:
        data = rng.normal(size=(250, 5)).astype(np.float32)
        delta = {"l1": 2.0, "l2": 1.0, "linf": 0.6, "cosine": 0.05, "angular": 0.15}[
            metric
        ]
    cfg = spjoin.JoinConfig(
        delta=delta, metric=metric, k=64, p=8, n_dims=3, tighten=tighten
    )
    r_on = spjoin.join(data, cfg)
    r_off = spjoin.join(data, dataclasses.replace(cfg, map_fused=False))
    assert r_on.pairs.tobytes() == r_off.pairs.tobytes()
    if metric not in ("cosine",):  # pseudo-metric: identity only, no oracle
        truth = spjoin.brute_force_pairs(data, delta, metric)
        np.testing.assert_array_equal(r_on.pairs, truth)


def test_join_fused_pallas_backend_exact(rng):
    """The fused kernel inside the full reference pipeline (interpret mode
    off-TPU) still produces the exact join."""
    data = rng.normal(size=(180, 5)).astype(np.float32)
    cfg = spjoin.JoinConfig(delta=1.5, metric="l1", k=64, p=8, n_dims=3,
                            backend="pallas")
    res = spjoin.join(data, cfg)
    np.testing.assert_array_equal(
        res.pairs, spjoin.brute_force_pairs(data, 1.5, "l1")
    )


def test_distributed_fused_on_off_byte_identical_1dev(rng):
    mesh = jax.make_mesh((1,), ("data",))
    data = jnp.asarray(rng.normal(size=(220, 5)), jnp.float32)
    rs = {}
    for fused in (True, False):
        r = dict()
        from repro.core import distributed

        res = distributed.distributed_join(
            data, mesh=mesh, delta=2.0, metric="l1", k=64, p=4, n_dims=3,
            emit_pairs=True, map_fused=fused, seed=0,
        )
        r["pairs"] = res.pairs
        r["verif"] = res.n_verifications
        rs[fused] = r
    assert rs[True]["pairs"].tobytes() == rs[False]["pairs"].tobytes()
    assert rs[True]["verif"] == rs[False]["verif"]


@pytest.mark.slow
def test_distributed_fused_on_off_byte_identical_8dev():
    """Multi-device parity: subprocess with 8 simulated CPU devices so the
    device-count flag never leaks into the suite."""
    prog = (
        "import os\n"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n"
        + textwrap.dedent(
            """
            import json, numpy as np, jax, jax.numpy as jnp
            mesh = jax.make_mesh((8,), ("data",))
            from repro.core import distributed, spjoin
            rng = np.random.default_rng(0)
            data = np.concatenate([
                rng.normal(loc=c, scale=1.0, size=(200, 6)) for c in (0., 5., 10.)
            ]).astype(np.float32)
            out = {}
            for fused in (True, False):
                r = distributed.distributed_join(
                    jnp.asarray(data), mesh=mesh, delta=3.0, metric="l2", k=192,
                    p=16, n_dims=4, emit_pairs=True, map_fused=fused, seed=0)
                out[str(fused)] = r.pairs.tolist()
            truth = spjoin.brute_force_pairs(data, 3.0, "l2").tolist()
            print(json.dumps(dict(identical=out["True"] == out["False"],
                                  exact=out["True"] == truth)))
            """
        )
    )
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-4000:]
    res = json.loads(out.stdout.splitlines()[-1])
    assert res["identical"] and res["exact"], res
