"""Sampling algorithms (paper §4) + error-bound theory (Thm 3, hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import expfam, gof, sampling


def _node_stats(rng, n_nodes=4, n=2_000, m=3):
    shards, stats = [], []
    for i in range(n_nodes):
        x = jnp.asarray(rng.normal(i * 2.0, 1.0 + 0.2 * i, size=(n, m)), jnp.float32)
        shards.append(x)
        params, res = gof.fit_best_family(x)
        stats.append(sampling.NodeStats(params.family, params,
                                        float(res.confidence), n))
    return shards, stats


# ---------------------------------------------------------------------------
# Eq. 11 allocation
# ---------------------------------------------------------------------------


@given(
    st.lists(st.integers(100, 10_000), min_size=2, max_size=8),
    st.lists(st.floats(0.01, 1.0), min_size=2, max_size=8),
    st.integers(16, 2_000),
)
@settings(max_examples=50, deadline=None)
def test_allocation_sums_to_k(ns, cs, k):
    n = min(len(ns), len(cs))
    alloc = sampling.allocate_samples(np.array(ns[:n]), np.array(cs[:n]), k)
    assert alloc.sum() == k
    assert (alloc >= 0).all()


def test_allocation_favors_low_confidence():
    ns = np.array([1000, 1000])
    alloc = sampling.allocate_samples(ns, np.array([0.1, 0.9]), 100)
    assert alloc[0] > alloc[1]


def test_allocation_caps_at_population_and_redistributes():
    """A tiny low-confidence node's Eq. 11 quota would exceed its population;
    the cap must bind and the surplus flow to nodes with room."""
    ns = np.array([10, 1000, 1000])
    cs = np.array([0.001, 0.9, 0.9])  # raw Eq. 11 sends ~90% of k to node 0
    alloc = sampling.allocate_samples(ns, cs, 500)
    assert (alloc <= ns).all()
    assert alloc.sum() == 500
    assert alloc[0] == 10  # capped at population


def test_allocation_k_exceeding_total_population():
    ns = np.array([5, 7])
    alloc = sampling.allocate_samples(ns, np.array([0.5, 0.5]), 100)
    assert np.array_equal(alloc, ns)  # whole population, no phantom quota


@given(
    st.lists(st.integers(1, 50), min_size=2, max_size=6),
    st.lists(st.floats(0.001, 1.0), min_size=2, max_size=6),
    st.integers(1, 400),
)
@settings(max_examples=50, deadline=None)
def test_allocation_capped_invariants(ns, cs, k):
    n = min(len(ns), len(cs))
    pop = np.array(ns[:n])
    alloc = sampling.allocate_samples(pop, np.array(cs[:n]), k)
    assert (alloc >= 0).all() and (alloc <= pop).all()
    assert alloc.sum() == min(k, pop.sum())


def test_distribution_aware_exact_k_when_quota_exceeds_population(rng):
    """Acceptance criterion: exactly k pivots even when a node's quota
    exceeds its population (pre-fix: silent truncation to < k)."""
    shards, stats = _node_stats(rng, n_nodes=3, n=800)
    tiny = jnp.asarray(rng.normal(8.0, 0.5, size=(12, 3)), jnp.float32)
    params, res = gof.fit_best_family(tiny)
    shards.append(tiny)
    stats.append(sampling.NodeStats(params.family, params, 0.001, 12))
    out = sampling.distribution_aware_sample(
        jax.random.PRNGKey(0), shards, stats, k=600
    )
    assert out.shape == (600, 3)


# ---------------------------------------------------------------------------
# Theorem 3 error bound
# ---------------------------------------------------------------------------


def test_required_sample_size_inverts_bound():
    for eps, dp, m in [(0.05, 0.05, 8), (0.02, 0.01, 128)]:
        k = sampling.required_sample_size(eps, dp, m)
        assert sampling.error_bound_probability(k, eps, m) <= dp
        assert sampling.error_bound_probability(k - 1, eps, m) > dp


def test_required_sample_size_clamps_vacuous_bound():
    """fail_prob ≥ 2m makes the bound vacuous; the raw inversion went ≤ 0."""
    assert sampling.required_sample_size(0.1, 2 * 8, 8) == 1
    assert sampling.required_sample_size(0.1, 100.0, 8) == 1
    assert sampling.required_sample_size(0.5, 16.0001, 8) == 1


@given(
    eps=st.floats(0.01, 0.5),
    dp=st.floats(0.001, 50.0),
    m=st.integers(1, 256),
)
@settings(max_examples=80, deadline=None)
def test_required_sample_size_forward_bound_property(eps, dp, m):
    """The forward bound must hold at the returned k across the whole grid,
    including the vacuous region fail_prob ≥ 2m."""
    k = sampling.required_sample_size(eps, dp, m)
    assert k >= 1
    # fp-tolerant: ceil() makes k exact up to exp/log rounding at the boundary
    assert sampling.error_bound_probability(k, eps, m) <= dp * (1 + 1e-9)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_theorem3_bound_holds_empirically(seed):
    """P[D_k >= eps] < 2m exp(-2 k eps^2): with k chosen for 5% failure at
    eps, observed marginal-CDF error should essentially never exceed eps."""
    rng = np.random.default_rng(seed)
    m, eps = 4, 0.08
    k = sampling.required_sample_size(eps, 0.05, m)  # ~ 470
    ref = jnp.asarray(rng.normal(size=(20_000, m)), jnp.float32)
    fails = 0
    for t in range(10):
        idx = rng.choice(20_000, size=k, replace=False)
        err = float(sampling.sampling_error(ref[idx], ref))
        fails += err >= eps
    assert fails <= 1, fails  # 5% bound; allow one unlucky draw in 10


def test_sampling_error_zero_for_identical():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(500, 3)), jnp.float32)
    assert float(sampling.sampling_error(x, x)) <= (1.0 / 500) + 1e-6


# ---------------------------------------------------------------------------
# Distribution-aware sampling (Alg. 2)
# ---------------------------------------------------------------------------


def test_stratified_sample_output_shape_and_membership(rng):
    shards, stats = _node_stats(rng)
    out = sampling.distribution_aware_sample(
        jax.random.PRNGKey(0), shards, stats, k=256
    )
    assert out.shape == (256, 3)
    allx = np.concatenate([np.asarray(s) for s in shards])
    # every sample must be a real object from the dataset
    for row in np.asarray(out)[:16]:
        assert (np.abs(allx - row).sum(1) < 1e-4).any()


def test_stratified_sample_better_marginal_error_than_random(rng):
    """The paper's core claim at small k: stratified pivots track the global
    CDF better than uniform pivots (averaged over draws). Uses proportional
    allocation to isolate stratification; Eq. 11's confidence reweighting is
    deliberately biased toward low-confidence nodes (covered by
    test_allocation_favors_low_confidence, quantified in EXPERIMENTS.md)."""
    shards, stats = _node_stats(rng, n_nodes=4, n=4_000)
    allx = jnp.concatenate(shards)
    k = 96
    errs_s, errs_r = [], []
    for t in range(8):
        key = jax.random.PRNGKey(t)
        s = sampling.distribution_aware_sample(key, shards, stats, k,
                                               allocation="proportional")
        r = sampling.random_sample(key, allx, k)
        errs_s.append(float(sampling.sampling_error(s, allx)))
        errs_r.append(float(sampling.sampling_error(r, allx)))
    assert np.mean(errs_s) <= np.mean(errs_r), (np.mean(errs_s), np.mean(errs_r))


# ---------------------------------------------------------------------------
# Generative sampling (Alg. 3/4)
# ---------------------------------------------------------------------------


def test_gibbs_matches_numpy_reference_distribution(rng):
    shards, stats = _node_stats(rng, n_nodes=3, n=3_000, m=2)
    k = 2_000
    s_jax, acc = sampling.generative_sample(jax.random.PRNGKey(0), stats, k)
    s_np = sampling.gibbs_chain_numpy(np.random.default_rng(0), stats, k)
    assert s_jax.shape == (k, 2) and s_np.shape == (k, 2)
    assert 0.2 < float(acc) <= 1.0
    # same generative law: per-dim means/stds agree within sampling noise
    np.testing.assert_allclose(
        np.asarray(s_jax).mean(0), s_np.mean(0), atol=0.25
    )
    np.testing.assert_allclose(np.asarray(s_jax).std(0), s_np.std(0), rtol=0.2)


def test_generative_tracks_global_distribution_high_confidence(rng):
    """In the paper's operating regime (c_i >= 0.95 'empirically', §3.4) the
    Gibbs mixture is ~unbiased and model samples track the global CDF."""
    shards, stats = _node_stats(rng, n_nodes=4, n=4_000, m=2)
    stats = [s._replace(confidence=0.97) for s in stats]
    allx = jnp.concatenate(shards)
    s, acc = sampling.generative_sample(jax.random.PRNGKey(1), stats, 1_000)
    err = float(sampling.sampling_error(s, allx))
    assert err < 0.1, err
    assert float(acc) > 0.9


def test_compact_accepted_zero_accept_falls_back_to_raw_draws():
    """All-rejected chain (all-confidence-≈0 shards): the old guard returned
    k copies of a REJECTED draw; now the raw chain draws come back, diverse,
    with 0.0 acceptance telemetry for the caller to warn on."""
    xs = jnp.arange(20, dtype=jnp.float32)[:, None]
    out, acc = sampling._compact_accepted(xs, jnp.zeros(20, bool), 5)
    assert float(acc) == 0.0
    assert np.array_equal(np.asarray(out)[:, 0], np.arange(5))


def test_compact_accepted_shortfall_repeats_first_accepted():
    xs = jnp.arange(20, dtype=jnp.float32)[:, None]
    accepted = jnp.zeros(20, bool).at[7].set(True).at[11].set(True)
    out, acc = sampling._compact_accepted(xs, accepted, 5)
    vals = np.asarray(out)[:, 0]
    assert vals[0] == 7.0 and vals[1] == 11.0
    assert (vals[2:] == 7.0).all()  # tail repeats an ACCEPTED row, never a reject
    assert float(acc) == pytest.approx(2 / 20)


def test_generative_low_confidence_bias_direction(rng):
    """Reproduction finding (EXPERIMENTS.md): Eqs. 17-19 cancel the
    acceptance rate only on the C=1 branch (N_i/c_i * c_i = N_i); after a
    rejection the chain draws e ~ N_i then accepts w.p. c_i, i.e. effective
    weight N_i*c_i — biased TOWARD high-confidence nodes. Assert the
    direction so the behavior is pinned, not accidental."""
    shards, stats = _node_stats(rng, n_nodes=4, n=4_000, m=2)
    # node 3 (largest mean) high-confidence, others low
    stats = [s._replace(confidence=0.15 if i < 3 else 0.9)
             for i, s in enumerate(stats)]
    allx = jnp.concatenate(shards)
    s, acc = sampling.generative_sample(jax.random.PRNGKey(1), stats, 2_000)
    assert float(acc) < 0.6
    assert float(np.asarray(s).mean()) > float(np.asarray(allx).mean())
