"""Sampling algorithms (paper §4) + error-bound theory (Thm 3, hypothesis)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import expfam, gof, sampling


def _node_stats(rng, n_nodes=4, n=2_000, m=3):
    shards, stats = [], []
    for i in range(n_nodes):
        x = jnp.asarray(rng.normal(i * 2.0, 1.0 + 0.2 * i, size=(n, m)), jnp.float32)
        shards.append(x)
        params, res = gof.fit_best_family(x)
        stats.append(sampling.NodeStats(params.family, params,
                                        float(res.confidence), n))
    return shards, stats


# ---------------------------------------------------------------------------
# Eq. 11 allocation
# ---------------------------------------------------------------------------


@given(
    st.lists(st.integers(100, 10_000), min_size=2, max_size=8),
    st.lists(st.floats(0.01, 1.0), min_size=2, max_size=8),
    st.integers(16, 2_000),
)
@settings(max_examples=50, deadline=None)
def test_allocation_sums_to_k(ns, cs, k):
    n = min(len(ns), len(cs))
    alloc = sampling.allocate_samples(np.array(ns[:n]), np.array(cs[:n]), k)
    assert alloc.sum() == k
    assert (alloc >= 0).all()


def test_allocation_favors_low_confidence():
    ns = np.array([1000, 1000])
    alloc = sampling.allocate_samples(ns, np.array([0.1, 0.9]), 100)
    assert alloc[0] > alloc[1]


# ---------------------------------------------------------------------------
# Theorem 3 error bound
# ---------------------------------------------------------------------------


def test_required_sample_size_inverts_bound():
    for eps, dp, m in [(0.05, 0.05, 8), (0.02, 0.01, 128)]:
        k = sampling.required_sample_size(eps, dp, m)
        assert sampling.error_bound_probability(k, eps, m) <= dp
        assert sampling.error_bound_probability(k - 1, eps, m) > dp


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_theorem3_bound_holds_empirically(seed):
    """P[D_k >= eps] < 2m exp(-2 k eps^2): with k chosen for 5% failure at
    eps, observed marginal-CDF error should essentially never exceed eps."""
    rng = np.random.default_rng(seed)
    m, eps = 4, 0.08
    k = sampling.required_sample_size(eps, 0.05, m)  # ~ 470
    ref = jnp.asarray(rng.normal(size=(20_000, m)), jnp.float32)
    fails = 0
    for t in range(10):
        idx = rng.choice(20_000, size=k, replace=False)
        err = float(sampling.sampling_error(ref[idx], ref))
        fails += err >= eps
    assert fails <= 1, fails  # 5% bound; allow one unlucky draw in 10


def test_sampling_error_zero_for_identical():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(500, 3)), jnp.float32)
    assert float(sampling.sampling_error(x, x)) <= (1.0 / 500) + 1e-6


# ---------------------------------------------------------------------------
# Distribution-aware sampling (Alg. 2)
# ---------------------------------------------------------------------------


def test_stratified_sample_output_shape_and_membership(rng):
    shards, stats = _node_stats(rng)
    out = sampling.distribution_aware_sample(
        jax.random.PRNGKey(0), shards, stats, k=256
    )
    assert out.shape == (256, 3)
    allx = np.concatenate([np.asarray(s) for s in shards])
    # every sample must be a real object from the dataset
    for row in np.asarray(out)[:16]:
        assert (np.abs(allx - row).sum(1) < 1e-4).any()


def test_stratified_sample_better_marginal_error_than_random(rng):
    """The paper's core claim at small k: stratified pivots track the global
    CDF better than uniform pivots (averaged over draws). Uses proportional
    allocation to isolate stratification; Eq. 11's confidence reweighting is
    deliberately biased toward low-confidence nodes (covered by
    test_allocation_favors_low_confidence, quantified in EXPERIMENTS.md)."""
    shards, stats = _node_stats(rng, n_nodes=4, n=4_000)
    allx = jnp.concatenate(shards)
    k = 96
    errs_s, errs_r = [], []
    for t in range(8):
        key = jax.random.PRNGKey(t)
        s = sampling.distribution_aware_sample(key, shards, stats, k,
                                               allocation="proportional")
        r = sampling.random_sample(key, allx, k)
        errs_s.append(float(sampling.sampling_error(s, allx)))
        errs_r.append(float(sampling.sampling_error(r, allx)))
    assert np.mean(errs_s) <= np.mean(errs_r), (np.mean(errs_s), np.mean(errs_r))


# ---------------------------------------------------------------------------
# Generative sampling (Alg. 3/4)
# ---------------------------------------------------------------------------


def test_gibbs_matches_numpy_reference_distribution(rng):
    shards, stats = _node_stats(rng, n_nodes=3, n=3_000, m=2)
    k = 2_000
    s_jax, acc = sampling.generative_sample(jax.random.PRNGKey(0), stats, k)
    s_np = sampling.gibbs_chain_numpy(np.random.default_rng(0), stats, k)
    assert s_jax.shape == (k, 2) and s_np.shape == (k, 2)
    assert 0.2 < float(acc) <= 1.0
    # same generative law: per-dim means/stds agree within sampling noise
    np.testing.assert_allclose(
        np.asarray(s_jax).mean(0), s_np.mean(0), atol=0.25
    )
    np.testing.assert_allclose(np.asarray(s_jax).std(0), s_np.std(0), rtol=0.2)


def test_generative_tracks_global_distribution_high_confidence(rng):
    """In the paper's operating regime (c_i >= 0.95 'empirically', §3.4) the
    Gibbs mixture is ~unbiased and model samples track the global CDF."""
    shards, stats = _node_stats(rng, n_nodes=4, n=4_000, m=2)
    stats = [s._replace(confidence=0.97) for s in stats]
    allx = jnp.concatenate(shards)
    s, acc = sampling.generative_sample(jax.random.PRNGKey(1), stats, 1_000)
    err = float(sampling.sampling_error(s, allx))
    assert err < 0.1, err
    assert float(acc) > 0.9


def test_generative_low_confidence_bias_direction(rng):
    """Reproduction finding (EXPERIMENTS.md): Eqs. 17-19 cancel the
    acceptance rate only on the C=1 branch (N_i/c_i * c_i = N_i); after a
    rejection the chain draws e ~ N_i then accepts w.p. c_i, i.e. effective
    weight N_i*c_i — biased TOWARD high-confidence nodes. Assert the
    direction so the behavior is pinned, not accidental."""
    shards, stats = _node_stats(rng, n_nodes=4, n=4_000, m=2)
    # node 3 (largest mean) high-confidence, others low
    stats = [s._replace(confidence=0.15 if i < 3 else 0.9)
             for i, s in enumerate(stats)]
    allx = jnp.concatenate(shards)
    s, acc = sampling.generative_sample(jax.random.PRNGKey(1), stats, 2_000)
    assert float(acc) < 0.6
    assert float(np.asarray(s).mean()) > float(np.asarray(allx).mean())
