"""Pivot-filter pruning: soundness (no true pair is ever pruned), fixed-seed
byte-identity of prune="pivot" vs prune="none" on both executors, capability
fallbacks, and the fused filter+pairdist kernel's parity with its oracle."""
import dataclasses
import json
import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import cost_model, distances, spjoin, verify
from repro.kernels import ops as kops
from repro.kernels import ref

# Metrics for which the filter must be SOUND (true metrics — the triangle
# inequality holds, so the L-inf bound over anchor distances never exceeds
# the true distance).
TRUE_METRICS = ["l1", "l2", "linf", "angular", "jaccard_minhash"]


def _dataset(metric, rng, n=120):
    if metric == "jaccard_minhash":
        data = rng.integers(0, 20, size=(n, 32)).astype(np.float32)
    else:
        data = np.concatenate(
            [rng.normal(loc=c, scale=1.0, size=(n // 3, 6)) for c in (0.0, 4.0, 9.0)]
        ).astype(np.float32)
    d = np.asarray(distances.pairwise(jnp.asarray(data), jnp.asarray(data), metric))
    delta = float(np.quantile(d[np.triu_indices(len(data), 1)], 0.05))
    return data, delta


# ---------------------------------------------------------------------------
# Soundness: the bound is a lower bound, so no true pair survives pruning
# ---------------------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000), metric=st.sampled_from(TRUE_METRICS))
def test_no_true_pair_is_ever_pruned(seed, metric):
    """THE soundness property: for every pair within delta, the L-inf lower
    bound over mapped coordinates stays within the (fp-slackened) prune
    threshold — pruning can only ever discard non-hits."""
    rng = np.random.default_rng(seed)
    data, delta = _dataset(metric, rng, n=60)
    anchors = data[rng.choice(len(data), size=4, replace=False)]
    coords = np.asarray(
        distances.pairwise(jnp.asarray(data), jnp.asarray(anchors), metric)
    )
    d = np.asarray(distances.pairwise(jnp.asarray(data), jnp.asarray(data), metric))
    bound = np.abs(coords[:, None, :] - coords[None, :, :]).max(-1)
    true_pairs = d <= delta
    surviving = bound <= ref.prune_delta(delta)
    # Every true pair must survive the filter (soundness = completeness here).
    assert np.all(surviving[true_pairs]), (
        metric,
        float(bound[true_pairs & ~surviving].max(initial=0.0)),
        delta,
    )


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 10_000),
    metric=st.sampled_from(TRUE_METRICS),
    backend=st.sampled_from(["numpy", "pallas"]),
    tile=st.sampled_from([16, 128]),
)
def test_engine_pruned_equals_unpruned(seed, metric, backend, tile):
    """Engine-level identity under pruning for every true metric, backend and
    tile size: the emitted pair set never changes."""
    if backend == "pallas" and not kops.supports_kernel(metric):
        backend = "numpy"
    rng = np.random.default_rng(seed)
    data, delta = _dataset(metric, rng, n=90)
    anchors = data[rng.choice(len(data), size=3, replace=False)]
    coords = np.asarray(
        distances.pairwise(jnp.asarray(data), jnp.asarray(anchors), metric)
    )
    cells = rng.integers(0, 4, size=len(data))
    member = rng.random((len(data), 4)) < 0.6
    member[np.arange(len(data)), cells] = True
    base, _ = verify.verify_pairs(
        data, cells, member, delta, metric,
        config=verify.EngineConfig(backend=backend, prune="none"),
    )
    pruned, stats = verify.verify_pairs(
        data, cells, member, delta, metric,
        config=verify.EngineConfig(
            backend=backend, prune="pivot", tile_v=tile, tile_w=tile
        ),
        coords=coords,
    )
    assert base.tobytes() == pruned.tobytes(), (metric, backend, tile)
    assert stats.prune == "pivot"
    assert stats.n_exact + stats.n_pruned == stats.n_verifications
    assert stats.n_hits <= stats.n_exact


# ---------------------------------------------------------------------------
# Fixed-seed byte-identity through the reference executor
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
def test_spjoin_fixed_seed_byte_identity(backend, rng):
    """Acceptance criterion: spjoin.join pair sets with prune="pivot" are
    byte-identical to prune="none" at a fixed seed, and pruning actually
    engages (nonzero rate) on clustered data."""
    data, delta = _dataset("l1", rng, n=150)
    cfg = spjoin.JoinConfig(
        delta=delta, metric="l1", k=64, p=6, n_dims=3, backend=backend,
        prune="pivot", seed=0,
    )
    res_p = spjoin.join(data, cfg)
    res_n = spjoin.join(data, dataclasses.replace(cfg, prune="none"))
    assert res_p.pairs.tobytes() == res_n.pairs.tobytes()
    assert res_p.verify_stats.prune == "pivot"
    assert res_n.verify_stats.prune == "none"
    assert res_p.verify_stats.n_pruned > 0
    assert res_p.verify_stats.prune_rate > 0.0
    # Pruning is invisible to every result-level quantity.
    assert res_p.n_verifications == res_n.n_verifications
    assert np.array_equal(res_p.pairs, spjoin.brute_force_pairs(data, delta, "l1"))


def test_spjoin_rs_byte_identity(rng):
    """Same invariant for the two-set R×S join (coords_w side)."""
    data, delta = _dataset("l2", rng, n=120)
    s = (data[::2] + 0.3).astype(np.float32)
    cfg = spjoin.JoinConfig(delta=delta, metric="l2", k=64, p=6, n_dims=3, seed=0)
    res_p = spjoin.join(data, cfg, s=s)
    res_n = spjoin.join(data, dataclasses.replace(cfg, prune="none"), s=s)
    assert res_p.pairs.tobytes() == res_n.pairs.tobytes()
    assert np.array_equal(
        res_p.pairs, spjoin.brute_force_pairs(data, delta, "l2", s=s)
    )


@pytest.mark.parametrize("metric,delta,offset", [
    ("l2", 1.5, 1000.0),   # dot-expansion error >> any fixed band
    ("l2", 1.5, 10000.0),  # fp32 distances barely meaningful; must stay sound
    ("l1", 0.05, 1000.0),
    ("linf", 0.8, 5000.0),
])
def test_byte_identity_far_from_origin(metric, delta, offset, rng):
    """Regression: the guard band must scale with coordinate magnitude.
    l2's MXU-friendly dot-expansion loses ~ulp(X²) absolute precision, so a
    fixed slack silently pruned computed hits on data offset ~1000 from the
    origin. The scale-aware band (ref.prune_delta) keeps pair sets
    byte-identical at any magnitude — degrading prune_rate toward 0 instead
    of dropping pairs when fp32 can no longer separate bound from distance."""
    data = (rng.normal(size=(200, 6)) + offset).astype(np.float32)
    cfg = spjoin.JoinConfig(delta=delta, metric=metric, k=64, p=6, n_dims=3, seed=0)
    res_p = spjoin.join(data, cfg)
    res_n = spjoin.join(data, dataclasses.replace(cfg, prune="none"))
    assert res_p.pairs.tobytes() == res_n.pairs.tobytes(), (metric, offset)


# ---------------------------------------------------------------------------
# Capability fallbacks and caller-bug errors
# ---------------------------------------------------------------------------


def test_pseudo_metric_resolves_to_none(rng):
    """cosine has no triangle inequality — prune="pivot" silently resolves to
    "none" (capability, like a missing kernel), never an unsound filter."""
    data, _ = _dataset("l1", rng, n=60)
    cfg = spjoin.JoinConfig(delta=0.05, metric="cosine", k=32, p=4, n_dims=3,
                            prune="pivot", seed=0)
    res = spjoin.join(data, cfg)
    assert res.verify_stats.prune == "none"
    assert res.verify_stats.n_pruned == 0
    assert verify.resolve_prune("pivot", "cosine", True) == "none"
    assert verify.resolve_prune("pivot", "l1", True) == "pivot"
    assert not verify.prune_supported("cosine")
    assert verify.prune_supported("angular")


def test_prune_requires_coords_and_valid_mode(rng):
    data = rng.normal(size=(30, 4)).astype(np.float32)
    cells = np.zeros(30, np.int64)
    member = np.ones((30, 1), bool)
    with pytest.raises(ValueError, match="coords"):
        verify.verify_pairs(
            data, cells, member, 1.0, "l1",
            config=verify.EngineConfig(prune="pivot"),
        )
    with pytest.raises(ValueError, match="prune mode"):
        verify.verify_pairs(
            data, cells, member, 1.0, "l1",
            config=verify.EngineConfig(prune="bogus"),
        )
    with pytest.raises(ValueError, match="unsound"):
        kops.pairdist_mask_filtered(
            jnp.zeros((4, 3)), jnp.zeros((4, 3)), jnp.zeros((4, 2)),
            jnp.zeros((4, 2)), 0.5, "cosine",
        )


# ---------------------------------------------------------------------------
# Fused kernel parity (both dispatch paths) and the whole-tile skip
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["numpy", "pallas"])
@pytest.mark.parametrize("metric", ["l1", "l2", "linf"])
def test_filtered_kernel_matches_oracle(metric, backend, rng):
    x = rng.normal(size=(70, 9)).astype(np.float32)
    y = rng.normal(size=(130, 9)).astype(np.float32)
    anchors = rng.normal(size=(5, 9)).astype(np.float32)
    px = np.asarray(distances.pairwise(jnp.asarray(x), jnp.asarray(anchors), metric))
    py = np.asarray(distances.pairwise(jnp.asarray(y), jnp.asarray(anchors), metric))
    delta = 3.0
    want = np.asarray(ref.pairdist_mask_filtered(x, y, px, py, delta, metric))
    got = np.asarray(
        kops.pairdist_mask_filtered(x, y, px, py, delta, metric, backend=backend)
    )
    assert np.array_equal(want, got), (metric, backend)
    # The filter never changes the hit set — only the work done to find it.
    assert np.array_equal(want, np.asarray(ref.pairdist_mask(x, y, delta, metric)))


def test_whole_tile_skip_counts(rng):
    """Two far-apart clumps sharing a cell: cross-clump work is eliminated
    BEFORE any exact dispatch. The ordered-window refinement slices the far
    clump out of every V tile's W range, so the cross-clump tiles are never
    even formed (n_tiles counts same-clump tiles only) and their pairs land
    in n_pruned."""
    a = rng.normal(loc=0.0, size=(40, 4)).astype(np.float32)
    b = rng.normal(loc=500.0, size=(40, 4)).astype(np.float32)
    data = np.concatenate([a, b])
    anchors = data[:3]
    coords = np.asarray(distances.pairwise(jnp.asarray(data), jnp.asarray(anchors), "l1"))
    cells = np.zeros(80, np.int64)
    member = np.ones((80, 1), bool)
    cfg = verify.EngineConfig(backend="numpy", prune="pivot", tile_v=40, tile_w=40)
    pruned, stats = verify.verify_pairs(data, cells, member, 2.0, "l1",
                                        config=cfg, coords=coords)
    base, _ = verify.verify_pairs(data, cells, member, 2.0, "l1",
                                  config=dataclasses.replace(cfg, prune="none"))
    assert pruned.tobytes() == base.tobytes()
    # Only the two same-clump tiles are dispatched; both cross-clump
    # products (2 * 40 * 40 pairs) are pruned without a tile.
    assert stats.n_tiles == 2
    assert stats.n_dispatched == 2 * 40 * 40
    assert stats.n_pruned >= 2 * 40 * 40
    assert stats.n_dispatched < stats.n_verifications
    assert 0.0 < stats.occupancy <= 1.0


def test_survival_estimate_and_pruning_aware_count(rng):
    data, delta = _dataset("l1", rng, n=90)
    anchors = data[:4]
    coords = np.asarray(distances.pairwise(jnp.asarray(data), jnp.asarray(anchors), "l1"))
    s = cost_model.estimate_survival_rate(coords, delta)
    assert 0.0 <= s <= 1.0
    # survival=1 keeps the paper quantity; smaller survival scales it down.
    v = np.array([10, 20]); w = np.array([30, 40])
    assert cost_model.verification_count(v, w) == 10 * 30 + 20 * 40
    assert cost_model.verification_count(v, w, survival=0.5) == (10 * 30 + 20 * 40) / 2
    # Degenerate inputs.
    assert cost_model.estimate_survival_rate(coords[:1], delta) == 1.0
    # Candidate-restricted form stays a valid fraction.
    cells = rng.integers(0, 3, size=90)
    member = rng.random((90, 3)) < 0.5
    s2 = cost_model.estimate_survival_rate(coords, delta, cells=cells, member=member)
    assert 0.0 <= s2 <= 1.0


# ---------------------------------------------------------------------------
# Distributed executor byte-identity (8 simulated devices, subprocess)
# ---------------------------------------------------------------------------


def _run_sub(code: str) -> dict:
    prog = (
        "import os\n"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n"
        + textwrap.dedent(code)
    )
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.splitlines()[-1])


@pytest.mark.slow
def test_distributed_fixed_seed_byte_identity():
    """Acceptance criterion, distributed half: prune="pivot" pair sets are
    byte-identical to prune="none" across the shard_map pipeline (the pivot
    columns riding the all_to_all change no emitted pair), with a nonzero
    pruning rate and unchanged dispatch/verification telemetry."""
    res = _run_sub("""
    import json, numpy as np, jax, jax.numpy as jnp
    mesh = jax.make_mesh((8,), ("data",))
    from repro.core import distributed, spjoin
    rng = np.random.default_rng(0)
    data = np.concatenate([
        rng.normal(loc=c, scale=1.0, size=(150, 8)) for c in (0., 5., 10., 15.)
    ]).astype(np.float32)
    kw = dict(mesh=mesh, delta=3.0, metric="l1", k=128, p=16, n_dims=4,
              emit_pairs=True, seed=0)
    rp = distributed.distributed_join(jnp.asarray(data), prune="pivot", **kw)
    rn = distributed.distributed_join(jnp.asarray(data), prune="none", **kw)
    truth = spjoin.brute_force_pairs(data, 3.0, "l1")
    print(json.dumps(dict(
        identical=bool(rp.pairs.tobytes() == rn.pairs.tobytes()),
        exact=bool(np.array_equal(rp.pairs, truth)),
        hits_match=bool(rp.n_hits == rn.n_hits),
        verif_match=bool(rp.n_verifications == rn.n_verifications),
        pruning_rate=float(rp.pruning_rate),
        pruning_rate_off=float(rn.pruning_rate),
        predicted_survival=float(rp.predicted_survival),
        prune_modes=[rp.prune, rn.prune])))
    """)
    assert res["identical"] and res["exact"], res
    assert res["hits_match"] and res["verif_match"], res
    assert res["pruning_rate"] > 0.0, res
    assert res["pruning_rate_off"] == 0.0, res
    assert 0.0 <= res["predicted_survival"] <= 1.0
    assert res["prune_modes"] == ["pivot", "none"]


@pytest.mark.slow
def test_distributed_rs_byte_identity():
    """R×S half: pivot coords ride BOTH dispatch all_to_alls (R's V buffers
    and S's W buffers); pair sets stay byte-identical."""
    res = _run_sub("""
    import json, numpy as np, jax, jax.numpy as jnp
    mesh = jax.make_mesh((8,), ("data",))
    from repro.core import distributed, spjoin
    rng = np.random.default_rng(1)
    r = np.concatenate([
        rng.normal(loc=c, scale=1.0, size=(60, 8)) for c in (0., 6., 12.)
    ]).astype(np.float32)
    s = np.concatenate([
        rng.normal(loc=c + 0.5, scale=1.0, size=(120, 8)) for c in (0., 6., 12.)
    ]).astype(np.float32)
    kw = dict(mesh=mesh, delta=3.0, metric="l1", k=128, p=16, n_dims=4,
              emit_pairs=True, seed=0)
    rp = distributed.distributed_join(jnp.asarray(r), s=jnp.asarray(s), prune="pivot", **kw)
    rn = distributed.distributed_join(jnp.asarray(r), s=jnp.asarray(s), prune="none", **kw)
    truth = spjoin.brute_force_pairs(r, 3.0, "l1", s=s)
    print(json.dumps(dict(
        identical=bool(rp.pairs.tobytes() == rn.pairs.tobytes()),
        exact=bool(np.array_equal(rp.pairs, truth)),
        pruning_rate=float(rp.pruning_rate))))
    """)
    assert res["identical"] and res["exact"], res
    assert res["pruning_rate"] > 0.0, res
