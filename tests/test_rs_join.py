"""Two-set R×S join parity suite (ISSUE 2): both executors vs the
brute-force cross oracle across metrics and asymmetric sizes, plus the
degenerate shapes (empty S, R = S aliasing) and the R×S cost model.

The 8-device distributed sweep lives in test_distributed.py conventions
(subprocess, slow tier); here a 1-device mesh keeps the distributed cross
path in the fast tier — same stages, same all_to_all code.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import cost_model, distances, spjoin, verify
from repro.data import synthetic


def _rs_dataset(metric, rng, n_r=80, n_s=200):
    if metric == "jaccard_minhash":
        r = rng.integers(0, 20, size=(n_r, 32)).astype(np.float32)
        s = rng.integers(0, 20, size=(n_s, 32)).astype(np.float32)
        return r, s, 0.55
    r = np.concatenate(
        [rng.normal(loc=c, scale=1.0, size=(n_r // 2, 5)) for c in (0.0, 4.0)]
    ).astype(np.float32)
    s = np.concatenate(
        [rng.normal(loc=c, scale=1.0, size=(n_s // 4, 5)) for c in (1.0, 4.0, 8.0, 12.0)]
    ).astype(np.float32)
    d = np.asarray(distances.pairwise(jnp.asarray(r), jnp.asarray(s), metric))
    delta = float(np.quantile(d, 0.03))
    return r, s, delta


# ---------------------------------------------------------------------------
# The oracle itself (overloaded call forms)
# ---------------------------------------------------------------------------


def test_brute_force_join_overloads(rng):
    x = jnp.asarray(rng.normal(size=(30, 4)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(50, 4)), jnp.float32)
    self_mask = np.asarray(distances.brute_force_join(x, 1.5, "l2"))
    assert self_mask.shape == (30, 30)
    assert not np.tril(self_mask).any()  # i < j only
    cross = np.asarray(distances.brute_force_join(x, y, 1.5, "l2"))
    assert cross.shape == (30, 50)
    d = np.asarray(distances.pairwise(x, y, "l2"))
    assert np.array_equal(cross, d <= 1.5)
    # keyword forms
    assert np.array_equal(
        np.asarray(distances.brute_force_join(x, s=y, delta=1.5, metric="l2")), cross
    )
    # empty sides
    empty = jnp.zeros((0, 4), jnp.float32)
    assert np.asarray(distances.brute_force_join(x, empty, 1.5)).shape == (30, 0)
    with pytest.raises(TypeError):
        distances.brute_force_join(x)
    with pytest.raises(TypeError):  # positional + keyword double assignment
        distances.brute_force_join(x, 1.5, delta=2.0)
    with pytest.raises(TypeError):
        distances.brute_force_join(x, y, 1.5, "l2", metric="l1")


def test_brute_force_pairs_cross_columns(rng):
    r = rng.normal(size=(10, 3)).astype(np.float32)
    s = rng.normal(size=(25, 3)).astype(np.float32)
    pairs = spjoin.brute_force_pairs(r, 2.0, "l1", s=s)
    assert pairs.shape[1] == 2
    assert (pairs[:, 0] < 10).all() and (pairs[:, 1] < 25).all()


# ---------------------------------------------------------------------------
# Reference executor parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", ["l1", "l2", "linf", "angular"])
def test_spjoin_rs_parity(metric, rng):
    """Acceptance criterion: R×S results exact for ≥2 metrics, |R| != |S|."""
    r, s, delta = _rs_dataset(metric, rng)
    cfg = spjoin.JoinConfig(delta=delta, metric=metric, k=64, p=6, n_dims=3, seed=0)
    res = spjoin.join(r, cfg, s=s)
    truth = spjoin.brute_force_pairs(r, delta, metric, s=s)
    assert np.array_equal(res.pairs, truth), (metric, res.pairs.shape, truth.shape)
    # cost model ran the R×S instantiation: no same-set (inner) term
    assert res.cost.inner == 0.0
    assert res.cost.duplication >= 0.0


@pytest.mark.parametrize("sampler", ["random", "distribution", "generative"])
def test_spjoin_rs_parity_all_samplers(sampler, rng):
    r, s, delta = _rs_dataset("l1", rng, n_r=60, n_s=160)
    cfg = spjoin.JoinConfig(
        delta=delta, metric="l1", sampler=sampler, k=48, p=4, n_dims=3, seed=1
    )
    res = spjoin.join(r, cfg, s=s)
    truth = spjoin.brute_force_pairs(r, delta, "l1", s=s)
    assert np.array_equal(res.pairs, truth), sampler


def test_spjoin_rs_shifted_distributions(rng):
    """The generator the benchmark uses: R and S have genuinely different
    per-node distributions; pooled R∪S pivots must still give an exact join."""
    r, s = synthetic.rs_mixture(90, 350, 6, n_clusters=3, shift=4.0, seed=2)
    cfg = spjoin.JoinConfig(delta=3.0, metric="l1", k=96, p=8, n_dims=4, seed=0)
    res = spjoin.join(r, cfg, s=s)
    truth = spjoin.brute_force_pairs(r, 3.0, "l1", s=s)
    assert np.array_equal(res.pairs, truth)


def test_spjoin_empty_s(rng):
    r = rng.normal(size=(50, 4)).astype(np.float32)
    cfg = spjoin.JoinConfig(delta=2.0, metric="l1", k=32, p=4, n_dims=3)
    res = spjoin.join(r, cfg, s=np.zeros((0, 4), np.float32))
    assert res.pairs.shape == (0, 2)
    assert res.n_verifications == 0


def test_spjoin_aliasing_reproduces_self_join(rng):
    """R = S aliasing must reproduce today's self-join pairs exactly."""
    data = rng.normal(size=(120, 4)).astype(np.float32)
    cfg = spjoin.JoinConfig(delta=1.5, metric="l2", k=48, p=6, n_dims=3, seed=0)
    self_res = spjoin.join(data, cfg)
    alias_res = spjoin.join(data, cfg, s=data)
    assert np.array_equal(self_res.pairs, alias_res.pairs)
    assert np.array_equal(self_res.pairs, spjoin.brute_force_pairs(data, 1.5, "l2"))


# ---------------------------------------------------------------------------
# Distributed executor parity (1-device mesh: fast tier; 8-device is slow)
# ---------------------------------------------------------------------------


def _dist_join(r, s, delta, metric, **kw):
    from repro.core import distributed

    mesh = jax.make_mesh((1,), ("data",))
    return distributed.distributed_join(
        jnp.asarray(r), s=None if s is None else jnp.asarray(s), mesh=mesh,
        delta=delta, metric=metric, k=48, p=4, n_dims=3,
        emit_pairs=True, seed=0, **kw,
    )


@pytest.mark.parametrize("metric", ["l1", "l2"])
def test_distributed_rs_parity(metric, rng):
    r, s, delta = _rs_dataset(metric, rng, n_r=60, n_s=150)
    res = _dist_join(r, s, delta, metric)
    truth = spjoin.brute_force_pairs(r, delta, metric, s=s)
    assert np.array_equal(res.pairs, truth), (metric, res.pairs.shape, truth.shape)
    assert res.overflow == 0
    assert res.duplication >= 0.0


def test_distributed_rs_empty_s(rng):
    r = rng.normal(size=(40, 4)).astype(np.float32)
    res = _dist_join(r, np.zeros((0, 4), np.float32), 2.0, "l1")
    assert res.pairs.shape == (0, 2)
    assert res.n_hits == 0


def test_distributed_rs_aliasing_matches_self(rng):
    data = rng.normal(size=(90, 4)).astype(np.float32)
    x = jnp.asarray(data)
    from repro.core import distributed

    mesh = jax.make_mesh((1,), ("data",))
    kw = dict(mesh=mesh, delta=1.5, metric="l2", k=48, p=4, n_dims=3,
              emit_pairs=True, seed=0)
    self_res = distributed.distributed_join(x, **kw)
    alias_res = distributed.distributed_join(x, s=x, **kw)
    assert np.array_equal(self_res.pairs, alias_res.pairs)
    assert np.array_equal(self_res.pairs, spjoin.brute_force_pairs(data, 1.5, "l2"))


@pytest.mark.slow
def test_distributed_rs_parity_8dev():
    """Multi-device cross join: subprocess with 8 simulated CPU devices."""
    import json
    import subprocess
    import sys
    import textwrap

    prog = (
        "import os\n"
        "os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n"
        + textwrap.dedent("""
        import json, numpy as np, jax, jax.numpy as jnp
        mesh = jax.make_mesh((8,), ("data",))
        from repro.core import distributed, spjoin
        from repro.data import synthetic
        out = {}
        for metric, delta in (("l1", 4.0), ("l2", 2.0)):
            r, s = synthetic.rs_mixture(120, 520, 6, n_clusters=4, shift=3.0, seed=5)
            res = distributed.distributed_join(
                jnp.asarray(r), s=jnp.asarray(s), mesh=mesh, delta=delta,
                metric=metric, k=192, p=16, n_dims=4, emit_pairs=True, seed=0)
            truth = spjoin.brute_force_pairs(r, delta, metric, s=s)
            out[metric] = dict(exact=bool(np.array_equal(res.pairs, truth)),
                               pairs=int(res.pairs.shape[0]),
                               overflow=int(res.overflow))
        print(json.dumps(out))
        """)
    )
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-4000:]
    res = json.loads(out.stdout.splitlines()[-1])
    for metric, row in res.items():
        assert row["exact"], (metric, row)
        assert row["overflow"] == 0


# ---------------------------------------------------------------------------
# Engine-level cross mode + R×S cost model
# ---------------------------------------------------------------------------


def test_verify_pairs_cross_full_membership(rng):
    """With all-cells membership the cross engine must equal the raw oracle."""
    r = rng.normal(size=(40, 4)).astype(np.float32)
    s = rng.normal(size=(70, 4)).astype(np.float32)
    cells = rng.integers(0, 3, size=40)
    member = np.ones((70, 3), bool)
    pairs, stats = verify.verify_pairs(r, cells, member, 2.0, "l1", data_w=s)
    mask = np.asarray(distances.brute_force_join(jnp.asarray(r), jnp.asarray(s), 2.0))
    want = np.stack(np.nonzero(mask), axis=1)
    assert np.array_equal(pairs, want)
    # V cells partition R: Σ_h |V_h|·|W_h| = |R|·|S| with full membership
    assert stats.n_verifications == 40 * 70


def test_verify_cross_tile_invariance(rng):
    r = rng.normal(size=(60, 4)).astype(np.float32)
    s = rng.normal(size=(90, 4)).astype(np.float32)
    cells = rng.integers(0, 4, size=60)
    member = rng.random((90, 4)) < 0.7
    base, _ = verify.verify_pairs(
        r, cells, member, 1.8, "l1", data_w=s,
        config=verify.EngineConfig(backend="numpy", tile_v=1024, tile_w=4096),
    )
    tiled, _ = verify.verify_pairs(
        r, cells, member, 1.8, "l1", data_w=s,
        config=verify.EngineConfig(backend="numpy", tile_v=8, tile_w=16),
    )
    assert np.array_equal(base, tiled)


def test_rs_partition_cost():
    v = np.array([3, 0, 5])
    w = np.array([10, 4, 2])
    c = cost_model.rs_partition_cost(v, w, n_s=16)
    assert c.inner == 0.0
    assert c.total == c.outer == 3 * 10 + 0 + 5 * 2
    assert c.max_cell == 30
    assert c.duplication == pytest.approx(16 / 16)


def test_rs_mixture_generator_shapes():
    r, s = synthetic.rs_mixture(50, 200, 7, seed=0)
    assert r.shape == (50, 7) and s.shape == (200, 7)
    assert r.dtype == np.float32 and s.dtype == np.float32
    r2, s2 = synthetic.rs_mixture(50, 200, 7, seed=0)
    assert np.array_equal(r, r2) and np.array_equal(s, s2)
    # shifted second set: the per-set means genuinely differ
    assert np.abs(r.mean(0) - s.mean(0)).max() > 0.5
