"""Shared fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see the real single device; multi-device tests spawn
subprocesses that set --xla_force_host_platform_device_count themselves."""
import os
import sys

import numpy as np
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end tests")
