"""Shared fixtures. NOTE: no XLA_FLAGS here by design — smoke tests and
benches must see the real single device; multi-device tests spawn
subprocesses that set --xla_force_host_platform_device_count themselves.

Hypothesis: the real library is a declared test dependency (CI installs it
via ``pip install -e .[test]``). When it is absent — hermetic containers
with no network — we fall back to the deterministic stub in ``tests/_stubs``
so the suite still collects and passes. CI selects the lighter ``ci``
profile (fewer examples, no deadline) via HYPOTHESIS_PROFILE=ci."""
import os
import sys

import numpy as np
import pytest

try:
    import repro  # noqa: F401  — editable install / PYTHONPATH=src is canonical
except ModuleNotFoundError:
    # Hermetic checkout run without `pip install -e .`: fall back to the
    # src layout declared in pyproject.toml.
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:
    import spjoin_lint  # noqa: F401  — the contract linter lives in tools/
except ModuleNotFoundError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.append(os.path.join(os.path.dirname(__file__), "_stubs"))
    import hypothesis  # noqa: F401

from hypothesis import settings as _hyp_settings  # noqa: E402

_hyp_settings.register_profile("ci", max_examples=10, deadline=None)
_hyp_settings.register_profile("nightly", max_examples=100, deadline=None)
if os.environ.get("HYPOTHESIS_PROFILE"):
    _hyp_settings.load_profile(os.environ["HYPOTHESIS_PROFILE"])


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running end-to-end tests")
