"""Tests for the spjoin-lint contract checker (tools/spjoin_lint).

Two layers, tested separately:

* AST rules — each rule gets a good/bad pair: the bad snippet (or the
  known-violating fixture module under ``tests/lint_fixtures/``) must fire
  the rule, the good one must stay silent.
* jaxpr auditor — each assertion family (f64 cast, collective budget,
  dynamic shapes, recompile budget) is driven with a function built to
  violate it and must be rejected.

The fixture tree mirrors ``repro/...`` paths because several rules are
scoped by path suffix (triad only in ``repro/kernels/ops.py``, stream tiers
only in the configured files).
"""
import json
import pathlib
import textwrap

import pytest

from spjoin_lint import astlint, cli, config, jaxpr_audit, waivers

FIXTURES = pathlib.Path(__file__).parent / "lint_fixtures"


def lint_snippet(tmp_path, relname: str, code: str):
    """Write ``code`` at tmp_path/<relname> and lint that one file."""
    path = tmp_path / relname
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(code))
    return astlint.lint_file(path)


def rules_fired(violations):
    return {v.rule for v in violations}


# ---------------------------------------------------------------------------
# AST rules: good/bad pairs
# ---------------------------------------------------------------------------


class TestHostSync:
    def test_bad_traced_sync(self, tmp_path):
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            import jax, numpy as np

            @jax.jit
            def f(x):
                return np.asarray(x).sum()
        """)
        assert rules_fired(vs) == {"host-sync"}

    def test_bad_item_and_float(self, tmp_path):
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            import jax
            import jax.numpy as jnp

            @jax.jit
            def f(x):
                return x[0].item() + float(jnp.max(x))
        """)
        assert len([v for v in vs if v.rule == "host-sync"]) == 2

    def test_good_static_args_not_flagged(self, tmp_path):
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            import jax
            import jax.numpy as jnp

            def f(x, delta):
                return x * float(delta)

            g = jax.jit(f, static_argnames=("delta",))
        """)
        assert vs == []

    def test_good_host_code_not_flagged(self, tmp_path):
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            import numpy as np

            def planner(xs):
                return float(np.asarray(xs).sum())
        """)
        assert vs == []

    def test_jit_assignment_seeds_traced_scope(self, tmp_path):
        # The seed is `g = jax.jit(f)` — f has no decorator.
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            import jax, numpy as np

            def f(x):
                return np.asarray(x)

            g = jax.jit(f)
        """)
        assert rules_fired(vs) == {"host-sync"}

    def test_propagation_reaches_callee(self, tmp_path):
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            import jax, numpy as np

            def helper(x):
                return np.asarray(x)

            @jax.jit
            def f(x):
                return helper(x)
        """)
        assert rules_fired(vs) == {"host-sync"}


class TestStreamTier:
    def test_fixture_flags_in_loop_only(self):
        vs = astlint.lint_file(FIXTURES / "repro/core/verify.py")
        sync = [v for v in vs if v.rule == "host-sync"]
        # Two in-loop syncs; pre-loop np.asarray and cold_helper are silent.
        assert len(sync) == 2
        assert all("verify_pairs" in v.message for v in sync)
        assert {v.line for v in sync} == {17, 21}


class TestDispatchTriad:
    def test_fixture_missing_legs(self):
        vs = astlint.lint_file(FIXTURES / "repro/kernels/ops.py")
        triad = [v for v in vs if v.rule == "dispatch-triad"]
        by_fn = {}
        for v in triad:
            name = v.message.split("`")[1]
            by_fn.setdefault(name, []).append(v)
        assert set(by_fn) == {"missing_pallas", "missing_everything"}
        assert len(by_fn["missing_pallas"]) == 1  # only the pallas leg
        assert len(by_fn["missing_everything"]) == 3  # all three legs
        # complete_op and delegating_op (transitively) are silent.

    def test_good_triad_not_flagged(self, tmp_path):
        vs = lint_snippet(tmp_path, "repro/kernels/ops.py", """
            from repro.kernels import pairdist as _pd
            from repro.kernels import ref

            def resolve_backend(b="auto"):
                return b

            def op(x, y, *, backend="auto"):
                backend = resolve_backend(backend)
                if backend == "pallas":
                    return _pd.kernel(x, y)
                return ref.oracle(x, y)
        """)
        assert [v for v in vs if v.rule == "dispatch-triad"] == []


class TestF64Cast:
    def test_fixture_module_wide_in_kernels(self):
        vs = astlint.lint_file(FIXTURES / "repro/kernels/ops.py")
        f64 = [v for v in vs if v.rule == "f64-cast"]
        assert len(f64) == 3  # np.float64, .astype(float), dtype=float

    def test_core_only_traced_scopes(self, tmp_path):
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            import jax
            import numpy as np

            def host_planner(x):
                return np.zeros(4, np.float64) + x

            @jax.jit
            def f(x):
                return x.astype("float64")
        """)
        f64 = [v for v in vs if v.rule == "f64-cast"]
        assert len(f64) == 1  # only the jitted astype; the planner is free


class TestDynControl:
    def test_bad_if_over_tracer(self, tmp_path):
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            import jax
            import jax.numpy as jnp

            @jax.jit
            def f(x):
                if jnp.sum(x) > 0:
                    return x
                return -x
        """)
        assert "dyn-control" in rules_fired(vs)

    def test_good_static_if(self, tmp_path):
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            import jax
            import jax.numpy as jnp

            def f(x, metric):
                if metric == "l2":
                    return jnp.square(x)
                return jnp.abs(x)

            g = jax.jit(f, static_argnames=("metric",))
        """)
        assert vs == []

    def test_good_host_utility_call(self, tmp_path):
        # jax.default_backend() returns a Python string, not a tracer.
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            import jax

            @jax.jit
            def f(x):
                return x * (2 if jax.default_backend() == "tpu" else 1)
        """)
        assert vs == []


class TestCollectiveSite:
    def test_bad_unblessed_all_to_all(self, tmp_path):
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            import jax

            def shuffle(x):
                return jax.lax.all_to_all(x, "data", 0, 0)
        """)
        assert rules_fired(vs) == {"collective-site"}

    def test_good_blessed_factory(self, tmp_path):
        # Same call, but in the blessed (file, function) site.
        vs = lint_snippet(tmp_path, "repro/core/distributed.py", """
            import jax

            def _make_exchange(axis):
                def exchange(x):
                    return jax.lax.all_to_all(x, axis, 0, 0)
                return exchange
        """)
        assert [v for v in vs if v.rule == "collective-site"] == []


class TestPallasConfined:
    def test_bad_core_imports(self):
        vs = astlint.lint_file(FIXTURES / "repro/core/bad_hotpath.py")
        confined = [v for v in vs if v.rule == "pallas-confined"]
        assert len(confined) == 2  # raw kernel module + pallas itself

    def test_good_ops_import(self, tmp_path):
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            from repro.kernels import ops, ref

            def f(x, y):
                return ops.pairdist(x, y, metric="l2", backend="auto")
        """)
        assert vs == []


class TestWaivers:
    def test_waiver_suppresses(self, tmp_path):
        vs = lint_snippet(tmp_path, "repro/core/mod.py", """
            import jax, numpy as np

            @jax.jit
            def f(x):
                # spjoin-lint: allow[host-sync] -- fixture: deliberately waived
                return np.asarray(x).sum()
        """)
        assert vs == []

    def test_waiver_hygiene_from_fixture(self):
        vs = astlint.lint_file(FIXTURES / "repro/core/bad_hotpath.py")
        hygiene = [v for v in vs if v.rule == "waiver-hygiene"]
        msgs = " | ".join(v.message for v in hygiene)
        assert "unknown rule" in msgs
        assert "justification" in msgs
        assert "unused waiver" in msgs

    def test_ratchet(self, tmp_path, monkeypatch):
        monkeypatch.setattr(config, "MAX_WAIVERS", 1)
        code = """
            import jax, numpy as np

            @jax.jit
            def f(x):
                a = np.asarray(x)  # spjoin-lint: allow[host-sync] -- fixture waiver one
                b = np.asarray(x)  # spjoin-lint: allow[host-sync] -- fixture waiver two
                return a + b
        """
        path = tmp_path / "repro/core/mod.py"
        path.parent.mkdir(parents=True)
        path.write_text(textwrap.dedent(code))
        vs, n = astlint.lint_paths([str(tmp_path)])
        assert n == 2
        assert any(
            v.rule == "waiver-hygiene" and "ratchet" in v.message for v in vs
        )

    def test_parse_binds_standalone_comment(self):
        src = "x = 1\n# spjoin-lint: allow[host-sync] -- next line\n\ny = 2\n"
        ws = waivers.parse_waivers(src, "f.py")
        assert len(ws) == 1 and ws[0].target_line == 4


class TestFixtureInventory:
    def test_bad_fixture_fires_six_rules(self):
        """The headline acceptance check: >= 6 distinct AST rules
        demonstrably fire across the known-violating fixture tree."""
        fired = set()
        for f in sorted(FIXTURES.rglob("*.py")):
            if f.name != "clean_mod.py":
                fired |= rules_fired(astlint.lint_file(f))
        assert {
            "host-sync", "dispatch-triad", "f64-cast", "dyn-control",
            "collective-site", "pallas-confined", "waiver-hygiene",
        } <= fired

    def test_clean_fixture_is_silent(self):
        assert astlint.lint_file(FIXTURES / "repro/core/clean_mod.py") == []

    def test_real_tree_is_clean(self):
        root = pathlib.Path(__file__).parent.parent / "src"
        vs, n_waivers = astlint.lint_paths([str(root)])
        assert vs == []
        assert n_waivers <= config.MAX_WAIVERS


# ---------------------------------------------------------------------------
# jaxpr auditor
# ---------------------------------------------------------------------------


class TestJaxprAudit:
    def test_rejects_f64_cast(self):
        import jax
        import jax.numpy as jnp

        def promoting(x):
            return x.astype(jnp.float64)

        with jax.experimental.enable_x64():
            entry = jaxpr_audit.trace_entry(
                "bad_f64", promoting, (jnp.zeros((4,), jnp.float32),)
            )
        assert entry["f64_casts"] >= 1

    def test_collective_budget_counts_all_sites(self):
        # A function with TWO all_to_all calls must trace as 2, exceeding a
        # 1-per-stage contract.
        import jax
        import jax.numpy as jnp
        import numpy as np
        from jax.sharding import Mesh
        from repro.compat import shard_map

        mesh = Mesh(np.array(jax.devices("cpu")[:1]), ("data",))

        def noisy(x):
            a = jax.lax.all_to_all(x[None], "data", 0, 0)
            b = jax.lax.all_to_all(x[None], "data", 0, 0)
            return a + b

        fn = shard_map(
            noisy, mesh=mesh, in_specs=jax.sharding.PartitionSpec("data"),
            out_specs=jax.sharding.PartitionSpec("data"),
        )
        entry = jaxpr_audit.trace_entry(
            "two_shuffles", fn, (jnp.zeros((1, 4), jnp.float32),)
        )
        assert entry["collectives"] == {"all_to_all": 2}
        assert entry["collectives"] != {"all_to_all": 1}

    def test_rejects_dynamic_output_shape(self):
        import jax.numpy as jnp

        def dynamic(x):
            return x[x > 0]  # boolean masking: data-dependent shape

        entry = jaxpr_audit.trace_entry(
            "dyn", dynamic, (jnp.zeros((8,), jnp.float32),)
        )
        assert entry["errors"]
        assert "untraceable" in entry["errors"][0]

    def test_recompile_budget_flags_identity_bucketing(self):
        # An identity "bucketing" (no quantization) has cap distinct shapes
        # and must blow any sane budget; the real quarter-pow2 one must not.
        from repro.core.verify import bucket_size

        bad = jaxpr_audit.audit_bucket_family(
            lambda n, cap, floor=8: max(n, floor), 1024, 4096
        )
        assert bad["errors"]
        good = jaxpr_audit.audit_bucket_family(bucket_size, 1024, 4096)
        assert good["errors"] == []
        assert good["v_buckets"] <= jaxpr_audit.RECOMPILE_BUDGET["v_buckets"]

    def test_walk_recurses_into_pjit(self):
        import jax
        import jax.numpy as jnp

        @jax.jit
        def inner(x):
            return x.astype(jnp.float64)

        def outer(x):
            return inner(x) + 1

        with jax.experimental.enable_x64():
            entry = jaxpr_audit.trace_entry(
                "nested", outer, (jnp.zeros((4,), jnp.float32),)
            )
        assert entry["f64_casts"] >= 1  # found inside the pjit sub-jaxpr


@pytest.mark.slow
class TestEndToEnd:
    def test_full_audit_matches_baseline(self, tmp_path):
        contracts, problems = jaxpr_audit.run_audit(
            out_path=str(tmp_path / "contracts.json"),
            baseline_path=str(
                pathlib.Path(__file__).parent.parent
                / "tools/spjoin_lint/contracts_baseline.json"
            ),
        )
        assert problems == []
        assert (tmp_path / "contracts.json").exists()
        written = json.loads((tmp_path / "contracts.json").read_text())
        assert written["entries"].keys() == contracts["entries"].keys()

    def test_cli_end_to_end(self, capsys):
        root = pathlib.Path(__file__).parent.parent
        rc = cli.main([str(root / "src")])
        out = capsys.readouterr().out
        assert rc == 0
        assert "0 violation(s)" in out

    def test_cli_fails_on_fixture(self, capsys):
        rc = cli.main([str(FIXTURES)])
        out = capsys.readouterr().out
        assert rc == 1
        assert "[dispatch-triad]" in out and "[host-sync]" in out
