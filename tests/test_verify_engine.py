"""The streaming tiled verify engine (repro.core.verify): parity with the
brute-force oracle across metrics and backends, streaming invariance to tile
size, bucket quantization, and degenerate-cell edge cases."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import distances, spjoin, verify

# Join-level exactness holds for true metrics only (cosine is a pseudo-metric:
# the space mapping's completeness lemma needs the triangle inequality).
EXACT_METRICS = ["l1", "l2", "linf", "angular", "jaccard_minhash"]
BACKENDS = ["numpy", "pallas"]  # pallas = interpret mode on CPU (CI path)


def _dataset(metric, rng, n=150):
    if metric == "jaccard_minhash":
        return rng.integers(0, 20, size=(n, 32)).astype(np.float32), 0.55
    data = np.concatenate(
        [rng.normal(loc=c, scale=1.0, size=(n // 3, 5)) for c in (0.0, 4.0, 9.0)]
    ).astype(np.float32)
    d = np.asarray(distances.pairwise(jnp.asarray(data), jnp.asarray(data), metric))
    delta = float(np.quantile(d[np.triu_indices(len(data), 1)], 0.02))
    return data, delta


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("metric", EXACT_METRICS)
def test_join_parity_all_metrics_both_backends(metric, backend, rng):
    """Acceptance criterion: join pairs == brute_force_pairs for every metric
    under both engine backends."""
    data, delta = _dataset(metric, rng)
    cfg = spjoin.JoinConfig(
        delta=delta, metric=metric, k=64, p=6, n_dims=3, backend=backend, seed=0
    )
    res = spjoin.join(data, cfg)
    truth = spjoin.brute_force_pairs(data, delta, metric)
    assert np.array_equal(res.pairs, truth), (metric, backend, res.n_pairs)
    assert res.verify_stats is not None
    assert res.verify_stats.n_verifications == res.n_verifications
    assert 0.0 < res.verify_stats.occupancy <= 1.0


@pytest.mark.parametrize("backend", BACKENDS)
def test_engine_matches_reference_loop(backend, rng):
    """Engine == the seed's dense per-cell loop on identical (cells, member),
    including hit counts — the two reduce implementations may never diverge."""
    x = rng.normal(size=(180, 6)).astype(np.float32)
    cells = rng.integers(0, 5, size=180)
    member = rng.random((180, 5)) < 0.6
    member[np.arange(180), cells] = True  # each row W-members its own cell
    got, stats = verify.verify_pairs(
        x, cells, member, 2.5, "l1", config=verify.EngineConfig(backend=backend)
    )
    want, n_verif = verify.reference_verify(x, cells, member, 2.5, "l1")
    assert np.array_equal(got, want)
    assert stats.n_verifications == n_verif
    assert stats.n_hits == got.shape[0]


def test_engine_exact_on_pseudo_metric_given_full_membership(rng):
    """With all-pairs membership the engine's verify semantics are exact even
    for cosine — join-level gaps come from the mapping, never from verify."""
    x = rng.normal(size=(80, 4)).astype(np.float32)
    cells = rng.integers(0, 3, size=80)
    member = np.ones((80, 3), bool)
    pairs, _ = verify.verify_pairs(x, cells, member, 0.05, "cosine")
    d = np.asarray(distances.pairwise(jnp.asarray(x), jnp.asarray(x), "cosine"))
    iu = np.triu_indices(80, 1)
    want = np.stack(iu, 1)[d[iu] <= 0.05]
    assert np.array_equal(pairs, want)


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(0, 1000),
    backend=st.sampled_from(BACKENDS),
    tile_v=st.sampled_from([8, 128]),
    tile_w=st.sampled_from([8, 128]),
)
def test_tiled_streaming_invariant_to_tile_size(seed, backend, tile_v, tile_w):
    """THE streaming property: output is identical for any tile capacity —
    tiling is an execution schedule, not a semantics change."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(90, 4)).astype(np.float32)
    cells = rng.integers(0, 4, size=90)
    member = rng.random((90, 4)) < 0.7
    member[np.arange(90), cells] = True
    base, _ = verify.verify_pairs(
        x, cells, member, 1.8, "l1",
        config=verify.EngineConfig(backend="numpy", tile_v=1024, tile_w=4096),
    )
    tiled, stats = verify.verify_pairs(
        x, cells, member, 1.8, "l1",
        config=verify.EngineConfig(backend=backend, tile_v=tile_v, tile_w=tile_w),
    )
    assert np.array_equal(base, tiled), (tile_v, tile_w, backend)
    assert stats.n_padded >= stats.n_verifications


def test_empty_cells_and_all_empty():
    x = np.random.default_rng(0).normal(size=(20, 3)).astype(np.float32)
    cells = np.zeros(20, np.int64)  # everything in cell 0; cells 1,2 empty
    member = np.zeros((20, 3), bool)
    member[:, 0] = True
    pairs, stats = verify.verify_pairs(x, cells, member, 100.0, "l1")
    assert stats.n_cells == 1 and pairs.shape[0] == 20 * 19 // 2  # delta=100: all
    # empty V on one side, empty W on the other, fully empty overall:
    pairs2, stats2 = verify.verify_cell_lists(
        x, cells,
        v_lists=[np.arange(10), np.array([], np.int64), np.arange(10, 20)],
        w_lists=[np.array([], np.int64), np.arange(20), np.array([], np.int64)],
        delta=1.0, metric="l1",
    )
    assert pairs2.shape == (0, 2) and stats2.n_verifications == 0
    assert stats2.n_cells == 0 and stats2.n_tiles == 0
    pairs3, stats3 = verify.verify_cell_lists(
        x, cells, v_lists=[], w_lists=[], delta=1.0, metric="l1"
    )
    assert pairs3.shape == (0, 2) and stats3.occupancy == 0.0


def test_return_pairs_false_still_counts(rng):
    x = rng.normal(size=(60, 3)).astype(np.float32)
    cells = rng.integers(0, 2, size=60)
    member = np.ones((60, 2), bool)
    pairs, stats = verify.verify_pairs(x, cells, member, 2.0, "l1",
                                       return_pairs=False)
    assert pairs.shape == (0, 2)
    assert stats.n_verifications == 60 * 60  # both cells: |V_h| * 60
    _, want = verify.reference_verify(x, cells, member, 2.0, "l1")
    assert stats.n_verifications == want


def test_bucket_size_quantization():
    assert verify.bucket_size(1, 1024) == 8  # floor
    assert verify.bucket_size(8, 1024) == 8
    assert verify.bucket_size(9, 1024) == 16  # octave 16, quantum floored at 8
    assert verify.bucket_size(100, 1024) == 128  # octave 128, quantum 32
    assert verify.bucket_size(129, 1024) == 192
    assert verify.bucket_size(5000, 1024) == 1024  # capped at tile capacity
    for n in range(1, 300):
        b = verify.bucket_size(n, 256)
        assert b >= min(n, 256) and b <= 256
        if n <= 256:
            assert b <= max(2 * n, 8)  # bounded padding overhead


def test_dedup_rule_unit():
    """min-cell rule: W rows in a lower cell never emit here; same-cell pairs
    keep id_v < id_w; padding never emits."""
    hits = jnp.ones((3, 4), bool)
    vids = jnp.array([0, 1, -1])
    wids = jnp.array([0, 1, 5, -1])
    wcells = jnp.array([2, 1, 3, -1])  # this cell = 2
    out = np.asarray(verify.apply_dedup(hits, vids, wids, wcells, 2))
    # v=0: w0 same cell id 0 !< 0 -> no; w1 cell 1 < 2 -> no; w2 cell 3 -> yes
    assert out.tolist() == [
        [False, False, True, False],
        [False, False, True, False],
        [False, False, False, False],  # padded V row
    ]
