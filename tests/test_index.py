"""The persistent metric index: build-once / query-forever semantics.

Covers the ISSUE-6 contract: query parity with the brute-force oracle on
every exact metric (including δ ≠ build-δ), save/load byte-identity of
pivots/coords/plan, loud failures on foreign or mismatched artifacts, the
no-rebuild-on-query regression (module-attribute call counters), and the
distributed serving path (1 device inline; 8 simulated devices under the
``slow`` marker, subprocess-isolated like tests/test_distributed.py)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import numpy as np
import pytest

from repro.core import index as index_lib
from repro.core import mapping, partition, spjoin

EXACT_METRICS = ["l1", "l2", "linf", "angular", "jaccard_minhash"]
DELTAS = {"l1": 2.0, "l2": 1.0, "linf": 0.6, "angular": 0.15,
          "jaccard_minhash": 0.4}


def _dataset(rng, metric, n=260, n_q=70):
    if metric == "jaccard_minhash":
        r = rng.integers(0, 30, size=(n, 16)).astype(np.float32)
        # random signatures almost never collide — queries are perturbed
        # copies of indexed rows (3/16 coords flipped -> distance 0.1875)
        q = r[:n_q].copy()
        q[:, :3] = rng.integers(30, 60, size=(n_q, 3))
    else:
        r = rng.normal(size=(n, 5)).astype(np.float32)
        q = rng.normal(size=(n_q, 5)).astype(np.float32)
    return r, q


def _build(r, metric, delta, **kw):
    cfg = spjoin.JoinConfig(delta=delta, metric=metric, k=64, p=8, n_dims=3,
                            **kw)
    return index_lib.build_index(r, cfg)


# ---------------------------------------------------------------------------
# Query parity vs the brute-force oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("metric", EXACT_METRICS)
def test_query_batch_parity_all_exact_metrics(metric, rng):
    r, q = _dataset(rng, metric)
    delta = DELTAS[metric]
    idx = _build(r, metric, delta)
    truth = index_lib.brute_force_query(r, q, delta, metric)
    assert truth.shape[0] > 0, "degenerate dataset: oracle found nothing"
    pairs = idx.query_batch(q)
    assert pairs.tobytes() == truth.tobytes()


def test_query_delta_differs_from_build_delta(rng):
    """The stored boxes are pre-expansion: any query radius answers exactly,
    below or above the build default."""
    r, q = _dataset(rng, "l2")
    idx = _build(r, "l2", 1.0)
    for delta in (0.4, 1.0, 1.7):
        truth = index_lib.brute_force_query(r, q, delta, "l2")
        np.testing.assert_array_equal(idx.query_batch(q, delta), truth)


def test_single_query_and_stats(rng):
    r, q = _dataset(rng, "l1")
    idx = _build(r, "l1", 2.0)
    truth = index_lib.brute_force_query(r, q[:1], 2.0, "l1")
    np.testing.assert_array_equal(idx.query(q[0]), np.sort(truth[:, 0]))
    with pytest.raises(ValueError):
        idx.query(q)  # a batch is not a point
    pairs, stats = idx.query_batch(q, with_stats=True)
    assert stats.n_queries == q.shape[0]
    assert stats.n_routed >= stats.n_queries  # every in-box query owns >=1 cell
    assert 0 < stats.n_cells_touched <= idx.p
    assert stats.duplication == stats.n_routed / stats.n_queries


def test_empty_results_and_out_of_box_queries(rng):
    r, _ = _dataset(rng, "l2")
    idx = _build(r, "l2", 0.5)
    far = np.full((6, 5), 500.0, np.float32)  # outside every δ-expanded box
    assert idx.query_batch(far).shape == (0, 2)
    assert idx.query(far[0]).shape == (0,)
    assert idx.query_batch(np.zeros((0, 5), np.float32)).shape == (0, 2)
    _, stats = idx.query_batch(far, with_stats=True)
    assert stats.n_routed == 0 and stats.n_cells_touched == 0


def test_query_batch_fused_on_off_byte_identical(rng):
    r, q = _dataset(rng, "l2")
    on = _build(r, "l2", 1.0, map_fused=True)
    off = _build(r, "l2", 1.0, map_fused=False)
    assert on.query_batch(q).tobytes() == off.query_batch(q).tobytes()


# ---------------------------------------------------------------------------
# Save / load
# ---------------------------------------------------------------------------


def test_save_load_roundtrip_byte_identity(rng, tmp_path):
    r, q = _dataset(rng, "l2")
    idx = _build(r, "l2", 1.0)
    path = idx.save(str(tmp_path / "idx"))
    idx2 = index_lib.MetricIndex.load(path)
    for name in index_lib._ARRAYS:
        assert getattr(idx, name).tobytes() == getattr(idx2, name).tobytes(), name
    for name in index_lib._PLAN_ARRAYS:
        a = np.asarray(getattr(idx.placement, name))
        b = np.asarray(getattr(idx2.placement, name))
        assert a.tobytes() == b.tobytes(), name
    assert idx2.metric == idx.metric and idx2.delta == idx.delta
    assert idx.query_batch(q).tobytes() == idx2.query_batch(q).tobytes()


def test_load_accepts_matching_expectations(rng, tmp_path):
    r, _ = _dataset(rng, "l1")
    path = _build(r, "l1", 2.0).save(str(tmp_path / "idx"))
    idx = index_lib.MetricIndex.load(path, metric="l1", delta=2.0, k=64)
    assert idx.metric == "l1"


def test_load_rejects_mismatched_config(rng, tmp_path):
    r, _ = _dataset(rng, "l1")
    path = _build(r, "l1", 2.0).save(str(tmp_path / "idx"))
    with pytest.raises(index_lib.IndexMismatchError, match="metric"):
        index_lib.MetricIndex.load(path, metric="l2")
    with pytest.raises(index_lib.IndexMismatchError, match="delta"):
        index_lib.MetricIndex.load(path, delta=9.0)
    with pytest.raises(index_lib.IndexMismatchError, match="pivots"):
        index_lib.MetricIndex.load(path, k=999)


def test_load_rejects_foreign_or_corrupt_artifacts(rng, tmp_path):
    with pytest.raises(index_lib.IndexFormatError, match="manifest"):
        index_lib.MetricIndex.load(str(tmp_path / "nowhere"))

    r, _ = _dataset(rng, "l1")
    path = _build(r, "l1", 2.0).save(str(tmp_path / "idx"))
    mpath = os.path.join(path, "manifest.json")
    man = json.load(open(mpath))

    json.dump({**man, "format": "something-else"}, open(mpath, "w"))
    with pytest.raises(index_lib.IndexFormatError, match="format"):
        index_lib.MetricIndex.load(path)

    json.dump({**man, "version": index_lib.FORMAT_VERSION + 1}, open(mpath, "w"))
    with pytest.raises(index_lib.IndexFormatError, match="version"):
        index_lib.MetricIndex.load(path)

    # manifest-vs-npz shape disagreement (artifact mixed between saves)
    man2 = dict(man)
    man2["arrays"] = {**man["arrays"], "pivots": [1, 1]}
    json.dump(man2, open(mpath, "w"))
    with pytest.raises(index_lib.IndexFormatError, match="corrupt|shape"):
        index_lib.MetricIndex.load(path)


def test_manifest_carries_incremental_counters(rng, tmp_path):
    r, _ = _dataset(rng, "l2")
    idx = _build(r, "l2", 1.0)
    idx.insert_batch(rng.normal(size=(40, 5)).astype(np.float32))
    man = idx.manifest()
    inc = man["incremental"]
    assert inc["n_base"] == 260 and inc["n_inserted"] == 40
    assert inc["n_base"] + inc["n_inserted"] == man["n_rows"]
    assert inc["n_batches"] == 1


def test_save_insert_load_insert_byte_identity(rng, tmp_path):
    """The ISSUE-8 round trip: save mid-stream, load, keep inserting — the
    loaded index's continuation is byte-identical to the uninterrupted one
    (arrays, observed_w drift state, emitted pairs, final answers)."""
    r = rng.normal(size=(200, 5)).astype(np.float32)
    d1 = rng.normal(size=(50, 5)).astype(np.float32)
    d2 = rng.normal(size=(30, 5)).astype(np.float32)
    live = _build(r, "l2", 1.0)
    p1_live, _ = live.insert_batch(d1)
    path = live.save(str(tmp_path / "stream"))

    loaded = index_lib.MetricIndex.load(path)
    assert (loaded.n_base, loaded.n_inserted, loaded.n_batches) == (200, 50, 1)
    for name in index_lib._ARRAYS:  # observed_w included since format v2
        assert getattr(live, name).tobytes() == getattr(loaded, name).tobytes(), name

    p2_live, s_live = live.insert_batch(d2)
    p2_loaded, s_loaded = loaded.insert_batch(d2)
    assert p2_live.tobytes() == p2_loaded.tobytes()
    assert np.isclose(s_live.drift, s_loaded.drift)
    assert s_live.action == s_loaded.action
    full = np.concatenate([r, d1, d2])
    q = rng.normal(size=(40, 5)).astype(np.float32)
    truth = index_lib.brute_force_query(full, q, 1.0, "l2")
    assert loaded.query_batch(q).tobytes() == truth.tobytes()


def test_load_rejects_manifest_without_incremental_block(rng, tmp_path):
    r, _ = _dataset(rng, "l1")
    path = _build(r, "l1", 2.0).save(str(tmp_path / "idx"))
    mpath = os.path.join(path, "manifest.json")
    man = json.load(open(mpath))
    man.pop("incremental")
    json.dump(man, open(mpath, "w"))
    with pytest.raises(index_lib.IndexFormatError, match="incremental"):
        index_lib.MetricIndex.load(path)


def test_load_rejects_inconsistent_stream_counters(rng, tmp_path):
    r, _ = _dataset(rng, "l1")
    path = _build(r, "l1", 2.0).save(str(tmp_path / "idx"))
    mpath = os.path.join(path, "manifest.json")
    man = json.load(open(mpath))
    man["incremental"]["n_inserted"] = 7  # n_base + n_inserted != n_rows
    json.dump(man, open(mpath, "w"))
    with pytest.raises(index_lib.IndexMismatchError, match="stream"):
        index_lib.MetricIndex.load(path)


# ---------------------------------------------------------------------------
# Regression: queries never re-enter the build control plane
# ---------------------------------------------------------------------------


def test_second_query_performs_no_sampling_or_partitioning(rng, monkeypatch):
    counts = {"fit": 0, "draw": 0, "anchors": 0, "partition": 0}
    wrap = lambda key, fn: (lambda *a, **k: (counts.__setitem__(key, counts[key] + 1), fn(*a, **k))[1])
    monkeypatch.setattr(spjoin, "fit_node_stats", wrap("fit", spjoin.fit_node_stats))
    monkeypatch.setattr(spjoin, "draw_pivots", wrap("draw", spjoin.draw_pivots))
    monkeypatch.setattr(mapping, "select_anchors", wrap("anchors", mapping.select_anchors))
    monkeypatch.setattr(partition, "build_partition", wrap("partition", partition.build_partition))

    r, q = _dataset(rng, "l2")
    idx = _build(r, "l2", 1.0)
    after_build = dict(counts)
    assert all(v == 1 for v in after_build.values()), after_build

    idx.query_batch(q)
    idx.query_batch(q, delta=0.5)  # different radius: still no rebuild
    idx.query(q[0])
    assert counts == after_build, f"query phase re-entered the build: {counts}"


# ---------------------------------------------------------------------------
# Distributed serving
# ---------------------------------------------------------------------------


def test_dist_index_parity_1dev(rng):
    r, q = _dataset(rng, "l2", n=300, n_q=90)
    idx = _build(r, "l2", 1.0)
    mesh = jax.make_mesh((1,), ("data",))
    didx = idx.to_distributed(mesh)
    truth = index_lib.brute_force_query(r, q, 1.0, "l2")
    assert didx.query_batch(q).tobytes() == truth.tobytes()
    # δ override flows through the distributed stage cache too
    truth_wide = index_lib.brute_force_query(r, q, 1.6, "l2")
    np.testing.assert_array_equal(didx.query_batch(q, 1.6), truth_wide)


def test_dist_index_rejects_kernel_less_metrics(rng):
    r, _ = _dataset(rng, "angular")
    idx = _build(r, "angular", 0.15)
    mesh = jax.make_mesh((1,), ("data",))
    with pytest.raises(ValueError, match="metric"):
        idx.to_distributed(mesh)


@pytest.mark.slow
def test_dist_index_parity_8dev_subprocess():
    """Serve on an 8-device mesh an index whose stored plan targets 4
    devices — exercises the cheap re-plan path. Subprocess-isolated so the
    device-count flag never leaks (tests/test_distributed.py pattern)."""
    prog = "import os\nos.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n" + textwrap.dedent("""
    import json, numpy as np, jax
    from repro.core import index as index_lib, spjoin
    rng = np.random.default_rng(0)
    r = rng.normal(size=(800, 6)).astype(np.float32)
    q = rng.normal(size=(160, 6)).astype(np.float32)
    cfg = spjoin.JoinConfig(delta=1.0, metric="l2", k=128, p=16, n_dims=4)
    idx = index_lib.build_index(r, cfg, n_devices=4)
    mesh = jax.make_mesh((8,), ("data",))
    didx = idx.to_distributed(mesh)
    truth = index_lib.brute_force_query(r, q, 1.0, "l2")
    got = didx.query_batch(q)
    print(json.dumps({
        "exact": bool(np.array_equal(got, truth)),
        "host_exact": bool(np.array_equal(idx.query_batch(q), truth)),
        "n_pairs": int(truth.shape[0]),
    }))
    """)
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert out.returncode == 0, out.stderr[-4000:]
    res = json.loads(out.stdout.splitlines()[-1])
    assert res["exact"] and res["host_exact"]
    assert res["n_pairs"] > 0
