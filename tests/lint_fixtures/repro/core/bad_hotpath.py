"""Known-violating fixture for spjoin-lint's AST rules.

This module is NEVER imported or executed — it exists so tests/test_lint.py
can assert that each rule actually fires. Every violation below is
deliberate. Its path contains ``repro/core/`` so the core-scoped rules
(pallas-confined, traced-scope host-sync/f64) apply.
"""
import jax
import jax.numpy as jnp
import numpy as np

# pallas-confined: core/ may not import raw kernel modules or pallas itself.
from repro.kernels import pairdist  # noqa: F401
from jax.experimental import pallas as pl  # noqa: F401


@jax.jit
def traced_sync(x):
    # host-sync (traced): np.asarray on a tracer is a trace-time transfer.
    host = np.asarray(x)
    # host-sync (traced): .item() blocks on the device.
    first = x[0].item()
    # host-sync (traced): float() concretizes the tracer.
    scale = float(jnp.max(x))
    return host.sum() + first + scale


@jax.jit
def traced_control(x):
    # dyn-control: Python `if` over a traced value.
    if jnp.sum(x) > 0:
        x = x * 2
    # dyn-control: conditional expression over a traced value.
    y = x if jnp.any(x > 1) else -x
    # f64-cast (traced scope): explicit float64 promotion.
    return y.astype(jnp.float64)


def rogue_collective(x):
    # collective-site: all_to_all outside the blessed _make_exchange factory.
    return jax.lax.all_to_all(x, "data", 0, 0)


def helper_calls_traced(x):
    return traced_sync(x)


# Waiver-hygiene fixtures ---------------------------------------------------

# spjoin-lint: allow[made-up-rule] -- the rule name does not exist
A = 1

# spjoin-lint: allow[host-sync]
B = 2

# spjoin-lint: allow[f64-cast] -- nothing on the next line violates f64-cast
C = 3
