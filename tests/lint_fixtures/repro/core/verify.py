"""Stream-tier fixture: named ``repro/core/verify.py`` so the config's
STREAM_SCOPES tiers apply to ``verify_pairs``. Never imported; parsed only.

The stream tier flags syncs only INSIDE loop bodies — the pre-loop sync
below must NOT fire, the in-loop ones must.
"""
import numpy as np


def verify_pairs(tiles, data):
    # Fine: one normalization before the loop starts.
    data = np.asarray(data)
    out = []
    for t in tiles:
        # host-sync (stream): a device->host transfer per tile stalls the
        # pipeline the streaming engine exists to keep full.
        mask = np.asarray(t)
        # host-sync (stream): int() over a jnp expression syncs per tile.
        import jax.numpy as jnp

        n = int(jnp.sum(t))
        out.append((mask, n))
    return out


def cold_helper(xs):
    # Not a configured stream scope: free to sync anywhere.
    return [np.asarray(x) for x in xs]
