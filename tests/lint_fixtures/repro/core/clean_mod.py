"""Clean fixture: everything here must pass every spjoin-lint rule.

Never imported; parsed only.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops  # blessed: the dispatch layer


@jax.jit
def traced_clean(x, y):
    d = jnp.abs(x[:, None, :] - y[None, :, :]).sum(-1)
    mask = jnp.where(d <= 1.0, 1.0, 0.0)
    return mask.astype(jnp.float32)


def host_driver(xs):
    # Host code outside any hot scope: syncs here are fine.
    total = 0.0
    arr = np.asarray(xs)
    for row in arr:
        total += float(row.sum())
    return total, ops


def static_shapes_only(x, n: int):
    # int() over a static Python value, not a tracer.
    k = int(n) * 2
    return jnp.zeros((k,), jnp.float32) + x.sum()
