"""Known-violating fixture for the dispatch-triad and module-wide f64 rules.

Never imported; parsed only. The path ends in ``repro/kernels/ops.py`` so
the triad rule applies, and lives under ``repro/kernels/`` so the f64 rule
is module-wide.
"""
import numpy as np

from repro.kernels import pairdist as _pairdist
from repro.kernels import ref


def resolve_backend(backend="auto"):
    return "numpy" if backend == "auto" else backend


def complete_op(x, y, *, backend="auto"):
    """All three legs: dispatch arm + ref oracle + pallas kernel."""
    backend = resolve_backend(backend)
    if backend == "pallas":
        return _pairdist.pairdist_kernel(x, y)
    return ref.pairdist(x, y, "l2")


def missing_pallas(x, y, *, backend="auto"):
    """Has dispatch + ref but never reaches a kernel-module call."""
    backend = resolve_backend(backend)
    return ref.pairdist(x, y, "l2")


def missing_everything(x, y, *, backend="auto"):
    """Takes backend= but implements a single hardwired path."""
    return abs(x - y)


def delegating_op(x, y, *, backend="auto"):
    """Triad satisfied transitively via same-module delegation."""
    return complete_op(x, y, backend=backend)


def f64_scratch(x):
    # f64-cast (module-wide in kernels/): three spellings of the promotion.
    a = np.zeros(4, np.float64)
    b = x.astype(float)
    c = np.arange(4, dtype=float)
    return a, b, c
