"""Sharding-profile rules: divisibility fallbacks, FSDP remap, elastic mesh.

Uses an 8-device subprocess-free path: spec construction needs no devices
beyond mesh *shape* arithmetic, so we build abstract meshes."""
import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.models import base
from repro.models.base import ParamDef


class _FakeMesh:
    """Duck-typed mesh: spec_for only touches .axis_names and .shape."""

    def __init__(self, shape: dict):
        self.shape = shape
        self.axis_names = tuple(shape)


MESH = _FakeMesh({"data": 16, "model": 16})
MESH_MP = _FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_tp_rules_shard_weights_two_ways():
    d = ParamDef((1024, 2816), ("embed", "mlp"))
    assert base.spec_for(d, MESH) == P("data", "model")


def test_divisibility_fallback_replicates():
    # hubert's 504-way classifier: 504 % 16 != 0 -> replicate that dim
    d = ParamDef((1280, 504), ("embed", "vocab"))
    assert base.spec_for(d, MESH) == P("data", None)


def test_axis_used_once():
    # two logical dims both wanting "model": first wins, second replicates
    d = ParamDef((64, 128, 256), ("experts", "mlp", "heads"))
    spec = base.spec_for(d, MESH)
    assert list(spec).count("model") == 1


def test_fsdp_rules_shard_one_dim_over_both_axes():
    rules, _, batch_axes = base.rules_for_profile("fsdp")
    d = ParamDef((1024, 2816), ("embed", "mlp"))
    spec = base.spec_for(d, MESH, rules)
    assert spec == P(("data", "model"), None)
    assert batch_axes == ("pod", "data", "model")


def test_fsdp_vocab_dim_falls_back_to_embed():
    # qwen vocab 151936 is NOT divisible by 256; the embed dim (1024) is —
    # the fallback shards the divisible dim instead of replicating the leaf.
    rules, _, _ = base.rules_for_profile("fsdp")
    d = ParamDef((151936, 1024), ("vocab", "embed"))
    spec = base.spec_for(d, MESH, rules)
    assert spec == P(None, ("data", "model"))


def test_fsdp_sp_profile_act_rules():
    _, act, batch_axes = base.rules_for_profile("fsdp_sp")
    assert act["act_seq"] == "model"
    assert batch_axes == ("pod", "data")


def test_layers_dim_never_sharded():
    d = ParamDef((88, 6144, 24576), ("layers", "embed", "mlp"))
    spec = base.spec_for(d, MESH)
    assert spec[0] is None


def test_elastic_mesh_shapes():
    from repro.launch import mesh as mesh_lib
    # shape arithmetic only (construction uses jax.make_mesh — needs devices;
    # verify the factorization logic instead)
    for hosts, chips in [(64, 4), (63, 4), (100, 8)]:
        total = hosts * chips
        for cand in (16, 8, 4, 2, 1):
            if total % cand == 0:
                model = cand
                break
        assert total % model == 0


def test_batch_spec_divisibility():
    from repro.launch import shardings as sh
    assert sh.batch_spec(MESH_MP, (256,), ("pod", "data")) == P(("pod", "data"))
    assert sh.batch_spec(MESH_MP, (1,), ("pod", "data")) == P()  # long_500k b=1
    assert sh.batch_spec(MESH_MP, (256,), ("pod", "data", "model")) == P()  # 256 < 512
