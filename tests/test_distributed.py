"""Multi-device tests: the shard_map distributed join on 8 simulated CPU
devices. Each test runs in a subprocess so the device-count flag never
leaks into the rest of the suite (smoke tests must see 1 device)."""
import json
import subprocess
import sys
import textwrap

import pytest

def _run(code: str) -> dict:
    prog = "import os\nos.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'\n" + textwrap.dedent(code)
    out = subprocess.run(
        [sys.executable, "-c", prog],
        capture_output=True, text=True, timeout=900,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
             "HOME": "/root", "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo",
    )
    assert out.returncode == 0, out.stderr[-4000:]
    return json.loads(out.stdout.splitlines()[-1])


@pytest.mark.slow
def test_distributed_join_exact_both_samplers():
    res = _run("""
    import json, numpy as np, jax, jax.numpy as jnp
    mesh = jax.make_mesh((8,), ("data",))
    from repro.core import distributed, spjoin
    rng = np.random.default_rng(0)
    data = np.concatenate([
        rng.normal(loc=c, scale=1.0, size=(250, 8)) for c in (0., 5., 10., 15.)
    ]).astype(np.float32)
    truth = spjoin.brute_force_pairs(data, 3.0, "l1")
    out = {}
    for sampler in ("generative", "random"):
        r = distributed.distributed_join(
            jnp.asarray(data), mesh=mesh, delta=3.0, metric="l1", k=256, p=16,
            n_dims=4, emit_pairs=True, sampler=sampler, seed=0)
        out[sampler] = dict(
            exact=bool(np.array_equal(r.pairs, truth)),
            hits=int(r.n_hits), overflow=int(r.overflow),
            padding=float(r.capacity_padding), verif=int(r.n_verifications))
    print(json.dumps(out))
    """)
    for sampler, r in res.items():
        assert r["exact"], (sampler, r)
        assert r["overflow"] == 0


@pytest.mark.slow
def test_distributed_stats_match_host_fits():
    res = _run("""
    import json, numpy as np, jax, jax.numpy as jnp
    mesh = jax.make_mesh((8,), ("data",))
    from repro.core import distributed, gof
    from jax.sharding import NamedSharding, PartitionSpec as P
    rng = np.random.default_rng(1)
    data = jnp.asarray(rng.normal(3.0, 2.0, size=(800, 4)), jnp.float32)
    valid = jnp.ones((800,), jnp.float32)
    sh = NamedSharding(mesh, P("data"))
    fn = distributed.make_stage_stats(mesh, "data")
    packets, confs, counts = jax.tree.map(
        np.asarray, fn(jax.device_put(data, sh), jax.device_put(valid, sh)))
    # host-side fit of shard 0 must match packet 0
    shard0 = np.asarray(data[:100])
    params, res0 = gof.fit_best_family(jnp.asarray(shard0))
    from repro.core import expfam
    want = np.asarray(expfam.pack(params))
    print(json.dumps(dict(
        packet_close=bool(np.allclose(packets[0], want, rtol=1e-3, atol=1e-3)),
        conf_close=bool(abs(confs[0] - float(res0.confidence)) < 1e-3),
        counts_ok=bool((counts == 100).all()))))
    """)
    assert res["packet_close"] and res["conf_close"] and res["counts_ok"], res


@pytest.mark.slow
def test_distributed_join_skewed_data_padding_story():
    """Better sampling -> lower capacity padding (TPU skew metric)."""
    res = _run("""
    import json, numpy as np, jax, jax.numpy as jnp
    mesh = jax.make_mesh((8,), ("data",))
    from repro.core import distributed
    rng = np.random.default_rng(2)
    from repro.data import synthetic
    data = synthetic.mixture(1600, 6, n_clusters=5, skew=0.6, seed=3)
    out = {}
    for sampler in ("generative", "random"):
        r = distributed.distributed_join(
            jnp.asarray(data), mesh=mesh, delta=2.0, metric="l1", k=192,
            p=16, n_dims=4, sampler=sampler, seed=0)
        out[sampler] = dict(hits=int(r.n_hits), verif=int(r.n_verifications),
                            cap=int(r.exact_cap_w))
    print(json.dumps(out))
    """)
    # both exact joins must agree on hit count regardless of sampler
    assert res["generative"]["hits"] == res["random"]["hits"], res


@pytest.mark.slow
def test_two_step_dp_tp_training_on_mesh():
    """2-step DP x TP train loop on a (4, 2) mesh — grads/updates flow
    through sharded params + sharded batch."""
    res = _run("""
    import json, numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    from repro import configs
    from repro.models import base, transformer
    from repro.train import optimizer as opt_lib, train_step as ts
    from repro.models.config import ShapeConfig
    cfg = configs.get_reduced("stablelm-3b")
    defs = transformer.model_defs(cfg)
    params = base.init_params(jax.random.PRNGKey(0), defs)
    shard = base.make_shardings(defs, mesh)
    params = jax.tree.map(jax.device_put, params, shard)
    ocfg = opt_lib.OptConfig(total_steps=10, warmup_steps=1)
    opt = opt_lib.init_opt_state(params, ocfg)
    step = jax.jit(ts.make_train_step(cfg, ocfg, ts.StepConfig()))
    batch = configs.input_specs(cfg, ShapeConfig("s", 64, 8, "train"), abstract=False)["batch"]
    bsh = NamedSharding(mesh, P("data"))
    batch = {k: jax.device_put(v, bsh) for k, v in batch.items()}
    with base.use_mesh(mesh):
        losses = []
        for _ in range(3):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["total"]))
    print(json.dumps(dict(losses=losses,
                          decreased=bool(losses[-1] < losses[0]),
                          finite=bool(np.isfinite(losses).all()))))
    """)
    assert res["decreased"] and res["finite"], res


@pytest.mark.slow
def test_shardmap_moe_matches_local_path():
    """H2's explicit expert-parallel shard_map must equal the local
    (single-device) MoE dispatch numerically."""
    res = _run("""
    import json, dataclasses, numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs
    from repro.models import base, moe as moe_lib
    cfg = dataclasses.replace(configs.get_reduced("deepseek-moe-16b"),
                              n_experts=8, top_k=2, n_shared_experts=2,
                              capacity_factor=8.0)
    params = base.init_params(jax.random.PRNGKey(0), moe_lib.moe_defs(cfg))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(4, 32, cfg.d_model)), jnp.float32)
    y_local, _ = moe_lib.moe_block(params, x, cfg, group_size=16)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    xs = jax.device_put(x, NamedSharding(mesh, P("data")))
    with base.use_mesh(mesh):
        y_sm, _ = jax.jit(lambda p, xx: moe_lib.moe_block(p, xx, cfg, group_size=16))(params, xs)
    close = bool(np.allclose(np.asarray(y_local), np.asarray(y_sm), rtol=2e-3, atol=2e-3))
    print(json.dumps(dict(close=close)))
    """)
    assert res["close"], res
