"""Exponential-family MLE (Lemma 1) + chi-square GoF (Lemma 2 / Thm 1/2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import expfam, gof


def test_normal_mle_recovers_params(rng):
    x = rng.normal(loc=3.0, scale=2.0, size=(20_000, 3)).astype(np.float32)
    p = expfam.fit_normal(expfam.suff_stats(jnp.asarray(x)))
    np.testing.assert_allclose(np.asarray(p.a), 3.0, atol=0.1)
    np.testing.assert_allclose(np.asarray(p.b), 4.0, rtol=0.1)


def test_exponential_mle_recovers_rate(rng):
    x = rng.exponential(1 / 1.7, size=(20_000, 2)).astype(np.float32)
    p = expfam.fit_exponential(expfam.suff_stats(jnp.asarray(x)))
    np.testing.assert_allclose(np.asarray(p.a), 1.7, rtol=0.1)


def test_gamma_mle_newton_converges(rng):
    x = rng.gamma(3.0, 1 / 2.0, size=(30_000, 2)).astype(np.float32)
    p = expfam.fit_gamma(expfam.suff_stats(jnp.asarray(x)))
    np.testing.assert_allclose(np.asarray(p.a), 3.0, rtol=0.15)
    np.testing.assert_allclose(np.asarray(p.b), 2.0, rtol=0.15)


def test_masked_stats_ignore_padding(rng):
    x = rng.normal(size=(100, 4)).astype(np.float32)
    xp = np.concatenate([x, 1e6 * np.ones((20, 4), np.float32)])
    mask = np.concatenate([np.ones(100), np.zeros(20)]).astype(np.float32)
    a = expfam.suff_stats(jnp.asarray(x))
    b = expfam.suff_stats(jnp.asarray(xp), jnp.asarray(mask))
    np.testing.assert_allclose(a.sum_x, b.sum_x, rtol=1e-5)
    np.testing.assert_allclose(a.n, b.n)


@pytest.mark.parametrize("family", expfam.FAMILIES)
def test_cdf_quantile_roundtrip(family, rng):
    if family == "normal":
        p = expfam.FamilyParams(family, jnp.asarray([1.0, -2.0]), jnp.asarray([2.0, 0.5]))
    elif family == "exponential":
        p = expfam.FamilyParams(family, jnp.asarray([0.7, 2.0]), jnp.zeros(2))
    else:
        p = expfam.FamilyParams(family, jnp.asarray([2.0, 5.0]), jnp.asarray([1.0, 3.0]))
    q = jnp.asarray(rng.uniform(0.05, 0.95, size=(50, 2)), jnp.float32)
    x = expfam.quantile(p, q)
    np.testing.assert_allclose(expfam.cdf(p, x), q, atol=2e-3)


def test_sample_matches_cdf(rng):
    p = expfam.FamilyParams("normal", jnp.asarray([0.0]), jnp.asarray([1.0]))
    s = expfam.sample(p, jax.random.PRNGKey(0), (20_000,))
    u = np.asarray(expfam.cdf(p, s)).ravel()
    # CDF-transform of correct distribution is uniform (KS check)
    ks = np.abs(np.sort(u) - np.arange(1, len(u) + 1) / len(u)).max()
    assert ks < 0.02, ks


def test_gof_confidence_high_for_true_family(rng):
    x = jnp.asarray(rng.normal(2.0, 1.5, size=(5_000, 2)), jnp.float32)
    params = expfam.fit_normal(expfam.suff_stats(x))
    res = gof.pearson_statistic(x, params, t=8)
    assert float(res.confidence) > 0.05


def test_gof_confidence_low_for_wrong_family(rng):
    # bimodal data fits a single normal badly
    x = np.concatenate([
        rng.normal(-6, 0.3, size=(2_500, 2)), rng.normal(6, 0.3, size=(2_500, 2))
    ]).astype(np.float32)
    params = expfam.fit_normal(expfam.suff_stats(jnp.asarray(x)))
    res = gof.pearson_statistic(jnp.asarray(x), params, t=8)
    assert float(res.confidence) < 1e-4


def test_fit_best_family_selects_right_one(rng):
    xe = jnp.asarray(rng.exponential(1.0, size=(5_000, 2)), jnp.float32)
    p, _ = gof.fit_best_family(xe)
    assert p.family in ("exponential", "gamma")  # gamma nests exponential
    xn = jnp.asarray(rng.normal(5.0, 1.0, size=(5_000, 2)), jnp.float32)
    p, _ = gof.fit_best_family(xn)
    assert p.family == "normal"


def test_negative_data_eliminates_positive_support_families(rng):
    x = jnp.asarray(rng.normal(-5.0, 1.0, size=(2_000, 2)), jnp.float32)
    p, _ = gof.fit_best_family(x)
    assert p.family == "normal"


def test_theorem2_global_confidence_lower_bound(rng):
    """Thm 2: global confidence >= min_i c_i (statement direction)."""
    ks, dofs, confs = [], [], []
    for i in range(6):
        x = jnp.asarray(rng.normal(i, 1.0 + 0.1 * i, size=(2_000, 2)), jnp.float32)
        params = expfam.fit_normal(expfam.suff_stats(x))
        r = gof.pearson_statistic(x, params, t=8)
        ks.append(float(r.statistic))
        dofs.append(float(r.dof))
        confs.append(float(r.confidence))
    c_bar = float(gof.global_confidence(jnp.asarray(ks), jnp.asarray(dofs)))
    assert c_bar >= min(confs) - 1e-6, (c_bar, min(confs))


def test_chi2_sf_matches_known_values():
    # chi2 with df=1: P(X >= 3.841) ~ 0.05; df=10: P(X >= 18.31) ~ 0.05
    np.testing.assert_allclose(float(gof.chi2_sf(3.841, 1.0)), 0.05, atol=2e-3)
    np.testing.assert_allclose(float(gof.chi2_sf(18.307, 10.0)), 0.05, atol=2e-3)
