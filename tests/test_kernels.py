"""Pallas kernel sweeps: every (shape, dtype, metric) cell vs the jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(5, 7, 3), (128, 128, 128), (130, 70, 33), (1, 1, 1), (257, 63, 130)]


@pytest.mark.parametrize("metric", ops.METRICS)
@pytest.mark.parametrize("a,b,m", SHAPES)
def test_pairdist_matches_ref(metric, a, b, m, rng):
    x = jnp.asarray(rng.normal(size=(a, m)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(b, m)), jnp.float32)
    np.testing.assert_allclose(
        ops.pairdist(x, y, metric), ref.pairdist(x, y, metric), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("metric", ["l1", "l2", "cosine"])
@pytest.mark.parametrize("a,b,m", [(64, 96, 16), (130, 70, 33)])
def test_pairdist_mask_matches_ref(metric, a, b, m, rng):
    x = jnp.asarray(rng.normal(size=(a, m)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(b, m)), jnp.float32)
    d = np.asarray(ref.pairdist(x, y, metric))
    for q in (0.1, 0.5, 0.9):
        delta = float(np.quantile(d, q))
        got = np.asarray(ops.pairdist_mask(x, y, delta, metric))
        want = np.asarray(ref.pairdist_mask(x, y, delta, metric))
        # threshold-boundary ties can flip with fp reassociation; tolerate
        # only exact-boundary disagreements
        diff = got != want
        if diff.any():
            assert np.allclose(d[diff], delta, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairdist_dtypes(dtype, rng):
    x = jnp.asarray(rng.normal(size=(64, 32)), dtype)
    y = jnp.asarray(rng.normal(size=(48, 32)), dtype)
    got = ops.pairdist(x, y, "l2")
    want = ref.pairdist(x.astype(jnp.float32), y.astype(jnp.float32), "l2")
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_pairdist_count(rng):
    x = jnp.asarray(rng.normal(size=(40, 8)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(30, 8)), jnp.float32)
    np.testing.assert_array_equal(
        ops.pairdist_count(x, y, 2.5, "l1"), ref.pairdist_count(x, y, 2.5, "l1")
    )


@pytest.mark.parametrize("n,m,t", [(10, 3, 8), (256, 8, 8), (300, 17, 5), (1000, 2, 16)])
def test_histogram_matches_ref(n, m, t, rng):
    u = jnp.asarray(rng.uniform(size=(n, m)), jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, size=(n,)), jnp.float32)
    np.testing.assert_allclose(ops.histogram(u, t), ref.histogram(u, t), atol=1e-6)
    np.testing.assert_allclose(
        ops.histogram(u, t, w), ref.histogram(u, t, w), atol=1e-6
    )


def test_histogram_counts_sum_to_n(rng):
    u = jnp.asarray(rng.uniform(size=(500, 4)), jnp.float32)
    h = np.asarray(ops.histogram(u, 8))
    np.testing.assert_allclose(h.sum(-1), 500.0)


def test_kernel_vs_oracle_consistency_in_join_path(rng):
    """The use_kernel flag must not change join semantics."""
    x = jnp.asarray(rng.normal(size=(100, 6)), jnp.float32)
    a = np.asarray(ops.pairdist(x, x[:10], "l1", use_kernel=True))
    b = np.asarray(ops.pairdist(x, x[:10], "l1", use_kernel=False))
    np.testing.assert_allclose(a, b, rtol=1e-6)
