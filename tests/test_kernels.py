"""Pallas kernel sweeps: every (shape, dtype, metric) cell vs the jnp oracle.

backend="pallas" is pinned everywhere: the "auto" default resolves to the
jnp oracle off-TPU, which would compare the oracle against itself."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

SHAPES = [(5, 7, 3), (128, 128, 128), (130, 70, 33), (1, 1, 1), (257, 63, 130)]


@pytest.mark.parametrize("metric", ops.METRICS)
@pytest.mark.parametrize("a,b,m", SHAPES)
def test_pairdist_matches_ref(metric, a, b, m, rng):
    x = jnp.asarray(rng.normal(size=(a, m)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(b, m)), jnp.float32)
    np.testing.assert_allclose(
        ops.pairdist(x, y, metric, backend="pallas"), ref.pairdist(x, y, metric), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("metric", ["l1", "l2", "cosine"])
@pytest.mark.parametrize("a,b,m", [(64, 96, 16), (130, 70, 33)])
def test_pairdist_mask_matches_ref(metric, a, b, m, rng):
    x = jnp.asarray(rng.normal(size=(a, m)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(b, m)), jnp.float32)
    d = np.asarray(ref.pairdist(x, y, metric))
    for q in (0.1, 0.5, 0.9):
        delta = float(np.quantile(d, q))
        got = np.asarray(ops.pairdist_mask(x, y, delta, metric, backend="pallas"))
        want = np.asarray(ref.pairdist_mask(x, y, delta, metric))
        # threshold-boundary ties can flip with fp reassociation; tolerate
        # only exact-boundary disagreements
        diff = got != want
        if diff.any():
            assert np.allclose(d[diff], delta, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_pairdist_dtypes(dtype, rng):
    x = jnp.asarray(rng.normal(size=(64, 32)), dtype)
    y = jnp.asarray(rng.normal(size=(48, 32)), dtype)
    got = ops.pairdist(x, y, "l2", backend="pallas")
    want = ref.pairdist(x.astype(jnp.float32), y.astype(jnp.float32), "l2")
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(got, want, rtol=tol, atol=tol)


def test_pairdist_count(rng):
    x = jnp.asarray(rng.normal(size=(40, 8)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(30, 8)), jnp.float32)
    np.testing.assert_array_equal(
        ops.pairdist_count(x, y, 2.5, "l1", backend="pallas"),
        ref.pairdist_count(x, y, 2.5, "l1"),
    )


@pytest.mark.parametrize("n,m,t", [(10, 3, 8), (256, 8, 8), (300, 17, 5), (1000, 2, 16)])
def test_histogram_matches_ref(n, m, t, rng):
    u = jnp.asarray(rng.uniform(size=(n, m)), jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, size=(n,)), jnp.float32)
    np.testing.assert_allclose(
        ops.histogram(u, t, backend="pallas"), ref.histogram(u, t), atol=1e-6
    )
    np.testing.assert_allclose(
        ops.histogram(u, t, w, backend="pallas"), ref.histogram(u, t, w), atol=1e-6
    )


@pytest.mark.parametrize("n,m", [(0, 3), (1, 1), (37, 5), (130, 3), (300, 17)])
def test_histogram_blocked_ragged_shapes(n, m, rng):
    """Regression: the raw blocked kernel used to hard-assert block-multiple
    shapes; it now pads internally (rows masked via the weights column,
    padded dimensions sliced off), so callers never pre-pad."""
    from repro.kernels.histogram import histogram_blocked

    u = jnp.asarray(rng.uniform(size=(n, m)), jnp.float32)
    w = jnp.asarray(rng.integers(0, 2, size=(n, 1)), jnp.float32)
    got = histogram_blocked(u, w, t=8, interpret=True)
    want = ref.histogram(u, 8, w[:, 0])
    assert got.shape == (m, 8)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_histogram_counts_sum_to_n(rng):
    u = jnp.asarray(rng.uniform(size=(500, 4)), jnp.float32)
    h = np.asarray(ops.histogram(u, 8))
    np.testing.assert_allclose(h.sum(-1), 500.0)


def test_kernel_vs_oracle_consistency_in_join_path(rng):
    """The use_kernel flag must not change join semantics."""
    x = jnp.asarray(rng.normal(size=(100, 6)), jnp.float32)
    a = np.asarray(ops.pairdist(x, x[:10], "l1", use_kernel=True))
    b = np.asarray(ops.pairdist(x, x[:10], "l1", use_kernel=False))
    np.testing.assert_allclose(a, b, rtol=1e-6)


def test_backend_dispatch_resolution():
    """The backend="numpy"|"pallas"|"auto" contract (off-TPU container)."""
    import jax

    on_tpu = jax.default_backend() == "tpu"
    assert ops.resolve_backend("numpy") == "numpy"
    assert ops.resolve_backend("pallas", "l1") == "pallas"
    assert ops.resolve_backend("auto", "l2") == ("pallas" if on_tpu else "numpy")
    # metrics without a kernel always fall back under "auto"
    assert ops.resolve_backend("auto", "jaccard_minhash") == "numpy"
    # legacy use_kernel overrides backend
    assert ops.resolve_backend("numpy", "l1", use_kernel=True) == "pallas"
    assert ops.resolve_backend("pallas", "l1", use_kernel=False) == "numpy"
    with pytest.raises(ValueError):
        ops.resolve_backend("pallas", "jaccard_minhash")
    with pytest.raises(ValueError):
        ops.resolve_backend("mlx")


def test_backend_paths_agree(rng):
    x = jnp.asarray(rng.normal(size=(70, 9)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(40, 9)), jnp.float32)
    for metric in ("l1", "l2"):
        a = np.asarray(ops.pairdist(x, y, metric, backend="pallas"))
        b = np.asarray(ops.pairdist(x, y, metric, backend="numpy"))
        c = np.asarray(ops.pairdist(x, y, metric, backend="auto"))
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(b, c, rtol=1e-5, atol=1e-5)
