"""HLO structural parser: trip-count-weighted FLOPs on known graphs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hloparse


def _parse(fn, *args):
    compiled = jax.jit(fn).lower(*args).compile()
    return hloparse.analyze(compiled.as_text())


def test_single_matmul_flops():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    res = _parse(lambda x, y: x @ y, a, b)
    assert res["flops_per_device"] == pytest.approx(2 * 128 * 256 * 64, rel=1e-6)


def test_scan_multiplies_by_trip_count():
    w = jnp.zeros((16, 64, 64), jnp.float32)  # 16 scanned layers
    x = jnp.zeros((8, 64), jnp.float32)

    def fn(w, x):
        def body(h, wi):
            return jnp.tanh(h @ wi), None
        h, _ = jax.lax.scan(body, x, w)
        return h

    res = _parse(fn, w, x)
    want = 16 * 2 * 8 * 64 * 64
    assert res["flops_per_device"] == pytest.approx(want, rel=0.01), (
        res["flops_per_device"], want)


def test_nested_scan_trip_counts():
    w = jnp.zeros((4, 3, 32, 32), jnp.float32)
    x = jnp.zeros((8, 32), jnp.float32)

    def fn(w, x):
        def outer(h, wg):
            def inner(h2, wi):
                return h2 @ wi, None
            h, _ = jax.lax.scan(inner, h, wg)
            return h, None
        h, _ = jax.lax.scan(outer, x, w)
        return h

    res = _parse(fn, w, x)
    want = 12 * 2 * 8 * 32 * 32
    assert res["flops_per_device"] == pytest.approx(want, rel=0.01)


def test_shape_bytes():
    assert hloparse.shape_bytes("f32[4,8]{1,0}") == 128
    assert hloparse.shape_bytes("bf16[10]") == 20
    assert hloparse.shape_bytes("(f32[2], s32[3])") == 20
    assert hloparse.shape_bytes("pred[]") == 1


def test_dot_traffic_counts_operands():
    a = jnp.zeros((128, 256), jnp.float32)
    b = jnp.zeros((256, 64), jnp.float32)
    res = _parse(lambda x, y: x @ y, a, b)
    want = (128 * 256 + 256 * 64 + 128 * 64) * 4
    assert res["dot_traffic_bytes_per_device"] == pytest.approx(want, rel=1e-6)
