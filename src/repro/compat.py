"""JAX version compatibility shims.

The codebase targets current JAX (``jax.shard_map`` with ``check_vma``);
older releases only ship ``jax.experimental.shard_map`` whose equivalent
flag is ``check_rep``. Everything that builds a shard_map goes through
:func:`shard_map` so the version probe lives in exactly one place.
"""
from __future__ import annotations

import inspect

import jax


def _resolve_shard_map():
    """Pick (shard_map fn, replication-check kwarg name) for this jax.

    The discriminant is the parameter name, not where the function lives:
    some releases export top-level ``jax.shard_map`` while still spelling
    the flag ``check_rep``.
    """
    if hasattr(jax, "shard_map"):
        fn = jax.shard_map
    else:
        from jax.experimental.shard_map import shard_map as fn
    try:
        params = inspect.signature(fn).parameters
    except (TypeError, ValueError):  # builtins without introspectable sigs
        params = {}
    flag = "check_vma" if "check_vma" in params else "check_rep"
    return fn, flag


_SHARD_MAP, _CHECK_FLAG = _resolve_shard_map()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True):
    """``jax.shard_map`` with the current flag spelling (``check_vma``),
    mapped onto ``check_rep`` for older releases."""
    kwargs = {_CHECK_FLAG: check_vma}
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kwargs)
