"""Pallas TPU kernels for the SP-Join hot spots.

  pairdist   — blocked all-pairs distance + fused threshold (verify phase,
               space mapping). MXU path for l2/cosine/dot, VPU for l1/linf.
  histogram  — fused per-dimension GoF cell counts (sampling stats phase).
  mapassign  — fused map phase: space map + kernel-cell assign + packed
               whole membership in one streamed pass (no (N, p, n) in HBM).

``ops`` holds the public jit'd wrappers (padding, dispatch, interpret mode on
non-TPU backends); ``ref`` the pure-jnp oracles the tests sweep against.
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.ops import (  # noqa: F401
    histogram,
    map_assign,
    pairdist,
    pairdist_count,
    pairdist_mask,
)
