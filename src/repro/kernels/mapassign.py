"""Pallas TPU kernel: fused map phase (space map + kernel assign + membership).

The map phase of SP-Join (paper §5.2, Lemma 4) takes every object o to its
pivot-space coordinates oⁿ = (D(a_1,o) … D(a_n,o)), finds the unique KERNEL
cell whose half-open box contains oⁿ, and computes the WHOLE-partition
membership mask over the δ-expanded (closed) boxes. Done naively that is a
pairdist pass plus TWO (N, p, n) containment broadcasts and an (N, p) bool
mask — all round-tripping HBM, per shard, twice per join (counting pass +
verify pass).

This kernel fuses all three into one streamed pass:

  * Grid (n_tiles, p_tiles), p innermost: at the first p-block the (bn, n)
    coordinate tile is computed in VMEM from the row block and the (small,
    fully resident) anchor set — the same feature-chunked MXU/VPU
    accumulation as ``pairdist.py`` (``_accumulate``/``_finalize`` are shared
    verbatim) — and written out once. Every p-block then reads that VMEM
    tile; the (bn, bp, n) containment broadcasts live and die in VMEM.
  * KERNEL cell id: boxes are half-open [lo, hi) and tile ℝⁿ, so at most one
    matches; a running "first containing box" scratch reproduces the jnp
    path's argmax-of-bool semantics exactly (first match wins, no match → 0).
  * WHOLE membership is packed 32 partitions per uint32 word in-register, so
    the per-shard mask costs N·⌈p/32⌉ words of HBM instead of N·p bools.

HBM traffic: N·(n + 1 + ⌈p/32⌉) words written, zero (N, p, n) or (N, p)
intermediates — vs 2·N·p·n + N·p bool bytes for the two-pass jnp path.

Correctness contract (validated in tests/test_map_phase.py against
``ref.map_assign``): callers (``ops.py``) pre-pad rows/features/partitions;
padded feature columns are zero (exact for every metric after cosine
pre-normalization), padded anchor DIMENSIONS carry (-BIG, BIG) box edges so
they never veto containment, and padded PARTITIONS carry lo = +BIG so they
never match. Half-open vs closed edges (kernel: ``< hi``; whole: ``<= hi``)
are the correctness hazard and are kept bit-exact with the reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pairdist import MXU_METRICS, _accumulate, _finalize
from repro.kernels.ref import BIG, MEMBER_WORD as WORD  # single-owner constants


def _kernel(
    x_ref,  # (bn, m) VMEM — payload rows (or mapped coords when metric None)
    a_ref,  # (na, m) VMEM — all anchors (tiny; fully resident)
    klo_ref,  # (bp, na) VMEM — kernel box lows for this p-block
    khi_ref,  # (bp, na)
    wlo_ref,  # (bp, na) — whole (δ-expanded) box lows
    whi_ref,  # (bp, na)
    xm_ref,  # (bn, na) f32 OUT — mapped coordinates (block revisited over j)
    cell_ref,  # (bn, 1) int32 OUT — kernel cell id
    bits_ref,  # (bn, bp // WORD) uint32 OUT — packed whole membership
    cell_s,  # (bn, 1) int32 VMEM scratch — first containing box so far (-1)
    *,
    metric: str | None,
    bm: int,
    npb: int,
    bp: int,
    want_cells: bool,
    want_member: bool,
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _space_map():
        # Fused pairdist tile: row block × ALL anchors, feature-chunked with
        # the verify kernel's accumulation (xm_ref doubles as the accumulator
        # — the block index map pins it to (i, 0), so it persists across j).
        if metric is None:
            xm_ref[...] = x_ref[...].astype(jnp.float32)
        else:
            xm_ref[...] = jnp.zeros_like(xm_ref)
            for c0 in range(0, x_ref.shape[1], bm):
                _accumulate(
                    xm_ref,
                    x_ref[:, c0 : c0 + bm].astype(jnp.float32),
                    a_ref[:, c0 : c0 + bm].astype(jnp.float32),
                    metric,
                )
            xm_ref[...] = _finalize(xm_ref[...], metric)
        if want_cells:
            cell_s[...] = jnp.full_like(cell_s, -1)
        else:
            cell_ref[...] = jnp.zeros_like(cell_ref)  # block (i, 0): write once

    xm = xm_ref[...]  # (bn, na)

    # Containment masks for this block of bp partitions — the (bn, bp, na)
    # broadcasts never leave VMEM. Kernel boxes are half-open, whole closed.
    # A skipped side (want_cells / want_member False) costs nothing and its
    # output is zero-filled.
    if want_cells:
        in_k = (
            (xm[:, None, :] >= klo_ref[...][None])
            & (xm[:, None, :] < khi_ref[...][None])
        ).all(-1)  # (bn, bp)
        # First containing box within this block; first block to match wins —
        # exactly argmax-of-bool over the full p axis (all-False rows → 0).
        col = jax.lax.broadcasted_iota(jnp.int32, in_k.shape, 1)
        local = jnp.min(jnp.where(in_k, col, bp), axis=1, keepdims=True)  # (bn, 1)
        cell_s[...] = jnp.where(
            (cell_s[...] < 0) & (local < bp), j * bp + local, cell_s[...]
        )

        @pl.when(j == npb - 1)
        def _emit_cells():
            cell_ref[...] = jnp.maximum(cell_s[...], 0)

    if want_member:
        in_w = (
            (xm[:, None, :] >= wlo_ref[...][None])
            & (xm[:, None, :] <= whi_ref[...][None])
        ).all(-1)
        # Pack membership, WORD partitions/uint32 (disjoint bits: sum == or).
        shift = jax.lax.broadcasted_iota(jnp.uint32, (1, WORD), 1)
        for w in range(bp // WORD):
            sel = in_w[:, w * WORD : (w + 1) * WORD].astype(jnp.uint32)
            bits_ref[:, w : w + 1] = (sel << shift).sum(-1, keepdims=True)
    else:
        bits_ref[...] = jnp.zeros_like(bits_ref)


@functools.partial(
    jax.jit,
    static_argnames=("metric", "bn", "bp", "bm", "interpret", "want_cells", "want_member"),
)
def map_assign_blocked(
    x: jnp.ndarray,  # (n, m) — n, m pre-padded to block multiples
    anchors: jnp.ndarray,  # (na, m) — na pre-padded; ignored when metric None
    kernel_lo: jnp.ndarray,  # (pp, na) — pp pre-padded to a bp multiple
    kernel_hi: jnp.ndarray,
    whole_lo: jnp.ndarray,
    whole_hi: jnp.ndarray,
    *,
    metric: str | None,
    bn: int = 128,
    bp: int = 128,
    bm: int | None = None,
    interpret: bool = False,
    want_cells: bool = True,
    want_member: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Raw blocked call — use ``ops.map_assign`` / ``ops.assign_membership``,
    which handle padding, normalization and backend dispatch.

    ``metric=None`` skips the space map: ``x`` then IS the (n, na) mapped
    coordinate matrix (assign-only mode). ``want_cells`` / ``want_member``
    skip the respective containment sweep (the skipped output is
    zero-filled) — what ``tighten``-style callers use to avoid paying for a
    side they recompute anyway. Returns (xm, cells, bits) with xm (n, na)
    f32, cells (n, 1) int32, bits (n, pp // WORD) uint32.
    """
    n, m = x.shape
    na = kernel_lo.shape[1]
    pp = kernel_lo.shape[0]
    if bm is None:
        bm = 128 if metric in MXU_METRICS else 16
    bm = min(bm, m)
    assert n % bn == 0 and m % bm == 0 and pp % bp == 0 and bp % WORD == 0, (
        x.shape, kernel_lo.shape, bn, bp, bm,
    )
    assert anchors.shape == (na, m) or metric is None, (anchors.shape, na, m)
    npb = pp // bp

    grid = (n // bn, npb)
    return pl.pallas_call(
        functools.partial(
            _kernel, metric=metric, bm=bm, npb=npb, bp=bp,
            want_cells=want_cells, want_member=want_member,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, m), lambda i, j: (i, 0)),
            pl.BlockSpec((na, m), lambda i, j: (0, 0)),
            pl.BlockSpec((bp, na), lambda i, j: (j, 0)),
            pl.BlockSpec((bp, na), lambda i, j: (j, 0)),
            pl.BlockSpec((bp, na), lambda i, j: (j, 0)),
            pl.BlockSpec((bp, na), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bn, na), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, bp // WORD), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, na), jnp.float32),
            jax.ShapeDtypeStruct((n, 1), jnp.int32),
            jax.ShapeDtypeStruct((n, pp // WORD), jnp.uint32),
        ],
        scratch_shapes=[pltpu.VMEM((bn, 1), jnp.int32)],
        interpret=interpret,
    )(x, anchors, kernel_lo, kernel_hi, whole_lo, whole_hi)
