"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function of the same name here. They are
deliberately written in the most obvious form (no tiling, no fusion).
"""
from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray

METRICS = ("l1", "l2", "linf", "cosine", "dot")


def _normalize(x: Array) -> Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def pairdist(x: Array, y: Array, metric: str = "l2") -> Array:
    """All-pairs distances, x: (a, m), y: (b, m) -> (a, b) float32."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if metric == "l1":
        return jnp.abs(x[:, None, :] - y[None, :, :]).sum(-1)
    if metric == "linf":
        return jnp.abs(x[:, None, :] - y[None, :, :]).max(-1)
    if metric == "l2":
        sq = (x * x).sum(-1)[:, None] + (y * y).sum(-1)[None, :] - 2.0 * x @ y.T
        return jnp.sqrt(jnp.maximum(sq, 0.0))
    if metric == "cosine":
        return 1.0 - _normalize(x) @ _normalize(y).T
    if metric == "dot":
        return x @ y.T
    raise ValueError(f"unknown metric {metric!r}")


def pairdist_mask(x: Array, y: Array, delta: float, metric: str = "l2") -> Array:
    """Thresholded join mask: (a, b) bool, True where D(x_i, y_j) <= delta."""
    return pairdist(x, y, metric) <= delta


_EPS32 = float(jnp.finfo(jnp.float32).eps)


def prune_delta(
    delta: float, metric: str = "l1", x_abs: float = 0.0, n_feat: int = 0
) -> float:
    """The pivot filter's fp guard band — the threshold the L-inf lower
    bound is pruned against.

    Mathematically the bound over mapped coordinates never exceeds the true
    distance (each coordinate is 1-Lipschitz), but both sides are computed
    in fp32, and the DISTANCE side is the worse-conditioned one: l2's
    MXU-friendly dot-expansion ``sqrt(|x|^2 + |y|^2 - 2xy)`` carries an
    absolute error ~ X^2·eps/delta near the threshold (X = coordinate
    magnitude), and l1/linf accumulate ~ m·X·eps — so a pair whose computed
    distance is <= delta can see a (well-conditioned) computed bound above
    delta when the data sits far from the origin. Pruning against a
    SCALE-AWARE slackened threshold restores fp soundness: callers pass
    ``x_abs`` (max |payload coordinate|) and ``n_feat`` (payload dims), and
    the slack covers the worst-case rounding of the distance path, the
    bound path (coordinates are distances, <= the m·X-ish diameter), and
    the threshold compare. This is what the byte-identity invariant
    (prune="pivot" == prune="none") relies on; the slack only admits extra
    candidates for exact evaluation, it never changes emitted pairs.

    With the scale left at 0 (unknown), only the fixed band remains —
    sound for data of modest magnitude (|x| up to ~1e2 at delta ~1e-2+),
    which is why every internal caller threads the real scale through.
    """
    d = float(delta)
    x = float(x_abs)
    m = float(max(n_feat, 1))
    if metric == "l2":
        # dot-expansion: |d̂² − d²| ≲ c·m·eps·X² (each of the ~2m+4 terms
        # rounds at ulp(X²)). Through the sqrt the worst DISTANCE violation
        # is sqrt of that (when d̂² collapses toward 0) plus the first-order
        # term near the threshold; the coordinates are l2 distances with the
        # same error profile, hence the 3x on the sqrt term (x-side, y-side,
        # bound-side). Empirically ~2x above the measured worst case.
        e2 = 8.0 * m * _EPS32
        slack = 3.0 * (e2 ** 0.5) * x + e2 * x * x / (2.0 * max(d, _EPS32))
    elif metric in ("l1", "linf"):
        # Same-sign close subtractions are exact (Sterbenz); what is left is
        # accumulation rounding of the coordinate distances themselves,
        # whose magnitudes reach the ~m·X diameter — hence m²·X·eps.
        slack = 4.0 * m * (m + 1.0) * _EPS32 * x
    else:
        # Bounded-output metrics (angular, jaccard_minhash, cosine): the
        # distance and the coordinates live in [0, 1]-ish ranges.
        slack = 16.0 * _EPS32
    return d * (1.0 + 1e-4) + 1e-6 + slack


def bound_mask(
    px: Array, py: Array, delta: float, delta_bound: float | None = None
) -> Array:
    """Pivot-filter survivor mask: (a, b) bool over mapped coordinates.

    ``px``/``py`` are per-object distances to the shared anchors (the space
    mapping's output). True where the L-inf lower bound
    max_p |px_i[p] - py_j[p]| is within the slackened threshold — i.e. the
    pair CANNOT be pruned and must be exactly evaluated. ``delta_bound``
    overrides the (scale-free) default band; every engine/executor path
    threads a single scale-aware value through all of its sub-masks so the
    pre-pass, the fused kernel and the telemetry always agree.
    """
    if delta_bound is None:
        delta_bound = prune_delta(delta)
    return pairdist(px, py, "linf") <= delta_bound


def pairdist_mask_filtered(
    x: Array,
    y: Array,
    px: Array,
    py: Array,
    delta: float,
    metric: str = "l2",
    delta_bound: float | None = None,
) -> Array:
    """Fused pivot-filter + thresholded join mask (a, b) bool.

    Semantically ``pairdist_mask(x, y, delta, metric) & bound_mask(px, py,
    delta, delta_bound)``; because the bound is a true lower bound (triangle
    inequality over the anchors, plus the fp guard band of
    :func:`prune_delta`), the result is IDENTICAL to the unfiltered mask —
    the filter only removes pairs whose distance already exceeds delta.
    Oracle for the fused Pallas kernel, which additionally skips the
    exact-distance work for fully pruned tiles.
    """
    return pairdist_mask(x, y, delta, metric) & bound_mask(px, py, delta, delta_bound)


def pairdist_count(x: Array, y: Array, delta: float, metric: str = "l2") -> Array:
    """Per-row join fan-out: (a,) int32 — |{j : D(x_i, y_j) <= delta}|."""
    return pairdist_mask(x, y, delta, metric).sum(-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Fused reduce phase: emission semantics + on-device pair compaction
# ---------------------------------------------------------------------------


def emit_mask(
    vids: Array, wids: Array, wcells: Array, cell_id, cross: bool = False
) -> Array:
    """(a, b) bool — pairs this cell is allowed to emit (pre-distance).

    Padding validity (id = -1 rows are never emitted) plus the min-cell
    de-dup rule of the reduce phase: a hit (v, w) with kernel cells
    (g = ``cell_id``, h = ``wcells[j]``) is emitted by cell min(g, h) only;
    within one cell both orders are present, so keep id_v < id_w. R×S mode
    (``cross=True``): the sets are disjoint and each R row lives in exactly
    one kernel cell, so validity alone suffices. Single owner of the rule —
    ``core.verify.apply_dedup`` and the fused compaction kernel both
    delegate here.
    """
    valid = (vids[:, None] >= 0) & (wids[None, :] >= 0)
    if cross:
        return valid
    return valid & (
        (wcells[None, :] > cell_id)
        | ((wcells[None, :] == cell_id) & (vids[:, None] < wids[None, :]))
    )


def compact_mask(
    mask: Array, vids: Array, wids: Array, capacity: int
) -> tuple[Array, Array]:
    """Prefix-sum compaction of a hit mask into a fixed-capacity pair buffer.

    Returns ``(pairs, count)``: ``pairs`` is (capacity, 2) int32 holding
    ``(vids[i], wids[j])`` for the True cells of ``mask`` in row-major
    (``np.nonzero``) order, padded with -1; ``count`` is int32 and equals the
    TRUE total number of hits — ``count > capacity`` signals overflow, in
    which case the retained prefix is the first ``capacity`` hits but callers
    must treat the buffer as unspecified and retry at a larger capacity (the
    Pallas kernel fills it in block-major, not row-major, order).

    Scatter-free formulation (the jnp/XLA fast path): the k-th hit's flat
    position is the first index where the inclusive prefix sum of the
    flattened mask reaches k — a ``searchsorted`` over ``capacity`` query
    points inverts the cumsum without a 1-element-scatter per hit.
    """
    a, b = mask.shape
    if a == 0 or b == 0:
        return (
            jnp.full((capacity, 2), -1, jnp.int32),
            jnp.zeros((), jnp.int32),
        )
    incl = jnp.cumsum(mask.astype(jnp.int32).reshape(-1))
    count = incl[-1].astype(jnp.int32)
    q = jnp.arange(1, capacity + 1, dtype=incl.dtype)
    pos = jnp.minimum(jnp.searchsorted(incl, q, side="left"), a * b - 1)
    ok = q <= count
    pv = jnp.where(ok, vids[pos // b].astype(jnp.int32), -1)
    pw = jnp.where(ok, wids[pos % b].astype(jnp.int32), -1)
    return jnp.stack([pv, pw], axis=1), count


def verify_compact(
    x: Array,
    y: Array,
    vids: Array,
    wids: Array,
    wcells: Array,
    cell_id,
    *,
    delta: float,
    metric: str,
    capacity: int,
    cross: bool = False,
    px: Array | None = None,
    py: Array | None = None,
    delta_bound: float | None = None,
) -> tuple[Array, Array, Array]:
    """Fused verify + on-device pair compaction, the obvious-form oracle.

    One tile's whole reduce step: (optional) pivot-filter bound, exact
    pairwise distance, ``<= delta`` threshold, validity + min-cell de-dup,
    then prefix-sum compaction of the surviving hits into a (capacity, 2)
    int32 id-pair buffer. Returns ``(pairs, count, n_cand)``:

      * ``pairs`` / ``count`` as :func:`compact_mask` (count is the TRUE hit
        total — ``count > capacity`` means overflow, retry bigger);
      * ``n_cand`` int32: valid pairs surviving the pivot filter (== the
        valid pair count when ``px`` is None) — same quantity the streaming
        engine's candidate pre-pass reports, so prune telemetry is identical
        across emission modes.
    """
    valid = (vids[:, None] >= 0) & (wids[None, :] >= 0)
    hits = pairdist_mask(x, y, delta, metric)
    if px is not None:
        assert py is not None
        bound = bound_mask(px, py, delta, delta_bound)
        n_cand = (bound & valid).sum().astype(jnp.int32)
        hits = hits & bound
    else:
        n_cand = valid.sum().astype(jnp.int32)
    hits = hits & emit_mask(vids, wids, wcells, cell_id, cross)
    pairs, count = compact_mask(hits, vids, wids, capacity)
    return pairs, count, n_cand


MEMBER_WORD = 32  # whole-membership bits per packed uint32 word
BIG = 3.0e38  # finite ±inf stand-in for box edges (fp32-representable);
#   core.partition aliases this — one owner for the sentinel


def pack_membership(member: Array) -> Array:
    """Pack an (N, p) bool membership mask 32 partitions per uint32 word:
    (N, ⌈p/32⌉), bit ``j % 32`` of word ``j // 32`` set iff ``member[:, j]``.
    Trailing pad bits of the last word are 0 (padded partitions are never
    members). Disjoint-bit sum == bitwise or, so the pack is exact."""
    n, p = member.shape
    pad = (-p) % MEMBER_WORD
    words = (p + pad) // MEMBER_WORD
    m = jnp.pad(member.astype(jnp.uint32), ((0, 0), (0, pad)))
    m = m.reshape(n, words, MEMBER_WORD)
    shift = jnp.arange(MEMBER_WORD, dtype=jnp.uint32)
    return (m << shift[None, None, :]).sum(-1)


def unpack_membership(bits: Array, p: int) -> Array:
    """Inverse of :func:`pack_membership`: (N, ⌈p/32⌉) uint32 → (N, p) bool."""
    shift = jnp.arange(MEMBER_WORD, dtype=jnp.uint32)
    b = (bits[:, :, None] >> shift[None, None, :]) & jnp.uint32(1)
    n, words = bits.shape
    return b.reshape(n, words * MEMBER_WORD)[:, :p].astype(bool)


def assign_kernel_cells(xm: Array, kernel_lo: Array, kernel_hi: Array) -> Array:
    """(N,) int32 kernel cell ids — the half-open [lo, hi) containment argmax
    (exactly one box contains; an all-False row degenerates to cell 0)."""
    xm = xm.astype(jnp.float32)
    inside_k = (xm[:, None, :] >= kernel_lo[None]) & (xm[:, None, :] < kernel_hi[None])
    return jnp.argmax(inside_k.all(-1), axis=1).astype(jnp.int32)


def membership_bits(xm: Array, whole_lo: Array, whole_hi: Array) -> Array:
    """(N, ⌈p/32⌉) uint32 packed whole membership — closed [lo, hi] boxes."""
    xm = xm.astype(jnp.float32)
    inside_w = (xm[:, None, :] >= whole_lo[None]) & (xm[:, None, :] <= whole_hi[None])
    return pack_membership(inside_w.all(-1))


def assign_membership(
    xm: Array,
    kernel_lo: Array,
    kernel_hi: Array,
    whole_lo: Array,
    whole_hi: Array,
) -> tuple[Array, Array]:
    """Kernel cell id + packed whole membership from mapped coordinates.

    The obvious (N, p, n) broadcast form — bit-for-bit the historical jnp
    map-phase path (``partition.assign_kernel`` / ``whole_membership``):
    kernel boxes are half-open [lo, hi), whole boxes closed [lo, hi]. Oracle
    for the fused Pallas kernel in ``mapassign.py``. Returns
    (cells (N,) int32, bits (N, ⌈p/32⌉) uint32).
    """
    return (
        assign_kernel_cells(xm, kernel_lo, kernel_hi),
        membership_bits(xm, whole_lo, whole_hi),
    )


def map_assign(
    x: Array,
    anchors: Array,
    kernel_lo: Array,
    kernel_hi: Array,
    whole_lo: Array,
    whole_hi: Array,
    metric: str = "l2",
) -> tuple[Array, Array, Array]:
    """Full map phase: space map + assign + membership, unfused.

    Semantic ground truth for the fused kernel: ``xm = pairdist(x, anchors)``
    then :func:`assign_membership`. Returns (xm, cells, bits)."""
    xm = pairdist(x, anchors, metric)
    cells, bits = assign_membership(xm, kernel_lo, kernel_hi, whole_lo, whole_hi)
    return xm, cells, bits


def histogram(u: Array, t: int, weights: Array | None = None) -> Array:
    """Per-dimension equal-width histogram of u in [0, 1): (n, m) -> (m, t).

    This is the GoF cell-count pass (paper Eq. 9): cell_j counts per marginal.
    ``weights``: optional (n,) validity/padding mask.
    """
    cell = jnp.clip((u.astype(jnp.float32) * t).astype(jnp.int32), 0, t - 1)
    onehot = (cell[:, :, None] == jnp.arange(t)[None, None, :]).astype(jnp.float32)
    if weights is not None:
        onehot = onehot * weights.astype(jnp.float32)[:, None, None]
    return onehot.sum(0)
