"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes/dtypes
and asserts allclose against the function of the same name here. They are
deliberately written in the most obvious form (no tiling, no fusion).
"""
from __future__ import annotations

import jax.numpy as jnp

Array = jnp.ndarray

METRICS = ("l1", "l2", "linf", "cosine", "dot")


def _normalize(x: Array) -> Array:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)


def pairdist(x: Array, y: Array, metric: str = "l2") -> Array:
    """All-pairs distances, x: (a, m), y: (b, m) -> (a, b) float32."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if metric == "l1":
        return jnp.abs(x[:, None, :] - y[None, :, :]).sum(-1)
    if metric == "linf":
        return jnp.abs(x[:, None, :] - y[None, :, :]).max(-1)
    if metric == "l2":
        sq = (x * x).sum(-1)[:, None] + (y * y).sum(-1)[None, :] - 2.0 * x @ y.T
        return jnp.sqrt(jnp.maximum(sq, 0.0))
    if metric == "cosine":
        return 1.0 - _normalize(x) @ _normalize(y).T
    if metric == "dot":
        return x @ y.T
    raise ValueError(f"unknown metric {metric!r}")


def pairdist_mask(x: Array, y: Array, delta: float, metric: str = "l2") -> Array:
    """Thresholded join mask: (a, b) bool, True where D(x_i, y_j) <= delta."""
    return pairdist(x, y, metric) <= delta


def pairdist_count(x: Array, y: Array, delta: float, metric: str = "l2") -> Array:
    """Per-row join fan-out: (a,) int32 — |{j : D(x_i, y_j) <= delta}|."""
    return pairdist_mask(x, y, delta, metric).sum(-1).astype(jnp.int32)


def histogram(u: Array, t: int, weights: Array | None = None) -> Array:
    """Per-dimension equal-width histogram of u in [0, 1): (n, m) -> (m, t).

    This is the GoF cell-count pass (paper Eq. 9): cell_j counts per marginal.
    ``weights``: optional (n,) validity/padding mask.
    """
    cell = jnp.clip((u.astype(jnp.float32) * t).astype(jnp.int32), 0, t - 1)
    onehot = (cell[:, :, None] == jnp.arange(t)[None, None, :]).astype(jnp.float32)
    if weights is not None:
        onehot = onehot * weights.astype(jnp.float32)[:, None, None]
    return onehot.sum(0)
