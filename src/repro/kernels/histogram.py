"""Pallas TPU kernel: fused per-dimension histogram (the GoF cell counts).

The sampling-phase stats pass needs, per shard, the observed count nu_j of
every (dimension, cell) pair under the CDF transform u = F(x) in [0,1)
(paper Eq. 9 with equal-probability cells). Done naively this is a one-hot of
shape (n, m, t) — n x t times the input size in HBM traffic. The kernel fuses
binning + accumulation so only the (m, t) count matrix is ever written.

Grid (m_tiles, n_tiles), n innermost: the output tile (bmm, t) accumulates in
place across n-chunks (sequential innermost grid on TPU). Cells are compared
against an iota instead of gathered — gather-free, VPU-only.

Weights (the padding/validity mask of static-shape distributed buffers) ride
along as a second input so masked counts need no second pass.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(u_ref, w_ref, out_ref, *, t: int, nn: int):
    i_n = pl.program_id(1)

    @pl.when(i_n == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    u = u_ref[...].astype(jnp.float32)  # (bn, bmm)
    w = w_ref[...].astype(jnp.float32)  # (bn, 1)
    cell = jnp.clip((u * t).astype(jnp.int32), 0, t - 1)  # (bn, bmm)
    hit = (cell[:, :, None] == jax.lax.broadcasted_iota(jnp.int32, (1, 1, t), 2)).astype(
        jnp.float32
    )
    out_ref[...] += (hit * w[:, :, None]).sum(0)  # (bmm, t)


@functools.partial(jax.jit, static_argnames=("t", "bn", "bmm", "interpret"))
def histogram_blocked(
    u: jnp.ndarray,  # (n, m) in [0, 1) — ragged shapes padded internally
    weights: jnp.ndarray,  # (n, 1) validity mask (0 for padding rows)
    *,
    t: int,
    bn: int = 256,
    bmm: int = 8,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns the (m, t) count matrix. Ragged n/m are handled here: padding
    rows ride the existing weights column with weight 0 (no contribution) and
    padding dimensions land in extra output rows that are sliced off — so
    callers never pre-pad."""
    n, m = u.shape
    if n == 0 or m == 0:
        return jnp.zeros((m, t), jnp.float32)
    bn = min(bn, n)
    bmm = min(bmm, m)
    pad_n = (-n) % bn
    pad_m = (-m) % bmm
    if pad_n or pad_m:
        u = jnp.pad(u, ((0, pad_n), (0, pad_m)))
        weights = jnp.pad(weights, ((0, pad_n), (0, 0)))
    np_, mp = u.shape
    grid = (mp // bmm, np_ // bn)
    out = pl.pallas_call(
        functools.partial(_kernel, t=t, nn=np_ // bn),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bmm), lambda j, i: (i, j)),
            pl.BlockSpec((bn, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bmm, t), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, t), jnp.float32),
        interpret=interpret,
    )(u, weights)
    return out[:m]
