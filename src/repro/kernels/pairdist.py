"""Pallas TPU kernel: blocked all-pairs distance + fused threshold epilogue.

This is the verify-phase hot spot of SP-Join (paper reduce phase: every
kernel-partition row is checked against every whole-partition row) and also
the map-phase space mapping (objects x anchors). The same kernel serves both.

TPU adaptation of the paper's per-reducer verify loop (DESIGN.md par.2):

  * Grid (nv, nw, nm): V-tiles x W-tiles x feature-chunks. The feature axis is
    innermost so a VMEM accumulator carries partial distances across chunks —
    the (a, b, m) intermediate never exists, and for the masked variant the
    (a, b) float distance matrix never touches HBM either (only the int8 mask
    or per-row counts do, an 8x/32x HBM-write saving over materializing f32
    distances).
  * MXU path (l2 / cosine / dot): the cross term is a (bv, bm) x (bm, bw)
    ``dot_general`` per chunk — systolic-array work, bm = 128 aligned.
  * VPU path (l1 / linf): |x - y| reductions are elementwise; the chunk is
    kept small (bm = 16) so the (bv, bw, bm) broadcast stays ~1 MiB in VMEM.
  * Fused epilogue on the last chunk: sqrt / 1-minus, then optional
    ``<= delta`` mask in int8.

Block sizes default to (128, 128, 128|16): MXU-aligned tiles; VMEM footprint
per step = x(64 KiB) + y(64 KiB) + acc(64 KiB) + out tile, far under the
~16 MiB/core budget, leaving room for double-buffered pipelining.

Correctness contract (validated against ``ref.py`` in tests/test_kernels.py):
inputs are zero-padded to block multiples by ``ops.py``; zero padding in the
feature dimension is exact for every supported metric (|0-0| contributes 0),
and padded rows/cols are sliced away after the call.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MXU_METRICS = ("l2", "cosine", "dot")
VPU_METRICS = ("l1", "linf")
METRICS = MXU_METRICS + VPU_METRICS


def _accumulate(acc_ref, xc, yc, metric: str) -> None:
    """One feature chunk's contribution to the (bv, bw) distance accumulator
    (shared by the plain and the filtered kernel)."""
    if metric == "l2":
        cross = jax.lax.dot_general(
            xc, yc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        acc_ref[...] += (
            (xc * xc).sum(1)[:, None] + (yc * yc).sum(1)[None, :] - 2.0 * cross
        )
    elif metric in ("cosine", "dot"):
        # cosine: ops.py pre-normalizes rows, so the dot accumulates cos-sim.
        acc_ref[...] += jax.lax.dot_general(
            xc, yc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
    elif metric == "l1":
        acc_ref[...] += jnp.abs(xc[:, None, :] - yc[None, :, :]).sum(-1)
    elif metric == "linf":
        # max-accumulation: init 0 is correct because |.| >= 0.
        acc_ref[...] = jnp.maximum(
            acc_ref[...], jnp.abs(xc[:, None, :] - yc[None, :, :]).max(-1)
        )
    else:  # pragma: no cover — guarded by ops.py
        raise ValueError(metric)


def _finalize(acc, metric: str):
    if metric == "l2":
        return jnp.sqrt(jnp.maximum(acc, 0.0))
    if metric == "cosine":
        return 1.0 - acc
    return acc


def _kernel(
    x_ref,  # (bv, bm) VMEM
    y_ref,  # (bw, bm) VMEM
    out_ref,  # (bv, bw) VMEM — f32 distances or int8 mask
    acc_ref,  # (bv, bw) f32 VMEM scratch, persists across the nm grid axis
    *,
    metric: str,
    delta: float | None,
    nm: int,
):
    im = pl.program_id(2)

    @pl.when(im == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _accumulate(acc_ref, x_ref[...].astype(jnp.float32),
                y_ref[...].astype(jnp.float32), metric)

    @pl.when(im == nm - 1)
    def _epilogue():
        acc = _finalize(acc_ref[...], metric)
        if delta is None:
            out_ref[...] = acc
        else:
            out_ref[...] = (acc <= delta).astype(out_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("metric", "delta", "bv", "bw", "bm", "interpret"),
)
def pairdist_blocked(
    x: jnp.ndarray,  # (a, m) — a, m already padded to block multiples
    y: jnp.ndarray,  # (b, m)
    *,
    metric: str = "l2",
    delta: float | None = None,
    bv: int = 128,
    bw: int = 128,
    bm: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw blocked call. Use ``ops.pairdist`` / ``ops.pairdist_mask`` which
    handle padding, normalization and backend dispatch."""
    a, m = x.shape
    b, _ = y.shape
    if bm is None:
        bm = 128 if metric in MXU_METRICS else 16
    bm = min(bm, m)
    assert a % bv == 0 and b % bw == 0 and m % bm == 0, (x.shape, y.shape, bv, bw, bm)
    nm = m // bm
    out_dtype = jnp.float32 if delta is None else jnp.int8

    grid = (a // bv, b // bw, nm)
    return pl.pallas_call(
        functools.partial(_kernel, metric=metric, delta=delta, nm=nm),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bv, bm), lambda i, j, k: (i, k)),
            pl.BlockSpec((bw, bm), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bv, bw), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a, b), out_dtype),
        scratch_shapes=[pltpu.VMEM((bv, bw), jnp.float32)],
        interpret=interpret,
    )(x, y)


# ---------------------------------------------------------------------------
# Fused pivot-filter + pairdist (the verify engine's prune="pivot" hot path)
# ---------------------------------------------------------------------------

# Pivot-coordinate chunk for the bound broadcast: the (bv, bw, BP_CHUNK)
# intermediate stays ~1 MiB in VMEM (same budget reasoning as the VPU bm=16).
BP_CHUNK = 16


def _filtered_kernel(
    x_ref,  # (bv, bm) VMEM — payload feature chunk
    y_ref,  # (bw, bm) VMEM
    px_ref,  # (bv, bp) VMEM — FULL mapped coordinates (anchor distances)
    py_ref,  # (bw, bp) VMEM
    out_ref,  # (bv, bw) int8 mask
    acc_ref,  # (bv, bw) f32 scratch — distance accumulator
    bound_ref,  # (bv, bw) f32 scratch — L-inf pivot lower bound
    *,
    metric: str,
    delta: float,
    delta_bound: float,
    nm: int,
    bp: int,
):
    im = pl.program_id(2)

    @pl.when(im == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        # The pivot axis is NOT chunked by the grid (bp is small — n_dims
        # padded); the bound is computed once per (i, j) tile, in BP_CHUNK
        # slices so the 3-d broadcast stays within the VMEM budget.
        pxc = px_ref[...].astype(jnp.float32)
        pyc = py_ref[...].astype(jnp.float32)
        bound = jnp.zeros_like(bound_ref)
        for c in range(0, bp, BP_CHUNK):
            bound = jnp.maximum(
                bound,
                jnp.abs(
                    pxc[:, None, c : c + BP_CHUNK] - pyc[None, :, c : c + BP_CHUNK]
                ).max(-1),
            )
        bound_ref[...] = bound

    # Whole-tile skip: when the lower bound already exceeds delta for EVERY
    # pair in this (bv, bw) tile, the exact-distance accumulation (the MXU /
    # VPU hot loop) is skipped outright — this is where pruning buys compute,
    # not just a masked epilogue. acc stays at its zero init; the epilogue's
    # bound conjunct forces the mask to all-False regardless.
    @pl.when((bound_ref[...] <= delta_bound).any())
    def _live():
        _accumulate(acc_ref, x_ref[...].astype(jnp.float32),
                    y_ref[...].astype(jnp.float32), metric)

    @pl.when(im == nm - 1)
    def _epilogue():
        acc = _finalize(acc_ref[...], metric)
        out_ref[...] = ((acc <= delta) & (bound_ref[...] <= delta_bound)).astype(
            out_ref.dtype
        )


@functools.partial(
    jax.jit,
    static_argnames=("metric", "delta", "delta_bound", "bv", "bw", "bm", "interpret"),
)
def pairdist_filtered_blocked(
    x: jnp.ndarray,  # (a, m) — a, m already padded to block multiples
    y: jnp.ndarray,  # (b, m)
    px: jnp.ndarray,  # (a, bp) — mapped coords, bp padded to a BP_CHUNK multiple
    py: jnp.ndarray,  # (b, bp)
    *,
    metric: str,
    delta: float,
    delta_bound: float,
    bv: int = 128,
    bw: int = 128,
    bm: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    """Raw blocked fused filter+pairdist call. Use ``ops.pairdist_mask_filtered``
    which handles padding, normalization and backend dispatch.

    Semantics (validated against ``ref.pairdist_mask_filtered``): int8 mask,
    1 where D(x_i, y_j) <= delta AND max_p |px_i[p] - py_j[p]| <= delta_bound.
    Zero padding is exact on both the feature and the pivot axis (|0-0| = 0
    contributes nothing to sum or max).
    """
    a, m = x.shape
    b, _ = y.shape
    bp = px.shape[1]
    if bm is None:
        bm = 128 if metric in MXU_METRICS else 16
    bm = min(bm, m)
    assert a % bv == 0 and b % bw == 0 and m % bm == 0, (x.shape, y.shape, bv, bw, bm)
    assert px.shape == (a, bp) and py.shape == (b, bp) and bp % BP_CHUNK == 0, (
        px.shape, py.shape, BP_CHUNK,
    )
    nm = m // bm

    grid = (a // bv, b // bw, nm)
    return pl.pallas_call(
        functools.partial(
            _filtered_kernel, metric=metric, delta=delta,
            delta_bound=delta_bound, nm=nm, bp=bp,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bv, bm), lambda i, j, k: (i, k)),
            pl.BlockSpec((bw, bm), lambda i, j, k: (j, k)),
            pl.BlockSpec((bv, bp), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bw, bp), lambda i, j, k: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bv, bw), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a, b), jnp.int8),
        scratch_shapes=[
            pltpu.VMEM((bv, bw), jnp.float32),
            pltpu.VMEM((bv, bw), jnp.float32),
        ],
        interpret=interpret,
    )(x, y, px, py)
