"""Pallas TPU kernel: fused reduce phase with on-device pair compaction.

The endgame form of the verify stage (ROADMAP "fully fused on-device reduce
phase"): ONE pass per tile bucket does the pivot-filter pre-mask, the exact
pairwise distance, the ``<= delta`` test, padding validity + the min-cell
de-dup rule, and an exclusive prefix-sum compaction that scatters surviving
``(v_id, w_id)`` pairs into a fixed-capacity output buffer. What leaves the
kernel is output-sensitive — O(capacity) ids plus two counters — instead of
the O(tile_v · tile_w) hit mask the host previously round-tripped through
``np.asarray`` / ``np.nonzero`` per tile.

Layout / pipelining (same scheme as ``pairdist.py``, whose accumulate /
finalize helpers and pivot-bound loop this kernel shares):

  * Grid (nv, nw, nm), feature chunks innermost: a VMEM f32 accumulator
    carries partial distances across chunks, so the (a, b) distance matrix
    never exists in HBM. Input tiles stream through Pallas' standard
    double-buffered DMA pipeline — each V/W slab is touched once per grid
    visit while compute overlaps the next tile's copy-in.
  * The pair buffer and the counter row use CONSTANT index maps: Pallas
    keeps them resident in VMEM across every grid step (the revisited-block
    rule), so the compaction cursor survives the whole sweep and the buffer
    is written back to HBM exactly once, at the end.
  * Epilogue per (i, j) tile on the last feature chunk: finalize, threshold,
    emit-mask (validity + min-cell de-dup, delegated semantics of
    ``ref.emit_mask``), block-local exclusive ranks via row cumsum + row
    offsets, then a value-level scatter ``buf.at[cursor + rank]`` with
    ``mode="drop"`` — slots past ``capacity`` fall off, the cursor keeps the
    TRUE total, and ``count > capacity`` is the overflow sentinel the engine
    retries on.
  * Prune variant: the L-inf pivot bound is computed once per (i, j) tile in
    ``BP_CHUNK`` slices (exactly ``pairdist._filtered_kernel``'s loop) and a
    whole-block ``pl.when`` skips the MXU/VPU accumulation when every pair
    in the block is pruned — the on-accelerator analogue of the streaming
    engine's tile skip.

Emission ORDER is block-major (tiles in grid order, row-major within a
tile), not global row-major: the engine sorts + uniques pairs at the end,
and the parity suite order-normalizes, so order is a non-contract.

Interpret-mode note: the scatter lowers through ``jnp``'s value-level
``.at[]`` — exact in interpret mode (the CI path off-TPU) and on the Mosaic
path it compiles to a serialized VMEM read-modify-write; block sizes keep
the buffer well inside the ~16 MiB VMEM budget (capacity <= tile area is
enforced by the engine's quarter-pow2 ladder).

Correctness contract (validated against ``ref.verify_compact`` in
tests/test_reduce_fused.py): rows are zero-padded to block multiples by
``ops.py`` with id/wcell padding = -1, so padded rows fail the validity
mask and can never be emitted; zero feature/pivot padding is exact for
every metric (|0-0| contributes nothing to sum or max).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.pairdist import BP_CHUNK, MXU_METRICS, _accumulate, _finalize


def _compact_kernel(
    *refs,
    metric: str,
    delta: float,
    delta_bound: float | None,
    nm: int,
    bp: int,
    capacity: int,
    cross: bool,
    prune: bool,
):
    """Fused verify + compaction. ``refs`` (prune variant adds px/py):

    inputs   x (bv, bm), y (bw, bm) [, px (bv, bp), py (bw, bp)],
             vids (bv, 1) i32, wids (bw, 1) i32, wcells (bw, 1) i32,
             cell_id (1, 1) i32
    outputs  pairs (capacity, 2) i32   — constant index map, VMEM-resident
             counts (1, 2) i32        — [total hits (cursor), candidates]
    scratch  acc (bv, bw) f32 [, bound (bv, bw) f32]
    """
    if prune:
        (x_ref, y_ref, px_ref, py_ref, vids_ref, wids_ref, wcells_ref,
         cell_ref, pairs_ref, counts_ref, acc_ref, bound_ref) = refs
    else:
        (x_ref, y_ref, vids_ref, wids_ref, wcells_ref,
         cell_ref, pairs_ref, counts_ref, acc_ref) = refs
        bound_ref = None
    iv, iw, im = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when((iv == 0) & (iw == 0) & (im == 0))
    def _init_out():
        # The outputs are revisited every grid step (constant index maps):
        # initialize once, at the very first step.
        pairs_ref[...] = jnp.full_like(pairs_ref, -1)
        counts_ref[...] = jnp.zeros_like(counts_ref)

    @pl.when(im == 0)
    def _init_tile():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        if prune:
            # Same bound loop as pairdist._filtered_kernel: the pivot axis is
            # not grid-chunked (bp is small), BP_CHUNK slices keep the 3-d
            # broadcast inside the VMEM budget.
            pxc = px_ref[...].astype(jnp.float32)
            pyc = py_ref[...].astype(jnp.float32)
            bound = jnp.zeros_like(bound_ref)
            for c in range(0, bp, BP_CHUNK):
                bound = jnp.maximum(
                    bound,
                    jnp.abs(
                        pxc[:, None, c : c + BP_CHUNK]
                        - pyc[None, :, c : c + BP_CHUNK]
                    ).max(-1),
                )
            bound_ref[...] = bound

    if prune:
        # Whole-block skip: all pairs pruned -> the exact-distance hot loop
        # never runs for this feature chunk (acc stays zero; the epilogue's
        # bound conjunct forces an all-False mask regardless).
        @pl.when((bound_ref[...] <= delta_bound).any())
        def _live():
            _accumulate(acc_ref, x_ref[...].astype(jnp.float32),
                        y_ref[...].astype(jnp.float32), metric)
    else:
        _accumulate(acc_ref, x_ref[...].astype(jnp.float32),
                    y_ref[...].astype(jnp.float32), metric)

    @pl.when(im == nm - 1)
    def _epilogue():
        vid = vids_ref[...][:, 0]  # (bv,)
        wid = wids_ref[...][:, 0]  # (bw,)
        valid = (vid[:, None] >= 0) & (wid[None, :] >= 0)
        hit = _finalize(acc_ref[...], metric) <= delta
        if prune:
            cand = (bound_ref[...] <= delta_bound) & valid
            hit = hit & (bound_ref[...] <= delta_bound)
        else:
            cand = valid
        if cross:
            mask = hit & valid
        else:
            # min-cell de-dup (ref.emit_mask semantics, inlined on refs).
            wc = wcells_ref[...][:, 0]
            cid = cell_ref[0, 0]
            mask = hit & valid & (
                (wc[None, :] > cid)
                | ((wc[None, :] == cid) & (vid[:, None] < wid[None, :]))
            )
        # Block-local exclusive rank: row-major within the block via row
        # cumsums + row offsets (a flat (bv*bw,) cumsum would defeat the VPU;
        # two small cumsums don't).
        m32 = mask.astype(jnp.int32)
        prow = jnp.cumsum(m32, axis=1)
        rowtot = prow[:, -1]
        rank = prow - 1 + (jnp.cumsum(rowtot) - rowtot)[:, None]
        cursor = counts_ref[0, 0]
        slot = jnp.where(mask, cursor + rank, capacity).reshape(-1)
        vv = jnp.broadcast_to(vid[:, None], mask.shape).reshape(-1)
        wv = jnp.broadcast_to(wid[None, :], mask.shape).reshape(-1)
        pairs_ref[...] = pairs_ref[...].at[slot].set(
            jnp.stack([vv, wv], axis=1), mode="drop"
        )
        counts_ref[0, 0] = cursor + rowtot.sum()
        counts_ref[0, 1] = counts_ref[0, 1] + cand.astype(jnp.int32).sum()


@functools.partial(
    jax.jit,
    static_argnames=(
        "metric", "delta", "delta_bound", "capacity", "cross",
        "bv", "bw", "bm", "interpret",
    ),
)
def verify_compact_blocked(
    x: jnp.ndarray,  # (a, m) — a, m already padded to block multiples
    y: jnp.ndarray,  # (b, m)
    vids: jnp.ndarray,  # (a, 1) int32, padding = -1
    wids: jnp.ndarray,  # (b, 1) int32, padding = -1
    wcells: jnp.ndarray,  # (b, 1) int32, padding = -1
    cell_id: jnp.ndarray,  # (1, 1) int32 — traced, NOT static (no recompiles
    #   per cell: the engine sweeps thousands of cells through one executable)
    px: jnp.ndarray | None = None,  # (a, bp) mapped coords, bp % BP_CHUNK == 0
    py: jnp.ndarray | None = None,  # (b, bp)
    *,
    metric: str,
    delta: float,
    capacity: int,
    delta_bound: float | None = None,
    cross: bool = False,
    bv: int = 128,
    bw: int = 128,
    bm: int | None = None,
    interpret: bool = False,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Raw blocked fused verify+compact call. Use ``ops.verify_compact``
    which handles padding, normalization and backend dispatch.

    Returns ``(pairs (capacity, 2) int32, counts (1, 2) int32)`` with
    ``counts[0, 0]`` the TRUE hit total (> capacity == overflow; buffer
    contents then unspecified) and ``counts[0, 1]`` the pivot-filter
    candidate count (valid pair count when unpruned) — semantics of
    ``ref.verify_compact`` up to emission order.
    """
    a, m = x.shape
    b, _ = y.shape
    prune = px is not None
    if bm is None:
        bm = 128 if metric in MXU_METRICS else 16
    bm = min(bm, m)
    assert a % bv == 0 and b % bw == 0 and m % bm == 0, (x.shape, y.shape, bv, bw, bm)
    assert vids.shape == (a, 1) and wids.shape == (b, 1) and wcells.shape == (b, 1)
    nm = m // bm
    bp = 0
    inputs = [x, y]
    in_specs = [
        pl.BlockSpec((bv, bm), lambda i, j, k: (i, k)),
        pl.BlockSpec((bw, bm), lambda i, j, k: (j, k)),
    ]
    if prune:
        assert py is not None
        bp = px.shape[1]
        assert px.shape == (a, bp) and py.shape == (b, bp) and bp % BP_CHUNK == 0
        inputs += [px, py]
        in_specs += [
            pl.BlockSpec((bv, bp), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bw, bp), lambda i, j, k: (j, 0)),
        ]
    inputs += [vids, wids, wcells, cell_id.reshape(1, 1).astype(jnp.int32)]
    in_specs += [
        pl.BlockSpec((bv, 1), lambda i, j, k: (i, 0)),
        pl.BlockSpec((bw, 1), lambda i, j, k: (j, 0)),
        pl.BlockSpec((bw, 1), lambda i, j, k: (j, 0)),
        pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
    ]
    scratch = [pltpu.VMEM((bv, bw), jnp.float32)]
    if prune:
        scratch.append(pltpu.VMEM((bv, bw), jnp.float32))
    pairs, counts = pl.pallas_call(
        functools.partial(
            _compact_kernel, metric=metric, delta=float(delta),
            delta_bound=None if delta_bound is None else float(delta_bound),
            nm=nm, bp=bp, capacity=capacity, cross=cross, prune=prune,
        ),
        grid=(a // bv, b // bw, nm),
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((capacity, 2), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 2), lambda i, j, k: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((capacity, 2), jnp.int32),
            jax.ShapeDtypeStruct((1, 2), jnp.int32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(*inputs)
    return pairs, counts
