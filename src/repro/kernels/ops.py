"""Public jit'd wrappers around the Pallas kernels.

Handles everything the raw kernels assume away: zero-padding to block
multiples, cosine pre-normalization, backend dispatch, and padding removal.

Backend dispatch (``backend=`` on every wrapper):

  "pallas"  the Pallas kernel — compiled on TPU, ``interpret=True`` elsewhere
            (the kernel body then runs as reference Python on CPU, which is
            how CI exercises the kernel path without an accelerator).
  "numpy"   the pure-jnp oracle in ``ref.py`` (XLA-compiled when called under
            jit — this is the CPU *fast* path, not just a debug path).
  "auto"    "pallas" on TPU, "numpy" elsewhere; metrics without a kernel
            always resolve to "numpy".

The legacy ``use_kernel`` bool is still accepted everywhere and, when given,
overrides ``backend`` (True -> "pallas", False -> "numpy").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import compact as _compact
from repro.kernels import pairdist as _pairdist
from repro.kernels import histogram as _histogram
from repro.kernels import mapassign as _mapassign
from repro.kernels import ref

Array = jnp.ndarray

METRICS = _pairdist.METRICS
BACKENDS = ("numpy", "pallas", "auto")


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def supports_kernel(metric: str) -> bool:
    """True when ``metric`` has a Pallas kernel implementation."""
    return metric in METRICS


def resolve_backend(
    backend: str = "auto", metric: str | None = None, use_kernel: bool | None = None
) -> str:
    """Resolve a backend request to a concrete "numpy" | "pallas".

    ``use_kernel`` (legacy bool) wins over ``backend`` when not None. "auto"
    picks the kernel only on TPU; explicitly asking for "pallas" with a metric
    that has no kernel is an error (callers that want graceful fallback go
    through "auto" or check :func:`supports_kernel` first).
    """
    if use_kernel is not None:
        backend = "pallas" if use_kernel else "numpy"
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; expected one of {BACKENDS}")
    if backend == "auto":
        if metric is not None and not supports_kernel(metric):
            return "numpy"
        return "pallas" if jax.default_backend() == "tpu" else "numpy"
    if backend == "pallas" and metric is not None and not supports_kernel(metric):
        raise ValueError(
            f"metric {metric!r} has no Pallas kernel; supported: {METRICS}"
        )
    return backend


def _pad_to(x: Array, mult: int, axis: int) -> Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _prep(x: Array, y: Array, metric: str, bv: int, bw: int, bm: int):
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}; kernels support {METRICS}")
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if metric == "cosine":
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
        y = y / jnp.maximum(jnp.linalg.norm(y, axis=-1, keepdims=True), 1e-12)
    xp = _pad_to(_pad_to(x, bv, 0), bm, 1)
    yp = _pad_to(_pad_to(y, bw, 0), bm, 1)
    return xp, yp


@functools.partial(
    jax.jit, static_argnames=("metric", "bv", "bw", "bm", "backend", "use_kernel")
)
def pairdist(
    x: Array,
    y: Array,
    metric: str = "l2",
    *,
    bv: int = 128,
    bw: int = 128,
    bm: int | None = None,
    backend: str = "auto",
    use_kernel: bool | None = None,
) -> Array:
    """All-pairs distance matrix (a, b) float32."""
    if resolve_backend(backend, metric, use_kernel) == "numpy":
        return ref.pairdist(x, y, metric)
    if bm is None:
        bm = 128 if metric in _pairdist.MXU_METRICS else 16
    a, b = x.shape[0], y.shape[0]
    xp, yp = _prep(x, y, metric, bv, bw, bm)
    bm = min(bm, xp.shape[1])
    out = _pairdist.pairdist_blocked(
        xp, yp, metric=metric, delta=None, bv=bv, bw=bw, bm=bm, interpret=_interpret()
    )
    return out[:a, :b]


@functools.partial(
    jax.jit,
    static_argnames=("metric", "delta", "bv", "bw", "bm", "backend", "use_kernel"),
)
def pairdist_mask(
    x: Array,
    y: Array,
    delta: float,
    metric: str = "l2",
    *,
    bv: int = 128,
    bw: int = 128,
    bm: int | None = None,
    backend: str = "auto",
    use_kernel: bool | None = None,
) -> Array:
    """Fused thresholded join mask (a, b) bool — distances never hit HBM."""
    if resolve_backend(backend, metric, use_kernel) == "numpy":
        return ref.pairdist_mask(x, y, delta, metric)
    if bm is None:
        bm = 128 if metric in _pairdist.MXU_METRICS else 16
    a, b = x.shape[0], y.shape[0]
    xp, yp = _prep(x, y, metric, bv, bw, bm)
    bm = min(bm, xp.shape[1])
    out = _pairdist.pairdist_blocked(
        xp,
        yp,
        metric=metric,
        delta=float(delta),
        bv=bv,
        bw=bw,
        bm=bm,
        interpret=_interpret(),
    )
    # Padded y-columns of an x row can false-positive (distance to the zero
    # vector may be <= delta); the slice removes them. Padded rows likewise.
    return out[:a, :b].astype(bool)


PRUNABLE_METRICS = ("l1", "l2", "linf")


def supports_prune(metric: str) -> bool:
    """True when the pivot filter is SOUND for ``metric`` on the kernel path.

    The L-inf lower bound over anchor distances needs the triangle inequality
    in the origin metric; "cosine" and "dot" are not true metrics, so pruning
    could drop genuine hits there. (The engine-level capability check in
    ``core.verify`` additionally admits the reference-only true metrics —
    angular, jaccard_minhash — which never reach this kernel.)
    """
    return metric in PRUNABLE_METRICS


@functools.partial(
    jax.jit,
    static_argnames=(
        "metric", "delta", "delta_bound", "bv", "bw", "bm", "backend",
        "use_kernel",
    ),
)
def pairdist_mask_filtered(
    x: Array,
    y: Array,
    px: Array,
    py: Array,
    delta: float,
    metric: str = "l2",
    *,
    delta_bound: float | None = None,
    bv: int = 128,
    bw: int = 128,
    bm: int | None = None,
    backend: str = "auto",
    use_kernel: bool | None = None,
) -> Array:
    """Fused pivot-filter + thresholded join mask (a, b) bool.

    ``px``/``py`` are the mapped coordinates (per-row distances to the shared
    anchors). Identical output to :func:`pairdist_mask` — the filter's L-inf
    lower bound (slackened by ``ref.prune_delta``; pass ``delta_bound`` for
    the scale-aware band) only removes pairs whose distance already exceeds
    ``delta`` — but the Pallas path skips the exact-distance accumulation
    for tiles where every pair is pruned.
    """
    if not supports_prune(metric):
        raise ValueError(
            f"pivot filter is unsound for {metric!r} (needs the triangle "
            f"inequality); prunable kernel metrics: {PRUNABLE_METRICS}"
        )
    if delta_bound is None:
        delta_bound = ref.prune_delta(delta, metric)
    if resolve_backend(backend, metric, use_kernel) == "numpy":
        return ref.pairdist_mask_filtered(x, y, px, py, delta, metric, delta_bound)
    if bm is None:
        bm = 128 if metric in _pairdist.MXU_METRICS else 16
    a, b = x.shape[0], y.shape[0]
    xp, yp = _prep(x, y, metric, bv, bw, bm)
    bm = min(bm, xp.shape[1])
    # Pivot coords ride un-normalized (they are distances, not payload);
    # zero row/column padding is exact for the L-inf max.
    pxp = _pad_to(_pad_to(px.astype(jnp.float32), bv, 0), _pairdist.BP_CHUNK, 1)
    pyp = _pad_to(_pad_to(py.astype(jnp.float32), bw, 0), _pairdist.BP_CHUNK, 1)
    out = _pairdist.pairdist_filtered_blocked(
        xp, yp, pxp, pyp, metric=metric, delta=float(delta),
        delta_bound=float(delta_bound), bv=bv, bw=bw, bm=bm,
        interpret=_interpret(),
    )
    # Padded rows/cols can false-positive exactly like pairdist_mask; slice.
    return out[:a, :b].astype(bool)


@functools.partial(
    jax.jit,
    static_argnames=(
        "delta", "metric", "capacity", "cross", "delta_bound",
        "bv", "bw", "bm", "backend", "use_kernel",
    ),
)
def verify_compact(
    x: Array,
    y: Array,
    vids: Array,
    wids: Array,
    wcells: Array,
    cell_id,
    px: Array | None = None,
    py: Array | None = None,
    *,
    delta: float,
    metric: str,
    capacity: int,
    cross: bool = False,
    delta_bound: float | None = None,
    bv: int = 128,
    bw: int = 128,
    bm: int | None = None,
    backend: str = "auto",
    use_kernel: bool | None = None,
) -> tuple[Array, Array, Array]:
    """Fused single-dispatch reduce step: (filter,) distance, threshold,
    validity + min-cell de-dup, and on-device pair compaction.

    ``vids`` / ``wids`` / ``wcells``: (a,) / (b,) int ids with padding = -1;
    ``cell_id`` the verified cell (traced, not static — no recompile per
    cell). With ``px``/``py`` (mapped coordinates) the pivot-filter bound is
    fused in front of the exact distance (prunable metrics only, same rules
    as :func:`pairdist_mask_filtered`).

    Returns ``(pairs, count, n_cand)``: ``pairs`` (capacity, 2) int32 id
    pairs padded with -1, ``count`` int32 the TRUE hit total (``count >
    capacity`` == overflow -> the caller retries at the next capacity
    bucket), ``n_cand`` int32 the pivot-filter survivor count (== valid pair
    count when unfiltered). Pair ORDER is backend-dependent (row-major on
    numpy, block-major on Pallas) — callers sort/unique, parity tests
    order-normalize. Semantics oracle: ``ref.verify_compact``.
    """
    if capacity < 1:
        raise ValueError(f"capacity must be >= 1, got {capacity}")
    if px is not None:
        if not supports_prune(metric):
            raise ValueError(
                f"pivot filter is unsound for {metric!r} (needs the triangle "
                f"inequality); prunable kernel metrics: {PRUNABLE_METRICS}"
            )
        if delta_bound is None:
            delta_bound = ref.prune_delta(delta, metric)
    if resolve_backend(backend, metric, use_kernel) == "numpy":
        pairs, count, n_cand = ref.verify_compact(
            x, y, vids, wids, wcells, cell_id, delta=delta, metric=metric,
            capacity=capacity, cross=cross, px=px, py=py,
            delta_bound=delta_bound,
        )
        return pairs, count, n_cand
    a, b = x.shape[0], y.shape[0]
    if a == 0 or b == 0:  # empty tile: nothing to grid over
        return (
            jnp.full((capacity, 2), -1, jnp.int32),
            jnp.zeros((), jnp.int32),
            jnp.zeros((), jnp.int32),
        )
    if bm is None:
        bm = 128 if metric in _pairdist.MXU_METRICS else 16
    xp, yp = _prep(x, y, metric, bv, bw, bm)
    bm = min(bm, xp.shape[1])
    # Row padding carries id/wcell = -1 so padded rows fail the validity
    # mask — they can never be emitted or counted as candidates.
    vp = _pad_const(vids.astype(jnp.int32).reshape(-1, 1), bv, 0, -1)
    wp = _pad_const(wids.astype(jnp.int32).reshape(-1, 1), bw, 0, -1)
    wcp = _pad_const(wcells.astype(jnp.int32).reshape(-1, 1), bw, 0, -1)
    pxp = pyp = None
    if px is not None:
        # Pivot coords ride un-normalized (they are distances, not payload);
        # zero row/column padding is exact for the L-inf max.
        pxp = _pad_to(_pad_to(px.astype(jnp.float32), bv, 0), _pairdist.BP_CHUNK, 1)
        pyp = _pad_to(_pad_to(py.astype(jnp.float32), bw, 0), _pairdist.BP_CHUNK, 1)
    pairs, counts = _compact.verify_compact_blocked(
        xp, yp, vp, wp, wcp, jnp.asarray(cell_id, jnp.int32).reshape(1, 1),
        pxp, pyp, metric=metric, delta=float(delta), capacity=capacity,
        delta_bound=None if delta_bound is None else float(delta_bound),
        cross=cross, bv=bv, bw=bw, bm=bm, interpret=_interpret(),
    )
    return pairs, counts[0, 0], counts[0, 1]


@functools.partial(
    jax.jit, static_argnames=("metric", "delta", "backend", "use_kernel")
)
def pairdist_count(
    x: Array,
    y: Array,
    delta: float,
    metric: str = "l2",
    *,
    backend: str = "auto",
    use_kernel: bool | None = None,
) -> Array:
    """Per-row join fan-out counts (a,) int32."""
    return pairdist_mask(
        x, y, delta, metric, backend=backend, use_kernel=use_kernel
    ).sum(-1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("t", "bn", "bmm", "backend", "use_kernel"))
def histogram(
    u: Array,
    t: int,
    weights: Array | None = None,
    *,
    bn: int = 256,
    bmm: int = 8,
    backend: str = "auto",
    use_kernel: bool | None = None,
) -> Array:
    """Per-dimension histogram (m, t) of CDF-space values u: (n, m)."""
    if resolve_backend(backend, use_kernel=use_kernel) == "numpy":
        return ref.histogram(u, t, weights)
    n, m = u.shape
    w = jnp.ones((n, 1), jnp.float32) if weights is None else weights.reshape(n, 1)
    # Ragged n/m are padded (and masked via the weights column) by the
    # blocked kernel itself.
    return _histogram.histogram_blocked(
        u, w.astype(jnp.float32), t=t, bn=bn, bmm=bmm, interpret=_interpret()
    )


# ---------------------------------------------------------------------------
# Fused map phase: space map + kernel assign + packed whole membership
# ---------------------------------------------------------------------------

_ND_MULT = 8  # mapped-coordinate (anchor) axis padded to this multiple
_BIG = _mapassign.BIG


def _pad_const(x: Array, mult: int, axis: int, value: float) -> Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def _prep_boxes(
    kernel_lo: Array, kernel_hi: Array, whole_lo: Array, whole_hi: Array, bp: int
):
    """Pad the (p, n) box edges for the blocked kernel.

    Padded DIMENSIONS get (-BIG, +BIG) edges — any finite coordinate
    satisfies them, so they never veto containment. Padded PARTITIONS get
    lo = +BIG — no finite coordinate reaches them, so they never match
    (neither half-open kernel nor closed whole)."""
    def dims(lo, hi):
        return (
            _pad_const(lo.astype(jnp.float32), _ND_MULT, 1, -_BIG),
            _pad_const(hi.astype(jnp.float32), _ND_MULT, 1, _BIG),
        )

    def parts(lo, hi):
        return _pad_const(lo, bp, 0, _BIG), _pad_const(hi, bp, 0, _BIG)

    klo, khi = parts(*dims(kernel_lo, kernel_hi))
    wlo, whi = parts(*dims(whole_lo, whole_hi))
    return klo, khi, wlo, whi


def _bp_eff(p: int, bp: int) -> int:
    """Concrete partition block: a WORD multiple no larger than needed."""
    if bp % _mapassign.WORD != 0:
        raise ValueError(f"bp={bp} must be a multiple of {_mapassign.WORD}")
    p_words = -(-p // _mapassign.WORD) * _mapassign.WORD
    return min(bp, p_words)


WANTS = ("both", "cells", "member")


def _want_flags(want: str) -> tuple[bool, bool]:
    if want not in WANTS:
        raise ValueError(f"unknown want {want!r}; expected one of {WANTS}")
    return want != "member", want != "cells"


@functools.partial(
    jax.jit,
    static_argnames=("metric", "bn", "bp", "bm", "backend", "use_kernel", "want"),
)
def map_assign(
    x: Array,
    anchors: Array,
    kernel_lo: Array,
    kernel_hi: Array,
    whole_lo: Array,
    whole_hi: Array,
    metric: str = "l2",
    *,
    bn: int = 128,
    bp: int = 128,
    bm: int | None = None,
    backend: str = "auto",
    use_kernel: bool | None = None,
    want: str = "both",
) -> tuple[Array, Array, Array]:
    """Fused map phase over one shard: one streamed pass computes the mapped
    coordinates ``xm = D(x, anchors)`` (N, n), the kernel cell id (N,) int32
    and the packed whole-membership bitmask (N, ⌈p/32⌉) uint32 — without the
    (N, p, n) / (N, p) HBM intermediates of the two-pass jnp path (unpack
    the bits with :func:`unpack_membership`). Kernel metrics only (callers
    with reference-only metrics map via ``core.mapping`` and use
    :func:`assign_membership` / the partition fallback).

    ``want``: "both" | "cells" | "member" — skip a containment side the
    caller will recompute anyway (e.g. membership against post-``tighten``
    boxes); the skipped output is zero-filled, never garbage."""
    n_rows = x.shape[0]
    n_dims = anchors.shape[0]
    p = kernel_lo.shape[0]
    words = -(-p // _mapassign.WORD)
    want_cells, want_member = _want_flags(want)
    if resolve_backend(backend, metric, use_kernel) == "numpy":
        xm = ref.pairdist(x, anchors, metric)
        cells, bits = _ref_assign(
            xm, kernel_lo, kernel_hi, whole_lo, whole_hi, want_cells, want_member
        )
        return xm, cells, bits
    if n_rows == 0:  # empty shard: nothing to grid over
        return (
            jnp.zeros((0, n_dims), jnp.float32),
            jnp.zeros((0,), jnp.int32),
            jnp.zeros((0, words), jnp.uint32),
        )
    if bm is None:
        bm = 128 if metric in _pairdist.MXU_METRICS else 16
    xp, ap = _prep(x, anchors, metric, bn, _ND_MULT, bm)
    bm = min(bm, xp.shape[1])
    bpe = _bp_eff(p, bp)
    xm, cells, bits = _mapassign.map_assign_blocked(
        xp, ap, *_prep_boxes(kernel_lo, kernel_hi, whole_lo, whole_hi, bpe),
        metric=metric, bn=bn, bp=bpe, bm=bm, interpret=_interpret(),
        want_cells=want_cells, want_member=want_member,
    )
    return xm[:n_rows, :n_dims], cells[:n_rows, 0], bits[:n_rows, :words]


def _ref_assign(xm, kernel_lo, kernel_hi, whole_lo, whole_hi, want_cells, want_member):
    """numpy-backend assign with the same zero-fill contract as the kernel."""
    n_rows = xm.shape[0]
    words = -(-kernel_lo.shape[0] // _mapassign.WORD)
    cells = (
        ref.assign_kernel_cells(xm, kernel_lo, kernel_hi)
        if want_cells
        else jnp.zeros((n_rows,), jnp.int32)
    )
    bits = (
        ref.membership_bits(xm, whole_lo, whole_hi)
        if want_member
        else jnp.zeros((n_rows, words), jnp.uint32)
    )
    return cells, bits


@functools.partial(
    jax.jit, static_argnames=("bn", "bp", "backend", "use_kernel", "want")
)
def assign_membership(
    xm: Array,
    kernel_lo: Array,
    kernel_hi: Array,
    whole_lo: Array,
    whole_hi: Array,
    *,
    bn: int = 128,
    bp: int = 128,
    backend: str = "auto",
    use_kernel: bool | None = None,
    want: str = "both",
) -> tuple[Array, Array]:
    """Assign-only variant of :func:`map_assign`: the coordinates ``xm``
    (N, n) are already mapped (the ``metric=None`` path of the same fused
    kernel — metric-independent, so every backend request is honored).
    Returns (cells (N,) int32, bits (N, ⌈p/32⌉) uint32); ``want`` as in
    :func:`map_assign` (the unwanted output is zero-filled)."""
    n_rows = xm.shape[0]
    p = kernel_lo.shape[0]
    words = -(-p // _mapassign.WORD)
    want_cells, want_member = _want_flags(want)
    if resolve_backend(backend, None, use_kernel) == "numpy":
        return _ref_assign(
            xm, kernel_lo, kernel_hi, whole_lo, whole_hi, want_cells, want_member
        )
    if n_rows == 0:  # empty shard: nothing to grid over
        return jnp.zeros((0,), jnp.int32), jnp.zeros((0, words), jnp.uint32)
    xp = _pad_to(_pad_to(xm.astype(jnp.float32), bn, 0), _ND_MULT, 1)
    bpe = _bp_eff(p, bp)
    # bm = _ND_MULT: the coordinate width is an _ND_MULT multiple (not
    # necessarily a multiple of the metric-default 16), and metric=None
    # never chunks over it anyway.
    _, cells, bits = _mapassign.map_assign_blocked(
        xp, jnp.zeros((xp.shape[1], xp.shape[1]), jnp.float32),
        *_prep_boxes(kernel_lo, kernel_hi, whole_lo, whole_hi, bpe),
        metric=None, bn=bn, bp=bpe, bm=_ND_MULT, interpret=_interpret(),
        want_cells=want_cells, want_member=want_member,
    )
    return cells[:n_rows, 0], bits[:n_rows, :words]


@functools.partial(jax.jit, static_argnames=("p",))
def unpack_membership(bits: Array, p: int) -> Array:
    """(N, ⌈p/32⌉) packed words → (N, p) bool whole-membership mask."""
    return ref.unpack_membership(bits, p)
