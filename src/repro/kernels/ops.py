"""Public jit'd wrappers around the Pallas kernels.

Handles everything the raw kernels assume away: zero-padding to block
multiples, cosine pre-normalization, backend dispatch (compiled Pallas on
TPU, ``interpret=True`` elsewhere — the kernel body then runs as reference
Python on CPU, which is how this container validates it), and an escape hatch
``use_kernel=False`` that routes to the pure-jnp oracle for A/B testing.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import pairdist as _pairdist
from repro.kernels import histogram as _histogram
from repro.kernels import ref

Array = jnp.ndarray

METRICS = _pairdist.METRICS


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: Array, mult: int, axis: int) -> Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _prep(x: Array, y: Array, metric: str, bv: int, bw: int, bm: int):
    if metric not in METRICS:
        raise ValueError(f"unknown metric {metric!r}; kernels support {METRICS}")
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    if metric == "cosine":
        x = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
        y = y / jnp.maximum(jnp.linalg.norm(y, axis=-1, keepdims=True), 1e-12)
    xp = _pad_to(_pad_to(x, bv, 0), bm, 1)
    yp = _pad_to(_pad_to(y, bw, 0), bm, 1)
    return xp, yp


@functools.partial(jax.jit, static_argnames=("metric", "bv", "bw", "bm", "use_kernel"))
def pairdist(
    x: Array,
    y: Array,
    metric: str = "l2",
    *,
    bv: int = 128,
    bw: int = 128,
    bm: int | None = None,
    use_kernel: bool = True,
) -> Array:
    """All-pairs distance matrix (a, b) float32."""
    if not use_kernel:
        return ref.pairdist(x, y, metric)
    if bm is None:
        bm = 128 if metric in _pairdist.MXU_METRICS else 16
    a, b = x.shape[0], y.shape[0]
    xp, yp = _prep(x, y, metric, bv, bw, bm)
    bm = min(bm, xp.shape[1])
    out = _pairdist.pairdist_blocked(
        xp, yp, metric=metric, delta=None, bv=bv, bw=bw, bm=bm, interpret=_interpret()
    )
    return out[:a, :b]


@functools.partial(
    jax.jit, static_argnames=("metric", "delta", "bv", "bw", "bm", "use_kernel")
)
def pairdist_mask(
    x: Array,
    y: Array,
    delta: float,
    metric: str = "l2",
    *,
    bv: int = 128,
    bw: int = 128,
    bm: int | None = None,
    use_kernel: bool = True,
) -> Array:
    """Fused thresholded join mask (a, b) bool — distances never hit HBM."""
    if not use_kernel:
        return ref.pairdist_mask(x, y, delta, metric)
    if bm is None:
        bm = 128 if metric in _pairdist.MXU_METRICS else 16
    a, b = x.shape[0], y.shape[0]
    xp, yp = _prep(x, y, metric, bv, bw, bm)
    bm = min(bm, xp.shape[1])
    out = _pairdist.pairdist_blocked(
        xp,
        yp,
        metric=metric,
        delta=float(delta),
        bv=bv,
        bw=bw,
        bm=bm,
        interpret=_interpret(),
    )
    # Padded y-columns of an x row can false-positive (distance to the zero
    # vector may be <= delta); the slice removes them. Padded rows likewise.
    return out[:a, :b].astype(bool)


@functools.partial(jax.jit, static_argnames=("metric", "delta", "use_kernel"))
def pairdist_count(
    x: Array, y: Array, delta: float, metric: str = "l2", *, use_kernel: bool = True
) -> Array:
    """Per-row join fan-out counts (a,) int32."""
    return pairdist_mask(x, y, delta, metric, use_kernel=use_kernel).sum(-1).astype(
        jnp.int32
    )


@functools.partial(jax.jit, static_argnames=("t", "bn", "bmm", "use_kernel"))
def histogram(
    u: Array,
    t: int,
    weights: Array | None = None,
    *,
    bn: int = 256,
    bmm: int = 8,
    use_kernel: bool = True,
) -> Array:
    """Per-dimension histogram (m, t) of CDF-space values u: (n, m)."""
    if not use_kernel:
        return ref.histogram(u, t, weights)
    n, m = u.shape
    w = jnp.ones((n, 1), jnp.float32) if weights is None else weights.reshape(n, 1)
    bn_ = min(bn, max(n, 1))
    up = _pad_to(_pad_to(u, bn_, 0), bmm, 1)
    wp = _pad_to(w, bn_, 0)  # padding rows get weight 0 -> no contribution
    out = _histogram.histogram_blocked(
        up, wp.astype(jnp.float32), t=t, bn=bn_, bmm=min(bmm, up.shape[1]), interpret=_interpret()
    )
    return out[:m]
