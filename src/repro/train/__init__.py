"""Training substrate: optimizer, checkpointing, step builders."""
from repro.train import checkpoint, optimizer, train_step  # noqa: F401
