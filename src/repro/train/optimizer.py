"""AdamW + cosine schedule + clipping, plus int8 error-feedback gradient
compression — pure-pytree implementations (no optax dependency).

The compressor is the distributed-optimization hook: on a real pod the DP
gradient all-reduce moves 4 bytes/param/step; quantizing to int8 with
error feedback (residual carried to the next step) cuts that 4x with no
convergence change at LM scale. Here it wraps the gradient pytree right
where XLA's reduce-scatter sees it; tests check the EF invariant
(quantized stream + residual == true stream exactly in expectation and
within one step's quantization error pointwise).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    compress_grads: bool = False  # int8 error-feedback DP compression


class AdamState(NamedTuple):
    step: jnp.ndarray  # scalar int32
    mu: PyTree
    nu: PyTree
    ef_residual: PyTree | None  # error-feedback residual (when compressing)


def init_opt_state(params: PyTree, cfg: OptConfig) -> AdamState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
    ef = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params) if cfg.compress_grads else None
    return AdamState(jnp.zeros((), jnp.int32), zeros, jax.tree.map(jnp.copy, zeros), ef)


def lr_at(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    s = step.astype(jnp.float32)
    warm = s / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (s - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, cos)


def global_norm(tree: PyTree) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def compress_int8_ef(grads: PyTree, residual: PyTree) -> tuple[PyTree, PyTree]:
    """int8 quantize (per-leaf absmax scale) with error feedback.

    Returns (dequantized grads — what the all-reduce would carry, new
    residual). The quantize->dequantize round trip is what crosses the wire;
    the residual keeps the scheme unbiased over steps."""

    def one(g, r):
        t = g.astype(jnp.float32) + r
        scale = jnp.maximum(jnp.max(jnp.abs(t)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        return deq, t - deq

    out = jax.tree.map(one, grads, residual)
    deq = jax.tree.map(lambda x: x[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda x: x[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return deq, new_r


def apply_updates(
    params: PyTree, grads: PyTree, state: AdamState, cfg: OptConfig
) -> tuple[PyTree, AdamState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    ef = state.ef_residual
    if cfg.compress_grads:
        grads, ef = compress_int8_ef(grads, ef)

    step = state.step + 1
    lr = lr_at(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)

    def upd(p, m, v):
        u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps) + cfg.weight_decay * p.astype(
            jnp.float32
        )
        return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

    new_params = jax.tree.map(upd, params, mu, nu)
    return new_params, AdamState(step, mu, nu, ef), {"grad_norm": gnorm, "lr": lr}


def abstract_opt_state(abstract_params: PyTree, cfg: OptConfig) -> AdamState:
    """ShapeDtypeStruct mirror of init_opt_state (dry-run)."""
    z = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params)
    ef = jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), abstract_params) if cfg.compress_grads else None
    return AdamState(jax.ShapeDtypeStruct((), jnp.int32), z, z, ef)
