"""Sharded, atomic, resumable checkpoints (no orbax in this environment).

Layout (one directory per step):

    <dir>/step_000123.tmp/...   -> written fully, then atomically renamed to
    <dir>/step_000123/
        meta.json               step, data cursor, rng, tree structure
        shard_<host>.npz        this host's param/opt leaves (flattened ids)

Multi-host protocol: every host writes only the leaves (or leaf-shards) it
owns; host 0 writes meta and performs the rename after a barrier. In this
single-process container there is one host, but the API keeps the host_id /
n_hosts parameters so the launcher code is the real thing.

Fault-tolerance contract (exercised by tests/test_checkpoint.py):
  * atomic: a crash mid-write leaves only a *.tmp dir, never a corrupt
    checkpoint; ``latest_step`` ignores tmp dirs.
  * resumable: params, opt state (incl. step counter), data cursor and RNG
    restore bit-exactly.
  * keep_k garbage collection never deletes the newest checkpoint.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


@dataclasses.dataclass
class TrainState:
    params: PyTree
    opt_state: PyTree
    step: int
    data_cursor: int  # global examples consumed (pipeline resume point)
    rng_seed: int


def _flatten(tree: PyTree) -> list[np.ndarray]:
    return [np.asarray(x) for x in jax.tree.leaves(tree)]


def save(
    ckpt_dir: str,
    state: TrainState,
    *,
    host_id: int = 0,
    n_hosts: int = 1,
    keep_k: int = 3,
) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    name = f"step_{state.step:09d}"
    tmp = os.path.join(ckpt_dir, name + ".tmp")
    final = os.path.join(ckpt_dir, name)
    os.makedirs(tmp, exist_ok=True)

    tree = {"params": state.params, "opt_state": state.opt_state}
    leaves = _flatten(tree)
    # Host h owns leaves with index % n_hosts == h (leaf-level sharding; a
    # real deployment shards within leaves via jax.experimental.multihost).
    own = {
        f"leaf_{i}": leaf for i, leaf in enumerate(leaves) if i % n_hosts == host_id
    }
    np.savez(os.path.join(tmp, f"shard_{host_id}.npz"), **own)

    if host_id == 0:
        meta = {
            "step": state.step,
            "data_cursor": state.data_cursor,
            "rng_seed": state.rng_seed,
            "n_leaves": len(leaves),
            "n_hosts": n_hosts,
        }
        with open(os.path.join(tmp, "meta.json"), "w") as f:
            json.dump(meta, f)
        os.replace(tmp, final)  # atomic publish
        _gc(ckpt_dir, keep_k)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: TrainState, step: int | None = None) -> TrainState:
    """Restore into the structure of ``like`` (shapes must match)."""
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)

    tree = {"params": like.params, "opt_state": like.opt_state}
    flat, treedef = jax.tree.flatten(tree)
    leaves: dict[int, np.ndarray] = {}
    for fn in os.listdir(path):
        if fn.startswith("shard_") and fn.endswith(".npz"):
            with np.load(os.path.join(path, fn)) as z:
                for k in z.files:
                    leaves[int(k.split("_")[1])] = z[k]
    assert len(leaves) == meta["n_leaves"] == len(flat), (
        len(leaves), meta["n_leaves"], len(flat),
    )
    new_flat = [
        jnp.asarray(leaves[i], dtype=flat[i].dtype) for i in range(len(flat))
    ]
    new_tree = jax.tree.unflatten(treedef, new_flat)
    return TrainState(
        params=new_tree["params"],
        opt_state=new_tree["opt_state"],
        step=meta["step"],
        data_cursor=meta["data_cursor"],
        rng_seed=meta["rng_seed"],
    )


def _gc(ckpt_dir: str, keep_k: int) -> None:
    steps = sorted(
        d for d in os.listdir(ckpt_dir) if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep_k]:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
    # Stale tmp dirs from crashes are garbage too.
    for d in os.listdir(ckpt_dir):
        if d.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)
