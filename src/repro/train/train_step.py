"""Loss + train/serve step builders (the functions the launcher jits).

``make_train_step`` returns a pure (params, opt_state, batch) ->
(params, opt_state, metrics) function with microbatched gradient
accumulation: the global batch is split into ``n_micro`` chunks scanned
sequentially, so per-device live activations stay at one microbatch
regardless of global batch (the knob that fits granite-34b train_4k into
16 GiB/chip together with scan-over-layers remat).

Losses:
  decoder families — next-token CE (labels shifted inside), label -1 masks
  encoder (audio)  — per-frame CE, no shift
MoE aux (load-balance) loss is added with weight ``aux_weight``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ArchConfig
from repro.train import optimizer as opt_lib

PyTree = Any


@dataclasses.dataclass(frozen=True)
class StepConfig:
    n_micro: int = 1
    aux_weight: float = 0.01
    causal_mode: str = "blocklist"
    grad_dtype: str = "float32"


def cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, shift: bool
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Masked mean CE. labels < 0 are ignored. Returns (loss, n_tokens)."""
    if shift:
        logits = logits[:, :-1]
        labels = labels[:, 1:]
    # Pad/patch positions may make labels longer/shorter than logits (vlm
    # prepends patches); align on the right.
    S = min(logits.shape[1], labels.shape[1])
    logits = logits[:, -S:]
    labels = labels[:, -S:]
    mask = labels >= 0
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(
        logits.astype(jnp.float32), jnp.maximum(labels, 0)[..., None], axis=-1
    )[..., 0]
    nll = (lse - ll) * mask
    n = jnp.maximum(mask.sum(), 1)
    return nll.sum() / n, n


def make_loss_fn(cfg: ArchConfig, scfg: StepConfig) -> Callable:
    def loss_fn(params: PyTree, batch: dict) -> tuple[jnp.ndarray, dict]:
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, aux = transformer.forward(
            params, inputs, cfg, causal_mode=scfg.causal_mode
        )
        loss, n_tok = cross_entropy(logits, batch["labels"], shift=not cfg.is_encoder)
        total = loss + scfg.aux_weight * aux
        return total, {"loss": loss, "aux": aux, "n_tokens": n_tok}

    return loss_fn


def make_train_step(
    cfg: ArchConfig, opt_cfg: opt_lib.OptConfig, scfg: StepConfig
) -> Callable:
    loss_fn = make_loss_fn(cfg, scfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params: PyTree, opt_state: opt_lib.AdamState, batch: dict):
        n_micro = scfg.n_micro
        if n_micro == 1:
            (total, metrics), grads = grad_fn(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:]), batch
            )

            def acc_step(carry, mb):
                g_acc, l_acc = carry
                (total, _), g = grad_fn(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / n_micro, g_acc, g
                )
                return (g_acc, l_acc + total / n_micro), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, total), _ = jax.lax.scan(
                acc_step, (g0, jnp.float32(0.0)), micro
            )
            metrics = {"loss": total, "aux": jnp.float32(0.0), "n_tokens": jnp.int32(0)}

        params, opt_state, om = opt_lib.apply_updates(params, grads, opt_state, opt_cfg)
        metrics = dict(metrics, **om, total=total)
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ArchConfig, scfg: StepConfig | None = None) -> Callable:
    scfg = scfg or StepConfig()
    loss_fn = make_loss_fn(cfg, scfg)

    def eval_step(params: PyTree, batch: dict):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step


def make_serve_step(cfg: ArchConfig, sample: str = "greedy", temperature: float = 1.0):
    """One decode step: (params, token, state, length[, key]) ->
    (next_token, logits, new_state). This is what ``decode_*`` shapes lower."""

    def serve_step(params: PyTree, token: jnp.ndarray, state: PyTree, length: jnp.ndarray, key=None):
        logits, state = transformer.decode_step(params, token, state, length, cfg)
        last = logits[:, -1].astype(jnp.float32)
        if sample == "greedy":
            nxt = jnp.argmax(last, axis=-1).astype(jnp.int32)[:, None]
        else:
            nxt = jax.random.categorical(key, last / temperature).astype(jnp.int32)[:, None]
        return nxt, logits, state

    return serve_step


def make_prefill_step(cfg: ArchConfig, scfg: StepConfig | None = None):
    """Full-sequence forward returning LAST-position logits (B, 1, vocab) —
    what serving prefill actually emits (the first sampled token). Slicing
    before the unembed keeps the (B, S, vocab) logits tensor out of HBM
    entirely (qwen's 152k / llama4's 202k vocab made the full tensor the
    peak-memory term; see EXPERIMENTS.md §Perf).

    Production prefill would also materialize the KV cache; the compiled
    artifact covers the compute side (the cache write is the decode path's
    dynamic_update_slice, exercised by decode shapes)."""
    scfg = scfg or StepConfig()

    def prefill_step(params: PyTree, batch: dict):
        inputs = {k: v for k, v in batch.items() if k != "labels"}
        logits, _ = transformer.forward(
            params, inputs, cfg, causal_mode=scfg.causal_mode, last_only=True
        )
        return logits

    return prefill_step
