import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Optimized dry-run sweep (§Perf final table): every runnable cell with the
per-arch execution config selected by the hillclimbs:

  train_4k   FSDP profile (batch over all 256/512 chips, weights ZeRO-3)
             for every dense/ssm/hybrid/audio/vlm arch — measured 4.5-9x
             mfu_bound over TP; MoE archs keep TP + shard_map expert
             parallelism with n_micro=4 (FSDP refuted for them: expert
             weight re-gathers dominate).
  prefill/decode   TP profile (serving batches are too small to shard over
             256 chips; KV-cache sharding as in launch/shardings.py).

Writes runs/dryrun_opt.jsonl. Baseline table: runs/dryrun.jsonl.
"""
import json
import traceback

from repro import configs
from repro.launch import dryrun

MOE_TP = {"deepseek-moe-16b", "llama4-scout-17b-a16e"}


def config_for(arch: str, shape: str, multi_pod: bool = False):
    if shape == "train_4k":
        if arch in MOE_TP:
            return dict(profile="tp", n_micro=4)
        if multi_pod:
            # global_batch 256 < 512 chips: FSDP cannot shard the batch
            # (measured collapse), and XLA-auto sequence parallelism is
            # worse than TP (0.019 vs 0.086 granite) — TP baseline stands;
            # ring-attention SP is the known path beyond it.
            return dict(profile="tp")
        return dict(profile="fsdp")
    return dict(profile="tp")


def main() -> None:
    out = "runs/dryrun_opt.jsonl"
    done = set()
    if os.path.exists(out):
        for line in open(out):
            r = json.loads(line)
            if "error" not in r:
                done.add((r["arch"], r["shape"], r["mesh"]))
    with open(out, "a") as f:
        for a, s, ok, why in configs.all_cells():
            for mp in (False, True):
                mesh_name = "multi_pod" if mp else "single_pod"
                if not ok or (a, s, mesh_name) in done:
                    continue
                kw = config_for(a, s, mp)
                print(f"=== {a} x {s} [{mesh_name}] {kw} ===", flush=True)
                try:
                    rec = dryrun.run_cell(a, s, mp, **kw)
                    rec["opt"] = kw
                    print(
                        f"    mfu_bound={rec.get('mfu_bound')} "
                        f"bottleneck={rec.get('roofline', {}).get('bottleneck')} "
                        f"[{rec.get('total_s')}s]", flush=True)
                except Exception as e:
                    rec = {"arch": a, "shape": s, "mesh": mesh_name,
                           "error": str(e),
                           "traceback": traceback.format_exc()[-1500:]}
                    print(f"    ERROR: {e}", flush=True)
                f.write(json.dumps(rec) + "\n")
                f.flush()


if __name__ == "__main__":
    main()
