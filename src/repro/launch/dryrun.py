import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell against the production mesh and extract the roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen1.5-0.5b \
        --shape train_4k [--multi-pod] [--out runs/dryrun.jsonl]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--only-missing]

Per cell this produces: per-device memory analysis (proves it fits),
HLO FLOPs/bytes from cost_analysis (roofline numerator), and collective
bytes parsed from the optimized HLO (all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute operand sizes) — the three
terms EXPERIMENTS.md §Roofline reports.

(No ``from __future__`` here: the XLA_FLAGS assignment must be the first
statement in the file, which PEP 236 disallows combining with futures.)
"""
import argparse
import json
import sys
import time
import traceback
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch import shardings as shlib
from repro.launch.mesh import V5E, make_production_mesh
from repro.models import base, transformer
from repro.models.config import SHAPES, shape_applicable
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts

def _opt_shardings(param_sh, mesh):
    step_sh = NamedSharding(mesh, P())
    return opt_lib.AdamState(step_sh, param_sh, param_sh, None)


def _metrics_sh(tree, mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def build_cell(arch: str, shape_name: str, multi_pod: bool, profile: str = "tp",
               param_dtype=None, remat: str | None = None,
               n_micro: int | None = None):
    """Returns (fn, args, in_shardings, out_shardings, meta).

    profile: "tp" (default Megatron-style) or "fsdp" (hillclimb H1 —
    "model" axis carries batch, weights pure-FSDP; right for small-d archs).
    """
    mesh = make_production_mesh(multi_pod=multi_pod)
    cfg = configs.get(arch)
    if remat is not None:
        import dataclasses as _dc
        cfg = _dc.replace(cfg, remat=remat)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"skip: {why}")

    rules, act_rules, profile_batch_axes = base.rules_for_profile(profile)
    defs = transformer.model_defs(cfg)
    aparams = base.abstract_params(defs, dtype=param_dtype)
    param_sh = base.make_shardings(defs, mesh, rules)
    specs = configs.input_specs(cfg, shape, abstract=True)

    batch_axes = tuple(a for a in profile_batch_axes if a in mesh.axis_names)
    n_devices_batch = int(np.prod([mesh.shape[a] for a in batch_axes]))

    if shape.kind == "train":
        if n_micro is None:
            n_micro = max(1, shape.global_batch // n_devices_batch)
        ocfg = opt_lib.OptConfig()
        scfg = ts.StepConfig(n_micro=n_micro)
        fn = ts.make_train_step(cfg, ocfg, scfg)
        aopt = opt_lib.abstract_opt_state(aparams, ocfg)
        opt_sh = _opt_shardings(param_sh, mesh)
        batch = specs["batch"]
        batch_sh = shlib.batch_shardings(batch, mesh, batch_axes)
        args = (aparams, aopt, batch)
        in_sh = (param_sh, opt_sh, batch_sh)
        metrics = {
            "loss": 0.0, "aux": 0.0, "n_tokens": 0, "grad_norm": 0.0,
            "lr": 0.0, "total": 0.0,
        }
        out_sh = (param_sh, opt_sh, _metrics_sh(metrics, mesh))
        meta = {"entry": "train_step", "n_micro": n_micro}
    elif shape.kind == "prefill":
        fn = ts.make_prefill_step(cfg)
        batch = specs["batch"]
        batch_sh = shlib.batch_shardings(batch, mesh, batch_axes)
        args = (aparams, batch)
        in_sh = (param_sh, batch_sh)
        out_sh = NamedSharding(mesh, shlib.batch_spec(mesh, (shape.global_batch, 1, 1), batch_axes))
        meta = {"entry": "prefill_step"}
    else:  # decode
        serve = ts.make_serve_step(cfg)
        state = specs["state"]
        state_sh = shlib.state_shardings(cfg, state, mesh)
        tok_sh = NamedSharding(mesh, shlib.batch_spec(mesh, specs["token"].shape, batch_axes))
        len_sh = NamedSharding(mesh, P())
        args = (aparams, specs["token"], state, specs["length"])
        in_sh = (param_sh, tok_sh, state_sh, len_sh)
        out_sh = (tok_sh, tok_sh, state_sh)
        fn = serve
        meta = {"entry": "serve_step"}

    meta.update(
        mesh_shape=str(dict(mesh.shape)), chips=int(np.prod(list(mesh.shape.values()))),
        profile=profile,
    )
    return mesh, fn, args, in_sh, out_sh, meta, cfg, shape, act_rules


def run_cell(
    arch: str, shape_name: str, multi_pod: bool = False, do_compile: bool = True,
    profile: str = "tp", param_dtype=None, remat: str | None = None,
    n_micro: int | None = None,
) -> dict[str, Any]:
    t0 = time.time()
    mesh, fn, args, in_sh, out_sh, meta, cfg, shape, act_rules = build_cell(
        arch, shape_name, multi_pod, profile, param_dtype, remat, n_micro
    )
    rec: dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi_pod" if multi_pod else "single_pod",
        **meta,
    }
    with base.use_mesh(mesh, act_rules):
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        rec["lower_s"] = round(time.time() - t0, 1)
        if not do_compile:
            return rec
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 1)

    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        }
    except Exception as e:  # backend-dependent
        rec["memory"] = {"error": str(e)}

    try:
        cost = compiled.cost_analysis()
        cost = cost[0] if isinstance(cost, (list, tuple)) else cost
        # raw XLA numbers (loop bodies counted ONCE — cross-check only)
        rec["xla_flops_unrolled_once"] = float(cost.get("flops", 0.0))
        rec["xla_bytes_unrolled_once"] = float(cost.get("bytes accessed", 0.0))
    except Exception as e:
        rec["cost_error"] = str(e)

    try:
        from repro.launch import hloparse

        hlo = compiled.as_text()
        rec["hlo_bytes"] = len(hlo)
        parsed = hloparse.analyze(hlo)
        rec["flops_per_device"] = parsed["flops_per_device"]
        rec["coll_bytes_per_device"] = parsed["collective_wire_bytes_per_device"]
        rec["coll_breakdown"] = {
            k: float(v) for k, v in parsed["collective_breakdown"].items()
        }
        rec["dot_traffic_per_device"] = parsed["dot_traffic_bytes_per_device"]
        rec["fusion_traffic_per_device"] = parsed["traffic_bytes_per_device"]
        rec["top_flop_computations"] = [
            [n[:60], float(f)] for n, f in parsed["top_flop_computations"][:4]
        ]
    except Exception as e:
        rec["parse_error"] = str(e) + traceback.format_exc()[-800:]

    # roofline terms — per device, per step (module is the per-device program)
    chips = rec["chips"]
    if "flops_per_device" in rec:
        terms = {
            "compute_s": rec["flops_per_device"] / V5E.peak_flops,
            "memory_s": rec["dot_traffic_per_device"] / V5E.hbm_bw,
            "collective_s": rec["coll_bytes_per_device"] / V5E.ici_bw,
        }
        rec["roofline"] = {k: float(v) for k, v in terms.items()}
        rec["roofline"]["bottleneck"] = max(terms, key=lambda k: terms[k])
        step_s = max(terms.values())
        total, active = cfg.n_params_active
        tokens = shape.global_batch * (1 if shape.is_decode else shape.seq_len)
        fmult = 6 if shape.kind == "train" else 2
        rec["model_flops"] = float(fmult * active * tokens)
        hlo_total_flops = rec["flops_per_device"] * chips
        rec["useful_flops_ratio"] = (
            rec["model_flops"] / hlo_total_flops if hlo_total_flops else None
        )
        # roofline fraction: useful model FLOP/s achieved at the predicted
        # step time vs mesh peak
        rec["mfu_bound"] = (
            rec["model_flops"] / (step_s * chips * V5E.peak_flops)
            if step_s > 0
            else None
        )
        rec["params_total"] = total
        rec["params_active"] = active
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--profile", default="tp", choices=["tp", "fsdp", "fsdp_sp"])
    ap.add_argument("--param-dtype", default=None, choices=[None, "bfloat16"])
    ap.add_argument("--remat", default=None, choices=[None, "full", "dots", "none"])
    ap.add_argument("--n-micro", type=int, default=None)
    ap.add_argument("--out", default="runs/dryrun.jsonl")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    done: set[tuple[str, str, str]] = set()
    if args.only_missing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                r = json.loads(line)
                if "error" not in r:
                    done.add((r["arch"], r["shape"], r["mesh"]))

    cells: list[tuple[str, str, bool]] = []
    if args.all:
        for a, s, ok, why in configs.all_cells():
            for mp in (False, True):
                if ok:
                    cells.append((a, s, mp))
                else:
                    print(f"SKIP {a} x {s}: {why}")
    else:
        cells.append((args.arch, args.shape, args.multi_pod))

    with open(args.out, "a") as f:
        for a, s, mp in cells:
            mesh_name = "multi_pod" if mp else "single_pod"
            if (a, s, mesh_name) in done:
                continue
            print(f"=== {a} x {s} [{mesh_name}] ===", flush=True)
            try:
                rec = run_cell(a, s, mp, do_compile=not args.no_compile,
                               profile=args.profile,
                               param_dtype=jnp.bfloat16 if args.param_dtype else None,
                               remat=args.remat, n_micro=args.n_micro)
                print(
                    f"    flops/dev={rec.get('flops_per_device', 0):.3e} "
                    f"coll/dev={rec.get('coll_bytes_per_device', 0):.3e} "
                    f"bottleneck={rec.get('roofline', {}).get('bottleneck')} "
                    f"mfu_bound={rec.get('mfu_bound')} [{rec.get('total_s')}s]",
                    flush=True,
                )
                if rec.get("memory"):
                    print(f"    memory={rec['memory']}", flush=True)
            except Exception as e:
                rec = {
                    "arch": a, "shape": s, "mesh": mesh_name,
                    "error": str(e), "traceback": traceback.format_exc()[-2000:],
                }
                print(f"    ERROR: {e}", flush=True)
            f.write(json.dumps(rec) + "\n")
            f.flush()


if __name__ == "__main__":
    main()
