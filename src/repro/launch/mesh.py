"""Production mesh construction + TPU v5e hardware model.

``make_production_mesh`` is a FUNCTION (never a module-level constant) so
importing this module touches no jax device state — the dry-run sets
XLA_FLAGS before first jax init, smoke tests keep their single device.

Mesh semantics:
  single-pod (16, 16)    axes ("data", "model") — 256 chips
  multi-pod  (2, 16, 16) axes ("pod", "data", "model") — 512 chips

"data" (+"pod") carries batch/FSDP and is the SP-Join "local node" axis;
"model" carries TP/EP. The pod axis crosses DCN: only data-parallel
gradient all-reduces (and nothing latency-sensitive) traverse it.

Serving: ``make_host_mesh`` is the mesh entry point of the query-serving
path (docs/SERVING.md) — ``MetricIndex.to_distributed(make_host_mesh())``
pins the per-slot V buffers over the "data" axis and every
``query_batch`` moves only query bytes (one W-side all_to_all). Runnable:
``python -m repro.launch.serve range``. ``HardwareModel``/``V5E`` are the
roofline denominators ``benchmarks/roofline.py`` renders.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, axis: str = "data") -> Mesh:
    """1-D mesh over whatever devices exist — the serving-path default
    (``MetricIndex.to_distributed`` shards V buffers over ``axis``) and the
    tests/examples mesh. ``n=None`` takes every visible device."""
    n = n or len(jax.devices())
    return jax.make_mesh((n,), (axis,))


def make_elastic_mesh(live_hosts: int, chips_per_host: int = 4) -> Mesh:
    """Elastic re-mesh: mesh shape as a function of the LIVE host set.

    The training driver calls this after membership changes; the data
    pipeline is step-addressed so the global batch is unchanged — only its
    sharding moves (launch/train.py)."""
    total = live_hosts * chips_per_host
    model = 1
    for cand in (16, 8, 4, 2, 1):
        if total % cand == 0 and cand <= total:
            model = cand
            break
    return jax.make_mesh((total // model, model), ("data", "model"))


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """TPU v5e per-chip constants (the roofline denominators)."""

    peak_flops: float = 197e12  # bf16 FLOP/s
    hbm_bw: float = 819e9  # bytes/s
    ici_bw: float = 50e9  # bytes/s per link direction
    hbm_bytes: float = 16e9  # capacity

    def roofline_seconds(
        self, flops: float, bytes_hbm: float, bytes_coll: float, chips: int
    ) -> dict:
        return {
            "compute_s": flops / (chips * self.peak_flops),
            "memory_s": bytes_hbm / (chips * self.hbm_bw),
            "collective_s": bytes_coll / (chips * self.ici_bw),
        }


V5E = HardwareModel()
