"""Structural parser for optimized HLO text -> roofline terms.

XLA's ``compiled.cost_analysis()`` visits every computation ONCE — a
``lax.scan`` body (our layer stack, microbatch loop, attention chunk loop)
is counted at 1/trip_count of its true cost, which understates a scanned
88-layer model by orders of magnitude. This module re-derives the terms
structurally from ``compiled.as_text()``:

  1. split the module into computation blocks; build a per-computation
     symbol table (instruction name -> shape) including parameters;
  2. build the call graph (fusion ``calls=``, ``to_apply=``, while
     ``body=/condition=``, conditional branches) and propagate an execution
     multiplier from ENTRY, multiplying by ``known_trip_count`` at while
     bodies;
  3. FLOPs: 2 * prod(result dims) * prod(lhs contracting dims) per ``dot``,
     weighted by the computation multiplier (CPU HLO keeps dots unfused, so
     this is exact for matmul FLOPs — elementwise FLOPs are ignored, they
     are < 1% for these models);
  4. collective bytes: per collective op, the ring-algorithm wire bytes per
     device derived from the result shape and replica_group size:
        all-gather        (g-1)/g * result
        reduce-scatter    (g-1)   * result          (input = g * result)
        all-reduce        2*(g-1)/g * result
        all-to-all        (g-1)/g * result
        collective-permute  result
  5. HBM-ish traffic: sum of (result + operand) bytes over instructions at
     fusion granularity (internals of fused computations excluded). CPU
     fusion decisions differ from TPU's — this term is an upper-ish proxy,
     flagged as such in EXPERIMENTS.md.

Everything is per-DEVICE (the module is the SPMD-partitioned per-device
program); multiply by chip count for whole-mesh totals.
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8, "c64": 8, "c128": 16,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"^\(?([a-z0-9]+)\[([\d,]*)\]")
# type may be a tuple "(f32[..], s32[..])" containing spaces -> non-greedy
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\(")
_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\((.*)$")
_PARAM_RE = re.compile(r"%?([\w.\-]+):\s+([a-z0-9]+\[[\d,]*\])")
_CALL_RE = re.compile(r"(?:calls=|to_apply=|body=|condition=)%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":\s*\{\s*"n":\s*"(\d+)"')
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)


def shape_bytes(type_str: str) -> int:
    """'f32[4,1024]{1,0}' -> byte count (tuples: sum of components)."""
    total = 0
    for dt, dims in re.findall(r"([a-z0-9]+)\[([\d,]*)\]", type_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def shape_dims(type_str: str) -> tuple[int, ...]:
    m = _SHAPE_RE.match(type_str)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(2).split(",") if d)


@dataclasses.dataclass
class Instruction:
    name: str
    type_str: str
    op: str
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    is_entry: bool
    params: dict  # name -> type_str
    instructions: list


def parse_computations(hlo: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    header_open = False
    for raw in hlo.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" "):  # computation header or module line
            if line.startswith("HloModule") or line.startswith("}"):
                cur = None
                continue
            m = _COMP_HDR_RE.match(line)
            if m:
                cur = Computation(m.group(2), bool(m.group(1)), {}, [])
                comps[cur.name] = cur
                header_open = "->" not in line
                for pname, ptype in _PARAM_RE.findall(line):
                    cur.params[pname] = ptype
            continue
        if cur is None:
            continue
        if header_open:
            for pname, ptype in _PARAM_RE.findall(line):
                cur.params[pname] = ptype
            if "->" in line:
                header_open = False
            continue
        d = _DEF_RE.match(line)
        if d:
            cur.instructions.append(Instruction(d.group(1), d.group(2), d.group(3), line))
    return comps


def _multipliers(comps: dict) -> dict:
    """Execution count per computation, from ENTRY, x trip_count at whiles."""
    entry = next((c.name for c in comps.values() if c.is_entry), None)
    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        return mult
    mult[entry] = 1.0
    # children edges: (parent, child, factor)
    edges: list[tuple[str, str, float]] = []
    fusion_children: set[str] = set()
    for c in comps.values():
        for ins in c.instructions:
            trip = 1.0
            if ins.op == "while":
                t = _TRIP_RE.search(ins.line)
                trip = float(t.group(1)) if t else 1.0
            for child in _CALL_RE.findall(ins.line):
                if child in comps:
                    edges.append((c.name, child, trip if ins.op == "while" else 1.0))
                    if ins.op == "fusion":
                        fusion_children.add(child)
            b = _BRANCHES_RE.search(ins.line)
            if b:
                for child in _OPERAND_RE.findall(b.group(1)):
                    if child in comps:
                        edges.append((c.name, child, 1.0))
    # fixed-point propagation (call graph is a DAG; few iterations suffice)
    for _ in range(64):
        changed = False
        new = defaultdict(float)
        new[entry] = 1.0
        for parent, child, factor in edges:
            new[child] += new.get(parent, mult.get(parent, 0.0)) * factor
        # iterate until stable using previous values for ordering robustness
        for k in set(list(new) + list(mult)):
            if abs(new[k] - mult.get(k, 0.0)) > 1e-9:
                changed = True
        mult = new
        if not changed:
            break
    mult["__fusion_children__"] = fusion_children  # type: ignore[assignment]
    return mult


def _group_size(line: str, default: int = 1) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))  # iota groups [n_groups, group_size]
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return default


def analyze(hlo: str) -> dict:
    comps = parse_computations(hlo)
    mult = _multipliers(comps)
    fusion_children: set = mult.pop("__fusion_children__", set())  # type: ignore[arg-type]

    flops = 0.0
    coll = {c: 0.0 for c in COLLECTIVES}
    coll_count = 0.0
    traffic = 0.0
    dot_traffic = 0.0  # matmul operand+result bytes — TPU HBM-traffic proxy
    by_comp_flops: dict[str, float] = defaultdict(float)

    for c in comps.values():
        m = mult.get(c.name, 0.0)
        if m == 0.0:
            continue
        symbols = dict(c.params)
        for ins in c.instructions:
            symbols[ins.name] = ins.type_str
        in_fusion = c.name in fusion_children

        for ins in c.instructions:
            op = ins.op
            if op == "dot":
                res_dims = shape_dims(ins.type_str)
                cm = _CONTRACT_RE.search(ins.line)
                call = ins.line.split("dot(")[-1]
                operands = _OPERAND_RE.findall(call.split(")")[0])
                contract = 1
                if cm and operands:
                    lhs_type = symbols.get(operands[0], "")
                    lhs_dims = shape_dims(lhs_type)
                    for idx in (int(i) for i in cm.group(1).split(",") if i):
                        if idx < len(lhs_dims):
                            contract *= lhs_dims[idx]
                f = 2.0 * math.prod(res_dims) * contract
                flops += m * f
                by_comp_flops[c.name] += m * f
                dsz = shape_bytes(ins.type_str)
                for operand in operands[:2]:
                    if operand in symbols:
                        dsz += shape_bytes(symbols[operand])
                dot_traffic += m * dsz
            base_op = op[:-6] if op.endswith("-start") else op
            if base_op in COLLECTIVES:
                g = _group_size(ins.line, 1)
                if op.endswith("-start"):
                    # async start results are (input, output) tuples; the
                    # destination buffer (last component) is the payload.
                    parts = re.findall(r"[a-z0-9]+\[[\d,]*\]", ins.type_str)
                    size = shape_bytes(parts[-1]) if parts else 0
                else:
                    size = shape_bytes(ins.type_str)
                if base_op == "all-gather":
                    wire = size * (g - 1) / max(g, 1)
                elif base_op == "reduce-scatter":
                    wire = size * (g - 1)
                elif base_op == "all-reduce":
                    wire = 2.0 * size * (g - 1) / max(g, 1)
                elif base_op == "all-to-all":
                    wire = size * (g - 1) / max(g, 1)
                else:  # collective-permute
                    wire = size
                coll[base_op] += m * wire
                coll_count += m

            if not in_fusion and op not in ("parameter", "constant", "tuple",
                                            "get-tuple-element", "bitcast"):
                sz = shape_bytes(ins.type_str)
                call = ins.line.split(f"{op}(")[-1].split(")")[0]
                for operand in _OPERAND_RE.findall(call):
                    if operand in symbols:
                        sz += shape_bytes(symbols[operand])
                traffic += m * sz

    top = sorted(by_comp_flops.items(), key=lambda kv: -kv[1])[:8]
    return {
        "flops_per_device": flops,
        "collective_wire_bytes_per_device": sum(coll.values()),
        "collective_breakdown": coll,
        "collective_op_executions": coll_count,
        "traffic_bytes_per_device": traffic,
        "dot_traffic_bytes_per_device": dot_traffic,
        "top_flop_computations": top,
        "n_computations": len(comps),
    }
