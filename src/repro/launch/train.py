"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b \
        --steps 200 --reduced --ckpt-dir runs/ckpt [--resume] \
        [--fail-at 50]   # fault injection: simulate a crash, then restart

Production behaviors demonstrated here (and exercised by
tests/test_driver.py on reduced configs):

  checkpoint/restart   atomic sharded checkpoints every --ckpt-every steps;
                       --resume restores params/opt/step/data-cursor and the
                       loss curve continues exactly where it left off.
  elastic re-mesh      the mesh is a function of the live device set
                       (mesh.make_elastic_mesh); on membership change the
                       driver re-lowers and re-shards from the checkpoint.
                       Data order is unchanged because batches are addressed
                       by global step, never by an iterator.
  straggler mitigation by construction: any host can recompute any shard of
                       any step's batch (pipeline.host_batch is pure), so a
                       backup task can shadow a slow worker without
                       coordination; on-TPU skew was already converted to
                       static padding by the capacity-bounded dispatch.
  failure injection    --fail-at N raises after step N (before checkpoint
                       GC), so restart paths stay tested, not theoretical.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.data.pipeline import PipelineConfig, TokenPipeline
from repro.launch import mesh as mesh_lib
from repro.models import base, transformer
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train import train_step as ts


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true", help="smoke-size config")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--n-micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    mesh = mesh_lib.make_host_mesh() if len(jax.devices()) > 1 else None

    pcfg = PipelineConfig(seed=0, seq_len=args.seq_len, global_batch=args.global_batch)
    pipe = TokenPipeline(cfg, pcfg)
    ocfg = opt_lib.OptConfig(
        lr=args.lr, total_steps=args.steps, warmup_steps=max(args.steps // 20, 1),
        compress_grads=args.compress_grads,
    )
    scfg = ts.StepConfig(n_micro=args.n_micro)

    defs = transformer.model_defs(cfg)
    params = base.init_params(jax.random.PRNGKey(0), defs)
    opt_state = opt_lib.init_opt_state(params, ocfg)
    step_fn = jax.jit(ts.make_train_step(cfg, ocfg, scfg))

    start_step = 0
    if args.resume and args.ckpt_dir and ckpt_lib.latest_step(args.ckpt_dir) is not None:
        state = ckpt_lib.restore(
            args.ckpt_dir,
            ckpt_lib.TrainState(params, opt_state, 0, 0, 0),
        )
        params, opt_state, start_step = state.params, state.opt_state, state.step
        print(f"[resume] restored step {start_step} from {args.ckpt_dir}", flush=True)

    ctx = base.use_mesh(mesh) if mesh is not None else _null_ctx()
    with ctx:
        t0 = time.time()
        for step in range(start_step, args.steps):
            if mesh is not None:
                batch = pipe.device_batch(step, mesh, batch_axes=("data",))
            else:
                batch = {k: jnp.asarray(v) for k, v in pipe.global_batch(step).items()}
            params, opt_state, metrics = step_fn(params, opt_state, batch)

            if (step + 1) % args.log_every == 0 or step == start_step:
                print(
                    f"step {step + 1:5d} loss {float(metrics['total']):.4f} "
                    f"gnorm {float(metrics['grad_norm']):.3f} "
                    f"lr {float(metrics['lr']):.2e} "
                    f"({(time.time() - t0) / max(step + 1 - start_step, 1):.2f}s/step)",
                    flush=True,
                )
            if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
                path = ckpt_lib.save(
                    args.ckpt_dir,
                    ckpt_lib.TrainState(
                        params, opt_state, step + 1, (step + 1) * args.global_batch, 0
                    ),
                )
                print(f"[ckpt] {path}", flush=True)
            if args.fail_at is not None and step + 1 >= args.fail_at:
                raise RuntimeError(
                    f"injected failure at step {step + 1} (restart with --resume)"
                )
    print("done", flush=True)


class _null_ctx:
    def __enter__(self):
        return None

    def __exit__(self, *a):
        return False


if __name__ == "__main__":
    main()
