"""Explicit sharding assignment for every entry-point operand.

The dry-run lowers with fully explicit in_shardings/out_shardings so the
compiled memory/collective profile is deterministic and auditable — nothing
is left to propagation defaults. Params use the logical rules in
models/base.py; batches shard their leading (global-batch) dim over
("pod","data"); decode states get per-family treatment here.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ArchConfig

PyTree = Any


def _axes(mesh: Mesh, names: tuple[str, ...]) -> tuple[str, ...]:
    return tuple(a for a in names if a in mesh.axis_names)


def _div(dim: int, mesh: Mesh, names: tuple[str, ...]) -> bool:
    size = int(np.prod([mesh.shape[a] for a in names])) if names else 1
    return size > 1 and dim % size == 0


def batch_spec(
    mesh: Mesh, shape: tuple[int, ...], batch_axes: tuple[str, ...] = ("pod", "data")
) -> P:
    """Shard dim 0 over the profile's batch axes when divisible, else
    replicate. FSDP-profile archs put "model" in batch_axes too."""
    bd = _axes(mesh, batch_axes)
    if shape and _div(shape[0], mesh, bd):
        return P(bd if len(bd) > 1 else bd[0])
    return P()


def batch_shardings(
    batch: PyTree, mesh: Mesh, batch_axes: tuple[str, ...] = ("pod", "data")
) -> PyTree:
    return jax.tree.map(
        lambda x: NamedSharding(mesh, batch_spec(mesh, x.shape, batch_axes)), batch
    )


def _model_dim_spec(shape, batch_idx, model_candidates, mesh):
    """P with batch on batch_idx and 'model' on the first candidate dim that
    divides; remaining dims replicated."""
    bd = _axes(mesh, ("pod", "data"))
    spec: list = [None] * len(shape)
    if batch_idx is not None and _div(shape[batch_idx], mesh, bd):
        spec[batch_idx] = bd if len(bd) > 1 else bd[0]
    if "model" in mesh.axis_names:
        for c in model_candidates:
            if c != batch_idx and c < len(shape) and shape[c] % mesh.shape["model"] == 0 and shape[c] > 1:
                spec[c] = "model"
                break
    return P(*spec)


def state_shardings(cfg: ArchConfig, state_shapes: PyTree, mesh: Mesh) -> PyTree:
    """Decode-state shardings keyed by the init_state tree structure.

    KV caches (…, B, S, KV, hd): batch over ("pod","data"); KV heads over
    "model" when they divide (GQA), else the SEQUENCE dim (MQA — per-rank
    partial softmax, psum'd by SPMD). SSD/conv/mLSTM states shard their
    head or feature dim over "model".
    """

    def assign(path, leaf):
        keys = [getattr(pp, "key", getattr(pp, "name", "")) for pp in path]
        shape = leaf.shape
        nd = len(shape)
        if "kv" in keys or "kv0" in keys or ("k" in keys or "v" in keys):
            # (L?, B, S, KV, hd) or (B, S, KV, hd) [or (groups, B, S, KV, hd)]
            b_idx = nd - 4
            kv_idx, s_idx = nd - 2, nd - 3
            if shape[kv_idx] % mesh.shape.get("model", 1) == 0 and shape[kv_idx] > 1:
                return NamedSharding(mesh, _model_dim_spec(shape, b_idx, (kv_idx,), mesh))
            return NamedSharding(mesh, _model_dim_spec(shape, b_idx, (s_idx,), mesh))
        if "ssd" in keys:  # (g, per, B, H, N, P) or (B, H, N, P)
            b_idx = nd - 4
            return NamedSharding(mesh, _model_dim_spec(shape, b_idx, (nd - 3,), mesh))
        if "conv" in keys:  # (g, per, B, W-1, C)
            b_idx = nd - 3
            return NamedSharding(mesh, _model_dim_spec(shape, b_idx, (nd - 1,), mesh))
        if "mlstm" in keys:  # (g, per, B, H, dk, dv+1)
            b_idx = nd - 4
            return NamedSharding(mesh, _model_dim_spec(shape, b_idx, (nd - 3, nd - 2), mesh))
        if "slstm" in keys:  # (g, B, H, dh)
            b_idx = nd - 3
            return NamedSharding(mesh, _model_dim_spec(shape, b_idx, (nd - 2, nd - 1), mesh))
        # fallback: replicate
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(assign, state_shapes)
