"""Serving entry points: the metric-index range-query server + an LM demo.

Two subcommands:

``range`` — the REAL query-serving path of this repo (docs/SERVING.md):
build a persistent ``core.index.MetricIndex`` once, pin its per-slot V
buffers on a ``launch.mesh.make_host_mesh`` device mesh, then serve
δ-range query batches through the distributed verify-stage slot machinery
(one W-side all_to_all per batch, zero R bytes moved after build). Prints
build time, per-batch latency, QPS/p50/p99, and checks one batch against
the brute-force oracle.

    PYTHONPATH=src python -m repro.launch.serve range \\
        --n 20000 --m 16 --queries 4096 --batch 256

``lm`` — the batched LM prefill+decode demo (prefill-by-decode keeps
KV/SSM state layouts identical between phases, which is what makes the
decode_* dry-run cells representative):

    PYTHONPATH=src python -m repro.launch.serve lm --arch qwen1.5-0.5b \\
        --reduced --batch 4 --prompt-len 32 --gen 32

Bare ``--arch ...`` argv (no subcommand) is routed to ``lm`` so
``examples/serve_lm.py`` keeps working unchanged.
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# range: metric-index query serving (build once, query millions)
# ---------------------------------------------------------------------------


def serve_range(args) -> None:
    from repro.core import index as index_lib
    from repro.core import spjoin
    from repro.data import synthetic
    from repro.launch import mesh as mesh_lib

    # queries drawn near the indexed clusters (rs_mixture shares centers) so
    # the default δ actually produces hits
    data, queries = synthetic.rs_mixture(args.n, args.queries, args.m,
                                         n_clusters=6, spread=6.0, skew=0.3,
                                         shift=1.5, seed=0)
    cfg = spjoin.JoinConfig(delta=args.delta, metric=args.metric,
                            k=min(1024, args.n // 4), p=16, n_dims=8, seed=0)

    t0 = time.perf_counter()
    idx = index_lib.build_index(data, cfg)
    print(f"build: N={idx.n_rows} m={idx.n_features} p={idx.p} "
          f"in {time.perf_counter() - t0:.2f}s")

    mesh = mesh_lib.make_host_mesh(axis="data")
    didx = idx.to_distributed(mesh)
    print(f"pinned V buffers on {mesh.devices.size} device(s); serving")

    batches = [queries[i : i + args.batch]
               for i in range(0, args.queries, args.batch)]
    didx.query_batch(batches[0])  # warm-up (stage compile)

    lat, n_pairs = [], 0
    for i, b in enumerate(batches):
        t0 = time.perf_counter()
        pairs = didx.query_batch(b)
        lat.append(time.perf_counter() - t0)
        n_pairs += int(pairs.shape[0])
        if i < 3 or (i + 1) == len(batches):
            print(f"  batch {i + 1}/{len(batches)}: {b.shape[0]} queries, "
                  f"{pairs.shape[0]} pairs, {lat[-1] * 1e3:.1f} ms")

    lat_ms = np.asarray(lat) * 1e3
    n_q = sum(b.shape[0] for b in batches)
    print(f"served {n_q} queries, {n_pairs} pairs: "
          f"{n_q / lat_ms.sum() * 1e3:.0f} QPS, "
          f"p50 {np.percentile(lat_ms, 50):.1f} ms, "
          f"p99 {np.percentile(lat_ms, 99):.1f} ms")

    truth = index_lib.brute_force_query(data, batches[0], args.delta,
                                        args.metric)
    got = didx.query_batch(batches[0])
    assert np.array_equal(got, truth), "parity check vs brute force FAILED"
    print("parity vs brute force: ok")


# ---------------------------------------------------------------------------
# lm: batched prefill + streaming decode demo
# ---------------------------------------------------------------------------


def prefill_by_decode(params, tokens, cfg, state, serve_step):
    """Feed prompt tokens one step at a time (exact state, any family)."""
    B, T = tokens.shape
    for t in range(T):
        _, _, state = serve_step(params, tokens[:, t : t + 1], state, jnp.int32(t))
    return state


def serve_lm(args) -> None:
    from repro import configs
    from repro.models import base, transformer
    from repro.train import train_step as ts

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")

    defs = transformer.model_defs(cfg)
    params = base.init_params(jax.random.PRNGKey(0), defs)
    max_len = args.prompt_len + args.gen
    state = transformer.init_state(cfg, args.batch, max_len)

    mode = "greedy" if args.temperature == 0.0 else "temp"
    serve_step = jax.jit(
        ts.make_serve_step(cfg, "greedy" if mode == "greedy" else "sample",
                           max(args.temperature, 1e-3))
    )

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )

    t0 = time.time()
    state = prefill_by_decode(params, prompts, cfg, state, serve_step)
    t_prefill = time.time() - t0

    tok = prompts[:, -1:]
    out = []
    t0 = time.time()
    for i in range(args.gen):
        tok, _, state = serve_step(
            params, tok, state, jnp.int32(args.prompt_len + i)
        )
        out.append(np.asarray(tok)[:, 0])
    t_decode = time.time() - t0

    gen = np.stack(out, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decode {args.gen} steps in {t_decode:.2f}s "
          f"({args.batch * args.gen / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample output ids:", gen[0][:16])
    assert gen.shape == (args.batch, args.gen)
    assert (gen >= 0).all() and (gen < cfg.vocab).all()
    print("ok")


def main() -> None:
    argv = sys.argv[1:]
    if argv and argv[0].startswith("-"):
        argv = ["lm"] + argv  # pre-subcommand compat: bare --arch means lm

    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser("range", help="metric-index δ-range query serving")
    rp.add_argument("--n", type=int, default=20_000, help="indexed rows")
    rp.add_argument("--m", type=int, default=16, help="features")
    rp.add_argument("--queries", type=int, default=4096)
    rp.add_argument("--batch", type=int, default=256)
    rp.add_argument("--delta", type=float, default=3.0)
    rp.add_argument("--metric", default="l2")
    rp.set_defaults(fn=serve_range)

    lp = sub.add_parser("lm", help="batched LM prefill + decode demo")
    lp.add_argument("--arch", required=True)
    lp.add_argument("--reduced", action="store_true")
    lp.add_argument("--batch", type=int, default=4)
    lp.add_argument("--prompt-len", type=int, default=32)
    lp.add_argument("--gen", type=int, default=32)
    lp.add_argument("--temperature", type=float, default=0.0)
    lp.set_defaults(fn=serve_lm)

    args = ap.parse_args(argv)
    args.fn(args)


if __name__ == "__main__":
    main()
