"""Batched serving driver: prefill a batch of prompts, then decode.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --reduced --batch 4 --prompt-len 32 --gen 32

Production shape: requests are padded into a fixed (batch, max_len) slab;
prefill runs the full-sequence forward, the KV/SSM state is materialized by
replaying tokens through ``decode_step`` (prefill-by-decode keeps state
layouts identical between phases, which is what makes the decode_* dry-run
cells representative), then greedy/temperature decode streams tokens.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import base, transformer
from repro.train import train_step as ts


def prefill_by_decode(params, tokens, cfg, state, serve_step):
    """Feed prompt tokens one step at a time (exact state, any family)."""
    B, T = tokens.shape
    for t in range(T):
        _, _, state = serve_step(params, tokens[:, t : t + 1], state, jnp.int32(t))
    return state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch) if args.reduced else configs.get(args.arch)
    if cfg.is_encoder:
        raise SystemExit(f"{cfg.name} is encoder-only: no decode path")

    defs = transformer.model_defs(cfg)
    params = base.init_params(jax.random.PRNGKey(0), defs)
    max_len = args.prompt_len + args.gen
    state = transformer.init_state(cfg, args.batch, max_len)

    mode = "greedy" if args.temperature == 0.0 else "temp"
    serve_step = jax.jit(
        ts.make_serve_step(cfg, "greedy" if mode == "greedy" else "sample",
                           max(args.temperature, 1e-3))
    )

    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32
    )

    t0 = time.time()
    state = prefill_by_decode(params, prompts, cfg, state, serve_step)
    t_prefill = time.time() - t0

    tok = prompts[:, -1:]
    out = []
    t0 = time.time()
    for i in range(args.gen):
        tok, _, state = serve_step(
            params, tok, state, jnp.int32(args.prompt_len + i)
        )
        out.append(np.asarray(tok)[:, 0])
    t_decode = time.time() - t0

    gen = np.stack(out, axis=1)
    print(f"prefill {args.batch}x{args.prompt_len} in {t_prefill:.2f}s; "
          f"decode {args.gen} steps in {t_decode:.2f}s "
          f"({args.batch * args.gen / max(t_decode, 1e-9):.1f} tok/s)")
    print("sample output ids:", gen[0][:16])
    assert gen.shape == (args.batch, args.gen)
    assert (gen >= 0).all() and (gen < cfg.vocab).all()
    print("ok")


if __name__ == "__main__":
    main()
