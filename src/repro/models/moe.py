"""Mixture-of-Experts FFN with capacity-bounded dispatch.

The dispatch machinery is deliberately the same shape as SP-Join's partition
shuffle (DESIGN.md §2): tokens are objects, experts are cells, the router is
the partitioner, and static capacity + drop/overflow accounting replaces the
dynamic shuffle — skew costs padding, not stragglers. Both llama4-scout
(16e top-1 + shared) and deepseek-moe (64e top-6 + 2 shared, fine-grained)
are instances of this one module.

Execution layout (TP/EP over the "model" mesh axis):
  - activations between blocks are replicated across "model" (Megatron
    convention), so routing + dispatch-buffer construction are computed
    redundantly per rank — zero communication;
  - expert weights are sharded on the expert dim ("experts" -> "model"), so
    the expert einsum partitions on E: each rank slices its experts' rows of
    the (replicated) dispatch buffer — again no gather;
  - the combine scatter-add sums contributions across expert shards; XLA
    SPMD realizes it as the block's single all-reduce (same cost as a dense
    TP block).

Tokens are processed in groups of ~``group_size`` (scan) so the (E, C, d)
dispatch buffer stays ~100s of MiB regardless of sequence length.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro import compat
from repro.models import layers
from repro.models.base import current_act_rules, current_mesh, pdef, shard_act

Array = jnp.ndarray


def moe_defs(cfg) -> dict:
    d, E, f = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    out = {
        "router": pdef((d, E), ("embed", None), init="scaled"),
        "gate": pdef((E, d, f), ("experts", "embed", "mlp"), init="scaled"),
        "up": pdef((E, d, f), ("experts", "embed", "mlp"), init="scaled"),
        "down": pdef((E, f, d), ("experts", "mlp", "embed"), init="scaled"),
    }
    if cfg.n_shared_experts:
        out["shared"] = layers.mlp_defs(cfg, cfg.n_shared_experts * cfg.d_ff_expert)
    return out


def _capacity(gs: int, cfg) -> int:
    c = int(np.ceil(gs * cfg.top_k / cfg.n_experts * cfg.capacity_factor))
    return max(int(np.ceil(c / 8) * 8), 8)


def _dispatch_group(params, xg: Array, cfg):
    """One token group. xg: (B, gs, d) -> (y (B, gs, d), aux_loss scalar)."""
    B, gs, d = xg.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(gs, cfg)

    logits = (xg @ params["router"].astype(xg.dtype)).astype(jnp.float32)  # (B,gs,E)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)  # (B, gs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)  # renormalize top-k

    # Switch-style load-balance loss: E * sum_e f_e * P_e.
    me = probs.mean((0, 1))
    ce = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(2).mean((0, 1))
    aux = E * (me * ce).sum()

    # ---- rank of each (token, choice) within its expert ------------------
    flat_e = idx.reshape(B, gs * k)  # (B, T') expert id per assignment
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)  # (B, T', E)
    rank = jnp.cumsum(onehot, axis=1) - 1  # (B, T', E)
    rank_of = jnp.take_along_axis(rank, flat_e[..., None], axis=2)[..., 0]  # (B, T')
    keep = rank_of < C  # dropped assignments beyond capacity

    ee = jnp.where(keep, flat_e, E)  # E -> OOB -> dropped by scatter
    cc = jnp.clip(rank_of, 0, C - 1)
    tok = jnp.broadcast_to(jnp.arange(gs)[:, None], (gs, k)).reshape(gs * k)

    # ---- dispatch: (B, E, C, d), replicated over "model", sliced by XLA --
    def scatter_one(xb, eb, cb):
        buf = jnp.zeros((E + 1, C, d), xb.dtype)
        buf = buf.at[eb, cb].add(xb[tok], mode="drop")
        return buf[:E]

    buf = jax.vmap(scatter_one)(xg, ee, cc)  # (B, E, C, d)
    buf = shard_act(buf, ("act_batch", "act_model", None, None))

    # ---- expert FFN (E sharded over "model") ------------------------------
    g = jnp.einsum("becd,edf->becf", buf, params["gate"].astype(buf.dtype))
    u = jnp.einsum("becd,edf->becf", buf, params["up"].astype(buf.dtype))
    h = jax.nn.silu(g) * u
    o = jnp.einsum("becf,efd->becd", h, params["down"].astype(buf.dtype))
    o = shard_act(o, ("act_batch", "act_model", None, None))

    # ---- combine: weighted scatter-add back to token order ----------------
    def combine_one(ob, eb, cb, wb):
        gathered = ob[jnp.clip(eb, 0, E - 1), cb]  # (T', d)
        gathered = jnp.where((eb < E)[:, None], gathered, 0.0)
        y = jnp.zeros((gs, d), ob.dtype)
        return y.at[tok].add(gathered * wb.reshape(gs * k)[:, None].astype(ob.dtype))

    y = jax.vmap(combine_one)(o, ee, cc, w)  # (B, gs, d)

    if cfg.n_shared_experts:
        y = y + layers.mlp(params["shared"], xg, "swiglu")
    return y.astype(xg.dtype), aux


def _moe_groups(params, x, cfg, group_size, dispatch_fn):
    B, S, d = x.shape
    gs = min(group_size, S)
    assert S % gs == 0, (S, gs)
    nG = S // gs
    if nG == 1:
        return dispatch_fn(params, x, cfg)
    xr = x.reshape(B, nG, gs, d)

    def step(aux, g):
        y, a = dispatch_fn(params, xr[:, g], cfg)
        return aux + a, y

    aux, ys = jax.lax.scan(step, jnp.float32(0.0), jnp.arange(nG))
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, d), aux / nG


def _dispatch_group_ep(params, xg: Array, cfg, e_offset, n_local: int):
    """Expert-parallel variant of _dispatch_group: this rank owns experts
    [e_offset, e_offset + n_local); routing is computed redundantly
    (replicated activations), non-local assignments are dropped into the
    scatter's OOB bucket, and the partial combine is psum'd by the caller.
    Identical math to the local path when summed over ranks."""
    B, gs, d = xg.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(gs, cfg)

    logits = (xg @ params["router"].astype(xg.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)

    me = probs.mean((0, 1))
    ce = jax.nn.one_hot(idx, E, dtype=jnp.float32).sum(2).mean((0, 1))
    aux = E * (me * ce).sum()

    flat_e = idx.reshape(B, gs * k)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
    rank = jnp.cumsum(onehot, axis=1) - 1
    rank_of = jnp.take_along_axis(rank, flat_e[..., None], axis=2)[..., 0]
    local = (flat_e >= e_offset) & (flat_e < e_offset + n_local)
    keep = local & (rank_of < C)

    ee = jnp.where(keep, flat_e - e_offset, n_local)  # OOB -> dropped
    cc = jnp.clip(rank_of, 0, C - 1)
    tok = jnp.broadcast_to(jnp.arange(gs)[:, None], (gs, k)).reshape(gs * k)

    def scatter_one(xb, eb, cb):
        buf = jnp.zeros((n_local + 1, C, d), xb.dtype)
        return buf.at[eb, cb].add(xb[tok], mode="drop")[:n_local]

    buf = jax.vmap(scatter_one)(xg, ee, cc)  # (B, n_local, C, d)

    g = jnp.einsum("becd,edf->becf", buf, params["gate"].astype(buf.dtype))
    u = jnp.einsum("becd,edf->becf", buf, params["up"].astype(buf.dtype))
    h = jax.nn.silu(g) * u
    o = jnp.einsum("becf,efd->becd", h, params["down"].astype(buf.dtype))

    def combine_one(ob, eb, cb, wb):
        gathered = ob[jnp.clip(eb, 0, n_local - 1), cb]
        gathered = jnp.where((eb < n_local)[:, None], gathered, 0.0)
        y = jnp.zeros((gs, d), ob.dtype)
        return y.at[tok].add(gathered * wb.reshape(gs * k)[:, None].astype(ob.dtype))

    y = jax.vmap(combine_one)(o, ee, cc, w)  # partial: local experts only
    return y.astype(xg.dtype), aux


def moe_block(params: dict, x: Array, cfg, group_size: int = 2048):
    """MoE FFN over (B, S, d). Returns (y, aux_loss).

    Under a mesh with a "model" axis that divides n_experts, dispatch runs
    as an explicit shard_map expert-parallel block (hillclimb H2,
    EXPERIMENTS.md §Perf): activations are replicated across "model"
    (Megatron convention), each rank routes all tokens but computes only its
    expert slice, and ONE psum combines — the same wire cost as a dense TP
    block. Left to SPMD propagation, the combine's gather-from-E-sharded
    forced involuntary full rematerialization (XLA warning) and a ~300x
    collective blow-up.
    """
    mesh = current_mesh()
    mdl = mesh is not None and "model" in mesh.axis_names
    # Under the FSDP profile "model" carries batch (act_model rule is None):
    # activations are NOT replicated across it, so the EP shard_map contract
    # doesn't hold — take the local path (experts FSDP'd like any weight).
    mdl = mdl and current_act_rules().get("act_model") is not None
    if mdl and cfg.n_experts % mesh.shape["model"] == 0 and cfg.n_experts >= mesh.shape["model"]:
        n_model = mesh.shape["model"]
        n_local = cfg.n_experts // n_model
        bd = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        bspec = bd if len(bd) > 1 else (bd[0] if bd else None)
        routed = {k_: params[k_] for k_ in ("router", "gate", "up", "down")}

        def body(xb, rp):
            e_off = jax.lax.axis_index("model") * n_local

            def dispatch(pp, xg, cfg_):
                return _dispatch_group_ep(pp, xg, cfg_, e_off, n_local)

            y, aux = _moe_groups(rp, xb, cfg, group_size, dispatch)
            if bd:
                aux = jax.lax.pmean(aux, bd)  # batch is sharded across bd
            return jax.lax.psum(y, "model"), aux

        in_specs = (
            P(bspec, None, None),
            {
                "router": P(None, None),
                "gate": P("model", None, None),
                "up": P("model", None, None),
                "down": P("model", None, None),
            },
        )
        y, aux = compat.shard_map(
            body, mesh=mesh, in_specs=in_specs,
            out_specs=(P(bspec, None, None), P()),
            check_vma=False,
        )(x, routed)
        if cfg.n_shared_experts:
            y = y + layers.mlp(params["shared"], x, "swiglu")
        return y, aux

    return _moe_groups(params, x, cfg, group_size, _dispatch_group)
