"""Architecture configuration: one dataclass covers the whole assigned zoo.

Families:
  dense   — standard decoder (stablelm / granite / phi3 / qwen1.5)
  moe     — mixture-of-experts decoder (llama4-scout / deepseek-moe)
  ssm     — xLSTM (mLSTM + sLSTM blocks)
  hybrid  — Mamba2 backbone + weight-shared attention blocks (zamba2)
  audio   — encoder-only transformer over frame embeddings (hubert)
  vlm     — decoder with prepended patch embeddings (llava-next)
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None  # default d_model // n_heads
    # --- attention/MLP details ---
    qkv_bias: bool = False
    mlp_kind: str = "swiglu"  # swiglu | gelu
    rope_theta: float = 10_000.0
    causal: bool = True  # False for encoder-only (hubert)
    logit_softcap: float | None = None
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    first_layer_dense: bool = False  # deepseek-moe layer 0 is dense
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    shared_attn_every: int = 0  # zamba2: shared attn block period (0 = none)
    slstm_every: int = 0  # xlstm: every n-th block is sLSTM (0 = none)
    # --- modality frontends (stubs) ---
    frontend: str | None = None  # "audio_frames" | "vision_patches"
    frontend_dim: int = 0  # stub embedding dim
    n_patches: int = 0  # vlm: patch positions prepended
    # --- numerics ---
    param_dtype: str = "float32"
    act_dtype: str = "bfloat16"
    # --- scan/remat ---
    scan_layers: bool = True
    remat: str = "full"  # full | dots | none
    attn_q_chunk: int = 1024
    attn_kv_chunk: int = 1024

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def is_encoder(self) -> bool:
        return not self.causal

    @property
    def sub_quadratic(self) -> bool:
        """Can this arch decode at 500k context? (SSM/hybrid state-based.)"""
        return self.family in ("ssm", "hybrid")

    @property
    def n_params_active(self) -> tuple[int, int]:
        """(total, active) parameter estimate — feeds MODEL_FLOPS = 6·N·D."""
        d, L, V = self.d_model, self.n_layers, self.vocab
        hd = self.hd
        emb = V * d * (1 if self.tie_embeddings else 2)
        attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) + (self.n_heads * hd) * d

        def mlp_params(dff: int) -> int:
            return d * dff * (3 if self.mlp_kind == "swiglu" else 2)

        if self.family == "moe":
            per_expert = mlp_params(self.d_ff_expert)
            shared = self.n_shared_experts * per_expert + (
                mlp_params(self.d_ff) if self.d_ff else 0
            )
            total_mlp = self.n_experts * per_expert + shared
            active_mlp = self.top_k * per_expert + shared
            n_moe = L - (1 if self.first_layer_dense else 0)
            dense_ff = mlp_params(self.d_ff or 4 * d) if self.first_layer_dense else 0
            total = emb + L * attn + n_moe * total_mlp + dense_ff
            active = emb + L * attn + n_moe * active_mlp + dense_ff
            return total, active
        if self.family == "ssm":  # xlstm: in/out proj + gates, no external FFN
            d_in = self.ssm_expand * d
            per = 2 * d * d_in + 4 * d_in * (d_in // max(self.n_heads, 1))
            total = emb + L * per
            return total, total
        if self.family == "hybrid":
            d_in = self.ssm_expand * d
            nheads = d_in // self.ssm_head_dim
            per = d * (2 * d_in + 2 * self.ssm_state + nheads) + d_in * d
            shared = attn + mlp_params(self.d_ff)
            total = emb + L * per + shared
            return total, total
        total = emb + L * (attn + mlp_params(self.d_ff))
        return total, total


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_decode(self) -> bool:
        return self.kind == "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """The assignment's skip rules. Returns (runnable, reason_if_not)."""
    if arch.is_encoder and shape.kind in ("decode",):
        return False, "encoder-only arch has no autoregressive decode step"
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "full-attention arch: 500k decode needs sub-quadratic attention"
    return True, ""
