"""GQA attention: chunked (flash-style) training/prefill + cached decode.

Memory is the design constraint at 32k prefill: a materialized (S, S) score
matrix per head is gigabytes, so full-sequence attention runs as a two-level
``lax.scan`` (outer: query chunks, inner: KV chunks) carrying the online-
softmax state (m, l, acc) — the standard flash recurrence, in pure JAX so XLA
pipelines it on any backend.

Two causal schedules (see EXPERIMENTS.md §Perf for the measured delta):

  "rect"       inner scan covers all KV chunks, causality by masking.
               Simple, but compiles the full S^2 rectangle of block matmuls —
               2x the useful FLOPs of causal attention.
  "blocklist"  scan over the static list of lower-triangular (qi, kj) block
               pairs (ordered row-major, so per-q-chunk online softmax stays
               sequential); dynamic-slice the chunks, scatter the state. HLO
               FLOPs = the causal triangle only. This is the optimized
               schedule; the dry-run cost analysis is how we validated the
               ~2x compute-term drop.

Decode reads the full cache with a length mask — one (B, H, S) logits tensor,
no chunking needed (S-sharded cache + SPMD softmax handles the MQA case where
KV heads cannot split over the model axis).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.base import pdef, shard_act

Array = jnp.ndarray

NEG = -2.0e38


def attn_defs(cfg) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    out = {
        "wq": pdef((d, H * hd), ("embed", "heads"), init="scaled"),
        "wk": pdef((d, KV * hd), ("embed", "kv"), init="scaled"),
        "wv": pdef((d, KV * hd), ("embed", "kv"), init="scaled"),
        "wo": pdef((H * hd, d), ("heads", "embed"), init="scaled"),
    }
    if cfg.qkv_bias:
        out["bq"] = pdef((H * hd,), ("heads",), init="zeros")
        out["bk"] = pdef((KV * hd,), ("kv",), init="zeros")
        out["bv"] = pdef((KV * hd,), ("kv",), init="zeros")
    return out


def _project_qkv(params, x, cfg, positions):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    q = layers.rope(q, positions, cfg.rope_theta)
    k = layers.rope(k, positions, cfg.rope_theta)
    return q, k, v


# ---------------------------------------------------------------------------
# Full-sequence chunked attention (train / prefill)
# ---------------------------------------------------------------------------


def _block(qc_, kc_, vc_, mask, scale):
    """One flash block: returns (m, l, acc) contribution.

    qc_: (B, qc, KV, G, hd); kc_/vc_: (B, kc, KV, hd); mask: (qc, kc) bool.
    """
    logits = jnp.einsum(
        "bqkgd,bskd->bqkgs", qc_, kc_, preferred_element_type=jnp.float32
    ) * scale
    logits = jnp.where(mask[None, :, None, None, :], logits, NEG)
    m = logits.max(-1)  # (B, qc, KV, G)
    p = jnp.exp(logits - m[..., None])
    l = p.sum(-1)
    acc = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(vc_.dtype), vc_)
    return m, l, acc.astype(jnp.float32)


def _merge(state, m2, l2, a2):
    m1, l1, a1 = state
    m = jnp.maximum(m1, m2)
    c1 = jnp.exp(m1 - m)
    c2 = jnp.exp(m2 - m)
    return m, l1 * c1 + l2 * c2, a1 * c1[..., None] + a2 * c2[..., None]


def chunked_attention(
    q: Array,  # (B, S, H, hd)
    k: Array,  # (B, S, KV, hd)
    v: Array,
    *,
    causal: bool,
    q_chunk: int,
    kv_chunk: int,
    causal_mode: str = "blocklist",
) -> Array:
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(hd)
    qc = min(q_chunk, S)
    kc = min(kv_chunk, S)
    assert S % qc == 0 and S % kc == 0, (S, qc, kc)
    nq, nk = S // qc, S // kc

    qr = q.reshape(B, nq, qc, KV, G, hd)
    kr = k.reshape(B, nk, kc, KV, hd)
    vr = v.reshape(B, nk, kc, KV, hd)
    q_pos = jnp.arange(S).reshape(nq, qc)
    k_pos = jnp.arange(S).reshape(nk, kc)

    if not causal or causal_mode == "rect":

        def outer(qi):
            def inner(state, kj):
                mask = (
                    (k_pos[kj][None, :] <= q_pos[qi][:, None])
                    if causal
                    else jnp.ones((qc, kc), bool)
                )
                blk = _block(qr[:, qi], kr[:, kj], vr[:, kj], mask, scale)
                return _merge(state, *blk), None

            init = (
                jnp.full((B, qc, KV, G), NEG, jnp.float32),
                jnp.zeros((B, qc, KV, G), jnp.float32),
                jnp.zeros((B, qc, KV, G, hd), jnp.float32),
            )
            (m, l, acc), _ = jax.lax.scan(inner, init, jnp.arange(nk))
            return acc / jnp.maximum(l, 1e-30)[..., None]

        out = jax.lax.map(outer, jnp.arange(nq))  # (nq, B, qc, KV, G, hd)
        out = jnp.moveaxis(out, 0, 1).reshape(B, S, KV, G, hd)
        return out.reshape(B, S, H, hd).astype(q.dtype)

    # ---- blocklist: causal triangle only --------------------------------
    assert qc == kc, "blocklist schedule wants q_chunk == kv_chunk"
    pairs = np.array(
        [(qi, kj) for qi in range(nq) for kj in range(qi + 1)], np.int32
    )  # row-major: all kj of one qi are consecutive -> softmax state is local

    def step(carry, pair):
        m_all, l_all, acc_all = carry  # (nq, B, qc, KV, G[, hd])
        qi, kj = pair[0], pair[1]
        qblk = jax.lax.dynamic_index_in_dim(qr, qi, 1, keepdims=False)
        kblk = jax.lax.dynamic_index_in_dim(kr, kj, 1, keepdims=False)
        vblk = jax.lax.dynamic_index_in_dim(vr, kj, 1, keepdims=False)
        on_diag = qi == kj
        tri = jnp.tril(jnp.ones((qc, kc), bool))
        mask = jnp.where(on_diag, tri, jnp.ones((qc, kc), bool))
        m2, l2, a2 = _block(qblk, kblk, vblk, mask, scale)
        st = (
            jax.lax.dynamic_index_in_dim(m_all, qi, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(l_all, qi, 0, keepdims=False),
            jax.lax.dynamic_index_in_dim(acc_all, qi, 0, keepdims=False),
        )
        m, l, acc = _merge(st, m2, l2, a2)
        return (
            jax.lax.dynamic_update_index_in_dim(m_all, m, qi, 0),
            jax.lax.dynamic_update_index_in_dim(l_all, l, qi, 0),
            jax.lax.dynamic_update_index_in_dim(acc_all, acc, qi, 0),
        ), None

    init = (
        jnp.full((nq, B, qc, KV, G), NEG, jnp.float32),
        jnp.zeros((nq, B, qc, KV, G), jnp.float32),
        jnp.zeros((nq, B, qc, KV, G, hd), jnp.float32),
    )
    (m_all, l_all, acc_all), _ = jax.lax.scan(step, init, jnp.asarray(pairs))
    out = acc_all / jnp.maximum(l_all, 1e-30)[..., None]
    out = jnp.moveaxis(out, 0, 1).reshape(B, S, KV, G, hd)
    return out.reshape(B, S, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode with KV cache
# ---------------------------------------------------------------------------


def init_kv_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16) -> dict:
    KV, hd = cfg.n_kv_heads, cfg.hd
    shape = (batch, max_len, KV, hd)
    # Shard KV heads over the model axis when they divide; otherwise shard
    # the sequence (MQA: per-rank partial softmax, combined by SPMD psum).
    axes = ("act_batch", None, "act_model", None)
    cache = {
        "k": shard_act(jnp.zeros(shape, dtype), axes),
        "v": shard_act(jnp.zeros(shape, dtype), axes),
    }
    return cache


def decode_attention(
    params: dict,
    x: Array,  # (B, 1, d)
    cache: dict,
    length: Array,  # scalar int32 — tokens already in cache
    cfg,
) -> tuple[Array, dict]:
    B, S1, d = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KV
    positions = jnp.broadcast_to(length, (B, 1))
    q, k, v = _project_qkv(params, x, cfg, positions)

    k_cache = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, length, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, length, 0, 0))
    S = k_cache.shape[1]

    qg = q.reshape(B, KV, G, hd)
    logits = jnp.einsum(
        "bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32
    ) / np.sqrt(hd)
    valid = jnp.arange(S)[None, None, None, :] <= length
    logits = jnp.where(valid, logits, NEG)
    p = jax.nn.softmax(logits, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache)
    o = o.reshape(B, 1, H * hd)
    y = o @ params["wo"].astype(o.dtype)
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Full block entry point
# ---------------------------------------------------------------------------


def attention_block(
    params: dict,
    x: Array,  # (B, S, d)
    cfg,
    *,
    positions: Array | None = None,
    cache: dict | None = None,
    cache_length: Array | None = None,
    causal_mode: str = "blocklist",
) -> tuple[Array, dict | None]:
    """Self-attention sub-block (no residual, no norm — the caller owns those).

    Returns (output (B, S, d), updated cache or None)."""
    if cache is not None:
        return decode_attention(params, x, cache, cache_length, cfg)
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    q, k, v = _project_qkv(params, x, cfg, positions)
    y = chunked_attention(
        q,
        k,
        v,
        causal=cfg.causal,
        q_chunk=cfg.attn_q_chunk,
        kv_chunk=cfg.attn_kv_chunk,
        causal_mode=causal_mode,
    )
    y = y.reshape(B, S, cfg.n_heads * cfg.hd)
    return y @ params["wo"].astype(y.dtype), None
