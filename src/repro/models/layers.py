"""Shared neural layers: norms, RoPE, linear/MLP blocks (pure functions).

Convention: every layer is a pair (``<name>_defs(cfg) -> ParamDef tree``,
``<name>(params, x, ...) -> y``). Computation runs in ``cfg.act_dtype``
(bf16 by default) with fp32 norms/softmax — the long-reduction rule.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.base import ParamDef, pdef

Array = jnp.ndarray


def act_dt(cfg):
    return jnp.bfloat16 if cfg.act_dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_defs(d: int) -> dict:
    return {"scale": pdef((d,), (None,), init="ones")}


def rmsnorm(params: dict, x: Array, eps: float = 1e-6) -> Array:
    xf = x.astype(jnp.float32)
    var = (xf * xf).mean(-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * params["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope(x: Array, positions: Array, theta: float = 10_000.0) -> Array:
    """Rotary embedding. x: (..., S, n_heads, head_dim), positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Linear / MLP
# ---------------------------------------------------------------------------


def linear_defs(d_in: int, d_out: int, axes=("embed", "mlp"), bias=False) -> dict:
    out = {"w": pdef((d_in, d_out), axes, init="scaled")}
    if bias:
        out["b"] = pdef((d_out,), (axes[1],), init="zeros")
    return out


def linear(params: dict, x: Array) -> Array:
    y = x @ params["w"].astype(x.dtype)
    if "b" in params:
        y = y + params["b"].astype(x.dtype)
    return y


def mlp_defs(cfg, d_ff: int | None = None) -> dict:
    d, dff = cfg.d_model, d_ff or cfg.d_ff
    if cfg.mlp_kind == "swiglu":
        return {
            "gate": linear_defs(d, dff, ("embed", "mlp")),
            "up": linear_defs(d, dff, ("embed", "mlp")),
            "down": linear_defs(dff, d, ("mlp", "embed")),
        }
    return {
        "up": linear_defs(d, dff, ("embed", "mlp")),
        "down": linear_defs(dff, d, ("mlp", "embed")),
    }


def mlp(params: dict, x: Array, kind: str = "swiglu") -> Array:
    if kind == "swiglu":
        g = linear(params["gate"], x)
        u = linear(params["up"], x)
        return linear(params["down"], jax.nn.silu(g) * u)
    return linear(params["down"], jax.nn.gelu(linear(params["up"], x)))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def embed_defs(cfg) -> dict:
    out = {"tokens": pdef((cfg.vocab, cfg.d_model), ("vocab", "embed"))}
    if not cfg.tie_embeddings:
        out["unembed"] = pdef((cfg.d_model, cfg.vocab), ("embed", "vocab"), init="scaled")
    return out


def embed(params: dict, tokens: Array, cfg) -> Array:
    return params["tokens"].astype(act_dt(cfg))[tokens]


def unembed(params: dict, x: Array, cfg) -> Array:
    if cfg.tie_embeddings:
        w = params["tokens"].astype(x.dtype).T
    else:
        w = params["unembed"].astype(x.dtype)
    logits = x @ w
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits.astype(jnp.float32) / c).astype(logits.dtype)
    return logits
