"""xLSTM blocks: chunked mLSTM (matrix memory) + recurrent sLSTM.

mLSTM rides the same chunked linear-recurrence engine as Mamba2 (ssm.py):
state C = f*C + i*(k (x) v), read y = q.C / max(q.n, eps) with the
normalizer n run as an extra value column. Gates are per-head scalars.

Numerics note (DESIGN.md §2): the xLSTM paper uses exponential input gating
with a running stabilizer m; we fold the input gate multiplicatively into k
with sigmoid gating, which keeps every exponent <= 0 (the same invariant the
SSD engine relies on). The memory/retrieval structure — matrix memory,
per-head forget decay, normalizer — is preserved; only the gate
parameterization is simplified, and the sweep tests cover state-carry
exactness under it.

sLSTM has no parallel form (true nonlinear recurrence) — it is a lax.scan
over time with block-diagonal per-head recurrent weights, exactly as the
paper describes the architecture's sequential part.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.base import pdef, shard_act
from repro.models.ssm import chunked_linear_recurrence, linear_recurrence_step

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_defs(cfg) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = cfg.n_heads
    return {
        "up_gate": pdef((d, d_in), ("embed", "mlp"), init="scaled"),
        "up": pdef((d, d_in), ("embed", "mlp"), init="scaled"),
        "wq": pdef((d_in, d_in), ("mlp", "heads"), init="scaled"),
        "wk": pdef((d_in, d_in), ("mlp", "heads"), init="scaled"),
        "wv": pdef((d_in, d_in), ("mlp", "heads"), init="scaled"),
        "w_if": pdef((d, 2 * H), ("embed", None), init="scaled"),
        "b_if": pdef((2 * H,), (None,), init="zeros"),
        "norm": layers.rmsnorm_defs(d_in),
        "down": pdef((d_in, d), ("mlp", "embed"), init="scaled"),
    }


def mlstm_block(
    params: dict,
    x: Array,  # (B, S, d)
    cfg,
    *,
    state: Array | None = None,  # (B, H, dk, dv+1) matrix memory + normalizer
) -> tuple[Array, Array]:
    B, S, d = x.shape
    H = cfg.n_heads
    d_in = cfg.ssm_expand * d
    dh = d_in // H

    u = x @ params["up"].astype(x.dtype)  # (B, S, d_in)
    gate = jax.nn.silu(x @ params["up_gate"].astype(x.dtype))
    q = (u @ params["wq"].astype(x.dtype)).reshape(B, S, H, dh)
    k = (u @ params["wk"].astype(x.dtype)).reshape(B, S, H, dh) / jnp.sqrt(dh).astype(x.dtype)
    v = (u @ params["wv"].astype(x.dtype)).reshape(B, S, H, dh)

    if_pre = (x @ params["w_if"].astype(x.dtype) + params["b_if"].astype(x.dtype)).astype(jnp.float32)
    i_gate = jax.nn.sigmoid(if_pre[..., :H])  # (B, S, H)
    log_f = jax.nn.log_sigmoid(if_pre[..., H:])  # <= 0

    k_in = k.astype(jnp.float32) * i_gate[..., None]
    v_ext = jnp.concatenate(
        [v.astype(jnp.float32), jnp.ones((B, S, H, 1), jnp.float32)], axis=-1
    )

    if state is None or S > 1:
        y_ext, new_state = chunked_linear_recurrence(
            q.astype(jnp.float32), k_in, v_ext, log_f, chunk=128, state0=state
        )
    else:
        y1, new_state = linear_recurrence_step(
            state, q[:, 0].astype(jnp.float32), k_in[:, 0], v_ext[:, 0], log_f[:, 0]
        )
        y_ext = y1[:, None]

    y = y_ext[..., :dh] / jnp.maximum(jnp.abs(y_ext[..., dh:]), 1e-6)
    y = y.reshape(B, S, d_in).astype(x.dtype) * gate
    y = layers.rmsnorm(params["norm"], y)
    return y @ params["down"].astype(x.dtype), new_state


def mlstm_state_init(cfg, batch: int) -> Array:
    H = cfg.n_heads
    dh = cfg.ssm_expand * cfg.d_model // H
    return shard_act(
        jnp.zeros((batch, H, dh, dh + 1), jnp.float32),
        ("act_batch", "act_model", None, None),
    )


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_defs(cfg) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = cfg.n_heads
    dh = d_in // H
    return {
        "w_in": pdef((d, 4 * d_in), ("embed", "mlp"), init="scaled"),
        "r": pdef((H, dh, 4 * dh), ("heads", None, None), init="scaled"),
        "b": pdef((4 * d_in,), ("mlp",), init="zeros"),
        "norm": layers.rmsnorm_defs(d_in),
        "down": pdef((d_in, d), ("mlp", "embed"), init="scaled"),
    }


def slstm_block(
    params: dict,
    x: Array,  # (B, S, d)
    cfg,
    *,
    state: tuple[Array, Array] | None = None,  # (c, h) each (B, H, dh)
) -> tuple[Array, tuple[Array, Array]]:
    B, S, d = x.shape
    H = cfg.n_heads
    d_in = cfg.ssm_expand * d
    dh = d_in // H

    pre = (x @ params["w_in"].astype(x.dtype) + params["b"].astype(x.dtype)).reshape(
        B, S, H, 4 * dh
    )
    if state is None:
        state = (
            jnp.zeros((B, H, dh), jnp.float32),
            jnp.zeros((B, H, dh), jnp.float32),
        )
    r = params["r"].astype(jnp.float32)

    def step(carry, pre_t):  # pre_t: (B, H, 4dh)
        c, h = carry
        rec = jnp.einsum("bhd,hde->bhe", h, r)  # (B, H, 4dh)
        z, i, f, o = jnp.split(pre_t.astype(jnp.float32) + rec, 4, axis=-1)
        c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(z)
        h = jax.nn.sigmoid(o) * jnp.tanh(c)
        return (c, h), h

    (c, h), hs = jax.lax.scan(step, state, jnp.moveaxis(pre, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(B, S, d_in).astype(x.dtype)
    y = layers.rmsnorm(params["norm"], y)
    return y @ params["down"].astype(x.dtype), (c, h)


def slstm_state_init(cfg, batch: int) -> tuple[Array, Array]:
    H = cfg.n_heads
    dh = cfg.ssm_expand * cfg.d_model // H
    z = shard_act(jnp.zeros((batch, H, dh), jnp.float32), ("act_batch", "act_model", None))
    return (z, z)
