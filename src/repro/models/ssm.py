"""State-space / linear-recurrence blocks: Mamba2 (SSD) + generic machinery.

``chunked_linear_recurrence`` is the shared engine: it computes

    y_i = q_i . ( sum_{j<=i} exp(cum_i - cum_j) * k_j (x) v_j )

for per-head log-decays <= 0 — the SSD dual form of Mamba2 *and* (with the
input gate folded into k) the chunkwise mLSTM of xLSTM. Chunked evaluation:
intra-chunk is a masked decay-weighted attention matmul (MXU work), inter-
chunk is a tiny scan carrying the (H, dk, dv) state — O(S) time, O(chunk^2)
memory, numerically safe because every exponent is <= 0.

Decode is the O(1) recurrent step on the same state, so prefill -> decode
handoff is exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers
from repro.models.base import pdef, shard_act

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Generic chunked linear recurrence (SSD dual form)
# ---------------------------------------------------------------------------


def chunked_linear_recurrence(
    q: Array,  # (B, S, H, dk)
    k: Array,  # (B, S, H, dk)
    v: Array,  # (B, S, H, dv)
    log_decay: Array,  # (B, S, H), <= 0; step i decays state *before* adding k_i(x)v_i
    chunk: int = 128,
    state0: Array | None = None,  # (B, H, dk, dv)
) -> tuple[Array, Array]:
    """Returns (y (B, S, H, dv), final_state (B, H, dk, dv))."""
    B, S, H, dk = q.shape
    dv = v.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nC = S // Q

    qr = q.reshape(B, nC, Q, H, dk)
    kr = k.reshape(B, nC, Q, H, dk)
    vr = v.reshape(B, nC, Q, H, dv)
    ar = log_decay.reshape(B, nC, Q, H).astype(jnp.float32)
    cum = jnp.cumsum(ar, axis=2)  # (B, nC, Q, H) inclusive of own decay

    if state0 is None:
        state0 = jnp.zeros((B, H, dk, dv), jnp.float32)

    def step(state, c):
        qc = qr[:, c].astype(jnp.float32)
        kc = kr[:, c].astype(jnp.float32)
        vc = vr[:, c].astype(jnp.float32)
        cc = cum[:, c]  # (B, Q, H)
        last = cc[:, -1]  # (B, H)

        # intra-chunk: scores (B, H, Q, Q) with decay weights exp(cc_i - cc_j)
        scores = jnp.einsum("bihd,bjhd->bhij", qc, kc)
        decay = jnp.exp(cc[:, :, None, :] - cc[:, None, :, :])  # (B, i, j, H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        w = jnp.where(tri[None, :, :, None], decay, 0.0)
        y_diag = jnp.einsum("bhij,bijh,bjhv->bihv", scores, w, vc)

        # inter-chunk: read old state, then fold this chunk into it
        y_off = jnp.einsum("bihd,bhdv->bihv", qc * jnp.exp(cc)[..., None], state)
        write = jnp.exp(last[:, None, :] - cc)  # (B, Q, H) decay to chunk end
        state = state * jnp.exp(last)[:, :, None, None] + jnp.einsum(
            "bjhd,bjh,bjhv->bhdv", kc, write, vc
        )
        return state, (y_diag + y_off).astype(v.dtype)

    state, ys = jax.lax.scan(step, state0, jnp.arange(nC))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, dv)
    return y, state


def linear_recurrence_step(
    state: Array,  # (B, H, dk, dv)
    q: Array,  # (B, H, dk)
    k: Array,
    v: Array,  # (B, H, dv)
    log_decay: Array,  # (B, H)
) -> tuple[Array, Array]:
    """One decode step; state is decayed then written, matching the chunked
    form's inclusive cumsum."""
    a = jnp.exp(log_decay.astype(jnp.float32))[..., None, None]
    state = state * a + jnp.einsum("bhd,bhv->bhdv", k.astype(jnp.float32), v.astype(jnp.float32))
    y = jnp.einsum("bhd,bhdv->bhv", q.astype(jnp.float32), state)
    return y.astype(v.dtype), state


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def mamba2_defs(cfg) -> dict:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_in + 2 * N
    return {
        "in_proj": pdef((d, 2 * d_in + 2 * N + H), ("embed", "mlp"), init="scaled"),
        "conv_w": pdef((cfg.conv_width, conv_dim), (None, "mlp"), init="scaled", scale=0.5),
        "conv_b": pdef((conv_dim,), ("mlp",), init="zeros"),
        "A_log": pdef((H,), ("heads",), init="zeros"),
        "D": pdef((H,), ("heads",), init="ones"),
        "dt_bias": pdef((H,), ("heads",), init="zeros"),
        "norm": layers.rmsnorm_defs(d_in),
        "out_proj": pdef((d_in, d), ("mlp", "embed"), init="scaled"),
    }


def _split_inproj(cfg, zxbcdt: Array):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in : 2 * d_in + 2 * N]
    dt = zxbcdt[..., 2 * d_in + 2 * N :]  # (..., H)
    return z, xbc, dt, d_in, H, N


def _causal_conv(xbc: Array, w: Array, b: Array, state: Array | None):
    """Depthwise causal conv over (B, S, C). state: (B, W-1, C) history."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], W - 1, xbc.shape[-1]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    full = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        full[:, i : i + xbc.shape[1]] * w[i].astype(xbc.dtype) for i in range(W)
    ) + b.astype(xbc.dtype)
    new_state = full[:, -(W - 1) :] if W > 1 else pad
    return jax.nn.silu(out), new_state


def mamba2_block(
    params: dict,
    x: Array,  # (B, S, d)
    cfg,
    *,
    state: dict | None = None,  # {"conv": (B,W-1,C), "ssd": (B,H,N,P)}
) -> tuple[Array, dict | None]:
    """Mamba2 sub-block (no residual). Decode when S == 1 and state given."""
    B, S, d = x.shape
    zxbcdt = x @ params["in_proj"].astype(x.dtype)
    z, xbc, dt, d_in, H, N = _split_inproj(cfg, zxbcdt)
    P = cfg.ssm_head_dim

    conv_state = state["conv"] if state is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], params["conv_b"], conv_state)
    xs = xbc[..., :d_in].reshape(B, S, H, P)
    Bmat = xbc[..., d_in : d_in + N]  # (B, S, N) shared across heads (MVA)
    Cmat = xbc[..., d_in + N :]  # (B, S, N)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,S,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,) < 0
    log_decay = dt * a  # (B, S, H) <= 0
    xbar = xs.astype(jnp.float32) * dt[..., None]

    kq_k = jnp.broadcast_to(Bmat[:, :, None, :], (B, S, H, N))
    kq_q = jnp.broadcast_to(Cmat[:, :, None, :], (B, S, H, N))

    if state is None:
        y, ssd_state = chunked_linear_recurrence(
            kq_q, kq_k, xbar, log_decay, chunk=128
        )
        new_state = {"conv": new_conv, "ssd": ssd_state}
    else:
        yv, ssd_state = linear_recurrence_step(
            state["ssd"], kq_q[:, 0], kq_k[:, 0], xbar[:, 0], log_decay[:, 0]
        )
        y = yv[:, None]
        new_state = {"conv": new_conv, "ssd": ssd_state}

    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = layers.rmsnorm(params["norm"], y * jax.nn.silu(z))
    return y @ params["out_proj"].astype(x.dtype), new_state


def mamba2_state_init(cfg, batch: int) -> dict:
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    conv_dim = d_in + 2 * N
    return {
        "conv": shard_act(
            jnp.zeros((batch, cfg.conv_width - 1, conv_dim), jnp.bfloat16),
            ("act_batch", None, "act_model"),
        ),
        "ssd": shard_act(
            jnp.zeros((batch, H, N, cfg.ssm_head_dim), jnp.float32),
            ("act_batch", "act_model", None, None),
        ),
    }
