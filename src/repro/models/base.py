"""Parameter system + logical sharding for the model zoo.

No flax in this environment, so models are pure functions over nested-dict
pytrees. Each model builds a tree of ``ParamDef`` (shape + logical axes +
initializer); three interpreters consume it:

  init_params        — materialize real arrays (smoke tests, examples)
  abstract_params    — ShapeDtypeStruct tree (dry-run: zero allocation)
  make_shardings     — NamedSharding tree: logical axis names -> mesh axes
                       via LOGICAL_RULES, with divisibility fallback (a dim
                       that doesn't divide the mesh axis is replicated, never
                       mis-sharded — e.g. hubert's 504-way vocab head).

Logical axis vocabulary (MaxText-style):
  "embed"    d_model dims           -> FSDP axis ("data")   [weights]
  "mlp"      FFN hidden dims        -> TP axis ("model")
  "heads"    attention-head dims    -> TP axis ("model")
  "kv"       KV-head dims           -> TP axis ("model") when divisible
  "vocab"    vocabulary dims        -> TP axis ("model")
  "experts"  MoE expert dim         -> TP/EP axis ("model")
  "layers"   scan-stacked layer dim -> replicated (scan carries it)
  None       replicated

Activations use ``shard_act`` with its own vocabulary ("act_batch" ->
("pod", "data"), "act_model" -> "model").
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Array = jnp.ndarray
PyTree = Any

LOGICAL_RULES: dict[str, str | tuple[str, ...]] = {
    "embed": "data",
    "mlp": "model",
    "heads": "model",
    "kv": "model",
    "vocab": "model",
    "experts": "model",
    "layers": None,  # type: ignore[dict-item]
    "conv": None,  # type: ignore[dict-item]
}

# FSDP profile (hillclimb H1, EXPERIMENTS.md §Perf): small-d models waste the
# mesh on tensor parallelism — per-layer activation all-reduces dwarf their
# compute. Here the "model" axis carries BATCH instead; weights shard one dim
# over both axes (pure FSDP/ZeRO-3) and the only collectives left are the
# per-layer param all-gather + gradient reduce-scatter.
FSDP_RULES: dict[str, str | tuple[str, ...]] = {
    "embed": ("data", "model"),
    "mlp": None,  # type: ignore[dict-item]
    "heads": None,  # type: ignore[dict-item]
    "kv": None,  # type: ignore[dict-item]
    "vocab": ("data", "model"),
    "experts": ("data", "model"),
    "layers": None,  # type: ignore[dict-item]
    "conv": None,  # type: ignore[dict-item]
}

ACT_RULES: dict[str, str | tuple[str, ...]] = {
    "act_batch": ("pod", "data"),
    "act_model": "model",
    "act_seq": "data",  # sequence sharding (long-context decode)
}

FSDP_ACT_RULES: dict[str, str | tuple[str, ...]] = {
    "act_batch": ("pod", "data", "model"),
    "act_model": None,  # type: ignore[dict-item]
    "act_seq": None,  # type: ignore[dict-item]
}

# Sequence-parallel FSDP (multi-pod trains where global_batch < chip count:
# 256 examples cannot shard over 512 chips, so the model axis shards the
# SEQUENCE instead; weights stay ZeRO-3 over (data, model)).
FSDP_SP_ACT_RULES: dict[str, str | tuple[str, ...]] = {
    "act_batch": ("pod", "data"),
    "act_model": None,  # type: ignore[dict-item]
    "act_seq": "model",
}


def rules_for_profile(profile: str):
    """(param_rules, act_rules, batch_axes) per sharding profile."""
    if profile == "fsdp":
        return FSDP_RULES, FSDP_ACT_RULES, ("pod", "data", "model")
    if profile == "fsdp_sp":
        return FSDP_RULES, FSDP_SP_ACT_RULES, ("pod", "data")
    return LOGICAL_RULES, ACT_RULES, ("pod", "data")


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]  # logical axis name per dim
    init: str = "normal"  # normal | zeros | ones | scaled
    scale: float = 0.02
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def pdef(shape, axes, init="normal", scale=0.02, dtype=jnp.float32) -> ParamDef:
    return ParamDef(tuple(shape), tuple(axes), init, scale, dtype)


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def init_params(key: jax.Array, defs: PyTree, dtype=None) -> PyTree:
    leaves, treedef = jax.tree.flatten(defs, is_leaf=_is_def)
    keys = jax.random.split(key, len(leaves))

    def make(k, d: ParamDef):
        dt = dtype or d.dtype
        if d.init == "zeros":
            return jnp.zeros(d.shape, dt)
        if d.init == "ones":
            return jnp.ones(d.shape, dt)
        if d.init == "scaled":  # fan-in scaled normal
            fan_in = d.shape[0] if len(d.shape) >= 2 else 1
            return (jax.random.normal(k, d.shape) / np.sqrt(max(fan_in, 1))).astype(dt)
        return (jax.random.normal(k, d.shape) * d.scale).astype(dt)

    return jax.tree.unflatten(treedef, [make(k, d) for k, d in zip(keys, leaves)])


def abstract_params(defs: PyTree, dtype=None) -> PyTree:
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype or d.dtype), defs, is_leaf=_is_def
    )


def spec_for(d: ParamDef, mesh: Mesh, rules=None) -> P:
    """Logical axes -> PartitionSpec with divisibility fallback. At most one
    mesh axis is assigned once (first logical dim wins on conflict)."""
    rules = rules or LOGICAL_RULES
    used: set[str] = set()
    out = []
    for dim, name in zip(d.shape, d.axes):
        phys = rules.get(name) if name else None
        if phys is None:
            out.append(None)
            continue
        ax_names = (phys,) if isinstance(phys, str) else tuple(phys)
        ax_names = tuple(a for a in ax_names if a in mesh.axis_names and a not in used)
        size = int(np.prod([mesh.shape[a] for a in ax_names])) if ax_names else 1
        if ax_names and dim % size == 0:
            out.append(ax_names[0] if len(ax_names) == 1 else ax_names)
            used.update(ax_names)
        else:
            out.append(None)
    return P(*out)


def make_shardings(defs: PyTree, mesh: Mesh, rules=None) -> PyTree:
    return jax.tree.map(
        lambda d: NamedSharding(mesh, spec_for(d, mesh, rules)), defs, is_leaf=_is_def
    )


def make_pspecs(defs: PyTree, mesh: Mesh, rules=None) -> PyTree:
    return jax.tree.map(lambda d: spec_for(d, mesh, rules), defs, is_leaf=_is_def)


# ---------------------------------------------------------------------------
# Activation sharding constraints
# ---------------------------------------------------------------------------

_CURRENT_MESH: list[tuple[Mesh | None, dict]] = [(None, ACT_RULES)]


class use_mesh:
    """Context manager: makes shard_act constraints bind to this mesh (and
    optionally a profile's activation rules)."""

    def __init__(self, mesh: Mesh | None, act_rules: dict | None = None):
        self.entry = (mesh, act_rules or ACT_RULES)

    def __enter__(self):
        _CURRENT_MESH.append(self.entry)
        return self.entry[0]

    def __exit__(self, *exc):
        _CURRENT_MESH.pop()


def current_mesh() -> Mesh | None:
    return _CURRENT_MESH[-1][0]


def current_act_rules() -> dict:
    return _CURRENT_MESH[-1][1]


def shard_act(x: Array, axes: tuple[str | None, ...]) -> Array:
    """with_sharding_constraint by logical activation axes; no-op without a
    mesh (single-device smoke tests) or when a dim doesn't divide."""
    mesh = current_mesh()
    if mesh is None:
        return x
    act_rules = current_act_rules()
    used: set[str] = set()
    out = []
    for dim, name in zip(x.shape, axes):
        phys = act_rules.get(name) if name else None
        if phys is None:
            out.append(None)
            continue
        ax_names = (phys,) if isinstance(phys, str) else tuple(phys)
        ax_names = tuple(a for a in ax_names if a in mesh.axis_names and a not in used)
        size = int(np.prod([mesh.shape[a] for a in ax_names])) if ax_names else 1
        if ax_names and dim % size == 0 and dim > 0:
            out.append(ax_names if len(ax_names) > 1 else ax_names[0])
            used.update(ax_names)
        else:
            out.append(None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, P(*out)))
