"""Model assembly: every assigned architecture as one composable stack.

Families share a skeleton — embed -> scanned residual blocks -> final norm ->
unembed — and differ only in the block body:

  dense / vlm / audio   pre-norm GQA attention + (Swi)GLU MLP
  moe                   attention + capacity-dispatch MoE (optional dense L0)
  hybrid (zamba2)       Mamba2 backbone; a weight-SHARED attention+MLP block
                        is applied after every ``shared_attn_every`` layers
                        (outer scan over groups, inner scan over Mamba layers)
  ssm (xlstm)           groups of (slstm_every - 1) mLSTM + 1 sLSTM

Layers are scan-stacked (leading "layers" axis on every layer param) so an
88-layer model lowers as one rolled loop — compile time and HLO size stay
flat in depth. ``cfg.remat`` wraps the scan body in jax.checkpoint.

Three entry points, matching the assigned shape kinds:
  forward()      full-sequence logits (train / prefill)
  init_state()   decode cache pytree (KV caches / SSM states / conv states)
  decode_step()  one token in, one token out, state updated in place
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe, ssm, xlstm
from repro.models.base import ParamDef, pdef, shard_act
from repro.models.config import ArchConfig

Array = jnp.ndarray
PyTree = Any


# ---------------------------------------------------------------------------
# Param-def construction
# ---------------------------------------------------------------------------


def _stack_defs(defs: PyTree, n: int) -> PyTree:
    return jax.tree.map(
        lambda d: ParamDef((n, *d.shape), ("layers", *d.axes), d.init, d.scale, d.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def _attn_layer_defs(cfg) -> dict:
    return {
        "attn_norm": layers.rmsnorm_defs(cfg.d_model),
        "attn": attention.attn_defs(cfg),
        "mlp_norm": layers.rmsnorm_defs(cfg.d_model),
        "mlp": layers.mlp_defs(cfg),
    }


def model_defs(cfg: ArchConfig) -> dict:
    d = cfg.d_model
    defs: dict = {"embed": layers.embed_defs(cfg), "final_norm": layers.rmsnorm_defs(d)}

    if cfg.frontend == "audio_frames":
        defs["frontend_proj"] = layers.linear_defs(cfg.frontend_dim, d, ("conv", "embed"))
    if cfg.frontend == "vision_patches":
        defs["patch_proj"] = layers.linear_defs(cfg.frontend_dim, d, ("conv", "embed"))

    if cfg.family in ("dense", "vlm", "audio"):
        defs["layers"] = _stack_defs(_attn_layer_defs(cfg), cfg.n_layers)
    elif cfg.family == "moe":
        n_moe = cfg.n_layers - (1 if cfg.first_layer_dense else 0)
        moe_layer = {
            "attn_norm": layers.rmsnorm_defs(d),
            "attn": attention.attn_defs(cfg),
            "mlp_norm": layers.rmsnorm_defs(d),
            "moe": moe.moe_defs(cfg),
        }
        defs["layers"] = _stack_defs(moe_layer, n_moe)
        if cfg.first_layer_dense:
            dense_cfg_ff = cfg.d_ff or 4 * d
            defs["layer0"] = {
                "attn_norm": layers.rmsnorm_defs(d),
                "attn": attention.attn_defs(cfg),
                "mlp_norm": layers.rmsnorm_defs(d),
                "mlp": layers.mlp_defs(cfg, dense_cfg_ff),
            }
    elif cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.shared_attn_every
        per = cfg.shared_attn_every
        mamba_layer = {"norm": layers.rmsnorm_defs(d), "mamba": ssm.mamba2_defs(cfg)}
        defs["layers"] = _stack_defs(_stack_defs(mamba_layer, per), groups)
        defs["shared"] = _attn_layer_defs(cfg)  # ONE block, applied `groups` times
    elif cfg.family == "ssm":
        groups = cfg.n_layers // cfg.slstm_every
        per_m = cfg.slstm_every - 1
        m_layer = {"norm": layers.rmsnorm_defs(d), "mlstm": xlstm.mlstm_defs(cfg)}
        s_layer = {"norm": layers.rmsnorm_defs(d), "slstm": xlstm.slstm_defs(cfg)}
        defs["layers"] = _stack_defs(_stack_defs(m_layer, per_m), groups)
        defs["slstm_layers"] = _stack_defs(s_layer, groups)
    else:
        raise ValueError(f"unknown family {cfg.family!r}")
    return defs


# ---------------------------------------------------------------------------
# Block bodies
# ---------------------------------------------------------------------------


def _remat(f, cfg):
    if cfg.remat == "none":
        return f
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        return jax.checkpoint(f, policy=policy)
    return jax.checkpoint(f)


def _attn_mlp_body(lp, h, cfg, causal_mode):
    a, _ = attention.attention_block(
        lp["attn"], layers.rmsnorm(lp["attn_norm"], h), cfg, causal_mode=causal_mode
    )
    h = h + a
    h = h + layers.mlp(lp["mlp"], layers.rmsnorm(lp["mlp_norm"], h), cfg.mlp_kind)
    return shard_act(h, ("act_batch", "act_seq", None))


def _moe_body(lp, h, aux, cfg, causal_mode):
    a, _ = attention.attention_block(
        lp["attn"], layers.rmsnorm(lp["attn_norm"], h), cfg, causal_mode=causal_mode
    )
    h = h + a
    y, aux_l = moe.moe_block(lp["moe"], layers.rmsnorm(lp["mlp_norm"], h), cfg)
    return shard_act(h + y, ("act_batch", "act_seq", None)), aux + aux_l


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def embed_inputs(params: dict, batch: dict, cfg: ArchConfig) -> Array:
    """Token / frame / patch embedding -> (B, S, d) activations."""
    dt = layers.act_dt(cfg)
    if cfg.family == "audio":
        h = layers.linear(params["frontend_proj"], batch["frames"].astype(dt))
    elif cfg.family == "vlm":
        patches = layers.linear(params["patch_proj"], batch["patches"].astype(dt))
        tok = layers.embed(params["embed"], batch["tokens"], cfg)
        h = jnp.concatenate([patches, tok], axis=1)
    else:
        h = layers.embed(params["embed"], batch["tokens"], cfg)
    return shard_act(h, ("act_batch", "act_seq", None))


def forward(
    params: dict,
    batch: dict,
    cfg: ArchConfig,
    *,
    causal_mode: str = "blocklist",
    last_only: bool = False,
) -> tuple[Array, Array]:
    """Full-sequence forward. Returns (logits (B, S, vocab), aux_loss).

    ``last_only`` slices the hidden state to the final position BEFORE the
    unembed — serving prefill emits (B, 1, vocab) and the (B, S, vocab)
    logits tensor never exists (it was the peak-memory term for the
    150k-200k-vocab archs)."""
    h = embed_inputs(params, batch, cfg)
    aux = jnp.float32(0.0)

    if cfg.family in ("dense", "vlm", "audio"):
        body = _remat(
            lambda hh, lp: (_attn_mlp_body(lp, hh, cfg, causal_mode), None), cfg
        )
        h, _ = jax.lax.scan(body, h, params["layers"])
    elif cfg.family == "moe":
        if cfg.first_layer_dense:
            h = _attn_mlp_body(params["layer0"], h, cfg, causal_mode)

        def moe_step(carry, lp):
            hh, a = carry
            hh, a = _moe_body(lp, hh, a, cfg, causal_mode)
            return (hh, a), None

        body = _remat(moe_step, cfg)
        (h, aux), _ = jax.lax.scan(body, (h, aux), params["layers"])
    elif cfg.family == "hybrid":

        def group(hh, glp):
            def inner(hhh, lp):
                y, _ = ssm.mamba2_block(lp["mamba"], layers.rmsnorm(lp["norm"], hhh), cfg)
                return shard_act(hhh + y, ("act_batch", "act_seq", None)), None

            hh, _ = jax.lax.scan(_remat(inner, cfg), hh, glp)
            hh = _attn_mlp_body(params["shared"], hh, cfg, causal_mode)
            return hh, None

        h, _ = jax.lax.scan(group, h, params["layers"])
    elif cfg.family == "ssm":

        def group(hh, xs):
            glp, slp = xs

            def inner(hhh, lp):
                y, _ = xlstm.mlstm_block(lp["mlstm"], layers.rmsnorm(lp["norm"], hhh), cfg)
                return shard_act(hhh + y, ("act_batch", "act_seq", None)), None

            hh, _ = jax.lax.scan(_remat(inner, cfg), hh, glp)
            y, _ = xlstm.slstm_block(slp["slstm"], layers.rmsnorm(slp["norm"], hh), cfg)
            return shard_act(hh + y, ("act_batch", "act_seq", None)), None

        h, _ = jax.lax.scan(group, h, (params["layers"], params["slstm_layers"]))
    else:
        raise ValueError(cfg.family)

    if last_only:
        h = h[:, -1:]
    h = layers.rmsnorm(params["final_norm"], h)
    logits = layers.unembed(params["embed"], h, cfg)
    return logits, aux


# ---------------------------------------------------------------------------
# Decode: state init + one-token step
# ---------------------------------------------------------------------------


def init_state(cfg: ArchConfig, batch: int, max_len: int) -> PyTree:
    """Decode-state pytree; shapes only depend on (cfg, batch, max_len)."""
    if cfg.family in ("dense", "vlm"):
        cache = attention.init_kv_cache(cfg, batch, max_len)
        return {
            "kv": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (cfg.n_layers, *x.shape)), cache
            )
        }
    if cfg.family == "moe":
        n_moe = cfg.n_layers - (1 if cfg.first_layer_dense else 0)
        cache = attention.init_kv_cache(cfg, batch, max_len)
        out = {"kv": jax.tree.map(lambda x: jnp.broadcast_to(x, (n_moe, *x.shape)), cache)}
        if cfg.first_layer_dense:
            out["kv0"] = attention.init_kv_cache(cfg, batch, max_len)
        return out
    if cfg.family == "hybrid":
        groups = cfg.n_layers // cfg.shared_attn_every
        per = cfg.shared_attn_every
        ms = ssm.mamba2_state_init(cfg, batch)
        kv = attention.init_kv_cache(cfg, batch, max_len)
        return {
            "mamba": jax.tree.map(
                lambda x: jnp.broadcast_to(x, (groups, per, *x.shape)), ms
            ),
            "kv": jax.tree.map(lambda x: jnp.broadcast_to(x, (groups, *x.shape)), kv),
        }
    if cfg.family == "ssm":
        groups = cfg.n_layers // cfg.slstm_every
        per_m = cfg.slstm_every - 1
        m = xlstm.mlstm_state_init(cfg, batch)
        s = xlstm.slstm_state_init(cfg, batch)
        return {
            "mlstm": jnp.broadcast_to(m, (groups, per_m, *m.shape)),
            "slstm": jax.tree.map(lambda x: jnp.broadcast_to(x, (groups, *x.shape)), s),
        }
    raise ValueError(f"no decode state for family {cfg.family!r}")


def _attn_decode_body(lp, h, kv, length, cfg):
    a, kv = attention.attention_block(
        lp["attn"],
        layers.rmsnorm(lp["attn_norm"], h),
        cfg,
        cache=kv,
        cache_length=length,
    )
    h = h + a
    if "mlp" in lp:
        h = h + layers.mlp(lp["mlp"], layers.rmsnorm(lp["mlp_norm"], h), cfg.mlp_kind)
    else:
        y, _ = moe.moe_block(lp["moe"], layers.rmsnorm(lp["mlp_norm"], h), cfg)
        h = h + y
    return h, kv


def decode_step(
    params: dict, token: Array, state: PyTree, length: Array, cfg: ArchConfig
) -> tuple[Array, PyTree]:
    """One decode step. token: (B, 1) int32 (or (B, 1, frontend_dim) for
    frame inputs); length: scalar int32 tokens already cached. Returns
    (logits (B, 1, vocab), new_state)."""
    h = layers.embed(params["embed"], token, cfg) if token.ndim == 2 else token
    h = shard_act(h, ("act_batch", "act_seq", None))

    if cfg.family in ("dense", "vlm", "moe"):
        lp_stack = params["layers"]
        if cfg.family == "moe" and cfg.first_layer_dense:
            h, kv0 = _attn_decode_body(params["layer0"], h, state["kv0"], length, cfg)

        def body(h, xs):
            lp, kv = xs
            h, kv = _attn_decode_body(lp, h, kv, length, cfg)
            return h, kv

        h, new_kv = jax.lax.scan(body, h, (lp_stack, state["kv"]))
        new_state = dict(state, kv=new_kv)
        if cfg.family == "moe" and cfg.first_layer_dense:
            new_state["kv0"] = kv0
    elif cfg.family == "hybrid":

        def group(h, xs):
            glp, mstates, kv = xs

            def inner(h, xs2):
                lp, st = xs2
                y, st = ssm.mamba2_block(
                    lp["mamba"], layers.rmsnorm(lp["norm"], h), cfg, state=st
                )
                return h + y, st

            h, mstates = jax.lax.scan(inner, h, (glp, mstates))
            a, kv = attention.attention_block(
                params["shared"]["attn"],
                layers.rmsnorm(params["shared"]["attn_norm"], h),
                cfg,
                cache=kv,
                cache_length=length,
            )
            h = h + a
            h = h + layers.mlp(
                params["shared"]["mlp"],
                layers.rmsnorm(params["shared"]["mlp_norm"], h),
                cfg.mlp_kind,
            )
            return h, (mstates, kv)

        h, (new_m, new_kv) = jax.lax.scan(group, h, (params["layers"], state["mamba"], state["kv"]))
        new_state = {"mamba": new_m, "kv": new_kv}
    elif cfg.family == "ssm":

        def group(h, xs):
            glp, slp, mst, sst = xs

            def inner(h, xs2):
                lp, st = xs2
                y, st = xlstm.mlstm_block(
                    lp["mlstm"], layers.rmsnorm(lp["norm"], h), cfg, state=st
                )
                return h + y, st

            h, mst = jax.lax.scan(inner, h, (glp, mst))
            y, sst = xlstm.slstm_block(
                slp["slstm"], layers.rmsnorm(slp["norm"], h), cfg, state=sst
            )
            return h + y, (mst, sst)

        h, (new_m, new_s) = jax.lax.scan(
            group, h, (params["layers"], params["slstm_layers"], state["mlstm"], state["slstm"])
        )
        new_state = {"mlstm": new_m, "slstm": new_s}
    else:
        raise ValueError(cfg.family)

    h = layers.rmsnorm(params["final_norm"], h)
    logits = layers.unembed(params["embed"], h, cfg)
    return logits, new_state
