"""Model zoo: the 10 assigned architectures as one composable stack.

  base         — ParamDef system, logical sharding rules, shard_act
  config       — ArchConfig / ShapeConfig / skip rules
  layers       — norms, RoPE, MLP, embeddings
  attention    — chunked (flash-style) GQA + cached decode
  moe          — capacity-bounded expert dispatch (llama4 / deepseek)
  ssm          — Mamba2 (SSD) + the shared chunked linear-recurrence engine
  xlstm        — mLSTM (matrix memory) + sLSTM (recurrent scan)
  transformer  — assembly: forward / init_state / decode_step per family
"""
from repro.models import attention, base, config, layers, moe, ssm, transformer, xlstm  # noqa: F401
