"""repro: SP-Join (error-bounded sampling similarity join) as a JAX/TPU framework.

Top-level namespaces:
  repro.core     — the paper's contribution (sampling, partitioning, distributed join)
  repro.kernels  — Pallas TPU kernels for the verify hot-spot (+ jnp oracles)
  repro.models   — the 10 assigned LM architectures (dense/GQA/MoE/SSM/hybrid)
  repro.data     — deterministic sharded data pipeline w/ SP-Join dedup stage
  repro.train    — optimizer / checkpointing / train-step builders
  repro.configs  — per-architecture configs
  repro.launch   — mesh construction, multi-pod dry-run, drivers
"""

__version__ = "0.1.0"
