"""llava-next-34b [vlm]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000 [hf:llava-hf/llava-v1.6 family]. The anyres vision tower is a
STUB: input_specs() supplies (B, 2304, 1024) precomputed patch embeddings
(4 anyres tiles x 576 patches) which are projected and prepended to the
token sequence; the LM loss covers text positions.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab=64000, mlp_kind="swiglu",
    frontend="vision_patches", frontend_dim=1024, n_patches=2304,
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=256, frontend_dim=32, n_patches=8,
        attn_q_chunk=32, attn_kv_chunk=32,
    )
