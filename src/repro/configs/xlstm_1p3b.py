"""xlstm-1.3b [ssm]: 48 blocks d_model=2048 4H vocab=50304, d_ff=0 (all
projections live inside the blocks) [arXiv:2405.04517]. Ratio 7 mLSTM :
1 sLSTM (groups of 8). Matrix-memory state -> O(1) decode, runs long_500k.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, ssm_expand=2, slstm_every=8,
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        vocab=128, slstm_every=2,
    )
