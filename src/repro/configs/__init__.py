"""Architecture registry + input specs for every (arch x shape) cell.

``get(name)`` / ``get_reduced(name)`` return ArchConfig; ``input_specs``
builds the exact inputs each entry point takes — as ShapeDtypeStructs
(dry-run: zero allocation) or concrete arrays (smoke tests / examples).
"""
from __future__ import annotations

import importlib
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer
from repro.models.config import SHAPES, ArchConfig, ShapeConfig, shape_applicable

ARCH_MODULES = {
    "zamba2-2.7b": "zamba2_2p7b",
    "hubert-xlarge": "hubert_xlarge",
    "stablelm-3b": "stablelm_3b",
    "granite-34b": "granite_34b",
    "phi3-mini-3.8b": "phi3_mini_3p8b",
    "qwen1.5-0.5b": "qwen1p5_0p5b",
    "llama4-scout-17b-a16e": "llama4_scout_17b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "xlstm-1.3b": "xlstm_1p3b",
    "llava-next-34b": "llava_next_34b",
}
ARCH_NAMES = tuple(ARCH_MODULES)


def _module(name: str):
    try:
        return importlib.import_module(f"repro.configs.{ARCH_MODULES[name]}")
    except KeyError:
        raise ValueError(f"unknown arch {name!r}; have {sorted(ARCH_MODULES)}") from None


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def get_reduced(name: str) -> ArchConfig:
    return _module(name).reduced()


def _token_specs(cfg: ArchConfig, shape: ShapeConfig, abstract: bool, kind: str):
    B, S = shape.global_batch, shape.seq_len

    def arr(shp, dtype, high=None):
        if abstract:
            return jax.ShapeDtypeStruct(shp, dtype)
        if dtype == jnp.int32:
            return jnp.asarray(
                np.random.default_rng(0).integers(0, high or cfg.vocab, shp), jnp.int32
            )
        return jnp.zeros(shp, dtype)

    if cfg.family == "audio":
        batch = {"frames": arr((B, S, cfg.frontend_dim), jnp.bfloat16)}
        labels = arr((B, S), jnp.int32)
    elif cfg.family == "vlm":
        s_text = S - cfg.n_patches
        assert s_text > 0, (S, cfg.n_patches)
        batch = {
            "patches": arr((B, cfg.n_patches, cfg.frontend_dim), jnp.bfloat16),
            "tokens": arr((B, s_text), jnp.int32),
        }
        labels = arr((B, S), jnp.int32)  # full-sequence labels, patch part masked
    else:
        batch = {"tokens": arr((B, S), jnp.int32)}
        labels = arr((B, S), jnp.int32)
    if kind == "train":
        batch["labels"] = labels
    return batch


def input_specs(
    cfg: ArchConfig, shape: ShapeConfig, *, abstract: bool = True
) -> dict[str, Any]:
    """Inputs for the entry point the shape exercises.

    train/prefill -> {"batch": {...}}           (forward / train_step)
    decode        -> {"token","state","length"} (serve_step: one new token
                     against a KV/SSM state already holding seq_len tokens)
    """
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise ValueError(f"{cfg.name} x {shape.name} skipped: {why}")
    if not shape.is_decode:
        return {"batch": _token_specs(cfg, shape, abstract, shape.kind)}

    B, S = shape.global_batch, shape.seq_len
    state = jax.eval_shape(lambda: transformer.init_state(cfg, B, S))
    if not abstract:
        state = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), state)
    token = (
        jax.ShapeDtypeStruct((B, 1), jnp.int32)
        if abstract
        else jnp.zeros((B, 1), jnp.int32)
    )
    length = (
        jax.ShapeDtypeStruct((), jnp.int32) if abstract else jnp.asarray(S - 1, jnp.int32)
    )
    return {"token": token, "state": state, "length": length}


def all_cells() -> list[tuple[str, str, bool, str]]:
    """Every (arch, shape) pair with (runnable, skip_reason)."""
    out = []
    for a in ARCH_NAMES:
        cfg = get(a)
        for s in SHAPES.values():
            ok, why = shape_applicable(cfg, s)
            out.append((a, s.name, ok, why))
    return out
