"""stablelm-3b [dense]: 32L d_model=2560 32H (kv=32) d_ff=6912 vocab=50304
[hf:stabilityai/stablelm-2-1_6b family]. RoPE + SwiGLU decoder.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-3b", family="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=6912, vocab=50304, mlp_kind="swiglu",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=128, attn_q_chunk=32, attn_kv_chunk=32,
    )
