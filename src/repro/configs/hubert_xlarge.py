"""hubert-xlarge [audio]: encoder-only transformer over frame embeddings.

48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504 [arXiv:2106.07447].
The convolutional waveform frontend is a STUB: input_specs() supplies
precomputed (B, S, 512) frame features; the model projects + encodes +
classifies per frame (masked-prediction vocab of 504 clusters).
No decode shapes (encoder-only — see DESIGN.md §Arch-applicability).
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="hubert-xlarge", family="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16,
    d_ff=5120, vocab=504, causal=False, mlp_kind="gelu",
    frontend="audio_frames", frontend_dim=512,
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=64, frontend_dim=32, attn_q_chunk=32, attn_kv_chunk=32,
    )
