"""granite-34b [dense]: 88L d_model=6144 48H (MQA kv=1) d_ff=24576
vocab=49152 [arXiv:2405.04324] — GPTBigCode-style code model: multi-query
attention, GELU MLP. The single KV head cannot split over the 16-way model
axis, so the decode KV cache shards its *sequence* dim instead (partial
softmax combined by SPMD psum) — see models/attention.py.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="granite-34b", family="dense",
    n_layers=88, d_model=6144, n_heads=48, n_kv_heads=1,
    d_ff=24576, vocab=49152, mlp_kind="gelu",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128,
        vocab=128, attn_q_chunk=32, attn_kv_chunk=32,
    )
