"""zamba2-2.7b [hybrid]: Mamba2 backbone + weight-shared attention blocks.

54L d_model=2560 32H (GQA kv=32) d_ff=10240 vocab=32000, ssm_state=64
[arXiv:2411.15242; hf]. Shared attention applied every 6 Mamba2 layers
(9 applications of ONE weight-tied block, zamba2's defining trick).
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2, conv_width=4,
    shared_attn_every=6, mlp_kind="swiglu",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=128, ssm_state=8, ssm_head_dim=16, shared_attn_every=2,
        attn_q_chunk=32, attn_kv_chunk=32,
    )
