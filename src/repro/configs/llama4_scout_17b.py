"""llama4-scout-17b-a16e [moe]: 48L d_model=5120 40H (GQA kv=8) expert
d_ff=8192 vocab=202048, 16 experts top-1 + 1 shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E]. Every layer is MoE (Scout's
interleave step = 1). 40 heads do not divide the 16-way model axis; the
flattened QKV projections shard and XLA re-shards the per-head compute —
flagged in EXPERIMENTS.md roofline notes.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="llama4-scout-17b-a16e", family="moe",
    n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8, head_dim=128,
    d_ff=0, vocab=202048,
    n_experts=16, top_k=1, n_shared_experts=1, d_ff_expert=8192,
    mlp_kind="swiglu",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        vocab=256, n_experts=4, top_k=1, n_shared_experts=1, d_ff_expert=64,
        attn_q_chunk=32, attn_kv_chunk=32,
    )
