"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) vocab=102400,
fine-grained MoE: 64 routed experts top-6 + 2 shared, expert d_ff=1408,
first layer dense (d_ff=10944) [arXiv:2401.06066].
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16,
    d_ff=10944, vocab=102400,
    n_experts=64, top_k=6, n_shared_experts=2, d_ff_expert=1408,
    first_layer_dense=True, mlp_kind="swiglu",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=256, n_experts=8, top_k=2, n_shared_experts=2, d_ff_expert=32,
        attn_q_chunk=32, attn_kv_chunk=32,
    )
