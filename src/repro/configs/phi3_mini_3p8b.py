"""phi3-mini-3.8b [dense]: 32L d_model=3072 32H (kv=32) d_ff=8192
vocab=32064 [arXiv:2404.14219]. RoPE + SwiGLU + GQA.
"""
import dataclasses
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi3-mini-3.8b", family="dense",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32,
    d_ff=8192, vocab=32064, mlp_kind="swiglu",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
        vocab=128, attn_q_chunk=32, attn_kv_chunk=32,
    )
