"""Synthetic dataset generators.

Join workloads mirror the paper's four datasets *statistically* (the real
NETFLIX/SIFT/AOL/PUBMED corpora are not shippable): per-node mixtures with
controllable skew, cluster structure and dimensionality, so every paper
claim (skew hurts random sampling, Gen/Dist fix it, ...) is reproducible
and parameterized. Token streams are index-addressable: example i is a pure
function of (seed, i), which is what makes the data pipeline resumable,
elastic and straggler-replayable (launch/train.py).
"""
from __future__ import annotations

import numpy as np


def mixture(
    n: int,
    m: int,
    n_clusters: int = 4,
    spread: float = 8.0,
    scale: float = 1.0,
    skew: float = 0.0,
    seed: int = 0,
) -> np.ndarray:
    """Gaussian mixture in m dims. ``skew`` in [0, 1): 0 = even cluster
    sizes; ->1 = one cluster dominates (the data-skew regime of Fig. 2)."""
    rng = np.random.default_rng(seed)
    weights = (1.0 - skew) * np.ones(n_clusters) / n_clusters
    weights[0] += skew
    weights /= weights.sum()
    counts = rng.multinomial(n, weights)
    centers = rng.normal(scale=spread, size=(n_clusters, m))
    parts = [
        rng.normal(loc=centers[c], scale=scale, size=(counts[c], m))
        for c in range(n_clusters)
    ]
    x = np.concatenate(parts).astype(np.float32)
    rng.shuffle(x)
    return x


def rs_mixture(
    n_r: int,
    n_s: int,
    m: int,
    n_clusters: int = 4,
    spread: float = 8.0,
    scale: float = 1.0,
    skew: float = 0.0,
    shift: float = 3.0,
    seed: int = 0,
) -> tuple[np.ndarray, np.ndarray]:
    """Two-set R×S workload: R is a Gaussian mixture; S reuses R's cluster
    centers but translates each by an independent random direction of length
    ``shift``, reverses the skew ordering and perturbs the per-cluster scale —
    so R and S overlap enough to join, yet have genuinely different per-node
    distributions (the regime where pooled R∪S pivots matter). Typical use is
    asymmetric |R| ≪ |S| (the skew-sensitive case of the ``--rs`` benchmark).
    """
    rng = np.random.default_rng(seed)
    weights = (1.0 - skew) * np.ones(n_clusters) / n_clusters
    weights[0] += skew
    weights /= weights.sum()
    centers = rng.normal(scale=spread, size=(n_clusters, m))

    def draw(n, w, ctr, scl):
        counts = rng.multinomial(n, w)
        parts = [
            rng.normal(loc=ctr[c], scale=scl[c], size=(counts[c], m))
            for c in range(n_clusters)
        ]
        x = np.concatenate(parts).astype(np.float32)
        rng.shuffle(x)
        return x

    r = draw(n_r, weights, centers, np.full(n_clusters, scale))
    dirs = rng.normal(size=(n_clusters, m))
    dirs /= np.linalg.norm(dirs, axis=1, keepdims=True) + 1e-9
    s_centers = centers + shift * dirs
    s_scales = scale * rng.uniform(0.5, 2.0, size=n_clusters)
    s = draw(n_s, weights[::-1], s_centers, s_scales)
    return r, s


def heavy_tailed(n: int, m: int, alpha: float = 2.5, seed: int = 0) -> np.ndarray:
    """Pareto-tailed magnitudes (SIFT-like heavy local density variation)."""
    rng = np.random.default_rng(seed)
    r = rng.pareto(alpha, size=(n, 1)).astype(np.float32) + 1.0
    d = rng.normal(size=(n, m)).astype(np.float32)
    d /= np.linalg.norm(d, axis=1, keepdims=True) + 1e-9
    return r * d


def exponential_nodes(
    n_per_node: int, m: int, n_nodes: int, seed: int = 0
) -> list[np.ndarray]:
    """Per-node exponential data with node-specific rates — the regime where
    the paper's exponential-family fit shines (high GoF confidence)."""
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n_nodes):
        lam = rng.uniform(0.5, 3.0, size=(m,))
        out.append(rng.exponential(1.0 / lam, size=(n_per_node, m)).astype(np.float32))
    return out


def strings(n: int, vocab: str = "abcdefgh", length: tuple[int, int] = (8, 24),
            n_templates: int = 32, mutate: float = 0.15, seed: int = 0) -> list[str]:
    """Near-duplicate string corpus: templates + character mutations (the
    AOL/PubMed analogue for §6.2 string-metric support)."""
    rng = np.random.default_rng(seed)
    templates = [
        "".join(rng.choice(list(vocab), size=rng.integers(*length)))
        for _ in range(n_templates)
    ]
    out = []
    for _ in range(n):
        t = list(templates[rng.integers(n_templates)])
        for j in range(len(t)):
            if rng.uniform() < mutate:
                t[j] = vocab[rng.integers(len(vocab))]
        out.append("".join(t))
    return out


def token_example(seed: int, index: int, seq_len: int, vocab: int) -> np.ndarray:
    """Pure function (seed, index) -> token sequence; basis of the resumable
    pipeline. Markov-ish stream so the LM loss has learnable structure."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, index]))
    base = rng.integers(0, vocab, size=seq_len)
    # inject copy structure: second half repeats first half with noise
    half = seq_len // 2
    noise = rng.integers(0, vocab, size=half)
    keep = rng.uniform(size=half) < 0.8
    base[half : half + half] = np.where(keep, base[:half], noise)[: seq_len - half]
    return base.astype(np.int32)
