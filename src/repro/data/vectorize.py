"""String/set -> vector transforms (paper §6.2).

The paper's argument: metric techniques designed for vectors transfer to
strings/sets once an ordering/embedding maps them into a vector space. We
ship the standard pair:

  qgram_profile   string -> q-gram count vector; L1 distance on profiles
                  lower-bounds 2q * edit distance (the classic q-gram
                  filter), so a join at delta' = 2*q*delta is a complete
                  candidate filter for EDIT <= delta.
  minhash         set -> k-permutation MinHash signature; signature Hamming
                  distance is an unbiased estimator of Jaccard distance and
                  1 - collision_prob is itself a metric.
"""
from __future__ import annotations

import numpy as np

_P1 = np.uint64(11400714819323198485)
_P2 = np.uint64(14029467366897019727)


def _hash64(x: np.ndarray, seed: np.uint64) -> np.ndarray:
    h = x.astype(np.uint64) * _P1 + seed
    h ^= h >> np.uint64(33)
    h *= _P2
    h ^= h >> np.uint64(29)
    return h


def qgrams(s: str, q: int = 2) -> list[str]:
    padded = ("#" * (q - 1)) + s + ("#" * (q - 1))
    return [padded[i : i + q] for i in range(len(padded) - q + 1)]


def qgram_profile(strings: list[str], q: int = 2, dim: int = 64) -> np.ndarray:
    """Hashed q-gram count vectors (n, dim) float32; L1 on these is the
    q-gram distance (complete filter for edit distance)."""
    out = np.zeros((len(strings), dim), np.float32)
    for i, s in enumerate(strings):
        for g in qgrams(s, q):
            out[i, hash(g) % dim] += 1.0
    return out


def shingle_sets(strings: list[str], q: int = 3) -> list[set[int]]:
    return [set(hash(g) & 0x7FFFFFFF for g in qgrams(s, q)) for s in strings]


def minhash(sets: list[set[int]], k: int = 64, seed: int = 0) -> np.ndarray:
    """(n, k) int32 MinHash signatures; mean(sig_a != sig_b) estimates
    Jaccard distance (repro.core.distances 'jaccard_minhash')."""
    rng = np.random.default_rng(seed)
    seeds = rng.integers(1, 2**63 - 1, size=k, dtype=np.uint64)
    out = np.zeros((len(sets), k), np.int32)
    for i, s in enumerate(sets):
        if not s:
            continue
        elems = np.fromiter(s, np.uint64, len(s))
        for j in range(k):
            out[i, j] = int(_hash64(elems, seeds[j]).min() & np.uint64(0x7FFFFFFF))
    return out


def edit_distance(a: str, b: str) -> int:
    """Reference DP edit distance (tests verify the q-gram filter bound)."""
    la, lb = len(a), len(b)
    prev = list(range(lb + 1))
    for i in range(1, la + 1):
        cur = [i] + [0] * lb
        for j in range(1, lb + 1):
            cur[j] = min(
                prev[j] + 1, cur[j - 1] + 1, prev[j - 1] + (a[i - 1] != b[j - 1])
            )
        prev = cur
    return prev[lb]


def jaccard_distance(a: set, b: set) -> float:
    if not a and not b:
        return 0.0
    return 1.0 - len(a & b) / len(a | b)
