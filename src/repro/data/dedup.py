"""SP-Join-powered semantic dedup — the paper's technique as an LM data
pipeline stage (web-page dedup / entity resolution are the paper's own
motivating applications; in an LLM data pipeline the same join runs over
example embeddings).

dedup(vectors, delta) = similarity self-join -> connected components of the
pair graph (union-find) -> keep the lowest-index representative per
component. The join is SP-Join (generative sampling + learning partition by
default), so dedup inherits its scalability story; on a mesh it routes
through core.distributed.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import spjoin


@dataclasses.dataclass
class DedupResult:
    keep_mask: np.ndarray  # (n,) bool
    n_components: int
    n_duplicates: int
    pairs: np.ndarray


class _UnionFind:
    def __init__(self, n: int):
        self.parent = np.arange(n)

    def find(self, a: int) -> int:
        p = self.parent
        while p[a] != a:
            p[a] = p[p[a]]
            a = p[a]
        return a

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[max(ra, rb)] = min(ra, rb)  # keep lowest index as root


def dedup(
    vectors: np.ndarray,
    delta: float,
    metric: str = "l2",
    cfg: spjoin.JoinConfig | None = None,
) -> DedupResult:
    n = vectors.shape[0]
    cfg = cfg or spjoin.JoinConfig(
        delta=delta, metric=metric, k=min(512, max(n // 4, 16)),
        p=8, n_dims=min(8, vectors.shape[1]),
    )
    res = spjoin.join(vectors, cfg)
    uf = _UnionFind(n)
    for i, j in res.pairs:
        uf.union(int(i), int(j))
    roots = np.array([uf.find(i) for i in range(n)])
    keep = roots == np.arange(n)
    return DedupResult(
        keep_mask=keep,
        n_components=int(keep.sum()),
        n_duplicates=int(n - keep.sum()),
        pairs=res.pairs,
    )
