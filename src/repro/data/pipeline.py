"""Deterministic, shardable, resumable data pipeline.

Design rule: a batch is a PURE FUNCTION of (seed, step) — no iterator state
anywhere. That one property buys three production behaviors for free:

  resume       restart at step s reproduces exactly the batches a
               non-crashed run would have seen (checkpoint stores only s);
  elastic      a re-meshed run (different host count) computes the same
               GLOBAL batch and just shards it differently;
  straggler    a backup executor can recompute any shard of any step
               without coordination (deterministic addressing).

``host_batch`` returns only this host's slice; ``global_batch`` the full
array (single-process container uses that + jax.device_put to the mesh).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data import synthetic
from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    seed: int = 0
    seq_len: int = 1024
    global_batch: int = 8


class TokenPipeline:
    """Synthetic LM token stream (swap ``example`` for a real tokenized
    store — the addressing contract is the whole interface)."""

    def __init__(self, cfg: ArchConfig, pcfg: PipelineConfig):
        self.cfg = cfg
        self.pcfg = pcfg

    def example(self, index: int) -> np.ndarray:
        return synthetic.token_example(
            self.pcfg.seed, index, self.pcfg.seq_len + 1, self.cfg.vocab
        )

    def global_batch(self, step: int) -> dict:
        B = self.pcfg.global_batch
        start = step * B
        toks = np.stack([self.example(start + i) for i in range(B)])
        batch = {"tokens": toks[:, :-1].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        if self.cfg.family == "vlm":
            # patch stand-ins ride along; label positions for patches masked
            n_p = self.cfg.n_patches
            rngs = np.random.default_rng(np.random.SeedSequence([self.pcfg.seed, step]))
            batch = {
                "patches": rngs.normal(size=(B, n_p, self.cfg.frontend_dim)).astype(np.float32),
                "tokens": batch["tokens"][:, : self.pcfg.seq_len - n_p],
                "labels": np.concatenate(
                    [np.full((B, n_p), -1, np.int32),
                     batch["labels"][:, : self.pcfg.seq_len - n_p]], axis=1),
            }
        if self.cfg.family == "audio":
            rngs = np.random.default_rng(np.random.SeedSequence([self.pcfg.seed, step]))
            batch = {
                "frames": rngs.normal(size=(B, self.pcfg.seq_len, self.cfg.frontend_dim)).astype(np.float32),
                "labels": batch["labels"] % self.cfg.vocab,
            }
        return batch

    def host_batch(self, step: int, host_id: int, n_hosts: int) -> dict:
        g = self.global_batch(step)
        B = self.pcfg.global_batch
        assert B % n_hosts == 0
        lo = host_id * (B // n_hosts)
        hi = lo + B // n_hosts
        return {k: v[lo:hi] for k, v in g.items()}

    def device_batch(self, step: int, mesh: Mesh, batch_axes=("pod", "data")) -> dict:
        g = self.global_batch(step)
        axes = tuple(a for a in batch_axes if a in mesh.axis_names)
        sharding = NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))
        return {k: jax.device_put(jnp.asarray(v), sharding) for k, v in g.items()}
