"""Deterministic, shardable, resumable data pipeline.

Design rule: a batch is a PURE FUNCTION of (seed, step) — no iterator state
anywhere. That one property buys three production behaviors for free:

  resume       restart at step s reproduces exactly the batches a
               non-crashed run would have seen (checkpoint stores only s);
  elastic      a re-meshed run (different host count) computes the same
               GLOBAL batch and just shards it differently;
  straggler    a backup executor can recompute any shard of any step
               without coordination (deterministic addressing).

``host_batch`` returns only this host's slice; ``global_batch`` the full
array (single-process container uses that + jax.device_put to the mesh).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data import synthetic
from repro.models.config import ArchConfig


@dataclasses.dataclass(frozen=True)
class PipelineConfig:
    seed: int = 0
    seq_len: int = 1024
    global_batch: int = 8


class TokenPipeline:
    """Synthetic LM token stream (swap ``example`` for a real tokenized
    store — the addressing contract is the whole interface)."""

    def __init__(self, cfg: ArchConfig, pcfg: PipelineConfig):
        self.cfg = cfg
        self.pcfg = pcfg

    def example(self, index: int) -> np.ndarray:
        return synthetic.token_example(
            self.pcfg.seed, index, self.pcfg.seq_len + 1, self.cfg.vocab
        )

    def global_batch(self, step: int) -> dict:
        B = self.pcfg.global_batch
        start = step * B
        toks = np.stack([self.example(start + i) for i in range(B)])
        batch = {"tokens": toks[:, :-1].astype(np.int32),
                 "labels": toks[:, 1:].astype(np.int32)}
        if self.cfg.family == "vlm":
            # patch stand-ins ride along; label positions for patches masked
            n_p = self.cfg.n_patches
            rngs = np.random.default_rng(np.random.SeedSequence([self.pcfg.seed, step]))
            batch = {
                "patches": rngs.normal(size=(B, n_p, self.cfg.frontend_dim)).astype(np.float32),
                "tokens": batch["tokens"][:, : self.pcfg.seq_len - n_p],
                "labels": np.concatenate(
                    [np.full((B, n_p), -1, np.int32),
                     batch["labels"][:, : self.pcfg.seq_len - n_p]], axis=1),
            }
        if self.cfg.family == "audio":
            rngs = np.random.default_rng(np.random.SeedSequence([self.pcfg.seed, step]))
            batch = {
                "frames": rngs.normal(size=(B, self.pcfg.seq_len, self.cfg.frontend_dim)).astype(np.float32),
                "labels": batch["labels"] % self.cfg.vocab,
            }
        return batch

    def host_batch(self, step: int, host_id: int, n_hosts: int) -> dict:
        g = self.global_batch(step)
        B = self.pcfg.global_batch
        assert B % n_hosts == 0
        lo = host_id * (B // n_hosts)
        hi = lo + B // n_hosts
        return {k: v[lo:hi] for k, v in g.items()}

    def device_batch(self, step: int, mesh: Mesh, batch_axes=("pod", "data")) -> dict:
        g = self.global_batch(step)
        axes = tuple(a for a in batch_axes if a in mesh.axis_names)
        sharding = NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))
        return {k: jax.device_put(jnp.asarray(v), sharding) for k, v in g.items()}


class StreamSource:
    """Deterministic row stream feeding the incremental join layer
    (``spjoin.join_incremental`` / ``MetricIndex.insert_batch``).

    Same addressing contract as ``TokenPipeline``: row ``i`` is a PURE
    FUNCTION of ``(seed, i)`` — ``np.random.SeedSequence([seed, i])`` —
    so the GLOBAL row sequence is independent of how it is chopped into
    insertion batches. That is exactly the property the streaming
    exactness suite leans on: any batching of ``prefix(n)`` feeds the
    incremental join the same rows a from-scratch join over ``prefix(n)``
    sees, making "byte-identical pair sets under ANY batch split" a
    well-posed claim (tests/test_incremental.py).

    ``dist`` picks the per-row generator: "normal" | "uniform" |
    "clustered" (rows drawn around ``n_clusters`` fixed centers — the
    skewed arm the drift monitor is exercised on; center choice is part of
    the per-row seed, so it too is split-invariant).
    """

    def __init__(
        self,
        n_features: int,
        seed: int = 0,
        dist: str = "normal",
        n_clusters: int = 4,
        scale: float = 1.0,
    ):
        if dist not in ("normal", "uniform", "clustered"):
            raise ValueError(f"unknown stream dist {dist!r}")
        self.n_features = n_features
        self.seed = seed
        self.dist = dist
        self.scale = scale
        # Cluster centers are a function of the seed alone (row index 2**62
        # is reserved for them — far outside any realistic stream prefix).
        if dist == "clustered":
            rng = np.random.default_rng(np.random.SeedSequence([seed, 2**62]))
            self.centers = rng.normal(size=(n_clusters, n_features)).astype(
                np.float32
            ) * np.float32(3.0 * scale)
        else:
            self.centers = None

    def row(self, i: int) -> np.ndarray:
        """Row ``i`` of the global stream — pure in (seed, i)."""
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, int(i)]))
        if self.dist == "uniform":
            x = rng.uniform(-1.0, 1.0, size=self.n_features) * self.scale
        elif self.dist == "clustered":
            c = self.centers[int(rng.integers(self.centers.shape[0]))]
            x = c + rng.normal(size=self.n_features) * (0.3 * self.scale)
        else:
            x = rng.normal(size=self.n_features) * self.scale
        return x.astype(np.float32)

    def prefix(self, n: int) -> np.ndarray:
        """The first ``n`` rows as one (n, m) array — what a from-scratch
        join over the stream-so-far operates on."""
        if n == 0:
            return np.zeros((0, self.n_features), np.float32)
        return np.stack([self.row(i) for i in range(n)])

    def batch(self, start: int, size: int) -> np.ndarray:
        """Rows [start, start + size) — one insertion batch. Chopping the
        stream as batch(0, a), batch(a, b), ... reproduces prefix(a + b +
        ...) row-for-row regardless of the split points."""
        if size == 0:
            return np.zeros((0, self.n_features), np.float32)
        return np.stack([self.row(i) for i in range(start, start + size)])
