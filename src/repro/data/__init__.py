"""Data substrate: deterministic pipeline, synthetic sets, vectorizers, dedup."""
from repro.data import dedup, pipeline, synthetic, vectorize  # noqa: F401
