"""Single-host end-to-end SP-Join (reference executor).

Runs the full three-phase pipeline of Figure 1 on in-memory shards:

  sampling phase — per-"node" exponential-family fit + GoF confidence
                   (repro.core.expfam / gof), then Random / Dist / Gen pivots
  map phase      — anchor selection, space mapping, partition tree
                   (Iter / Learn), kernel assignment + whole membership
  reduce phase   — per-cell V_h × W_h verification via the streaming tiled
                   verify engine (repro.core.verify) — the same engine the
                   distributed executor routes through, with
                   backend="numpy"|"pallas"|"auto" dispatch

This executor keeps dynamic shapes (host loops over cells) — it is the
*semantic reference* the distributed static-shape executor and all benchmarks
are validated against, and it is what the paper-figure benchmarks run.

Pair de-duplication rule: a result pair (i, j), i's cell = g, j's cell = h,
is emitted by cell min(g, h) only; within one cell, both orders are present so
we keep i < j. Lemma 4 (applied symmetrically) guarantees the pair is seen by
both g and h, hence exactly once after the rule.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, distances, expfam, gof, mapping, partition, sampling
from repro.core import placement as placement_lib
from repro.core import verify as verify_lib
from repro.kernels import ops as kops

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class JoinConfig:
    delta: float
    metric: str = "l1"
    sampler: str = "generative"  # random | distribution | generative
    partitioner: str = "learning"  # iterative | learning
    k: int = 1024  # sample (pivot) count; cf. required_sample_size
    p: int = 16  # number of partitions / reducers
    n_dims: int = 8  # target-space dimensionality n
    t_cells: int = 8  # GoF cells per dimension
    n_clusters: int | None = None  # labels for Learn (default: 2p)
    anchor_method: str = "fft"  # fft | random (paper)
    tighten: bool = True  # object-MBB tightening of whole boxes
    backend: str = "auto"  # verify engine: numpy | pallas | auto
    tile_v: int = 1024  # verify engine streaming tile (V side)
    tile_w: int = 4096  # verify engine streaming tile (W side)
    prune: str = "pivot"  # pivot-filter pruning: "pivot" | "window" | "none"
    #   ("window" = host-side range/tile pruning only — the wall-clock mode;
    #   sound for true metrics; cosine resolves back to "none" — core.verify)
    emit: str = "mask"  # verify-engine emission path: "mask" | "compact"
    #   (fused on-device pair compaction; reference-only metrics resolve
    #   back to "mask" — see core.verify, *Emission paths*). Pair sets are
    #   byte-identical either way.
    map_fused: bool = True  # single-pass map kernel (kernels.ops.map_assign);
    #   metrics without a kernel fall back to the two-pass path (capability,
    #   like backend dispatch). False: always the legacy two-pass path.
    #   On/off is byte-identical on the numpy backend; on Pallas, coordinate
    #   fp low bits at box edges may differ (pair sets stay exact).
    placement: str = "lpt"  # reduce-placement plan to REPORT ("lpt" |
    #   "contiguous" — core.placement). The reference executor is single-host
    #   so the plan never changes execution here; it is computed from the
    #   same cost-model loads (sampled pivots, survival-adjusted) and the
    #   same planner as the distributed executor, so parity tests can compare
    #   the two plans and benchmarks can read predicted balance without a
    #   device mesh. Devices modeled = the n_nodes argument of join().
    seed: int = 0

    def engine_config(self) -> verify_lib.EngineConfig:
        return verify_lib.EngineConfig(
            backend=self.backend, tile_v=self.tile_v, tile_w=self.tile_w,
            prune=self.prune, emit=self.emit,
        )


@dataclasses.dataclass
class JoinResult:
    pairs: np.ndarray  # (n_pairs, 2) int64, unique; self-join: i < j both
    #   indexing data — R×S: column 0 indexes R, column 1 indexes S
    n_verifications: int  # Σ_h |V_h|·|W_h| actually computed
    cost: cost_model.PartitionCost
    node_confidences: np.ndarray
    sample_time_s: float
    map_time_s: float
    verify_time_s: float
    verify_stats: verify_lib.VerifyStats | None = None  # engine telemetry
    per_cell_verified: np.ndarray | None = None  # (p,) per-cell verification
    #   loads |V_h|·|W_h| the engine ran — the Table 3 AVER/STDEV input,
    #   same semantics as DistJoinResult.per_cell_verified
    placement_plan: placement_lib.PlacementPlan | None = None  # the reported
    #   cell→device plan (cfg.placement strategy over n_nodes devices)
    device_loads: np.ndarray | None = None  # (n_nodes,) PREDICTED loads of
    #   the plan (single host executes everything; the distributed executor
    #   reports the measured analogue)
    balance_std: float = 0.0  # std of per-device loads (predicted here;
    #   same definition as DistJoinResult.balance_std, which is measured)
    makespan_ratio: float = 1.0  # max/mean of per-device loads (predicted
    #   here, measured on DistJoinResult — one definition across executors;
    #   the plan's own makespan/lower-bound ratio is placement_plan.
    #   makespan_ratio)
    capacity_saved_bytes: int = 0  # modeled dispatch-buffer saving of the
    #   plan vs the contiguous global-max layout (cf. distributed executor)

    @property
    def n_pairs(self) -> int:
        return int(self.pairs.shape[0])


def fit_node_stats(shards: Sequence[Array], t_cells: int = 8) -> list[sampling.NodeStats]:
    """Sampling phase stages 1–2 (Alg. 1 lines 1–4) for every node."""
    out = []
    for shard in shards:
        params, res = gof.fit_best_family(jnp.asarray(shard), t=t_cells)
        out.append(
            sampling.NodeStats(
                family=params.family,
                params=params,
                confidence=float(res.confidence),
                count=int(shard.shape[0]),
            )
        )
    return out


def draw_pivots(
    key: jax.Array,
    shards: Sequence[Array],
    node_stats: list[sampling.NodeStats],
    cfg: JoinConfig,
) -> Array:
    if cfg.sampler == "random":
        allx = jnp.concatenate([jnp.asarray(s) for s in shards], axis=0)
        return sampling.random_sample(key, allx, cfg.k)
    if cfg.sampler == "distribution":
        return sampling.distribution_aware_sample(key, list(shards), node_stats, cfg.k)
    if cfg.sampler == "generative":
        if distances.get_metric(cfg.metric).discrete:
            # Equality-based metrics (raw MinHash vectors) have no continuous
            # support: a model-GENERATED pivot collides with no real
            # signature, every distance degenerates to 1.0, and the space
            # mapping collapses (caught by benchmarks — 100% verification
            # rate). The paper's own string/set story (§6.2) evaluates via
            # transformed vectors under L1 (our q-gram arm); for the MinHash
            # extension the generative arm falls back to distribution-aware
            # REAL samples. Flagged in DESIGN.md §limitations.
            return sampling.distribution_aware_sample(
                key, list(shards), node_stats, cfg.k
            )
        pivots, acc = sampling.generative_sample(key, node_stats, cfg.k)
        if float(acc) <= 0.0:
            warnings.warn(
                "gibbs chain accepted no draws (all node confidences ≈ 0); "
                "pivots fall back to raw chain draws", stacklevel=2,
            )
        return pivots
    raise ValueError(f"unknown sampler {cfg.sampler!r}")


def build_plan(
    key: jax.Array,
    pivots: Array,
    cfg: JoinConfig,
) -> tuple[partition.PartitionPlan, mapping.SpaceMap]:
    """Map phase control plane: anchors, mapping, labels, partition tree."""
    smap = mapping.select_anchors(key, pivots, cfg.n_dims, cfg.metric, cfg.anchor_method)
    pivots_mapped = np.asarray(smap(pivots))
    labels = None
    if cfg.partitioner == "learning":
        d = np.asarray(distances.pairwise(pivots, pivots, cfg.metric))
        labels = partition.single_linkage_labels(d, cfg.n_clusters or 2 * cfg.p)
    plan = partition.build_partition(
        pivots_mapped, cfg.p, cfg.delta, strategy=cfg.partitioner, labels=labels, seed=cfg.seed
    )
    return plan, smap


def _as_shards(x: Array | Sequence[Array], n_nodes: int) -> list[Array]:
    if isinstance(x, (list, tuple)):
        return [jnp.asarray(v) for v in x]
    x = jnp.asarray(x)
    if x.shape[0] == 0:
        return []
    return list(jnp.array_split(x, n_nodes))


def join(
    data: Array | Sequence[Array],
    cfg: JoinConfig,
    return_pairs: bool = True,
    n_nodes: int = 4,
    *,
    s: Array | Sequence[Array] | None = None,
) -> JoinResult:
    """Metric similarity join.

    Self-join (``s=None``): all pairs (i, j), i < j, with D(o_i, o_j) ≤ δ.

    Two-set R×S join (``s`` given): all pairs (i ∈ R, j ∈ S) with
    D(r_i, s_j) ≤ δ — ``data`` is R, ``s`` is S. Node stats are fitted on the
    union of R and S shards so pivots cover both distributions (Alg. 1 over
    every local node); V-side rows come from R's kernel cells, W-side rows
    from S's whole membership, and each cross pair is emitted exactly once
    (in R's kernel cell). Passing the same object as both ``data`` and ``s``
    (R = S aliasing) is detected and routed through the self-join path.

    ``data`` / ``s``: either the full (N, m) array (split into ``n_nodes``
    simulated local nodes) or an explicit list of per-node shards.
    """
    if s is data:
        s = None  # R = S aliasing: the canonical semantics is the self-join
    cross = s is not None
    key = jax.random.PRNGKey(cfg.seed)
    shards = _as_shards(data, n_nodes)
    allx = jnp.concatenate(shards, axis=0) if shards else jnp.asarray(data)

    s_shards: list[Array] = _as_shards(s, n_nodes) if cross else []
    s_all = (
        jnp.concatenate(s_shards, axis=0)
        if s_shards
        else jnp.zeros((0, allx.shape[1]), allx.dtype)
    )

    # ---- sampling phase -------------------------------------------------
    t0 = time.perf_counter()
    k_sample, k_anchor = jax.random.split(key)
    # R∪S: pivots must cover both distributions (empty-set shards carry no
    # distribution and are skipped — the self path keeps its exact shard list).
    fit_shards = (
        [sh for sh in shards + s_shards if sh.shape[0] > 0] if cross else shards
    )
    node_stats = fit_node_stats(fit_shards, cfg.t_cells)
    pivots = draw_pivots(k_sample, fit_shards, node_stats, cfg)
    t_sample = time.perf_counter() - t0

    # ---- map phase -------------------------------------------------------
    t0 = time.perf_counter()
    plan, smap = build_plan(k_anchor, pivots, cfg)
    # Fused single-pass map kernel (space map + assign + packed membership)
    # when the metric has one; reference-only metrics (angular,
    # jaccard_minhash) keep the two-pass jnp path — capability, not error,
    # exactly like backend dispatch. Outputs are byte-identical either way.
    fused = cfg.map_fused and kops.supports_kernel(cfg.metric)
    assign_backend = cfg.backend if fused else None
    if fused:
        # Membership is only worth computing in the first pass when the whole
        # boxes are final (no tighten, self-join) — otherwise request cells
        # only and pay for exactly one membership sweep below, same total
        # containment work as the legacy path.
        want = "both" if (not cfg.tighten and not cross) else "cells"
        x_mapped, cells, bits = kops.map_assign(
            allx, smap.anchors, plan.kernel_lo, plan.kernel_hi,
            plan.whole_lo, plan.whole_hi, cfg.metric, backend=cfg.backend,
            want=want,
        )
    else:
        x_mapped = smap(allx)
        cells = partition.assign_kernel(plan, x_mapped)
        bits = None
    if cfg.tighten:
        # Kernel-cell MBBs come from R only (V rows); Lemma 4 still covers
        # every S partner: it lies within L∞ δ of an R member of the cell.
        plan = partition.tighten(plan, x_mapped, cells)
    s_mapped = None
    if cross:
        if s_all.shape[0] == 0:
            s_mapped = jnp.zeros((0, smap.n_dims), jnp.float32)
            member = jnp.zeros((0, plan.p), bool)
        elif fused:
            # Same fused pass (and fp algorithm) as the R side — a borderline
            # S coordinate must not land on a different side of a whole-box
            # edge than R's kernel-computed MBB implies.
            s_mapped, _, s_bits = kops.map_assign(
                s_all, smap.anchors, plan.kernel_lo, plan.kernel_hi,
                plan.whole_lo, plan.whole_hi, cfg.metric, backend=cfg.backend,
                want="member",
            )
            member = kops.unpack_membership(s_bits, plan.p)
        else:
            s_mapped = smap(s_all)
            member = partition.whole_membership(plan, s_mapped)
    elif fused and not cfg.tighten:
        # The fused pass already produced membership for the final boxes.
        member = kops.unpack_membership(bits, plan.p)
    else:
        member = partition.whole_membership(plan, x_mapped, backend=assign_backend)
    t_map = time.perf_counter() - t0

    # ---- reduce phase: streaming tiled verify engine ---------------------
    # The mapped coordinates double as the verify phase's pivot filter
    # (prune="pivot"): the map phase already paid for them, the engine only
    # gathers them into tiles alongside the payload.
    t0 = time.perf_counter()
    cells_np = np.asarray(cells)
    member_np = np.asarray(member)
    stats = partition.partition_stats(cells_np, member_np)
    pairs, vstats = verify_lib.verify_pairs(
        allx, cells_np, member_np, cfg.delta, cfg.metric,
        config=cfg.engine_config(), return_pairs=return_pairs,
        data_w=s_all if cross else None,
        coords=x_mapped, coords_w=s_mapped,
    )
    t_verify = time.perf_counter() - t0

    if cross:
        cost = cost_model.rs_partition_cost(
            stats["v_sizes"], stats["w_sizes"], int(s_all.shape[0])
        )
    else:
        cost = cost_model.partition_cost(stats["v_sizes"], stats["w_sizes"])

    # ---- reduce-placement report (same cost-model loads + planner as the
    # distributed executor; single-host, so the plan is telemetry only) ----
    piv_mapped = np.asarray(smap(pivots), np.float32)
    piv_cells = np.asarray(partition.assign_kernel(plan, jnp.asarray(piv_mapped)))
    piv_member = np.asarray(partition.whole_membership(plan, jnp.asarray(piv_mapped)))
    cell_loads, _, _, _ = placement_lib.planner_inputs(
        piv_mapped, piv_cells, piv_member,
        int(allx.shape[0]), int(s_all.shape[0]) if cross else int(allx.shape[0]),
        cfg.delta, vstats.prune == "pivot",
    )
    pl = placement_lib.plan_placement(
        cell_loads, max(len(shards), 1), strategy=cfg.placement
    )
    cap_saved = placement_lib.capacity_saved_bytes(
        pl, stats["v_sizes"][None, :], stats["w_sizes"][None, :],
        placement_lib.dispatch_row_bytes(
            int(allx.shape[1]), smap.n_dims, vstats.prune == "pivot"
        ),
    )
    dev_loads = pl.device_loads

    return JoinResult(
        pairs=pairs,
        n_verifications=vstats.n_verifications,
        cost=cost,
        node_confidences=np.array([st.confidence for st in node_stats]),
        sample_time_s=t_sample,
        map_time_s=t_map,
        verify_time_s=t_verify,
        verify_stats=vstats,
        per_cell_verified=(stats["v_sizes"] * stats["w_sizes"]).astype(np.int64),
        placement_plan=pl,
        device_loads=dev_loads,
        balance_std=float(dev_loads.std()),
        makespan_ratio=float(dev_loads.max(initial=0.0) / max(dev_loads.mean(), 1e-9)),
        capacity_saved_bytes=int(cap_saved),
    )


class IncrementalJoin:
    """Streaming self-join session: feed insertion batches, accumulate the
    canonical pair set (sorted unique (i, j) int64, i < j, GLOBAL ids in
    arrival order).

    Batch 0 runs the one-time build (``index.build_index`` — the only time
    sampling / anchor selection / partitioning execute) and emits its
    self-join pairs through the index's cached artifacts; every later batch
    goes through ``MetricIndex.insert_batch`` — only the delta is mapped,
    ΔR×R_old streams against the resident V lists and ΔR×ΔR self-joins
    under the updated member MBBs. The drift monitor rides along: a re-plan
    is a static permutation (pairs unchanged), and a re-sample-worthy drift
    rebuilds with this session's own ``cfg`` (the control plane the caller
    already chose).

    Exactness contract (tests/test_incremental.py): for a fixed seed and ANY
    split of R into batches, ``pairs`` after the last insert is
    byte-identical to ``join(R, cfg).pairs`` over the concatenated rows.
    """

    def __init__(
        self,
        cfg: JoinConfig,
        *,
        n_nodes: int = 4,
        n_devices: int | None = None,
        replan_drift: float | None = None,
        resample_drift: float | None = None,
    ):
        self.cfg = cfg
        self.n_nodes = n_nodes
        self.n_devices = n_devices
        self.replan_drift = replan_drift
        self.resample_drift = resample_drift
        self.index = None  # built lazily on the first non-empty batch
        self.stats: list = []  # one StreamStats per insert() call
        self._pairs = np.zeros((0, 2), np.int64)

    @property
    def pairs(self) -> np.ndarray:
        """Accumulated canonical pair set (sorted unique, global ids)."""
        return self._pairs

    @property
    def n_rows(self) -> int:
        return 0 if self.index is None else self.index.n_rows

    def insert(self, new_rows: Array | np.ndarray):
        """Absorb one insertion batch; returns (new_pairs, StreamStats)."""
        from repro.core import index as index_lib  # deferred: import cycle

        d_np = np.asarray(new_rows, np.float32)
        if self.index is None:
            if d_np.shape[0] == 0:
                # Nothing to build from yet — stay lazy, report a no-op.
                stats = index_lib.StreamStats(action="none")
                self.stats.append(stats)
                return np.zeros((0, 2), np.int64), stats
            bcfg = self.cfg
            if int(d_np.shape[0]) < bcfg.n_dims:
                # A tiny first batch can yield fewer distinct pivots than
                # mapped dimensions (row-fallback samplers cap pivots at B).
                # Clamping n_dims is free: exactness holds under ANY
                # containment-consistent plan, and a drift re-sample later
                # rebuilds with the full config once data exists.
                bcfg = dataclasses.replace(
                    bcfg, n_dims=max(1, int(d_np.shape[0]))
                )
            self.index = index_lib.build_index(
                d_np, bcfg,
                n_nodes=max(1, min(self.n_nodes, int(d_np.shape[0]))),
                n_devices=self.n_devices,
            )
            new_pairs = self.index.self_pairs()
            stats = index_lib.StreamStats(
                n_delta=int(d_np.shape[0]), n_resident=0,
                n_total=int(d_np.shape[0]),
                n_self_pairs=int(new_pairs.shape[0]),
                n_new_pairs=int(new_pairs.shape[0]),
                action="build",
            )
        else:
            new_pairs, stats = self.index.insert_batch(
                d_np,
                replan_drift=self.replan_drift,
                resample_drift=self.resample_drift,
                rebuild_cfg=self.cfg,
            )
        if new_pairs.shape[0]:
            self._pairs = np.unique(
                np.concatenate([self._pairs, new_pairs]), axis=0
            )
        self.stats.append(stats)
        return new_pairs, stats


def join_incremental(
    batches,
    cfg: JoinConfig,
    *,
    n_nodes: int = 4,
    n_devices: int | None = None,
    replan_drift: float | None = None,
    resample_drift: float | None = None,
) -> IncrementalJoin:
    """Run the streaming layer over an iterable of insertion batches and
    return the finished session (``.pairs`` is the accumulated canonical
    set, ``.stats`` the per-batch drift/telemetry trail, ``.index`` the
    live ``MetricIndex``). Equivalent to one ``IncrementalJoin`` with every
    batch ``insert``-ed in order — the convenience entry point benchmarks
    and tests use."""
    session = IncrementalJoin(
        cfg, n_nodes=n_nodes, n_devices=n_devices,
        replan_drift=replan_drift, resample_drift=resample_drift,
    )
    for b in batches:
        session.insert(b)
    return session


def brute_force_pairs(
    data: Array, delta: float, metric: str = "l1", s: Array | None = None
) -> np.ndarray:
    """Ground-truth pair list for tests (quadratic; small inputs only).

    ``s=None``: self-join pairs (i, j), i < j. With ``s``: cross R×S pairs,
    column 0 indexing ``data`` (R), column 1 indexing ``s`` (S)."""
    if s is None:
        mask = np.asarray(distances.brute_force_join(jnp.asarray(data), delta, metric))
    else:
        mask = np.asarray(
            distances.brute_force_join(
                jnp.asarray(data), jnp.asarray(s), delta, metric
            )
        )
    i, j = np.nonzero(mask)
    return np.stack([i, j], axis=1).astype(np.int64)
