"""Partition cost model (paper §5.1, Eqs. 28/33) + capacity prediction.

The paper's cost G(A) = 𝟙ᵀ·A·Aᵀ·𝟙 counts pairwise co-residencies; rewritten
over KERNEL/WHOLE partitions (Eq. 33):

    G = Σ_h |V_h|²                      (inner verification cost)
      + Σ_h |V_h| · (|W_h| − |V_h|)     (outer verification cost)

Minimizing G under the correctness constraint A·Aᵀ ≥ B is NP-hard (Theorem 4),
hence the two heuristics in repro.core.partition.

TPU adaptation: on a static-shape machine, skew doesn't cost straggler time —
it costs *capacity padding* in the all_to_all dispatch. This module converts
sample-based partition-size estimates into the static per-cell capacity the
distributed executor compiles with, and exposes the skew/balance metrics that
EXPERIMENTS.md reports (Table 3 and Fig. 12 analogues).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class PartitionCost:
    inner: float  # Σ |V_h|²
    outer: float  # Σ |V_h|·(|W_h|−|V_h|)
    total: float  # G(A)
    max_cell: float  # max_h |V_h|·|W_h| — the "last reducer" load
    balance_std: float  # std of per-cell verification counts (Table 3 metric)
    duplication: float  # Σ|W_h| / N — shuffle volume amplification


def partition_cost(v_sizes: np.ndarray, w_sizes: np.ndarray) -> PartitionCost:
    """Evaluate Eq. 33 given per-cell |V_h| and |W_h|."""
    v = np.asarray(v_sizes, np.float64)
    w = np.asarray(w_sizes, np.float64)
    inner = float((v * v).sum())
    outer = float((v * np.maximum(w - v, 0.0)).sum())
    per_cell = v * w
    n = max(v.sum(), 1.0)
    return PartitionCost(
        inner=inner,
        outer=outer,
        total=inner + outer,
        max_cell=float(per_cell.max(initial=0.0)),
        balance_std=float(per_cell.std()),
        duplication=float(w.sum() / n),
    )


def rs_partition_cost(
    v_sizes: np.ndarray, w_sizes: np.ndarray, n_s: int
) -> PartitionCost:
    """Eq. 33 instantiated for a two-set R×S join.

    ``v_sizes[h]`` = |V_h| (R rows whose kernel cell is h), ``w_sizes[h]`` =
    |W_h| (S rows whole-member of h). Every verification crosses the sets, so
    the "inner" (same-set) term vanishes and G = Σ_h |V_h|·|W_h| is all
    outer cost. ``duplication`` is the shuffle amplification of the S side,
    Σ_h |W_h| / |S| — how many copies of each S row cross the wire.
    """
    v = np.asarray(v_sizes, np.float64)
    w = np.asarray(w_sizes, np.float64)
    per_cell = v * w
    return PartitionCost(
        inner=0.0,
        outer=float(per_cell.sum()),
        total=float(per_cell.sum()),
        max_cell=float(per_cell.max(initial=0.0)),
        balance_std=float(per_cell.std()),
        duplication=float(w.sum() / max(float(n_s), 1.0)),
    )


def lower_bound_inner(n_total: int, p: int) -> float:
    """Eq. 34: Σ|V_h|² ≥ N²/p — the even-partition floor."""
    return float(n_total) ** 2 / max(p, 1)


def estimate_from_samples(
    sample_cells: np.ndarray,
    sample_membership: np.ndarray,
    n_total: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Scale sample-based cell statistics to the full dataset.

    sample_cells: (k,) kernel cell id per sampled pivot.
    sample_membership: (k, p) whole membership of the samples.
    Returns (v_est, w_est), each (p,), in object counts.

    This is where Theorem 3 earns its keep: the marginal-CDF error ε of the
    sample bounds the error of every box-count estimate (box counts are CDF
    differences), so |V̂_h/N − V_h/N| ≤ 2nε with probability ≥ 1 − 2m·e^{−2kε²}.
    """
    k, p = sample_membership.shape
    scale = n_total / max(k, 1)
    v_est = np.bincount(sample_cells, minlength=p).astype(np.float64) * scale
    w_est = sample_membership.sum(0).astype(np.float64) * scale
    return v_est, w_est


def predicted_cell_loads(
    v_est: np.ndarray, w_est: np.ndarray, survival: float = 1.0
) -> np.ndarray:
    """Per-cell predicted verification loads — the placement planner's input.

    Eq. 33's per-cell cost |V̂_h|·|Ŵ_h| from the sample-scaled estimates of
    :func:`estimate_from_samples`, times the pivot-filter ``survival``
    fraction (:func:`estimate_survival_rate`) so the loads model the exact
    evaluations a device will actually run, not the pre-filter candidate
    area. ``core.placement.plan_placement`` turns these into the cell→device
    assignment; docs/COST_MODEL.md walks a worked example.

    ``survival`` is floored at 1e-3: a sample estimate of exactly 0 is a
    small-sample artifact (any true hit survives the bound), and a scalar
    survival only rescales the loads — flooring preserves the per-cell
    structure the planner needs instead of erasing it.
    """
    return (
        np.asarray(v_est, np.float64)
        * np.asarray(w_est, np.float64)
        * float(np.clip(survival, 1e-3, 1.0))
    )


def load_drift(predicted: np.ndarray, observed: np.ndarray) -> float:
    """Scale-free drift between the cost model's predicted per-cell loads and
    the loads actually observed: the total-variation distance
    ``0.5 · Σ_h |p̂_h − ô_h|`` of the sum-normalized load vectors, in [0, 1].

    0 means the pivot sample still describes the data (the placement plan's
    relative cell weights are right even if the absolute scale grew with
    inserts); 1 means the observed mass sits entirely in cells the sample
    predicted empty. Normalizing first is what makes append-only growth
    drift-free when the distribution is stationary: doubling every cell's
    load changes nothing. The streaming layer compares this against the
    re-plan / re-sample thresholds (``core.placement.drift_action``,
    decision table in docs/STREAMING.md).
    """
    p = np.asarray(predicted, np.float64).reshape(-1)
    o = np.asarray(observed, np.float64).reshape(-1)
    if p.shape != o.shape:
        raise ValueError(
            f"predicted and observed loads must align per cell; got "
            f"{p.shape} vs {o.shape}"
        )
    ps, os_ = p.sum(), o.sum()
    if ps <= 0 and os_ <= 0:
        return 0.0
    if ps <= 0 or os_ <= 0:
        return 1.0
    return float(0.5 * np.abs(p / ps - o / os_).sum())


def predict_capacity(
    w_est: np.ndarray,
    n_shards: int,
    slack: float = 1.25,
    quantize: int = 8,
) -> int:
    """Static per-(cell, source-shard) dispatch capacity.

    Each source shard sends at most `cap` rows to each destination cell; the
    compiled buffer is (p, n_shards, cap). We provision the max estimated
    cell load, spread over shards, times a slack factor; `quantize` rounds up
    to keep re-compilations rare across epochs. Overflow is exact-handled by
    the residual pass — slack trades padding FLOPs against residual volume.
    """
    per_shard = float(np.max(w_est, initial=1.0)) / max(n_shards, 1)
    cap = int(np.ceil(per_shard * slack))
    cap = max(cap, 1)
    return int(np.ceil(cap / quantize) * quantize)


def verification_count(
    v_sizes: np.ndarray, w_sizes: np.ndarray, survival: float = 1.0
) -> float:
    """The paper's Fig. 12 metric: total pairwise verifications performed,
    Σ_h |V_h|·|W_h| (each kernel row is checked against every whole row).

    ``survival`` makes the estimate pruning-aware: with the pivot filter
    enabled only a ``survival`` fraction of candidate pairs reaches exact
    metric evaluation (estimate it with :func:`estimate_survival_rate`), so
    the expected exact-evaluation count is G·survival. The default 1.0 is
    the unpruned paper quantity.
    """
    g = float(
        (np.asarray(v_sizes, np.float64) * np.asarray(w_sizes, np.float64)).sum()
    )
    return g * float(np.clip(survival, 0.0, 1.0))


def estimate_survival_rate(
    piv_mapped: np.ndarray,
    delta: float,
    cells: np.ndarray | None = None,
    member: np.ndarray | None = None,
    chunk: int = 256,
) -> float:
    """Sample-based estimate of the pivot-filter survival fraction.

    ``piv_mapped``: (k, n) mapped coordinates of the sampled pivots — the
    same sample that sizes the partitions. The estimate is the fraction of
    off-diagonal pivot pairs whose L∞ lower bound is ≤ δ; 1 − survival is
    the predicted pruning rate, and G·survival (see
    :func:`verification_count`) the expected exact-evaluation count. Same
    Theorem-3 reasoning as the box-count estimates: the bound is a function
    of the marginal coordinate distributions the sample approximates.

    ``cells``/``member`` (the pivots' kernel assignment and whole
    membership, as produced for :func:`estimate_from_samples`) restrict the
    estimate to CANDIDATE pairs — pivot j whole-member of pivot i's kernel
    cell, the V×W structure the verify phase actually enumerates. Without
    them the estimate averages over all pairs, which skews low: candidate
    pairs are co-partitioned, hence closer than random pairs and more likely
    to survive the bound.

    Row-chunked so the (k, k, n) broadcast never materializes (k can be the
    full pivot budget, ~10³–10⁴).
    """
    x = np.asarray(piv_mapped, np.float32)
    k = x.shape[0]
    if k < 2:
        return 1.0
    restrict = cells is not None and member is not None
    if restrict:
        cells = np.asarray(cells)
        member = np.asarray(member, bool)
    surviving = 0
    total = 0
    for i0 in range(0, k, chunk):
        xi = x[i0 : i0 + chunk]
        c = xi.shape[0]
        bound = np.abs(xi[:, None, :] - x[None, :, :]).max(-1)  # (c, k)
        if restrict:
            cand = member[:, cells[i0 : i0 + c]].T  # (c, k) — V×W structure
        else:
            cand = np.ones_like(bound, bool)
        cand[np.arange(c), i0 + np.arange(c)] = False  # drop the diagonal
        surviving += int((cand & (bound <= delta)).sum())
        total += int(cand.sum())
    if total == 0:
        return 1.0
    return float(surviving / total)
