"""Core SP-Join algorithms (paper: Wu et al., 2019).

Modules map 1:1 onto the paper's sections:
  distances    — Def. 1/2 metric-space distances
  expfam       — §3.3 exponential-family MLE (Lemma 1)
  gof          — §3.4 chi-square goodness-of-fit confidence (Lemma 2, Thm 1, Eq. 10)
  sampling     — §4 distribution-aware (Alg. 2) + generative Gibbs (Alg. 3/4),
                 Eq. 11 allocation, Thm 2/3 bounds
  mapping      — §5.2 space mapping via anchor pivots (Lemma 4)
  partition    — §5.2 iterative (Alg. 5) + §5.3 learning-based (Alg. 6) partitioning
  cost_model   — §5.1 cost model G(A) (Eq. 28/33) and capacity prediction
  placement    — §5.1 cost model as placement guideline: skew-aware
                 cell→device planner (LPT + heavy-cell splitting)
  spjoin       — single-host end-to-end reference executor
  distributed  — shard_map multi-device 3-phase join (TPU-native adaptation)
  baselines    — ball-partition (MRSimJoin-like) + KPM-like baselines
"""
