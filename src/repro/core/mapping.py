"""Space mapping: metric space → ℝⁿ via anchor pivots (paper §5.2).

A set of n *dimensional pivots* A = {a_1..a_n} maps an object o to

    oⁿ = ( D(a_1, o), D(a_2, o), ..., D(a_n, o) )

Triangle inequality gives |oⁿ_x[i] − oⁿ_y[i]| ≤ D(o_x, o_y) for every i
(each coordinate is 1-Lipschitz), which is exactly what Lemma 4 needs: a pair
within δ in the *origin* space lands within an L∞ ball of radius δ in the
*target* space, so δ-expanded boxes are a correct (complete) filter.

Anchor selection: the paper samples A randomly from the pivots; we default to
a farthest-first traversal (greedy k-center) over the pivots, which spreads
anchors and strictly improves the filter's discrimination (beyond-paper
optimization, flagged in EXPERIMENTS.md §Perf); ``method="random"`` recovers
the paper's choice.

The map itself is the first compute hot-spot of the map phase — a (N × n)
pairwise-distance evaluation — and routes through the same Pallas kernel as
the verify phase on TPU.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class SpaceMap:
    """Frozen mapping: anchors (n, m) + metric name."""

    anchors: Array
    metric: str = "l1"

    @property
    def n_dims(self) -> int:
        return self.anchors.shape[0]

    def __call__(self, x: Array) -> Array:
        """(N, m) objects → (N, n) target-space coordinates."""
        return distances.pairwise(x, self.anchors, self.metric)


def select_anchors(
    key: jax.Array,
    pivots: Array,
    n: int,
    metric: str = "l1",
    method: str = "fft",
) -> SpaceMap:
    """Choose n anchors from the sampled pivots.

    method="fft"    — farthest-first traversal (greedy k-center, default)
    method="random" — uniform choice (the paper's A ⊂ S)

    Duplicate pivots (common for generative pivots on near-discrete data)
    cannot trap the traversal: while any unchosen row at positive distance
    from the chosen set remains, argmax never lands on a zero-distance twin
    of a chosen anchor. "Distinct" is counted in METRIC space, not by value:
    rows at distance 0 under a pseudo-metric (e.g. scaled copies under
    angular) collapse mapped dimensions just like value repeats do. Only
    when fewer than n metric-distinct rows exist does the traversal run dry
    — the residual anchors then fall back to method="random" fill over the
    pivots (every leftover row is a zero-distance twin of a chosen anchor
    anyway), instead of silently collapsing onto copies of row 0.
    """
    k = pivots.shape[0]
    if n > k:
        raise ValueError(f"need n={n} anchors from only k={k} pivots")
    if method == "random":
        idx = jax.random.choice(key, k, shape=(n,), replace=False)
        return SpaceMap(pivots[idx], metric)
    if method != "fft":
        raise ValueError(f"unknown anchor method {method!r}")

    d = distances.pairwise(pivots, pivots, metric)  # (k, k)
    # Metric-distinct count: a row is a twin if some earlier row sits at
    # ~zero distance (value duplicates give exactly 0; pseudo-metric
    # collisions give 0 up to fp noise — arccos is ill-conditioned near 1,
    # hence the absolute tolerance). Zero-distance is transitive under the
    # triangle inequality, so "no earlier twin" counts equivalence classes.
    d_np = np.asarray(d)
    twin = np.tril(d_np <= 1e-4, -1).any(1)
    piv_np = np.asarray(pivots)
    _, first, inv = np.unique(piv_np, axis=0, return_index=True, return_inverse=True)
    twin |= first[inv] < np.arange(k)  # value repeats (exact, any metric)
    n_distinct = int(k - twin.sum())
    n_fft = min(n, n_distinct)
    first = jax.random.randint(key, (), 0, k)

    def body(carry, _):
        chosen_mask, min_dist = carry
        # Next anchor: farthest pivot from the chosen set.
        nxt = jnp.argmax(jnp.where(chosen_mask, -jnp.inf, min_dist))
        chosen_mask = chosen_mask.at[nxt].set(True)
        min_dist = jnp.minimum(min_dist, d[nxt])
        return (chosen_mask, min_dist), nxt

    mask0 = jnp.zeros((k,), bool).at[first].set(True)
    (_, _), rest = jax.lax.scan(body, (mask0, d[first]), None, length=n_fft - 1)
    idx = jnp.concatenate([first[None], rest])
    if n_fft < n:
        fill = jax.random.choice(
            jax.random.fold_in(key, 1), k, shape=(n - n_fft,), replace=False
        )
        idx = jnp.concatenate([idx, fill])
    return SpaceMap(pivots[idx], metric)


def map_shards(space_map: SpaceMap, shards: list[Array]) -> list[Array]:
    """Map a list of host shards (reference executor convenience)."""
    return [space_map(s) for s in shards]


def as_numpy(space_map: SpaceMap) -> "SpaceMap":
    return SpaceMap(np.asarray(space_map.anchors), space_map.metric)
