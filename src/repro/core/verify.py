"""Streaming tiled verify engine — the shared reduce phase of SP-Join.

The reduce phase (paper §5) checks every kernel-partition row V_h against
every whole-partition row W_h: Σ_h |V_h|·|W_h| distance evaluations. This
module is the ONE implementation of that stage; both executors route
through it:

  * ``spjoin.join``            calls :func:`verify_pairs` (host-streamed tiles)
  * ``distributed.stage_verify`` calls :func:`verify_tile` / :func:`apply_dedup`
                               inside its shard_map trace (static buffers)

so the reference and distributed paths cannot silently diverge on verify
semantics (padding validity + the min-cell de-dup rule live here, once).

Streaming + bucketing (the TPU/XLA adaptation of DIMS-style tile-scheduled
verification):

  * Each cell's |V_h| × |W_h| rectangle is cut into fixed-capacity tiles of
    at most ``tile_v × tile_w`` — peak working set is O(tile), never
    O(|V_h|·|W_h|), so skewed cells stream instead of blowing up memory.
  * Tiles are padded up to a small set of static *bucket* shapes (quarter-
    power-of-two quantized per axis), so XLA compiles O(buckets) executables
    instead of O(cells) — the classic static-shape trade: a bounded padding
    overhead (reported as ``occupancy``) buys compile-cache hits.
  * The distance + ``<= delta`` threshold is one fused jitted call per tile
    (Pallas ``pairdist_mask`` or the jnp oracle, per ``backend``); mask →
    global-pair-index extraction happens per tile on the host, with the
    min-cell de-dup rule already applied inside the compiled mask.

De-dup rule (same statement as the seed executor): a hit (i, j) with
cell(i) = g, cell(j) = h is emitted by cell min(g, h) only; within one cell
both orders are present so we keep id_i < id_j. Lemma 4 guarantees each
qualifying pair is seen by both cells, hence exactly once after the rule.

Two-set R×S mode (``cross=True`` / ``data_w`` given): V rows come from R's
kernel cells, W rows from S's whole membership. Each R row lives in exactly
one kernel cell and Lemma 4 puts every δ-neighbour s ∈ S inside that cell's
whole box, so "emit in R's kernel cell only" already yields each cross pair
exactly once — the min-cell + id ordering rule degenerates to plain padding
validity, and emitted pairs are (i ∈ R, j ∈ S), never reordered.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances
from repro.kernels import ops as kops
from repro.kernels import ref

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs for the streaming engine.

    ``backend``: "numpy" | "pallas" | "auto" (see ``kernels.ops``). Metrics
    without a Pallas kernel (angular, jaccard_minhash) always take the jnp
    path regardless — the engine treats the kernel metric set as a backend
    capability, not an error.
    ``tile_v`` / ``tile_w``: streaming tile capacity (rows per side). Peak
    per-tile footprint ≈ tile_v·tile_w bytes of mask + gathered rows.
    ``min_bucket``: smallest padded tile side; tiles below it still pad up.
    """

    backend: str = "auto"
    tile_v: int = 1024
    tile_w: int = 4096
    min_bucket: int = 8


@dataclasses.dataclass
class VerifyStats:
    """What the engine actually did — fed to benchmarks and Table-3 metrics."""

    n_verifications: int = 0  # Σ_h |V_h|·|W_h| (valid pair area)
    n_padded: int = 0  # Σ padded tile area actually dispatched
    n_tiles: int = 0
    n_cells: int = 0  # non-empty cells
    n_hits: int = 0  # emitted (de-duplicated) hits
    bucket_shapes: set = dataclasses.field(default_factory=set)

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_shapes)

    @property
    def occupancy(self) -> float:
        """Valid / padded verification ratio — 1.0 means zero padding waste."""
        return self.n_verifications / max(self.n_padded, 1)


# ---------------------------------------------------------------------------
# Shared verify semantics (used verbatim by the distributed executor)
# ---------------------------------------------------------------------------


def pair_validity(vids: Array, wids: Array) -> Array:
    """(a, b) bool — True where both sides are real rows (padding id = -1)."""
    return (vids[:, None] >= 0) & (wids[None, :] >= 0)


def apply_dedup(
    hits: Array, vids: Array, wids: Array, wcells: Array, cell_id, cross: bool = False
) -> Array:
    """Mask a raw hit matrix down to pairs this cell should emit.

    Self-join (``cross=False``): ``wcells`` is the *kernel* cell of each W
    row; ``cell_id`` the cell being verified (V rows' own cell). Min-cell
    rule: emit iff the W row's cell is greater than this cell, or equal with
    id_v < id_w.

    R×S (``cross=True``): V and W rows index different sets, so no symmetric
    duplicate exists — every valid hit is emitted (each R row has exactly one
    kernel cell, hence each cross pair is verified exactly once).
    """
    if cross:
        return hits & pair_validity(vids, wids)
    emit = (wcells[None, :] > cell_id) | (
        (wcells[None, :] == cell_id) & (vids[:, None] < wids[None, :])
    )
    return hits & pair_validity(vids, wids) & emit


def verify_tile(
    xv: Array,
    xw: Array,
    vids: Array,
    wids: Array,
    wcells: Array,
    cell_id,
    *,
    delta: float,
    metric: str,
    backend: str,
    cross: bool = False,
) -> Array:
    """One tile's fused verify: distances, threshold, validity, de-dup.

    jit-safe; the streaming engine wraps it in its own jit, the distributed
    stage calls it inside shard_map. ``backend`` must already be concrete
    ("numpy" | "pallas" — resolve with :func:`resolve_engine_backend`).
    ``cross=True`` switches to R×S semantics (validity only, no min-cell).
    """
    if backend == "pallas":
        hits = kops.pairdist_mask(xv, xw, delta, metric, use_kernel=True)
    elif metric in ref.METRICS:
        hits = ref.pairdist_mask(xv, xw, delta, metric)
    else:
        # Metrics only the reference module knows (angular, jaccard_minhash).
        hits = distances.pairwise(xv, xw, metric) <= delta
    return apply_dedup(hits, vids, wids, wcells, cell_id, cross=cross)


def resolve_engine_backend(backend: str, metric: str) -> str:
    """Engine-level backend resolution: kernel-less metrics fall back to the
    jnp path even under an explicit "pallas" request (capability, not error)."""
    if not kops.supports_kernel(metric):
        return "numpy"
    return kops.resolve_backend(backend, metric)


_tile_verify = jax.jit(
    verify_tile, static_argnames=("delta", "metric", "backend", "cross")
)


# ---------------------------------------------------------------------------
# Capacity bucketing
# ---------------------------------------------------------------------------


def bucket_size(n: int, cap: int, floor: int = 8) -> int:
    """Quantize a tile side to a static bucket capacity.

    Quarter-power-of-two steps: within each octave [2^k, 2^(k+1)) sizes round
    up to a multiple of 2^k / 4, giving ≤ 33% padding per axis with at most 4
    shapes per octave — small enough that XLA's compile cache covers every
    tile after a handful of traces.
    """
    n = max(int(n), 1)
    if n >= cap:
        return cap
    octave = 1 << max(n - 1, 0).bit_length()  # smallest pow2 >= n
    quantum = max(octave // 4, floor)
    return min(cap, -(-n // quantum) * quantum)


def _pad_gather(
    data: np.ndarray, idx: np.ndarray, cap: int
) -> tuple[np.ndarray, np.ndarray]:
    """Gather rows ``idx`` of ``data`` into a (cap, m) buffer; ids pad = -1."""
    a = idx.size
    rows = np.zeros((cap, data.shape[1]), data.dtype)
    rows[:a] = data[idx]
    ids = np.full((cap,), -1, np.int64)
    ids[:a] = idx
    return rows, ids


# ---------------------------------------------------------------------------
# The streaming engine
# ---------------------------------------------------------------------------


def verify_cell_lists(
    data: Array | np.ndarray,
    cells_of: np.ndarray,
    v_lists: Sequence[np.ndarray],
    w_lists: Sequence[np.ndarray],
    delta: float,
    metric: str,
    *,
    config: EngineConfig = EngineConfig(),
    return_pairs: bool = True,
    data_w: Array | np.ndarray | None = None,
) -> tuple[np.ndarray, VerifyStats]:
    """Run the full reduce phase over explicit per-cell index sets.

    ``data``: (N, m) objects; ``cells_of``: (N,) kernel cell per object;
    ``v_lists[h]`` / ``w_lists[h]``: global row indices of V_h / W_h.
    Returns (pairs, stats) with pairs (n_pairs, 2) int64, i < j, unique.

    Two-set mode: when ``data_w`` is given, ``w_lists`` index into ``data_w``
    (the S side) while ``v_lists``/``cells_of`` index ``data`` (the R side);
    pairs come back as (i ∈ R, j ∈ S) — not reordered, unique by
    construction (each R row sits in exactly one kernel cell).
    """
    data_np = np.asarray(data, np.float32)
    cells_np = np.asarray(cells_of)
    cross = data_w is not None
    data_w_np = np.asarray(data_w, np.float32) if cross else data_np
    backend = resolve_engine_backend(config.backend, metric)
    stats = VerifyStats()
    chunks: list[np.ndarray] = []

    for h, (v_idx, w_idx) in enumerate(zip(v_lists, w_lists)):
        v_idx = np.asarray(v_idx)
        w_idx = np.asarray(w_idx)
        if v_idx.size == 0 or w_idx.size == 0:
            continue
        stats.n_cells += 1
        stats.n_verifications += int(v_idx.size) * int(w_idx.size)
        # W tiles are prepared once per cell (not per V tile): the copies are
        # O(|W_h|·m) — linear in cell size, like the input rows themselves —
        # while only the pair product is streamed tile-by-tile.
        w_tiles = []
        for w0 in range(0, w_idx.size, config.tile_w):
            wt = w_idx[w0 : w0 + config.tile_w]
            cap_w = bucket_size(wt.size, config.tile_w, config.min_bucket)
            xw, wids = _pad_gather(data_w_np, wt, cap_w)
            wc = np.full((cap_w,), -1, np.int64)
            if not cross:  # W kernel cells only exist / matter for self-join
                wc[: wt.size] = cells_np[wt]
            w_tiles.append((wt, cap_w, xw, wids, wc))
        for v0 in range(0, v_idx.size, config.tile_v):
            vt = v_idx[v0 : v0 + config.tile_v]
            cap_v = bucket_size(vt.size, config.tile_v, config.min_bucket)
            xv, vids = _pad_gather(data_np, vt, cap_v)
            for wt, cap_w, xw, wids, wc in w_tiles:
                stats.n_tiles += 1
                stats.n_padded += cap_v * cap_w
                stats.bucket_shapes.add((cap_v, cap_w))
                mask = np.asarray(
                    _tile_verify(
                        xv, xw, vids, wids, wc, h,
                        delta=float(delta), metric=metric, backend=backend,
                        cross=cross,
                    )
                )
                if not mask.any():
                    continue
                vi, wi = np.nonzero(mask)
                stats.n_hits += vi.size
                if return_pairs:
                    chunks.append(np.stack([vt[vi], wt[wi]], axis=1))

    if chunks:
        # Each pair is emitted once (min-cell rule / unique kernel cell);
        # sort+unique is kept as a cheap invariant matching the seed
        # executor. Cross pairs index different sets, so no column sort.
        pairs = np.concatenate(chunks)
        if not cross:
            pairs = np.sort(pairs, axis=1)
        pairs = np.unique(pairs, axis=0)
    else:
        pairs = np.zeros((0, 2), np.int64)
    return pairs.astype(np.int64), stats


def verify_pairs(
    data: Array | np.ndarray,
    cells: np.ndarray,
    member: np.ndarray,
    delta: float,
    metric: str,
    *,
    config: EngineConfig = EngineConfig(),
    return_pairs: bool = True,
    data_w: Array | np.ndarray | None = None,
) -> tuple[np.ndarray, VerifyStats]:
    """Reduce phase from a kernel-cell assignment + whole-membership matrix.

    Self-join: ``cells``: (N,) int cell id of ``data``; ``member``: (N, p)
    bool whole membership of the same rows.

    R×S: ``data``/``cells`` describe R (the V side); ``data_w`` is S and
    ``member`` is then S's whole membership (|S|, p) — V_h comes from R's
    kernel cells, W_h from S's whole membership.

    Derives the per-cell index sets and streams them through
    :func:`verify_cell_lists`.
    """
    cells_np = np.asarray(cells)
    member_np = np.asarray(member)
    p = member_np.shape[1]
    order = np.argsort(cells_np, kind="stable")
    bounds = np.searchsorted(cells_np[order], np.arange(p + 1))
    v_lists = [order[bounds[h] : bounds[h + 1]] for h in range(p)]
    w_lists = [np.flatnonzero(member_np[:, h]) for h in range(p)]
    return verify_cell_lists(
        data, cells_np, v_lists, w_lists, delta, metric,
        config=config, return_pairs=return_pairs, data_w=data_w,
    )


# ---------------------------------------------------------------------------
# The seed's dense per-cell loop — kept as the benchmark baseline / oracle
# ---------------------------------------------------------------------------


def reference_verify(
    data: Array | np.ndarray,
    cells: np.ndarray,
    member: np.ndarray,
    delta: float,
    metric: str,
    *,
    return_pairs: bool = True,
) -> tuple[np.ndarray, int]:
    """The pre-engine reduce loop: one dense eager pairwise matrix per cell.

    O(|V_h|·|W_h|·m) intermediates per cell, no tiling, no fusion. Retained
    verbatim so benchmarks can report engine speedup against the seed path
    and tests can cross-check semantics. Returns (pairs, n_verifications).
    """
    allx = jnp.asarray(data)
    cells_np = np.asarray(cells)
    member_np = np.asarray(member)
    metric_fn = distances.get_metric(metric)
    n_verif = 0
    chunks: list[np.ndarray] = []
    for h in range(member_np.shape[1]):
        v_idx = np.flatnonzero(cells_np == h)
        w_idx = np.flatnonzero(member_np[:, h])
        if v_idx.size == 0 or w_idx.size == 0:
            continue
        n_verif += int(v_idx.size) * int(w_idx.size)
        d = np.asarray(metric_fn.pairwise(allx[v_idx], allx[w_idx]))
        hit_v, hit_w = np.nonzero(d <= delta)
        gi = v_idx[hit_v]
        gj = w_idx[hit_w]
        cj = cells_np[gj]
        keep = ((cj == h) & (gi < gj)) | (cj > h)
        if return_pairs and keep.any():
            chunks.append(np.stack([gi[keep], gj[keep]], axis=1))
    if chunks:
        pairs = np.unique(np.sort(np.concatenate(chunks), axis=1), axis=0)
    else:
        pairs = np.zeros((0, 2), np.int64)
    return pairs.astype(np.int64), n_verif
