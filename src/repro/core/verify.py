"""Streaming tiled verify engine — the shared reduce phase of SP-Join.

The reduce phase (paper §5) checks every kernel-partition row V_h against
every whole-partition row W_h: Σ_h |V_h|·|W_h| distance evaluations. This
module is the ONE implementation of that stage; both executors route
through it:

  * ``spjoin.join``            calls :func:`verify_pairs` (host-streamed tiles)
  * ``distributed.stage_verify`` calls :func:`verify_tile` / :func:`apply_dedup`
                               inside its shard_map trace (static buffers)

so the reference and distributed paths cannot silently diverge on verify
semantics (padding validity + the min-cell de-dup rule live here, once).

Streaming + bucketing (the TPU/XLA adaptation of DIMS-style tile-scheduled
verification):

  * Each cell's |V_h| × |W_h| rectangle is cut into fixed-capacity tiles of
    at most ``tile_v × tile_w`` — peak working set is O(tile), never
    O(|V_h|·|W_h|), so skewed cells stream instead of blowing up memory.
  * Tiles are padded up to a small set of static *bucket* shapes (quarter-
    power-of-two quantized per axis), so XLA compiles O(buckets) executables
    instead of O(cells) — the classic static-shape trade: a bounded padding
    overhead (reported as ``occupancy``) buys compile-cache hits.
  * The distance + ``<= delta`` threshold is one fused jitted call per tile
    (Pallas ``pairdist_mask`` or the jnp oracle, per ``backend``); mask →
    global-pair-index extraction happens per tile on the host, with the
    min-cell de-dup rule already applied inside the compiled mask.

De-dup rule (same statement as the seed executor): a hit (i, j) with
cell(i) = g, cell(j) = h is emitted by cell min(g, h) only; within one cell
both orders are present so we keep id_i < id_j. Lemma 4 guarantees each
qualifying pair is seen by both cells, hence exactly once after the rule.

Two-set R×S mode (``cross=True`` / ``data_w`` given): V rows come from R's
kernel cells, W rows from S's whole membership. Each R row lives in exactly
one kernel cell and Lemma 4 puts every δ-neighbour s ∈ S inside that cell's
whole box, so "emit in R's kernel cell only" already yields each cross pair
exactly once — the min-cell + id ordering rule degenerates to plain padding
validity, and emitted pairs are (i ∈ R, j ∈ S), never reordered.

Pivot-filter pruning (``prune="pivot"`` — the DIMS-style triangle-inequality
candidate filter, run BEFORE any exact metric evaluation):

  * Each object's mapped coordinates (its distances to the shared anchors,
    produced once by ``core.mapping``) are threaded into the tiles alongside
    the payload rows. Every coordinate is 1-Lipschitz, so
    ``max_p |d(v,p) − d(w,p)|`` is a lower bound on D(v, w): a pair whose
    bound exceeds δ cannot be a hit and skips exact evaluation.
  * The bound is evaluated against a slightly slackened threshold
    (``ref.prune_delta`` — an fp guard band), which makes the filter sound
    in fp32 as well: fixed-seed pair sets are BYTE-IDENTICAL between
    ``prune="pivot"`` and ``prune="none"``. Pruning is a pure optimization,
    never a semantics change.
  * The streaming engine skips a tile's exact-distance call outright when
    every pair in it is pruned (``VerifyStats.n_tiles_pruned``); surviving
    tiles run the fused filter+pairdist kernel, whose Pallas path likewise
    skips the MXU/VPU accumulation for all-pruned blocks.
  * Capability, not error: metrics without the triangle inequality (cosine,
    dot) silently resolve to ``prune="none"`` — same treatment as backends
    without a kernel.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances
from repro.kernels import ops as kops
from repro.kernels import ref

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs for the streaming engine.

    ``backend``: "numpy" | "pallas" | "auto" (see ``kernels.ops``). Metrics
    without a Pallas kernel (angular, jaccard_minhash) always take the jnp
    path regardless — the engine treats the kernel metric set as a backend
    capability, not an error.
    ``tile_v`` / ``tile_w``: streaming tile capacity (rows per side). Peak
    per-tile footprint ≈ tile_v·tile_w bytes of mask + gathered rows.
    ``min_bucket``: smallest padded tile side; tiles below it still pad up.
    ``prune``: "none" | "pivot" — pivot-filter pruning (L∞ lower bound over
    mapped coordinates, module docstring). "pivot" requires the caller to
    pass ``coords`` (and ``coords_w`` in R×S mode); metrics without the
    triangle inequality resolve back to "none" (capability, not error).
    """

    backend: str = "auto"
    tile_v: int = 1024
    tile_w: int = 4096
    min_bucket: int = 8
    prune: str = "none"


@dataclasses.dataclass
class VerifyStats:
    """What the engine actually did — fed to benchmarks and Table-3 metrics.

    ``n_verifications`` keeps its paper meaning (Σ_h |V_h|·|W_h|, the
    CANDIDATE pair area) so Fig.-12 numbers stay comparable across prune
    modes; ``n_exact`` is the subset that actually reached exact metric
    evaluation after the pivot filter (== n_verifications when pruning is
    off).
    """

    n_verifications: int = 0  # Σ_h |V_h|·|W_h| (valid pair area)
    n_padded: int = 0  # Σ padded tile area dispatched to exact evaluation
    n_dispatched: int = 0  # valid pair area of tiles that ran exact evaluation
    n_tiles: int = 0  # tiles that ran exact evaluation
    n_cells: int = 0  # non-empty cells
    n_hits: int = 0  # emitted (de-duplicated) hits
    n_pruned: int = 0  # valid pairs eliminated by the pivot filter
    n_tiles_pruned: int = 0  # tiles skipped outright (every pair pruned)
    prune: str = "none"  # resolved prune mode the engine actually ran
    bucket_shapes: set = dataclasses.field(default_factory=set)

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_shapes)

    @property
    def occupancy(self) -> float:
        """Valid / padded ratio of the exact-evaluation dispatch — 1.0 means
        zero padding waste. Tiles the pivot filter skipped count in neither
        numerator nor denominator (they cost a bound pass, not a dispatch)."""
        return self.n_dispatched / max(self.n_padded, 1)

    @property
    def n_exact(self) -> int:
        """Pairs that reached exact metric evaluation (post-filter)."""
        return self.n_verifications - self.n_pruned

    @property
    def prune_rate(self) -> float:
        """Fraction of candidate pairs the pivot filter eliminated."""
        return self.n_pruned / max(self.n_verifications, 1)


# ---------------------------------------------------------------------------
# Shared verify semantics (used verbatim by the distributed executor)
# ---------------------------------------------------------------------------


def pair_validity(vids: Array, wids: Array) -> Array:
    """(a, b) bool — True where both sides are real rows (padding id = -1)."""
    return (vids[:, None] >= 0) & (wids[None, :] >= 0)


def apply_dedup(
    hits: Array, vids: Array, wids: Array, wcells: Array, cell_id, cross: bool = False
) -> Array:
    """Mask a raw hit matrix down to pairs this cell should emit.

    Self-join (``cross=False``): ``wcells`` is the *kernel* cell of each W
    row; ``cell_id`` the cell being verified (V rows' own cell). Min-cell
    rule: emit iff the W row's cell is greater than this cell, or equal with
    id_v < id_w.

    R×S (``cross=True``): V and W rows index different sets, so no symmetric
    duplicate exists — every valid hit is emitted (each R row has exactly one
    kernel cell, hence each cross pair is verified exactly once).
    """
    if cross:
        return hits & pair_validity(vids, wids)
    emit = (wcells[None, :] > cell_id) | (
        (wcells[None, :] == cell_id) & (vids[:, None] < wids[None, :])
    )
    return hits & pair_validity(vids, wids) & emit


def verify_tile(
    xv: Array,
    xw: Array,
    vids: Array,
    wids: Array,
    wcells: Array,
    cell_id,
    *,
    delta: float,
    metric: str,
    backend: str,
    cross: bool = False,
    pv: Array | None = None,
    pw: Array | None = None,
    prune: str = "none",
    premask: Array | None = None,
    delta_bound: float | None = None,
) -> Array:
    """One tile's fused verify: (filter,) distances, threshold, validity, de-dup.

    jit-safe; the streaming engine wraps it in its own jit, the distributed
    stage calls it inside shard_map. ``backend`` and ``prune`` must already
    be concrete (resolve with :func:`resolve_engine_backend` /
    :func:`resolve_prune`). ``cross=True`` switches to R×S semantics
    (validity only, no min-cell). With ``prune="pivot"``, ``pv``/``pw`` are
    the tiles' mapped coordinates and the hit mask is additionally ANDed with
    the L∞ lower-bound survivor mask — identical output by construction (the
    bound never prunes a true hit), but the Pallas path skips exact-distance
    work for all-pruned blocks. ``premask`` (jnp-path only): a survivor mask
    the caller already computed via :func:`candidate_mask` — reused instead
    of re-deriving the bound, so the streaming engine pays for it once.
    ``delta_bound``: the (scale-aware) slackened prune threshold — compute
    it ONCE per join with ``ref.prune_delta(delta, metric, x_abs, m)`` and
    pass the same value to every sub-mask (pre-pass, fused kernel, stats)
    so they can never disagree; None falls back to the scale-free band.
    """
    if prune == "pivot":
        # resolve_prune guarantees coords are present in pivot mode; the
        # assert narrows `Array | None` for the type checker at zero trace
        # cost (it runs on static Python values, not tracers).
        assert pv is not None and pw is not None, 'prune="pivot" without coords'
        if backend == "pallas":
            # Fused kernel recomputes the (cheap, VPU) bound in-block — that
            # is what lets it skip the MXU/VPU exact work per pruned block.
            hits = kops.pairdist_mask_filtered(
                xv, xw, pv, pw, delta, metric, delta_bound=delta_bound,
                use_kernel=True,
            )
        else:
            bound = (
                premask
                if premask is not None
                else ref.bound_mask(pv, pw, delta, delta_bound)
            )
            if metric in ref.METRICS:
                hits = ref.pairdist_mask(xv, xw, delta, metric) & bound
            else:
                # True metrics only the reference module knows (angular,
                # jaccard_minhash): same bound, jnp distance path.
                hits = (distances.pairwise(xv, xw, metric) <= delta) & bound
    elif backend == "pallas":
        hits = kops.pairdist_mask(xv, xw, delta, metric, use_kernel=True)
    elif metric in ref.METRICS:
        hits = ref.pairdist_mask(xv, xw, delta, metric)
    else:
        # Metrics only the reference module knows (angular, jaccard_minhash).
        hits = distances.pairwise(xv, xw, metric) <= delta
    return apply_dedup(hits, vids, wids, wcells, cell_id, cross=cross)


def resolve_engine_backend(backend: str, metric: str) -> str:
    """Engine-level backend resolution: kernel-less metrics fall back to the
    jnp path even under an explicit "pallas" request (capability, not error)."""
    if not kops.supports_kernel(metric):
        return "numpy"
    return kops.resolve_backend(backend, metric)


def prune_supported(metric: str) -> bool:
    """True when the pivot filter is sound for ``metric``: the L∞ lower
    bound needs the triangle inequality, i.e. a TRUE metric (excludes cosine
    and dot — see ``distances.Metric.true_metric``)."""
    m = distances.METRICS.get(metric)
    return m is not None and m.true_metric


def resolve_prune(prune: str, metric: str, have_coords: bool) -> str:
    """Resolve a prune request to a concrete "none" | "pivot".

    Mirrors :func:`resolve_engine_backend`: a metric the filter is unsound
    for (no triangle inequality) falls back to "none" — capability, not
    error. Requesting "pivot" WITHOUT mapped coordinates, however, is a
    caller bug and raises.
    """
    if prune not in ("none", "pivot"):
        raise ValueError(f'unknown prune mode {prune!r}; expected "none" | "pivot"')
    if prune == "pivot" and not have_coords:
        raise ValueError(
            'prune="pivot" requires the mapped coordinates (coords / coords_w)'
        )
    if prune == "pivot" and not prune_supported(metric):
        return "none"
    return prune


def candidate_mask(
    pv: Array,
    pw: Array,
    vids: Array,
    wids: Array,
    delta: float,
    delta_bound: float | None = None,
) -> Array:
    """(a, b) bool — pivot-filter SURVIVORS among valid pairs: the L∞ lower
    bound over mapped coordinates is within the (fp-slackened) threshold and
    neither side is padding. Hits are always a subset of this mask when the
    caller passes the SAME ``delta_bound`` here and to the verify call.
    jit-safe; used for pruning-rate telemetry and the streaming engine's
    whole-tile skip."""
    return ref.bound_mask(pv, pw, delta, delta_bound) & pair_validity(vids, wids)


def prune_band(
    delta: float,
    metric: str,
    *arrays: Array | np.ndarray | None,
) -> float:
    """The scale-aware prune threshold for a join over ``arrays`` (payload
    sets; None entries skipped): ``ref.prune_delta`` fed with the joint
    coordinate magnitude and feature count. One value per join, shared by
    every mask so the filter is self-consistent."""
    live = [a for a in arrays if a is not None and a.shape[0] > 0]
    if not live:
        return ref.prune_delta(delta, metric, 0.0, 0)
    # One device->host sync for the whole join, after every per-array max
    # has been enqueued — not one blocking float() per array.
    x_abs = float(jnp.max(jnp.stack([jnp.max(jnp.abs(a)) for a in live])))
    n_feat = max(int(a.shape[1]) for a in live)
    return ref.prune_delta(delta, metric, x_abs, n_feat)


_tile_verify = jax.jit(
    verify_tile,
    static_argnames=("delta", "metric", "backend", "cross", "prune", "delta_bound"),
)

_tile_candidates = jax.jit(candidate_mask, static_argnames=("delta", "delta_bound"))


# ---------------------------------------------------------------------------
# Capacity bucketing
# ---------------------------------------------------------------------------


def bucket_size(n: int, cap: int, floor: int = 8) -> int:
    """Quantize a tile side to a static bucket capacity.

    Quarter-power-of-two steps: within each octave [2^k, 2^(k+1)) sizes round
    up to a multiple of 2^k / 4, giving ≤ 33% padding per axis with at most 4
    shapes per octave — small enough that XLA's compile cache covers every
    tile after a handful of traces.
    """
    n = max(int(n), 1)
    if n >= cap:
        return cap
    octave = 1 << max(n - 1, 0).bit_length()  # smallest pow2 >= n
    quantum = max(octave // 4, floor)
    return min(cap, -(-n // quantum) * quantum)


def _pad_gather(
    data: np.ndarray, idx: np.ndarray, cap: int
) -> tuple[np.ndarray, np.ndarray]:
    """Gather rows ``idx`` of ``data`` into a (cap, m) buffer; ids pad = -1."""
    a = idx.size
    rows = np.zeros((cap, data.shape[1]), data.dtype)
    rows[:a] = data[idx]
    ids = np.full((cap,), -1, np.int64)
    ids[:a] = idx
    return rows, ids


# ---------------------------------------------------------------------------
# The streaming engine
# ---------------------------------------------------------------------------


def verify_cell_lists(
    data: Array | np.ndarray,
    cells_of: np.ndarray,
    v_lists: Sequence[np.ndarray],
    w_lists: Sequence[np.ndarray],
    delta: float,
    metric: str,
    *,
    config: EngineConfig = EngineConfig(),
    return_pairs: bool = True,
    data_w: Array | np.ndarray | None = None,
    coords: Array | np.ndarray | None = None,
    coords_w: Array | np.ndarray | None = None,
) -> tuple[np.ndarray, VerifyStats]:
    """Run the full reduce phase over explicit per-cell index sets.

    ``data``: (N, m) objects; ``cells_of``: (N,) kernel cell per object;
    ``v_lists[h]`` / ``w_lists[h]``: global row indices of V_h / W_h.
    Returns (pairs, stats) with pairs (n_pairs, 2) int64, i < j, unique.

    Two-set mode: when ``data_w`` is given, ``w_lists`` index into ``data_w``
    (the S side) while ``v_lists``/``cells_of`` index ``data`` (the R side);
    pairs come back as (i ∈ R, j ∈ S) — not reordered, unique by
    construction (each R row sits in exactly one kernel cell).

    Pivot-filter pruning: with ``config.prune="pivot"``, ``coords`` is the
    (N, n) mapped-coordinate matrix of ``data`` (``coords_w`` of ``data_w``
    in two-set mode). Per tile the engine first evaluates the cheap L∞
    lower-bound mask (O(tile·n) vs O(tile·m) exact work); a tile with zero
    surviving pairs skips exact evaluation entirely, the rest run the fused
    filter+pairdist kernel. Output pairs are byte-identical to
    ``prune="none"`` — the filter only ever removes non-hits.
    """
    data_np = np.asarray(data, np.float32)
    cells_np = np.asarray(cells_of)
    cross = data_w is not None
    data_w_np = np.asarray(data_w, np.float32) if cross else data_np
    backend = resolve_engine_backend(config.backend, metric)
    have_coords = coords is not None and (not cross or coords_w is not None)
    prune = resolve_prune(config.prune, metric, have_coords)
    delta_bound = None
    if prune == "pivot":
        coords_np = np.asarray(coords, np.float32)
        coords_w_np = np.asarray(coords_w, np.float32) if cross else coords_np
        # One scale-aware fp guard band for the whole call — every sub-mask
        # (pre-pass, fused kernel) shares it, so hits ⊆ candidates always.
        delta_bound = prune_band(
            delta, metric, data_np, data_w_np if cross else None
        )
    stats = VerifyStats(prune=prune)
    chunks: list[np.ndarray] = []

    for h, (v_idx, w_idx) in enumerate(zip(v_lists, w_lists)):
        # spjoin-lint: allow[host-sync] -- index lists arrive as host arrays/lists; once per CELL, not per tile
        v_idx = np.asarray(v_idx)
        w_idx = np.asarray(w_idx)  # spjoin-lint: allow[host-sync] -- same: host-side cell index normalization
        if v_idx.size == 0 or w_idx.size == 0:
            continue
        stats.n_cells += 1
        stats.n_verifications += int(v_idx.size) * int(w_idx.size)
        # W tiles are prepared once per cell (not per V tile): the copies are
        # O(|W_h|·m) — linear in cell size, like the input rows themselves —
        # while only the pair product is streamed tile-by-tile.
        w_tiles = []
        for w0 in range(0, w_idx.size, config.tile_w):
            wt = w_idx[w0 : w0 + config.tile_w]
            cap_w = bucket_size(wt.size, config.tile_w, config.min_bucket)
            xw, wids = _pad_gather(data_w_np, wt, cap_w)
            wc = np.full((cap_w,), -1, np.int64)
            if not cross:  # W kernel cells only exist / matter for self-join
                wc[: wt.size] = cells_np[wt]
            pw = _pad_gather(coords_w_np, wt, cap_w)[0] if prune == "pivot" else None
            w_tiles.append((wt, cap_w, xw, wids, wc, pw))
        for v0 in range(0, v_idx.size, config.tile_v):
            vt = v_idx[v0 : v0 + config.tile_v]
            cap_v = bucket_size(vt.size, config.tile_v, config.min_bucket)
            xv, vids = _pad_gather(data_np, vt, cap_v)
            pv = _pad_gather(coords_np, vt, cap_v)[0] if prune == "pivot" else None
            for wt, cap_w, xw, wids, wc, pw in w_tiles:
                n_valid = int(vt.size) * int(wt.size)
                premask = None
                if prune == "pivot":
                    # Cheap pre-pass: O(tile·n) bound vs O(tile·m) exact.
                    cand_dev = _tile_candidates(
                        pv, pw, vids, wids, delta=float(delta),
                        delta_bound=delta_bound,
                    )
                    # spjoin-lint: allow[host-sync] -- the whole-tile skip decision IS a sync: O(tile*n) bound read back to elide the O(tile*m) kernel
                    n_cand = int(np.asarray(cand_dev).sum())
                    stats.n_pruned += n_valid - n_cand
                    if n_cand == 0:
                        # Every pair pruned: the exact kernel never runs.
                        stats.n_tiles_pruned += 1
                        continue
                    if backend != "pallas":
                        premask = cand_dev  # jnp path reuses the bound
                stats.n_tiles += 1
                stats.n_padded += cap_v * cap_w
                stats.n_dispatched += n_valid
                stats.bucket_shapes.add((cap_v, cap_w))
                # spjoin-lint: allow[host-sync] -- tile result must land on host to be compacted into (i, j) pairs; one readback per dispatched tile by design
                mask = np.asarray(
                    _tile_verify(
                        xv, xw, vids, wids, wc, h,
                        delta=float(delta), metric=metric, backend=backend,
                        cross=cross, pv=pv, pw=pw, prune=prune, premask=premask,
                        delta_bound=delta_bound,
                    )
                )
                if not mask.any():
                    continue
                vi, wi = np.nonzero(mask)
                stats.n_hits += vi.size
                if return_pairs:
                    chunks.append(np.stack([vt[vi], wt[wi]], axis=1))

    if chunks:
        # Each pair is emitted once (min-cell rule / unique kernel cell);
        # sort+unique is kept as a cheap invariant matching the seed
        # executor. Cross pairs index different sets, so no column sort.
        pairs = np.concatenate(chunks)
        if not cross:
            pairs = np.sort(pairs, axis=1)
        pairs = np.unique(pairs, axis=0)
    else:
        pairs = np.zeros((0, 2), np.int64)
    return pairs.astype(np.int64), stats


def verify_resident(
    data: Array | np.ndarray,
    cells_of: np.ndarray,
    v_lists: Sequence[np.ndarray],
    member_w: np.ndarray,
    delta: float,
    metric: str,
    *,
    config: EngineConfig = EngineConfig(),
    data_w: Array | np.ndarray,
    coords: Array | np.ndarray | None = None,
    coords_w: Array | np.ndarray | None = None,
) -> tuple[np.ndarray, VerifyStats]:
    """Delta-vs-resident cross verify: W rows come from a whole-membership
    matrix (|W|, p) over ``data_w`` (a routed query batch or an insertion
    delta), V rows from the RESIDENT per-cell index lists. This is the one
    tile path both the serving ``query_batch`` and the streaming
    ``insert_batch`` stream through — one membership→w_lists derivation, so
    the two callers can never disagree on how a routed row reaches a cell.
    Pairs come back as (i ∈ resident, j ∈ delta), R×S semantics.
    """
    member_np = np.asarray(member_w, bool)
    w_lists = [np.flatnonzero(member_np[:, h]) for h in range(len(v_lists))]
    return verify_cell_lists(
        data, np.asarray(cells_of), v_lists, w_lists, delta, metric,
        config=config, data_w=data_w, coords=coords, coords_w=coords_w,
    )


def verify_pairs(
    data: Array | np.ndarray,
    cells: np.ndarray,
    member: np.ndarray,
    delta: float,
    metric: str,
    *,
    config: EngineConfig = EngineConfig(),
    return_pairs: bool = True,
    data_w: Array | np.ndarray | None = None,
    coords: Array | np.ndarray | None = None,
    coords_w: Array | np.ndarray | None = None,
) -> tuple[np.ndarray, VerifyStats]:
    """Reduce phase from a kernel-cell assignment + whole-membership matrix.

    Self-join: ``cells``: (N,) int cell id of ``data``; ``member``: (N, p)
    bool whole membership of the same rows.

    R×S: ``data``/``cells`` describe R (the V side); ``data_w`` is S and
    ``member`` is then S's whole membership (|S|, p) — V_h comes from R's
    kernel cells, W_h from S's whole membership.

    ``coords`` / ``coords_w``: mapped coordinates of ``data`` / ``data_w``
    (required when ``config.prune="pivot"`` — see the module docstring).

    Derives the per-cell index sets and streams them through
    :func:`verify_cell_lists`.
    """
    cells_np = np.asarray(cells)
    member_np = np.asarray(member)
    p = member_np.shape[1]
    order = np.argsort(cells_np, kind="stable")
    bounds = np.searchsorted(cells_np[order], np.arange(p + 1))
    v_lists = [order[bounds[h] : bounds[h + 1]] for h in range(p)]
    w_lists = [np.flatnonzero(member_np[:, h]) for h in range(p)]
    return verify_cell_lists(
        data, cells_np, v_lists, w_lists, delta, metric,
        config=config, return_pairs=return_pairs, data_w=data_w,
        coords=coords, coords_w=coords_w,
    )


# ---------------------------------------------------------------------------
# The seed's dense per-cell loop — kept as the benchmark baseline / oracle
# ---------------------------------------------------------------------------


def reference_verify(
    data: Array | np.ndarray,
    cells: np.ndarray,
    member: np.ndarray,
    delta: float,
    metric: str,
    *,
    return_pairs: bool = True,
) -> tuple[np.ndarray, int]:
    """The pre-engine reduce loop: one dense eager pairwise matrix per cell.

    O(|V_h|·|W_h|·m) intermediates per cell, no tiling, no fusion. Retained
    verbatim so benchmarks can report engine speedup against the seed path
    and tests can cross-check semantics. Returns (pairs, n_verifications).
    """
    allx = jnp.asarray(data)
    cells_np = np.asarray(cells)
    member_np = np.asarray(member)
    metric_fn = distances.get_metric(metric)
    n_verif = 0
    chunks: list[np.ndarray] = []
    for h in range(member_np.shape[1]):
        v_idx = np.flatnonzero(cells_np == h)
        w_idx = np.flatnonzero(member_np[:, h])
        if v_idx.size == 0 or w_idx.size == 0:
            continue
        n_verif += int(v_idx.size) * int(w_idx.size)
        d = np.asarray(metric_fn.pairwise(allx[v_idx], allx[w_idx]))
        hit_v, hit_w = np.nonzero(d <= delta)
        gi = v_idx[hit_v]
        gj = w_idx[hit_w]
        cj = cells_np[gj]
        keep = ((cj == h) & (gi < gj)) | (cj > h)
        if return_pairs and keep.any():
            chunks.append(np.stack([gi[keep], gj[keep]], axis=1))
    if chunks:
        pairs = np.unique(np.sort(np.concatenate(chunks), axis=1), axis=0)
    else:
        pairs = np.zeros((0, 2), np.int64)
    return pairs.astype(np.int64), n_verif
