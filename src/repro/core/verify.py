"""Streaming tiled verify engine — the shared reduce phase of SP-Join.

The reduce phase (paper §5) checks every kernel-partition row V_h against
every whole-partition row W_h: Σ_h |V_h|·|W_h| distance evaluations. This
module is the ONE implementation of that stage; both executors route
through it:

  * ``spjoin.join``            calls :func:`verify_pairs` (host-streamed tiles)
  * ``distributed.stage_verify`` calls :func:`verify_tile` / :func:`apply_dedup`
                               inside its shard_map trace (static buffers)

so the reference and distributed paths cannot silently diverge on verify
semantics (padding validity + the min-cell de-dup rule live here, once).

Streaming + bucketing (the TPU/XLA adaptation of DIMS-style tile-scheduled
verification):

  * Each cell's |V_h| × |W_h| rectangle is cut into fixed-capacity tiles of
    at most ``tile_v × tile_w`` — peak working set is O(tile), never
    O(|V_h|·|W_h|), so skewed cells stream instead of blowing up memory.
  * Tiles are padded up to a small set of static *bucket* shapes (quarter-
    power-of-two quantized per axis), so XLA compiles O(buckets) executables
    instead of O(cells) — the classic static-shape trade: a bounded padding
    overhead (reported as ``occupancy``) buys compile-cache hits.
  * The distance + ``<= delta`` threshold is one fused jitted call per tile
    (Pallas ``pairdist_mask`` or the jnp oracle, per ``backend``); mask →
    global-pair-index extraction happens per tile on the host, with the
    min-cell de-dup rule already applied inside the compiled mask.

De-dup rule (same statement as the seed executor): a hit (i, j) with
cell(i) = g, cell(j) = h is emitted by cell min(g, h) only; within one cell
both orders are present so we keep id_i < id_j. Lemma 4 guarantees each
qualifying pair is seen by both cells, hence exactly once after the rule.

Two-set R×S mode (``cross=True`` / ``data_w`` given): V rows come from R's
kernel cells, W rows from S's whole membership. Each R row lives in exactly
one kernel cell and Lemma 4 puts every δ-neighbour s ∈ S inside that cell's
whole box, so "emit in R's kernel cell only" already yields each cross pair
exactly once — the min-cell + id ordering rule degenerates to plain padding
validity, and emitted pairs are (i ∈ R, j ∈ S), never reordered.

Pivot-filter pruning (``prune="pivot"`` — the DIMS-style triangle-inequality
candidate filter, run BEFORE any exact metric evaluation):

  * Each object's mapped coordinates (its distances to the shared anchors,
    produced once by ``core.mapping``) are threaded into the tiles alongside
    the payload rows. Every coordinate is 1-Lipschitz, so
    ``max_p |d(v,p) − d(w,p)|`` is a lower bound on D(v, w): a pair whose
    bound exceeds δ cannot be a hit and skips exact evaluation.
  * The bound is evaluated against a slightly slackened threshold
    (``ref.prune_delta`` — an fp guard band), which makes the filter sound
    in fp32 as well: fixed-seed pair sets are BYTE-IDENTICAL between
    ``prune="pivot"`` and ``prune="none"``. Pruning is a pure optimization,
    never a semantics change.
  * The streaming engine skips a tile's exact-distance call outright when
    every pair in it is pruned (``VerifyStats.n_tiles_pruned``); surviving
    tiles run the fused filter+pairdist kernel, whose Pallas path likewise
    skips the MXU/VPU accumulation for all-pruned blocks.
  * Capability, not error: metrics without the triangle inequality (cosine,
    dot) silently resolve to ``prune="none"`` — same treatment as backends
    without a kernel.
  * Window refinement (paper §5's ordered-range pruning): with pruning on,
    each cell's V and W lists are ordered by their first mapped coordinate,
    and a binary search slices each V tile's W range down to the
    ``± delta_bound`` window — rows outside it already exceed the L∞ lower
    bound on that single coordinate, so they are pruned before any gather
    or device dispatch ever happens. On top of the window, whole W tiles
    whose coordinate bounding box is farther than ``delta_bound`` from the
    V tile's box on ANY coordinate are skipped the same way (interval
    arithmetic on host-side min/max — every pair in such a tile provably
    fails the L∞ bound).

Two prune modes share that machinery:

  * ``prune="pivot"`` — windows + the per-PAIR bound mask above. Exact
    per-pair pruning telemetry (``n_pruned`` counts every bound-failing
    pair), and on the Pallas backend the fused kernel skips exact work for
    all-pruned blocks. The per-pair mask costs O(tile·n) extra lanes on
    backends that cannot skip them, so this mode optimizes telemetry and
    accelerator block-skipping, not host wall-clock.
  * ``prune="window"`` — windows + bounding-box tile skips ONLY: all
    pruning happens on the host BEFORE gather/dispatch, cutting real
    dispatch area with zero extra per-pair lanes. ``n_pruned`` counts the
    window/box-pruned pairs (a subset of what "pivot" would count). This
    is the wall-clock mode: the pruned arm does strictly less device work
    than ``prune="none"``.

Emission paths (``EngineConfig.emit``):

  * ``"mask"``: the original per-tile (cap_v, cap_w) hit mask is read back
    and compacted on the host (``np.nonzero`` + gather).
  * ``"compact"``: the fused verify+compaction tile
    (``ref.verify_compact`` / ``kernels.compact``) emits an on-device
    prefix-sum-compacted (capacity, 2) id-pair buffer plus a true-total
    counter — the readback is output-sensitive, O(capacity) instead of
    O(tile area). Capacity is seeded from the cost model's survival
    estimate on a quarter-pow2 bucket ladder; a counter above capacity is
    the overflow sentinel and the engine retries that tile at the exact
    next bucket (the counter is the true total), with a bounded number of
    retries and the mask path as last-resort fallback. Fixed-seed pair
    sets are byte-identical to ``emit="mask"`` on every metric, backend
    and executor. Reference-only metrics (no fused tile) resolve back to
    ``"mask"`` — capability, not error.

Emission lowering is a BACKEND decision: the pair-buffer contract above is
what crosses the tile boundary, not a prescribed instruction sequence. The
Pallas backend (and the "pivot" prune mode, whose survivor count rides the
buffer's counter row) runs the true fused prefix-sum compaction
(``kernels.compact`` / the ``ref.verify_compact`` oracle). The numpy
backend outside "pivot" mode has no device boundary to compact across —
host and "device" memory are the same arena — so the engine lowers compact
emission to the mask dispatch plus a host pack of the identical buffer
contents; same pairs, same counters, none of the O(area) prefix-sum work
that only pays off across a real DMA boundary.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, distances
from repro.kernels import ops as kops
from repro.kernels import ref

Array = jnp.ndarray


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Knobs for the streaming engine.

    ``backend``: "numpy" | "pallas" | "auto" (see ``kernels.ops``). Metrics
    without a Pallas kernel (angular, jaccard_minhash) always take the jnp
    path regardless — the engine treats the kernel metric set as a backend
    capability, not an error.
    ``tile_v`` / ``tile_w``: streaming tile capacity (rows per side). Peak
    per-tile footprint ≈ tile_v·tile_w bytes of mask + gathered rows.
    ``min_bucket``: smallest padded tile side; tiles below it still pad up.
    ``prune``: "none" | "pivot" | "window" — pivot-filter pruning (L∞ lower
    bound over mapped coordinates, module docstring). "pivot" adds the
    per-pair bound mask (exact telemetry, Pallas block-skips); "window"
    prunes only at range/tile granularity before dispatch (the wall-clock
    mode). Both require the caller to pass ``coords`` (and ``coords_w`` in
    R×S mode); metrics without the triangle inequality resolve back to
    "none" (capability, not error).
    ``emit``: "mask" | "compact" — how a tile's hits come back to the host
    (module docstring, *Emission paths*). "compact" reads back an on-device
    prefix-sum-compacted pair buffer instead of the full tile mask;
    reference-only metrics resolve back to "mask" (capability, not error).
    """

    backend: str = "auto"
    tile_v: int = 1024
    tile_w: int = 4096
    min_bucket: int = 8
    prune: str = "none"
    emit: str = "mask"


@dataclasses.dataclass
class VerifyStats:
    """What the engine actually did — fed to benchmarks and Table-3 metrics.

    ``n_verifications`` keeps its paper meaning (Σ_h |V_h|·|W_h|, the
    CANDIDATE pair area) so Fig.-12 numbers stay comparable across prune
    modes; ``n_exact`` is the subset that actually reached exact metric
    evaluation after the pivot filter (== n_verifications when pruning is
    off).

    Emission invariance: ``n_verifications``, ``n_hits`` and ``n_pruned``
    (hence ``prune_rate`` / ``n_exact``) are IDENTICAL across ``emit`` modes
    by construction. The dispatch-schedule counters — ``n_tiles``,
    ``n_padded``, ``n_dispatched``, ``n_tiles_pruned`` — legitimately
    differ: compact emission never host-skips a tile in "pivot" mode (its
    filter runs fused in-kernel), and with windowed pruning all-pruned V
    tiles never materialize W tiles at all.

    Prune-mode semantics of ``n_pruned``: "pivot" counts every pair the L∞
    bound eliminates (per-pair mask); "window" counts the pairs eliminated
    at range/tile granularity — a provable-non-hit SUBSET of the former, so
    ``n_exact`` is an upper bound on exact evaluations in window mode.
    """

    n_verifications: int = 0  # Σ_h |V_h|·|W_h| (valid pair area)
    n_padded: int = 0  # Σ padded tile area dispatched to exact evaluation
    n_dispatched: int = 0  # valid pair area of tiles that ran exact evaluation
    n_tiles: int = 0  # tiles that ran exact evaluation
    n_cells: int = 0  # non-empty cells
    n_hits: int = 0  # emitted (de-duplicated) hits
    n_pruned: int = 0  # valid pairs eliminated by the pivot filter / windows
    n_tiles_pruned: int = 0  # tiles skipped outright (every pair pruned)
    n_overflow_retries: int = 0  # compact-emission re-dispatches (overflow sentinel)
    prune: str = "none"  # resolved prune mode the engine actually ran
    emit: str = "mask"  # resolved emission path the engine actually ran
    bucket_shapes: set = dataclasses.field(default_factory=set)

    @property
    def n_buckets(self) -> int:
        return len(self.bucket_shapes)

    @property
    def occupancy(self) -> float:
        """Valid / padded ratio of the exact-evaluation dispatch — 1.0 means
        zero padding waste. Tiles the pivot filter skipped count in neither
        numerator nor denominator (they cost a bound pass, not a dispatch)."""
        return self.n_dispatched / max(self.n_padded, 1)

    @property
    def n_exact(self) -> int:
        """Pairs that reached exact metric evaluation (post-filter)."""
        return self.n_verifications - self.n_pruned

    @property
    def prune_rate(self) -> float:
        """Fraction of candidate pairs the pivot filter eliminated."""
        return self.n_pruned / max(self.n_verifications, 1)


# ---------------------------------------------------------------------------
# Shared verify semantics (used verbatim by the distributed executor)
# ---------------------------------------------------------------------------


def pair_validity(vids: Array, wids: Array) -> Array:
    """(a, b) bool — True where both sides are real rows (padding id = -1)."""
    return (vids[:, None] >= 0) & (wids[None, :] >= 0)


def apply_dedup(
    hits: Array, vids: Array, wids: Array, wcells: Array, cell_id, cross: bool = False
) -> Array:
    """Mask a raw hit matrix down to pairs this cell should emit.

    Self-join (``cross=False``): ``wcells`` is the *kernel* cell of each W
    row; ``cell_id`` the cell being verified (V rows' own cell). Min-cell
    rule: emit iff the W row's cell is greater than this cell, or equal with
    id_v < id_w.

    R×S (``cross=True``): V and W rows index different sets, so no symmetric
    duplicate exists — every valid hit is emitted (each R row has exactly one
    kernel cell, hence each cross pair is verified exactly once).

    The rule itself lives in :func:`ref.emit_mask` — the single owner both
    emission paths (this mask path and the fused compaction tile) delegate
    to, so they cannot diverge on emission semantics.
    """
    return hits & ref.emit_mask(vids, wids, wcells, cell_id, cross=cross)


def verify_tile(
    xv: Array,
    xw: Array,
    vids: Array,
    wids: Array,
    wcells: Array,
    cell_id,
    *,
    delta: float,
    metric: str,
    backend: str,
    cross: bool = False,
    pv: Array | None = None,
    pw: Array | None = None,
    prune: str = "none",
    premask: Array | None = None,
    delta_bound: float | None = None,
) -> Array:
    """One tile's fused verify: (filter,) distances, threshold, validity, de-dup.

    jit-safe; the streaming engine wraps it in its own jit, the distributed
    stage calls it inside shard_map. ``backend`` and ``prune`` must already
    be concrete (resolve with :func:`resolve_engine_backend` /
    :func:`resolve_prune`). ``cross=True`` switches to R×S semantics
    (validity only, no min-cell). With ``prune="pivot"``, ``pv``/``pw`` are
    the tiles' mapped coordinates and the hit mask is additionally ANDed with
    the L∞ lower-bound survivor mask — identical output by construction (the
    bound never prunes a true hit), but the Pallas path skips exact-distance
    work for all-pruned blocks. ``premask`` (jnp-path only): a survivor mask
    the caller already computed via :func:`candidate_mask` — reused instead
    of re-deriving the bound, so the streaming engine pays for it once.
    ``delta_bound``: the (scale-aware) slackened prune threshold — compute
    it ONCE per join with ``ref.prune_delta(delta, metric, x_abs, m)`` and
    pass the same value to every sub-mask (pre-pass, fused kernel, stats)
    so they can never disagree; None falls back to the scale-free band.
    """
    if prune == "pivot":
        # resolve_prune guarantees coords are present in pivot mode; the
        # assert narrows `Array | None` for the type checker at zero trace
        # cost (it runs on static Python values, not tracers).
        assert pv is not None and pw is not None, 'prune="pivot" without coords'
        if backend == "pallas":
            # Fused kernel recomputes the (cheap, VPU) bound in-block — that
            # is what lets it skip the MXU/VPU exact work per pruned block.
            hits = kops.pairdist_mask_filtered(
                xv, xw, pv, pw, delta, metric, delta_bound=delta_bound,
                use_kernel=True,
            )
        else:
            bound = (
                premask
                if premask is not None
                else ref.bound_mask(pv, pw, delta, delta_bound)
            )
            if metric in ref.METRICS:
                hits = ref.pairdist_mask(xv, xw, delta, metric) & bound
            else:
                # True metrics only the reference module knows (angular,
                # jaccard_minhash): same bound, jnp distance path.
                hits = (distances.pairwise(xv, xw, metric) <= delta) & bound
    elif backend == "pallas":
        hits = kops.pairdist_mask(xv, xw, delta, metric, use_kernel=True)
    elif metric in ref.METRICS:
        hits = ref.pairdist_mask(xv, xw, delta, metric)
    else:
        # Metrics only the reference module knows (angular, jaccard_minhash).
        hits = distances.pairwise(xv, xw, metric) <= delta
    return apply_dedup(hits, vids, wids, wcells, cell_id, cross=cross)


def resolve_engine_backend(backend: str, metric: str) -> str:
    """Engine-level backend resolution: kernel-less metrics fall back to the
    jnp path even under an explicit "pallas" request (capability, not error)."""
    if not kops.supports_kernel(metric):
        return "numpy"
    return kops.resolve_backend(backend, metric)


def prune_supported(metric: str) -> bool:
    """True when the pivot filter is sound for ``metric``: the L∞ lower
    bound needs the triangle inequality, i.e. a TRUE metric (excludes cosine
    and dot — see ``distances.Metric.true_metric``)."""
    m = distances.METRICS.get(metric)
    return m is not None and m.true_metric


def resolve_prune(prune: str, metric: str, have_coords: bool) -> str:
    """Resolve a prune request to a concrete "none" | "pivot" | "window".

    Mirrors :func:`resolve_engine_backend`: a metric the filter is unsound
    for (no triangle inequality) falls back to "none" — capability, not
    error. Requesting pruning WITHOUT mapped coordinates, however, is a
    caller bug and raises.
    """
    if prune not in ("none", "pivot", "window"):
        raise ValueError(
            f'unknown prune mode {prune!r}; expected "none" | "pivot" | "window"'
        )
    if prune != "none" and not have_coords:
        raise ValueError(
            f'prune={prune!r} requires the mapped coordinates (coords / coords_w)'
        )
    if prune != "none" and not prune_supported(metric):
        return "none"
    return prune


def resolve_emit(emit: str, metric: str) -> str:
    """Resolve an emission request to a concrete "mask" | "compact".

    Mirrors :func:`resolve_engine_backend` / :func:`resolve_prune`: compact
    emission needs the fused verify+compaction tile, which exists for the
    exact-metric set (``ref.METRICS``); reference-only metrics (angular,
    jaccard_minhash) resolve back to "mask" — capability, not error.
    """
    if emit not in ("mask", "compact"):
        raise ValueError(f'unknown emit mode {emit!r}; expected "mask" | "compact"')
    if emit == "compact" and metric not in ref.METRICS:
        return "mask"
    return emit


def verify_tile_compact(
    xv: Array,
    xw: Array,
    vids: Array,
    wids: Array,
    wcells: Array,
    cell_id,
    *,
    delta: float,
    metric: str,
    backend: str,
    capacity: int,
    cross: bool = False,
    pv: Array | None = None,
    pw: Array | None = None,
    prune: str = "none",
    delta_bound: float | None = None,
) -> Array:
    """One tile's fused verify + on-device pair compaction, packed for ONE
    host readback.

    Same contract as :func:`verify_tile` on the verify side (filter,
    distances, threshold, validity, min-cell de-dup — all shared with the
    mask path through ``ref``), but instead of the (cap_v, cap_w) hit mask
    it returns a single (capacity + 1, 2) int32 array:

      * rows ``[0:capacity]`` — compacted (v_id, w_id) GLOBAL id pairs,
        padded with -1; emission order is unspecified (backends differ),
        the caller order-normalizes.
      * row ``capacity``     — ``[count, n_cand]``: the TRUE number of
        emitted pairs (``count > capacity`` is the overflow sentinel: the
        buffer contents are then unspecified but ``count`` is exact, so the
        retry capacity can be sized in one step) and the pivot-filter
        survivor count (== valid pair count when pruning is off), so the
        pruning telemetry needs no second readback.

    ``capacity`` must be static (it is an output shape); bucket it with
    :func:`bucket_size` so XLA's compile cache covers the tile stream.
    """
    if prune == "pivot":
        assert pv is not None and pw is not None, 'prune="pivot" without coords'
    else:
        pv = pw = None
    if backend == "pallas":
        pairs, count, n_cand = kops.verify_compact(
            xv, xw, vids, wids, wcells, cell_id, pv, pw,
            delta=delta, metric=metric, capacity=capacity, cross=cross,
            delta_bound=delta_bound, use_kernel=True,
        )
    else:
        pairs, count, n_cand = ref.verify_compact(
            xv, xw, vids, wids, wcells, cell_id,
            delta=delta, metric=metric, capacity=capacity, cross=cross,
            px=pv, py=pw, delta_bound=delta_bound,
        )
    tail = jnp.stack([count, n_cand]).astype(jnp.int32)[None, :]
    return jnp.concatenate([pairs, tail], axis=0)


def candidate_mask(
    pv: Array,
    pw: Array,
    vids: Array,
    wids: Array,
    delta: float,
    delta_bound: float | None = None,
) -> Array:
    """(a, b) bool — pivot-filter SURVIVORS among valid pairs: the L∞ lower
    bound over mapped coordinates is within the (fp-slackened) threshold and
    neither side is padding. Hits are always a subset of this mask when the
    caller passes the SAME ``delta_bound`` here and to the verify call.
    jit-safe; used for pruning-rate telemetry and the streaming engine's
    whole-tile skip."""
    return ref.bound_mask(pv, pw, delta, delta_bound) & pair_validity(vids, wids)


def prune_band(
    delta: float,
    metric: str,
    *arrays: Array | np.ndarray | None,
) -> float:
    """The scale-aware prune threshold for a join over ``arrays`` (payload
    sets; None entries skipped): ``ref.prune_delta`` fed with the joint
    coordinate magnitude and feature count. One value per join, shared by
    every mask so the filter is self-consistent."""
    live = [a for a in arrays if a is not None and a.shape[0] > 0]
    if not live:
        return ref.prune_delta(delta, metric, 0.0, 0)
    # One device->host sync for the whole join, after every per-array max
    # has been enqueued — not one blocking float() per array.
    x_abs = float(jnp.max(jnp.stack([jnp.max(jnp.abs(a)) for a in live])))
    n_feat = max(int(a.shape[1]) for a in live)
    return ref.prune_delta(delta, metric, x_abs, n_feat)


_tile_verify = jax.jit(
    verify_tile,
    static_argnames=("delta", "metric", "backend", "cross", "prune", "delta_bound"),
)

_tile_candidates = jax.jit(candidate_mask, static_argnames=("delta", "delta_bound"))

_tile_compact = jax.jit(
    verify_tile_compact,
    static_argnames=(
        "delta", "metric", "backend", "capacity", "cross", "prune", "delta_bound",
    ),
)


# ---------------------------------------------------------------------------
# Capacity bucketing
# ---------------------------------------------------------------------------


def bucket_size(n: int, cap: int, floor: int = 8) -> int:
    """Quantize a tile side to a static bucket capacity.

    Quarter-power-of-two steps: within each octave [2^k, 2^(k+1)) sizes round
    up to a multiple of 2^k / 4, giving ≤ 33% padding per axis with at most 4
    shapes per octave — small enough that XLA's compile cache covers every
    tile after a handful of traces.
    """
    n = max(int(n), 1)
    if n >= cap:
        return cap
    octave = 1 << max(n - 1, 0).bit_length()  # smallest pow2 >= n
    quantum = max(octave // 4, floor)
    return min(cap, -(-n // quantum) * quantum)


def _pad_gather(
    data: np.ndarray, idx: np.ndarray, cap: int
) -> tuple[np.ndarray, np.ndarray]:
    """Gather rows ``idx`` of ``data`` into a (cap, m) buffer; ids pad = -1."""
    a = idx.size
    rows = np.zeros((cap, data.shape[1]), data.dtype)
    rows[:a] = data[idx]
    ids = np.full((cap,), -1, np.int64)
    ids[:a] = idx
    return rows, ids


def _pad_rows(
    rows: np.ndarray, ids: np.ndarray, cap: int
) -> tuple[np.ndarray, np.ndarray]:
    """Pad pre-gathered rows (a contiguous slice) into a (cap, m) buffer;
    ids pad = -1. The slice-copy twin of :func:`_pad_gather` — the windowed
    prune modes gather each cell ONCE and tile by slicing, so the per-tile
    cost is a memcpy, not a fancy index."""
    a = ids.size
    buf = np.zeros((cap, rows.shape[1]), rows.dtype)
    buf[:a] = rows
    out_ids = np.full((cap,), -1, np.int64)
    out_ids[:a] = ids
    return buf, out_ids


def _prep_w_tiles(
    w_sub: np.ndarray,
    data_w_np: np.ndarray,
    cells_np: np.ndarray,
    coords_w_np: np.ndarray | None,
    cross: bool,
    config: EngineConfig,
) -> list[tuple]:
    """Gather + pad a W-side index range into padded tiles (host-side numpy).

    Returns ``[(wt, cap_w, xw, wids, wc, pw, wbox), ...]`` — one entry per
    ``tile_w`` slice; ``pw`` is None unless mapped coordinates are given,
    ``wbox`` (coordinate bounding box) is None here (no-prune path).
    """
    tiles = []
    for w0 in range(0, w_sub.size, config.tile_w):
        wt = w_sub[w0 : w0 + config.tile_w]
        cap_w = bucket_size(wt.size, config.tile_w, config.min_bucket)
        xw, wids = _pad_gather(data_w_np, wt, cap_w)
        wc = np.full((cap_w,), -1, np.int64)
        if not cross:  # W kernel cells only exist / matter for self-join
            wc[: wt.size] = cells_np[wt]
        pw = None
        if coords_w_np is not None:
            pw = _pad_gather(coords_w_np, wt, cap_w)[0]
        tiles.append((wt, cap_w, xw, wids, wc, pw, None))
    return tiles


def _prep_w_tiles_sorted(
    w_idx: np.ndarray,
    w_data: np.ndarray,
    w_cells: np.ndarray | None,
    w_coords: np.ndarray,
    lo: int,
    hi: int,
    config: EngineConfig,
    need_pw: bool,
) -> list[tuple]:
    """Windowed-mode tile prep over the per-cell PRE-SORTED buffers: the
    [lo, hi) window is contiguous in every buffer, so each tile is a slice
    copy plus its coordinate bounding box (for the bbox skip) — no per-tile
    fancy gather. Same tuple layout as :func:`_prep_w_tiles`."""
    tiles = []
    for w0 in range(lo, hi, config.tile_w):
        w1 = min(w0 + config.tile_w, hi)
        wt = w_idx[w0:w1]
        cap_w = bucket_size(w1 - w0, config.tile_w, config.min_bucket)
        xw, wids = _pad_rows(w_data[w0:w1], wt, cap_w)
        wc = np.full((cap_w,), -1, np.int64)
        if w_cells is not None:  # self-join: kernel cell per W row
            wc[: w1 - w0] = w_cells[w0:w1]
        cw = w_coords[w0:w1]
        pw = _pad_rows(cw, wt, cap_w)[0] if need_pw else None
        tiles.append((wt, cap_w, xw, wids, wc, pw, (cw.min(axis=0), cw.max(axis=0))))
    return tiles


# --- Compact-emission capacity sizing --------------------------------------
#
# The pair buffer's capacity is a STATIC output shape, so it rides the same
# quarter-pow2 bucket ladder as the tile sides. It is seeded from the cost
# model's bound-survival estimate (an overestimate of the hit rate, hence a
# conservative buffer), padded by a slack factor, floored, and grown online
# from observed per-tile counts. All knobs are module-level on purpose —
# tests monkeypatch them to force the overflow→retry→fallback ladder.

DEFAULT_EMIT_RATE = 0.05  # prior hit fraction when no coordinate sample exists
EMIT_SLACK = 2.0  # capacity head-room multiplier over the estimated rate
_EMIT_FLOOR = 32  # minimum pre-bucket capacity, absorbs tiny-tile noise
_EMIT_SAMPLE = 256  # rows fed to the survival estimate (O(sample^2) pairs)
_MAX_OVERFLOW_RETRIES = 3  # capacity doublings before the mask-path fallback


# --- Batched window dispatch ------------------------------------------------
#
# prune="window" cuts tiles small by design (the surviving W window shrinks
# with tile_v), so a per-tile Python->XLA dispatch would swallow the pruned
# area in launch overhead. The jnp window path therefore DEFERS its tiles and
# verifies every same-bucket batch in one vmapped call: one dispatch and one
# host readback per bucket shape per flush, not per tile. The flush area cap
# bounds resident mask memory; emission order does not matter (the final
# sort+unique canonicalizes), so flushing early is always safe.

_BATCH_FLUSH_AREA = 1 << 24  # max summed mask elements resident per flush

_BATCH_VERIFY_JIT: dict[tuple[str, bool], Callable] = {}


def _batched_tile_verify(metric: str, cross: bool) -> Callable:
    """jit(vmap) of :func:`verify_tile` over a leading tile-batch axis, one
    cached trace per (metric, cross); delta rides as a traced scalar so every
    bucket shape shares the same wrapper."""
    fn = _BATCH_VERIFY_JIT.get((metric, cross))
    if fn is None:
        def _one(xv, xw, vids, wids, wcells, cell_id, delta):
            return verify_tile(
                xv, xw, vids, wids, wcells, cell_id,
                delta=delta, metric=metric, backend="numpy", cross=cross,
            )

        fn = jax.jit(jax.vmap(_one, in_axes=(0, 0, 0, 0, 0, 0, None)))
        _BATCH_VERIFY_JIT[(metric, cross)] = fn
    return fn


def _flush_window_batch(
    pending: list[tuple],
    delta: float,
    metric: str,
    cross: bool,
    stats: VerifyStats,
    chunks: list[np.ndarray],
    return_pairs: bool,
) -> None:
    """Dispatch the deferred window tiles: stack same-bucket tiles, run ONE
    vmapped verify per bucket shape, emit hits with one batched nonzero.
    Identical per-tile masks to the immediate path by construction (vmap of
    the same :func:`verify_tile`)."""
    fn = _batched_tile_verify(metric, cross)
    groups: dict[tuple[int, int], list[int]] = {}
    for i, t in enumerate(pending):
        groups.setdefault((t[0].shape[0], t[1].shape[0]), []).append(i)
    batches = []
    for idxs in groups.values():
        xv = np.stack([pending[i][0] for i in idxs])
        xw = np.stack([pending[i][1] for i in idxs])
        vids = np.stack([pending[i][2] for i in idxs])
        wids = np.stack([pending[i][3] for i in idxs])
        wcs = np.stack([pending[i][4] for i in idxs])
        hs = np.fromiter((pending[i][5] for i in idxs), np.int64, len(idxs))
        batches.append((vids, wids, fn(xv, xw, vids, wids, wcs, hs, float(delta))))
    # ONE device->host sync for the whole flush, after every bucket-shape
    # batch has been enqueued — not one blocking readback per batch (the
    # prune_band idiom).
    outs = jax.device_get([b[2] for b in batches])
    for (vids, wids, _), out in zip(batches, outs):
        bi, vi, wi = out.nonzero()
        stats.n_hits += int(bi.size)
        if return_pairs and bi.size:
            # Padding lanes carry id -1 but can never be hits (pair
            # validity is ANDed inside verify_tile), so the gathered ids
            # are always real rows.
            chunks.append(
                np.stack([vids[bi, vi], wids[bi, wi]], axis=1).astype(np.int64)
            )
    pending.clear()


def _estimate_emit_rate(coords: np.ndarray, delta: float) -> float:
    """Survival-rate prior for compact-emission capacity sizing.

    The cost model's pivot-pair bound-survival fraction over a deterministic
    row subsample — the engine-side analogue of the distributed planner's
    ``predicted_survival``. An OVERestimate of the true hit rate (the L∞
    bound admits every hit), which is the safe direction for buffer sizing.
    """
    n = coords.shape[0]
    k = min(n, _EMIT_SAMPLE)
    if k < 2:
        return 1.0
    idx = np.linspace(0, n - 1, k).astype(np.int64)
    rate = cost_model.estimate_survival_rate(coords[idx], delta)
    return float(min(max(rate, 1.0 / (k * k)), 1.0))


# ---------------------------------------------------------------------------
# The streaming engine
# ---------------------------------------------------------------------------


def verify_cell_lists(
    data: Array | np.ndarray,
    cells_of: np.ndarray,
    v_lists: Sequence[np.ndarray],
    w_lists: Sequence[np.ndarray],
    delta: float,
    metric: str,
    *,
    config: EngineConfig = EngineConfig(),
    return_pairs: bool = True,
    data_w: Array | np.ndarray | None = None,
    coords: Array | np.ndarray | None = None,
    coords_w: Array | np.ndarray | None = None,
) -> tuple[np.ndarray, VerifyStats]:
    """Run the full reduce phase over explicit per-cell index sets.

    ``data``: (N, m) objects; ``cells_of``: (N,) kernel cell per object;
    ``v_lists[h]`` / ``w_lists[h]``: global row indices of V_h / W_h.
    Returns (pairs, stats) with pairs (n_pairs, 2) int64, i < j, unique.

    Two-set mode: when ``data_w`` is given, ``w_lists`` index into ``data_w``
    (the S side) while ``v_lists``/``cells_of`` index ``data`` (the R side);
    pairs come back as (i ∈ R, j ∈ S) — not reordered, unique by
    construction (each R row sits in exactly one kernel cell).

    Pivot-filter pruning: with ``config.prune="pivot"``, ``coords`` is the
    (N, n) mapped-coordinate matrix of ``data`` (``coords_w`` of ``data_w``
    in two-set mode). Per tile the engine first evaluates the cheap L∞
    lower-bound mask (O(tile·n) vs O(tile·m) exact work); a tile with zero
    surviving pairs skips exact evaluation entirely, the rest run the fused
    filter+pairdist kernel. ``config.prune="window"`` keeps only the
    host-side range/tile pruning (ordered windows + bounding-box skips,
    module docstring) — no per-pair bound lanes, so the pruned dispatch is
    strictly smaller than unpruned. Output pairs are byte-identical to
    ``prune="none"`` in both modes — pruning only ever removes non-hits.

    Compact emission: with ``config.emit="compact"`` each dispatched tile
    returns the fused on-device pair buffer instead of the hit mask (module
    docstring, *Emission paths*); the pair capacity is seeded from the cost
    model's survival estimate when ``coords`` is given, grown on overflow,
    with the mask path as bounded last-resort fallback. Output pairs are
    byte-identical to ``emit="mask"``.
    """
    data_np = np.asarray(data, np.float32)
    cells_np = np.asarray(cells_of)
    cross = data_w is not None
    data_w_np = np.asarray(data_w, np.float32) if cross else data_np
    backend = resolve_engine_backend(config.backend, metric)
    have_coords = coords is not None and (not cross or coords_w is not None)
    prune = resolve_prune(config.prune, metric, have_coords)
    delta_bound = None
    if prune != "none":
        coords_np = np.asarray(coords, np.float32)
        coords_w_np = np.asarray(coords_w, np.float32) if cross else coords_np
        # One scale-aware fp guard band for the whole call — every sub-mask
        # (window, bbox skip, pre-pass, fused kernel) shares it, so
        # hits ⊆ candidates always.
        delta_bound = prune_band(
            delta, metric, data_np, data_w_np if cross else None
        )
    emit = resolve_emit(config.emit, metric)
    # Which tiles actually carry the on-device pair buffer (module docstring,
    # *Emission lowering*): the Pallas backend always; the jnp path only in
    # "pivot" mode, where the buffer's counter row carries the per-pair
    # survivor count the telemetry contract needs. Everything else lowers
    # compact emission to mask dispatch + host pack — identical bytes.
    buffered = emit == "compact" and (backend == "pallas" or prune == "pivot")
    # Batched window dispatch (see _flush_window_batch): the jnp window path
    # defers its (deliberately small) tiles and verifies same-bucket batches
    # in one vmapped call each, so launch overhead cannot swallow the area
    # the windows pruned. The Pallas path keeps per-tile dispatch — its
    # block-skip already amortizes launches in-kernel.
    batch_w = prune == "window" and backend != "pallas"
    pending: list[tuple] = []
    pending_area = 0
    emit_rate = DEFAULT_EMIT_RATE
    if buffered and coords is not None:
        # Capacity prior: bound-survival fraction on a coordinate subsample,
        # measured at delta_bound when the filter runs so prior and filter
        # can never disagree on what survives.
        emit_rate = _estimate_emit_rate(
            np.asarray(coords, np.float32),
            float(delta_bound if delta_bound is not None else delta),
        )
    stats = VerifyStats(prune=prune, emit=emit)
    chunks: list[np.ndarray] = []

    for h, (v_idx, w_idx) in enumerate(zip(v_lists, w_lists)):
        # spjoin-lint: allow[host-sync] -- index lists arrive as host arrays/lists; once per CELL, not per tile
        v_idx = np.asarray(v_idx)
        w_idx = np.asarray(w_idx)  # spjoin-lint: allow[host-sync] -- same: host-side cell index normalization
        if v_idx.size == 0 or w_idx.size == 0:
            continue
        stats.n_cells += 1
        stats.n_verifications += int(v_idx.size) * int(w_idx.size)
        w_coord0 = None
        if prune != "none":
            # Window refinement (module docstring): order both sides by ONE
            # mapped coordinate, so V tiles become coordinate bands and the
            # binary search below slices each one's W range down to the
            # ± delta_bound window. Any 1-Lipschitz coordinate is sound, so
            # pick the one this cell's W rows spread widest on — the kernel
            # grid already localizes the partitioned coordinates, leaving
            # them little window to cut. Pure reordering — the emitted pair
            # SET is unchanged; everything sliced off is a provable non-hit.
            wc_all = coords_w_np[w_idx]
            sort_dim = int((wc_all.max(axis=0) - wc_all.min(axis=0)).argmax())
            v_idx = v_idx[np.argsort(coords_np[v_idx, sort_dim], kind="stable")]
            word = np.argsort(wc_all[:, sort_dim], kind="stable")
            w_idx = w_idx[word]
            # One gather per cell into sort order; every tile below is a
            # contiguous slice of these buffers (window = contiguous range).
            w_coords_cell = wc_all[word]
            w_coord0 = w_coords_cell[:, sort_dim]
            w_data_cell = data_w_np[w_idx]
            w_cells_cell = None if cross else cells_np[w_idx]
            v_coords_cell = coords_np[v_idx]
            v_data_cell = data_np[v_idx]
            w_tiles = None  # sliced per V tile from the surviving window
        else:
            # W tiles are prepared once per cell (not per V tile): the copies
            # are O(|W_h|·m) — linear in cell size, like the input rows
            # themselves — while only the pair product streams tile-by-tile.
            w_tiles = _prep_w_tiles(w_idx, data_w_np, cells_np, None, cross, config)
        for v0 in range(0, v_idx.size, config.tile_v):
            vt = v_idx[v0 : v0 + config.tile_v]
            cap_v = bucket_size(vt.size, config.tile_v, config.min_bucket)
            pv = v_box = None
            if prune != "none":
                v_coords = v_coords_cell[v0 : v0 + config.tile_v]
                v_box = (v_coords.min(axis=0), v_coords.max(axis=0))
                xv, vids = _pad_rows(v_data_cell[v0 : v0 + config.tile_v], vt, cap_v)
                if prune == "pivot":  # per-pair bound rides into the tile
                    pv = _pad_rows(v_coords, vt, cap_v)[0]
                vc = v_coords[:, sort_dim]
                lo = int(np.searchsorted(w_coord0, vc.min() - delta_bound, "left"))
                hi = int(np.searchsorted(w_coord0, vc.max() + delta_bound, "right"))
                # W rows outside [lo, hi) differ from every V row in this
                # tile by more than delta_bound on one 1-Lipschitz coordinate
                # — already above the L∞ lower bound, pruned with zero
                # gather and zero dispatch.
                stats.n_pruned += int(vt.size) * int(w_idx.size - (hi - lo))
                if lo == hi:
                    continue
                w_tiles = _prep_w_tiles_sorted(
                    w_idx, w_data_cell, w_cells_cell, w_coords_cell,
                    lo, hi, config, need_pw=prune == "pivot",
                )
            else:
                xv, vids = _pad_gather(data_np, vt, cap_v)
            for wt, cap_w, xw, wids, wc, pw, w_box in w_tiles:
                n_valid = int(vt.size) * int(wt.size)
                if v_box is not None and w_box is not None:
                    # Bounding-box tile skip: interval arithmetic on the
                    # mapped coordinates. The gap between the V and W boxes
                    # lower-bounds every pair's L∞ bound, so a gap beyond
                    # delta_bound means the whole tile is provable non-hits
                    # — skipped before any dispatch, on every coordinate
                    # (the window above only exploits the sort coordinate).
                    gap = np.maximum(
                        w_box[0] - v_box[1], v_box[0] - w_box[1]
                    ).max()
                    if gap > delta_bound:
                        stats.n_pruned += n_valid
                        stats.n_tiles_pruned += 1
                        continue
                premask = None
                if emit == "mask" and prune == "pivot":
                    # Cheap pre-pass: O(tile·n) bound vs O(tile·m) exact.
                    # Compact emission skips it — its filter runs fused
                    # in-kernel and the survivor count comes back in-band.
                    cand_dev = _tile_candidates(
                        pv, pw, vids, wids, delta=float(delta),
                        delta_bound=delta_bound,
                    )
                    # spjoin-lint: allow[host-sync] -- the whole-tile skip decision IS a sync: O(tile*n) bound read back to elide the O(tile*m) kernel
                    n_cand = int(np.asarray(cand_dev).sum())
                    stats.n_pruned += n_valid - n_cand
                    if n_cand == 0:
                        # Every pair pruned: the exact kernel never runs.
                        stats.n_tiles_pruned += 1
                        continue
                    if backend != "pallas":
                        premask = cand_dev  # jnp path reuses the bound
                stats.n_tiles += 1
                stats.n_padded += cap_v * cap_w
                stats.n_dispatched += n_valid
                stats.bucket_shapes.add((cap_v, cap_w))
                if batch_w:
                    pending.append((xv, xw, vids, wids, wc, h))
                    pending_area += cap_v * cap_w
                    if pending_area >= _BATCH_FLUSH_AREA:
                        # Cap resident mask memory; early flushes are safe
                        # (the final sort+unique canonicalizes pair order).
                        _flush_window_batch(
                            pending, delta, metric, cross,
                            stats, chunks, return_pairs,
                        )
                        pending_area = 0
                    continue
                # "window" prunes entirely on the host (above); the tile
                # itself runs the plain verify — no per-pair bound lanes.
                tile_prune = prune if prune == "pivot" else "none"
                tile_band = delta_bound if tile_prune == "pivot" else None
                mode = "compact" if buffered else "mask"
                cap_pairs = 0
                if mode == "compact":
                    cap_pairs = bucket_size(
                        int(n_valid * min(emit_rate * EMIT_SLACK, 1.0)) + _EMIT_FLOOR,
                        cap_v * cap_w,
                    )
                tile_counts = None
                out = None
                for attempt in range(_MAX_OVERFLOW_RETRIES + 2):
                    if mode == "compact":
                        out_dev = _tile_compact(
                            xv, xw, vids, wids, wc, h,
                            delta=float(delta), metric=metric, backend=backend,
                            capacity=cap_pairs, cross=cross, pv=pv, pw=pw,
                            prune=tile_prune, delta_bound=tile_band,
                        )
                    else:
                        out_dev = _tile_verify(
                            xv, xw, vids, wids, wc, h,
                            delta=float(delta), metric=metric, backend=backend,
                            cross=cross, pv=pv, pw=pw, prune=tile_prune,
                            premask=premask, delta_bound=tile_band,
                        )
                    # spjoin-lint: allow[host-sync] -- tile result must land on host to become (i, j) pairs; ONE readback per dispatch, both emission paths
                    out = np.asarray(out_dev)
                    if mode != "compact":
                        break
                    tile_counts = (int(out[-1, 0]), int(out[-1, 1]))
                    if tile_counts[0] <= cap_pairs:
                        break
                    # Overflow sentinel: count > capacity means the buffer
                    # contents are unspecified, but count itself is the TRUE
                    # total — the retry bucket is sized exactly in one step.
                    # Bounded retries, then the mask path as last resort;
                    # the emitted pair set is identical on every rung.
                    stats.n_overflow_retries += 1
                    if attempt >= _MAX_OVERFLOW_RETRIES:
                        mode = "mask"
                    else:
                        cap_pairs = bucket_size(
                            max(tile_counts[0], 2 * cap_pairs), cap_v * cap_w
                        )
                if mode == "compact":
                    count, n_cand = tile_counts
                    if prune == "pivot":
                        stats.n_pruned += n_valid - n_cand
                    # Grow the prior from observed hit rates so one hot tile
                    # does not turn into a retry per tile downstream.
                    emit_rate = max(emit_rate, count / max(n_valid, 1))
                    stats.n_hits += count
                    if return_pairs and count:
                        chunks.append(out[:count].astype(np.int64))
                else:
                    if tile_counts is not None and prune == "pivot":
                        # Overflow fallback: the mask path ran, but the last
                        # compact dispatch already reported the survivor
                        # count — pruning telemetry stays emission-invariant.
                        stats.n_pruned += n_valid - tile_counts[1]
                    mask = out
                    if not mask.any():
                        continue
                    vi, wi = np.nonzero(mask)
                    stats.n_hits += vi.size
                    if return_pairs:
                        chunks.append(np.stack([vt[vi], wt[wi]], axis=1))

    if pending:
        _flush_window_batch(
            pending, delta, metric, cross, stats, chunks, return_pairs
        )
    if chunks:
        # Each pair is emitted once (min-cell rule / unique kernel cell);
        # sort+unique is kept as a cheap invariant matching the seed
        # executor. Cross pairs index different sets, so no column sort.
        pairs = np.concatenate(chunks)
        if not cross:
            pairs = np.sort(pairs, axis=1)
        pairs = np.unique(pairs, axis=0)
    else:
        pairs = np.zeros((0, 2), np.int64)
    return pairs.astype(np.int64), stats


def verify_resident(
    data: Array | np.ndarray,
    cells_of: np.ndarray,
    v_lists: Sequence[np.ndarray],
    member_w: np.ndarray,
    delta: float,
    metric: str,
    *,
    config: EngineConfig = EngineConfig(),
    data_w: Array | np.ndarray,
    coords: Array | np.ndarray | None = None,
    coords_w: Array | np.ndarray | None = None,
) -> tuple[np.ndarray, VerifyStats]:
    """Delta-vs-resident cross verify: W rows come from a whole-membership
    matrix (|W|, p) over ``data_w`` (a routed query batch or an insertion
    delta), V rows from the RESIDENT per-cell index lists. This is the one
    tile path both the serving ``query_batch`` and the streaming
    ``insert_batch`` stream through — one membership→w_lists derivation, so
    the two callers can never disagree on how a routed row reaches a cell.
    Pairs come back as (i ∈ resident, j ∈ delta), R×S semantics.
    """
    member_np = np.asarray(member_w, bool)
    w_lists = [np.flatnonzero(member_np[:, h]) for h in range(len(v_lists))]
    return verify_cell_lists(
        data, np.asarray(cells_of), v_lists, w_lists, delta, metric,
        config=config, data_w=data_w, coords=coords, coords_w=coords_w,
    )


def verify_pairs(
    data: Array | np.ndarray,
    cells: np.ndarray,
    member: np.ndarray,
    delta: float,
    metric: str,
    *,
    config: EngineConfig = EngineConfig(),
    return_pairs: bool = True,
    data_w: Array | np.ndarray | None = None,
    coords: Array | np.ndarray | None = None,
    coords_w: Array | np.ndarray | None = None,
) -> tuple[np.ndarray, VerifyStats]:
    """Reduce phase from a kernel-cell assignment + whole-membership matrix.

    Self-join: ``cells``: (N,) int cell id of ``data``; ``member``: (N, p)
    bool whole membership of the same rows.

    R×S: ``data``/``cells`` describe R (the V side); ``data_w`` is S and
    ``member`` is then S's whole membership (|S|, p) — V_h comes from R's
    kernel cells, W_h from S's whole membership.

    ``coords`` / ``coords_w``: mapped coordinates of ``data`` / ``data_w``
    (required when ``config.prune="pivot"`` — see the module docstring).

    Derives the per-cell index sets and streams them through
    :func:`verify_cell_lists`.
    """
    cells_np = np.asarray(cells)
    member_np = np.asarray(member)
    p = member_np.shape[1]
    order = np.argsort(cells_np, kind="stable")
    bounds = np.searchsorted(cells_np[order], np.arange(p + 1))
    v_lists = [order[bounds[h] : bounds[h + 1]] for h in range(p)]
    w_lists = [np.flatnonzero(member_np[:, h]) for h in range(p)]
    return verify_cell_lists(
        data, cells_np, v_lists, w_lists, delta, metric,
        config=config, return_pairs=return_pairs, data_w=data_w,
        coords=coords, coords_w=coords_w,
    )


# ---------------------------------------------------------------------------
# The seed's dense per-cell loop — kept as the benchmark baseline / oracle
# ---------------------------------------------------------------------------


def reference_verify(
    data: Array | np.ndarray,
    cells: np.ndarray,
    member: np.ndarray,
    delta: float,
    metric: str,
    *,
    return_pairs: bool = True,
) -> tuple[np.ndarray, int]:
    """The pre-engine reduce loop: one dense eager pairwise matrix per cell.

    O(|V_h|·|W_h|·m) intermediates per cell, no tiling, no fusion. Retained
    verbatim so benchmarks can report engine speedup against the seed path
    and tests can cross-check semantics. Returns (pairs, n_verifications).
    """
    allx = jnp.asarray(data)
    cells_np = np.asarray(cells)
    member_np = np.asarray(member)
    metric_fn = distances.get_metric(metric)
    n_verif = 0
    chunks: list[np.ndarray] = []
    for h in range(member_np.shape[1]):
        v_idx = np.flatnonzero(cells_np == h)
        w_idx = np.flatnonzero(member_np[:, h])
        if v_idx.size == 0 or w_idx.size == 0:
            continue
        n_verif += int(v_idx.size) * int(w_idx.size)
        d = np.asarray(metric_fn.pairwise(allx[v_idx], allx[w_idx]))
        hit_v, hit_w = np.nonzero(d <= delta)
        gi = v_idx[hit_v]
        gj = w_idx[hit_w]
        cj = cells_np[gj]
        keep = ((cj == h) & (gi < gj)) | (cj > h)
        if return_pairs and keep.any():
            chunks.append(np.stack([gi[keep], gj[keep]], axis=1))
    if chunks:
        pairs = np.unique(np.sort(np.concatenate(chunks), axis=1), axis=0)
    else:
        pairs = np.zeros((0, 2), np.int64)
    return pairs.astype(np.int64), n_verif
