"""Cost-model-guided reduce placement: the skew-aware cell→device planner.

The paper's second headline contribution is the cost model "as the guideline
to split the whole datasets into partitions in map and reduce phases" (§5.1);
optimal assignment is NP-hard (Theorem 4), so SP-Join ships heuristics with
*explainable* balance quality (Table 3). This module is the placement half of
that story for the distributed executor: given the cost model's per-cell
predicted verification loads (``cost_model.estimate_from_samples`` scaled by
``estimate_survival_rate`` — Eq. 33 costs from the sampled pivots alone), it
produces a cell→device assignment that minimizes the makespan (the "curse of
the last reducer"), instead of the historical ``cell h → device h // (p/D)``
contiguous layout that lets one hot cell straggle its device.

Two mechanisms, both static (planned on the host before the verify stage
compiles, so they ride the existing ``all_to_all`` — no new collectives):

* **Cardinality-constrained LPT** (longest-processing-time greedy): slots are
  sorted by descending predicted load and each is assigned to the least-loaded
  device that still has a free dispatch slot. The cardinality constraint
  (exactly ``n_slots / D`` slots per device) is what keeps the shuffle layout
  a pure *permutation* of the contiguous one — same buffer shapes, same single
  ``all_to_all``, only the scatter targets reorder.

* **Heavy-cell splitting**: a cell whose predicted load exceeds the per-device
  budget (mean device load) is split into V-side row *slabs* — V rows are
  dealt round-robin across the slabs by intra-cell rank while the W side is
  replicated into every slab. Each candidate pair (v, w) of the cell appears
  in exactly the slab holding v, and every slab carries the cell's original id
  for the min-cell de-dup rule, so the emitted pair set is unchanged (the
  "emission ownership is R's kernel cell" invariant — slabs only partition V).
  Splitting trades W-side duplication for a bounded max slot load.

Quality report (all a-posteriori, computed on the loads actually planned):

* ``lower_bound`` = max(Σloads / D, max slot load) — no schedule beats it.
* ``makespan_ratio`` = makespan / lower_bound (≥ 1; 1 = perfectly balanced).
* ``lpt_factor`` = 4/3 − 1/(3D) — Graham's guarantee for unconstrained LPT
  (LPT-makespan ≤ lpt_factor · OPT). The cardinality-constrained variant we
  run additionally certifies ``certified_bound`` per plan: when the critical
  device's last slot was placed while it was the globally least-loaded device
  (the common case), Graham's argument gives makespan ≤ Σ/D + (1 − 1/D)·x
  with x that slot's load; otherwise the trivial slots-per-device bound
  applies. ``makespan ≤ certified_bound`` always holds and is asserted by
  ``tests/test_placement.py``; docs/COST_MODEL.md walks the derivation.

Byte-identity contract: placement NEVER changes the emitted pair set — it only
permutes which device verifies which cell (and slabs only partition V rows).
``tests/test_placement.py`` enforces fixed-seed byte-identity placement on/off
on both executors, self-join and R×S.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import cost_model

STRATEGIES = ("contiguous", "lpt")

# Streaming drift thresholds (docs/STREAMING.md decision table). Measured as
# cost_model.load_drift (total-variation distance of normalized cell loads):
# past REPLAN_DRIFT the plan's relative weights are stale enough that a cheap
# re-plan (static permutation, pairs unchanged) pays for itself; past
# RESAMPLE_DRIFT the pivot sample itself no longer describes the data and
# only a re-sample + rebuild resets the predictions.
REPLAN_DRIFT = 0.15
RESAMPLE_DRIFT = 0.5

DRIFT_ACTIONS = ("none", "replan", "resample")


def drift_action(
    drift: float,
    replan_threshold: float = REPLAN_DRIFT,
    resample_threshold: float = RESAMPLE_DRIFT,
) -> str:
    """Map a measured drift to the action the streaming layer should fire:
    the cheap one ("replan" — re-run :func:`plan_placement` on the observed
    loads; a static permutation, the pair set cannot change) before the
    expensive one ("resample" — redraw pivots and rebuild the index). The
    thresholds are ordered: a drift past both fires "resample"."""
    if resample_threshold < replan_threshold:
        raise ValueError(
            f"resample threshold ({resample_threshold}) must be >= replan "
            f"threshold ({replan_threshold}) — the cheap action fires first"
        )
    if drift >= resample_threshold:
        return "resample"
    if drift >= replan_threshold:
        return "replan"
    return "none"


def device_loads_under(plan: "PlacementPlan", cell_loads: np.ndarray) -> np.ndarray:
    """(D,) per-device loads an EXISTING plan induces for a NEW per-cell load
    vector (each cell's load spread evenly over its slabs, padding slots 0).
    This is how the drift monitor scores the stale plan against observed
    loads — ``plan.device_loads`` always reflects the loads the plan was
    built from, not what the data has become."""
    loads = np.asarray(cell_loads, np.float64).reshape(-1)
    if loads.shape[0] != plan.p:
        raise ValueError(f"expected {plan.p} cell loads, got {loads.shape[0]}")
    real = plan.slot_cell >= 0
    cell = np.clip(plan.slot_cell, 0, None)
    slot_load = np.where(real, loads[cell] / plan.cell_n_slabs[cell], 0.0)
    out = np.zeros(plan.n_devices, np.float64)
    np.add.at(out, plan.device_of_slot, slot_load)
    return out


def planner_inputs(
    piv_mapped: np.ndarray,
    piv_cells: np.ndarray,
    piv_member: np.ndarray,
    n_v: int,
    n_w: int,
    delta: float,
    prune_active: bool,
) -> tuple[np.ndarray, float, np.ndarray, np.ndarray]:
    """The cost-model → planner pipeline, shared VERBATIM by both executors
    (their plan-parity contract is "same loads → same plan", so the loads
    must come from one code path).

    ``piv_mapped`` / ``piv_cells`` / ``piv_member``: the sampled pivots'
    mapped coordinates, kernel cells and whole membership under the final
    partition plan. ``n_v`` / ``n_w``: dataset sizes the V / W estimates
    scale to (equal for a self-join; |R| / |S| for R×S — the W side scales
    with S). ``prune_active``: pivot filter resolved on ⇒ survival-adjust
    the loads (:func:`cost_model.estimate_survival_rate`).

    Returns ``(cell_loads, predicted_survival, v_est, w_est)``.
    """
    piv_cells = np.asarray(piv_cells)
    piv_member = np.asarray(piv_member)
    piv_mapped = np.asarray(piv_mapped)
    v_est, w_est = cost_model.estimate_from_samples(piv_cells, piv_member, n_v)
    if n_w != n_v:
        _, w_est = cost_model.estimate_from_samples(piv_cells, piv_member, n_w)
    survival = (
        cost_model.estimate_survival_rate(
            piv_mapped, delta, cells=piv_cells, member=piv_member
        )
        if prune_active
        else 1.0
    )
    return (
        cost_model.predicted_cell_loads(v_est, w_est, survival),
        float(survival),
        v_est,
        w_est,
    )


def dispatch_row_bytes(m_features: int, n_coords: int, prune_active: bool) -> int:
    """Bytes of one dispatched row in the shuffle buffers: f32 payload
    (plus the mapped coordinates riding as trailing columns under the pivot
    filter) + the id and own-cell int32s. One formula for both executors'
    ``capacity_saved_bytes`` accounting."""
    return 4 * (m_features + (n_coords if prune_active else 0)) + 8


@dataclasses.dataclass(frozen=True)
class PlacementPlan:
    """A static cell→device assignment plus its quality report.

    Slot space: each original cell h occupies ``cell_n_slabs[h]`` consecutive
    slots starting at ``cell_first_slot[h]``; padding slots (``slot_cell ==
    -1``, zero load) round ``n_slots`` up to a multiple of ``n_devices``.
    Dispatch space: ``dispatch_of_slot`` is the permutation the executor
    scatters through — dispatch index ``d·spd + j`` lives on device ``d``
    (``spd = n_slots // n_devices``), exactly like the historical contiguous
    layout, so the plan rides the existing ``all_to_all`` unchanged.
    """

    strategy: str  # "contiguous" | "lpt"
    n_devices: int
    p: int  # original cell count
    n_slots: int  # p + extra slabs + padding; multiple of n_devices
    cell_loads: np.ndarray  # (p,) predicted per-cell verification loads
    cell_first_slot: np.ndarray  # (p,) int32 — first slot of each cell
    cell_n_slabs: np.ndarray  # (p,) int32 ≥ 1 — V-slab count per cell
    slot_cell: np.ndarray  # (n_slots,) int32 — original cell, -1 = padding
    slot_slab: np.ndarray  # (n_slots,) int32 — slab index within the cell
    slot_load: np.ndarray  # (n_slots,) float64 — predicted load per slot
    dispatch_of_slot: np.ndarray  # (n_slots,) int32 permutation slot→dispatch
    certified_bound: float  # provable a-posteriori makespan bound (see module)

    # -- derived views -----------------------------------------------------

    @property
    def slots_per_device(self) -> int:
        return self.n_slots // self.n_devices

    @property
    def slot_of_dispatch(self) -> np.ndarray:
        """(n_slots,) inverse permutation: dispatch index → slot."""
        inv = np.empty(self.n_slots, np.int32)
        inv[self.dispatch_of_slot] = np.arange(self.n_slots, dtype=np.int32)
        return inv

    @property
    def cell_of_dispatch(self) -> np.ndarray:
        """(n_slots,) original cell id per dispatch index (-1 = padding).
        This is the array the verify stage uses as the per-slot de-dup cell
        id, and the driver uses to fold per-slot telemetry back to cells."""
        return self.slot_cell[self.slot_of_dispatch]

    @property
    def device_of_slot(self) -> np.ndarray:
        return (self.dispatch_of_slot // self.slots_per_device).astype(np.int32)

    @property
    def device_loads(self) -> np.ndarray:
        """(D,) predicted load per device under this plan."""
        out = np.zeros(self.n_devices, np.float64)
        np.add.at(out, self.device_of_slot, self.slot_load)
        return out

    @property
    def makespan(self) -> float:
        return float(self.device_loads.max(initial=0.0))

    @property
    def lower_bound(self) -> float:
        """max(mean device load, max slot load) — no schedule of these slots
        on D devices can finish sooner."""
        return float(
            max(
                self.slot_load.sum() / max(self.n_devices, 1),
                self.slot_load.max(initial=0.0),
            )
        )

    @property
    def makespan_ratio(self) -> float:
        """Makespan / lower bound (≥ 1); the Table-3-style balance headline."""
        return self.makespan / max(self.lower_bound, 1e-12)

    @property
    def balance_std(self) -> float:
        """Std of predicted per-device loads (Table 3 STDEV, device-level)."""
        return float(self.device_loads.std())

    @property
    def lpt_factor(self) -> float:
        """Graham's LPT guarantee vs the (unknown) optimum: 4/3 − 1/(3D)."""
        return 4.0 / 3.0 - 1.0 / (3.0 * max(self.n_devices, 1))

    @property
    def n_split_cells(self) -> int:
        return int((self.cell_n_slabs > 1).sum())


def _slot_tables(
    cell_loads: np.ndarray, n_slabs: np.ndarray, n_devices: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Lay cells out into slot space (cell-major, slabs consecutive) and pad
    ``n_slots`` up to a multiple of ``n_devices`` with zero-load -1 slots."""
    p = cell_loads.shape[0]
    first = np.zeros(p, np.int64)
    if p:
        first[1:] = np.cumsum(n_slabs)[:-1]
    n_real = int(n_slabs.sum())
    n_slots = -(-max(n_real, 1) // n_devices) * n_devices
    slot_cell = np.full(n_slots, -1, np.int32)
    slot_slab = np.zeros(n_slots, np.int32)
    slot_load = np.zeros(n_slots, np.float64)
    for h in range(p):
        s = int(n_slabs[h])
        sl = slice(int(first[h]), int(first[h]) + s)
        slot_cell[sl] = h
        slot_slab[sl] = np.arange(s)
        slot_load[sl] = cell_loads[h] / s  # V rows dealt evenly across slabs
    return first.astype(np.int32), slot_cell, slot_slab, slot_load


def plan_placement(
    cell_loads: np.ndarray,
    n_devices: int,
    strategy: str = "lpt",
    split: bool = True,
    max_slabs: int | None = None,
) -> PlacementPlan:
    """Plan the cell→device assignment for the reduce phase.

    ``cell_loads``: (p,) predicted per-cell verification loads — Eq. 33 cell
    costs |V̂_h|·|Ŵ_h| (survival-adjusted when the pivot filter is on), from
    ``cost_model.estimate_from_samples`` / ``estimate_survival_rate``.
    ``strategy``: "contiguous" reproduces the historical ``h → h // (p/D)``
    layout (identity permutation, no splitting — the control arm);
    "lpt" runs heavy-cell splitting + cardinality-constrained LPT.
    ``split``: disable heavy-cell splitting (LPT permutation only).
    ``max_slabs``: cap on slabs per cell (default: ``n_devices``).

    Deterministic: ties in the load sort break by slot id (stable sort), ties
    in device choice by lowest device id — same loads in, same plan out.
    """
    loads = np.asarray(cell_loads, np.float64).reshape(-1)
    if np.any(loads < 0) or not np.all(np.isfinite(loads)):
        raise ValueError("cell loads must be finite and non-negative")
    if strategy not in STRATEGIES:
        raise ValueError(f"unknown placement strategy {strategy!r}; expected {STRATEGIES}")
    p = loads.shape[0]
    d = max(int(n_devices), 1)

    # -- heavy-cell splitting (lpt only) ------------------------------------
    n_slabs = np.ones(p, np.int64)
    if strategy == "lpt" and split and d > 1 and p:
        budget = loads.sum() / d  # per-device budget = mean device load
        if budget > 0:
            cap = max_slabs if max_slabs is not None else d
            over = loads > budget
            n_slabs[over] = np.minimum(
                np.ceil(loads[over] / budget).astype(np.int64), max(int(cap), 1)
            )
    first, slot_cell, slot_slab, slot_load = _slot_tables(loads, n_slabs, d)
    n_slots = slot_cell.shape[0]
    spd = n_slots // d

    dispatch = np.arange(n_slots, dtype=np.int32)
    certified = float("inf")
    if strategy == "lpt":
        # Cardinality-constrained LPT: descending load (stable ⇒ slot-id tie
        # break), each slot to the least-loaded device with a free slot
        # (lowest device id on ties).
        order = np.argsort(-slot_load, kind="stable")
        dev_load = np.zeros(d, np.float64)
        dev_count = np.zeros(d, np.int64)
        dev_slots: list[list[int]] = [[] for _ in range(d)]
        # Per device: was its LAST assignment made while it was the globally
        # least-loaded device? (Graham's argument then applies a-posteriori.)
        last_unconstrained = np.zeros(d, bool)
        last_load = np.zeros(d, np.float64)
        for s in order:
            free = dev_count < spd
            cand = np.where(free, dev_load, np.inf)
            dd = int(np.argmin(cand))  # argmin takes the lowest id on ties
            if slot_load[s] > 0:  # zero-load slots never move the makespan
                last_unconstrained[dd] = dev_load[dd] <= dev_load.min()
                last_load[dd] = slot_load[s]
            dev_load[dd] += slot_load[s]
            dev_count[dd] += 1
            dev_slots[dd].append(int(s))
        for dd in range(d):
            for j, s in enumerate(dev_slots[dd]):
                dispatch[s] = dd * spd + j
        # A-posteriori certificate (see module docstring / docs/COST_MODEL.md):
        # Graham bound when the critical device's last slot was an
        # unconstrained (global-min) choice, else the trivial spd·max bound.
        crit = int(np.argmax(dev_load))
        max_slot = float(slot_load.max(initial=0.0))
        if last_unconstrained[crit]:
            certified = slot_load.sum() / d + (1.0 - 1.0 / d) * float(last_load[crit])
        else:
            certified = spd * max_slot
    else:
        # Contiguous: identity permutation; certificate is just the makespan.
        pass

    plan = PlacementPlan(
        strategy=strategy,
        n_devices=d,
        p=p,
        n_slots=n_slots,
        cell_loads=loads,
        cell_first_slot=first,
        cell_n_slabs=n_slabs.astype(np.int32),
        slot_cell=slot_cell,
        slot_slab=slot_slab,
        slot_load=slot_load,
        dispatch_of_slot=dispatch,
        certified_bound=0.0,  # patched below (needs the frozen plan's makespan)
    )
    if strategy != "lpt":
        certified = plan.makespan
    # fp guard: the certificate is exact in reals; allow accumulation slack.
    certified = float(max(certified, plan.makespan * (1.0 - 1e-12)))
    return dataclasses.replace(plan, certified_bound=certified)


def slot_exact_counts(
    plan: PlacementPlan, v_cnt: np.ndarray, w_cnt: np.ndarray
) -> tuple[np.ndarray, np.ndarray]:
    """Exact per-(source-shard, slot) row counts under the plan.

    ``v_cnt`` / ``w_cnt``: (M, p) exact per-(shard, cell) counts from the
    counting pass. V rows of cell h are dealt to slab j by intra-cell rank
    (``rank % n_slabs``), so shard i's slab j receives
    ``(c + s − 1 − j) // s`` rows with ``c = v_cnt[i, h]`` — the slabs
    partition V exactly (Σ_j = c). W rows replicate into every slab.
    Returned in SLOT order (use ``plan.dispatch_of_slot`` to reorder);
    padding slots count 0. These size the static dispatch capacities.
    """
    v_cnt = np.asarray(v_cnt)
    w_cnt = np.asarray(w_cnt)
    real = plan.slot_cell >= 0
    cell = np.clip(plan.slot_cell, 0, None)
    s = plan.cell_n_slabs[cell].astype(np.int64)  # (n_slots,)
    j = plan.slot_slab.astype(np.int64)
    v_slot = (v_cnt[:, cell].astype(np.int64) + s - 1 - j) // s
    w_slot = w_cnt[:, cell].astype(np.int64)
    v_slot[:, ~real] = 0
    w_slot[:, ~real] = 0
    return v_slot, w_slot


def capacity_saved_bytes(
    plan: PlacementPlan,
    v_cnt: np.ndarray,
    w_cnt: np.ndarray,
    row_bytes: int,
    slack: float = 1.0,
) -> int:
    """Dispatch-buffer bytes the plan saves vs the contiguous global-max
    layout, across the whole mesh.

    The compiled buffers are (n_slots, cap, row) per source shard, per side;
    the contiguous baseline provisions every one of its p slots at the global
    worst-cell capacity, while the plan provisions ``n_slots`` slots at the
    post-split worst-SLOT capacity. Splitting a hot cell shrinks cap_v (the
    hot cell's rows spread over slabs) at the price of extra slots carrying
    replicated W rows — this metric reports the NET effect (negative = the
    plan spends more buffer than it saves; the planner only splits when the
    makespan says it's worth it).
    """
    v_slot, w_slot = slot_exact_counts(plan, v_cnt, w_cnt)
    m = v_cnt.shape[0]

    def cap(c: np.ndarray) -> int:
        return int(np.ceil(max(int(c.max(initial=1)), 1) * slack))

    base = plan.p * (cap(np.asarray(v_cnt)) + cap(np.asarray(w_cnt)))
    new = plan.n_slots * (cap(v_slot) + cap(w_slot))
    return int((base - new) * row_bytes * m)
