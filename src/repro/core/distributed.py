"""Distributed SP-Join on a JAX device mesh (the paper's Spark pipeline,
re-derived as SPMD stages — DESIGN.md §2).

The paper's three phases map onto three jitted ``shard_map`` stages over the
``data`` mesh axis (each device along that axis is one "local node"):

  stage_stats    sampling phase stages 1–2 (Alg. 1 lines 1–4): per-shard
                 exponential-family MLE for every candidate family + chi-square
                 GoF, best-family selection by max confidence, then one
                 ``all_gather`` of the (2m+2)-float parameter packet per node —
                 the paper's "broadcast ⟨F_i(x), c_i⁰, N_i⟩" (line 5), O(M²)
                 scalars on the interconnect, *independent of k*.

  host control   the generative Gibbs chain runs identically on every host
  plane          from the gathered packets (zero sample bytes cross the
                 network — the paper's §4.2 claim, literally). Anchors,
                 labels, and the partition tree are built from those pivots,
                 all replicated deterministic work.

  stage_counts   one cheap counting pass: per-(cell, source-shard) |V| and |W|
                 counts, all-reduced. The host sizes the static dispatch
                 capacities from the *actual* counts (exact-fit planning pass,
                 a beyond-paper TPU adaptation: Spark shuffles dynamically;
                 XLA wants static shapes, so we buy exactness with one tiny
                 extra pass). The cost-model *predicted* capacity (paper
                 §5.1 / sample-scaled) is also computed and reported — the gap
                 between predicted and exact capacity is precisely the
                 sampling-quality metric the paper optimizes.

  stage_verify   map + reduce phases: the fused map kernel (one streamed
                 Pallas pass: anchor distances + kernel-cell assignment +
                 packed whole membership — ``kernels.ops.map_assign``;
                 ``map_fused=False`` keeps the legacy two-broadcast path),
                 capacity-bounded dispatch buffers, ONE ``all_to_all`` over
                 the data axis
                 (the shuffle — with ``prune="pivot"`` the mapped
                 coordinates ride it as trailing payload columns), then
                 per-local-cell blocked verification (pivot-filter L∞
                 pre-mask + Pallas pairdist + fused ≤ δ mask). Pair de-dup
                 happens in the mask epilogue via the min-cell rule.

  host placement the cost model's per-cell predicted loads (same pivot
  plan           sample) feed ``core.placement``'s cell→device planner; the
                 verify stage compiles with the plan's static slot
                 permutation and per-slot capacities (``placement=`` knob).

Skew economics on TPU: a skewed partition no longer straggles — it inflates
the static capacity every device must allocate and stream. The padding ratio
(Σ cap / Σ actual) is therefore the TPU-native analogue of the paper's
"curse of the last reducer", and it is exactly what better pivots shrink.
The placement plan attacks both sides: LPT balances per-device loads and
heavy-cell splitting bounds the worst slot the capacities are sized by
(docs/COST_MODEL.md).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import compat
from repro.core import cost_model, distances, expfam, gof, mapping, partition, sampling
from repro.core import placement as placement_lib
from repro.core import verify as verify_lib
from repro.kernels import ops as kops
from repro.kernels import ref as kref

Array = jnp.ndarray


# ---------------------------------------------------------------------------
# Stage 1: per-shard stats + gather (sampling phase stages 1-2)
# ---------------------------------------------------------------------------


def _fit_all_families(x: Array, valid: Array, t_cells: int, backend: str):
    """Fit every candidate family on one shard; return (packed, conf) stacked
    per family. Families whose support excludes the data self-eliminate."""
    stats = expfam.suff_stats(x, valid)
    nonneg = jnp.all((x >= 0) | ~valid.astype(bool)[:, None])
    packed, confs = [], []
    for fam in expfam.FAMILIES:
        params = expfam.fit(fam, stats)
        u = expfam.cdf(params, x.astype(jnp.float32))
        nu = kops.histogram(u, t_cells, valid.astype(jnp.float32), backend=backend)
        n_eff = valid.astype(jnp.float32).sum()
        expected = jnp.maximum(n_eff / t_cells, 1e-9)
        k_star = (((nu - expected) ** 2) / expected).sum()
        m = x.shape[-1]
        # spjoin-lint: allow[host-sync] -- all Python ints (shape dim + static config), no tracer is concretized
        dof = jnp.maximum(float(m * (t_cells - params.n_params - 1)), 1.0)
        conf = gof.chi2_sf(k_star, dof)
        if fam in ("exponential", "gamma"):
            conf = jnp.where(nonneg, conf, 0.0)
        packed.append(expfam.pack(params))
        confs.append(conf)
    return jnp.stack(packed), jnp.stack(confs)  # (F, 2m+1), (F,)


def make_stage_stats(
    mesh: Mesh,
    axis: str,
    t_cells: int = 8,
    backend: str = "auto",
    use_kernel: bool | None = None,
):
    """Build the jitted stats stage. Input: global (N, m) data sharded on
    ``axis`` plus an (N,) validity mask. Output (replicated): per-node packed
    params (M, 2m+1), confidences (M,), counts (M,)."""
    backend = kops.resolve_backend(backend, use_kernel=use_kernel)

    def per_shard(x: Array, valid: Array):
        packed, confs = _fit_all_families(x, valid, t_cells, backend)
        best = jnp.argmax(confs)
        my_packet = packed[best]
        my_conf = confs[best]
        my_count = valid.astype(jnp.float32).sum()
        packets = jax.lax.all_gather(my_packet, axis)  # (M, 2m+1)
        conf_all = jax.lax.all_gather(my_conf, axis)  # (M,)
        count_all = jax.lax.all_gather(my_count, axis)  # (M,)
        return packets, conf_all, count_all

    shmap = compat.shard_map(
        per_shard,
        mesh=mesh,
        in_specs=(P(axis), P(axis)),
        out_specs=(P(), P(), P()),
        check_vma=False,
    )
    return jax.jit(shmap)


# ---------------------------------------------------------------------------
# Host control plane: replicated Gibbs + partition plan
# ---------------------------------------------------------------------------


def _packed_node_sample(packets: Array, key: jax.Array, e: Array) -> Array:
    """x ~ f_e with the family chosen by the *traced* id in packets[e, 0]."""
    v = packets[e]
    fid = v[0].astype(jnp.int32)

    def branch(fam):
        def f(key):
            return expfam.sample(expfam.unpack(v, fam), key, ())

        return f

    return jax.lax.switch(fid, [branch(f) for f in expfam.FAMILIES], key)


@functools.partial(jax.jit, static_argnames=("k", "length"))
def gibbs_from_packets(
    key: jax.Array, packets: Array, confs: Array, counts: Array, k: int, length: int
) -> tuple[Array, Array]:
    """Alg. 4 as a fixed-length scan over gathered packets (traced families).

    Deterministic in (key, packets): every host/device replays the identical
    chain, so pivots are replicated without communication. Acceptance runs
    on max-normalized confidences (scale-invariant for the C=1 branch; see
    sampling.gibbs_chain). Shortfall/zero-accept compaction is shared with
    the single-host chain (sampling._compact_accepted): tail slots repeat the
    first ACCEPTED row, and an all-rejected chain falls back to the raw
    draws with acceptance telemetry = 0.0 so the driver can warn."""
    conf = jnp.clip(confs.astype(jnp.float32), 1e-6, 1.0)
    conf = jnp.clip(conf / jnp.max(conf), 1e-3, 1.0)
    cnt = jnp.maximum(counts.astype(jnp.float32), 1.0)
    logw_c0 = jnp.log(cnt)
    logw_c1 = jnp.log(cnt) - jnp.log(conf)

    def step(c_prev, key):
        k_e, k_x, k_c = jax.random.split(key, 3)
        logw = jnp.where(c_prev == 1, logw_c1, logw_c0)
        e = jax.random.categorical(k_e, logw)
        x = _packed_node_sample(packets, k_x, e)
        c = (jax.random.uniform(k_c) < conf[e]).astype(jnp.int32)
        return c, (x, c)

    _, (xs, cs) = jax.lax.scan(step, jnp.int32(1), jax.random.split(key, length))
    return sampling._compact_accepted(xs, cs == 1, k)


@dataclasses.dataclass(frozen=True)
class JoinPlan:
    """Everything stage_verify needs, all replicated host-side artifacts."""

    anchors: Array  # (n, m)
    metric: str
    kernel_lo: Array  # (p, n)
    kernel_hi: Array
    whole_lo: Array
    whole_hi: Array
    delta: float
    p: int


def build_join_plan(
    key: jax.Array,
    pivots: Array,
    *,
    delta: float,
    metric: str = "l1",
    p: int = 16,
    n_dims: int = 8,
    partitioner: str = "learning",
    anchor_method: str = "fft",
    n_clusters: int | None = None,
    seed: int = 0,
) -> JoinPlan:
    smap = mapping.select_anchors(key, pivots, n_dims, metric, anchor_method)
    mapped = np.asarray(smap(pivots))
    labels = None
    if partitioner == "learning":
        d = np.asarray(distances.pairwise(pivots, pivots, metric))
        labels = partition.single_linkage_labels(d, n_clusters or 2 * p)
    plan = partition.build_partition(mapped, p, delta, partitioner, labels, seed)
    return JoinPlan(
        anchors=smap.anchors,
        metric=metric,
        kernel_lo=plan.kernel_lo,
        kernel_hi=plan.kernel_hi,
        whole_lo=plan.whole_lo,
        whole_hi=plan.whole_hi,
        delta=delta,
        p=p,
    )


def _map_assign(plan: JoinPlan, x: Array, valid: Array, backend: str, fused: bool = True):
    """Space-map a shard and compute kernel cell + whole membership.

    ``fused=True`` (default) runs the single-pass ``kernels.ops.map_assign``
    op — anchor distances, cell id and the packed membership bitmask in one
    streamed kernel, no (n_loc, p, n) / (n_loc, p) HBM intermediates on the
    Pallas path. ``fused=False`` keeps the historical two-broadcast jnp path
    (the parity control — byte-identical outputs on fixed seeds).

    Also returns the mapped coordinates ``xm`` so callers that need them
    (the counting stage's MBB pass) don't recompute the pairdist."""
    if fused:
        xm, cells, bits = kops.map_assign(
            x, plan.anchors, plan.kernel_lo, plan.kernel_hi,
            plan.whole_lo, plan.whole_hi, plan.metric, backend=backend,
        )
        member = kops.unpack_membership(bits, plan.p)
    else:
        xm = kops.pairdist(x, plan.anchors, plan.metric, backend=backend)  # (n_loc, n)
        inside_k = (xm[:, None, :] >= plan.kernel_lo[None]) & (
            xm[:, None, :] < plan.kernel_hi[None]
        )
        cells = jnp.argmax(inside_k.all(-1), axis=1).astype(jnp.int32)
        member = (
            (xm[:, None, :] >= plan.whole_lo[None])
            & (xm[:, None, :] <= plan.whole_hi[None])
        ).all(-1)
    v = valid.astype(bool)
    return cells, member & v[:, None], v, xm


# ---------------------------------------------------------------------------
# Stage 2: counting pass (exact-fit capacity planning)
# ---------------------------------------------------------------------------


def make_stage_counts(
    mesh: Mesh,
    axis: str,
    plan: JoinPlan,
    backend: str = "auto",
    use_kernel: bool | None = None,
    fused: bool = True,
):
    """Returns jitted fn: (data, valid) ->
    (v_counts (M, p), w_counts (M, p), cell_lo (M, p, n), cell_hi (M, p, n)).

    The per-cell mapped-coordinate MBBs ride along for free (segment
    min/max): the host shrinks each WHOLE box to the δ-expanded MBB of the
    cell's actual members (§Perf H3-it1 — the paper's tighten trick applied
    distributed; Lemma 4 is preserved because every member stays inside its
    own cell's MBB).

    ``fused``: route the map pass through the single-pass
    ``kernels.ops.map_assign`` kernel (default) or the legacy two-broadcast
    jnp path (the benchmark/parity control)."""
    big = jnp.float32(partition.BIG)
    backend = kops.resolve_backend(backend, plan.metric, use_kernel)

    def per_shard(x: Array, valid: Array):
        cells, member, v, xm = _map_assign(plan, x, valid, backend, fused)
        v_cnt = jnp.zeros((plan.p,), jnp.int32).at[cells].add(v.astype(jnp.int32))
        w_cnt = member.sum(0).astype(jnp.int32)
        safe_cells = jnp.where(v, cells, plan.p)  # invalid -> dropped
        lo = jnp.full((plan.p + 1, xm.shape[1]), big).at[safe_cells].min(xm)[: plan.p]
        hi = jnp.full((plan.p + 1, xm.shape[1]), -big).at[safe_cells].max(xm)[: plan.p]
        return (
            jax.lax.all_gather(v_cnt, axis),  # (M, p)
            jax.lax.all_gather(w_cnt, axis),
            jax.lax.all_gather(lo, axis),  # (M, p, n)
            jax.lax.all_gather(hi, axis),
        )

    shmap = compat.shard_map(
        per_shard, mesh=mesh, in_specs=(P(axis), P(axis)),
        out_specs=(P(), P(), P(), P()),
        check_vma=False,
    )
    return jax.jit(shmap)


# ---------------------------------------------------------------------------
# Stage 3: dispatch (all_to_all) + blocked verify
# ---------------------------------------------------------------------------


def _scatter_dispatch(
    rows: Array,  # (n_loc, m)
    ids: Array,  # (n_loc,) global ids
    cells_of_row: Array,  # (n_loc,) destination cell (or p = drop)
    own_cell: Array,  # (n_loc,) kernel cell of the row (carried for dedup)
    p: int,
    cap: int,
):
    """Scatter rows into a (p, cap, ...) buffer by (dest slot, intra-slot rank).

    ``cells_of_row`` is the destination DISPATCH SLOT of each row (the kernel
    cell under contiguous placement; the planner's permuted/slab slot under
    LPT — see ``core.placement``). Rows whose slot == p, or whose rank
    overflows cap, are dropped (mode=drop); the overflow count is returned so
    the caller can surface it. Vectorized, O(n_loc · p) for the rank
    computation (one cumsum per slot column)."""
    onehot = (cells_of_row[:, None] == jnp.arange(p)[None, :]).astype(jnp.int32)
    rank = jnp.cumsum(onehot, axis=0) - 1  # (n_loc, p)
    rank_of_row = jnp.take_along_axis(
        rank, jnp.clip(cells_of_row, 0, p - 1)[:, None], axis=1
    )[:, 0]
    slot_ok = (cells_of_row < p) & (rank_of_row < cap)
    cc = jnp.where(slot_ok, cells_of_row, p)  # p -> out of bounds -> dropped
    rr = jnp.clip(rank_of_row, 0, cap - 1)

    buf = jnp.zeros((p, cap, rows.shape[-1]), rows.dtype).at[cc, rr].set(
        rows, mode="drop"
    )
    buf_ids = jnp.full((p, cap), -1, jnp.int32).at[cc, rr].set(
        ids.astype(jnp.int32), mode="drop"
    )
    buf_cell = jnp.full((p, cap), -1, jnp.int32).at[cc, rr].set(
        own_cell.astype(jnp.int32), mode="drop"
    )
    overflow = ((cells_of_row < p) & (rank_of_row >= cap)).sum()
    return buf, buf_ids, buf_cell, overflow


@dataclasses.dataclass(frozen=True)
class _RoutingTables:
    """Static slot-routing tables of a placement plan, baked into stage
    traces. One construction shared by the join's verify stage and the
    serving stage (``make_stage_serve``) so the two can never disagree on
    how a cell maps to dispatch slots."""

    p: int
    n_slots: int
    first_slot: Array  # (p,) first slot of each cell
    n_slabs: Array  # (p,) V-slab count per cell
    disp_of_slot: Array  # (n_slots,) slot -> dispatch permutation
    w_col_of_disp: Array  # (n_slots,) membership gather column per dispatch
    #   index (padding slots -> the always-False extra column p)
    cell_id_of_disp: Array  # (n_slots,) original cell id, -1 = padding


def _routing_tables(pl: placement_lib.PlacementPlan) -> _RoutingTables:
    p = pl.p
    cell_of_disp_np = pl.cell_of_dispatch
    return _RoutingTables(
        p=p,
        n_slots=pl.n_slots,
        first_slot=jnp.asarray(pl.cell_first_slot, jnp.int32),
        n_slabs=jnp.asarray(pl.cell_n_slabs, jnp.int32),
        disp_of_slot=jnp.asarray(pl.dispatch_of_slot, jnp.int32),
        w_col_of_disp=jnp.asarray(
            np.where(cell_of_disp_np >= 0, cell_of_disp_np, p), jnp.int32
        ),
        cell_id_of_disp=jnp.asarray(cell_of_disp_np, jnp.int32),
    )


def _make_v_dispatch(rt: _RoutingTables, cap_v: int):
    """Each valid row -> its kernel cell's dispatch slot (a heavy cell's
    rows are dealt round-robin over its slabs by intra-cell rank)."""
    p, n_slots = rt.p, rt.n_slots

    def v_dispatch(x: Array, ids: Array, cells: Array, v: Array):
        v_cells = jnp.where(v, cells, p)
        safe = jnp.clip(v_cells, 0, p - 1)
        onehot = (v_cells[:, None] == jnp.arange(p)[None, :]).astype(jnp.int32)
        rank_in_cell = jnp.take_along_axis(
            jnp.cumsum(onehot, axis=0) - 1, safe[:, None], axis=1
        )[:, 0]
        slot = rt.first_slot[safe] + rank_in_cell % rt.n_slabs[safe]
        dest = jnp.where(v_cells < p, rt.disp_of_slot[slot], n_slots)
        return _scatter_dispatch(x, ids, dest, cells, n_slots, cap_v)

    return v_dispatch


def _make_w_dispatch(rt: _RoutingTables, cap_w: int):
    """Each valid row -> every whole-member cell's slot(s) — replicated into
    each slab of a split cell (ranked per dispatch slot)."""
    n_slots = rt.n_slots

    def w_dispatch(x: Array, ids: Array, cells: Array, member: Array):
        member_ext = jnp.concatenate(
            [member, jnp.zeros((member.shape[0], 1), member.dtype)], axis=1
        )
        member_d = member_ext[:, rt.w_col_of_disp]  # (n_loc, n_slots) disp order
        w_rank = jnp.cumsum(member_d.astype(jnp.int32), axis=0) - 1
        slot_ok = member_d & (w_rank < cap_w)
        cc = jnp.where(slot_ok, jnp.arange(n_slots)[None, :], n_slots)
        rr = jnp.clip(w_rank, 0, cap_w - 1)
        w_buf = (
            jnp.zeros((n_slots, cap_w, x.shape[-1]), x.dtype)
            .at[cc, rr]
            .set(x[:, None, :], mode="drop")
        )
        w_ids = (
            jnp.full((n_slots, cap_w), -1, jnp.int32)
            .at[cc, rr]
            .set(jnp.broadcast_to(ids.astype(jnp.int32)[:, None], cc.shape), mode="drop")
        )
        w_own = (
            jnp.full((n_slots, cap_w), -1, jnp.int32)
            .at[cc, rr]
            .set(jnp.broadcast_to(cells[:, None], cc.shape), mode="drop")
        )
        overflow_w = (member_d & (w_rank >= cap_w)).sum()
        return w_buf, w_ids, w_own, overflow_w

    return w_dispatch


def _make_exchange(axis: str, M: int, spd: int):
    """The shuffle: ONE ``all_to_all`` over ``axis`` per buffer, plus the
    (M, spd, cap, ...) -> per-local-slot (spd, M·cap, ...) flattening."""

    def exchange(buf):
        # (n_slots, cap, ...) -> (M, spd, cap, ...) -> a2a -> received
        # from every source shard: (M, spd, cap, ...).
        shaped = buf.reshape(M, spd, *buf.shape[1:])
        return jax.lax.all_to_all(shaped, axis, split_axis=0, concat_axis=0)

    def flat(r):
        return jnp.moveaxis(r, 0, 1).reshape(spd, M * r.shape[2], *r.shape[3:])

    return exchange, flat


@dataclasses.dataclass(frozen=True)
class VerifyConfig:
    """Static knobs compiled into the verify stage.

    ``cap_v`` / ``cap_w``: per-(cell, source-shard) dispatch capacities — the
    static shapes the ``all_to_all`` buffers compile with (exact-fit planned
    by the counting pass, times ``capacity_slack``).
    ``prune``: "none" | "pivot" — pivot-filter pruning in the verify tiles.
    With "pivot" each row's mapped coordinates are concatenated onto its
    payload so they ride the SAME ``all_to_all`` as the data (n extra f32
    columns of shuffle volume), and the per-cell verification masks pairs
    whose L∞ lower bound exceeds δ before exact evaluation. Metrics without
    the triangle inequality (cosine, dot) resolve back to "none" —
    capability, not error (see ``core.verify.resolve_prune``).
    """

    cap_v: int  # per-(cell, source-shard) kernel-row capacity
    cap_w: int  # per-(cell, source-shard) whole-row capacity
    emit_pairs: bool = False  # also return hit masks + id buffers (tests)
    emit: str = "mask"  # pair-emission path when emit_pairs: "mask" returns
    #   the per-slot hit masks + id buffers; "compact" compacts each slot's
    #   hits in-trace (ref.compact_mask under vmap) into a static
    #   (pair_cap, 2) global-id buffer + true-count — an output-sensitive
    #   stage OUTPUT, not a new collective: the pairs ride the stage's
    #   existing out_specs, the all_to_all budget is unchanged. A count
    #   above pair_cap is the overflow sentinel (buffer unspecified, count
    #   exact); the driver re-sizes and re-runs, mask path as last resort.
    pair_cap: int = 0  # static per-slot pair capacity (emit="compact" only)
    backend: str = "auto"  # numpy | pallas | auto (see kernels.ops)
    use_kernel: bool | None = None  # legacy override of backend
    prune: str = "none"  # pivot-filter pruning: "none" | "pivot"
    delta_bound: float | None = None  # scale-aware fp band for the filter
    #   (verify.prune_band; None -> the scale-free ref.prune_delta default)
    map_fused: bool = True  # single-pass map kernel (False: legacy two-pass
    #   jnp broadcasts — the parity/benchmark control, byte-identical output)


def make_stage_verify(
    mesh: Mesh, axis: str, plan: JoinPlan, vcfg: VerifyConfig, cross: bool = False,
    pl: placement_lib.PlacementPlan | None = None,
):
    """The fused map+shuffle+reduce stage.

    Per shard: assign -> dispatch buffers keyed (dest slot, rank) ->
    all_to_all over ``axis`` -> per-local-slot masked blocked verification.

    Cell -> device is governed by ``pl`` (``core.placement``): dispatch slot
    ``d·spd + j`` lives on device ``d``. The default (``pl=None``) is the
    historical contiguous layout — cell h on device h // (p/M), identity
    permutation, no slabs; requires p % M == 0 (the driver rounds p up).
    Under an LPT plan the scatter targets are permuted through
    ``pl.dispatch_of_slot`` and a heavy cell's V rows are dealt round-robin
    over its slabs (W rows replicated into each slab) — same buffers, same
    single ``all_to_all``, byte-identical pair sets (each candidate pair
    lands in exactly one slab and every slab keeps the cell's original id
    for the de-dup rule).

    ``cross=False`` (self-join): V and W buffers are both scattered from the
    one data set; the min-cell de-dup rule applies. ``cross=True`` (R×S):
    the stage takes (xr, valid_r, ids_r, xs, valid_s, ids_s) — V buffers are
    scattered from R's shards (kernel cells), W buffers from S's shards
    (whole membership), one ``all_to_all`` each, and the de-dup rule
    degenerates to padding validity (each R row has a unique kernel cell).

    With ``vcfg.prune="pivot"`` the mapped coordinates (already computed by
    the in-stage ``_map_assign``) are appended to each row's payload before
    dispatch — the pivot distances ride the same ``all_to_all`` as the data
    — and split back off at the destination cell, where ``verify_tile``
    applies the L∞ pre-mask. Hit masks (hence emitted pairs) are identical
    to ``prune="none"``; the ``candidates`` output tracks how many pairs
    survived the filter (pruning-rate telemetry).
    """
    M = mesh.shape[axis]
    p = plan.p
    if pl is None:  # historical contiguous layout: cell h -> device h//(p/M)
        pl = placement_lib.plan_placement(
            np.zeros(p, np.float64), M, strategy="contiguous"
        )
    assert pl.p == p, f"placement planned for p={pl.p}, stage has p={p}"
    rt = _routing_tables(pl)
    n_slots = rt.n_slots
    assert n_slots % M == 0, f"n_slots={n_slots} must be a multiple of {axis}={M}"
    spd = n_slots // M  # dispatch slots per device
    cap_v, cap_w = vcfg.cap_v, vcfg.cap_w
    map_fused = vcfg.map_fused
    backend = kops.resolve_backend(vcfg.backend, plan.metric, vcfg.use_kernel)
    if vcfg.prune == "window":
        # Host-streamed range pruning has no analogue inside a static
        # shard_map trace; the distributed stage filters per pair.
        raise ValueError('the distributed stage supports prune="none" | "pivot"')
    prune = verify_lib.resolve_prune(vcfg.prune, plan.metric, True)
    emit = verify_lib.resolve_emit(vcfg.emit, plan.metric) if vcfg.emit_pairs else "mask"
    if emit == "compact" and vcfg.pair_cap < 1:
        raise ValueError('emit="compact" needs pair_cap >= 1 (a static out-shape)')
    n_dims = plan.anchors.shape[0]
    delta_bound = vcfg.delta_bound  # static — shared by mask + telemetry

    # Static routing tables + dispatch/shuffle closures (identity permutation
    # under contiguous placement) — shared with make_stage_serve.
    cell_id_of_disp = rt.cell_id_of_disp
    v_dispatch = _make_v_dispatch(rt, cap_v)
    w_dispatch = _make_w_dispatch(rt, cap_w)
    exchange, flat = _make_exchange(axis, M, spd)

    def shuffle_and_verify(v_parts, w_parts, overflow):
        """ONE all_to_all per side over the data axis, then per-local-slot
        masked blocked verification."""
        fv, fvi, fvo = (flat(exchange(b)) for b in v_parts)
        fw, fwi, fwo = (flat(exchange(b)) for b in w_parts)

        my_dev = jax.lax.axis_index(axis)
        # De-dup runs against the slot's ORIGINAL cell id (slabs share it),
        # so placement can never change which pairs a cell emits.
        local_cells = cell_id_of_disp[my_dev * spd + jnp.arange(spd)]

        # Distances, threshold, padding validity, the de-dup rule and the
        # pivot filter all live in repro.core.verify — the same code path
        # the reference executor streams through.
        def verify_cell(vx, vids, vown, wx, wids, wown, cell_id):
            pv = pw = None
            if prune == "pivot":
                # Mapped coords rode the payload's trailing n_dims columns.
                vx, pv = vx[:, :-n_dims], vx[:, -n_dims:]
                wx, pw = wx[:, :-n_dims], wx[:, -n_dims:]
            mask = verify_lib.verify_tile(
                vx, wx, vids, wids, wown, cell_id,
                delta=plan.delta, metric=plan.metric, backend=backend,
                cross=cross, pv=pv, pw=pw, prune=prune,
                delta_bound=delta_bound,
            )
            n_verified = verify_lib.pair_validity(vids, wids).sum()
            if prune == "pivot":
                n_cand = verify_lib.candidate_mask(
                    pv, pw, vids, wids, plan.delta, delta_bound
                ).sum()
            else:
                n_cand = n_verified
            return mask, n_verified, n_cand

        masks, n_verified, n_cand = jax.vmap(verify_cell)(
            fv, fvi, fvo, fw, fwi, fwo, local_cells
        )
        hit_count = masks.sum()
        out = {
            "hits": hit_count.astype(jnp.float32)[None],
            "verified": n_verified.sum().astype(jnp.float32)[None],
            "candidates": n_cand.sum().astype(jnp.float32)[None],
            # Per DISPATCH SLOT (== per cell under contiguous placement); the
            # driver folds slabs back to cells and devices host-side.
            "per_cell_verified": n_verified.astype(jnp.float32),
            "overflow": overflow.astype(jnp.float32)[None],
        }
        if vcfg.emit_pairs:
            if emit == "compact":
                # Per-slot on-device compaction: masks are already validity-
                # and de-dup-filtered (verify_tile -> ref.emit_mask), so the
                # compaction just gathers global ids. Pure jnp, vmap-safe —
                # the kernel dispatch and collective budget are untouched.
                cpairs, ccounts = jax.vmap(
                    lambda mk, vi, wi: kref.compact_mask(mk, vi, wi, vcfg.pair_cap)
                )(masks, fvi, fwi)
                out["pairs"] = cpairs  # (spd, pair_cap, 2) int32, -1 padded
                out["pair_counts"] = ccounts  # (spd,) int32 TRUE totals
            else:
                out["masks"] = masks  # (spd, M*cap_v, M*cap_w)
                out["v_ids"] = fvi
                out["w_ids"] = fwi
        return out

    def payload(x: Array, xm: Array) -> Array:
        """Dispatch rows: the raw features, plus — under prune="pivot" — the
        mapped coordinates as trailing columns (same all_to_all, no second
        shuffle)."""
        if prune == "pivot":
            return jnp.concatenate([x, xm.astype(x.dtype)], axis=1)
        return x

    if cross:
        def per_shard(xr: Array, valid_r: Array, ids_r: Array,
                      xs: Array, valid_s: Array, ids_s: Array):
            cells_r, _, v_r, xm_r = _map_assign(plan, xr, valid_r, backend, map_fused)
            cells_s, member_s, _, xm_s = _map_assign(plan, xs, valid_s, backend, map_fused)
            v_buf, v_ids, v_own, overflow_v = v_dispatch(
                payload(xr, xm_r), ids_r, cells_r, v_r
            )
            w_buf, w_ids, w_own, overflow_w = w_dispatch(
                payload(xs, xm_s), ids_s, cells_s, member_s
            )
            return shuffle_and_verify(
                (v_buf, v_ids, v_own), (w_buf, w_ids, w_own),
                overflow_v + overflow_w,
            )
        in_specs = (P(axis),) * 6
    else:
        def per_shard(x: Array, valid: Array, ids: Array):
            cells, member, v, xm = _map_assign(plan, x, valid, backend, map_fused)
            rows = payload(x, xm)
            v_buf, v_ids, v_own, overflow_v = v_dispatch(rows, ids, cells, v)
            w_buf, w_ids, w_own, overflow_w = w_dispatch(rows, ids, cells, member)
            return shuffle_and_verify(
                (v_buf, v_ids, v_own), (w_buf, w_ids, w_own),
                overflow_v + overflow_w,
            )
        in_specs = (P(axis),) * 3

    out_specs = {
        "hits": P(axis),
        "verified": P(axis),
        "candidates": P(axis),
        "per_cell_verified": P(axis),
        "overflow": P(axis),
    }
    if vcfg.emit_pairs:
        if emit == "compact":
            out_specs.update({"pairs": P(axis), "pair_counts": P(axis)})
        else:
            out_specs.update({"masks": P(axis), "v_ids": P(axis), "w_ids": P(axis)})

    shmap = compat.shard_map(
        per_shard,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        check_vma=False,
    )
    return jax.jit(shmap)


# ---------------------------------------------------------------------------
# Driver: the end-to-end distributed join
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DistJoinResult:
    """Driver-level result + telemetry of one distributed join.

    ``n_verifications`` is the candidate pair area (Σ_h |V_h|·|W_h| over
    dispatched buffers — the paper's Fig. 12 metric, independent of prune
    mode); ``n_candidates`` is the subset surviving the pivot filter, i.e.
    the pairs that actually reach exact metric evaluation (== n_verifications
    when pruning is off).
    """

    n_hits: int
    n_verifications: int
    per_cell_verified: np.ndarray  # (p,) — Table 3 balance metric
    overflow: int
    capacity_padding: float  # Sigma cap / Sigma actual (TPU skew metric)
    predicted_cap_w: int  # cost-model capacity (sample-scaled)
    exact_cap_w: int
    node_confidences: np.ndarray
    accept_rate: float
    pairs: np.ndarray | None = None  # (n_pairs, 2) when emit_pairs; self-join
    #   columns are (min, max) over one set — R×S: (i ∈ R, j ∈ S)
    duplication: float = 0.0  # Σ_slots |W_slot| / |S| (|S|=N for self) — the
    #   ACTUAL S-side shuffle amplification: == the paper's Σ|W_h|/|S| under
    #   contiguous placement, and additionally counts the per-slab W replicas
    #   when heavy-cell splitting engages (splitting buys balance with bytes)
    n_candidates: int = 0  # pairs surviving the pivot filter (exact evals)
    pruning_rate: float = 0.0  # 1 − n_candidates / n_verifications
    predicted_survival: float = 1.0  # cost-model (sample-based) survival est.
    prune: str = "none"  # resolved prune mode the stage compiled with
    placement: str = "contiguous"  # cell→device strategy the stage compiled
    placement_plan: Any = None  # the core.placement.PlacementPlan (telemetry)
    device_loads: np.ndarray | None = None  # (M,) MEASURED verifications/dev
    balance_std: float = 0.0  # std of measured per-device loads (Table 3)
    makespan_ratio: float = 1.0  # max/mean of measured per-device loads
    capacity_saved_bytes: int = 0  # dispatch-buffer bytes the plan saved
    #   vs the contiguous global-max layout (negative = plan spends more)
    emit: str = "mask"  # pair-emission path the stage actually ran with
    #   (after capability resolution and any overflow fallback)
    n_overflow_retries: int = 0  # compact-emission stage re-runs forced by
    #   the overflow sentinel (same counter semantics as VerifyStats)


def _pad_shard_set(x: Array, M: int, sharding) -> tuple[Array, Array, Array, int]:
    """Pad a set to a multiple of M rows (≥ M, so empty sets still shard),
    build validity + global-id vectors, and device_put all three."""
    n, m = x.shape
    pad = (-n) % M or (M if n == 0 else 0)
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, m), x.dtype)])
    valid = (jnp.arange(n + pad) < n).astype(jnp.float32)
    ids = jnp.arange(n + pad, dtype=jnp.int32)
    return (
        jax.device_put(x, sharding),
        jax.device_put(valid, sharding),
        jax.device_put(ids, sharding),
        n,
    )


def distributed_join(
    data: Array,
    *,
    mesh: Mesh,
    axis: str = "data",
    delta: float,
    metric: str = "l1",
    k: int = 1024,
    p: int | None = None,
    n_dims: int = 8,
    sampler: str = "generative",
    partitioner: str = "learning",
    t_cells: int = 8,
    emit_pairs: bool = False,
    emit: str = "mask",
    backend: str = "auto",
    use_kernel: bool | None = None,
    capacity_slack: float = 1.0,
    tighten: bool = True,
    prune: str = "pivot",
    map_fused: bool = True,
    placement: str = "lpt",
    seed: int = 0,
    s: Array | None = None,
) -> DistJoinResult:
    """End-to-end distributed join of ``data`` (N, m) on ``mesh``.

    Self-join by default. Pass ``s`` (N_s, m) for the two-set R×S join:
    ``data`` is R, ``s`` is S; per-set stats are gathered and pooled (2M
    "local nodes") so pivots cover both distributions, the counting pass and
    exact-fit capacities are computed per set (V capacity from R's kernel
    counts, W capacity from S's whole counts), and the verify stage scatters
    V buffers from R's shards and W buffers from S's — one ``all_to_all``
    each. ``emit_pairs`` then yields (i ∈ R, j ∈ S) pairs. Passing the same
    object as both (R = S aliasing) routes through the self-join path.

    ``sampler``: "generative" (default, Alg. 3/4) or "random" (baseline —
    pivots drawn uniformly from an all-gathered subsample, the prior-work
    scheme). "distribution" (Alg. 2) is intentionally routed through the
    single-host executor; its comm pattern (sample rows on the wire) is what
    the generative scheme was designed to remove.

    ``backend``: verify/mapping kernel dispatch ("numpy" | "pallas" | "auto");
    the legacy ``use_kernel`` bool overrides it when given. Unlike the
    single-host executor (whose verify engine falls back to the jnp path for
    kernel-less metrics), the distributed stages require a kernel metric on
    every path — fail fast with the supported set rather than deep in a
    shard_map trace.

    ``prune``: "pivot" (default) masks out candidate pairs whose L∞
    lower bound over the mapped coordinates exceeds δ before exact
    evaluation; the coordinates ride the dispatch ``all_to_all`` as trailing
    payload columns. Results are byte-identical to ``prune="none"`` — the
    bound never eliminates a true hit — and the pruning rate is reported in
    the result. Cosine (no triangle inequality) resolves back to "none".

    ``map_fused``: "pivot"-style toggle for the map phase — True (default)
    runs the single-pass fused map kernel in the counting and verify stages;
    False keeps the legacy two-broadcast jnp path. On the numpy backend the
    two are byte-identical (same XLA expressions); on the Pallas backend the
    coordinate fp low bits may differ at box edges, which can move an object
    between adjacent cells without ever changing the emitted pair set (the
    join is exact under any containment-consistent assignment).

    ``emit``: pair-emission path when ``emit_pairs`` — "mask" (default)
    reads back the per-slot hit masks and compacts on the host; "compact"
    compacts on device into static per-slot pair buffers sized from the
    cost model's survival estimate (``VerifyConfig.emit``) and retries at
    the next capacity bucket on overflow (the counter is exact), falling
    back to "mask" after a bounded number of retries. Pair sets are
    byte-identical either way; ``DistJoinResult.emit`` /
    ``n_overflow_retries`` report what actually ran.

    ``placement``: "lpt" (default) | "contiguous" — the cell→device plan of
    the reduce phase (``core.placement``). "contiguous" is the historical
    layout (cell h on device h // (p/M), one global worst-cell capacity);
    "lpt" plans a skew-aware assignment from the cost model's per-cell
    predicted loads (LPT bin packing + heavy-cell V-slab splitting) and
    sizes the static capacities from the planned per-slot loads. Pair sets
    are byte-identical under either — placement only moves work between
    devices. Plan + measured balance land in the result
    (``placement_plan``, ``device_loads``, ``balance_std``,
    ``makespan_ratio``, ``capacity_saved_bytes``).
    """
    if not kops.supports_kernel(metric):
        raise ValueError(
            f"distributed executor supports kernel metrics only ({kops.METRICS}); "
            f"got {metric!r} — use repro.core.spjoin for reference-path metrics"
        )
    if s is data:
        s = None  # R = S aliasing: the canonical semantics is the self-join
    cross = s is not None
    backend = kops.resolve_backend(backend, metric, use_kernel)
    M = mesh.shape[axis]
    key = jax.random.PRNGKey(seed)
    n, m = data.shape
    # Pre-padding host pools, only materialized for the random sampler (the
    # generative default never moves sample rows off-device).
    r_host = np.asarray(data) if sampler == "random" else None
    sharding = NamedSharding(mesh, P(axis))
    data, valid, ids, _ = _pad_shard_set(jnp.asarray(data), M, sharding)
    if cross:
        s_host = np.asarray(s) if sampler == "random" else None
        s_arr, valid_s, ids_s, n_s = _pad_shard_set(jnp.asarray(s), M, sharding)
    else:
        n_s = n

    p = p or 2 * M
    p = int(np.ceil(p / M) * M)

    # ---- sampling phase -----------------------------------------------------
    stats_fn = make_stage_stats(mesh, axis, t_cells, backend)
    packets, confs, counts = jax.tree.map(np.asarray, stats_fn(data, valid))
    if cross:
        # S's shards are additional "local nodes": pool both sets' packets so
        # the replicated Gibbs chain samples from the R∪S mixture.
        pk_s, cf_s, ct_s = jax.tree.map(np.asarray, stats_fn(s_arr, valid_s))
        packets = np.concatenate([packets, pk_s])
        confs = np.concatenate([confs, cf_s])
        counts = np.concatenate([counts, ct_s])
        # All-padding shards (|S| < M, or empty S) carry no distribution.
        keep = counts > 0
        packets, confs, counts = packets[keep], confs[keep], counts[keep]

    k_gibbs, k_anchor = jax.random.split(key)
    accept_rate = 1.0
    if sampler == "generative":
        conf_n = np.clip(confs / max(confs.max(), 1e-6), 1e-3, 1.0)
        c_min = float(np.clip(conf_n.min(), 0.05, 1.0))
        length = int(np.ceil(k / c_min * 1.5)) + 8
        pivots, acc = gibbs_from_packets(
            k_gibbs, jnp.asarray(packets), jnp.asarray(confs), jnp.asarray(counts), k, length
        )
        accept_rate = float(acc)
        if accept_rate <= 0.0:
            warnings.warn(
                "gibbs_from_packets accepted no draws (all node confidences "
                "≈ 0); pivots fall back to raw chain draws", stacklevel=2,
            )
    elif sampler == "random":
        pool = np.concatenate([r_host, s_host]) if cross else r_host
        idx = jax.random.choice(
            k_gibbs, pool.shape[0], shape=(min(k, pool.shape[0]),), replace=False
        )
        pivots = jnp.asarray(pool)[idx]
    else:
        raise ValueError(f"distributed sampler must be generative|random, got {sampler!r}")

    # ---- control plane ------------------------------------------------------
    plan = build_join_plan(
        k_anchor,
        pivots,
        delta=delta,
        metric=metric,
        p=p,
        n_dims=n_dims,
        partitioner=partitioner,
        seed=seed,
    )

    # ---- counting pass + capacity planning ----------------------------------
    # V capacities always come from R's kernel counts; W capacities from the
    # W-side set's whole counts (S when cross, R itself when self).
    counts_fn = make_stage_counts(mesh, axis, plan, backend, fused=map_fused)
    v_cnt, w_cnt, cell_lo, cell_hi = jax.tree.map(
        np.asarray, counts_fn(data, valid)
    )  # (M, p[, n])
    if cross and not tighten:
        # With tighten the S recount below supersedes this pass entirely.
        _, w_cnt, _, _ = jax.tree.map(np.asarray, counts_fn(s_arr, valid_s))

    if tighten:
        # H3-it1: whole box := delta-expanded MBB of the cell's members.
        # Kernel-cell MBBs come from R (the V side) in both modes: Lemma 4
        # puts every within-δ W partner inside the δ-expanded R MBB.
        glo = cell_lo.min(0)  # (p, n) across shards
        ghi = cell_hi.max(0)
        empty = glo > ghi  # no members anywhere
        glo = np.where(empty, partition.BIG, glo)
        ghi = np.where(empty, -partition.BIG, ghi)
        plan = dataclasses.replace(
            plan,
            whole_lo=jnp.asarray(glo - plan.delta, jnp.float32),
            whole_hi=jnp.asarray(ghi + plan.delta, jnp.float32),
        )
        # W counts changed: one cheap recount against the tightened plan
        # (kernel assignment — the V counts — is unaffected by whole boxes).
        counts_fn = make_stage_counts(mesh, axis, plan, backend, fused=map_fused)
        if cross:
            _, w_cnt, _, _ = jax.tree.map(np.asarray, counts_fn(s_arr, valid_s))
        else:
            v_cnt, w_cnt, _, _ = jax.tree.map(np.asarray, counts_fn(data, valid))

    # Cost-model prediction from the pivots alone (what a single-pass system
    # would have to provision) — reported for the EXPERIMENTS Table 3 story,
    # and the input of the placement planner below.
    piv_mapped = kops.pairdist(pivots, plan.anchors, metric, backend=backend)
    piv_cells = partition.assign_kernel(
        partition.PartitionPlan(plan.kernel_lo, plan.kernel_hi, plan.whole_lo, plan.whole_hi, delta),
        piv_mapped,
    )
    piv_member = partition.whole_membership(
        partition.PartitionPlan(plan.kernel_lo, plan.kernel_hi, plan.whole_lo, plan.whole_hi, delta),
        piv_mapped,
    )
    if prune == "window":
        raise ValueError('distributed_join supports prune="none" | "pivot"')
    prune_resolved = verify_lib.resolve_prune(prune, metric, True)
    delta_bound = (
        verify_lib.prune_band(delta, metric, data, s_arr if cross else None)
        if prune_resolved == "pivot"
        else None
    )

    # ---- placement plan (cost-model-guided reduce placement) ----------------
    # Predicted per-cell verification loads (Eq. 33 costs from the pivot
    # sample, survival-adjusted — the fraction of candidate pivot pairs
    # surviving the L∞ bound forecasts the post-filter exact-evaluation
    # fraction) drive the cell→device plan; the EXACT counting-pass counts,
    # re-laid-out per planned slot, size the static capacities — so placement
    # never risks overflow, it only moves work and shrinks the worst-slot
    # capacity. In R×S mode the W estimate scales with |S|, not |R|; caveat:
    # the pivots approximate the POOLED R∪S mixture, so when the two
    # distributions diverge the estimates are biased toward R's geography —
    # only the exact-count capacities govern correctness; predicted_cap_w is
    # the "single-pass provisioning" story metric.
    cell_loads, predicted_survival, _, w_est = placement_lib.planner_inputs(
        np.asarray(piv_mapped), np.asarray(piv_cells), np.asarray(piv_member),
        n, n_s, delta, prune_resolved == "pivot",
    )
    predicted_cap_w = cost_model.predict_capacity(w_est, M, slack=1.25)
    pl = placement_lib.plan_placement(cell_loads, M, strategy=placement)
    v_slot, w_slot = placement_lib.slot_exact_counts(pl, v_cnt, w_cnt)
    exact_cap_v = max(int(v_slot.max(initial=0)), 1)
    exact_cap_w = max(int(w_slot.max(initial=0)), 1)
    cap_v = int(np.ceil(exact_cap_v * capacity_slack))
    cap_w = int(np.ceil(exact_cap_w * capacity_slack))
    cap_saved = placement_lib.capacity_saved_bytes(
        pl, v_cnt, w_cnt,
        placement_lib.dispatch_row_bytes(m, n_dims, prune_resolved == "pivot"),
        slack=capacity_slack,
    )

    # ---- dispatch + verify ---------------------------------------------------
    # Compact emission: static per-slot pair capacity from the cost model's
    # survival estimate (an overestimate of the hit rate — the safe
    # direction), on the same quarter-pow2 bucket ladder as the engine.
    emit_resolved = verify_lib.resolve_emit(emit, metric) if emit_pairs else "mask"
    slot_area = max(int(v_slot.max(initial=0)) * int(w_slot.max(initial=0)), 1)
    pair_cap = 0
    if emit_resolved == "compact":
        est = int(slot_area * min(predicted_survival * verify_lib.EMIT_SLACK, 1.0))
        pair_cap = verify_lib.bucket_size(est + verify_lib._EMIT_FLOOR, slot_area)
    vcfg = VerifyConfig(
        cap_v=cap_v, cap_w=cap_w, emit_pairs=emit_pairs, backend=backend,
        prune=prune, delta_bound=delta_bound, map_fused=map_fused,
        emit=emit_resolved, pair_cap=pair_cap,
    )
    n_overflow_retries = 0
    for attempt in range(verify_lib._MAX_OVERFLOW_RETRIES + 2):
        verify_fn = make_stage_verify(mesh, axis, plan, vcfg, cross=cross, pl=pl)
        out = (
            verify_fn(data, valid, ids, s_arr, valid_s, ids_s)
            if cross
            else verify_fn(data, valid, ids)
        )
        if vcfg.emit != "compact":
            break
        max_count = int(np.asarray(out["pair_counts"]).max(initial=0))
        if max_count <= vcfg.pair_cap:
            break
        # Overflow sentinel: the counts are TRUE totals, so one re-size is
        # exact; a bounded ladder guards monkeypatched/adversarial sizing,
        # then the mask path — emitted pairs are identical on every rung.
        n_overflow_retries += 1
        if attempt >= verify_lib._MAX_OVERFLOW_RETRIES:
            vcfg = dataclasses.replace(vcfg, emit="mask", pair_cap=0)
        else:
            vcfg = dataclasses.replace(
                vcfg,
                pair_cap=verify_lib.bucket_size(
                    max(max_count, 2 * vcfg.pair_cap), slot_area
                ),
            )

    # Per-slot telemetry (dispatch order) folds back to cells and devices.
    per_slot = np.asarray(out["per_cell_verified"]).reshape(-1)  # (n_slots,)
    cod = pl.cell_of_dispatch
    per_cell = np.zeros(p, np.float32)
    np.add.at(per_cell, cod[cod >= 0], per_slot[cod >= 0])
    device_loads = per_slot.reshape(M, -1).sum(1)
    actual_v = int(v_slot.sum())  # dispatched rows (W counts slab replicas)
    actual_w = int(w_slot.sum())
    padding = (pl.n_slots * M * (cap_v + cap_w)) / max(actual_v + actual_w, 1)

    pairs = None
    if emit_pairs and vcfg.emit == "compact":
        # (M*spd, pair_cap, 2) compacted global-id pairs + per-slot counts;
        # rows past each slot's count are -1 padding (or, pre-retry,
        # unspecified) and are sliced off here.
        cpairs = np.asarray(out["pairs"]).reshape(-1, vcfg.pair_cap, 2)
        ccounts = np.asarray(out["pair_counts"]).reshape(-1)
        rows = [cp[:c] for cp, c in zip(cpairs, ccounts) if c]
        if rows:
            pr = np.concatenate(rows).astype(np.int64)
            if not cross:
                pr = np.stack([pr.min(axis=1), pr.max(axis=1)], 1)
            pairs = np.unique(pr, axis=0)
        else:
            pairs = np.zeros((0, 2), np.int64)
    elif emit_pairs:
        masks = np.asarray(out["masks"])  # (M*spd, Mcap_v, Mcap_w) flattened over devices
        v_ids = np.asarray(out["v_ids"]).reshape(masks.shape[0], -1)
        w_ids = np.asarray(out["w_ids"]).reshape(masks.shape[0], -1)
        masks = masks.reshape(masks.shape[0], v_ids.shape[1], w_ids.shape[1])
        cell, vi, wi = np.nonzero(masks)
        gi = v_ids[cell, vi]
        gj = w_ids[cell, wi]
        if cross:
            pr = np.stack([gi, gj], 1)  # columns index different sets
        else:
            pr = np.stack([np.minimum(gi, gj), np.maximum(gi, gj)], 1)
        pairs = np.unique(pr, axis=0).astype(np.int64) if pr.size else np.zeros((0, 2), np.int64)

    n_verifications = int(np.asarray(out["verified"]).sum())
    n_candidates = int(np.asarray(out["candidates"]).sum())
    return DistJoinResult(
        n_hits=int(out["hits"].sum()) if np.asarray(out["hits"]).ndim else int(out["hits"]),
        n_verifications=n_verifications,
        per_cell_verified=per_cell,
        overflow=int(np.asarray(out["overflow"]).sum()),
        capacity_padding=float(padding),
        predicted_cap_w=int(predicted_cap_w),
        exact_cap_w=exact_cap_w,
        node_confidences=confs,
        accept_rate=accept_rate,
        pairs=pairs,
        duplication=float(actual_w / max(n_s, 1)),
        n_candidates=n_candidates,
        pruning_rate=float(1.0 - n_candidates / max(n_verifications, 1)),
        predicted_survival=float(predicted_survival),
        prune=prune_resolved,
        placement=placement,
        placement_plan=pl,
        device_loads=device_loads,
        balance_std=float(device_loads.std()),
        makespan_ratio=float(device_loads.max() / max(device_loads.mean(), 1e-9)),
        capacity_saved_bytes=int(cap_saved),
        emit=vcfg.emit if emit_pairs else "mask",
        n_overflow_retries=n_overflow_retries,
    )


# ---------------------------------------------------------------------------
# Query serving: pinned V buffers + W-side-only dispatch (core.index backend)
# ---------------------------------------------------------------------------


def make_stage_serve(
    mesh: Mesh,
    axis: str,
    qplan: JoinPlan,
    pl: placement_lib.PlacementPlan,
    *,
    cap_w: int,
    backend: str,
    prune: str,
    delta_bound: float | None = None,
    map_fused: bool = True,
):
    """The query phase of a persistent index: verify a query batch against
    V buffers that are ALREADY RESIDENT per device (``DistIndex`` pins them
    once at build) — only the queries move.

    Per shard: the same fused map-assign as the join's map phase routes the
    local queries to their whole-member cells under the δ-expanded query
    boxes, the shared W-dispatch scatters them (coords ride as trailing
    payload columns under the pivot filter), ONE ``all_to_all`` over
    ``axis``, then per-local-slot ``verify_tile`` in R×S mode against the
    pinned V slots. No sampling, no partitioning, zero V-side bytes on the
    wire per batch.

    The routing tables, W dispatch and shuffle closures are the exact ones
    ``make_stage_verify`` compiles with (module-level factories), so serving
    and the one-shot join can never disagree on slot semantics.
    """
    M = mesh.shape[axis]
    rt = _routing_tables(pl)
    n_slots = rt.n_slots
    assert n_slots % M == 0, f"n_slots={n_slots} must be a multiple of {axis}={M}"
    spd = n_slots // M
    n_dims = qplan.anchors.shape[0]
    cell_id_of_disp = rt.cell_id_of_disp
    w_dispatch = _make_w_dispatch(rt, cap_w)
    exchange, flat = _make_exchange(axis, M, spd)

    def per_shard(fv: Array, fvi: Array, q: Array, valid: Array, ids: Array):
        # fv: (spd, cap_v, m[+n]) this device's pinned V slots (dispatch
        # order); fvi: (spd, cap_v) their global R ids (pad = -1).
        cells_q, member_q, _, qm = _map_assign(qplan, q, valid, backend, map_fused)
        rows = (
            jnp.concatenate([q, qm.astype(q.dtype)], axis=1)
            if prune == "pivot"
            else q
        )
        w_buf, w_ids, w_own, overflow = w_dispatch(rows, ids, cells_q, member_q)
        fw = flat(exchange(w_buf))
        fwi = flat(exchange(w_ids))
        fwo = flat(exchange(w_own))

        my_dev = jax.lax.axis_index(axis)
        local_cells = cell_id_of_disp[my_dev * spd + jnp.arange(spd)]

        def verify_slot(vx, vids, wx, wids, wown, cell_id):
            pv = pw = None
            if prune == "pivot":
                vx, pv = vx[:, :-n_dims], vx[:, -n_dims:]
                wx, pw = wx[:, :-n_dims], wx[:, -n_dims:]
            mask = verify_lib.verify_tile(
                vx, wx, vids, wids, wown, cell_id,
                delta=qplan.delta, metric=qplan.metric, backend=backend,
                cross=True, pv=pv, pw=pw, prune=prune,
                delta_bound=delta_bound,
            )
            return mask, verify_lib.pair_validity(vids, wids).sum()

        masks, n_verified = jax.vmap(verify_slot)(fv, fvi, fw, fwi, fwo, local_cells)
        return {
            "masks": masks,  # (spd, cap_v, M*cap_w)
            "w_ids": fwi,
            "hits": masks.sum().astype(jnp.float32)[None],
            "verified": n_verified.sum().astype(jnp.float32)[None],
            "overflow": overflow.astype(jnp.float32)[None],
        }

    shmap = compat.shard_map(
        per_shard,
        mesh=mesh,
        in_specs=(P(axis),) * 5,
        out_specs={
            "masks": P(axis), "w_ids": P(axis), "hits": P(axis),
            "verified": P(axis), "overflow": P(axis),
        },
        check_vma=False,
    )
    return jax.jit(shmap)


@dataclasses.dataclass
class DistIndex:
    """A ``core.index.MetricIndex`` pinned on a device mesh for serving.

    ``from_index`` lays the indexed set's rows out per placement slot
    (slabs deal V rows round-robin by intra-cell rank, exactly like the
    join's V dispatch), device_puts the buffers sharded over ``axis`` ONCE,
    and re-plans placement (cheap: a static permutation from the stored
    cost-model loads — no re-sampling, no re-partitioning) when the mesh
    size differs from the plan the index was built for. Every
    ``query_batch`` after that moves only query bytes: one fused map pass,
    one W-side ``all_to_all``, per-slot tiled verification against the
    resident V buffers. See docs/SERVING.md for the lifecycle.
    """

    index: Any  # the host MetricIndex (duck-typed; no import cycle)
    mesh: Mesh
    axis: str
    pl: placement_lib.PlacementPlan  # re-planned for this mesh if needed
    backend: str  # resolved concrete backend
    prune: str  # resolved prune mode
    cap_v: int
    fv: Array  # (n_slots, cap_v, m[+n]) pinned V payload, dispatch order,
    #   sharded over ``axis`` on dim 0
    fv_ids: Array  # (n_slots, cap_v) int32 global R ids, same layout
    _fv_ids_host: np.ndarray  # host copy for pair extraction
    _x_abs: float  # max |payload| of the indexed set (prune-band input)
    _stages: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def n_devices(self) -> int:
        return self.mesh.shape[self.axis]

    @classmethod
    def from_index(cls, index: Any, mesh: Mesh, axis: str = "data") -> "DistIndex":
        if not kops.supports_kernel(index.metric):
            raise ValueError(
                f"distributed serving supports kernel metrics only "
                f"({kops.METRICS}); got {index.metric!r} — query the host "
                f"MetricIndex directly for reference-path metrics"
            )
        M = mesh.shape[axis]
        backend = kops.resolve_backend(index.backend, index.metric)
        if index.prune == "window":
            raise ValueError('distributed serving supports prune="none" | "pivot"')
        prune = verify_lib.resolve_prune(index.prune, index.metric, True)
        pl = index.placement
        if pl.n_devices != M:
            # Cheap re-plan: same cost-model loads, new device count — a
            # static permutation, never a rebuild (docs/SERVING.md).
            pl = placement_lib.plan_placement(
                pl.cell_loads, M, strategy=index.placement_strategy
            )
        payload = (
            np.concatenate([index.data, index.coords.astype(index.data.dtype)], axis=1)
            if prune == "pivot"
            else index.data
        )
        # Slot layout (slot order): slab j of cell h takes the cell's rows
        # with intra-cell rank ≡ j (mod n_slabs) — the V-dispatch deal.
        slot_rows = []
        for slot in range(pl.n_slots):
            cell = int(pl.slot_cell[slot])
            if cell < 0:
                slot_rows.append(np.zeros(0, np.int64))
                continue
            rows = index.v_lists[cell]
            s = int(pl.cell_n_slabs[cell])
            slot_rows.append(rows[int(pl.slot_slab[slot])::s])
        cap_v = max(1, max(r.size for r in slot_rows))
        buf = np.zeros((pl.n_slots, cap_v, payload.shape[1]), np.float32)
        ids = np.full((pl.n_slots, cap_v), -1, np.int32)
        for slot, rows in enumerate(slot_rows):
            buf[slot, : rows.size] = payload[rows]
            ids[slot, : rows.size] = rows
        # Slot order -> dispatch order: device d owns dispatch d·spd .. — the
        # same addressing every stage's all_to_all output uses.
        disp = pl.dispatch_of_slot
        buf_d = np.empty_like(buf)
        ids_d = np.empty_like(ids)
        buf_d[disp] = buf
        ids_d[disp] = ids
        sharding = NamedSharding(mesh, P(axis))
        return cls(
            index=index,
            mesh=mesh,
            axis=axis,
            pl=pl,
            backend=backend,
            prune=prune,
            cap_v=cap_v,
            fv=jax.device_put(jnp.asarray(buf_d), sharding),
            fv_ids=jax.device_put(jnp.asarray(ids_d), sharding),
            _fv_ids_host=ids_d,
            _x_abs=float(np.abs(payload).max(initial=0.0)),
        )

    def _stage(self, delta: float, cap_w: int, delta_bound: float | None):
        key = (float(delta), int(cap_w), delta_bound)
        fn = self._stages.get(key)
        if fn is None:
            idx = self.index
            qlo, qhi = idx.query_boxes(delta)
            qplan = JoinPlan(
                anchors=jnp.asarray(idx.anchors),
                metric=idx.metric,
                kernel_lo=jnp.asarray(idx.kernel_lo),
                kernel_hi=jnp.asarray(idx.kernel_hi),
                whole_lo=jnp.asarray(qlo),
                whole_hi=jnp.asarray(qhi),
                delta=float(delta),
                p=idx.p,
            )
            fn = make_stage_serve(
                self.mesh, self.axis, qplan, self.pl,
                cap_w=cap_w, backend=self.backend, prune=self.prune,
                delta_bound=delta_bound, map_fused=idx.map_fused,
            )
            self._stages[key] = fn
        return fn

    def query_batch(
        self, q: Array | np.ndarray, delta: float | None = None
    ) -> np.ndarray:
        """Batched δ-range query over the mesh: (i ∈ R, j ∈ Q) pairs with
        D ≤ δ, byte-identical to the host index's ``query_batch`` (and hence
        to ``distances.brute_force_join``). Only query bytes move."""
        idx = self.index
        delta = idx.delta if delta is None else float(delta)
        q_np = np.asarray(q, np.float32)
        if q_np.shape[0] == 0:
            return np.zeros((0, 2), np.int64)
        M = self.n_devices
        sharding = NamedSharding(self.mesh, P(self.axis))
        q_arr, valid, ids, _ = _pad_shard_set(jnp.asarray(q_np), M, sharding)

        # Exact-fit W capacity from a host routing pass (same fused map path
        # as the stage, so counts can never disagree), quantized up to a
        # power of two so repeat batches reuse the compiled stage.
        _, member = idx.route(q_np, delta)
        n_tot = int(q_arr.shape[0])
        per = n_tot // M
        mem_pad = np.zeros((n_tot, idx.p), bool)
        mem_pad[: q_np.shape[0]] = member
        w_cnt = mem_pad.reshape(M, per, idx.p).sum(1)  # (M, p)
        w_slot = w_cnt[:, np.clip(self.pl.slot_cell, 0, None)]
        w_slot[:, self.pl.slot_cell < 0] = 0
        exact = int(w_slot.max(initial=1))
        cap_w = 1 << max(exact - 1, 1).bit_length()  # next pow2, ≥ 2

        delta_bound = None
        if self.prune == "pivot":
            # Scale-aware fp band; the query magnitude is quantized up to a
            # power of two so the (static) band doesn't recompile per batch.
            q_abs = float(np.abs(q_np).max(initial=0.0))
            q_pow = float(2.0 ** np.ceil(np.log2(max(q_abs, 1e-9))))
            x_abs = max(self._x_abs, q_pow)
            delta_bound = kref.prune_delta(
                delta, idx.metric, x_abs, int(idx.data.shape[1])
            )

        out = self._stage(delta, cap_w, delta_bound)(
            self.fv, self.fv_ids, q_arr, valid, ids
        )
        assert int(np.asarray(out["overflow"]).sum()) == 0, "serve W overflow"
        masks = np.asarray(out["masks"])  # (n_slots, cap_v, M*cap_w)
        w_ids = np.asarray(out["w_ids"]).reshape(masks.shape[0], -1)
        slot, vi, wi = np.nonzero(masks)
        if slot.size == 0:
            return np.zeros((0, 2), np.int64)
        gi = self._fv_ids_host[slot, vi]
        gj = w_ids[slot, wi]
        return np.unique(np.stack([gi, gj], axis=1), axis=0).astype(np.int64)

    def _repin(self) -> None:
        """Re-lay the host index out on the mesh after an absorb (or a
        drift-triggered re-plan/rebuild): fresh slot buffers, fresh routing
        plan, and — critically — a cleared stage cache, because the query
        boxes the serve stage compiled with are baked into its trace and the
        absorb just grew them."""
        fresh = DistIndex.from_index(self.index, self.mesh, self.axis)
        self.pl = fresh.pl
        self.backend = fresh.backend
        self.prune = fresh.prune
        self.cap_v = fresh.cap_v
        self.fv = fresh.fv
        self.fv_ids = fresh.fv_ids
        self._fv_ids_host = fresh._fv_ids_host
        self._x_abs = fresh._x_abs
        self._stages.clear()

    def insert_batch(
        self,
        new_rows: Array | np.ndarray,
        *,
        replan_drift: float | None = None,
        resample_drift: float | None = None,
        rebuild_cfg=None,
    ):
        """Distributed mirror of ``MetricIndex.insert_batch``: same control
        flow, same drift monitor, byte-identical pair set — but the ΔR×R_old
        cross verify rides the serve stage, so only delta bytes cross the
        interconnect (one W-side ``all_to_all``) while the resident V
        buffers stay pinned. The ΔR×ΔR self-join and the index update run on
        the replicated host control plane (they touch only delta-sized
        state), then the grown index is re-pinned.

        Returns ``(new_pairs, StreamStats)`` exactly like the host method;
        global ids, i < j, sorted unique.
        """
        pairs, stats = self.index.insert_batch(
            new_rows,
            replan_drift=replan_drift,
            resample_drift=resample_drift,
            rebuild_cfg=rebuild_cfg,
            _cross_pairs_fn=self.query_batch,
        )
        self._repin()
        return pairs, stats
