"""Sampling algorithms (paper §4): the error-bounded pivot selection.

Three samplers, matching the paper's experimental arms:

  random_sample            — the baseline every prior system used (§1, §7 "Random")
  distribution_aware       — Alg. 2: per-node stratified sampling with Eq. 11
                             allocation and confidence-based rejection
  generative               — Alg. 3/4: Gibbs chain over (E, C, X) built from the
                             broadcast per-node (family, η, c⁰, N) — network cost
                             O(M²) parameters, independent of sample size k

plus the supporting theory:

  allocate_samples         — Eq. 11:  k_i ∝ N_i / c_i⁰
  required_sample_size     — Theorem 3 inverted: k ≥ ln(2m/δ) / (2ε²)
  sampling_error           — Def. 4:  max over dims of the marginal KS distance
  error_bound_probability  — Theorem 3 forward form: 2m·exp(−2kε²)

JAX-shape-static adaptation of Alg. 4 (documented in DESIGN.md §2): the paper
loops "until k accepted"; data-dependent loop lengths do not compile, so we run
a fixed-length chain of L = ceil(k / ĉ_min) + slack steps, mask accepted draws,
and compact the first k accepted with an argsort. ``gibbs_chain_numpy`` is the
exact paper loop (reference, used in tests to cross-check the distribution).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import expfam

Array = jnp.ndarray


# --------------------------------------------------------------------------
# Theory: error bound (Theorem 3) and sample sizing
# --------------------------------------------------------------------------


def error_bound_probability(k: int, epsilon: float, m: int) -> float:
    """P[D_k ≥ ε] < 2m·exp(−2kε²) (Theorem 3)."""
    return float(2.0 * m * np.exp(-2.0 * k * epsilon**2))


def required_sample_size(epsilon: float, fail_prob: float, m: int) -> int:
    """Smallest k ≥ 1 with 2m·exp(−2kε²) ≤ fail_prob — the paper's §4.3
    guideline for choosing k from a tolerated error level (previous work had
    no such guideline and could only blindly enlarge k).

    When fail_prob ≥ 2m the bound is vacuous (it holds for every k), and the
    raw inversion goes non-positive — clamp to the smallest meaningful sample
    size, k = 1."""
    k = int(np.ceil(np.log(2.0 * m / fail_prob) / (2.0 * epsilon**2)))
    return max(k, 1)


def sampling_error(samples: Array, reference: Array) -> Array:
    """Def. 4: D_k = max_d sup_x |P̃_d(x) − P_d(x)| — the maximum marginal
    Kolmogorov–Smirnov distance, with the *empirical* CDF of ``reference``
    standing in for the true distribution (how the tests/benchmarks use it).
    """
    s = jnp.sort(samples, axis=0)  # (k, m)
    r = jnp.sort(reference, axis=0)  # (n, m)
    k = s.shape[0]
    # Empirical CDF of reference evaluated at sample order statistics.
    pos = jax.vmap(jnp.searchsorted, in_axes=(1, 1), out_axes=1)(r, s)
    ref_cdf = pos.astype(jnp.float32) / r.shape[0]  # (k, m)
    emp_lo = jnp.arange(k, dtype=jnp.float32)[:, None] / k
    emp_hi = (jnp.arange(k, dtype=jnp.float32)[:, None] + 1.0) / k
    dks = jnp.maximum(jnp.abs(ref_cdf - emp_lo), jnp.abs(ref_cdf - emp_hi))
    return dks.max()


# --------------------------------------------------------------------------
# Eq. 11 allocation
# --------------------------------------------------------------------------


def allocate_samples(n_i: np.ndarray, conf_i: np.ndarray, k: int) -> np.ndarray:
    """Per-node sample counts  k_i = k · (N_i/c_i⁰) / Σ_j (N_j/c_j⁰)  (Eq. 11),
    rounded by largest remainder so Σ k_i == k exactly.

    Lower confidence ⇒ *more* samples from that node (the paper's intuition:
    we know less about it, so spend budget learning it).

    Quotas are capped at the node population, k_i ≤ N_i: a node cannot
    contribute more real objects than it holds, and an uncapped quota would
    make the local sampler silently truncate (returning < k pivots overall).
    Capped surplus is redistributed over the remaining nodes by the same
    largest-remainder rule until k is placed (or every node is full, when
    k > Σ N_i — the sampler then returns the whole population).
    """
    pop = np.asarray(n_i, np.int64)
    weights = np.asarray(n_i, np.float64) / np.clip(
        np.asarray(conf_i, np.float64), 1e-6, None
    )
    alloc = np.zeros(pop.shape, np.int64)
    k_left = int(min(k, pop.sum()))
    while k_left > 0:
        room = pop - alloc
        w = np.where(room > 0, weights, 0.0)
        if w.sum() <= 0:
            break
        shares = k_left * w / w.sum()
        give = np.floor(shares).astype(np.int64)
        rem = k_left - int(give.sum())
        if rem > 0:
            order = np.argsort(-(shares - give))
            give[order[:rem]] += 1
        give = np.minimum(give, room)
        alloc += give
        k_left -= int(give.sum())
    return alloc


# --------------------------------------------------------------------------
# Baseline: simple random sampling
# --------------------------------------------------------------------------


def random_sample(key: jax.Array, x: Array, k: int) -> Array:
    """Uniform sampling without replacement — the prior-work baseline.
    k is clamped to the population (relevant for oversized-k ablations)."""
    k = min(k, x.shape[0])
    idx = jax.random.choice(key, x.shape[0], shape=(k,), replace=False)
    return x[idx]


# --------------------------------------------------------------------------
# Alg. 2: distribution-aware stratified sampling (per node)
# --------------------------------------------------------------------------


def stratified_local_sample(
    key: jax.Array,
    x: Array,
    params: expfam.FamilyParams,
    confidence: Array,
    lc: int,
) -> Array:
    """Alg. 2 lines 3–7 on one node: split the node's space into ⌊√lc⌋
    equal-probability boxes under F_i, draw lc·P{X∈B_j} from each box,
    rejecting each draw with probability 1 − c_i⁰ (resample within box).

    Boxes: equal-probability intervals of the FIRST marginal's CDF,
    u = F_1(x_1) — uniform on [0,1) under the fitted model, so every box has
    P{X∈B_j} = 1/n_strata and the quota lc·P{X∈B_j} is the even allocation
    the paper intends. (A mean-of-CDFs transform is NOT uniform — it follows
    a Bates distribution and starves the tail strata; tests caught exactly
    that regression.)

    Static-shape notes: rejection/resampling is a Gumbel-top-k weighted draw
    where rejected candidates get demoted priority (distributionally
    equivalent because the box pool is exchangeable); boxes with fewer
    members than quota return their surplus to the highest-priority leftover
    rows globally, so the sampler always returns exactly lc real objects.
    """
    n = x.shape[0]
    n_strata = max(int(np.floor(np.sqrt(max(lc, 1)))), 1)
    u = expfam.cdf(params, x.astype(jnp.float32))[:, 0]  # (n,) uniform under fit
    stratum = jnp.clip((u * n_strata).astype(jnp.int32), 0, n_strata - 1)

    # Per-stratum quota, summing exactly to lc.
    quota = np.full((n_strata,), lc // n_strata, np.int64)
    quota[: lc - int(quota.sum())] += 1

    k_round, k_acc = jax.random.split(key)
    # Acceptance degree (Alg. 2 line 6): a draw survives w.p. c_i⁰.
    accept = jax.random.uniform(k_acc, (n,)) < confidence
    gumbel = jax.random.gumbel(k_round, (n,))
    # Rejected rows get heavily demoted priority → equivalent to resampling
    # from the remaining pool of their stratum.
    priority = jnp.where(accept, gumbel, gumbel - 1e6)

    # Rank rows within their stratum by priority (descending).
    order = jnp.lexsort((-priority, stratum))  # stable: stratum asc, prio desc
    sorted_stratum = stratum[order]
    first_in_stratum = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_stratum[1:] != sorted_stratum[:-1]]
    )
    pos_in_stratum = jnp.arange(n) - jax.lax.associative_scan(
        jnp.maximum, jnp.where(first_in_stratum, jnp.arange(n), -1)
    )
    take = pos_in_stratum < jnp.asarray(quota)[sorted_stratum]

    # Exactly-lc selection: quota-satisfying rows first, then the best
    # leftovers (underfull boxes return surplus to the global pool).
    final = jnp.lexsort((-priority[order], ~take))  # take=True first
    out_idx = order[final[:lc]]
    return x[out_idx]


class NodeStats(NamedTuple):
    """What each node broadcasts (Alg. 1 line 5): ⟨F_i(x), c_i⁰, N_i⟩."""

    family: str
    params: expfam.FamilyParams
    confidence: float
    count: int


def distribution_aware_sample(
    key: jax.Array,
    shards: Sequence[Array],
    node_stats: Sequence[NodeStats],
    k: int,
    allocation: str = "eq11",
) -> Array:
    """Alg. 2 end-to-end over explicit shards (single-host reference; the
    mesh version lives in repro.core.distributed). Communication analogue:
    O(k·(M−1)) sample rows cross the network.

    allocation="eq11" is the paper's confidence reweighting (oversamples
    low-confidence nodes to learn them — at the price of biasing the pivot
    set's empirical CDF when confidences diverge); "proportional" allocates
    k_i ∝ N_i (unbiased; isolates the stratification benefit — used by the
    Fig. 6 ablation arm Dist-prop in benchmarks)."""
    n_i = np.array([s.count for s in node_stats])
    c_i = np.array([s.confidence for s in node_stats])
    if allocation == "proportional":
        lcs = allocate_samples(n_i, np.ones_like(c_i), k)
    else:
        lcs = allocate_samples(n_i, c_i, k)
    out = []
    for i, (shard, st) in enumerate(zip(shards, node_stats)):
        if lcs[i] == 0:
            continue
        sub = jax.random.fold_in(key, i)
        out.append(
            stratified_local_sample(
                sub, shard, st.params, jnp.asarray(st.confidence), int(lcs[i])
            )
        )
    return jnp.concatenate(out, axis=0)


# --------------------------------------------------------------------------
# Alg. 3/4: generative sampling via Gibbs over (E, C, X)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GenerativeModel:
    """The broadcast global model: per-node packed params + confidence + size.

    Conditionals (Eqs. 17–19):
      p(E=i | C=c) ∝ N_i · (c_i⁰)^{−c}
      p(X | E=i)   = f_i(X)           (the node's fitted product density)
      p(C=1 | E=i) = c_i⁰
    """

    families: tuple[str, ...]  # per-node family name
    packed_params: Array  # (M, 2m+1) — expfam.pack per node
    confidence: Array  # (M,)
    counts: Array  # (M,)

    @property
    def n_nodes(self) -> int:
        return len(self.families)


def _node_sample(model: GenerativeModel, key: jax.Array, e: Array) -> Array:
    """Draw x ~ f_e for traced node index e (Eq. 18). Families are static
    python strings, so we branch with lax.switch over the distinct families."""
    distinct = sorted(set(model.families))
    fam_idx = jnp.asarray([distinct.index(f) for f in model.families])[e]

    def make_branch(fam: str):
        def branch(key):
            p = expfam.unpack(model.packed_params[e], fam)
            return expfam.sample(p, key, ())

        return branch

    return jax.lax.switch(fam_idx, [make_branch(f) for f in distinct], key)


def _compact_accepted(xs: Array, accepted: Array, k: int) -> tuple[Array, Array]:
    """Compact the first k accepted chain draws (stable order).

    Shortfall tail slots repeat the FIRST ACCEPTED row (``order[0]`` is
    accepted whenever anything was). If the chain accepted nothing at all,
    no accepted row exists to repeat — instead of degenerating to k copies
    of one rejected draw, fall back to the first k raw chain draws (still
    mixture-distributed and diverse); the returned 0.0 acceptance rate is
    the caller's telemetry to warn on.
    """
    order = jnp.argsort(~accepted, stable=True)
    take = order[:k]
    take = jnp.where(accepted[take], take, take[0])
    take = jnp.where(accepted.sum() > 0, take, jnp.arange(k))
    return xs[take], accepted.mean()


def gibbs_chain(
    key: jax.Array,
    model: GenerativeModel,
    k: int,
    oversample: float = 1.5,
    normalize_confidence: bool = True,
) -> tuple[Array, Array]:
    """Alg. 4 as a fixed-length lax.scan.

    Chain state (e, c); per step:
      e ~ p(E | C=c_prev)   — categorical, weights N_i·(c_i⁰)^{−c_prev}
      x ~ p(X | E=e)
      c ~ p(C | E=e)        — Bernoulli(c_e⁰); x kept iff c == 1

    ``normalize_confidence`` (beyond-paper fix, default on): acceptance is
    run on c_i / max_j c_j. The C=1 branch is scale-invariant by design
    (weights N_i/c_i x accept c_i = N_i), so this preserves Eqs. 17-19's
    stationary mixture while keeping the acceptance rate high — without it,
    data that fits NO exponential family (all c_i ~ 0, e.g. multimodal
    shards) drives acceptance to ~0 and the fixed-length chain degenerates
    to a handful of distinct pivots. Measured in EXPERIMENTS.md §Perf.

    Returns (samples (k, m), acceptance_rate). Chain length is
    L = ceil(k / c_min · oversample) so that k acceptances occur with
    overwhelming probability; accepted draws are compacted with a stable
    argsort and, in the (measure-zero in practice) case of a shortfall, the
    tail repeats the first accepted row — never rejected ones. If the chain
    accepts NOTHING (all-confidence-≈0 shards), there is no accepted row to
    repeat; the first k raw chain draws are returned instead (still drawn
    from the node mixture, and diverse — not k copies of one rejected draw)
    and the 0.0 acceptance rate is the caller's cue to warn.
    """
    counts = model.counts.astype(jnp.float32)
    conf = jnp.clip(model.confidence.astype(jnp.float32), 1e-6, 1.0)
    if normalize_confidence:
        conf = conf / jnp.max(conf)
    conf = jnp.clip(conf, 1e-3, 1.0)
    c_min = float(jnp.clip(conf.min(), 0.05, 1.0))
    length = int(np.ceil(k / c_min * oversample)) + 8

    logw_c0 = jnp.log(counts)  # C=0 → weights N_i
    logw_c1 = jnp.log(counts) - jnp.log(conf)  # C=1 → weights N_i / c_i

    def step(carry, key):
        c_prev = carry
        k_e, k_x, k_c = jax.random.split(key, 3)
        logw = jnp.where(c_prev == 1, logw_c1, logw_c0)
        e = jax.random.categorical(k_e, logw)
        x = _node_sample(model, k_x, e)
        c = (jax.random.uniform(k_c) < conf[e]).astype(jnp.int32)
        return c, (x, c)

    _, (xs, cs) = jax.lax.scan(step, jnp.int32(1), jax.random.split(key, length))
    return _compact_accepted(xs, cs == 1, k)


def generative_sample(
    key: jax.Array,
    node_stats: Sequence[NodeStats],
    k: int,
    m: int | None = None,
) -> tuple[Array, Array]:
    """Alg. 3: build the broadcast model and run the Gibbs chain.

    Communication analogue: only (family, η, c⁰, N) per node crosses the
    network — O(M·(M−1)) scalars, independent of k (§4.2 cost analysis).
    """
    model = GenerativeModel(
        families=tuple(s.family for s in node_stats),
        packed_params=jnp.stack([expfam.pack(s.params) for s in node_stats]),
        confidence=jnp.asarray([s.confidence for s in node_stats], jnp.float32),
        counts=jnp.asarray([s.count for s in node_stats], jnp.float32),
    )
    return gibbs_chain(key, model, k)


def gibbs_chain_numpy(
    rng: np.random.Generator,
    node_stats: Sequence[NodeStats],
    k: int,
) -> np.ndarray:
    """The exact Alg. 4 loop (dynamic length, host numpy) — reference used by
    tests to validate the fixed-shape scan against the paper's semantics."""
    counts = np.array([s.count for s in node_stats], np.float64)
    conf = np.clip(np.array([s.confidence for s in node_stats], np.float64), 1e-3, 1.0)
    out: list[np.ndarray] = []
    c_prev = 1
    guard = 0
    while len(out) < k and guard < 1000 * k:
        guard += 1
        w = counts / np.power(conf, c_prev)
        e = rng.choice(len(counts), p=w / w.sum())
        p = node_stats[e].params
        key = jax.random.PRNGKey(int(rng.integers(0, 2**31 - 1)))
        x = np.asarray(expfam.sample(p, key, ()))
        c_prev = int(rng.uniform() < conf[e])
        if c_prev == 1:
            out.append(x)
    return np.stack(out)
