"""Exponential-family distribution estimation (paper §3.3, Lemma 1, Table 1).

The paper models each cluster node's local shard as i.i.d. draws from an
exponential-family distribution p(x; η) = h(x)·exp(ηᵀT(x) − α(η)) and fits η by
closed-form MLE: η⁰ = μ⁻¹(mean of T(o_i)) where μ(η) = E_η[T(X)] (Lemma 1).

We implement the families the paper's Table 1 highlights that are useful for
real vector data, each as a *product* distribution over the m dimensions (the
paper's error definition, Def. 4, and its partitioning both operate on
marginals, so per-dimension products are the faithful granularity):

  normal       T(x) = (x, x²)    → μ, σ²       (w = 2 params / dim)
  exponential  T(x) = x          → λ           (w = 1; requires x ≥ 0)
  gamma        T(x) = (x, log x) → (α, β)      (w = 2; requires x > 0;
                                                MLE has no closed form in α —
                                                Lemma 1's μ⁻¹ is evaluated with
                                                a Newton iteration on ψ(α),
                                                exactly the paper's remark that
                                                gradient methods solve families
                                                without explicit E_η[T] inverse)

Everything here is pure JAX and runs *inside* the per-shard stats pass of the
distributed join — sufficient statistics are the only thing ever reduced, so a
shard's fit costs one streaming pass and O(m) memory, matching the paper's
"lightweight, no shuffle" design.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import digamma, erf, gammainc, polygamma

Array = jnp.ndarray

_SQRT2 = 1.4142135623730951
FAMILIES = ("normal", "exponential", "gamma")


class SuffStats(NamedTuple):
    """Per-dimension sufficient statistics Σ T(o_i) plus the count.

    This is the *only* cross-device payload of the stats phase: for every
    family in Table 1 that we support, T(x) ⊆ {x, x², log x}, so we carry all
    three sums (m floats each) and the count. Shards combine by addition.
    """

    n: Array  # scalar, number of (weighted) observations
    sum_x: Array  # (m,)
    sum_x2: Array  # (m,)
    sum_logx: Array  # (m,)  computed on max(x, tiny) to stay finite


def suff_stats(x: Array, mask: Array | None = None) -> SuffStats:
    """One-pass sufficient statistics for an (n, m) shard.

    ``mask``: optional (n,) validity mask (padding rows in static-shape
    distributed buffers contribute nothing).
    """
    x = x.astype(jnp.float32)
    if mask is None:
        n = jnp.asarray(x.shape[0], jnp.float32)
        w = None
    else:
        w = mask.astype(jnp.float32)[:, None]
        n = w.sum()

    def _sum(v: Array) -> Array:
        return (v if w is None else v * w).sum(0)

    safe = jnp.maximum(jnp.abs(x), 1e-20)  # log of |x| as a stand-in off-support
    return SuffStats(n=n, sum_x=_sum(x), sum_x2=_sum(x * x), sum_logx=_sum(jnp.log(safe)))


def merge_stats(stats: SuffStats) -> SuffStats:
    """Combine per-shard stats stacked on a leading axis into global stats."""
    return SuffStats(*(s.sum(0) for s in stats))


@dataclasses.dataclass(frozen=True)
class FamilyParams:
    """Fitted per-dimension parameters for one family. All fields (m,)."""

    family: str
    a: Array  # normal: μ      exponential: λ      gamma: α (shape)
    b: Array  # normal: σ²     exponential: unused gamma: β (rate)

    @property
    def n_params(self) -> int:
        """w in Theorem 1 (degrees-of-freedom correction), per dimension."""
        return 1 if self.family == "exponential" else 2


# --------------------------------------------------------------------------
# MLE fits (Lemma 1): η⁰ = μ⁻¹( Σ T(o_i) / N )
# --------------------------------------------------------------------------


def fit_normal(s: SuffStats) -> FamilyParams:
    n = jnp.maximum(s.n, 1.0)
    mu = s.sum_x / n
    var = jnp.maximum(s.sum_x2 / n - mu * mu, 1e-12)
    return FamilyParams("normal", mu, var)


def fit_exponential(s: SuffStats) -> FamilyParams:
    n = jnp.maximum(s.n, 1.0)
    mean = jnp.maximum(s.sum_x / n, 1e-12)
    lam = 1.0 / mean
    return FamilyParams("exponential", lam, jnp.zeros_like(lam))


def fit_gamma(s: SuffStats, newton_iters: int = 12) -> FamilyParams:
    """Gamma MLE. μ(η) has no explicit inverse: solve

        log α − ψ(α) = log( mean(x) ) − mean(log x)  =: c

    by Newton on g(α) = log α − ψ(α) − c (g is monotone decreasing in α).
    Initialized with the Minka-style approximation α₀ ≈ (3−c+√((c−3)²+24c))/(12c).
    """
    n = jnp.maximum(s.n, 1.0)
    mean = jnp.maximum(s.sum_x / n, 1e-12)
    mean_log = s.sum_logx / n
    c = jnp.maximum(jnp.log(mean) - mean_log, 1e-8)
    alpha = (3.0 - c + jnp.sqrt((c - 3.0) ** 2 + 24.0 * c)) / (12.0 * c)

    def body(alpha, _):
        g = jnp.log(alpha) - digamma(alpha) - c
        gp = 1.0 / alpha - polygamma(1, alpha)
        alpha = jnp.clip(alpha - g / gp, 1e-4, 1e7)
        return alpha, None

    alpha, _ = jax.lax.scan(body, alpha, None, length=newton_iters)
    beta = alpha / mean
    return FamilyParams("gamma", alpha, beta)


def fit(family: str, s: SuffStats) -> FamilyParams:
    if family == "normal":
        return fit_normal(s)
    if family == "exponential":
        return fit_exponential(s)
    if family == "gamma":
        return fit_gamma(s)
    raise ValueError(f"unknown family {family!r}; have {FAMILIES}")


# --------------------------------------------------------------------------
# CDFs / quantiles / sampling — used by strata construction (Alg. 2), the
# goodness-of-fit cells (Lemma 2), and the Gibbs sampler's p(X|E=i) (Eq. 18).
# --------------------------------------------------------------------------


def cdf(p: FamilyParams, x: Array) -> Array:
    """Per-dimension CDF, broadcasting x: (..., m) against params (m,)."""
    if p.family == "normal":
        z = (x - p.a) / jnp.sqrt(2.0 * p.b)
        return 0.5 * (1.0 + erf(z))
    if p.family == "exponential":
        return jnp.where(x > 0, 1.0 - jnp.exp(-p.a * jnp.maximum(x, 0.0)), 0.0)
    if p.family == "gamma":
        return jnp.where(x > 0, gammainc(p.a, p.b * jnp.maximum(x, 1e-30)), 0.0)
    raise ValueError(p.family)


def quantile(p: FamilyParams, q: Array, bisect_iters: int = 60) -> Array:
    """Inverse CDF per dimension. Normal uses erfinv; others bisect.

    q: (..., m) in (0, 1) → same shape of x values.
    """
    q = jnp.clip(q, 1e-6, 1.0 - 1e-6)
    if p.family == "normal":
        return p.a + jnp.sqrt(2.0 * p.b) * jax.scipy.special.erfinv(2.0 * q - 1.0)
    if p.family == "exponential":
        return -jnp.log1p(-q) / p.a
    # gamma: monotone bisection on a generous bracket.
    hi0 = (p.a + 10.0 * jnp.sqrt(p.a) + 10.0) / p.b

    def body(state, _):
        lo, hi = state
        mid = 0.5 * (lo + hi)
        below = cdf(p, mid) < q
        return (jnp.where(below, mid, lo), jnp.where(below, hi, mid)), None

    (lo, hi), _ = jax.lax.scan(
        body, (jnp.zeros_like(q), jnp.broadcast_to(hi0, q.shape)), None, length=bisect_iters
    )
    return 0.5 * (lo + hi)


def sample(p: FamilyParams, key: jax.Array, shape: tuple[int, ...]) -> Array:
    """Draw samples of shape (*shape, m) from the fitted product distribution."""
    m = p.a.shape[-1]
    if p.family == "normal":
        z = jax.random.normal(key, (*shape, m))
        return p.a + jnp.sqrt(p.b) * z
    if p.family == "exponential":
        return jax.random.exponential(key, (*shape, m)) / p.a
    if p.family == "gamma":
        return jax.random.gamma(key, p.a, (*shape, m)) / p.b
    raise ValueError(p.family)


def log_prob(p: FamilyParams, x: Array) -> Array:
    """Per-dimension log-density (summed over dims), for diagnostics."""
    if p.family == "normal":
        lp = -0.5 * ((x - p.a) ** 2 / p.b + jnp.log(2.0 * jnp.pi * p.b))
    elif p.family == "exponential":
        lp = jnp.where(x >= 0, jnp.log(p.a) - p.a * x, -jnp.inf)
    elif p.family == "gamma":
        lp = jnp.where(
            x > 0,
            p.a * jnp.log(p.b) - jax.scipy.special.gammaln(p.a) + (p.a - 1) * jnp.log(jnp.maximum(x, 1e-30)) - p.b * x,
            -jnp.inf,
        )
    else:
        raise ValueError(p.family)
    return lp.sum(-1)


# --------------------------------------------------------------------------
# Packing — FamilyParams must cross shard_map boundaries as flat arrays.
# --------------------------------------------------------------------------

_FAMILY_ID = {name: i for i, name in enumerate(FAMILIES)}


def pack(p: FamilyParams) -> Array:
    """(2m + 1,) flat vector: [family_id, a..., b...]."""
    fid = jnp.full((1,), _FAMILY_ID[p.family], jnp.float32)
    return jnp.concatenate([fid, p.a.astype(jnp.float32), p.b.astype(jnp.float32)])


def unpack(v: Array, family: str | None = None) -> FamilyParams:
    m = (v.shape[-1] - 1) // 2
    fam = family if family is not None else FAMILIES[int(v[0])]
    return FamilyParams(fam, v[1 : 1 + m], v[1 + m :])


@functools.partial(jax.jit, static_argnames=("family",))
def fit_jit(family: str, x: Array) -> Array:
    """Convenience: data → packed params in one jitted call."""
    return pack(fit(family, suff_stats(x)))
