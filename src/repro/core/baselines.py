"""Baseline distributed-join strategies the paper compares against (§7.3).

The paper's four baselines are Spark systems; what distinguishes them
algorithmically is (a) random pivot sampling and (b) their partitioning rule.
We reproduce the *algorithmic cores* so Fig. 9's comparison is apples-to-
apples inside one executor:

  ball_join        MRSimJoin/ClusterJoin-style generalized-hyperplane (Voronoi)
                   partitioning with the 2-delta window replication rule.
                   KERNEL cell = nearest pivot; WHOLE membership of cell h =
                   D(o, p_h) <= D(o, p_nearest) + 2*delta  (complete by the
                   triangle inequality — proof in the module test).
  kpm_join         KPM (Chen et al. 2017): random sampling + KD-style
                   equi-depth space splitting. Exactly this framework's
                   Random + Iter arm — we expose a config alias rather than
                   duplicate code (spjoin.JoinConfig(sampler="random",
                   partitioner="iterative", anchor_method="random",
                   tighten=False)).

Both emit the same JoinResult as repro.core.spjoin.join, so every benchmark
metric (verifications, balance std, cost model) is directly comparable.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, distances, sampling, spjoin

Array = jnp.ndarray


def kpm_config(delta: float, metric: str = "l1", k: int = 1024, p: int = 16,
               n_dims: int = 8, seed: int = 0) -> spjoin.JoinConfig:
    """The KPM-like arm: random pivots + iterative equi-depth splits."""
    return spjoin.JoinConfig(
        delta=delta, metric=metric, sampler="random", partitioner="iterative",
        k=k, p=p, n_dims=n_dims, anchor_method="random", tighten=False, seed=seed,
    )


def ball_join(
    data: Array,
    delta: float,
    metric: str = "l1",
    n_pivots: int = 16,
    seed: int = 0,
    return_pairs: bool = True,
) -> spjoin.JoinResult:
    """MRSimJoin-style ball (generalized-hyperplane) partitioning join.

    Pivots are drawn uniformly (the baseline's sampling). Every object's
    KERNEL cell is its nearest pivot; it is replicated to every cell within
    the 2-delta window. Verification is per-cell V_h x W_h with the min-cell
    de-dup rule (same rule as spjoin.join, so results are identical sets).
    """
    key = jax.random.PRNGKey(seed)
    data = jnp.asarray(data)
    n = data.shape[0]

    t0 = time.perf_counter()
    pivots = sampling.random_sample(key, data, min(n_pivots, n))
    t_sample = time.perf_counter() - t0

    t0 = time.perf_counter()
    d = distances.pairwise(data, pivots, metric)  # (n, p)
    cells = jnp.argmin(d, axis=1).astype(jnp.int32)
    nearest = d.min(axis=1, keepdims=True)
    member = d <= nearest + 2.0 * delta  # (n, p) window rule
    t_map = time.perf_counter() - t0

    t0 = time.perf_counter()
    cells_np = np.asarray(cells)
    member_np = np.asarray(member)
    p = member_np.shape[1]
    v_sizes = np.bincount(cells_np, minlength=p).astype(np.int64)
    w_sizes = member_np.sum(0).astype(np.int64)

    metric_fn = distances.get_metric(metric)
    n_verif = 0
    chunks: list[np.ndarray] = []
    for h in range(p):
        v_idx = np.flatnonzero(cells_np == h)
        w_idx = np.flatnonzero(member_np[:, h])
        if v_idx.size == 0 or w_idx.size == 0:
            continue
        n_verif += int(v_idx.size) * int(w_idx.size)
        dm = np.asarray(metric_fn.pairwise(data[v_idx], data[w_idx]))
        hv, hw = np.nonzero(dm <= delta)
        gi, gj = v_idx[hv], w_idx[hw]
        cj = cells_np[gj]
        keep = ((cj == h) & (gi < gj)) | (cj > h)
        if return_pairs and keep.any():
            chunks.append(np.stack([gi[keep], gj[keep]], axis=1))
    pairs = (
        np.unique(np.sort(np.concatenate(chunks), axis=1), axis=0)
        if chunks
        else np.zeros((0, 2), np.int64)
    )
    t_verify = time.perf_counter() - t0

    return spjoin.JoinResult(
        pairs=pairs.astype(np.int64),
        n_verifications=n_verif,
        cost=cost_model.partition_cost(v_sizes, w_sizes),
        node_confidences=np.zeros((0,)),
        sample_time_s=t_sample,
        map_time_s=t_map,
        verify_time_s=t_verify,
    )
