"""Chi-square goodness-of-fit confidence (paper §3.4: Lemma 2, Theorem 1, Eq. 10).

The paper's five-step hypothesis test, per local node i:
  1. H₀: X ~ P(x; η⁰)                                   (Eq. 7)
  2. Pearson statistic K_i = Σ_j (ν_j − N·q_j)² / (N·q_j)  (Lemma 2 / Eq. 8)
  3. K_i ~ χ²(t − w − 1) under H₀                       (Theorem 1)
  4. evaluate K_i* on the node's data                   (Eq. 9)
  5. confidence c_i⁰ = sup{c : K_i* > χ²_{t−w−1}(c)}    (Eq. 10)

Step 5's sup is exactly the p-value P[χ²_{df} ≥ K*] — the probability, under
H₀, of a statistic at least as extreme as observed. We compute it with the
regularized incomplete gamma function (no scipy dependency).

Cells Z_j: the paper discretizes the space into t cells set "empirically". We
use *equal-probability* cells per dimension under the fitted marginal — i.e.
cell edges at fitted quantiles — which (a) makes every expected count N/t
(maximally powerful Pearson cells, Mann–Wald), and (b) lets the statistic for a
product distribution decompose as a sum of per-dimension statistics with
additive degrees of freedom, which is what Theorem 2's global statistic
\\bar{K} = Σ_i K_i needs.

Everything is fixed-shape JAX so it can run inside the per-shard stats pass.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.scipy.special import gammainc

from repro.core import expfam

Array = jnp.ndarray


def chi2_cdf(x: Array, df: Array) -> Array:
    """CDF of χ²_df at x: P(df/2, x/2) (regularized lower incomplete gamma)."""
    df = jnp.asarray(df, jnp.float32)
    return gammainc(df / 2.0, jnp.maximum(x, 0.0) / 2.0)


def chi2_sf(x: Array, df: Array) -> Array:
    """Survival function 1 − CDF: the Eq. 10 confidence/p-value."""
    return 1.0 - chi2_cdf(x, df)


class GofResult(NamedTuple):
    statistic: Array  # K_i* — summed Pearson statistic over dims (scalar)
    dof: Array  # t·m − w·m − 1-per-dim aggregated degrees of freedom
    confidence: Array  # c_i⁰ ∈ [0, 1]
    per_dim_statistic: Array  # (m,) decomposition, for diagnostics


def pearson_statistic(
    x: Array,
    params: expfam.FamilyParams,
    t: int = 8,
    mask: Array | None = None,
) -> GofResult:
    """Evaluate K* (Eq. 9) on a shard with t equal-probability cells per dim.

    x: (n, m); mask: optional (n,) validity. Cell counts ν_j come from a
    one-pass histogram on the CDF-transform u = F(x) ∈ [0,1]: equal-probability
    cells in x-space are equal-*width* cells in u-space, so the histogram is a
    single floor() — no per-cell quantile evaluation.
    """
    u = expfam.cdf(params, x.astype(jnp.float32))  # (n, m) in [0, 1]
    cell = jnp.clip((u * t).astype(jnp.int32), 0, t - 1)  # (n, m)
    w = None if mask is None else mask.astype(jnp.float32)
    n_eff = jnp.asarray(x.shape[0], jnp.float32) if w is None else w.sum()

    onehot = jax.nn.one_hot(cell, t, dtype=jnp.float32)  # (n, m, t)
    if w is not None:
        onehot = onehot * w[:, None, None]
    nu = onehot.sum(0)  # (m, t) observed counts per dim/cell

    expected = jnp.maximum(n_eff / t, 1e-9)  # equal-probability cells
    per_dim = ((nu - expected) ** 2 / expected).sum(-1)  # (m,)
    k_star = per_dim.sum()

    m = x.shape[-1]
    w_params = params.n_params
    # df per dim: t − w − 1 (Theorem 1); product model sums over dims.
    dof = jnp.maximum(jnp.asarray(m * (t - w_params - 1), jnp.float32), 1.0)
    conf = chi2_sf(k_star, dof)
    return GofResult(k_star, dof, conf, per_dim)


def fit_best_family(
    x: Array,
    t: int = 8,
    mask: Array | None = None,
    families: tuple[str, ...] = expfam.FAMILIES,
) -> tuple[expfam.FamilyParams, GofResult]:
    """Fit every candidate family and keep the max-confidence one (paper §3.4:
    "if there are multiple possible distributions, we select the distribution
    with the maximum confidence").

    Families whose support excludes the data (e.g. exponential on negative
    values) self-eliminate: their cells collapse and confidence → 0.
    """
    stats = expfam.suff_stats(x, mask)
    nonneg = (
        jnp.all(x >= 0)
        if mask is None
        else jnp.all((x >= 0) | ~mask.astype(bool)[:, None])
    )
    best: tuple[expfam.FamilyParams, GofResult] | None = None
    for fam in families:
        params = expfam.fit(fam, stats)
        res = pearson_statistic(x, params, t=t, mask=mask)
        if fam in ("exponential", "gamma"):
            res = res._replace(confidence=jnp.where(nonneg, res.confidence, 0.0))
        if best is None or float(res.confidence) > float(best[1].confidence):
            best = (params, res)
    assert best is not None
    return best


def global_confidence(k_stars: Array, dofs: Array) -> Array:
    """Theorem 2 machinery: the global statistic is \\bar{K}* = Σ_i K_i* with
    Σ_i df_i degrees of freedom (sum of independent χ² is χ² with summed df);
    returns \\bar{c}⁰ (Eq. 13). Theorem 2 states \\bar{c}⁰ ≥ min_i c_i⁰ (the
    paper's proof ends with the inequality written the other way round — a
    typo; the *statement* direction is the one that holds for p-values of
    summed χ² statistics, and tests/test_gof.py checks it empirically).
    """
    return chi2_sf(k_stars.sum(), dofs.sum())
