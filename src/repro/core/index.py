"""Persistent metric index: build once, query millions (serving phase).

Every call to ``spjoin.join`` / ``distributed_join`` re-runs the whole
pipeline — sampling, GoF fits, anchor selection, the partition tree, the
placement plan — which is correct for one batch join and wrong for serving
query traffic. This module splits the pipeline into an explicit **build
phase** and a **query phase** (the DIMS three-stage shape — arXiv
2410.05091 — mapped onto our artifacts):

  build  (once)   sampling → anchors → kernel boxes → per-cell member MBBs
                  → cost-model placement plan → cached mapped coordinates
                  and per-cell V row lists of the indexed set R.
  query  (hot)    a batch of query points Q is routed through the SAME
                  fused map-assign kernel as the join's map phase — each
                  query's anchor distances (its mapped coordinates) are
                  computed exactly once and reused twice: first as the box
                  containment test that routes it to only the owning cells
                  (Lemma 4), then as the pivot-filter coordinates that
                  prune candidate pairs before exact evaluation
                  (``core.verify`` candidate mask). Verification streams
                  through the tiled verify engine in R×S mode (V = the
                  pinned index cells, W = the routed queries) without ever
                  re-sampling, re-fitting or re-partitioning.

δ at query time: the index stores the *pre-expansion* base boxes (the
tightened member MBB of each cell, or the kernel box when ``tighten=False``)
and expands them by the QUERY radius on the way in, so any ``delta`` — equal
to, below, or above the build-time default — answers exactly (Lemma 4 holds
for whatever radius the boxes were expanded by). The build-time δ is only the
default radius and the one the placement plan was costed at; see
docs/SERVING.md for the re-plan vs rebuild trade-off.

On-disk format (``index.save(path)`` / ``MetricIndex.load(path)``): a
directory holding ``manifest.json`` (format name + version, the build
config, array shapes, the placement summary) and ``arrays.npz`` (every
array, bit-exact). The manifest is validated first: an unknown format or a
version this code does not speak fails loudly (``IndexFormatError``), and a
manifest disagreeing with the caller's expected metric / δ / pivot count
fails with ``IndexMismatchError`` instead of silently mis-answering —
worked example in docs/SERVING.md.

The distributed serving path (``index.to_distributed(mesh)`` →
``core.distributed.DistIndex``) pins the per-slot V buffers on devices once
and serves query batches through the verify stage's slot machinery — one
W-side ``all_to_all`` per batch, zero R-side bytes moved after build.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import distances, mapping, partition, spjoin
from repro.core import placement as placement_lib
from repro.core import verify as verify_lib
from repro.kernels import ops as kops

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.core.distributed import DistIndex

Array = jnp.ndarray

FORMAT_NAME = "spjoin-metric-index"
FORMAT_VERSION = 1

# Arrays persisted bit-exact in arrays.npz (name -> MetricIndex attribute).
_ARRAYS = (
    "data", "coords", "cells", "pivots", "anchors",
    "kernel_lo", "kernel_hi", "box_lo", "box_hi",
)
_PLAN_ARRAYS = (
    "cell_loads", "cell_first_slot", "cell_n_slabs",
    "slot_cell", "slot_slab", "slot_load", "dispatch_of_slot",
)


class IndexFormatError(ValueError):
    """The on-disk artifact is not a metric index this code can read."""


class IndexMismatchError(ValueError):
    """The manifest disagrees with the caller's expected query config."""


@dataclasses.dataclass
class QueryStats:
    """Telemetry of one ``query_batch`` call (the serving analogue of
    ``VerifyStats`` — which it embeds as ``verify``)."""

    n_queries: int = 0
    n_routed: int = 0  # Σ per-query owning-cell memberships (dispatch fan-out)
    n_cells_touched: int = 0  # cells that received ≥ 1 query
    route_s: float = 0.0  # map-assign + membership time
    verify_s: float = 0.0  # tiled engine time
    verify: verify_lib.VerifyStats | None = None

    @property
    def duplication(self) -> float:
        """Σ memberships / |Q| — the query-side routing amplification
        (the serving analogue of the shuffle metric Σ|W_h|/|S|)."""
        return self.n_routed / max(self.n_queries, 1)


@dataclasses.dataclass
class MetricIndex:
    """Everything the query phase needs, with the build phase paid once.

    All arrays are host numpy (the single-host serving path gathers verify
    tiles from them; ``to_distributed`` device-puts the per-slot V buffers).
    ``coords`` are R's mapped coordinates — the cached index-to-pivot
    distances the pivot filter reuses on every query.
    """

    # -- build config (the manifest scalars) --------------------------------
    metric: str
    delta: float  # build-time default query radius
    n_dims: int
    tighten: bool
    backend: str  # RESOLVED backend ("numpy" | "pallas") the build mapped with
    prune: str  # requested prune mode ("pivot" | "none")
    map_fused: bool
    tile_v: int
    tile_w: int
    seed: int
    placement_strategy: str
    n_devices: int  # devices the stored placement plan targets

    # -- build artifacts ----------------------------------------------------
    data: np.ndarray  # (N, m) the indexed set R
    coords: np.ndarray  # (N, n) R's mapped coordinates (pivot distances)
    cells: np.ndarray  # (N,) kernel cell of each R row
    pivots: np.ndarray  # (k, m) sampled pivots
    anchors: np.ndarray  # (n, m) anchor pivots of the space map
    kernel_lo: np.ndarray  # (p, n) half-open kernel boxes
    kernel_hi: np.ndarray
    box_lo: np.ndarray  # (p, n) PRE-expansion whole-box base (member MBB
    box_hi: np.ndarray  # when tighten, else the kernel box); query boxes
    #   are box ∓ query-δ — recomputed per batch, any radius answers exactly
    placement: placement_lib.PlacementPlan
    build_s: float = 0.0
    node_confidences: np.ndarray | None = None

    # -- derived query-phase caches (never persisted) -----------------------
    _v_lists: list[np.ndarray] | None = dataclasses.field(default=None, repr=False)

    # ------------------------------------------------------------------ api

    @property
    def n_rows(self) -> int:
        return int(self.data.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.data.shape[1])

    @property
    def k(self) -> int:
        return int(self.pivots.shape[0])

    @property
    def p(self) -> int:
        return int(self.kernel_lo.shape[0])

    @property
    def space_map(self) -> mapping.SpaceMap:
        return mapping.SpaceMap(jnp.asarray(self.anchors), self.metric)

    @property
    def v_lists(self) -> list[np.ndarray]:
        """Per-cell V row lists (global R indices), computed once per index."""
        if self._v_lists is None:
            order = np.argsort(self.cells, kind="stable")
            bounds = np.searchsorted(self.cells[order], np.arange(self.p + 1))
            self._v_lists = [
                order[bounds[h] : bounds[h + 1]] for h in range(self.p)
            ]
        return self._v_lists

    def query_boxes(self, delta: float) -> tuple[np.ndarray, np.ndarray]:
        """The δ-expanded whole boxes for a given query radius — the exact
        expression the build phase would have produced for that δ, so
        ``delta == self.delta`` reproduces the join's boxes bit-for-bit."""
        return (
            (self.box_lo - np.float32(delta)).astype(np.float32),
            (self.box_hi + np.float32(delta)).astype(np.float32),
        )

    def route(self, q: np.ndarray | Array, delta: float) -> tuple[np.ndarray, np.ndarray]:
        """Map a query batch and route it to its owning cells.

        Returns ``(q_coords (B, n), member (B, p))`` — the mapped
        coordinates (reused by the pivot filter) and the whole-box
        membership under the δ-expanded query boxes. Uses the same fused
        map-assign kernel (and fp algorithm) as the build phase, so a
        borderline query coordinate can never land on a different side of
        a box edge than the indexed MBB implies.
        """
        q = jnp.asarray(q, jnp.float32)
        wlo, whi = self.query_boxes(delta)
        if q.shape[0] == 0:
            return (
                np.zeros((0, self.n_dims), np.float32),
                np.zeros((0, self.p), bool),
            )
        if self.map_fused and kops.supports_kernel(self.metric):
            qm, _, bits = kops.map_assign(
                q, jnp.asarray(self.anchors),
                jnp.asarray(self.kernel_lo), jnp.asarray(self.kernel_hi),
                jnp.asarray(wlo), jnp.asarray(whi),
                self.metric, backend=self.backend, want="member",
            )
            member = kops.unpack_membership(bits, self.p)
        else:
            qm = self.space_map(q)
            member = (
                (qm[:, None, :] >= jnp.asarray(wlo)[None])
                & (qm[:, None, :] <= jnp.asarray(whi)[None])
            ).all(-1)
        return np.asarray(qm, np.float32), np.asarray(member, bool)

    def query_batch(
        self,
        q: np.ndarray | Array,
        delta: float | None = None,
        *,
        with_stats: bool = False,
    ):
        """Batched δ-range query: all pairs (i ∈ R, j ∈ Q) with
        D(r_i, q_j) ≤ δ, as an (n_pairs, 2) int64 array (column 0 indexes
        the indexed set, column 1 the query batch). ``delta=None`` uses the
        build-time default. Fixed-seed results are byte-identical to
        ``distances.brute_force_join(R, Q, delta)``.

        No sampling, fitting or partitioning happens here — only the fused
        map pass over Q and the tiled verify engine over the routed cells.
        """
        delta = self.delta if delta is None else float(delta)
        q_np = np.asarray(q, np.float32)
        t0 = time.perf_counter()
        q_coords, member = self.route(q_np, delta)
        w_lists = [np.flatnonzero(member[:, h]) for h in range(self.p)]
        t_route = time.perf_counter() - t0

        prune = verify_lib.resolve_prune(self.prune, self.metric, True)
        cfg = verify_lib.EngineConfig(
            backend=self.backend, tile_v=self.tile_v, tile_w=self.tile_w,
            prune=prune,
        )
        t0 = time.perf_counter()
        pairs, vstats = verify_lib.verify_cell_lists(
            self.data, self.cells, self.v_lists, w_lists, delta, self.metric,
            config=cfg, data_w=q_np, coords=self.coords, coords_w=q_coords,
        )
        t_verify = time.perf_counter() - t0
        if not with_stats:
            return pairs
        touched = sum(1 for w in w_lists if w.size)
        stats = QueryStats(
            n_queries=int(q_np.shape[0]),
            n_routed=int(member.sum()),
            n_cells_touched=touched,
            route_s=t_route,
            verify_s=t_verify,
            verify=vstats,
        )
        return pairs, stats

    def query(self, q: np.ndarray | Array, delta: float | None = None) -> np.ndarray:
        """Single-point δ-range query: sorted R row indices within δ of ``q``."""
        q = np.asarray(q, np.float32)
        if q.ndim != 1:
            raise ValueError(f"query() takes one point (m,); got shape {q.shape}")
        pairs = self.query_batch(q[None, :], delta)
        return np.sort(pairs[:, 0])

    # ----------------------------------------------------------- distributed

    def to_distributed(self, mesh, axis: str = "data") -> "DistIndex":
        """Pin the per-slot V buffers on ``mesh`` and serve query batches
        through the distributed verify-stage slot machinery (one W-side
        ``all_to_all`` per batch, no R bytes moved after this call).

        Re-plans placement (cheap — a static permutation from the stored
        cost-model loads) when the mesh size differs from the plan's
        ``n_devices``; never re-samples or re-partitions.
        """
        from repro.core import distributed as dist_lib

        return dist_lib.DistIndex.from_index(self, mesh, axis=axis)

    # ------------------------------------------------------------- save/load

    def manifest(self) -> dict:
        """The JSON manifest (format + config + shapes + placement summary)."""
        return {
            "format": FORMAT_NAME,
            "version": FORMAT_VERSION,
            "metric": self.metric,
            "delta": float(self.delta),
            "k": self.k,
            "p": self.p,
            "n_dims": self.n_dims,
            "n_rows": self.n_rows,
            "n_features": self.n_features,
            "tighten": bool(self.tighten),
            "backend": self.backend,
            "prune": self.prune,
            "map_fused": bool(self.map_fused),
            "tile_v": self.tile_v,
            "tile_w": self.tile_w,
            "seed": self.seed,
            "build_s": float(self.build_s),
            "placement": {
                "strategy": self.placement.strategy,
                "n_devices": self.placement.n_devices,
                "n_slots": self.placement.n_slots,
                "certified_bound": float(self.placement.certified_bound),
            },
            "arrays": {name: list(getattr(self, name).shape) for name in _ARRAYS},
        }

    def save(self, path: str) -> str:
        """Write the versioned on-disk format: ``path/manifest.json`` +
        ``path/arrays.npz`` (all arrays bit-exact). Returns ``path``."""
        os.makedirs(path, exist_ok=True)
        arrays = {name: np.asarray(getattr(self, name)) for name in _ARRAYS}
        for name in _PLAN_ARRAYS:
            arrays[f"pl_{name}"] = np.asarray(getattr(self.placement, name))
        if self.node_confidences is not None:
            arrays["node_confidences"] = np.asarray(self.node_confidences)
        np.savez(os.path.join(path, "arrays.npz"), **arrays)
        with open(os.path.join(path, "manifest.json"), "w") as f:
            json.dump(self.manifest(), f, indent=2, sort_keys=True)
        return path

    @classmethod
    def load(
        cls,
        path: str,
        *,
        metric: str | None = None,
        delta: float | None = None,
        k: int | None = None,
    ) -> "MetricIndex":
        """Load an index, failing loudly instead of mis-answering.

        Format checks (``IndexFormatError``): missing/foreign manifest, a
        version this code does not speak, manifest/array shape disagreement.
        Config checks (``IndexMismatchError``): when the caller states the
        ``metric`` / ``delta`` / pivot count ``k`` its queries assume, any
        disagreement with the manifest raises with both values named.
        """
        mpath = os.path.join(path, "manifest.json")
        if not os.path.exists(mpath):
            raise IndexFormatError(f"no metric-index manifest at {mpath}")
        with open(mpath) as f:
            man = json.load(f)
        if man.get("format") != FORMAT_NAME:
            raise IndexFormatError(
                f"{mpath} is not a {FORMAT_NAME!r} artifact "
                f"(format={man.get('format')!r})"
            )
        version = man.get("version")
        if version != FORMAT_VERSION:
            raise IndexFormatError(
                f"index format version {version!r} is not supported by this "
                f"build (speaks version {FORMAT_VERSION}); re-save the index "
                f"with a matching version of the code"
            )
        if metric is not None and metric != man["metric"]:
            raise IndexMismatchError(
                f"index was built for metric {man['metric']!r} but the query "
                f"config expects {metric!r} — distances would be silently "
                f"wrong; rebuild the index for {metric!r}"
            )
        if delta is not None and not np.isclose(delta, man["delta"]):
            raise IndexMismatchError(
                f"index default delta is {man['delta']} but the query config "
                f"expects {delta} — pass delta= per query_batch() call for a "
                f"different radius, or rebuild to change the default"
            )
        if k is not None and k != man["k"]:
            raise IndexMismatchError(
                f"index holds {man['k']} pivots but the query config expects "
                f"k={k} — the partition plan would not match; rebuild"
            )

        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {name: z[name] for name in z.files}
        missing = [n for n in _ARRAYS if n not in arrays]
        if missing:
            raise IndexFormatError(f"arrays.npz is missing {missing}")
        for name, shape in man["arrays"].items():
            got = list(arrays[name].shape)
            if got != shape:
                raise IndexFormatError(
                    f"manifest says {name} has shape {shape} but arrays.npz "
                    f"holds {got} — artifact is corrupt or mixed between saves"
                )
        if int(man["k"]) != arrays["pivots"].shape[0]:
            raise IndexFormatError(
                f"manifest pivot count k={man['k']} disagrees with the stored "
                f"pivots array ({arrays['pivots'].shape[0]} rows)"
            )

        pman = man["placement"]
        loads = arrays["pl_cell_loads"]
        plan = placement_lib.PlacementPlan(
            strategy=pman["strategy"],
            n_devices=int(pman["n_devices"]),
            p=int(man["p"]),
            n_slots=int(pman["n_slots"]),
            cell_loads=loads,
            cell_first_slot=arrays["pl_cell_first_slot"],
            cell_n_slabs=arrays["pl_cell_n_slabs"],
            slot_cell=arrays["pl_slot_cell"],
            slot_slab=arrays["pl_slot_slab"],
            slot_load=arrays["pl_slot_load"],
            dispatch_of_slot=arrays["pl_dispatch_of_slot"],
            certified_bound=float(pman["certified_bound"]),
        )
        return cls(
            metric=man["metric"],
            delta=float(man["delta"]),
            n_dims=int(man["n_dims"]),
            tighten=bool(man["tighten"]),
            backend=man["backend"],
            prune=man["prune"],
            map_fused=bool(man["map_fused"]),
            tile_v=int(man["tile_v"]),
            tile_w=int(man["tile_w"]),
            seed=int(man["seed"]),
            placement_strategy=pman["strategy"],
            n_devices=int(pman["n_devices"]),
            data=arrays["data"],
            coords=arrays["coords"],
            cells=arrays["cells"],
            pivots=arrays["pivots"],
            anchors=arrays["anchors"],
            kernel_lo=arrays["kernel_lo"],
            kernel_hi=arrays["kernel_hi"],
            box_lo=arrays["box_lo"],
            box_hi=arrays["box_hi"],
            placement=plan,
            build_s=float(man.get("build_s", 0.0)),
            node_confidences=arrays.get("node_confidences"),
        )


# ---------------------------------------------------------------------------
# The build phase
# ---------------------------------------------------------------------------


def _base_boxes(
    plan: partition.PartitionPlan,
    x_mapped: Array,
    cells: Array,
    tighten: bool,
) -> tuple[np.ndarray, np.ndarray]:
    """Pre-expansion whole-box base: the member MBB of each cell (the same
    segment min/max expression ``partition.tighten`` uses, so expanding by
    the build δ reproduces the join's whole boxes bit-for-bit), or the
    kernel box when tightening is off. Empty cells collapse to the inverted
    (BIG, −BIG) box — no query radius can ever route into them."""
    if not tighten:
        return np.asarray(plan.kernel_lo), np.asarray(plan.kernel_hi)
    p = plan.p
    seg_min = jax.ops.segment_min(x_mapped, cells, num_segments=p)
    seg_max = jax.ops.segment_max(x_mapped, cells, num_segments=p)
    counts = jax.ops.segment_sum(
        jnp.ones_like(cells, jnp.float32), cells, num_segments=p
    )
    empty = counts == 0
    lo = jnp.where(empty[:, None], partition.BIG, seg_min)
    hi = jnp.where(empty[:, None], -partition.BIG, seg_max)
    return np.asarray(lo, np.float32), np.asarray(hi, np.float32)


def build_index(
    data: np.ndarray | Array,
    cfg: spjoin.JoinConfig,
    *,
    n_nodes: int = 4,
    n_devices: int | None = None,
) -> MetricIndex:
    """Run the build phase ONCE: sampling → anchors → partition boxes →
    member MBBs → LPT placement plan → cached coordinates and V lists.

    ``data`` is the indexed set R (full array or per-node shard list, as for
    ``spjoin.join``); ``cfg`` carries the same knobs the join uses (δ becomes
    the default query radius). ``n_devices`` sizes the stored placement plan
    (default: ``n_nodes``) — ``to_distributed`` re-plans cheaply when the
    serving mesh differs.

    The exact same control-plane helpers as ``spjoin.join`` run here
    (``fit_node_stats`` → ``draw_pivots`` → ``build_plan``), so a fixed seed
    yields the identical partition geometry the one-shot join would use.
    """
    t_start = time.perf_counter()
    key = jax.random.PRNGKey(cfg.seed)
    shards = spjoin._as_shards(data, n_nodes)
    allx = jnp.concatenate(shards, axis=0) if shards else jnp.asarray(data)

    # ---- sampling phase (once, at build) ---------------------------------
    k_sample, k_anchor = jax.random.split(key)
    node_stats = spjoin.fit_node_stats(shards, cfg.t_cells)
    pivots = spjoin.draw_pivots(k_sample, shards, node_stats, cfg)

    # ---- map-phase control plane (once, at build) ------------------------
    plan, smap = spjoin.build_plan(k_anchor, pivots, cfg)
    fused = cfg.map_fused and kops.supports_kernel(cfg.metric)
    backend = (
        kops.resolve_backend(cfg.backend, cfg.metric)
        if kops.supports_kernel(cfg.metric)
        else "numpy"
    )
    if fused:
        x_mapped, cells, _ = kops.map_assign(
            allx, smap.anchors, plan.kernel_lo, plan.kernel_hi,
            plan.whole_lo, plan.whole_hi, cfg.metric, backend=backend,
            want="cells",
        )
    else:
        x_mapped = smap(allx)
        cells = partition.assign_kernel(plan, x_mapped)
    box_lo, box_hi = _base_boxes(plan, x_mapped, cells, cfg.tighten)

    # ---- placement plan (cost-model loads from the pivots alone) ---------
    n_dev = int(n_devices or max(len(shards), 1))
    piv_mapped = np.asarray(smap(pivots), np.float32)
    piv_plan = partition.PartitionPlan(
        plan.kernel_lo, plan.kernel_hi,
        jnp.asarray(box_lo - np.float32(cfg.delta)),
        jnp.asarray(box_hi + np.float32(cfg.delta)),
        cfg.delta,
    )
    piv_cells = np.asarray(partition.assign_kernel(piv_plan, jnp.asarray(piv_mapped)))
    piv_member = np.asarray(
        partition.whole_membership(piv_plan, jnp.asarray(piv_mapped))
    )
    prune_active = verify_lib.resolve_prune(cfg.prune, cfg.metric, True) == "pivot"
    cell_loads, _, _, _ = placement_lib.planner_inputs(
        piv_mapped, piv_cells, piv_member,
        int(allx.shape[0]), int(allx.shape[0]), cfg.delta, prune_active,
    )
    pl = placement_lib.plan_placement(cell_loads, n_dev, strategy=cfg.placement)

    idx = MetricIndex(
        metric=cfg.metric,
        delta=float(cfg.delta),
        n_dims=int(smap.n_dims),
        tighten=bool(cfg.tighten),
        backend=backend,
        prune=cfg.prune,
        map_fused=bool(fused),
        tile_v=cfg.tile_v,
        tile_w=cfg.tile_w,
        seed=cfg.seed,
        placement_strategy=cfg.placement,
        n_devices=n_dev,
        data=np.asarray(allx, np.float32),
        coords=np.asarray(x_mapped, np.float32),
        cells=np.asarray(cells, np.int32),
        pivots=np.asarray(pivots, np.float32),
        anchors=np.asarray(smap.anchors, np.float32),
        kernel_lo=np.asarray(plan.kernel_lo, np.float32),
        kernel_hi=np.asarray(plan.kernel_hi, np.float32),
        box_lo=box_lo,
        box_hi=box_hi,
        placement=pl,
        node_confidences=np.array([st.confidence for st in node_stats]),
    )
    idx.build_s = time.perf_counter() - t_start
    return idx


def brute_force_query(
    index_data: np.ndarray, q: np.ndarray, delta: float, metric: str
) -> np.ndarray:
    """Oracle for tests/benchmarks: (i ∈ R, j ∈ Q) pairs from the dense
    cross-distance matrix — the parity target of ``query_batch``."""
    mask = np.asarray(
        distances.brute_force_join(
            jnp.asarray(index_data), jnp.asarray(q), delta, metric
        )
    )
    i, j = np.nonzero(mask)
    return np.stack([i, j], axis=1).astype(np.int64)
