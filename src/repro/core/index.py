"""Persistent metric index: build once, query millions (serving phase).

Every call to ``spjoin.join`` / ``distributed_join`` re-runs the whole
pipeline — sampling, GoF fits, anchor selection, the partition tree, the
placement plan — which is correct for one batch join and wrong for serving
query traffic. This module splits the pipeline into an explicit **build
phase** and a **query phase** (the DIMS three-stage shape — arXiv
2410.05091 — mapped onto our artifacts):

  build  (once)   sampling → anchors → kernel boxes → per-cell member MBBs
                  → cost-model placement plan → cached mapped coordinates
                  and per-cell V row lists of the indexed set R.
  query  (hot)    a batch of query points Q is routed through the SAME
                  fused map-assign kernel as the join's map phase — each
                  query's anchor distances (its mapped coordinates) are
                  computed exactly once and reused twice: first as the box
                  containment test that routes it to only the owning cells
                  (Lemma 4), then as the pivot-filter coordinates that
                  prune candidate pairs before exact evaluation
                  (``core.verify`` candidate mask). Verification streams
                  through the tiled verify engine in R×S mode (V = the
                  pinned index cells, W = the routed queries) without ever
                  re-sampling, re-fitting or re-partitioning.

δ at query time: the index stores the *pre-expansion* base boxes (the
tightened member MBB of each cell, or the kernel box when ``tighten=False``)
and expands them by the QUERY radius on the way in, so any ``delta`` — equal
to, below, or above the build-time default — answers exactly (Lemma 4 holds
for whatever radius the boxes were expanded by). The build-time δ is only the
default radius and the one the placement plan was costed at; see
docs/SERVING.md for the re-plan vs rebuild trade-off.

On-disk format (``index.save(path)`` / ``MetricIndex.load(path)``): a
directory holding ``manifest.json`` (format name + version, the build
config, array shapes, the placement summary) and ``arrays.npz`` (every
array, bit-exact). The manifest is validated first: an unknown format or a
version this code does not speak fails loudly (``IndexFormatError``), and a
manifest disagreeing with the caller's expected metric / δ / pivot count
fails with ``IndexMismatchError`` instead of silently mis-answering —
worked example in docs/SERVING.md.

The distributed serving path (``index.to_distributed(mesh)`` →
``core.distributed.DistIndex``) pins the per-slot V buffers on devices once
and serves query batches through the verify stage's slot machinery — one
W-side ``all_to_all`` per batch, zero R-side bytes moved after build.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import TYPE_CHECKING

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, distances, mapping, partition, spjoin
from repro.core import placement as placement_lib
from repro.core import verify as verify_lib
from repro.kernels import ops as kops

if TYPE_CHECKING:  # pragma: no cover - typing only, avoids an import cycle
    from repro.core.distributed import DistIndex

Array = jnp.ndarray

FORMAT_NAME = "spjoin-metric-index"
# Version 2 adds the incremental-insert state: the manifest's "incremental"
# block (n_base / n_inserted / n_batches) and the observed_w drift telemetry
# array. Version-1 artifacts predate insert_batch and are refused (re-save
# with current code) — silently defaulting the counters would let a
# save→insert→load→insert round trip diverge from the unsaved session.
FORMAT_VERSION = 2

# Arrays persisted bit-exact in arrays.npz (name -> MetricIndex attribute).
_ARRAYS = (
    "data", "coords", "cells", "pivots", "anchors",
    "kernel_lo", "kernel_hi", "box_lo", "box_hi", "observed_w",
)
_PLAN_ARRAYS = (
    "cell_loads", "cell_first_slot", "cell_n_slabs",
    "slot_cell", "slot_slab", "slot_load", "dispatch_of_slot",
)


class IndexFormatError(ValueError):
    """The on-disk artifact is not a metric index this code can read."""


class IndexMismatchError(ValueError):
    """The manifest disagrees with the caller's expected query config."""


@dataclasses.dataclass
class QueryStats:
    """Telemetry of one ``query_batch`` call (the serving analogue of
    ``VerifyStats`` — which it embeds as ``verify``)."""

    n_queries: int = 0
    n_routed: int = 0  # Σ per-query owning-cell memberships (dispatch fan-out)
    n_cells_touched: int = 0  # cells that received ≥ 1 query
    route_s: float = 0.0  # map-assign + membership time
    verify_s: float = 0.0  # tiled engine time
    verify: verify_lib.VerifyStats | None = None

    @property
    def duplication(self) -> float:
        """Σ memberships / |Q| — the query-side routing amplification
        (the serving analogue of the shuffle metric Σ|W_h|/|S|)."""
        return self.n_routed / max(self.n_queries, 1)


@dataclasses.dataclass
class StreamStats:
    """Telemetry of one ``insert_batch`` call — the streaming analogue of
    ``QueryStats``, plus the drift monitor's decision trail.

    ``drift`` is ``cost_model.load_drift`` between the placement plan's
    predicted per-cell loads and the loads observed so far; ``action`` is
    what actually fired ("none" | "replan" | "resample"; the session layer
    also stamps "build" on the first batch). ``resample_due`` flags a drift
    past the re-sample threshold when no rebuild config was supplied — the
    cheap re-plan ran instead and the caller should rebuild when it can.
    ``balance_std_before``/``after`` score the plan in force before/after
    the action on the SAME observed loads (``placement.device_loads_under``),
    so a re-plan's improvement is directly visible.
    """

    n_delta: int = 0  # rows in this insertion batch
    n_resident: int = 0  # rows resident before the insert
    n_total: int = 0  # rows resident after the insert
    n_cross_pairs: int = 0  # ΔR×R_old pairs emitted
    n_self_pairs: int = 0  # ΔR×ΔR pairs emitted
    n_new_pairs: int = 0  # total pairs this batch contributed
    drift: float = 0.0
    replan_threshold: float = 0.0
    resample_threshold: float = 0.0
    action: str = "none"
    resample_due: bool = False
    balance_std_before: float = 0.0
    balance_std_after: float = 0.0
    route_s: float = 0.0  # fused delta map-assign time
    verify_s: float = 0.0  # cross + self verify time
    update_s: float = 0.0  # absorb + drift bookkeeping time
    cross_verify: verify_lib.VerifyStats | None = None
    self_verify: verify_lib.VerifyStats | None = None


def _member_matrix(
    coords: np.ndarray, wlo: np.ndarray, whi: np.ndarray, chunk: int = 65536
) -> np.ndarray:
    """(n, p) bool whole membership of mapped coordinates under δ-expanded
    boxes — the same closed-interval comparison the fused kernel packs into
    its bitmask, evaluated host-side from CACHED coordinates (no re-map).
    Row-chunked so the (n, p, dims) broadcast never materializes."""
    n = coords.shape[0]
    out = np.zeros((n, wlo.shape[0]), bool)
    for i0 in range(0, n, chunk):
        c = coords[i0 : i0 + chunk]
        out[i0 : i0 + chunk] = (
            (c[:, None, :] >= wlo[None]) & (c[:, None, :] <= whi[None])
        ).all(-1)
    return out


def _member_counts(
    coords: np.ndarray, wlo: np.ndarray, whi: np.ndarray, chunk: int = 65536
) -> np.ndarray:
    """(p,) float64 per-cell whole-member counts (drift telemetry baseline)."""
    out = np.zeros(wlo.shape[0], np.float64)
    for i0 in range(0, coords.shape[0], chunk):
        c = coords[i0 : i0 + chunk]
        out += (
            ((c[:, None, :] >= wlo[None]) & (c[:, None, :] <= whi[None]))
            .all(-1)
            .sum(0)
        )
    return out


@dataclasses.dataclass
class MetricIndex:
    """Everything the query phase needs, with the build phase paid once.

    All arrays are host numpy (the single-host serving path gathers verify
    tiles from them; ``to_distributed`` device-puts the per-slot V buffers).
    ``coords`` are R's mapped coordinates — the cached index-to-pivot
    distances the pivot filter reuses on every query.
    """

    # -- build config (the manifest scalars) --------------------------------
    metric: str
    delta: float  # build-time default query radius
    n_dims: int
    tighten: bool
    backend: str  # RESOLVED backend ("numpy" | "pallas") the build mapped with
    prune: str  # requested prune mode ("pivot" | "none")
    map_fused: bool
    tile_v: int
    tile_w: int
    seed: int
    placement_strategy: str
    n_devices: int  # devices the stored placement plan targets

    # -- build artifacts ----------------------------------------------------
    data: np.ndarray  # (N, m) the indexed set R
    coords: np.ndarray  # (N, n) R's mapped coordinates (pivot distances)
    cells: np.ndarray  # (N,) kernel cell of each R row
    pivots: np.ndarray  # (k, m) sampled pivots
    anchors: np.ndarray  # (n, m) anchor pivots of the space map
    kernel_lo: np.ndarray  # (p, n) half-open kernel boxes
    kernel_hi: np.ndarray
    box_lo: np.ndarray  # (p, n) PRE-expansion whole-box base (member MBB
    box_hi: np.ndarray  # when tighten, else the kernel box); query boxes
    #   are box ∓ query-δ — recomputed per batch, any radius answers exactly
    placement: placement_lib.PlacementPlan
    build_s: float = 0.0
    node_confidences: np.ndarray | None = None

    # -- incremental-insert state (persisted, format v2) --------------------
    n_base: int = 0  # rows the initial build indexed
    n_inserted: int = 0  # rows appended by insert_batch since build/rebuild
    n_batches: int = 0  # insert_batch calls absorbed (survives rebuilds)
    observed_w: np.ndarray | None = None  # (p,) observed whole-member counts
    #   — exact at build, then accumulated per delta at insert time (an old
    #   row's membership is not recomputed as boxes grow); drift TELEMETRY,
    #   never exactness-bearing (docs/STREAMING.md)

    # -- derived query-phase caches (never persisted) -----------------------
    _v_lists: list[np.ndarray] | None = dataclasses.field(default=None, repr=False)

    # ------------------------------------------------------------------ api

    @property
    def n_rows(self) -> int:
        return int(self.data.shape[0])

    @property
    def n_features(self) -> int:
        return int(self.data.shape[1])

    @property
    def k(self) -> int:
        return int(self.pivots.shape[0])

    @property
    def p(self) -> int:
        return int(self.kernel_lo.shape[0])

    @property
    def space_map(self) -> mapping.SpaceMap:
        return mapping.SpaceMap(jnp.asarray(self.anchors), self.metric)

    @property
    def v_lists(self) -> list[np.ndarray]:
        """Per-cell V row lists (global R indices), computed once per index."""
        if self._v_lists is None:
            order = np.argsort(self.cells, kind="stable")
            bounds = np.searchsorted(self.cells[order], np.arange(self.p + 1))
            self._v_lists = [
                order[bounds[h] : bounds[h + 1]] for h in range(self.p)
            ]
        return self._v_lists

    def query_boxes(self, delta: float) -> tuple[np.ndarray, np.ndarray]:
        """The δ-expanded whole boxes for a given query radius — the exact
        expression the build phase would have produced for that δ, so
        ``delta == self.delta`` reproduces the join's boxes bit-for-bit."""
        return (
            (self.box_lo - np.float32(delta)).astype(np.float32),
            (self.box_hi + np.float32(delta)).astype(np.float32),
        )

    def route(self, q: np.ndarray | Array, delta: float) -> tuple[np.ndarray, np.ndarray]:
        """Map a query batch and route it to its owning cells.

        Returns ``(q_coords (B, n), member (B, p))`` — the mapped
        coordinates (reused by the pivot filter) and the whole-box
        membership under the δ-expanded query boxes. Uses the same fused
        map-assign kernel (and fp algorithm) as the build phase, so a
        borderline query coordinate can never land on a different side of
        a box edge than the indexed MBB implies.
        """
        q = jnp.asarray(q, jnp.float32)
        wlo, whi = self.query_boxes(delta)
        if q.shape[0] == 0:
            return (
                np.zeros((0, self.n_dims), np.float32),
                np.zeros((0, self.p), bool),
            )
        if self.map_fused and kops.supports_kernel(self.metric):
            qm, _, bits = kops.map_assign(
                q, jnp.asarray(self.anchors),
                jnp.asarray(self.kernel_lo), jnp.asarray(self.kernel_hi),
                jnp.asarray(wlo), jnp.asarray(whi),
                self.metric, backend=self.backend, want="member",
            )
            member = kops.unpack_membership(bits, self.p)
        else:
            qm = self.space_map(q)
            member = (
                (qm[:, None, :] >= jnp.asarray(wlo)[None])
                & (qm[:, None, :] <= jnp.asarray(whi)[None])
            ).all(-1)
        return np.asarray(qm, np.float32), np.asarray(member, bool)

    def query_batch(
        self,
        q: np.ndarray | Array,
        delta: float | None = None,
        *,
        with_stats: bool = False,
    ):
        """Batched δ-range query: all pairs (i ∈ R, j ∈ Q) with
        D(r_i, q_j) ≤ δ, as an (n_pairs, 2) int64 array (column 0 indexes
        the indexed set, column 1 the query batch). ``delta=None`` uses the
        build-time default. Fixed-seed results are byte-identical to
        ``distances.brute_force_join(R, Q, delta)``.

        No sampling, fitting or partitioning happens here — only the fused
        map pass over Q and the tiled verify engine over the routed cells.
        """
        delta = self.delta if delta is None else float(delta)
        q_np = np.asarray(q, np.float32)
        t0 = time.perf_counter()
        q_coords, member = self.route(q_np, delta)
        t_route = time.perf_counter() - t0

        t0 = time.perf_counter()
        pairs, vstats = verify_lib.verify_resident(
            self.data, self.cells, self.v_lists, member, delta, self.metric,
            config=self._engine_config(), data_w=q_np,
            coords=self.coords, coords_w=q_coords,
        )
        t_verify = time.perf_counter() - t0
        if not with_stats:
            return pairs
        touched = int((member.sum(0) > 0).sum())
        stats = QueryStats(
            n_queries=int(q_np.shape[0]),
            n_routed=int(member.sum()),
            n_cells_touched=touched,
            route_s=t_route,
            verify_s=t_verify,
            verify=vstats,
        )
        return pairs, stats

    def query(self, q: np.ndarray | Array, delta: float | None = None) -> np.ndarray:
        """Single-point δ-range query: sorted R row indices within δ of ``q``."""
        q = np.asarray(q, np.float32)
        if q.ndim != 1:
            raise ValueError(f"query() takes one point (m,); got shape {q.shape}")
        pairs = self.query_batch(q[None, :], delta)
        return np.sort(pairs[:, 0])

    # ------------------------------------------------------------ streaming

    def _engine_config(self) -> verify_lib.EngineConfig:
        return verify_lib.EngineConfig(
            backend=self.backend, tile_v=self.tile_v, tile_w=self.tile_w,
            prune=verify_lib.resolve_prune(self.prune, self.metric, True),
        )

    def _ensure_stream_state(self) -> None:
        """Initialize the incremental counters on indexes that predate them
        (hand-constructed in tests, or deserialized mid-refactor)."""
        if self.n_base == 0 and self.n_rows > self.n_inserted:
            self.n_base = self.n_rows - self.n_inserted
        if self.observed_w is None:
            wlo, whi = self.query_boxes(self.delta)
            self.observed_w = _member_counts(self.coords, wlo, whi)

    @property
    def observed_loads(self) -> np.ndarray:
        """(p,) OBSERVED per-cell verification loads |V_h|·|W_h| — the
        measured counterpart of the placement plan's predicted
        ``cell_loads`` and the drift monitor's second input."""
        self._ensure_stream_state()
        v_obs = np.bincount(self.cells, minlength=self.p).astype(np.float64)
        assert self.observed_w is not None
        return v_obs * self.observed_w[: self.p]

    def self_pairs(self) -> np.ndarray:
        """Self-join pairs of the resident set through the index's own
        cached artifacts (coords, cells, δ-expanded boxes) — what a one-shot
        ``spjoin.join`` over this partition geometry emits, without
        re-running any control plane. The streaming session uses this for
        batch 0; fixed-seed output is byte-identical to
        ``spjoin.brute_force_pairs`` (the join is exact under any
        containment-consistent plan)."""
        wlo, whi = self.query_boxes(self.delta)
        member = _member_matrix(self.coords, wlo, whi)
        pairs, _ = verify_lib.verify_pairs(
            self.data, self.cells, member, self.delta, self.metric,
            config=self._engine_config(), coords=self.coords,
        )
        return pairs

    def _delta_route(
        self, d_np: np.ndarray
    ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Map an insertion delta through the SAME fused map-assign pass as
        the build: mapped coordinates, kernel cells, and whole membership
        under the CURRENT (pre-absorb) δ-expanded boxes — the Lemma-4 routing
        for the ΔR×R_old cross verify."""
        wlo, whi = self.query_boxes(self.delta)
        if self.map_fused and kops.supports_kernel(self.metric):
            dm, cells, bits = kops.map_assign(
                jnp.asarray(d_np), jnp.asarray(self.anchors),
                jnp.asarray(self.kernel_lo), jnp.asarray(self.kernel_hi),
                jnp.asarray(wlo), jnp.asarray(whi),
                self.metric, backend=self.backend, want="both",
            )
            member = kops.unpack_membership(bits, self.p)
            return (
                np.asarray(dm, np.float32),
                np.asarray(cells, np.int32),
                np.asarray(member, bool),
            )
        dm = np.asarray(self.space_map(jnp.asarray(d_np)), np.float32)
        pplan = partition.PartitionPlan(
            jnp.asarray(self.kernel_lo), jnp.asarray(self.kernel_hi),
            jnp.asarray(wlo), jnp.asarray(whi), self.delta,
        )
        cells = np.asarray(partition.assign_kernel(pplan, jnp.asarray(dm)), np.int32)
        member = _member_matrix(dm, wlo, whi)
        return dm, cells, member

    def _delta_self_pairs(
        self, d_np: np.ndarray, d_coords: np.ndarray, d_cells: np.ndarray
    ) -> tuple[np.ndarray, verify_lib.VerifyStats, np.ndarray, np.ndarray, np.ndarray]:
        """ΔR×ΔR self-join, DELTA-LOCAL ids, plus the updated base boxes.

        The member MBBs are first extended with the delta's own coordinates —
        only then does Lemma 4 cover delta-vs-delta partners (each delta row
        must sit inside its own cell's box before the δ-expansion can catch
        its neighbours). Returns (pairs_local, stats, new_box_lo, new_box_hi,
        member_new) with member_new the delta's membership under the UPDATED
        boxes (also the absorb's observed_w increment).
        """
        new_lo = self.box_lo.copy()
        new_hi = self.box_hi.copy()
        np.minimum.at(new_lo, d_cells, d_coords)
        np.maximum.at(new_hi, d_cells, d_coords)
        qlo = (new_lo - np.float32(self.delta)).astype(np.float32)
        qhi = (new_hi + np.float32(self.delta)).astype(np.float32)
        member_new = _member_matrix(d_coords, qlo, qhi)
        pairs, vstats = verify_lib.verify_pairs(
            d_np, d_cells, member_new, self.delta, self.metric,
            config=self._engine_config(), coords=d_coords,
        )
        return pairs, vstats, new_lo, new_hi, member_new

    def _absorb(
        self,
        d_np: np.ndarray,
        d_coords: np.ndarray,
        d_cells: np.ndarray,
        member_new: np.ndarray,
        new_lo: np.ndarray,
        new_hi: np.ndarray,
    ) -> None:
        """Append the delta to the resident arrays and every derived cache.

        The per-cell V lists are EXTENDED, not recomputed: delta ids are
        global-contiguous above the resident set, so appending each cell's
        delta members preserves the exact order the stable-argsort
        derivation would produce from scratch — repeated deltas amortize.
        """
        n_old = self.n_rows
        assert self.observed_w is not None
        self.data = np.concatenate([self.data, d_np])
        self.coords = np.concatenate([self.coords, d_coords])
        self.cells = np.concatenate([self.cells, d_cells.astype(self.cells.dtype)])
        self.box_lo = new_lo
        self.box_hi = new_hi
        if self._v_lists is not None:
            order = np.argsort(d_cells, kind="stable")
            bounds = np.searchsorted(d_cells[order], np.arange(self.p + 1))
            for h in range(self.p):
                extra = order[bounds[h] : bounds[h + 1]]
                if extra.size:
                    self._v_lists[h] = np.concatenate(
                        [self._v_lists[h], n_old + extra]
                    )
        self.observed_w = self.observed_w + member_new.sum(0)
        self.n_inserted += int(d_np.shape[0])
        self.n_batches += 1

    def _rebuild(self, cfg) -> None:
        """Re-sample pivots and rebuild from the full accumulated data (the
        expensive drift action): every artifact — pivots, anchors, partition,
        boxes, placement, caches — is replaced in place. The accumulated
        PAIR SET is untouched: the join is exact under any
        containment-consistent plan, so a rebuild resets predictions, never
        answers."""
        n_batches = self.n_batches
        if self.n_rows < cfg.n_dims:
            # Row-fallback samplers cap pivots at n_rows; a tiny stream can't
            # support the full mapped dimensionality yet (spjoin session
            # applies the same clamp on its first build).
            cfg = dataclasses.replace(cfg, n_dims=max(1, self.n_rows))
        fresh = build_index(
            self.data, cfg,
            n_nodes=max(1, min(4, self.n_rows)), n_devices=self.n_devices,
        )
        for f in dataclasses.fields(self):
            setattr(self, f.name, getattr(fresh, f.name))
        self.n_batches = n_batches

    def _drift_step(
        self,
        stats: StreamStats,
        replan_drift: float,
        resample_drift: float,
        rebuild_cfg,
    ) -> None:
        """Measure drift against the plan in force and fire the cheap action
        (re-plan: a static permutation, pairs unchanged) before the expensive
        one (re-sample → full rebuild; needs ``rebuild_cfg``)."""
        observed = self.observed_loads
        stats.drift = cost_model.load_drift(self.placement.cell_loads, observed)
        stats.balance_std_before = float(
            placement_lib.device_loads_under(self.placement, observed).std()
        )
        action = placement_lib.drift_action(stats.drift, replan_drift, resample_drift)
        if action == "resample" and rebuild_cfg is None:
            # No control-plane config to rebuild with: fall back to the cheap
            # action and surface the debt (resample_due) to the caller.
            stats.resample_due = True
            action = "replan"
        if action == "resample":
            self._rebuild(rebuild_cfg)
        elif action == "replan":
            self.placement = placement_lib.plan_placement(
                observed, self.placement.n_devices,
                strategy=self.placement_strategy,
            )
        stats.action = action
        stats.balance_std_after = float(
            placement_lib.device_loads_under(self.placement, self.observed_loads).std()
        )

    def insert_batch(
        self,
        new_rows: np.ndarray | Array,
        *,
        replan_drift: float | None = None,
        resample_drift: float | None = None,
        rebuild_cfg=None,
        _cross_pairs_fn=None,
    ) -> tuple[np.ndarray, StreamStats]:
        """Absorb an insertion batch and return the NEW pairs it creates.

        Only the delta is mapped (one fused map-assign pass); the new pairs
        are ΔR×R_old — the delta routed against the RESIDENT per-cell V
        lists through the same ``verify_resident`` tile path as
        ``query_batch`` — plus the ΔR×ΔR self-join under the updated member
        MBBs. Returned pairs use GLOBAL row ids (delta row j ↦ n_resident +
        j), i < j, sorted unique; no sampling, fitting, partitioning or
        placement work happens unless the drift monitor fires.

        Exactness contract: for a fixed seed and ANY split of R into
        insertion batches, the union of ``build``-time pairs and every
        ``insert_batch`` return is byte-identical to a from-scratch join of
        the full R (property-tested in tests/test_incremental.py).

        ``replan_drift`` / ``resample_drift``: drift thresholds (default
        ``core.placement.REPLAN_DRIFT`` / ``RESAMPLE_DRIFT``). ``rebuild_cfg``
        (a ``spjoin.JoinConfig``) arms the re-sample action; without it a
        re-sample-worthy drift downgrades to a re-plan with
        ``StreamStats.resample_due`` set. ``_cross_pairs_fn`` lets the
        distributed mirror route the ΔR×R_old verify through its serve stage
        while sharing this exact control flow.
        """
        self._ensure_stream_state()
        rt = placement_lib.REPLAN_DRIFT if replan_drift is None else float(replan_drift)
        rs = placement_lib.RESAMPLE_DRIFT if resample_drift is None else float(resample_drift)
        d_np = np.asarray(new_rows, np.float32)
        if d_np.ndim != 2 or (d_np.shape[0] and d_np.shape[1] != self.n_features):
            raise ValueError(
                f"insert_batch expects (B, {self.n_features}) rows; got "
                f"shape {d_np.shape}"
            )
        stats = StreamStats(
            n_delta=int(d_np.shape[0]), n_resident=self.n_rows,
            n_total=self.n_rows + int(d_np.shape[0]),
            replan_threshold=rt, resample_threshold=rs,
        )
        if d_np.shape[0] == 0:
            # Empty delta: nothing routed, nothing absorbed, nothing fired.
            stats.drift = cost_model.load_drift(
                self.placement.cell_loads, self.observed_loads
            )
            return np.zeros((0, 2), np.int64), stats

        n_old = self.n_rows
        t0 = time.perf_counter()
        d_coords, d_cells, d_member_old = self._delta_route(d_np)
        stats.route_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        if _cross_pairs_fn is None:
            cross, cstats = verify_lib.verify_resident(
                self.data, self.cells, self.v_lists, d_member_old,
                self.delta, self.metric, config=self._engine_config(),
                data_w=d_np, coords=self.coords, coords_w=d_coords,
            )
            stats.cross_verify = cstats
        else:
            cross = np.asarray(_cross_pairs_fn(d_np), np.int64).reshape(-1, 2)
        self_local, sstats, new_lo, new_hi, member_new = self._delta_self_pairs(
            d_np, d_coords, d_cells
        )
        stats.self_verify = sstats
        stats.verify_s = time.perf_counter() - t0
        stats.n_cross_pairs = int(cross.shape[0])
        stats.n_self_pairs = int(self_local.shape[0])

        # Globalize: cross pairs are (i ∈ resident, j ∈ delta) — already
        # i < n_old + j; ΔΔ pairs shift both columns above the resident set.
        chunks = []
        if cross.shape[0]:
            chunks.append(
                np.stack([cross[:, 0], n_old + cross[:, 1]], axis=1)
            )
        if self_local.shape[0]:
            chunks.append(self_local + n_old)
        if chunks:
            pairs = np.unique(np.concatenate(chunks), axis=0).astype(np.int64)
        else:
            pairs = np.zeros((0, 2), np.int64)
        stats.n_new_pairs = int(pairs.shape[0])

        t0 = time.perf_counter()
        self._absorb(d_np, d_coords, d_cells, member_new, new_lo, new_hi)
        self._drift_step(stats, rt, rs, rebuild_cfg)
        stats.update_s = time.perf_counter() - t0
        return pairs, stats

    # ----------------------------------------------------------- distributed

    def to_distributed(self, mesh, axis: str = "data") -> "DistIndex":
        """Pin the per-slot V buffers on ``mesh`` and serve query batches
        through the distributed verify-stage slot machinery (one W-side
        ``all_to_all`` per batch, no R bytes moved after this call).

        Re-plans placement (cheap — a static permutation from the stored
        cost-model loads) when the mesh size differs from the plan's
        ``n_devices``; never re-samples or re-partitions.
        """
        from repro.core import distributed as dist_lib

        return dist_lib.DistIndex.from_index(self, mesh, axis=axis)

    # ------------------------------------------------------------- save/load

    def manifest(self) -> dict:
        """The JSON manifest (format + config + shapes + placement summary)."""
        return {
            "format": FORMAT_NAME,
            "version": FORMAT_VERSION,
            "metric": self.metric,
            "delta": float(self.delta),
            "k": self.k,
            "p": self.p,
            "n_dims": self.n_dims,
            "n_rows": self.n_rows,
            "n_features": self.n_features,
            "tighten": bool(self.tighten),
            "backend": self.backend,
            "prune": self.prune,
            "map_fused": bool(self.map_fused),
            "tile_v": self.tile_v,
            "tile_w": self.tile_w,
            "seed": self.seed,
            "build_s": float(self.build_s),
            "incremental": {
                "n_base": int(self.n_base),
                "n_inserted": int(self.n_inserted),
                "n_batches": int(self.n_batches),
            },
            "placement": {
                "strategy": self.placement.strategy,
                "n_devices": self.placement.n_devices,
                "n_slots": self.placement.n_slots,
                "certified_bound": float(self.placement.certified_bound),
            },
            "arrays": {name: list(getattr(self, name).shape) for name in _ARRAYS},
        }

    def save(self, path: str) -> str:
        """Write the versioned on-disk format: ``path/manifest.json`` +
        ``path/arrays.npz`` (all arrays bit-exact). Returns ``path``."""
        self._ensure_stream_state()
        os.makedirs(path, exist_ok=True)
        arrays = {name: np.asarray(getattr(self, name)) for name in _ARRAYS}
        for name in _PLAN_ARRAYS:
            arrays[f"pl_{name}"] = np.asarray(getattr(self.placement, name))
        if self.node_confidences is not None:
            arrays["node_confidences"] = np.asarray(self.node_confidences)
        np.savez(os.path.join(path, "arrays.npz"), **arrays)
        with open(os.path.join(path, "manifest.json"), "w") as f:
            json.dump(self.manifest(), f, indent=2, sort_keys=True)
        return path

    @classmethod
    def load(
        cls,
        path: str,
        *,
        metric: str | None = None,
        delta: float | None = None,
        k: int | None = None,
    ) -> "MetricIndex":
        """Load an index, failing loudly instead of mis-answering.

        Format checks (``IndexFormatError``): missing/foreign manifest, a
        version this code does not speak, manifest/array shape disagreement.
        Config checks (``IndexMismatchError``): when the caller states the
        ``metric`` / ``delta`` / pivot count ``k`` its queries assume, any
        disagreement with the manifest raises with both values named.
        """
        mpath = os.path.join(path, "manifest.json")
        if not os.path.exists(mpath):
            raise IndexFormatError(f"no metric-index manifest at {mpath}")
        with open(mpath) as f:
            man = json.load(f)
        if man.get("format") != FORMAT_NAME:
            raise IndexFormatError(
                f"{mpath} is not a {FORMAT_NAME!r} artifact "
                f"(format={man.get('format')!r})"
            )
        version = man.get("version")
        if version != FORMAT_VERSION:
            raise IndexFormatError(
                f"index format version {version!r} is not supported by this "
                f"build (speaks version {FORMAT_VERSION}); re-save the index "
                f"with a matching version of the code"
            )
        if metric is not None and metric != man["metric"]:
            raise IndexMismatchError(
                f"index was built for metric {man['metric']!r} but the query "
                f"config expects {metric!r} — distances would be silently "
                f"wrong; rebuild the index for {metric!r}"
            )
        if delta is not None and not np.isclose(delta, man["delta"]):
            raise IndexMismatchError(
                f"index default delta is {man['delta']} but the query config "
                f"expects {delta} — pass delta= per query_batch() call for a "
                f"different radius, or rebuild to change the default"
            )
        if k is not None and k != man["k"]:
            raise IndexMismatchError(
                f"index holds {man['k']} pivots but the query config expects "
                f"k={k} — the partition plan would not match; rebuild"
            )

        with np.load(os.path.join(path, "arrays.npz")) as z:
            arrays = {name: z[name] for name in z.files}
        missing = [n for n in _ARRAYS if n not in arrays]
        if missing:
            raise IndexFormatError(f"arrays.npz is missing {missing}")
        for name, shape in man["arrays"].items():
            got = list(arrays[name].shape)
            if got != shape:
                raise IndexFormatError(
                    f"manifest says {name} has shape {shape} but arrays.npz "
                    f"holds {got} — artifact is corrupt or mixed between saves"
                )
        if int(man["k"]) != arrays["pivots"].shape[0]:
            raise IndexFormatError(
                f"manifest pivot count k={man['k']} disagrees with the stored "
                f"pivots array ({arrays['pivots'].shape[0]} rows)"
            )
        inc = man.get("incremental")
        if not isinstance(inc, dict) or not {
            "n_base", "n_inserted", "n_batches"
        } <= set(inc):
            raise IndexFormatError(
                "version-2 manifest is missing the incremental block "
                "(n_base / n_inserted / n_batches) — artifact is corrupt"
            )
        if int(inc["n_base"]) + int(inc["n_inserted"]) != int(man["n_rows"]):
            raise IndexMismatchError(
                f"incremental counters disagree with the stored data: "
                f"n_base={inc['n_base']} + n_inserted={inc['n_inserted']} != "
                f"n_rows={man['n_rows']} — the appended-delta history does "
                f"not describe this artifact; refusing to resume the stream"
            )

        pman = man["placement"]
        loads = arrays["pl_cell_loads"]
        plan = placement_lib.PlacementPlan(
            strategy=pman["strategy"],
            n_devices=int(pman["n_devices"]),
            p=int(man["p"]),
            n_slots=int(pman["n_slots"]),
            cell_loads=loads,
            cell_first_slot=arrays["pl_cell_first_slot"],
            cell_n_slabs=arrays["pl_cell_n_slabs"],
            slot_cell=arrays["pl_slot_cell"],
            slot_slab=arrays["pl_slot_slab"],
            slot_load=arrays["pl_slot_load"],
            dispatch_of_slot=arrays["pl_dispatch_of_slot"],
            certified_bound=float(pman["certified_bound"]),
        )
        return cls(
            metric=man["metric"],
            delta=float(man["delta"]),
            n_dims=int(man["n_dims"]),
            tighten=bool(man["tighten"]),
            backend=man["backend"],
            prune=man["prune"],
            map_fused=bool(man["map_fused"]),
            tile_v=int(man["tile_v"]),
            tile_w=int(man["tile_w"]),
            seed=int(man["seed"]),
            placement_strategy=pman["strategy"],
            n_devices=int(pman["n_devices"]),
            data=arrays["data"],
            coords=arrays["coords"],
            cells=arrays["cells"],
            pivots=arrays["pivots"],
            anchors=arrays["anchors"],
            kernel_lo=arrays["kernel_lo"],
            kernel_hi=arrays["kernel_hi"],
            box_lo=arrays["box_lo"],
            box_hi=arrays["box_hi"],
            placement=plan,
            build_s=float(man.get("build_s", 0.0)),
            node_confidences=arrays.get("node_confidences"),
            n_base=int(inc["n_base"]),
            n_inserted=int(inc["n_inserted"]),
            n_batches=int(inc["n_batches"]),
            observed_w=arrays["observed_w"],
        )


# ---------------------------------------------------------------------------
# The build phase
# ---------------------------------------------------------------------------


def _base_boxes(
    plan: partition.PartitionPlan,
    x_mapped: Array,
    cells: Array,
    tighten: bool,
) -> tuple[np.ndarray, np.ndarray]:
    """Pre-expansion whole-box base: the member MBB of each cell (the same
    segment min/max expression ``partition.tighten`` uses, so expanding by
    the build δ reproduces the join's whole boxes bit-for-bit), or the
    kernel box when tightening is off. Empty cells collapse to the inverted
    (BIG, −BIG) box — no query radius can ever route into them."""
    if not tighten:
        return np.asarray(plan.kernel_lo), np.asarray(plan.kernel_hi)
    p = plan.p
    seg_min = jax.ops.segment_min(x_mapped, cells, num_segments=p)
    seg_max = jax.ops.segment_max(x_mapped, cells, num_segments=p)
    counts = jax.ops.segment_sum(
        jnp.ones_like(cells, jnp.float32), cells, num_segments=p
    )
    empty = counts == 0
    lo = jnp.where(empty[:, None], partition.BIG, seg_min)
    hi = jnp.where(empty[:, None], -partition.BIG, seg_max)
    return np.asarray(lo, np.float32), np.asarray(hi, np.float32)


def build_index(
    data: np.ndarray | Array,
    cfg: spjoin.JoinConfig,
    *,
    n_nodes: int = 4,
    n_devices: int | None = None,
) -> MetricIndex:
    """Run the build phase ONCE: sampling → anchors → partition boxes →
    member MBBs → LPT placement plan → cached coordinates and V lists.

    ``data`` is the indexed set R (full array or per-node shard list, as for
    ``spjoin.join``); ``cfg`` carries the same knobs the join uses (δ becomes
    the default query radius). ``n_devices`` sizes the stored placement plan
    (default: ``n_nodes``) — ``to_distributed`` re-plans cheaply when the
    serving mesh differs.

    The exact same control-plane helpers as ``spjoin.join`` run here
    (``fit_node_stats`` → ``draw_pivots`` → ``build_plan``), so a fixed seed
    yields the identical partition geometry the one-shot join would use.
    """
    t_start = time.perf_counter()
    key = jax.random.PRNGKey(cfg.seed)
    shards = spjoin._as_shards(data, n_nodes)
    allx = jnp.concatenate(shards, axis=0) if shards else jnp.asarray(data)

    # ---- sampling phase (once, at build) ---------------------------------
    k_sample, k_anchor = jax.random.split(key)
    node_stats = spjoin.fit_node_stats(shards, cfg.t_cells)
    pivots = spjoin.draw_pivots(k_sample, shards, node_stats, cfg)

    # ---- map-phase control plane (once, at build) ------------------------
    plan, smap = spjoin.build_plan(k_anchor, pivots, cfg)
    fused = cfg.map_fused and kops.supports_kernel(cfg.metric)
    backend = (
        kops.resolve_backend(cfg.backend, cfg.metric)
        if kops.supports_kernel(cfg.metric)
        else "numpy"
    )
    if fused:
        x_mapped, cells, _ = kops.map_assign(
            allx, smap.anchors, plan.kernel_lo, plan.kernel_hi,
            plan.whole_lo, plan.whole_hi, cfg.metric, backend=backend,
            want="cells",
        )
    else:
        x_mapped = smap(allx)
        cells = partition.assign_kernel(plan, x_mapped)
    box_lo, box_hi = _base_boxes(plan, x_mapped, cells, cfg.tighten)

    # ---- placement plan (cost-model loads from the pivots alone) ---------
    n_dev = int(n_devices or max(len(shards), 1))
    piv_mapped = np.asarray(smap(pivots), np.float32)
    piv_plan = partition.PartitionPlan(
        plan.kernel_lo, plan.kernel_hi,
        jnp.asarray(box_lo - np.float32(cfg.delta)),
        jnp.asarray(box_hi + np.float32(cfg.delta)),
        cfg.delta,
    )
    piv_cells = np.asarray(partition.assign_kernel(piv_plan, jnp.asarray(piv_mapped)))
    piv_member = np.asarray(
        partition.whole_membership(piv_plan, jnp.asarray(piv_mapped))
    )
    prune_active = verify_lib.resolve_prune(cfg.prune, cfg.metric, True) == "pivot"
    cell_loads, _, _, _ = placement_lib.planner_inputs(
        piv_mapped, piv_cells, piv_member,
        int(allx.shape[0]), int(allx.shape[0]), cfg.delta, prune_active,
    )
    pl = placement_lib.plan_placement(cell_loads, n_dev, strategy=cfg.placement)

    idx = MetricIndex(
        metric=cfg.metric,
        delta=float(cfg.delta),
        n_dims=int(smap.n_dims),
        tighten=bool(cfg.tighten),
        backend=backend,
        prune=cfg.prune,
        map_fused=bool(fused),
        tile_v=cfg.tile_v,
        tile_w=cfg.tile_w,
        seed=cfg.seed,
        placement_strategy=cfg.placement,
        n_devices=n_dev,
        data=np.asarray(allx, np.float32),
        coords=np.asarray(x_mapped, np.float32),
        cells=np.asarray(cells, np.int32),
        pivots=np.asarray(pivots, np.float32),
        anchors=np.asarray(smap.anchors, np.float32),
        kernel_lo=np.asarray(plan.kernel_lo, np.float32),
        kernel_hi=np.asarray(plan.kernel_hi, np.float32),
        box_lo=box_lo,
        box_hi=box_hi,
        placement=pl,
        node_confidences=np.array([st.confidence for st in node_stats]),
        n_base=int(allx.shape[0]),
        observed_w=_member_counts(
            np.asarray(x_mapped, np.float32),
            (box_lo - np.float32(cfg.delta)).astype(np.float32),
            (box_hi + np.float32(cfg.delta)).astype(np.float32),
        ),
    )
    idx.build_s = time.perf_counter() - t_start
    return idx


def brute_force_query(
    index_data: np.ndarray, q: np.ndarray, delta: float, metric: str
) -> np.ndarray:
    """Oracle for tests/benchmarks: (i ∈ R, j ∈ Q) pairs from the dense
    cross-distance matrix — the parity target of ``query_batch``."""
    mask = np.asarray(
        distances.brute_force_join(
            jnp.asarray(index_data), jnp.asarray(q), delta, metric
        )
    )
    i, j = np.nonzero(mask)
    return np.stack([i, j], axis=1).astype(np.int64)
