"""Partition strategies (paper §5.2 Alg. 5 — iterative, §5.3 Alg. 6 — learning).

Both strategies recursively bisect the *target space* (the ℝⁿ image of the
space mapping) at the ⌈p/2⌉-fractile of a chosen dimension until p leaf areas
exist:

  iterative  — the split dimension is chosen at random (Alg. 5 line 4);
               balances KERNEL sizes → minimizes the inner cost (Eq. 34).
  learning   — pivots carry labels from hierarchical clustering in the origin
               space; the split dimension maximizes the regularized
               information-gain ratio (Eqs. 35–37, i.e. C4.5 gain ratio with
               Eq. 35 being exactly the label entropy); compact areas →
               smaller WHOLE partitions → lower outer cost.

Correctness refinement vs. the paper (documented in DESIGN.md §2): the paper
computes each area's Minimum Bounding Box from the *pivots* that landed in it
and expands that by δ. Pivot MBBs do not cover the space, so an object can
fall outside every pivot MBB and its δ-neighbour could be missed. We instead
take the leaf's *half-space box* (the intersection of its split constraints —
these tile ℝⁿ, so every object has exactly one KERNEL cell), and optionally
*tighten* to the MBB of the actual objects assigned to the cell (a cheap
segment-min/max second pass) before the δ-expansion. Both variants satisfy
Lemma 4; tightening strictly shrinks WHOLE partitions.

Tree construction is control-plane work over k≈3200 pivots — it runs on host
numpy once per join. Cell *assignment* of the full dataset is data-plane and
fully vectorized jnp (runs inside the jitted map phase).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops
from repro.kernels import ref as kref

Array = jnp.ndarray

BIG = kref.BIG  # stand-in for ±inf that stays finite in fp32 (one owner)


@dataclasses.dataclass(frozen=True)
class PartitionPlan:
    """p leaf boxes of the split tree in target space.

    kernel_lo/hi: (p, n) — half-open boxes [lo, hi) tiling ℝⁿ.
    whole_lo/hi:  (p, n) — kernel boxes expanded by δ (after optional
                  tightening). WHOLE membership is closed: [lo − δ, hi + δ].
    delta:        the join threshold used for the expansion.
    """

    kernel_lo: Array
    kernel_hi: Array
    whole_lo: Array
    whole_hi: Array
    delta: float

    @property
    def p(self) -> int:
        return self.kernel_lo.shape[0]

    @property
    def n_dims(self) -> int:
        return self.kernel_lo.shape[1]


# --------------------------------------------------------------------------
# Label generation for the learning strategy (hierarchical clustering, §5.3)
# --------------------------------------------------------------------------


def single_linkage_labels(dist_matrix: np.ndarray, n_clusters: int) -> np.ndarray:
    """Single-linkage agglomerative clustering via the MST equivalence:
    build the minimum spanning tree (Prim, O(k²)) and delete the
    (n_clusters − 1) heaviest edges; connected components are the clusters.

    dist_matrix: (k, k) origin-space pivot distances.
    Returns int labels (k,).
    """
    k = dist_matrix.shape[0]
    n_clusters = int(min(max(n_clusters, 1), k))
    if n_clusters == 1:
        return np.zeros((k,), np.int64)

    in_tree = np.zeros(k, bool)
    in_tree[0] = True
    best = dist_matrix[0].copy()
    parent = np.zeros(k, np.int64)
    edges = []  # (weight, a, b)
    for _ in range(k - 1):
        best_masked = np.where(in_tree, np.inf, best)
        j = int(np.argmin(best_masked))
        edges.append((best[j], parent[j], j))
        in_tree[j] = True
        closer = dist_matrix[j] < best
        parent = np.where(closer, j, parent)
        best = np.minimum(best, dist_matrix[j])

    edges.sort(key=lambda e: e[0])
    keep = edges[: k - n_clusters]  # drop the n_clusters−1 heaviest

    # Union-find over the kept edges.
    uf = np.arange(k)

    def find(a: int) -> int:
        while uf[a] != a:
            uf[a] = uf[uf[a]]
            a = uf[a]
        return a

    for _, a, b in keep:
        ra, rb = find(int(a)), find(int(b))
        if ra != rb:
            uf[ra] = rb
    roots = np.array([find(i) for i in range(k)])
    _, labels = np.unique(roots, return_inverse=True)
    return labels


# --------------------------------------------------------------------------
# Gain-ratio dimension scoring (Eqs. 35–37)
# --------------------------------------------------------------------------


def _entropy(labels: np.ndarray) -> float:
    """Eq. 35: Cost(S, L) = Σ_y (freq/|S|)·(−log freq/|S|) — label entropy."""
    if labels.size == 0:
        return 0.0
    _, counts = np.unique(labels, return_counts=True)
    f = counts / labels.size
    return float(-(f * np.log(np.maximum(f, 1e-12))).sum())


def gain_ratio(labels: np.ndarray, left_mask: np.ndarray) -> float:
    """Eq. 37: F_d = C_d / split_info, with C_d the entropy reduction (Eq. 36)
    and split_info = −Σ |K|/|S| log |K|/|S| the regularizer."""
    n = labels.size
    nl = int(left_mask.sum())
    nr = n - nl
    if nl == 0 or nr == 0:
        return -np.inf
    h = _entropy(labels)
    hl = _entropy(labels[left_mask])
    hr = _entropy(labels[~left_mask])
    gain = h - (nl / n) * hl - (nr / n) * hr
    fl, fr = nl / n, nr / n
    split_info = -(fl * np.log(fl) + fr * np.log(fr))
    return float(gain / max(split_info, 1e-12))


# --------------------------------------------------------------------------
# Tree construction (Alg. 5 with Alg. 6 as the line-5 replacement)
# --------------------------------------------------------------------------


def build_partition(
    pivots_mapped: np.ndarray,
    p: int,
    delta: float,
    strategy: str = "learning",
    labels: np.ndarray | None = None,
    seed: int = 0,
) -> PartitionPlan:
    """Recursively split the mapped pivots into p leaf boxes.

    pivots_mapped: (k, n) target-space pivot coordinates (numpy).
    labels: required for strategy="learning" (origin-space cluster labels).
    """
    pivots_mapped = np.asarray(pivots_mapped, np.float64)
    k, n = pivots_mapped.shape
    if strategy == "learning" and labels is None:
        raise ValueError("learning strategy requires pivot labels")
    if p < 1:
        raise ValueError("p must be ≥ 1")
    rng = np.random.default_rng(seed)

    boxes: list[tuple[np.ndarray, np.ndarray]] = []

    def recurse(idx: np.ndarray, p_want: int, lo: np.ndarray, hi: np.ndarray) -> None:
        if p_want == 1:
            boxes.append((lo.copy(), hi.copy()))
            return
        pts = pivots_mapped[idx]
        lab = None if labels is None else labels[idx]
        p_left = int(np.ceil(p_want / 2))
        frac = p_left / p_want  # Alg. 5 line 5: the ⌈p/2⌉/p fractile

        if strategy == "iterative":
            # Random dim, but skip degenerate (constant) dims when possible.
            spans = pts.max(0) - pts.min(0) if pts.size else np.ones(n)
            candidates = np.flatnonzero(spans > 0)
            d = int(rng.choice(candidates)) if candidates.size else int(rng.integers(n))
        elif strategy == "learning":
            best_d, best_gain = 0, -np.inf
            for d_try in range(n):
                cut_try = np.quantile(pts[:, d_try], frac) if pts.size else 0.0
                left = pts[:, d_try] < cut_try
                g = gain_ratio(lab, left) if lab is not None else -np.inf
                if g > best_gain:
                    best_gain, best_d = g, d_try
            d = best_d
        else:
            raise ValueError(f"unknown strategy {strategy!r}")

        cut = float(np.quantile(pts[:, d], frac)) if pts.size else float(0.5 * (lo[d] + hi[d]))
        # Guard: a cut at the box edge would create an empty child box.
        cut = float(np.clip(cut, lo[d] + 1e-9 if lo[d] > -BIG else -BIG / 2, hi[d]))

        left_sel = pts[:, d] < cut if pts.size else np.zeros(0, bool)
        hi_l = hi.copy()
        hi_l[d] = cut
        lo_r = lo.copy()
        lo_r[d] = cut
        recurse(idx[left_sel], p_left, lo, hi_l)
        recurse(idx[~left_sel], p_want - p_left, lo_r, hi)

    lo0 = np.full((n,), -BIG)
    hi0 = np.full((n,), BIG)
    recurse(np.arange(k), p, lo0, hi0)
    assert len(boxes) == p, (len(boxes), p)

    kl = np.stack([b[0] for b in boxes]).astype(np.float32)
    kh = np.stack([b[1] for b in boxes]).astype(np.float32)
    return PartitionPlan(
        kernel_lo=jnp.asarray(kl),
        kernel_hi=jnp.asarray(kh),
        whole_lo=jnp.asarray(kl - delta),
        whole_hi=jnp.asarray(kh + delta),
        delta=float(delta),
    )


# --------------------------------------------------------------------------
# Data-plane: assignment + membership (jnp, runs inside the jitted map phase)
# --------------------------------------------------------------------------


def assign_kernel(
    plan: PartitionPlan, x_mapped: Array, backend: str | None = None
) -> Array:
    """KERNEL cell id per object: the unique leaf box containing it.

    This defines the V side of the reduce phase: V_h = {o : cell(o) = h} —
    every object is verified (as the "query" side) in exactly ONE cell,
    which is what makes the min-cell de-dup rule in ``core.verify`` emit
    each pair exactly once.

    Boxes are half-open [lo, hi) and tile ℝⁿ, so exactly one matches; argmax
    over the (N, p) containment mask returns it. O(N·p·n) — vectorized.

    ``backend``: None keeps the inline jnp broadcast; "numpy" | "pallas" |
    "auto" routes through the fused ``kernels.ops.assign_membership`` op
    (one streamed pass, no (N, p, n) HBM intermediate on the Pallas path —
    byte-identical cells by construction).
    """
    if backend is not None:
        cells, _ = kops.assign_membership(
            x_mapped, plan.kernel_lo, plan.kernel_hi, plan.whole_lo, plan.whole_hi,
            backend=backend, want="cells",
        )
        return cells
    inside = (x_mapped[:, None, :] >= plan.kernel_lo[None]) & (
        x_mapped[:, None, :] < plan.kernel_hi[None]
    )
    return jnp.argmax(inside.all(-1), axis=1).astype(jnp.int32)


def whole_membership(
    plan: PartitionPlan, x_mapped: Array, backend: str | None = None
) -> Array:
    """(N, p) bool — WHOLE partition membership (δ-expanded, closed boxes).

    This defines the W side of the reduce phase: W_h = {o : o within the
    δ-expanded box of cell h} ⊇ V_h. An object may be whole-member of many
    cells (the shuffle duplication Σ|W_h|/N); Lemma 4 guarantees every
    δ-neighbour of a V_h row appears in W_h, so verifying V_h × W_h per
    cell is complete. In R×S mode this is evaluated on S's mapped rows
    (W from S) while kernel assignment runs on R (V from R).

    ``backend``: None keeps the inline jnp broadcast; "numpy" | "pallas" |
    "auto" routes through the fused ``kernels.ops.assign_membership`` op
    (the (N, ⌈p/32⌉) packed bitmask is unpacked here for API compatibility).
    """
    if backend is not None:
        _, bits = kops.assign_membership(
            x_mapped, plan.kernel_lo, plan.kernel_hi, plan.whole_lo, plan.whole_hi,
            backend=backend, want="member",
        )
        return kops.unpack_membership(bits, plan.p)
    inside = (x_mapped[:, None, :] >= plan.whole_lo[None]) & (
        x_mapped[:, None, :] <= plan.whole_hi[None]
    )
    return inside.all(-1)


def tighten(plan: PartitionPlan, x_mapped: Array, cell_ids: Array) -> PartitionPlan:
    """Shrink each kernel box to the MBB of its assigned objects, then
    re-expand by δ. Empty cells collapse to a point box (no members ⇒ no
    verifications). Preserves Lemma 4: every object stays inside its own
    cell's box, so every δ-neighbour stays inside the expanded box.
    """
    p = plan.p
    seg_min = jax.ops.segment_min(x_mapped, cell_ids, num_segments=p)
    seg_max = jax.ops.segment_max(x_mapped, cell_ids, num_segments=p)
    empty = jax.ops.segment_sum(jnp.ones_like(cell_ids, jnp.float32), cell_ids, num_segments=p) == 0
    lo = jnp.where(empty[:, None], BIG, seg_min)
    hi = jnp.where(empty[:, None], -BIG, seg_max)
    return PartitionPlan(
        kernel_lo=plan.kernel_lo,
        kernel_hi=plan.kernel_hi,
        whole_lo=lo - plan.delta,
        whole_hi=hi + plan.delta,
        delta=plan.delta,
    )


def partition_stats(cell_ids: np.ndarray, membership: np.ndarray) -> dict:
    """Per-cell partition sizes, ``{"v_sizes": (p,), "w_sizes": (p,)}``.

    ``v_sizes[h]`` = |V_h| (kernel rows), ``w_sizes[h]`` = |W_h| (whole
    rows) — the inputs of Eq. 33 (``cost_model.partition_cost``) and the
    Table 3 balance metrics; Σ v_sizes·w_sizes is the candidate
    verification count of the reduce phase (Fig. 12)."""
    p = membership.shape[1]
    v = np.bincount(np.asarray(cell_ids), minlength=p).astype(np.int64)
    w = np.asarray(membership).sum(0).astype(np.int64)
    return {"v_sizes": v, "w_sizes": w}
