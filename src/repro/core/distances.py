"""Metric-space distance functions (paper Def. 1 / Def. 2).

Every metric is exposed in two forms:
  dist(x, y)        — single-pair distance, x/y: (m,)
  pairwise(X, Y)    — all-pairs matrix, X: (a, m), Y: (b, m) -> (a, b)

``pairwise`` here is the *reference* (pure jnp) implementation; the Pallas
verify kernel in ``repro.kernels`` computes the same quantity blocked/fused and
is validated against this module.

Supported metrics:
  l1        Σ|x−y|              (paper's running example, Example 1)
  l2        √Σ(x−y)²            (EUCLIDEAN; evaluated on Netflix/SIFT)
  linf      max|x−y|
  cosine    1 − x·y/(‖x‖‖y‖)    (pseudo-metric; common for embeddings — the
                                 semantic-dedup use case. Triangle inequality
                                 holds for the induced angular distance; we use
                                 the angular form when exactness matters.)
  angular   arccos(cos_sim)/π   (a true metric on the unit sphere)
  jaccard_minhash
            1 − mean(sig_x == sig_y) over MinHash signatures (unbiased
            estimator of Jaccard distance; §6.2 string/set support via
            ``repro.data.vectorize``)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Array = jnp.ndarray


def _l1_pairwise(x: Array, y: Array) -> Array:
    # (a, 1, m) - (1, b, m) -> (a, b). O(a·b·m) VPU work.
    return jnp.abs(x[:, None, :] - y[None, :, :]).sum(-1)


def _l2_pairwise(x: Array, y: Array) -> Array:
    # MXU-friendly form: ‖x‖² + ‖y‖² − 2 x·yᵀ. Clamped for fp error.
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    sq = (x * x).sum(-1)[:, None] + (y * y).sum(-1)[None, :] - 2.0 * x @ y.T
    return jnp.sqrt(jnp.maximum(sq, 0.0))


def _linf_pairwise(x: Array, y: Array) -> Array:
    return jnp.abs(x[:, None, :] - y[None, :, :]).max(-1)


def _cosine_pairwise(x: Array, y: Array) -> Array:
    xn = x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), 1e-12)
    yn = y / jnp.maximum(jnp.linalg.norm(y, axis=-1, keepdims=True), 1e-12)
    return 1.0 - xn @ yn.T


def _angular_pairwise(x: Array, y: Array) -> Array:
    cos = 1.0 - _cosine_pairwise(x, y)
    return jnp.arccos(jnp.clip(cos, -1.0, 1.0)) / jnp.pi


def _jaccard_minhash_pairwise(x: Array, y: Array) -> Array:
    # x, y are integer MinHash signatures; distance = 1 − estimated Jaccard sim.
    eq = (x[:, None, :] == y[None, :, :]).astype(jnp.float32)
    return 1.0 - eq.mean(-1)


@dataclasses.dataclass(frozen=True)
class Metric:
    """A metric-space distance (Def. 1): the function plus metadata.

    ``mxu_friendly`` marks metrics whose pairwise form reduces to a matmul
    (the Pallas kernel routes those through the MXU path).
    """

    name: str
    pairwise: Callable[[Array, Array], Array]
    mxu_friendly: bool = False
    true_metric: bool = True
    # Equality-based metrics (MinHash) are only meaningful on the data's
    # integer support: model-GENERATED pivots must be rounded onto it, or
    # every distance degenerates to 1.0 (floats never collide).
    discrete: bool = False

    def dist(self, x: Array, y: Array) -> Array:
        return self.pairwise(x[None, :], y[None, :])[0, 0]


METRICS: dict[str, Metric] = {
    "l1": Metric("l1", _l1_pairwise),
    "l2": Metric("l2", _l2_pairwise, mxu_friendly=True),
    "linf": Metric("linf", _linf_pairwise),
    "cosine": Metric("cosine", _cosine_pairwise, mxu_friendly=True, true_metric=False),
    "angular": Metric("angular", _angular_pairwise, mxu_friendly=True),
    "jaccard_minhash": Metric("jaccard_minhash", _jaccard_minhash_pairwise, discrete=True),
}


def get_metric(name: str) -> Metric:
    try:
        return METRICS[name]
    except KeyError:
        raise ValueError(f"unknown metric {name!r}; have {sorted(METRICS)}") from None


def pairwise(x: Array, y: Array, metric: str = "l1") -> Array:
    """All-pairs distance matrix (reference implementation)."""
    return get_metric(metric).pairwise(x, y)


def brute_force_join(x: Array, *args, **kwargs) -> Array:
    """Oracle join — ground truth for tests/benchmarks (quadratic).

    Two call forms, overloaded on whether the second argument is a set:

      brute_force_join(x, delta[, metric])
          self-join: boolean (n, n) matrix, True where D(o_i, o_j) ≤ δ, i < j.
      brute_force_join(r, s, delta[, metric])
          cross R×S join: boolean (n_r, n_s) matrix, True where
          D(r_i, s_j) ≤ δ — no triangular de-dup, (i, j) index different sets.
    """
    y = kwargs.pop("s", None)
    delta = kwargs.pop("delta", None)
    metric = kwargs.pop("metric", None)
    if kwargs:
        raise TypeError(f"unexpected keyword arguments {sorted(kwargs)}")
    pos = list(args)
    # Cross form iff the second positional is a set — always (n, m); scalars
    # (and anything else) route to delta, so a stray 0-d array can't misroute.
    if pos and jnp.ndim(pos[0]) == 2:
        if y is not None:
            raise TypeError("brute_force_join got multiple values for s")
        y = pos.pop(0)
    if pos:
        if delta is not None:
            raise TypeError("brute_force_join got multiple values for delta")
        delta = pos.pop(0)
    if pos:
        if metric is not None:
            raise TypeError("brute_force_join got multiple values for metric")
        metric = pos.pop(0)
    if pos:
        raise TypeError("too many positional arguments")
    if delta is None:
        raise TypeError("brute_force_join requires a delta threshold")
    metric = metric or "l1"
    if y is None:
        d = pairwise(x, x, metric)
        n = x.shape[0]
        iu = jnp.triu_indices(n, k=1)
        mask = jnp.zeros((n, n), bool).at[iu].set(True)
        return (d <= delta) & mask
    if x.shape[0] == 0 or y.shape[0] == 0:
        return jnp.zeros((x.shape[0], y.shape[0]), bool)
    return pairwise(x, y, metric) <= delta
