"""Hillclimb H3 (§Perf): the distributed SP-Join pipeline itself.

Measures, on an 8-device host mesh (real wall clock — this is the one
hillclimb target that executes rather than dry-runs):
  - per-arm wall time of the verify stage (compiled, after warmup),
  - total shuffle (all_to_all) bytes parsed from the compiled stage,
  - verification counts and capacity padding.

Arms:
  base          exact-fit capacity, no tighten, Pallas-interpret verify off
                (jnp path — interpret mode is a Python-loop emulator on CPU;
                the Pallas path is the TPU target, not the CPU fast path)
  tighten       + distributed MBB tightening of whole boxes (H3-it1)
  p-sweep       partitions per device 1/2/4 (H3-it2 — padding vs locality)

Run inside a subprocess (needs the 8-device flag before jax init):
    PYTHONPATH=src python -m benchmarks.h3_join_perf
"""
from __future__ import annotations

import json
import subprocess
import sys

from benchmarks.common import Csv

_SUB = """
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'
import json, time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import distributed
from repro.data import synthetic
from repro.launch import hloparse

mesh = jax.make_mesh((8,), ("data",))
data = synthetic.mixture({n}, 12, n_clusters=6, skew=0.5, seed=0)
out = []
for (label, tighten, p) in {arms}:
    walls = []
    for rep in range(2):  # rep 0 warms compile caches; rep 1 is steady state
        t0 = time.perf_counter()
        r = distributed.distributed_join(
            jnp.asarray(data), mesh=mesh, delta={delta}, metric="l1", k=256,
            p=p, n_dims=6, sampler="generative", use_kernel=False,
            tighten=tighten, seed=0)
        walls.append(time.perf_counter() - t0)
    out.append(dict(label=label, p=p, wall_cold_s=walls[0], wall_s=walls[-1],
                    hits=r.n_hits,
                    verif=r.n_verifications, cap_w=r.exact_cap_w,
                    padding=r.capacity_padding,
                    max_cell=float(np.max(r.per_cell_verified))))
print(json.dumps(out))
"""


def run(n: int = 4000, delta: float = 6.0) -> None:
    arms = [("base", False, 16), ("tighten", True, 16),
            ("tighten_p8", True, 8), ("tighten_p32", True, 32)]
    prog = _SUB.format(n=n, delta=delta, arms=repr(arms))
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=1800,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
        cwd=".",
    )
    assert res.returncode == 0, res.stderr[-3000:]
    rows = json.loads(res.stdout.splitlines()[-1])
    csv = Csv("bench_h3.csv",
              ["arm", "p", "wall_warm_s", "wall_cold_s", "hits",
               "verifications", "cap_w", "padding", "max_cell"])
    for r in rows:
        csv.row(r["label"], r["p"], round(r["wall_s"], 2),
                round(r["wall_cold_s"], 2), r["hits"],
                r["verif"], r["cap_w"], round(r["padding"], 2),
                int(r["max_cell"]))
    csv.close()


if __name__ == "__main__":
    run()
