"""Hillclimb H3 (§Perf): the distributed SP-Join pipeline + the verify engine.

Sections (``--rs`` adds a fourth):

1. distributed — per-arm wall time of the 8-device shard_map pipeline
   (real wall clock; base / tighten / p-sweep / noprune arms), run in a
   subprocess so the device-count flag never leaks into the parent process.
   Each arm reports its pivot-filter pruning rate (fraction of candidate
   pairs skipping exact evaluation) and exact-evaluation count.
2. verify-engine — the reduce-phase hot spot head-to-head: the seed's dense
   per-cell eager loop (``verify.reference_verify``) vs the streaming tiled
   engine (``verify.verify_pairs``, numpy backend = jitted/fused XLA) on one
   shared partition plan, with and without pivot-filter pruning. Reports
   speedups, tile/bucket counts, padding occupancy, pruning rate and
   exact-evaluation counts; asserts prune="pivot" pairs are byte-identical
   to prune="none". Acceptance floor: engine >= 2x at N >= 20k on CPU.
3. map-phase — the fused single-pass map kernel (``kernels.ops.map_assign``:
   space map + kernel assign + packed membership) vs the legacy two-broadcast
   jnp path, on BOTH executors (reference: in-process; distributed: the
   8-device counting stage with ``fused=`` toggled). Reports ``map_ms`` /
   ``map_ms_legacy`` wall times, the modeled HBM-intermediate saving
   ``map_bytes_saved`` (2·N·p·n + N·p bool bytes avoided minus the N·⌈p/32⌉
   packed words written) and asserts outputs are byte-identical.
4. placement — cost-model-guided reduce placement (``core.placement``) on a
   hard-skew mixture: contiguous vs LPT cell→device plans on the 8-device
   mesh. Reports measured per-device ``balance_std`` / ``makespan_ratio``,
   the planner's quality report (certified bound), slot/split counts and the
   capacity effect; asserts both placements emit byte-identical pair sets.
5. incremental — the streaming layer (``MetricIndex.insert_batch``): one
   delta absorbed into a live index vs a from-scratch rebuild-and-join over
   the grown set, at 1% / 10% / 50% delta fractions. Reports the amortized
   delta cost, the rebuild cost it displaces, the drift monitor's decision
   and the ``incremental_identical`` certificate (accumulated pairs
   byte-identical to the from-scratch join — docs/STREAMING.md).
6. rs (``--rs``) — the two-set R×S cross join with asymmetric |R| << |S|
   (the skew-sensitive case), exactness-checked in-subprocess against the
   brute-force cross oracle; reports wall time, W capacity, the S-side
   duplication metric Σ|W_h|/|S| and the pruning rate.

Emits ``runs/bench_h3.csv`` + ``runs/h3_perf.json`` (the JSON is the CI
smoke-benchmark contract: ``python benchmarks/h3_join_perf.py --smoke --rs``
must run to completion, write it, report a NONZERO pruning rate, a
byte-identical map-phase section, a placement section with
``placement_identical == true`` and LPT ``balance_std`` no worse than
contiguous, and an incremental section with
``incremental_identical == true`` whose 1%-fraction arm absorbs the delta
cheaper than the rebuild it displaces). Schema of the JSON:
docs/BENCHMARKS.md.

Run:
    PYTHONPATH=src python benchmarks/h3_join_perf.py [--smoke] [--rs]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/h3_join_perf.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))  # repro without install

from benchmarks.common import Csv, OUT_DIR

_SUB = """
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'
import json, time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import distributed
from repro.data import synthetic
from repro.launch import hloparse

mesh = jax.make_mesh((8,), ("data",))
data = synthetic.mixture({n}, 12, n_clusters=6, skew=0.5, seed=0)
out = []
for (label, tighten, p, prune) in {arms}:
    walls = []
    for rep in range(2):  # rep 0 warms compile caches; rep 1 is steady state
        t0 = time.perf_counter()
        r = distributed.distributed_join(
            jnp.asarray(data), mesh=mesh, delta={delta}, metric="l1", k=256,
            p=p, n_dims=6, sampler="generative", backend="numpy",
            tighten=tighten, prune=prune, seed=0)
        walls.append(time.perf_counter() - t0)
    out.append(dict(label=label, p=p, wall_cold_s=walls[0], wall_s=walls[-1],
                    hits=r.n_hits,
                    verif=r.n_verifications, cap_w=r.exact_cap_w,
                    padding=r.capacity_padding,
                    max_cell=float(np.max(r.per_cell_verified)),
                    pruning_rate=r.pruning_rate, n_exact=r.n_candidates,
                    predicted_survival=r.predicted_survival))
print(json.dumps(out))
"""


_SUB_RS = """
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'
import json, time, numpy as np, jax, jax.numpy as jnp
from repro.core import distributed, spjoin
from repro.data import synthetic

mesh = jax.make_mesh((8,), ("data",))
# Asymmetric |R| << |S| — the skew-sensitive cross-join case: every R row
# fans out against a much larger S side, so W capacity planning dominates.
r, s = synthetic.rs_mixture({n_r}, {n_s}, 12, n_clusters=6, skew=0.5, seed=0)
walls = []
for rep in range(2):  # rep 0 warms compile caches; rep 1 is steady state
    t0 = time.perf_counter()
    res = distributed.distributed_join(
        jnp.asarray(r), s=jnp.asarray(s), mesh=mesh, delta={delta},
        metric="l1", k=256, p=16, n_dims=6, sampler="generative",
        backend="numpy", emit_pairs=True, seed=0)
    walls.append(time.perf_counter() - t0)
truth = spjoin.brute_force_pairs(r, {delta}, "l1", s=s)
assert np.array_equal(res.pairs, truth), (res.pairs.shape, truth.shape)
print(json.dumps(dict(
    label="rs", n_r={n_r}, n_s={n_s}, wall_cold_s=walls[0], wall_s=walls[-1],
    pairs=int(res.pairs.shape[0]), verif=res.n_verifications,
    cap_w=res.exact_cap_w, padding=res.capacity_padding,
    duplication=res.duplication, pruning_rate=res.pruning_rate,
    n_exact=res.n_candidates, exact=True)))
"""


_SUB_MAP = """
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'
import json, time, numpy as np, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import distributed
from repro.data import synthetic

mesh = jax.make_mesh((8,), ("data",))
data = synthetic.mixture({n}, 12, n_clusters=6, skew=0.5, seed=0)
sharding = NamedSharding(mesh, P("data"))
x, valid, ids, _ = distributed._pad_shard_set(jnp.asarray(data), 8, sharding)

# One shared plan (sampling + control plane) — the map pass is what differs.
stats_fn = distributed.make_stage_stats(mesh, "data")
packets, confs, counts = jax.tree.map(np.asarray, stats_fn(x, valid))
kg, ka = jax.random.split(jax.random.PRNGKey(0))
c_min = float(np.clip(np.clip(confs / max(confs.max(), 1e-6), 1e-3, 1.0).min(), 0.05, 1.0))
pivots, _ = distributed.gibbs_from_packets(
    kg, jnp.asarray(packets), jnp.asarray(confs), jnp.asarray(counts), 256,
    int(np.ceil(256 / c_min * 1.5)) + 8)
plan = distributed.build_join_plan(
    ka, pivots, delta={delta}, metric="l1", p=16, n_dims=6, seed=0)

out, baseline = {{}}, None
for label, fused in (("legacy", False), ("fused", True)):
    fn = distributed.make_stage_counts(mesh, "data", plan, backend="numpy", fused=fused)
    walls = []
    for rep in range(3):  # rep 0 warms the compile cache
        t0 = time.perf_counter()
        res = jax.block_until_ready(fn(x, valid))
        walls.append(time.perf_counter() - t0)
    arrs = [np.asarray(a) for a in res]
    if baseline is None:
        baseline = arrs
    out[label] = dict(
        map_ms=min(walls[1:]) * 1e3,
        identical=all(a.tobytes() == b.tobytes() for a, b in zip(arrs, baseline)),
    )
print(json.dumps(out))
"""


_SUB_PLACEMENT = """
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'
import json, time, numpy as np, jax, jax.numpy as jnp
from repro.core import distributed
from repro.data import synthetic

mesh = jax.make_mesh((8,), ("data",))
# Hard-skew mixture: one cluster dominates, so contiguous placement parks
# the hot cell(s) on one straggler device — the regime Table 3 is about.
data = synthetic.mixture({n}, 12, n_clusters=5, skew={skew}, seed=3)
out = {{}}
pairs = {{}}
for strategy in ("contiguous", "lpt"):
    walls = []
    for rep in range(2):  # rep 0 warms compile caches; rep 1 is steady state
        t0 = time.perf_counter()
        r = distributed.distributed_join(
            jnp.asarray(data), mesh=mesh, delta={delta}, metric="l1", k=256,
            p=16, n_dims=6, sampler="generative", backend="numpy",
            placement=strategy, emit_pairs=True, seed=0)
        walls.append(time.perf_counter() - t0)
    pairs[strategy] = r.pairs.tobytes()
    pl = r.placement_plan
    out[strategy] = dict(
        wall_cold_s=walls[0], wall_s=walls[-1], hits=r.n_hits,
        verif=r.n_verifications,
        balance_std=float(r.balance_std),
        makespan_ratio=float(r.makespan_ratio),
        device_loads=[float(x) for x in r.device_loads],
        capacity_saved_bytes=int(r.capacity_saved_bytes),
        padding=float(r.capacity_padding),
        n_slots=int(pl.n_slots), n_split_cells=int(pl.n_split_cells),
        plan_makespan_ratio=float(pl.makespan_ratio),
        plan_certified_bound=float(pl.certified_bound),
    )
out["placement_identical"] = pairs["contiguous"] == pairs["lpt"]
print(json.dumps(out))
"""


def _run_sub(prog: str):
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {"PYTHONPATH": os.path.join(root, "src"), "PATH": "/usr/bin:/bin",
           "HOME": os.environ.get("HOME", "/root")}
    if os.environ.get("JAX_PLATFORMS"):
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    res = subprocess.run(
        [sys.executable, "-c", prog], capture_output=True, text=True,
        timeout=1800, env=env, cwd=root,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.splitlines()[-1])


def run_rs(n_r: int, n_s: int, delta: float) -> dict:
    """The R×S arm: exactness-checked cross join with |R| << |S|."""
    return _run_sub(_SUB_RS.format(n_r=n_r, n_s=n_s, delta=delta))


def run_distributed(n: int, delta: float, arms) -> list[dict]:
    return _run_sub(_SUB.format(n=n, delta=delta, arms=repr(arms)))


def run_placement(n: int, delta: float, skew: float = 0.85) -> dict:
    """Section 5: contiguous vs LPT reduce placement on a hard-skew mixture
    (8-device mesh). Reports measured per-device balance (`balance_std`,
    `makespan_ratio`), the planner's own quality report and the capacity
    effect; asserts the two placements emit byte-identical pair sets."""
    out = _run_sub(_SUB_PLACEMENT.format(n=n, delta=delta, skew=skew))
    assert out["placement_identical"], "placement changed the pair set"
    out["n"] = n
    out["skew"] = skew
    return out


def _map_bytes_saved(n: int, p: int, nd: int) -> int:
    """Modeled HBM-intermediate bytes the fused map pass avoids per shard:
    two (N, p, n) bool containment broadcasts + the (N, p) bool mask of the
    legacy path, minus the (N, ⌈p/32⌉) uint32 packed mask it writes instead
    (the (N, n) f32 coordinates are written by both paths)."""
    words = -(-p // 32)
    return 2 * n * p * nd + n * p - 4 * n * words


def run_map_phase(n: int, delta: float) -> dict:
    """Section 3: fused vs legacy map pass, both executors (ref in-process,
    distributed as the 8-device counting stage in a subprocess)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import partition, spjoin
    from repro.data import synthetic
    from repro.kernels import ops as kops

    data = synthetic.mixture(n, 12, n_clusters=6, skew=0.5, seed=0)
    cfg = spjoin.JoinConfig(delta=delta, metric="l1", k=256, p=16, n_dims=6,
                            sampler="generative", seed=0)
    key = jax.random.PRNGKey(cfg.seed)
    shards = list(jnp.array_split(jnp.asarray(data), 4))
    allx = jnp.concatenate(shards)
    k_sample, k_anchor = jax.random.split(key)
    node_stats = spjoin.fit_node_stats(shards, cfg.t_cells)
    pivots = spjoin.draw_pivots(k_sample, shards, node_stats, cfg)
    plan, smap = spjoin.build_plan(k_anchor, pivots, cfg)

    def legacy():
        xm = smap(allx)
        cells = partition.assign_kernel(plan, xm)
        member = partition.whole_membership(plan, xm)
        return jax.block_until_ready((xm, cells, member))

    def fused():
        xm, cells, bits = kops.map_assign(
            allx, smap.anchors, plan.kernel_lo, plan.kernel_hi,
            plan.whole_lo, plan.whole_hi, cfg.metric, backend="numpy",
        )
        member = kops.unpack_membership(bits, plan.p)
        return jax.block_until_ready((xm, cells, member))

    results = {}
    for label, fn in (("legacy", legacy), ("fused", fused)):
        walls, out = [], None
        for _ in range(3):  # rep 0 warms compile/dispatch caches
            t0 = time.perf_counter()
            out = fn()
            walls.append(time.perf_counter() - t0)
        results[label] = (min(walls[1:]) * 1e3, out)
    t_leg, (_, cells_l, member_l) = results["legacy"]
    t_fus, (_, cells_f, member_f) = results["fused"]
    identical = (
        np.asarray(cells_l).tobytes() == np.asarray(cells_f).tobytes()
        and np.asarray(member_l).tobytes() == np.asarray(member_f).tobytes()
    )
    reference = dict(
        executor="reference", n=n, p=plan.p,
        map_ms=round(t_fus, 3), map_ms_legacy=round(t_leg, 3),
        speedup=round(t_leg / max(t_fus, 1e-9), 2),
        map_bytes_saved=_map_bytes_saved(n, plan.p, plan.n_dims),
        identical=bool(identical),
    )

    sub = _run_sub(_SUB_MAP.format(n=n, delta=delta))
    distributed_row = dict(
        executor="distributed", n=n, p=16,
        map_ms=round(sub["fused"]["map_ms"], 3),
        map_ms_legacy=round(sub["legacy"]["map_ms"], 3),
        speedup=round(sub["legacy"]["map_ms"] / max(sub["fused"]["map_ms"], 1e-9), 2),
        map_bytes_saved=_map_bytes_saved(n, 16, 6),
        identical=bool(sub["fused"]["identical"] and sub["legacy"]["identical"]),
    )
    return dict(n=n, reference=reference, distributed=distributed_row)


def run_verify_engine(n: int, delta: float) -> dict:
    """Reference dense loop vs streaming engine on one shared partition plan."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.core import partition, spjoin, verify
    from repro.data import synthetic

    data = synthetic.mixture(n, 12, n_clusters=6, skew=0.5, seed=0)
    cfg = spjoin.JoinConfig(delta=delta, metric="l1", k=256, p=16, n_dims=6,
                            sampler="generative", seed=0)
    key = jax.random.PRNGKey(cfg.seed)
    shards = list(jnp.array_split(jnp.asarray(data), 4))
    allx = jnp.concatenate(shards)
    k_sample, k_anchor = jax.random.split(key)
    node_stats = spjoin.fit_node_stats(shards, cfg.t_cells)
    pivots = spjoin.draw_pivots(k_sample, shards, node_stats, cfg)
    plan, smap = spjoin.build_plan(k_anchor, pivots, cfg)
    xm = smap(allx)
    cells = partition.assign_kernel(plan, xm)
    plan = partition.tighten(plan, xm, cells)
    member = partition.whole_membership(plan, xm)
    cells_np, member_np = np.asarray(cells), np.asarray(member)

    # Symmetric protocol: min of 2 reps for ALL paths (rep 0 warms eager
    # dispatch caches on the reference and the per-bucket compile cache on
    # the engine), so the speedups compare steady state to steady state.
    t_ref, ref_pairs, n_verif = float("inf"), None, 0
    for _ in range(2):
        t0 = time.perf_counter()
        ref_pairs, n_verif = verify.reference_verify(
            allx, cells_np, member_np, cfg.delta, cfg.metric
        )
        t_ref = min(t_ref, time.perf_counter() - t0)

    ecfg = verify.EngineConfig(backend="numpy", prune="none")
    t_eng, eng_pairs, stats = float("inf"), None, None
    for _ in range(2):
        t0 = time.perf_counter()
        eng_pairs, stats = verify.verify_pairs(
            allx, cells_np, member_np, cfg.delta, cfg.metric, config=ecfg
        )
        t_eng = min(t_eng, time.perf_counter() - t0)
    assert np.array_equal(ref_pairs, eng_pairs), "engine != reference pairs"

    # Emission/pruning arms on the same plan: the host mask-readback path
    # with window pruning (compact and mask emission), plus the pivot-filter
    # telemetry arm. Hard invariant (the engine's soundness contract): every
    # arm's pair set is byte-identical to the unpruned mask run.
    xm_np = np.asarray(xm, np.float32)

    def _arm(prune: str, emit: str, coords=None, **tiles):
        acfg = verify.EngineConfig(backend="numpy", prune=prune, emit=emit,
                                   **tiles)
        t_best, pairs_a, stats_a = float("inf"), None, None
        for _ in range(3):
            t0 = time.perf_counter()
            pairs_a, stats_a = verify.verify_pairs(
                allx, cells_np, member_np, cfg.delta, cfg.metric, config=acfg,
                coords=coords,
            )
            t_best = min(t_best, time.perf_counter() - t0)
        assert pairs_a.tobytes() == eng_pairs.tobytes(), (
            f"engine arm prune={prune} emit={emit} changed the pair set"
        )
        return t_best, stats_a

    t_compact, _ = _arm("none", "compact")
    # The headline fused arm: window pruning (host-side ordered windows +
    # bounding-box skips — ZERO extra device lanes) + compact emission.
    # tile_v=128 narrows each V band's surviving W window; the batched
    # window dispatch keeps the smaller tiles from paying per-launch
    # overhead (core.verify, *Batched window dispatch*).
    _WTILES = dict(tile_v=128, tile_w=512)
    t_prune_mask, pmstats = _arm("window", "mask", xm_np, **_WTILES)
    t_prune, pstats = _arm("window", "compact", xm_np, **_WTILES)
    # The pivot-filter telemetry arm (per-pair bound lanes + fused on-device
    # compaction): exact per-pair pruning counts, block skips on Pallas.
    t_pivot, pvstats = _arm("pivot", "compact", xm_np)

    return dict(
        n=n, delta=delta, n_pairs=int(eng_pairs.shape[0]),
        n_verifications=n_verif,
        reference_s=round(t_ref, 3), engine_s=round(t_eng, 3),
        speedup=round(t_ref / max(t_eng, 1e-9), 2),
        n_tiles=stats.n_tiles, n_buckets=stats.n_buckets,
        occupancy=round(stats.occupancy, 3),
        compact_s=round(t_compact, 3),
        speedup_compact=round(t_eng / max(t_compact, 1e-9), 2),
        prune=pstats.prune,
        pruned_s=round(t_prune, 3),
        speedup_prune=round(t_eng / max(t_prune, 1e-9), 2),
        pruned_mask_s=round(t_prune_mask, 3),
        speedup_prune_mask=round(t_eng / max(t_prune_mask, 1e-9), 2),
        pruned_pivot_s=round(t_pivot, 3),
        speedup_prune_pivot=round(t_eng / max(t_pivot, 1e-9), 2),
        emit=pstats.emit,
        n_overflow_retries=pvstats.n_overflow_retries,
        pruning_rate=round(pstats.prune_rate, 4),
        pivot_pruning_rate=round(pvstats.prune_rate, 4),
        n_exact=pstats.n_exact,
        n_tiles_pruned=pmstats.n_tiles_pruned,
        prune_identical=True,  # asserted per arm above (byte-identity)
    )


def run_incremental(n: int, delta: float) -> dict:
    """Section 5: the streaming layer's amortization claim, measured.

    For each delta fraction f, build a live index on n rows, absorb an
    f·n-row delta through ``insert_batch`` (only the delta is mapped; the
    ΔR×R_old verify streams against the resident V lists), and compare
    against what a batch system pays for the same state: a from-scratch
    ``spjoin.join`` over the n + f·n rows. The exactness certificate rides
    along: build-time pairs ∪ insert_batch pairs must be byte-identical to
    the from-scratch pair set (the ISSUE-8 contract)."""
    import numpy as np
    from repro.core import index as index_lib, spjoin
    from repro.data import synthetic

    pool = synthetic.mixture(n + n // 2 + 1, 12, n_clusters=6, skew=0.5, seed=0)
    cfg = spjoin.JoinConfig(delta=delta, metric="l1", k=256, p=16, n_dims=6,
                            sampler="generative", seed=0)
    arms = []
    for frac in (0.01, 0.10, 0.50):
        n_delta = max(1, int(n * frac))
        base, delta_rows = pool[:n], pool[n : n + n_delta]
        full = pool[: n + n_delta]

        t0 = time.perf_counter()
        idx = index_lib.build_index(base, cfg)
        build_s = time.perf_counter() - t0
        base_pairs = idx.self_pairs()

        t0 = time.perf_counter()
        new_pairs, stats = idx.insert_batch(delta_rows, rebuild_cfg=cfg)
        delta_s = time.perf_counter() - t0

        t0 = time.perf_counter()
        scratch = spjoin.join(full, cfg)
        rebuild_s = time.perf_counter() - t0

        acc = np.unique(np.concatenate([base_pairs, new_pairs]), axis=0)
        arms.append(dict(
            frac=frac, n=n, n_delta=n_delta,
            build_ms=round(build_s * 1e3, 1),
            delta_ms=round(delta_s * 1e3, 1),
            rebuild_ms=round(rebuild_s * 1e3, 1),
            amortization=round(rebuild_s / max(delta_s, 1e-9), 2),
            n_new_pairs=int(new_pairs.shape[0]),
            drift=round(stats.drift, 4), action=stats.action,
            identical=bool(acc.tobytes() == scratch.pairs.tobytes()),
        ))
    return dict(
        n=n, arms=arms,
        incremental_identical=bool(all(a["identical"] for a in arms)),
    )


def run(n: int = 4000, delta: float = 6.0, n_verify: int = 20_000,
        smoke: bool = False, rs: bool = False) -> dict:
    if smoke:
        # Smoke shrinks only sizes the caller left at their defaults, so
        # `--smoke --n-verify 50000` still measures the requested N.
        n = 400 if n == 4000 else n
        n_verify = 2_000 if n_verify == 20_000 else n_verify
        arms = [("tighten", True, 16, "pivot")]
    else:
        arms = [("base", False, 16, "pivot"), ("tighten", True, 16, "pivot"),
                ("tighten_p8", True, 8, "pivot"),
                ("tighten_p32", True, 32, "pivot"),
                ("noprune", True, 16, "none")]

    rows = run_distributed(n, delta, arms)
    csv = Csv("bench_h3.csv",
              ["arm", "p", "wall_warm_s", "wall_cold_s", "hits",
               "verifications", "n_exact", "pruning_rate", "cap_w", "padding",
               "max_cell"])
    for r in rows:
        csv.row(r["label"], r["p"], round(r["wall_s"], 2),
                round(r["wall_cold_s"], 2), r["hits"],
                r["verif"], r["n_exact"], round(r["pruning_rate"], 4),
                r["cap_w"], round(r["padding"], 2),
                int(r["max_cell"]))
    csv.close()

    engine = run_verify_engine(n_verify, delta)
    csv2 = Csv("bench_h3_verify.csv",
               ["n", "reference_s", "engine_s", "compact_s", "prune",
                "pruned_mask_s", "pruned_s", "pruned_pivot_s", "speedup",
                "speedup_prune", "speedup_prune_mask", "speedup_prune_pivot",
                "emit", "n_overflow_retries", "pruning_rate",
                "pivot_pruning_rate", "n_exact", "tiles", "tiles_pruned",
                "buckets", "occupancy"])
    csv2.row(engine["n"], engine["reference_s"], engine["engine_s"],
             engine["compact_s"], engine["prune"], engine["pruned_mask_s"],
             engine["pruned_s"], engine["pruned_pivot_s"], engine["speedup"],
             engine["speedup_prune"], engine["speedup_prune_mask"],
             engine["speedup_prune_pivot"], engine["emit"],
             engine["n_overflow_retries"], engine["pruning_rate"],
             engine["pivot_pruning_rate"], engine["n_exact"],
             engine["n_tiles"], engine["n_tiles_pruned"],
             engine["n_buckets"], engine["occupancy"])
    csv2.close()
    # The fused-engine acceptance gate: window pruning + compact emission
    # must BEAT the unpruned mask engine on the SAME plan (the windowed
    # mask-path and pivot-telemetry numbers ride along for the
    # emission-path comparison).
    assert engine["speedup_prune"] >= 1.0, (
        f"fused engine arm regressed: speedup_prune={engine['speedup_prune']} "
        f"(mask-path speedup_prune_mask={engine['speedup_prune_mask']})"
    )

    map_phase = run_map_phase(n, delta)
    csv_map = Csv("bench_h3_map.csv",
                  ["executor", "n", "p", "map_ms", "map_ms_legacy", "speedup",
                   "map_bytes_saved", "identical"])
    for row in (map_phase["reference"], map_phase["distributed"]):
        csv_map.row(row["executor"], row["n"], row["p"], row["map_ms"],
                    row["map_ms_legacy"], row["speedup"],
                    row["map_bytes_saved"], row["identical"])
    csv_map.close()

    placement = run_placement(max(n // 4, 400), delta)
    csv_pl = Csv("bench_h3_placement.csv",
                 ["strategy", "n", "skew", "wall_warm_s", "balance_std",
                  "makespan_ratio", "n_slots", "n_split_cells",
                  "capacity_saved_bytes", "padding", "identical"])
    for strategy in ("contiguous", "lpt"):
        row = placement[strategy]
        csv_pl.row(strategy, placement["n"], placement["skew"],
                   round(row["wall_s"], 2), round(row["balance_std"], 1),
                   round(row["makespan_ratio"], 3), row["n_slots"],
                   row["n_split_cells"], row["capacity_saved_bytes"],
                   round(row["padding"], 2), placement["placement_identical"])
    csv_pl.close()

    stream = run_incremental(max(n // 2, 400), delta)
    csv_st = Csv("bench_h3_stream.csv",
                 ["frac", "n", "n_delta", "build_ms", "delta_ms",
                  "rebuild_ms", "amortization", "n_new_pairs", "drift",
                  "action", "identical"])
    for a in stream["arms"]:
        csv_st.row(a["frac"], a["n"], a["n_delta"], a["build_ms"],
                   a["delta_ms"], a["rebuild_ms"], a["amortization"],
                   a["n_new_pairs"], a["drift"], a["action"], a["identical"])
    csv_st.close()

    report = dict(smoke=smoke, distributed=rows, verify_engine=engine,
                  map_phase=map_phase, placement=placement,
                  incremental=stream)

    if rs:
        # Asymmetric two-set arm: |R| = n/5 against |S| = n, exactness-checked
        # against the brute-force cross oracle inside the subprocess.
        rs_row = run_rs(max(n // 5, 16), n, delta)
        csv3 = Csv("bench_h3_rs.csv",
                   ["n_r", "n_s", "wall_warm_s", "wall_cold_s", "pairs",
                    "verifications", "n_exact", "pruning_rate", "cap_w",
                    "padding", "duplication"])
        csv3.row(rs_row["n_r"], rs_row["n_s"], round(rs_row["wall_s"], 2),
                 round(rs_row["wall_cold_s"], 2), rs_row["pairs"],
                 rs_row["verif"], rs_row["n_exact"],
                 round(rs_row["pruning_rate"], 4), rs_row["cap_w"],
                 round(rs_row["padding"], 2), round(rs_row["duplication"], 3))
        csv3.close()
        report["rs"] = rs_row
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, "h3_perf.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report))
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny sizes; CI contract: must finish and emit JSON")
    ap.add_argument("--n", type=int, default=4000,
                    help="distributed-section dataset size")
    ap.add_argument("--n-verify", type=int, default=20_000,
                    help="verify-engine-section dataset size")
    ap.add_argument("--delta", type=float, default=6.0)
    ap.add_argument("--rs", action="store_true",
                    help="also run the asymmetric R×S cross-join arm "
                         "(|R| = n/5 vs |S| = n, exactness-checked)")
    args = ap.parse_args()
    run(n=args.n, delta=args.delta, n_verify=args.n_verify, smoke=args.smoke,
        rs=args.rs)
