"""Fig. 11: scale-up — join cost at 25/50/75/100% of the dataset.

Paper claim: near-linear growth in join time with data size (the partition
machinery keeps the quadratic term per-cell)."""
from __future__ import annotations

from benchmarks.common import Csv, make_datasets, timed
from repro.core import spjoin


def run(n: int = 1600, k: int = 256, p: int = 12) -> None:
    csv = Csv("bench_fig11.csv",
              ["dataset", "fraction", "n", "join_s", "verifications", "pairs"])
    for ds in make_datasets(n)[:2]:
        delta = ds.deltas[-1]
        for frac in (0.25, 0.5, 0.75, 1.0):
            sub = ds.data[: int(len(ds.data) * frac)]
            cfg = spjoin.JoinConfig(delta=delta, metric=ds.metric,
                                    sampler="generative", partitioner="learning",
                                    k=k, p=p, n_dims=8, seed=0)
            res, t = timed(spjoin.join, sub, cfg)
            csv.row(ds.name, frac, len(sub), round(t, 3),
                    res.n_verifications, res.n_pairs)
    csv.close()


if __name__ == "__main__":
    run()
