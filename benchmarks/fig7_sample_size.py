"""Fig. 7: random sampling at {1x, 3x, 10x} sample size vs generative at 1x.

Paper claim: 3x random helps marginally; 10x random HURTS (map-phase
partition-tree cost grows with k and eats the benefit); Gen at 1x beats all.
"""
from __future__ import annotations

from benchmarks.common import Csv, make_datasets, timed
from repro.core import spjoin


def run(n: int = 1200, k: int = 192, p: int = 12) -> None:
    csv = Csv(
        "bench_fig7.csv",
        ["dataset", "delta", "arm", "k", "join_s", "map_s", "verifications"],
    )
    for ds in make_datasets(n):
        delta = ds.deltas[-1]
        k1 = min(k, len(ds.data) // 12)  # keep the 10x arm < population
        arms = [("gen_1x", "generative", k1), ("random_1x", "random", k1),
                ("random_3x", "random", 3 * k1), ("random_10x", "random", 10 * k1)]
        for name, sampler, kk in arms:
            cfg = spjoin.JoinConfig(delta=delta, metric=ds.metric,
                                    sampler=sampler, partitioner="learning",
                                    k=kk, p=p, n_dims=8, seed=0)
            res, t = timed(spjoin.join, ds.data, cfg)
            csv.row(ds.name, round(delta, 4), name, kk, round(t, 3),
                    round(res.map_time_s, 3), res.n_verifications)
    csv.close()


if __name__ == "__main__":
    run()
