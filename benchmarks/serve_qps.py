"""§Serving: build-once-query-millions QPS / latency benchmark.

The serving claim of docs/SERVING.md, measured: ``core.index.build_index``
pays the control plane (sampling → anchors → partition boxes → placement
plan) EXACTLY ONCE, and every ``query_batch`` after that performs zero
sampling/anchor/partition calls — enforced here with module-attribute call
counters around the build entry points (the same technique as the
regression test in ``tests/test_index.py``), not just asserted by eye.

Arms:

  host  — the single-host ``MetricIndex.query_batch`` path: one warm-up
          batch (compile), then ≥1000 timed queries in fixed-size batches.
          Reports QPS (queries / total timed seconds), p50/p99 per-batch
          latency, routing duplication, and byte-identity of one batch
          against ``distances.brute_force_join``.
  dist  — the same index pinned on a 1-device mesh via ``to_distributed``
          (the ``DistIndex`` slot machinery end-to-end: W dispatch,
          all_to_all, per-slot verify against resident V buffers), same
          metrics + parity. CI exercises the full path without a real mesh.
  load  — save → load → one parity batch (the lifecycle round trip).

Emits ``runs/bench_serve_qps.csv`` + ``runs/serve_qps.json`` (the CI
serving-smoke contract: ``build_count == 1``,
``build_calls_during_queries == 0``, ``parity_ok`` true on every arm,
``n_queries >= 1000``, positive ``qps``).

Run:
    PYTHONPATH=src python benchmarks/serve_qps.py [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

if __package__ in (None, ""):  # `python benchmarks/serve_qps.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

import numpy as np

from benchmarks.common import Csv, OUT_DIR
from repro.core import index as index_lib
from repro.core import mapping, partition, spjoin
from repro.data import synthetic

# Control-plane entry points the BUILD phase owns. Each is patched at its
# defining module, and every call site reaches it through module-attribute
# access, so a query that re-enters any of them is counted.
BUILD_CALLS = (
    (spjoin, "fit_node_stats"),
    (spjoin, "draw_pivots"),
    (mapping, "select_anchors"),
    (partition, "build_partition"),
)


class BuildCallCounter:
    """Context manager counting calls to the build-phase entry points."""

    def __init__(self):
        self.counts: dict[str, int] = {}
        self._orig: list[tuple] = []

    def __enter__(self) -> "BuildCallCounter":
        for mod, name in BUILD_CALLS:
            fn = getattr(mod, name)
            key = f"{mod.__name__.rsplit('.', 1)[-1]}.{name}"
            self.counts[key] = 0

            def wrapper(*a, _fn=fn, _key=key, **kw):
                self.counts[_key] += 1
                return _fn(*a, **kw)

            self._orig.append((mod, name, fn))
            setattr(mod, name, wrapper)
        return self

    def __exit__(self, *exc) -> None:
        for mod, name, fn in self._orig:
            setattr(mod, name, fn)
        self._orig.clear()

    @property
    def total(self) -> int:
        return sum(self.counts.values())


def _timed_queries(query_fn, batches: list[np.ndarray]):
    """Warm up on batch 0 (compile), then time every batch."""
    query_fn(batches[0])  # warm-up: stage compile + bucket traces
    lat, n_pairs = [], 0
    for b in batches:
        t0 = time.perf_counter()
        pairs = query_fn(b)
        lat.append(time.perf_counter() - t0)
        n_pairs += int(pairs.shape[0])
    lat_ms = np.array(lat) * 1e3
    total_s = float(np.array(lat).sum())
    n_q = sum(b.shape[0] for b in batches)
    return {
        "n_queries": int(n_q),
        "n_batches": len(batches),
        "batch_size": int(batches[0].shape[0]),
        "n_pairs": n_pairs,
        "qps": float(n_q / max(total_s, 1e-9)),
        "p50_ms": float(np.percentile(lat_ms, 50)),
        "p99_ms": float(np.percentile(lat_ms, 99)),
        "total_s": total_s,
    }


def run(
    n: int = 20_000,
    m: int = 16,
    n_queries: int = 4096,
    batch: int = 256,
    smoke: bool = False,
) -> dict:
    if smoke:
        n, m, n_queries, batch = 3000, 12, 1024, 128
    assert n_queries >= 1000, "acceptance: build once across >= 1000 queries"

    data = synthetic.mixture(n, m, n_clusters=6, spread=6.0, skew=0.3, seed=0)
    queries = synthetic.mixture(
        n_queries, m, n_clusters=6, spread=6.0, skew=0.3, seed=7
    )
    # δ at a small quantile of the R↔Q cross distances, so results are
    # non-empty but selective (the serving regime).
    from repro.core import distances
    import jax.numpy as jnp

    d = np.asarray(
        distances.pairwise(jnp.asarray(data[:512]), jnp.asarray(queries[:512]), "l2")
    )
    delta = float(np.quantile(d, 0.001))

    cfg = spjoin.JoinConfig(
        delta=delta, metric="l2", k=min(1024, n // 4), p=16,
        n_dims=8, seed=0,
    )

    # ---- build phase: exactly once, counted -------------------------------
    counter = BuildCallCounter()
    with counter:
        idx = index_lib.build_index(data, cfg)
    build_calls = dict(counter.counts)
    assert counter.total > 0, "build must exercise the control plane"

    batches = [
        queries[i : i + batch]
        for i in range(0, n_queries, batch)
        if queries[i : i + batch].shape[0] == batch
    ]

    # ---- query phase: zero build calls, measured --------------------------
    with counter:  # re-enter: counters reset to 0
        host = _timed_queries(idx.query_batch, batches)
    build_calls_during_queries = counter.total
    assert build_calls_during_queries == 0, (
        f"query phase re-entered the build control plane: {counter.counts}"
    )

    oracle = index_lib.brute_force_query(data, batches[0], delta, cfg.metric)
    host["parity_ok"] = bool(np.array_equal(idx.query_batch(batches[0]), oracle))
    _, qstats = idx.query_batch(batches[0], with_stats=True)
    host["duplication"] = qstats.duplication
    host["cells_touched"] = qstats.n_cells_touched

    # ---- distributed arm: the slot machinery end-to-end (1 device) --------
    from repro.launch import mesh as mesh_lib

    dist_idx = idx.to_distributed(mesh_lib.make_host_mesh(1))
    with counter:
        dist = _timed_queries(dist_idx.query_batch, batches[: max(4, len(batches) // 4)])
    assert counter.total == 0, "distributed query phase re-entered the build"
    dist["parity_ok"] = bool(np.array_equal(dist_idx.query_batch(batches[0]), oracle))

    # ---- lifecycle round trip: save -> load -> query ----------------------
    path = os.path.join(OUT_DIR, "serve_qps_index")
    t0 = time.perf_counter()
    idx.save(path)
    save_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    idx2 = index_lib.MetricIndex.load(path, metric=cfg.metric)
    load_s = time.perf_counter() - t0
    load_parity = bool(np.array_equal(idx2.query_batch(batches[0]), oracle))

    report = {
        "smoke": smoke,
        "n_index": n,
        "m": m,
        "delta": delta,
        "metric": cfg.metric,
        "build_count": 1,  # build_index invoked exactly once above
        "build_s": idx.build_s,
        "build_calls": build_calls,
        "build_calls_during_queries": build_calls_during_queries,
        "host": host,
        "distributed": dist,
        "lifecycle": {"save_s": save_s, "load_s": load_s, "parity_ok": load_parity},
    }

    csv = Csv(
        "bench_serve_qps.csv",
        ["arm", "n_index", "n_queries", "batch", "build_s", "qps",
         "p50_ms", "p99_ms", "n_pairs", "parity_ok"],
    )
    for arm, r in (("host", host), ("dist-1dev", dist)):
        csv.row(
            arm, n, r["n_queries"], r["batch_size"], f"{idx.build_s:.3f}",
            f"{r['qps']:.1f}", f"{r['p50_ms']:.2f}", f"{r['p99_ms']:.2f}",
            r["n_pairs"], r["parity_ok"],
        )
    csv.close()

    out_path = os.path.join(OUT_DIR, "serve_qps.json")
    with open(out_path, "w") as f:
        json.dump(report, f, indent=2)
    print(json.dumps(report))
    return report


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI sizing: 3k index rows, 1024 queries")
    ap.add_argument("--n", type=int, default=20_000)
    ap.add_argument("--n-queries", type=int, default=4096)
    ap.add_argument("--batch", type=int, default=256)
    args = ap.parse_args()
    run(n=args.n, n_queries=args.n_queries, batch=args.batch, smoke=args.smoke)


if __name__ == "__main__":
    main()
