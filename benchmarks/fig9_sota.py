"""Fig. 9: SP-Join vs the baseline algorithmic cores.

  spjoin        Gen + Learn (this paper)
  kpm-like      random sampling + KD equi-depth splits (Chen et al.'17 core)
  mrsim-like    ball partitioning, p pivots (Silva & Reed'12 core)
  cluster-like  ball partitioning with 2p pivots + window (Sarma et al.'14
                flavor: more, finer balls)

All four produce exact results (asserted); cost = wall time + verifications.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, make_datasets, timed
from repro.core import baselines, spjoin


def run(n: int = 1200, k: int = 256, p: int = 12) -> None:
    csv = Csv(
        "bench_fig9.csv",
        ["dataset", "delta", "system", "join_s", "verifications", "pairs"],
    )
    for ds in make_datasets(n):
        for delta in ds.deltas:
            cfg = spjoin.JoinConfig(delta=delta, metric=ds.metric,
                                    sampler="generative", partitioner="learning",
                                    k=k, p=p, n_dims=8, seed=0)
            res_sp, t_sp = timed(spjoin.join, ds.data, cfg)
            res_kpm, t_kpm = timed(
                spjoin.join, ds.data,
                baselines.kpm_config(delta, ds.metric, k=k, p=p, n_dims=8),
            )
            res_mr, t_mr = timed(baselines.ball_join, ds.data, delta, ds.metric, p)
            res_cl, t_cl = timed(baselines.ball_join, ds.data, delta, ds.metric, 2 * p)
            assert res_sp.n_pairs == res_kpm.n_pairs == res_mr.n_pairs == res_cl.n_pairs
            for name, res, t in [("spjoin", res_sp, t_sp), ("kpm-like", res_kpm, t_kpm),
                                 ("mrsim-like", res_mr, t_mr),
                                 ("cluster-like", res_cl, t_cl)]:
                csv.row(ds.name, round(delta, 4), name, round(t, 3),
                        res.n_verifications, res.n_pairs)
    csv.close()


if __name__ == "__main__":
    run()
