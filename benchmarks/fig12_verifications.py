"""Fig. 12: number of verifications per technique combo.

Paper claims: Random+Iter worst; Gen+Learn best; ordering consistent with
Fig. 6 join times (verifications are the machine-independent cost).

Beyond-paper columns: the streaming verify engine's telemetry per arm —
tile count, static-bucket count and padding occupancy (valid / padded
verification ratio) — the TPU-native cost the bucketed engine trades for
compile-cache hits."""
from __future__ import annotations

from benchmarks.common import Csv, make_datasets
from repro.core import spjoin

ARMS = [("random", "iterative"), ("distribution", "iterative"),
        ("generative", "iterative"), ("generative", "learning")]


def run(n: int = 1200, k: int = 256, p: int = 12) -> None:
    csv = Csv("bench_fig12.csv",
              ["dataset", "delta", "arm", "verifications", "inner", "outer",
               "tiles", "buckets", "occupancy"])
    for ds in make_datasets(n):
        delta = ds.deltas[-1]
        for sampler, part in ARMS:
            cfg = spjoin.JoinConfig(delta=delta, metric=ds.metric,
                                    sampler=sampler, partitioner=part,
                                    k=k, p=p, n_dims=8, seed=0)
            res = spjoin.join(ds.data, cfg)
            vs = res.verify_stats
            csv.row(ds.name, round(delta, 4), f"{sampler}+{part}",
                    res.n_verifications, int(res.cost.inner),
                    int(res.cost.outer), vs.n_tiles, vs.n_buckets,
                    round(vs.occupancy, 3))
    csv.close()


if __name__ == "__main__":
    run()
