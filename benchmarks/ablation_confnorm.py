"""Ablation (EXPERIMENTS.md finding #2): Gibbs confidence normalization.

The paper's Eqs. 17-19 acceptance collapses when no exponential family fits
(all c_i ~ 0 — e.g. multimodal shards): the fixed-length chain yields few
distinct pivots and partition quality degrades. Max-normalizing the
confidences is scale-invariant for the unbiased C=1 branch; this ablation
quantifies what it buys on mixture data.

    PYTHONPATH=src python -m benchmarks.ablation_confnorm
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Csv
from repro.core import distances, expfam, gof, mapping, partition, sampling
from repro.data import synthetic


def run(n: int = 2000) -> None:
    csv = Csv("bench_ablation_confnorm.csv",
              ["normalize", "accept_rate", "distinct_pivots",
               "verifications", "max_cell"])
    data = synthetic.mixture(n, 8, n_clusters=5, skew=0.4, seed=0)
    shards = np.array_split(data, 4)
    stats = []
    for s in shards:
        params, res = gof.fit_best_family(jnp.asarray(s))
        stats.append(sampling.NodeStats(params.family, params,
                                        float(res.confidence), len(s)))

    model = sampling.GenerativeModel(
        families=tuple(s.family for s in stats),
        packed_params=jnp.stack([expfam.pack(s.params) for s in stats]),
        confidence=jnp.asarray([s.confidence for s in stats], jnp.float32),
        counts=jnp.asarray([s.count for s in stats], jnp.float32),
    )

    for normalize in (False, True):
        pivots, acc = sampling.gibbs_chain(
            jax.random.PRNGKey(0), model, k=256, normalize_confidence=normalize
        )
        distinct = len(np.unique(np.asarray(pivots).round(4), axis=0))

        # partition quality downstream of those pivots
        smap = mapping.select_anchors(jax.random.PRNGKey(1), pivots, 6, "l1")
        mapped = np.asarray(smap(pivots))
        labels = partition.single_linkage_labels(
            np.asarray(distances.pairwise(pivots, pivots, "l1")), 32)
        plan = partition.build_partition(mapped, 16, 3.0, "learning", labels)
        xm = smap(jnp.asarray(data))
        cells = np.asarray(partition.assign_kernel(plan, xm))
        member = np.asarray(partition.whole_membership(plan, xm))
        v = np.bincount(cells, minlength=16)
        w = member.sum(0)
        csv.row(normalize, round(float(acc), 3), distinct,
                int((v * w).sum()), int((v * w).max()))
    csv.close()


if __name__ == "__main__":
    run()
