"""§Roofline: render the dry-run JSONL into the per-(arch x shape x mesh)
three-term table (compute / memory / collective seconds, bottleneck,
MODEL_FLOPS ratio, roofline-bound MFU). Source of truth for EXPERIMENTS.md.

Two sources, newest-wins merged:

  runs/dryrun.jsonl   — measured records from ``repro.launch.dryrun``
                        (only produced by the heavy 512-device dry run);
  ``synth_records()`` — analytic SP-Join phase records derived from the
                        ``launch.mesh.V5E`` hardware model, always
                        available, used whenever the dry-run JSONL is
                        absent so the artifact is never empty.

Emits ``runs/bench_roofline.csv`` and ``runs/roofline.md`` (the same table
``scripts/gen_roofline_md.py`` renders).
"""
from __future__ import annotations

import json
import os
import sys

if __package__ in (None, ""):  # `python benchmarks/roofline.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    sys.path.insert(0, os.path.join(_root, "src"))

from benchmarks.common import OUT_DIR, Csv

DRYRUN = os.environ.get("DRYRUN_JSONL", "runs/dryrun.jsonl")


def _rec(arch, shape, mesh, chips, flops, bytes_hbm, bytes_coll, useful,
         peak_bytes, temp_bytes) -> dict:
    from repro.launch.mesh import V5E

    t = V5E.roofline_seconds(flops, bytes_hbm, bytes_coll, chips)
    bottleneck = max(t, key=t.get)
    t_bound = t[bottleneck]
    return {
        "arch": arch,
        "shape": shape,
        "mesh": mesh,
        "roofline": {**t, "bottleneck": bottleneck},
        "model_flops": flops,
        "useful_flops_ratio": useful,
        # best MFU the roofline permits: useful compute share of the
        # bottleneck term (== useful when compute-bound).
        "mfu_bound": useful * t["compute_s"] / t_bound if t_bound else 0.0,
        "memory": {"peak_bytes": peak_bytes, "temp_bytes": temp_bytes},
        "source": "synthetic",
    }


def synth_records() -> list[dict]:
    """Analytic roofline records for the three SP-Join phases.

    Workload: N = 1e9 rows, m = 64 features, n = 8 mapped dims, p = 512
    cells, fp32 throughout. Per phase:

      map     flops = N·(2mn + 4p)          (anchor distances + box compares)
              hbm   = 2·N·(m+n)·4           (read rows, write rows+coords)
              coll  = N·8                   (cell ids + counts to the planner)
      verify  flops = C·2m, C = dup·N·w̄    (candidate distance evals;
              dup = 1.6 W-duplication, w̄ = 2048 mean opposing-tile rows)
              hbm   = dup·N·(m+n)·4·T       (T = 4 tile passes over V/W)
              coll  = dup·N·(m+n)·4         (the one all_to_all shuffle)
              useful = 0.32                 (pivot-filter survival: evals
                                             the filter could not prune)
      serve   flops = B·(2mn + c·2m), B = 1e6 queries, c = 4096 candidates
              hbm   = B·(m+n)·4 + pinned V traffic B·c·(m+n)·4 / r, r = 64
                      tile reuse
              coll  = 2·B·dup·(m+n)·4       (query dispatch + result masks)
              useful = 0.25
    """
    n_rows, m, nd, p = 1e9, 64, 8, 512
    dup, w_mean, tiles, surv = 1.6, 2048, 4, 0.32
    b_q, cand, reuse = 1e6, 4096, 64
    row4 = (m + nd) * 4
    phases = [
        ("spjoin-map",
         n_rows * (2 * m * nd + 4 * p), 2 * n_rows * row4, n_rows * 8,
         1.0, n_rows * row4, n_rows * 8 * 4),
        ("spjoin-verify",
         dup * n_rows * w_mean * 2 * m, dup * n_rows * row4 * tiles,
         dup * n_rows * row4, surv, dup * n_rows * row4, n_rows * 16),
        ("spjoin-serve",
         b_q * (2 * m * nd + cand * 2 * m),
         b_q * row4 + b_q * cand * row4 / reuse, 2 * b_q * dup * row4,
         0.25, n_rows * row4 / 256, b_q * cand / 8),
    ]
    shape = f"N={n_rows:.0e} m={m} n={nd} p={p}"  # no commas: CSV-safe
    out = []
    for mesh, chips in (("single_pod", 256), ("multi_pod", 512)):
        for arch, fl, bh, bc, useful, peak, temp in phases:
            out.append(_rec(arch, shape, mesh, chips, fl, bh, bc, useful,
                            peak, temp))
    return out


def load(path: str = DRYRUN) -> list[dict]:
    recs = []
    if not os.path.exists(path):
        return recs
    best: dict[tuple, dict] = {}
    for line in open(path):
        r = json.loads(line)
        if "roofline" in r:
            best[(r["arch"], r["shape"], r["mesh"])] = r  # newest wins
    return list(best.values())


def render_md(recs: list[dict]) -> str:
    rows = [
        "| arch | shape | mesh | compute ms | memory ms | collective ms "
        "| bottleneck | useful | mfu_bound |",
        "|---|---|---|---:|---:|---:|---|---:|---:|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['compute_s'] * 1e3:.1f} | {t['memory_s'] * 1e3:.1f} "
            f"| {t['collective_s'] * 1e3:.1f} | {t['bottleneck'][:-2]} "
            f"| {r.get('useful_flops_ratio') or 0:.2f} "
            f"| {r.get('mfu_bound') or 0:.4f} |"
        )
    return "\n".join(rows)


def run() -> None:
    recs = load()
    if not recs:
        print("no dry-run records; using analytic synth_records()")
        recs = synth_records()
    csv = Csv(
        "bench_roofline.csv",
        ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
         "bottleneck", "model_flops", "useful_ratio", "mfu_bound", "peak_gb"],
    )
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = r["roofline"]
        peak = (r.get("memory") or {}).get("peak_bytes") or 0
        csv.row(
            r["arch"], r["shape"], r["mesh"],
            f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
            f"{t['collective_s']:.4f}", t["bottleneck"],
            f"{r.get('model_flops', 0):.3e}",
            f"{(r.get('useful_flops_ratio') or 0):.3f}",
            f"{(r.get('mfu_bound') or 0):.4f}",
            f"{peak / 1e9:.2f}",
        )
    csv.close()
    md_path = os.path.join(OUT_DIR, "roofline.md")
    with open(md_path, "w") as f:
        f.write(render_md(recs) + "\n")
    print(f"wrote {md_path} ({len(recs)} records)")


if __name__ == "__main__":
    run()
