"""§Roofline: render the dry-run JSONL into the per-(arch x shape x mesh)
three-term table (compute / memory / collective seconds, bottleneck,
MODEL_FLOPS ratio, roofline-bound MFU). Source of truth for EXPERIMENTS.md.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import Csv

DRYRUN = os.environ.get("DRYRUN_JSONL", "runs/dryrun.jsonl")


def load(path: str = DRYRUN) -> list[dict]:
    recs = []
    if not os.path.exists(path):
        return recs
    best: dict[tuple, dict] = {}
    for line in open(path):
        r = json.loads(line)
        if "roofline" in r:
            best[(r["arch"], r["shape"], r["mesh"])] = r  # newest wins
    return list(best.values())


def run() -> None:
    recs = load()
    csv = Csv(
        "bench_roofline.csv",
        ["arch", "shape", "mesh", "compute_s", "memory_s", "collective_s",
         "bottleneck", "model_flops", "useful_ratio", "mfu_bound", "peak_gb"],
    )
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = r["roofline"]
        peak = (r.get("memory") or {}).get("peak_bytes") or 0
        csv.row(
            r["arch"], r["shape"], r["mesh"],
            f"{t['compute_s']:.4f}", f"{t['memory_s']:.4f}",
            f"{t['collective_s']:.4f}", t["bottleneck"],
            f"{r.get('model_flops', 0):.3e}",
            f"{(r.get('useful_flops_ratio') or 0):.3f}",
            f"{(r.get('mfu_bound') or 0):.4f}",
            f"{peak / 1e9:.2f}",
        )
    csv.close()
    if not recs:
        print("no dry-run records found; run: python -m repro.launch.dryrun --all")


if __name__ == "__main__":
    run()
