"""Benchmark harness entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig6,fig9]

Emits CSV to stdout and runs/bench_*.csv. The dry-run roofline table reads
runs/dryrun.jsonl (produced by repro.launch.dryrun --all).
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import (
    fig6_techniques, fig7_sample_size, fig8_partitions, fig9_sota,
    fig10_scaleout, fig11_scaleup, fig12_verifications, table3_balance,
    roofline, serve_qps,
)

MODULES = {
    "fig6": lambda q: fig6_techniques.run(n=800 if q else 1200),
    "fig7": lambda q: fig7_sample_size.run(n=800 if q else 1200),
    "fig8": lambda q: fig8_partitions.run(n=800 if q else 1200),
    "fig9": lambda q: fig9_sota.run(n=800 if q else 1200),
    "fig10": lambda q: fig10_scaleout.run(n=1000 if q else 1600),
    "fig11": lambda q: fig11_scaleup.run(n=1000 if q else 1600),
    "fig12": lambda q: fig12_verifications.run(n=800 if q else 1200),
    "table3": lambda q: table3_balance.run(n=800 if q else 1200),
    "roofline": lambda q: roofline.run(),
    "serve_qps": lambda q: serve_qps.run(smoke=q),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated module keys")
    args = ap.parse_args()

    keys = args.only.split(",") if args.only else list(MODULES)
    failures = []
    for key in keys:
        print(f"\n===== {key} =====", flush=True)
        t0 = time.time()
        try:
            MODULES[key](args.quick)
            print(f"===== {key} done in {time.time() - t0:.1f}s =====", flush=True)
        except Exception:
            failures.append(key)
            traceback.print_exc()
    if failures:
        raise SystemExit(f"benchmark failures: {failures}")
    print("\nall benchmarks complete")


if __name__ == "__main__":
    main()
