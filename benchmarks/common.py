"""Shared benchmark machinery: the four paper-analogue datasets, timing,
CSV output.

The paper evaluates NETFLIX (L1/L2 over rating vectors), SIFT (L1/L2 over
image descriptors), AOL (edit distance over query strings) and PUBMED
(Jaccard over abstracts). Those corpora aren't shippable; each is mirrored
by a synthetic generator with the same *statistical* stress: clustered
ratings, heavy-tailed descriptors, near-duplicate query strings, and
shingled documents. Sizes are CPU-scaled; every number the harness emits is
a ratio/count comparison, which is what the paper's figures assert.
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Callable

import numpy as np

from repro.data import synthetic, vectorize

OUT_DIR = os.environ.get("BENCH_OUT", "runs")


@dataclasses.dataclass
class Dataset:
    name: str
    data: np.ndarray  # vectors handed to the join
    metric: str
    deltas: tuple[float, ...]  # evaluated thresholds (paper sweeps these)


def make_datasets(n: int = 1500, seed: int = 0) -> list[Dataset]:
    nf = synthetic.mixture(n, 20, n_clusters=6, spread=6.0, skew=0.3, seed=seed)
    sift = synthetic.heavy_tailed(n, 32, alpha=2.5, seed=seed + 1)

    strs = synthetic.strings(n, mutate=0.12, seed=seed + 2)
    aol = vectorize.qgram_profile(strs, q=2, dim=64)

    docs = synthetic.strings(n, length=(24, 60), mutate=0.08, seed=seed + 3)
    pubmed = vectorize.minhash(vectorize.shingle_sets(docs, q=3), k=64).astype(
        np.float32
    )

    def q(data, metric, qs=(0.003, 0.01)):
        from repro.core import distances
        import jax.numpy as jnp

        sub = data[:400]
        d = np.asarray(distances.pairwise(jnp.asarray(sub), jnp.asarray(sub), metric))
        iu = np.triu_indices(len(sub), 1)
        return tuple(float(np.quantile(d[iu], x)) for x in qs)

    return [
        Dataset("netflix-like", nf, "l1", q(nf, "l1")),
        Dataset("sift-like", sift, "l2", q(sift, "l2")),
        Dataset("aol-like", aol, "l1", q(aol, "l1")),
        Dataset("pubmed-like", pubmed, "jaccard_minhash", q(pubmed, "jaccard_minhash")),
    ]


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    best, out = float("inf"), None
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        best = min(best, time.perf_counter() - t0)
    return out, best


class Csv:
    def __init__(self, name: str, header: list[str]):
        os.makedirs(OUT_DIR, exist_ok=True)
        self.path = os.path.join(OUT_DIR, name)
        self.f = open(self.path, "w")
        self.header = header
        self.f.write(",".join(header) + "\n")
        print(",".join(header))

    def row(self, *vals):
        line = ",".join(str(v) for v in vals)
        self.f.write(line + "\n")
        self.f.flush()
        print(line)

    def close(self):
        self.f.close()
