"""Fig. 8: sensitivity to the number of partitions p.

Paper claim: robust — best-to-worst spread ~10% over a wide p range.
(Wall time on one CPU conflates with constant factors; the load metric —
max per-cell verifications, i.e. the parallel critical path — is the
p-sensitivity the claim is about. Both are emitted.)
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, make_datasets, timed
from repro.core import spjoin


def run(n: int = 1200, k: int = 256) -> None:
    csv = Csv(
        "bench_fig8.csv",
        ["dataset", "p", "join_s", "verifications", "max_cell", "balance_std"],
    )
    for ds in make_datasets(n)[:2]:  # paper shows SIFT + AOL
        delta = ds.deltas[-1]
        for p in (4, 8, 12, 16, 24, 32):
            cfg = spjoin.JoinConfig(delta=delta, metric=ds.metric,
                                    sampler="generative", partitioner="learning",
                                    k=k, p=p, n_dims=8, seed=0)
            res, t = timed(spjoin.join, ds.data, cfg)
            csv.row(ds.name, p, round(t, 3), res.n_verifications,
                    int(res.cost.max_cell), round(res.cost.balance_std, 1))
    csv.close()


if __name__ == "__main__":
    run()
