"""Fig. 6: join cost for {Random, Dist, Gen} x {Iter, Learn}.

Paper claim: Gen/Dist beat Random under every setting; Gen ~ Dist quality
with far lower sampling communication. Emits wall time + phase breakdown +
verification count per arm.
"""
from __future__ import annotations

from benchmarks.common import Csv, make_datasets, timed
from repro.core import spjoin

ARMS = [
    ("random", "iterative"), ("random", "learning"),
    ("distribution", "iterative"), ("distribution", "learning"),
    ("generative", "iterative"), ("generative", "learning"),
]


def run(n: int = 1200, k: int = 256, p: int = 12) -> None:
    csv = Csv(
        "bench_fig6.csv",
        ["dataset", "delta", "sampler", "partitioner", "join_s", "sample_s",
         "map_s", "verify_s", "verifications", "pairs"],
    )
    for ds in make_datasets(n):
        for delta in ds.deltas:
            for sampler, part in ARMS:
                cfg = spjoin.JoinConfig(
                    delta=delta, metric=ds.metric, sampler=sampler,
                    partitioner=part, k=k, p=p, n_dims=8, seed=0,
                )
                res, t = timed(spjoin.join, ds.data, cfg)
                csv.row(ds.name, round(delta, 4), sampler, part, round(t, 3),
                        round(res.sample_time_s, 3), round(res.map_time_s, 3),
                        round(res.verify_time_s, 3), res.n_verifications,
                        res.n_pairs)
    csv.close()


if __name__ == "__main__":
    run()
