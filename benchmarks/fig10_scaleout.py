"""Fig. 10: scale-out — nodes M in {2,4,8}.

Two views, because this container has one physical core:
  * real multi-device wall time via a subprocess per M (XLA host devices;
    same-core contention makes absolute speedups flat, so this validates
    *runnability*, not speedup);
  * the parallel-critical-path proxy: max per-node verification load from
    the single-host executor sharded M ways — the quantity whose M-scaling
    the paper's Fig. 10 actually demonstrates.
"""
from __future__ import annotations

import json
import subprocess
import sys

import numpy as np

from benchmarks.common import Csv, make_datasets


_SUB = """
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count={m}'
import json, numpy as np, jax, jax.numpy as jnp, time
from repro.core import distributed
from repro.data import synthetic
mesh = jax.make_mesh(({m},), ("data",))
data = synthetic.mixture({n}, 12, n_clusters=6, skew=0.3, seed=0)
t0 = time.perf_counter()
r = distributed.distributed_join(jnp.asarray(data), mesh=mesh, delta={delta},
                                 metric="l1", k=192, p={p}, n_dims=6, seed=0)
t = time.perf_counter() - t0
print(json.dumps(dict(m={m}, wall_s=t, hits=r.n_hits, verif=r.n_verifications,
                      max_cell=float(np.max(r.per_cell_verified)),
                      padding=r.capacity_padding)))
"""


def run(n: int = 1600, p: int = 16) -> None:
    csv = Csv(
        "bench_fig10.csv",
        ["nodes", "wall_s", "hits", "verifications", "max_cell_load", "padding"],
    )
    # delta from data scale
    from repro.core import distances
    import jax.numpy as jnp

    data = make_datasets(400)[0]
    delta = data.deltas[-1]
    for m in (2, 4, 8):
        out = subprocess.run(
            [sys.executable, "-c", _SUB.format(m=m, n=n, delta=delta, p=p)],
            capture_output=True, text=True, timeout=1200,
            env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"},
            cwd=".",
        )
        assert out.returncode == 0, out.stderr[-2000:]
        r = json.loads(out.stdout.splitlines()[-1])
        csv.row(m, round(r["wall_s"], 2), r["hits"], r["verif"],
                int(r["max_cell"]), round(r["padding"], 2))
    csv.close()


if __name__ == "__main__":
    run()
