"""Table 3: per-partition verification balance (AVER / STDEV) per system.

Paper claim: SP-Join (Gen+Learn) has both the lowest mean and the lowest
std of per-partition verification counts — the load-balancing result. Each
row reports the TRUE per-cell loads the engine ran (``JoinResult.
per_cell_verified`` — |V_h|·|W_h| per cell), not a derived ratio.

A second table (``bench_table3_dist.csv``) extends the claim to the
distributed executor's per-DEVICE loads: the contiguous cell→device layout
vs the cost-model-guided LPT plan (``core.placement``) on a skewed mixture,
8 simulated devices — the paper's Table 3 balance story, finally measured
at placement granularity. Run in a subprocess so the device-count flag
never leaks into the parent.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

import numpy as np

from benchmarks.common import Csv, make_datasets
from repro.core import baselines, spjoin

_SUB_DIST = """
import os
os.environ['XLA_FLAGS']='--xla_force_host_platform_device_count=8'
import json, numpy as np, jax, jax.numpy as jnp
from repro.core import distributed
from repro.data import synthetic

mesh = jax.make_mesh((8,), ("data",))
data = synthetic.mixture({n}, 8, n_clusters=5, skew=0.8, seed=3)
out = {{}}
for strategy in ("contiguous", "lpt"):
    r = distributed.distributed_join(
        jnp.asarray(data), mesh=mesh, delta=2.5, metric="l1", k=256, p=16,
        n_dims=6, sampler="generative", backend="numpy",
        placement=strategy, seed=0)
    loads = np.asarray(r.device_loads, np.float64)
    out[strategy] = dict(
        aver=float(loads.mean()), stdev=float(loads.std()),
        makespan_ratio=float(r.makespan_ratio), hits=int(r.n_hits))
print(json.dumps(out))
"""


def run(n: int = 1200, k: int = 256, p: int = 12) -> None:
    csv = Csv("bench_table3.csv", ["dataset", "system", "aver", "stdev"])
    for ds in make_datasets(n):
        arms = {
            "kpm-like": baselines.kpm_config(ds.deltas[-1], ds.metric, k=k, p=p, n_dims=8),
            "random+iter": spjoin.JoinConfig(delta=ds.deltas[-1], metric=ds.metric,
                                             sampler="random", partitioner="iterative",
                                             k=k, p=p, n_dims=8),
            "dist+iter": spjoin.JoinConfig(delta=ds.deltas[-1], metric=ds.metric,
                                           sampler="distribution", partitioner="iterative",
                                           k=k, p=p, n_dims=8),
            "gen+iter": spjoin.JoinConfig(delta=ds.deltas[-1], metric=ds.metric,
                                          sampler="generative", partitioner="iterative",
                                          k=k, p=p, n_dims=8),
            "gen+learn": spjoin.JoinConfig(delta=ds.deltas[-1], metric=ds.metric,
                                           sampler="generative", partitioner="learning",
                                           k=k, p=p, n_dims=8),
        }
        for name, cfg in arms.items():
            res = spjoin.join(ds.data, cfg, return_pairs=False)
            # True per-cell verification loads the engine ran (|V_h|·|W_h|
            # per cell), straight from the result — the Table 3 metric.
            per_cell = np.asarray(res.per_cell_verified, np.float64)
            csv.row(ds.name, name, int(per_cell.mean()), int(per_cell.std()))
    csv.close()

    # Distributed arm: per-DEVICE balance, contiguous vs LPT placement.
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {"PYTHONPATH": os.path.join(root, "src"), "PATH": "/usr/bin:/bin",
           "HOME": os.environ.get("HOME", "/root")}
    if os.environ.get("JAX_PLATFORMS"):
        env["JAX_PLATFORMS"] = os.environ["JAX_PLATFORMS"]
    res = subprocess.run(
        [sys.executable, "-c", _SUB_DIST.format(n=n)],
        capture_output=True, text=True, timeout=1800, env=env, cwd=root,
    )
    assert res.returncode == 0, res.stderr[-3000:]
    dist = json.loads(res.stdout.splitlines()[-1])
    csv2 = Csv("bench_table3_dist.csv",
               ["placement", "aver", "stdev", "makespan_ratio"])
    for strategy in ("contiguous", "lpt"):
        row = dist[strategy]
        csv2.row(strategy, int(row["aver"]), int(row["stdev"]),
                 round(row["makespan_ratio"], 3))
    csv2.close()


if __name__ == "__main__":
    run()
