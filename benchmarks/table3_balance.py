"""Table 3: per-partition verification balance (AVER / STDEV) per system.

Paper claim: SP-Join (Gen+Learn) has both the lowest mean and the lowest
std of per-partition verification counts — the load-balancing result."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Csv, make_datasets
from repro.core import baselines, spjoin


def _per_cell(data, cfg):
    res = spjoin.join(data, cfg, return_pairs=False)
    return res


def run(n: int = 1200, k: int = 256, p: int = 12) -> None:
    csv = Csv("bench_table3.csv", ["dataset", "system", "aver", "stdev"])
    for ds in make_datasets(n):
        arms = {
            "kpm-like": baselines.kpm_config(ds.deltas[-1], ds.metric, k=k, p=p, n_dims=8),
            "random+iter": spjoin.JoinConfig(delta=ds.deltas[-1], metric=ds.metric,
                                             sampler="random", partitioner="iterative",
                                             k=k, p=p, n_dims=8),
            "dist+iter": spjoin.JoinConfig(delta=ds.deltas[-1], metric=ds.metric,
                                           sampler="distribution", partitioner="iterative",
                                           k=k, p=p, n_dims=8),
            "gen+iter": spjoin.JoinConfig(delta=ds.deltas[-1], metric=ds.metric,
                                          sampler="generative", partitioner="iterative",
                                          k=k, p=p, n_dims=8),
            "gen+learn": spjoin.JoinConfig(delta=ds.deltas[-1], metric=ds.metric,
                                           sampler="generative", partitioner="learning",
                                           k=k, p=p, n_dims=8),
        }
        for name, cfg in arms.items():
            res = spjoin.join(ds.data, cfg, return_pairs=False)
            # per-cell verification loads from the cost model's inputs
            csv.row(ds.name, name, int(res.n_verifications / max(cfg.p, 1)),
                    int(res.cost.balance_std))
    csv.close()


if __name__ == "__main__":
    run()
