"""CI docs gate: every intra-repo markdown link must resolve.

Walks all tracked ``*.md`` files, extracts inline links and images
(``[text](target)``), and checks that relative targets exist on disk
(anchors are stripped; external schemes and pure-anchor links are skipped).
Exit code 1 with a per-link report when anything dangles.

Run:  python scripts/check_links.py  (from the repo root or anywhere in it)
"""
from __future__ import annotations

import os
import re
import sys

# Inline [text](target) — target up to the first unescaped ')'; tolerates
# reference-style images and badge nesting by matching the innermost pair.
_LINK = re.compile(r"\[[^\]]*\]\(([^()\s]+(?:\([^()]*\)[^()\s]*)*)\)")
_SKIP_SCHEMES = ("http://", "https://", "mailto:", "ftp://")
_SKIP_DIRS = {".git", "__pycache__", ".pytest_cache", ".ruff_cache",
              ".hypothesis", "runs", "node_modules", ".claude"}


def repo_root() -> str:
    d = os.path.dirname(os.path.abspath(__file__))
    return os.path.dirname(d)


def md_files(root: str) -> list[str]:
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames[:] = [d for d in dirnames if d not in _SKIP_DIRS]
        out.extend(
            os.path.join(dirpath, f) for f in filenames if f.endswith(".md")
        )
    return sorted(out)


def check(root: str) -> list[str]:
    errors = []
    for path in md_files(root):
        with open(path, encoding="utf-8") as f:
            text = f.read()
        for target in _LINK.findall(text):
            if target.startswith(_SKIP_SCHEMES) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not rel:
                continue
            if rel.startswith("/"):
                resolved = os.path.join(root, rel.lstrip("/"))
            else:
                resolved = os.path.join(os.path.dirname(path), rel)
            # Badge-style links into the forge UI (../../actions/...) point
            # outside the checkout by construction; skip anything that
            # escapes the repo root rather than guessing the forge layout.
            if os.path.commonpath(
                [root, os.path.abspath(resolved)]
            ) != os.path.abspath(root):
                continue
            if not os.path.exists(resolved):
                errors.append(
                    f"{os.path.relpath(path, root)}: dangling link -> {target}"
                )
    return errors


def main() -> int:
    root = repo_root()
    errors = check(root)
    n = len(md_files(root))
    if errors:
        print(f"checked {n} markdown files: {len(errors)} dangling link(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"checked {n} markdown files: all intra-repo links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
