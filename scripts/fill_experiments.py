"""Fill the generated tables in EXPERIMENTS.md from the dry-run JSONLs."""
import json
import re
import sys

sys.path.insert(0, "scripts")
from gen_roofline_md import load, table  # noqa: E402

HILLCLIMBED = [
    ("qwen1.5-0.5b", "train_4k"),
    ("deepseek-moe-16b", "train_4k"),
    ("granite-34b", "train_4k"),
    ("stablelm-3b", "train_4k"),
    ("llama4-scout-17b-a16e", "train_4k"),
    ("llava-next-34b", "train_4k"),
    ("phi3-mini-3.8b", "train_4k"),
    ("hubert-xlarge", "train_4k"),
    ("zamba2-2.7b", "train_4k"),
    ("xlstm-1.3b", "train_4k"),
    ("llama4-scout-17b-a16e", "prefill_32k"),
    ("qwen1.5-0.5b", "prefill_32k"),
]


def delta_table(base, opt):
    rows = [
        "| arch | shape | mesh | mfu_bound base | mfu_bound opt | × | bottleneck base → opt |",
        "|---|---|---|---:|---:|---:|---|",
    ]
    for arch, shape in HILLCLIMBED:
        for mesh in ("single_pod", "multi_pod"):
            b = base.get((arch, shape, mesh))
            o = opt.get((arch, shape, mesh))
            if not b or not o:
                continue
            mb = b.get("mfu_bound") or 0
            mo = o.get("mfu_bound") or 0
            x = mo / mb if mb else float("inf")
            rows.append(
                f"| {arch} | {shape} | {mesh.replace('_pod','')} "
                f"| {mb:.4f} | {mo:.4f} | {x:.1f} "
                f"| {b['roofline']['bottleneck'][:-2]} → {o['roofline']['bottleneck'][:-2]} |"
            )
    return "\n".join(rows)


def main():
    base = load("runs/dryrun.jsonl")
    opt = load("runs/dryrun_opt.jsonl")
    md = open("EXPERIMENTS.md").read()
    md = md.replace("<!-- BASELINE_TABLE -->", table(base))
    md = md.replace("<!-- OPT_TABLE -->", table(opt))
    md = md.replace("<!-- DELTA_TABLE -->", delta_table(base, opt))
    open("EXPERIMENTS.md", "w").write(md)
    print(f"baseline cells: {len(base)}, optimized cells: {len(opt)}")


if __name__ == "__main__":
    main()
