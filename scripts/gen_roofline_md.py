"""Render dry-run JSONL(s) into the EXPERIMENTS.md roofline tables."""
import json, sys

def load(path):
    best = {}
    try:
        for line in open(path):
            r = json.loads(line)
            if "roofline" in r:
                best[(r["arch"], r["shape"], r["mesh"])] = r
    except FileNotFoundError:
        pass
    return best

def fmt(r):
    t = r["roofline"]
    peak = (r.get("memory") or {}).get("temp_bytes") or 0
    return (f"| {r['arch']} | {r['shape']} | {r['mesh'].replace('_pod','')} "
            f"| {t['compute_s']*1e3:9.1f} | {t['memory_s']*1e3:9.1f} | {t['collective_s']*1e3:9.1f} "
            f"| {t['bottleneck'][:-2]} | {r.get('useful_flops_ratio') or 0:.2f} "
            f"| {(r.get('mfu_bound') or 0):.4f} | {peak/1e9:.1f} |")

def table(recs):
    out = ["| arch | shape | mesh | compute ms | memory ms | collective ms | bottleneck | useful | mfu_bound | temp GB |",
           "|---|---|---|---:|---:|---:|---|---:|---:|---:|"]
    for k in sorted(recs):
        out.append(fmt(recs[k]))
    return "\n".join(out)

if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "runs/dryrun.jsonl"
    print(table(load(which)))
