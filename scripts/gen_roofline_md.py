"""Render dry-run JSONL(s) into the EXPERIMENTS.md roofline tables.

Falls back to the analytic SP-Join records from ``benchmarks.roofline``
when the measured JSONL is absent, so the table is never empty. ``--out``
writes the markdown next to printing it (CI uploads runs/roofline.md).
"""
import argparse, json, os, sys

def load(path):
    best = {}
    try:
        for line in open(path):
            r = json.loads(line)
            if "roofline" in r:
                best[(r["arch"], r["shape"], r["mesh"])] = r
    except FileNotFoundError:
        pass
    return best

def fmt(r):
    t = r["roofline"]
    peak = (r.get("memory") or {}).get("temp_bytes") or 0
    return (f"| {r['arch']} | {r['shape']} | {r['mesh'].replace('_pod','')} "
            f"| {t['compute_s']*1e3:9.1f} | {t['memory_s']*1e3:9.1f} | {t['collective_s']*1e3:9.1f} "
            f"| {t['bottleneck'][:-2]} | {r.get('useful_flops_ratio') or 0:.2f} "
            f"| {(r.get('mfu_bound') or 0):.4f} | {peak/1e9:.1f} |")

def table(recs):
    out = ["| arch | shape | mesh | compute ms | memory ms | collective ms | bottleneck | useful | mfu_bound | temp GB |",
           "|---|---|---|---:|---:|---:|---|---:|---:|---:|"]
    for k in sorted(recs):
        out.append(fmt(recs[k]))
    return "\n".join(out)

def synth():
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, root)
    sys.path.insert(0, os.path.join(root, "src"))
    from benchmarks.roofline import synth_records
    return {(r["arch"], r["shape"], r["mesh"]): r for r in synth_records()}

if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("source", nargs="?", default="runs/dryrun.jsonl")
    ap.add_argument("--out", default=None, help="also write the table to this file")
    args = ap.parse_args()
    recs = load(args.source) or synth()
    text = table(recs)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text + "\n")
    print(text)
