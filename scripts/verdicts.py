"""Summarize paper-claim verdicts from the benchmark CSVs (fills the
§Validation verdict lines in EXPERIMENTS.md)."""
import csv
import os
from collections import defaultdict

R = "runs"


def rows(name):
    path = os.path.join(R, name)
    if not os.path.exists(path):
        return []
    with open(path) as f:
        return list(csv.DictReader(f))


def fig6():
    by = defaultdict(dict)
    for r in rows("bench_fig6.csv"):
        by[(r["dataset"], r["delta"])][(r["sampler"], r["partitioner"])] = r
    wins_v, total = 0, 0
    for key, arms in by.items():
        rand = min(int(arms[(s, p)]["verifications"])
                   for (s, p) in arms if s == "random")
        best_ours = min(int(arms[(s, p)]["verifications"])
                        for (s, p) in arms if s != "random")
        total += 1
        wins_v += best_ours <= rand
    print(f"fig6: best(Dist/Gen) <= best(Random) verifications in {wins_v}/{total} settings")
    # gen+learn vs random+iter
    imp = []
    for key, arms in by.items():
        a = int(arms[("generative", "learning")]["verifications"])
        b = int(arms[("random", "iterative")]["verifications"])
        imp.append(b / max(a, 1))
    print(f"fig6: Gen+Learn vs Random+Iter verification ratio: "
          f"median {sorted(imp)[len(imp)//2]:.2f}x, max {max(imp):.2f}x")


def fig7():
    by = defaultdict(dict)
    for r in rows("bench_fig7.csv"):
        by[r["dataset"]][r["arm"]] = r
    for ds, arms in by.items():
        m1 = float(arms["random_1x"]["map_s"])
        m10 = float(arms["random_10x"]["map_s"])
        g = float(arms["gen_1x"]["join_s"])
        r10 = float(arms["random_10x"]["join_s"])
        print(f"fig7 {ds}: map_s 1x->10x = {m1:.2f}->{m10:.2f} "
              f"({m10/max(m1,1e-9):.1f}x); gen_1x join {g:.1f}s vs random_10x {r10:.1f}s")


def fig8():
    by = defaultdict(list)
    for r in rows("bench_fig8.csv"):
        by[r["dataset"]].append(r)
    for ds, rs in by.items():
        js = [float(r["join_s"]) for r in rs]
        mc = [int(r["max_cell"]) for r in rs]
        print(f"fig8 {ds}: join_s spread {min(js):.1f}-{max(js):.1f} "
              f"({(max(js)-min(js))/min(js):.0%}); max_cell {min(mc)}-{max(mc)}")


def fig9():
    by = defaultdict(dict)
    for r in rows("bench_fig9.csv"):
        by[(r["dataset"], r["delta"])][r["system"]] = r
    wins = 0
    for key, arms in by.items():
        sp = int(arms["spjoin"]["verifications"])
        others = min(int(arms[s]["verifications"]) for s in arms if s != "spjoin")
        wins += sp <= others
    print(f"fig9: spjoin fewest verifications in {wins}/{len(by)} settings")


def fig11():
    by = defaultdict(list)
    for r in rows("bench_fig11.csv"):
        by[r["dataset"]].append(r)
    for ds, rs in by.items():
        rs.sort(key=lambda r: float(r["fraction"]))
        v = [int(r["verifications"]) for r in rs]
        print(f"fig11 {ds}: verifications at 25/50/75/100% = {v} "
              f"(100%/25% = {v[-1]/max(v[0],1):.1f}x; linear would be 4x, "
              f"quadratic 16x)")


def table3():
    by = defaultdict(dict)
    for r in rows("bench_table3.csv"):
        by[r["dataset"]][r["system"]] = r
    wins = 0
    for ds, arms in by.items():
        gl = int(arms["gen+learn"]["stdev"])
        others = min(int(arms[s]["stdev"]) for s in arms if s != "gen+learn")
        wins += gl <= others
        print(f"table3 {ds}: gen+learn stdev {gl} vs best-other {others}")
    print(f"table3: gen+learn lowest stdev in {wins}/{len(by)} datasets")


if __name__ == "__main__":
    for fn in (fig6, fig7, fig8, fig9, fig11, table3):
        try:
            fn()
        except Exception as e:
            print(f"{fn.__name__}: (pending) {e}")
