"""The AST rules. Each takes a ModuleIndex and yields Violations.

Rule catalogue (rationale + examples in docs/INVARIANTS.md):

  host-sync        no ``np.asarray`` / ``.item()`` / ``.block_until_ready()``
                   / ``jax.device_get`` / ``int()``-on-tracer in hot scopes.
                   Traced tier: flagged anywhere. Stream tier: flagged inside
                   ``for``/``while`` bodies (per-tile syncs stall the stream).
  dispatch-triad   every public ``backend=``-dispatched op in kernels/ops.py
                   must reach a ref.py oracle, a Pallas kernel module, and
                   ``resolve_backend`` (directly or through same-module
                   delegation).
  f64-cast         no float64 (or weak-f64 ``dtype=float``) in kernel paths.
  dyn-control      no ``if``/``while``/``for`` on values computed by jnp/jax
                   inside a traced scope (data-dependent Python control flow
                   either crashes the trace or silently bakes one branch in).
  collective-site  communication primitives only at the blessed sites
                   (the ``_make_exchange`` shuffle factory; the stats/counts
                   gathers).
  pallas-confined  core/ imports the kernels package only through ``ops`` /
                   ``ref`` — never the raw kernel modules or pallas itself.
  waiver-hygiene   every waiver names a real rule, carries a justification,
                   suppresses something, and the global count is ratcheted.
"""
from __future__ import annotations

import ast

from spjoin_lint import config
from spjoin_lint.astlint import (
    FuncInfo,
    ModuleIndex,
    Violation,
    _attr_tail,
    _root_name,
    scope_walk,
)

_NP_NAMES = frozenset({"np", "numpy"})
_JNP_NAMES = frozenset({"jnp", "jax"})


def _is_np_call(node: ast.Call, funcs: frozenset) -> bool:
    return (
        isinstance(node.func, ast.Attribute)
        and node.func.attr in funcs
        and _root_name(node.func) in _NP_NAMES
    )


# jax.* utilities that return host Python values, not tracers — control flow
# over these is configuration, not data dependence.
_JAX_HOST_UTILS = frozenset(
    {"default_backend", "device_count", "local_device_count", "devices",
     "local_devices", "process_index", "process_count"}
)


def _is_jnp_rooted_call(node: ast.AST) -> bool:
    return (
        isinstance(node, ast.Call)
        and isinstance(node.func, ast.Attribute)
        and _root_name(node.func) in _JNP_NAMES
        and node.func.attr not in _JAX_HOST_UTILS
    )


def _contains_jnp_call(node: ast.AST) -> bool:
    return any(_is_jnp_rooted_call(n) for n in ast.walk(node))


def _sync_violation(idx: ModuleIndex, node: ast.Call, fi: FuncInfo) -> str | None:
    """Return a message when ``node`` is a host-sync construct, else None."""
    f = node.func
    if _is_np_call(node, config.SYNC_NP_FUNCS):
        return f"{_root_name(f)}.{f.attr}() forces a device->host transfer"
    if isinstance(f, ast.Attribute) and f.attr in config.SYNC_METHODS:
        return f".{f.attr}() blocks on the device"
    if (
        isinstance(f, ast.Attribute)
        and f.attr in config.SYNC_JAX_FUNCS
        and _root_name(f) in _JNP_NAMES
    ):
        return f"jax.{f.attr}() forces a device->host transfer"
    return None


# ---------------------------------------------------------------------------
# host-sync
# ---------------------------------------------------------------------------


def check_host_sync(idx: ModuleIndex):
    for fi in idx.functions.values():
        if fi.tier == "traced":
            yield from _host_sync_traced(idx, fi)
        elif fi.tier == "stream":
            yield from _host_sync_stream(idx, fi)


def _host_sync_traced(idx: ModuleIndex, fi: FuncInfo):
    for node in scope_walk(fi.node):
        if not isinstance(node, ast.Call):
            continue
        msg = _sync_violation(idx, node, fi)
        if msg:
            yield Violation(
                idx.relpath, node.lineno, "host-sync",
                f"{msg} inside traced scope `{fi.qualname}`",
            )
            continue
        # int()/float()/bool() on anything but a static argname or constant
        # concretizes a tracer.
        if (
            isinstance(node.func, ast.Name)
            and node.func.id in ("int", "float", "bool")
            and node.args
        ):
            arg = node.args[0]
            ok = isinstance(arg, ast.Constant) or (
                isinstance(arg, ast.Name) and arg.id in fi.static_args
            )
            if not ok:
                yield Violation(
                    idx.relpath, node.lineno, "host-sync",
                    f"{node.func.id}() on a non-static value inside traced "
                    f"scope `{fi.qualname}` concretizes the tracer",
                )


def _host_sync_stream(idx: ModuleIndex, fi: FuncInfo):
    # Only loop bodies: a per-tile/per-cell sync serializes the stream.
    loops = [
        n
        for n in scope_walk(fi.node)
        if isinstance(n, (ast.For, ast.While, ast.AsyncFor))
    ]
    seen: set[int] = set()
    for loop in loops:
        for node in ast.walk(loop):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                continue
            if not isinstance(node, ast.Call) or id(node) in seen:
                continue
            seen.add(id(node))
            msg = _sync_violation(idx, node, fi)
            if msg:
                yield Violation(
                    idx.relpath, node.lineno, "host-sync",
                    f"{msg} inside the hot loop of stream scope "
                    f"`{fi.qualname}`",
                )
            elif (
                isinstance(node.func, ast.Name)
                and node.func.id in ("int", "float", "bool")
                and node.args
                and _contains_jnp_call(node.args[0])
            ):
                yield Violation(
                    idx.relpath, node.lineno, "host-sync",
                    f"{node.func.id}() over a jnp expression inside the hot "
                    f"loop of stream scope `{fi.qualname}` syncs per "
                    f"iteration",
                )


# ---------------------------------------------------------------------------
# dispatch-triad
# ---------------------------------------------------------------------------


def _kernel_aliases(tree: ast.Module) -> tuple[set, set]:
    """(ref aliases, raw kernel-module aliases) from the import statements."""
    ref_alias, kern_alias = set(), set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module and (
            node.module == "repro.kernels" or node.module.endswith(".kernels")
        ):
            for a in node.names:
                name = a.asname or a.name
                if a.name == "ref":
                    ref_alias.add(name)
                elif a.name in config.RAW_KERNEL_MODULES:
                    kern_alias.add(name)
    return ref_alias, kern_alias


def check_dispatch_triad(idx: ModuleIndex):
    if not any(idx.relpath.endswith(m) for m in config.TRIAD_MODULES):
        return
    tree = idx.tree
    ref_alias, kern_alias = _kernel_aliases(tree)

    defs = {name: fi.node for name, fi in idx.module_scope.items()}
    effects: dict[str, set] = {}
    calls: dict[str, set] = {}
    for name, fn in defs.items():
        eff, callees = set(), set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute):
                root = _root_name(f)
                if root in ref_alias:
                    eff.add("ref")
                elif root in kern_alias:
                    eff.add("pallas")
                elif f.attr == "resolve_backend":
                    eff.add("dispatch")
            elif isinstance(f, ast.Name):
                if f.id == "resolve_backend":
                    eff.add("dispatch")
                elif f.id in defs:
                    callees.add(f.id)
        effects[name] = eff
        calls[name] = callees

    # Same-module delegation closes the triad (pairdist_count -> pairdist_mask).
    changed = True
    while changed:
        changed = False
        for name in defs:
            for callee in calls[name]:
                merged = effects[name] | effects[callee]
                if merged != effects[name]:
                    effects[name] = merged
                    changed = True

    legs = {
        "ref": "a ref.py oracle call (the numpy backend / parity oracle)",
        "pallas": "a Pallas kernel-module call (the accelerator backend)",
        "dispatch": "a resolve_backend() dispatch arm",
    }
    for name, fn in defs.items():
        if name.startswith("_"):
            continue
        args = fn.args
        kwonly = {a.arg for a in args.kwonlyargs}
        if "backend" not in kwonly:
            continue
        missing = [leg for leg in ("ref", "pallas", "dispatch") if leg not in effects[name]]
        for leg in missing:
            yield Violation(
                idx.relpath, fn.lineno, "dispatch-triad",
                f"public op `{name}` takes backend= but never reaches "
                f"{legs[leg]} (directly or via same-module delegation)",
            )


# ---------------------------------------------------------------------------
# f64-cast
# ---------------------------------------------------------------------------


def _f64_violations(idx: ModuleIndex, nodes, where: str):
    for node in nodes:
        if isinstance(node, ast.Attribute) and node.attr == "float64":
            root = _root_name(node)
            if root in _NP_NAMES | _JNP_NAMES:
                yield Violation(
                    idx.relpath, node.lineno, "f64-cast",
                    f"{root}.float64 in {where} — kernel paths are f32; f64 "
                    f"doubles HBM traffic and detunes the MXU",
                )
        elif isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Attribute) and f.attr == "astype" and node.args:
                a = node.args[0]
                if (isinstance(a, ast.Name) and a.id == "float") or (
                    isinstance(a, ast.Constant) and a.value == "float64"
                ):
                    yield Violation(
                        idx.relpath, node.lineno, "f64-cast",
                        f".astype({ast.unparse(a)}) in {where} promotes to "
                        f"float64 (python float == f64)",
                    )
            for kw in node.keywords:
                if kw.arg == "dtype" and isinstance(kw.value, ast.Name) and (
                    kw.value.id == "float"
                ):
                    yield Violation(
                        idx.relpath, node.lineno, "f64-cast",
                        f"dtype=float in {where} is a weak-typed f64 "
                        f"promotion; spell the f32 dtype explicitly",
                    )


def check_f64_cast(idx: ModuleIndex):
    module_wide = any(root in idx.relpath for root in config.F64_MODULE_WIDE)
    if module_wide:
        yield from _f64_violations(idx, ast.walk(idx.tree), "a kernel module")
        return
    for fi in idx.functions.values():
        if fi.tier == "traced":
            yield from _f64_violations(
                idx, scope_walk(fi.node), f"traced scope `{fi.qualname}`"
            )


# ---------------------------------------------------------------------------
# dyn-control
# ---------------------------------------------------------------------------


def check_dyn_control(idx: ModuleIndex):
    for fi in idx.functions.values():
        if fi.tier != "traced":
            continue
        for node in scope_walk(fi.node):
            if isinstance(node, (ast.If, ast.While)) and _contains_jnp_call(node.test):
                kind = "if" if isinstance(node, ast.If) else "while"
                yield Violation(
                    idx.relpath, node.lineno, "dyn-control",
                    f"`{kind}` over a jnp/jax expression in traced scope "
                    f"`{fi.qualname}` is data-dependent Python control flow — "
                    f"use jnp.where / lax.cond / lax.while_loop",
                )
            elif isinstance(node, (ast.For, ast.AsyncFor)) and _contains_jnp_call(
                node.iter
            ):
                yield Violation(
                    idx.relpath, node.lineno, "dyn-control",
                    f"`for` over a jnp/jax expression in traced scope "
                    f"`{fi.qualname}` unrolls a data-dependent loop — use "
                    f"lax.scan / lax.fori_loop",
                )
            elif isinstance(node, ast.IfExp) and _contains_jnp_call(node.test):
                yield Violation(
                    idx.relpath, node.lineno, "dyn-control",
                    f"conditional expression over a jnp/jax value in traced "
                    f"scope `{fi.qualname}` — use jnp.where",
                )


# ---------------------------------------------------------------------------
# collective-site
# ---------------------------------------------------------------------------


def check_collective_site(idx: ModuleIndex):
    class V(ast.NodeVisitor):
        def __init__(self):
            self.stack: list[FuncInfo] = []
            self.hits: list[Violation] = []

        def visit_FunctionDef(self, node):  # noqa: N802
            fi = idx.func_of(node)
            self.stack.append(fi)
            self.generic_visit(node)
            self.stack.pop()

        visit_AsyncFunctionDef = visit_FunctionDef  # noqa: N815

        def visit_Call(self, node):  # noqa: N802
            f = node.func
            if (
                isinstance(f, ast.Attribute)
                and f.attr in config.COLLECTIVE_PRIMS
                and _root_name(f) in _JNP_NAMES | {"lax"}
            ):
                top = self.stack[-1].qualname.split(".")[0] if self.stack else "<module>"
                blessed = config.BLESSED_COLLECTIVE_SITES.get(f.attr, frozenset())
                if not any(
                    idx.relpath.endswith(suffix) and top == qual
                    for suffix, qual in blessed
                ):
                    sites = (
                        " / ".join(f"{s}::{q}" for s, q in sorted(blessed))
                        or "none — this collective has no blessed site"
                    )
                    self.hits.append(
                        Violation(
                            idx.relpath, node.lineno, "collective-site",
                            f"jax.lax.{f.attr} outside its blessed site(s): "
                            f"{sites}. New collectives change the stage comm "
                            f"contract the jaxpr auditor pins",
                        )
                    )
            self.generic_visit(node)

    v = V()
    v.visit(idx.tree)
    yield from v.hits


# ---------------------------------------------------------------------------
# pallas-confined
# ---------------------------------------------------------------------------


def check_pallas_confined(idx: ModuleIndex):
    if "repro/core/" not in idx.relpath:
        return
    for node in ast.walk(idx.tree):
        if isinstance(node, ast.ImportFrom) and node.module:
            mod = node.module
            if mod == "repro.kernels" or mod.endswith(".kernels"):
                for a in node.names:
                    if a.name in config.RAW_KERNEL_MODULES:
                        yield Violation(
                            idx.relpath, node.lineno, "pallas-confined",
                            f"core/ imports raw kernel module "
                            f"`repro.kernels.{a.name}` — go through ops/ref "
                            f"(layering: core -> ops -> pallas)",
                        )
            elif mod.startswith("repro.kernels."):
                leaf = mod.rsplit(".", 1)[1]
                if leaf in config.RAW_KERNEL_MODULES:
                    yield Violation(
                        idx.relpath, node.lineno, "pallas-confined",
                        f"core/ imports from raw kernel module `{mod}` — go "
                        f"through ops/ref (layering: core -> ops -> pallas)",
                    )
            if "pallas" in mod.split(".") or any(
                a.name == "pallas" for a in node.names
            ):
                yield Violation(
                    idx.relpath, node.lineno, "pallas-confined",
                    "core/ imports pallas directly — kernels/ is the only "
                    "layer that may touch pallas",
                )
        elif isinstance(node, ast.Import):
            for a in node.names:
                parts = a.name.split(".")
                if "pallas" in parts or (
                    len(parts) >= 3
                    and parts[-2] == "kernels"
                    and parts[-1] in config.RAW_KERNEL_MODULES
                ):
                    yield Violation(
                        idx.relpath, node.lineno, "pallas-confined",
                        f"core/ imports `{a.name}` — raw kernel/pallas "
                        f"modules are confined to kernels/",
                    )


ALL_RULES = (
    check_host_sync,
    check_dispatch_triad,
    check_f64_cast,
    check_dyn_control,
    check_collective_site,
    check_pallas_confined,
)
