"""spjoin-lint: two-layer static analysis for the SP-Join repro.

Layer 1 (``astlint``/``rules``): AST rules over ``src/repro/core`` and
``src/repro/kernels`` — host-sync hygiene, dispatch-triad completeness,
f64 confinement, data-dependent control flow, blessed collective sites,
kernel-layer confinement, waiver hygiene.

Layer 2 (``jaxpr_audit``): traces every jitted public entry point with
abstract shapes and pins its contract surface (collective counts, zero f64
casts, static output shapes, recompile budget) into ``runs/contracts.json``,
diffed against a committed baseline in CI.

Run ``python -m spjoin_lint src/`` (AST layer) or add ``--audit`` for both.
"""
from __future__ import annotations

__version__ = "0.1.0"

from spjoin_lint.astlint import Violation, lint_file, lint_paths  # noqa: E402,F401
