"""Command-line entry point: ``python -m spjoin_lint [paths...]``.

Exit status 0 means every contract holds; 1 means violations (printed one
per line, ``file:line: [rule] message``). ``--audit`` additionally runs the
jaxpr trace auditor and writes ``runs/contracts.json``.
"""
from __future__ import annotations

import argparse
import os
import sys


def _repo_root() -> str:
    # tools/spjoin_lint/cli.py -> repo root is two levels up from the package.
    return os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__))))


def _ensure_import_paths() -> None:
    """Make ``repro`` importable when run from a source checkout."""
    src = os.path.join(_repo_root(), "src")
    if os.path.isdir(src) and src not in sys.path:
        sys.path.insert(0, src)


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="spjoin-lint",
        description="SP-Join contract linter: AST rules + jaxpr trace audit.",
    )
    parser.add_argument(
        "paths", nargs="*", default=None,
        help="files or directories to lint (default: <repo>/src)",
    )
    parser.add_argument(
        "--audit", action="store_true",
        help="also run the jaxpr trace auditor (imports jax + repro)",
    )
    parser.add_argument(
        "--audit-only", action="store_true",
        help="run only the jaxpr trace auditor, skip the AST layer",
    )
    parser.add_argument(
        "--contracts-out", default=None, metavar="PATH",
        help="where the auditor writes its report (default: <repo>/runs/contracts.json)",
    )
    parser.add_argument(
        "--baseline", default=None, metavar="PATH",
        help="baseline to diff against (default: tools/spjoin_lint/contracts_baseline.json)",
    )
    parser.add_argument(
        "--write-baseline", action="store_true",
        help="rewrite the committed baseline from this run instead of diffing",
    )
    args = parser.parse_args(argv)

    root = _repo_root()
    paths = args.paths or [os.path.join(root, "src")]
    failed = False

    if not args.audit_only:
        from spjoin_lint.astlint import lint_paths

        violations, n_waivers = lint_paths(paths)
        for v in violations:
            print(v.format())
        n_files = sum(1 for p in paths for _ in _walk_py(p))
        print(
            f"spjoin-lint [ast]: {len(violations)} violation(s) across "
            f"{n_files} file(s) in scope ({n_waivers} waiver(s) in use)"
        )
        failed |= bool(violations)

    if args.audit or args.audit_only:
        _ensure_import_paths()
        from spjoin_lint.jaxpr_audit import run_audit

        out = args.contracts_out or os.path.join(root, "runs", "contracts.json")
        baseline = args.baseline or os.path.join(
            root, "tools", "spjoin_lint", "contracts_baseline.json"
        )
        contracts, problems = run_audit(
            out_path=out, baseline_path=baseline,
            write_baseline=args.write_baseline,
        )
        for p in problems:
            print(f"contracts: {p}")
        print(
            f"spjoin-lint [jaxpr]: {len(contracts['entries'])} entry point(s) "
            f"traced, {len(problems)} problem(s); report at {out}"
        )
        failed |= bool(problems)

    return 1 if failed else 0


def _walk_py(path: str):
    from spjoin_lint.astlint import iter_lint_files

    yield from iter_lint_files([path])


if __name__ == "__main__":
    raise SystemExit(main())
