from spjoin_lint.cli import main

raise SystemExit(main())
