"""Rule configuration for the SP-Join contract linter.

Everything repo-specific lives here: which modules are in scope, which
functions are hot (and in which tier), where collectives are blessed, and
the waiver ratchet. The rule implementations in ``rules.py`` are generic;
this file is the policy.

Two-tier hot-scope model (docs/INVARIANTS.md):

  "traced"  the function body runs under ``jax.jit`` / ``shard_map`` /
            ``vmap`` / ``scan`` — a host sync here is a trace error or a
            silent recompile trigger, so ALL host-sync constructs are
            flagged, plus ``int()``/``float()``/``bool()`` on anything that
            is not a static argument.
  "stream"  a host-side streaming driver (the verify engine's tile loop,
            the serving query path). Syncs are its job — but one sync *per
            tile* is the difference between streaming and stalling, so
            sync constructs are flagged only inside ``for``/``while``
            bodies, where they must carry a waiver with a justification.

Traced scopes are mostly DETECTED structurally (functions passed to
``jax.jit`` / ``compat.shard_map`` / ``jax.vmap`` / ``jax.lax.scan`` /
``pl.pallas_call``, plus everything they call in the same module); the
lists below only add what structure cannot see (closures returned by a
factory and invoked through a variable) and the stream tier, which is a
design decision, not a syntactic fact.
"""
from __future__ import annotations

# Rule identifiers (the names used in `# spjoin-lint: allow[...]` waivers).
RULES = (
    "host-sync",  # no host/device sync in hot scopes
    "dispatch-triad",  # ops.py public fns need ref oracle + pallas + dispatch
    "f64-cast",  # no float64 / weak-f64 promotion in kernel paths
    "dyn-control",  # no data-dependent Python control flow under trace
    "collective-site",  # collectives only at blessed sites
    "pallas-confined",  # core/ must not import raw kernel modules
    "waiver-hygiene",  # waivers are justified, known, used, and bounded
)

# Files the linter runs over, as posix-path suffixes.
LINT_ROOTS = ("repro/core/", "repro/kernels/")

# ---------------------------------------------------------------------------
# Hot scopes
# ---------------------------------------------------------------------------

# Host streaming drivers: sync-in-loop is flagged, sync-outside-loop is fine.
# Qualnames are dotted nesting without <locals> ("Class.method", "outer.inner").
STREAM_SCOPES: dict[str, frozenset[str]] = {
    "repro/core/verify.py": frozenset(
        {"verify_cell_lists", "verify_pairs", "prune_band",
         "_flush_window_batch"}
    ),
    "repro/core/index.py": frozenset(
        {
            "MetricIndex.route",
            "MetricIndex.query_batch",
            "MetricIndex.query",
            "MetricIndex.insert_batch",
        }
    ),
    "repro/core/distributed.py": frozenset(
        {"DistIndex.query_batch", "DistIndex.insert_batch"}
    ),
}

# Traced scopes the structural detector cannot see: closures RETURNED by a
# factory and called through a local variable (the dispatch/shuffle closures
# are bound with `v_dispatch = _make_v_dispatch(...)` and invoked as
# `v_dispatch(...)` — no FunctionDef of that name is reachable by name
# resolution from the call site).
EXTRA_TRACED: dict[str, frozenset[str]] = {
    "repro/core/distributed.py": frozenset(
        {
            "_make_v_dispatch.v_dispatch",
            "_make_w_dispatch.w_dispatch",
            "_make_exchange.exchange",
            "_make_exchange.flat",
        }
    ),
}

# Scopes exempt from hot-scope rules entirely. reference_verify is the SEED
# baseline kept verbatim as the benchmark/parity oracle — its dense eager
# loop is the thing the engine exists to replace, not a hot path.
EXEMPT_SCOPES: dict[str, frozenset[str]] = {
    "repro/core/verify.py": frozenset({"reference_verify"}),
}

# ---------------------------------------------------------------------------
# Rule scoping
# ---------------------------------------------------------------------------

# dispatch-triad applies to these modules' PUBLIC functions that take a
# keyword-only `backend` argument.
TRIAD_MODULES = ("repro/kernels/ops.py",)

# f64-cast applies module-wide in kernels/ (everything there feeds a kernel
# path) and inside traced scopes elsewhere. Host-side planners (placement,
# cost_model) legitimately use float64 numpy.
F64_MODULE_WIDE = ("repro/kernels/",)

# pallas-confined: core/ may import only these names from repro.kernels —
# the dispatch layer and the jnp oracle. Raw kernel modules and pallas
# itself are off limits outside kernels/ (layering: core -> ops -> pallas).
BLESSED_KERNEL_IMPORTS = frozenset({"ops", "ref"})
RAW_KERNEL_MODULES = frozenset({"pairdist", "mapassign", "histogram", "compact"})

# collective-site: communication primitives and where each is blessed.
# Sites are (file suffix, top-level qualname) — closures inside the listed
# function are covered. Anything not listed here has NO blessed site.
COLLECTIVE_PRIMS = frozenset(
    {
        "all_to_all",
        "all_gather",
        "psum",
        "psum_scatter",
        "pmean",
        "pmax",
        "pmin",
        "ppermute",
        "pshuffle",
        "pswapaxes",
        "all_to_all_p",
    }
)
BLESSED_COLLECTIVE_SITES: dict[str, frozenset[tuple[str, str]]] = {
    # THE shuffle: one all_to_all per dispatch buffer, built in exactly one
    # factory shared by stage_verify and stage_serve.
    "all_to_all": frozenset({("repro/core/distributed.py", "_make_exchange")}),
    # Parameter-packet / counting gathers of the sampling + planning passes.
    "all_gather": frozenset(
        {
            ("repro/core/distributed.py", "make_stage_stats"),
            ("repro/core/distributed.py", "make_stage_counts"),
        }
    ),
}

# Host-sync construct lists shared by both tiers.
SYNC_NP_FUNCS = frozenset({"asarray", "array"})  # np.asarray / np.array
SYNC_METHODS = frozenset({"item", "block_until_ready"})
SYNC_JAX_FUNCS = frozenset({"device_get"})

# ---------------------------------------------------------------------------
# Waiver ratchet
# ---------------------------------------------------------------------------

# Maximum number of `# spjoin-lint: allow[...]` waivers across the linted
# tree. This is a RATCHET: it equals the number of waivers shipped today, so
# adding a waiver without removing one fails the build and forces the
# conversation. Lower it when waivers are removed; never raise it casually.
MAX_WAIVERS = 5

# Minimum justification length (characters after `--`) for a waiver.
MIN_JUSTIFICATION = 10
