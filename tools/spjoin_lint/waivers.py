"""Waiver parsing for the SP-Join contract linter.

A waiver suppresses one (or more) rules on one line of code:

    x = np.asarray(v)  # spjoin-lint: allow[host-sync] -- one-off per cell, not per tile

or, as a standalone comment, it applies to the next code line:

    # spjoin-lint: allow[host-sync] -- one-off per cell, not per tile
    x = np.asarray(v)

The `-- justification` part is mandatory (enforced by the waiver-hygiene
rule), as is naming a real rule and actually suppressing something; the
total waiver count across the tree is capped by ``config.MAX_WAIVERS``.
"""
from __future__ import annotations

import dataclasses
import re

WAIVER_RE = re.compile(
    r"#\s*spjoin-lint:\s*allow\[([A-Za-z0-9_,\- ]+)\]\s*(?:--\s*(.*\S))?\s*$"
)


@dataclasses.dataclass
class Waiver:
    file: str
    line: int  # line the waiver comment sits on
    target_line: int  # line of code the waiver applies to
    rules: tuple[str, ...]
    justification: str
    used: bool = False


def parse_waivers(source: str, filename: str) -> list[Waiver]:
    """Extract every waiver in ``source``; standalone comment lines bind to
    the next non-blank, non-comment line."""
    lines = source.splitlines()
    out: list[Waiver] = []
    for i, text in enumerate(lines, start=1):
        m = WAIVER_RE.search(text)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        just = (m.group(2) or "").strip()
        target = i
        if text.lstrip().startswith("#"):  # standalone comment: next code line
            j = i  # 0-based index of the following line
            while j < len(lines):
                nxt = lines[j].strip()
                if nxt and not nxt.startswith("#"):
                    target = j + 1
                    break
                j += 1
        out.append(
            Waiver(
                file=filename, line=i, target_line=target,
                rules=rules, justification=just,
            )
        )
    return out


def waivers_by_target(waivers: list[Waiver]) -> dict[int, list[Waiver]]:
    by_line: dict[int, list[Waiver]] = {}
    for w in waivers:
        by_line.setdefault(w.target_line, []).append(w)
    return by_line
