"""Scope machinery + driver of the AST layer.

The interesting part is hot-scope detection. Rather than hand-listing every
jitted function (which rots on the first refactor), the linter finds traced
scopes STRUCTURALLY:

  * decorated: ``@jax.jit`` / ``@functools.partial(jax.jit, ...)``
  * passed to a tracer: ``jax.jit(f, ...)``, ``compat.shard_map(f, ...)``,
    ``jax.vmap(f)``, ``jax.lax.scan(f, ...)``, ``jax.lax.switch(i, [f, g])``,
    ``pl.pallas_call(f, ...)`` — including module-level aliases like
    ``_tile_verify = jax.jit(verify_tile, static_argnames=...)``
  * nested inside a traced function (closures trace with their parent)
  * CALLED from a traced function in the same module (intra-module call
    graph, iterated to a fixpoint) — helpers like ``apply_dedup`` or
    ``_map_assign`` are traced because their callers are.

``static_argnames`` are read off the jit call/decorator so that
``float(delta)`` on a static argument is not a sync. What structure cannot
see (factory-returned closures invoked through a variable, and the "stream"
tier, which is a design decision) comes from ``config.EXTRA_TRACED`` /
``config.STREAM_SCOPES``.
"""
from __future__ import annotations

import ast
import dataclasses
import pathlib

from spjoin_lint import config


@dataclasses.dataclass
class Violation:
    file: str
    line: int
    rule: str
    message: str
    waived: bool = False

    def format(self) -> str:
        tag = " (waived)" if self.waived else ""
        return f"{self.file}:{self.line}: [{self.rule}] {self.message}{tag}"


@dataclasses.dataclass
class FuncInfo:
    node: ast.AST  # FunctionDef | AsyncFunctionDef
    qualname: str
    parent: "FuncInfo | None"
    tier: str | None = None  # "traced" | "stream" | None
    exempt: bool = False
    static_args: frozenset = frozenset()
    children: dict = dataclasses.field(default_factory=dict)  # name -> FuncInfo


def _root_name(node: ast.AST) -> str | None:
    """Leftmost Name of a dotted attribute chain (``jax.lax.scan`` -> jax)."""
    while isinstance(node, ast.Attribute):
        node = node.value
    return node.id if isinstance(node, ast.Name) else None


def _attr_tail(node: ast.AST) -> str | None:
    return node.attr if isinstance(node, ast.Attribute) else (
        node.id if isinstance(node, ast.Name) else None
    )


def _static_argnames_from_call(call: ast.Call) -> frozenset:
    for kw in call.keywords:
        if kw.arg == "static_argnames":
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return frozenset({v.value})
            if isinstance(v, (ast.Tuple, ast.List)):
                return frozenset(
                    e.value
                    for e in v.elts
                    if isinstance(e, ast.Constant) and isinstance(e.value, str)
                )
    return frozenset()


def _is_jit_expr(node: ast.AST) -> bool:
    """``jax.jit`` or bare ``jit`` as an expression."""
    return _attr_tail(node) == "jit"


# Call-taking tracer APIs: attr name -> index/extractor of the traced callee.
_TRACER_FIRST_ARG = {"shard_map", "jit", "vmap", "pmap", "scan", "pallas_call",
                     "checkpoint", "remat", "custom_vjp", "grad", "value_and_grad"}


class ModuleIndex:
    """Per-file scope index: functions, tiers, static argnames."""

    def __init__(self, tree: ast.Module, relpath: str):
        self.tree = tree
        self.relpath = relpath
        self.functions: dict[str, FuncInfo] = {}
        self._by_node: dict[int, FuncInfo] = {}
        self.module_scope: dict[str, FuncInfo] = {}
        self._build(tree)
        self._detect_seeds(tree)
        self._apply_config()
        self._propagate_calls()
        self._apply_config()  # config tiers win over propagation

    # -- construction ------------------------------------------------------

    def _build(self, tree: ast.Module) -> None:
        def visit(node: ast.AST, parent: FuncInfo | None, prefix: str) -> None:
            for child in ast.iter_child_nodes(node):
                if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    qual = f"{prefix}{child.name}"
                    fi = FuncInfo(node=child, qualname=qual, parent=parent)
                    self.functions[qual] = fi
                    self._by_node[id(child)] = fi
                    if parent is None:
                        self.module_scope[child.name] = fi
                    else:
                        parent.children[child.name] = fi
                    visit(child, fi, qual + ".")
                elif isinstance(child, ast.ClassDef):
                    visit(child, parent, f"{prefix}{child.name}.")
                else:
                    visit(child, parent, prefix)

        visit(tree, None, "")

    def func_of(self, node: ast.AST) -> FuncInfo | None:
        return self._by_node.get(id(node))

    # -- seed detection ----------------------------------------------------

    def _mark_traced(self, fi: FuncInfo, statics: frozenset = frozenset()) -> None:
        stack = [fi]
        while stack:
            f = stack.pop()
            if f.tier is None:
                f.tier = "traced"
            stack.extend(f.children.values())
        if statics:
            fi.static_args = fi.static_args | statics

    def _resolve(self, name: str, scope: FuncInfo | None) -> FuncInfo | None:
        """Resolve a bare function name from a scope, innermost first."""
        s = scope
        while s is not None:
            if name in s.children:
                return s.children[name]
            s = s.parent
        return self.module_scope.get(name)

    def _detect_seeds(self, tree: ast.Module) -> None:
        # Decorators.
        for fi in self.functions.values():
            for dec in getattr(fi.node, "decorator_list", []):
                if _is_jit_expr(dec):
                    self._mark_traced(fi)
                elif isinstance(dec, ast.Call):
                    if _is_jit_expr(dec.func):
                        self._mark_traced(fi, _static_argnames_from_call(dec))
                    elif (
                        _attr_tail(dec.func) == "partial"
                        and dec.args
                        and _is_jit_expr(dec.args[0])
                    ):
                        self._mark_traced(fi, _static_argnames_from_call(dec))

        # Call sites: jax.jit(f, ...), shard_map(f, ...), vmap/scan/switch...
        scope_stack: list[FuncInfo] = []

        index = self

        class SeedVisitor(ast.NodeVisitor):
            def visit_FunctionDef(self, node):  # noqa: N802
                scope_stack.append(index._by_node[id(node)])
                self.generic_visit(node)
                scope_stack.pop()

            visit_AsyncFunctionDef = visit_FunctionDef  # noqa: N815

            def visit_Call(self, node):  # noqa: N802
                tail = _attr_tail(node.func)
                scope = scope_stack[-1] if scope_stack else None
                if tail in _TRACER_FIRST_ARG and node.args:
                    arg = node.args[0]
                    if isinstance(arg, ast.Name):
                        fi = index._resolve(arg.id, scope)
                        if fi is not None:
                            statics = (
                                _static_argnames_from_call(node)
                                if tail == "jit"
                                else frozenset()
                            )
                            index._mark_traced(fi, statics)
                elif tail == "switch" and len(node.args) >= 2:
                    branches = node.args[1]
                    if isinstance(branches, (ast.List, ast.Tuple)):
                        for e in branches.elts:
                            if isinstance(e, ast.Name):
                                fi = index._resolve(e.id, scope)
                                if fi is not None:
                                    index._mark_traced(fi)
                self.generic_visit(node)

        SeedVisitor().visit(tree)

    def _propagate_calls(self) -> None:
        """Callees of traced functions (same module, bare-name calls) trace
        with their caller. Iterated to a fixpoint."""
        changed = True
        while changed:
            changed = False
            for fi in list(self.functions.values()):
                if fi.tier != "traced":
                    continue
                for node in scope_walk(fi.node):
                    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name):
                        callee = self._resolve(node.func.id, fi)
                        if callee is not None and callee.tier is None:
                            self._mark_traced(callee)
                            changed = True

    def _apply_config(self) -> None:
        rel = self.relpath
        for suffix, quals in config.STREAM_SCOPES.items():
            if rel.endswith(suffix):
                for q in quals:
                    if q in self.functions:
                        self.functions[q].tier = "stream"
        for suffix, quals in config.EXTRA_TRACED.items():
            if rel.endswith(suffix):
                for q in quals:
                    if q in self.functions:
                        self._mark_traced(self.functions[q])
        for suffix, quals in config.EXEMPT_SCOPES.items():
            if rel.endswith(suffix):
                for q in quals:
                    if q in self.functions:
                        self.functions[q].tier = None
                        self.functions[q].exempt = True

    def top_level_name(self, fi: FuncInfo) -> str:
        return fi.qualname.split(".")[0]


def scope_walk(func_node: ast.AST):
    """Walk a function body WITHOUT descending into nested function defs
    (each scope is checked once, under its own tier)."""
    stack = list(ast.iter_child_nodes(func_node))
    while stack:
        node = stack.pop()
        yield node
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            stack.extend(ast.iter_child_nodes(node))


def iter_lint_files(paths: list[str]) -> list[pathlib.Path]:
    """Expand CLI paths to the .py files in scope (config.LINT_ROOTS)."""
    out: list[pathlib.Path] = []
    for p in paths:
        path = pathlib.Path(p)
        if path.is_file():
            out.append(path)
            continue
        for f in sorted(path.rglob("*.py")):
            rel = f.as_posix()
            if any(root in rel for root in config.LINT_ROOTS):
                out.append(f)
    return out


def lint_file(path: pathlib.Path, max_waivers: int | None = None) -> list[Violation]:
    """Lint one file: run every rule, apply waivers, check waiver hygiene.

    ``max_waivers=None`` skips the global-ratchet check (it is cross-file;
    ``lint_paths`` applies it once over the whole run).
    """
    from spjoin_lint import rules as rules_mod
    from spjoin_lint import waivers as waivers_mod

    source = path.read_text()
    tree = ast.parse(source, filename=str(path))
    relpath = path.as_posix()
    idx = ModuleIndex(tree, relpath)

    violations: list[Violation] = []
    for rule in rules_mod.ALL_RULES:
        violations.extend(rule(idx))

    wvs = waivers_mod.parse_waivers(source, relpath)
    by_line = waivers_mod.waivers_by_target(wvs)
    for v in violations:
        for w in by_line.get(v.line, []):
            if v.rule in w.rules:
                v.waived = True
                w.used = True

    # waiver-hygiene: justified, known rule, actually used.
    for w in wvs:
        unknown = [r for r in w.rules if r not in config.RULES]
        if unknown:
            violations.append(
                Violation(
                    relpath, w.line, "waiver-hygiene",
                    f"waiver names unknown rule(s) {unknown}; known rules: "
                    f"{list(config.RULES)}",
                )
            )
        if len(w.justification) < config.MIN_JUSTIFICATION:
            violations.append(
                Violation(
                    relpath, w.line, "waiver-hygiene",
                    "waiver has no (or a trivial) justification — write "
                    "`# spjoin-lint: allow[rule] -- why this sync/cast is "
                    "sound here`",
                )
            )
        if not w.used:
            violations.append(
                Violation(
                    relpath, w.line, "waiver-hygiene",
                    "unused waiver (suppresses nothing on its target line) — "
                    "remove it and lower config.MAX_WAIVERS",
                )
            )
    violations = [v for v in violations if not v.waived]
    violations.sort(key=lambda v: (v.line, v.rule))
    return violations


def lint_paths(paths: list[str]) -> tuple[list[Violation], int]:
    """Lint every in-scope file under ``paths``.

    Returns (violations, n_waivers). The waiver-count ratchet
    (``config.MAX_WAIVERS``) is applied across the whole run; exceeding it
    appends one waiver-hygiene violation.
    """
    from spjoin_lint import waivers as waivers_mod

    violations: list[Violation] = []
    n_waivers = 0
    files = iter_lint_files(paths)
    for f in files:
        violations.extend(lint_file(f))
        n_waivers += len(waivers_mod.parse_waivers(f.read_text(), f.as_posix()))
    if n_waivers > config.MAX_WAIVERS:
        violations.append(
            Violation(
                paths[0] if paths else ".", 0, "waiver-hygiene",
                f"{n_waivers} waivers in tree exceed the ratchet "
                f"(MAX_WAIVERS={config.MAX_WAIVERS}). The ratchet only moves "
                f"down: fix the new violation for real, or make the case for "
                f"raising it in review",
            )
        )
    return violations, n_waivers
