"""Layer 2: the jaxpr trace auditor.

The AST layer proves things about *source text*; this layer proves things
about what XLA will actually be asked to run. Every jitted public entry
point is traced with abstract shapes (``jax.make_jaxpr`` — no compilation,
no execution) and the resulting jaxpr is walked recursively (through
``pjit`` / ``shard_map`` / ``scan`` / ``cond`` inner jaxprs) to assert:

  (a) **no f64**: zero ``convert_element_type`` equations with a float64
      target anywhere in the trace — the kernel paths are f32 end to end.
  (b) **collective budget**: each distributed stage contains EXACTLY its
      contracted communication. One logical shuffle per stage = one
      ``all_to_all`` per dispatch buffer: the verify stage moves
      (payload, ids, own-cell) per side -> 6 primitives; serving moves the
      W side only -> 3; the stats/counts stages gather 3/4 packets. Any
      other collective primitive anywhere is a violation.
  (c) **static shapes**: every output aval has concrete integer dims — the
      capacity-bucket contract (no data-dependent output shapes survive a
      trace; a function that *can't* be traced abstractly, e.g. boolean
      masking `x[x > 0]`, is rejected with the trace error).
  (d) **recompile budget**: the verify engine's bucket quantizer
      (``verify.bucket_size``) bounds the distinct tile shapes — and hence
      XLA compilations — per entry point. The family size is computed
      exactly over every possible tile size and checked against a budget;
      a handful of family members are traced live to pin the out-shape =
      (cap_v, cap_w) law.

Results are emitted as ``runs/contracts.json`` and diffed against
``tools/spjoin_lint/contracts_baseline.json`` in CI, so a new collective,
an f64 cast, or a bucket-family blowup fails the build before any test runs.
"""
from __future__ import annotations

import collections
import json
import os
import pathlib

# Budgets for assertion (d). bucket_size is quarter-pow2: <= 4 shapes per
# octave + the floor sizes, so the family grows with log(cap), not cap.
RECOMPILE_BUDGET = {"v_buckets": 16, "w_buckets": 24}

# Contracted collective counts per entry point; entries not listed contract
# to ZERO collectives. Exactness matters both ways: fewer means the stage
# stopped communicating (broken), more means a second shuffle snuck in.
EXPECTED_COLLECTIVES = {
    "stage_stats": {"all_gather": 3},  # packet, confidence, count
    "stage_counts": {"all_gather": 4},  # v_cnt, w_cnt, mbb lo, mbb hi
    "stage_verify": {"all_to_all": 6},  # (payload, ids, own) x (V, W)
    "stage_verify_cross": {"all_to_all": 6},  # same buffers, R and S sides
    "stage_serve": {"all_to_all": 3},  # W side only: V buffers are pinned
}


# ---------------------------------------------------------------------------
# Jaxpr walking (duck-typed: works across jax versions without private deps)
# ---------------------------------------------------------------------------


def _inner_jaxprs(value):
    """Yield any jaxpr-like objects inside an eqn param value."""
    if hasattr(value, "eqns"):  # Jaxpr
        yield value
    elif hasattr(value, "jaxpr") and hasattr(getattr(value, "jaxpr"), "eqns"):
        yield value.jaxpr  # ClosedJaxpr
    elif isinstance(value, (tuple, list)):
        for v in value:
            yield from _inner_jaxprs(v)


def walk_eqns(jaxpr):
    """Every equation in ``jaxpr`` and any jaxpr nested in its params."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for inner in _inner_jaxprs(v):
                yield from walk_eqns(inner)


def collect_primitives(closed_jaxpr) -> collections.Counter:
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    return collections.Counter(e.primitive.name for e in walk_eqns(jaxpr))


def count_f64_casts(closed_jaxpr) -> int:
    jaxpr = getattr(closed_jaxpr, "jaxpr", closed_jaxpr)
    n = 0
    for eqn in walk_eqns(jaxpr):
        if eqn.primitive.name == "convert_element_type":
            if str(eqn.params.get("new_dtype", "")) in ("float64", "f64"):
                n += 1
    return n


def collective_counts(closed_jaxpr) -> dict:
    from spjoin_lint import config

    prims = collect_primitives(closed_jaxpr)
    return {k: v for k, v in prims.items() if k in config.COLLECTIVE_PRIMS}


# ---------------------------------------------------------------------------
# Entry tracing
# ---------------------------------------------------------------------------


def trace_entry(name: str, fn, args, *, static_argnames=()) -> dict:
    """Trace ``fn(*args)`` abstractly and report its contract surface.

    Never raises: a function that cannot be traced with abstract shapes
    (data-dependent output shape, host sync on a tracer) is *rejected* —
    the failure lands in ``entry["errors"]`` and fails the audit.
    """
    import jax

    entry = {
        "name": name,
        "collectives": {},
        "f64_casts": 0,
        "out_shapes": [],
        "out_dtypes": [],
        "errors": [],
    }
    try:
        jaxpr = jax.make_jaxpr(fn, static_argnums=())(*args) if not static_argnames \
            else jax.make_jaxpr(fn, static_argnames=static_argnames)(*args)
    except TypeError:
        # static handling differences across jax versions: fall back to a
        # closure with statics already bound.
        try:
            jaxpr = jax.make_jaxpr(fn)(*args)
        except Exception as e:  # noqa: BLE001 - any trace failure is the finding
            entry["errors"].append(f"untraceable with abstract shapes: {type(e).__name__}: {e}")
            return entry
    except Exception as e:  # noqa: BLE001 - any trace failure is the finding
        entry["errors"].append(f"untraceable with abstract shapes: {type(e).__name__}: {e}")
        return entry

    entry["collectives"] = collective_counts(jaxpr)
    entry["f64_casts"] = count_f64_casts(jaxpr)
    for aval in jaxpr.out_avals:
        shape = getattr(aval, "shape", None)
        if shape is None or not all(isinstance(d, int) for d in shape):
            entry["errors"].append(f"non-static output shape: {aval}")
        else:
            entry["out_shapes"].append(list(shape))
            entry["out_dtypes"].append(str(getattr(aval, "dtype", "?")))
    return entry


def bucket_family(bucket_fn, cap: int, floor: int = 8) -> list[int]:
    """Exact set of bucket capacities ``bucket_fn`` can emit for 1..cap."""
    return sorted({int(bucket_fn(n, cap, floor)) for n in range(1, cap + 1)})


def audit_bucket_family(bucket_fn, cap_v: int, cap_w: int, budget=None) -> dict:
    """Assertion (d): the quantized tile family — the compile-cache keyspace
    — stays within budget. Returns the report dict (errors inside)."""
    budget = dict(RECOMPILE_BUDGET if budget is None else budget)
    fam_v = bucket_family(bucket_fn, cap_v)
    fam_w = bucket_family(bucket_fn, cap_w)
    rep = {
        "cap_v": cap_v,
        "cap_w": cap_w,
        "v_buckets": len(fam_v),
        "w_buckets": len(fam_w),
        "max_traces": len(fam_v) * len(fam_w),
        "budget": budget,
        "errors": [],
    }
    if len(fam_v) > budget["v_buckets"]:
        rep["errors"].append(
            f"V bucket family has {len(fam_v)} shapes for cap {cap_v} "
            f"(budget {budget['v_buckets']}) — every extra shape is an XLA "
            f"recompile"
        )
    if len(fam_w) > budget["w_buckets"]:
        rep["errors"].append(
            f"W bucket family has {len(fam_w)} shapes for cap {cap_w} "
            f"(budget {budget['w_buckets']})"
        )
    return rep


# ---------------------------------------------------------------------------
# The repo's entry points
# ---------------------------------------------------------------------------


def _synthetic_plan(p: int = 4, n: int = 4, m: int = 4, delta: float = 1.0):
    """A tiny JoinPlan with the right shapes; trace structure does not
    depend on the box values."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import distributed as dist

    edges = np.linspace(-2.0, 2.0, p + 1, dtype=np.float32)
    big = np.float32(1e9)
    klo = np.full((p, n), -big, np.float32)
    khi = np.full((p, n), big, np.float32)
    klo[:, 0] = edges[:-1]
    khi[:, 0] = edges[1:]
    return dist.JoinPlan(
        anchors=jnp.zeros((n, m), jnp.float32),
        metric="l1",
        kernel_lo=jnp.asarray(klo),
        kernel_hi=jnp.asarray(khi),
        whole_lo=jnp.asarray(klo - delta),
        whole_hi=jnp.asarray(khi + delta),
        delta=delta,
        p=p,
    )


def repo_entries() -> list[dict]:
    """Trace every jitted public entry point with tiny abstract shapes."""
    import functools

    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import Mesh

    from repro.core import distributed as dist
    from repro.core import placement as placement_lib
    from repro.core import verify as verify_lib
    from repro.kernels import ops as kops

    f32 = jnp.float32
    entries: list[dict] = []

    # ---- kernel dispatch ops (numpy backend: the CI-stable trace) --------
    x = jnp.zeros((8, 4), f32)
    y = jnp.zeros((6, 4), f32)
    entries.append(trace_entry(
        "ops.pairdist",
        functools.partial(kops.pairdist, metric="l2", backend="numpy"), (x, y),
    ))
    entries.append(trace_entry(
        "ops.pairdist_mask",
        functools.partial(kops.pairdist_mask, delta=1.0, metric="l2", backend="numpy"),
        (x, y),
    ))
    entries.append(trace_entry(
        "ops.pairdist_mask_filtered",
        functools.partial(
            kops.pairdist_mask_filtered, delta=1.0, metric="l2",
            delta_bound=1.01, backend="numpy",
        ),
        (x, y, jnp.zeros((8, 4), f32), jnp.zeros((6, 4), f32)),
    ))
    boxes = tuple(jnp.zeros((4, 4), f32) for _ in range(4))
    entries.append(trace_entry(
        "ops.map_assign",
        functools.partial(kops.map_assign, metric="l1", backend="numpy"),
        (x, jnp.zeros((4, 4), f32)) + boxes,
    ))
    entries.append(trace_entry(
        "ops.assign_membership",
        functools.partial(kops.assign_membership, backend="numpy"),
        (jnp.zeros((8, 4), f32),) + boxes,
    ))

    # ---- the verify engine's tile kernel over the bucket family ----------
    def tile(cv, cw):
        def f(xv, xw, vids, wids, wcells):
            return verify_lib.verify_tile(
                xv, xw, vids, wids, wcells, 0,
                delta=1.0, metric="l1", backend="numpy", prune="none",
            )
        args = (
            jnp.zeros((cv, 4), f32), jnp.zeros((cw, 4), f32),
            jnp.zeros((cv,), jnp.int32), jnp.zeros((cw,), jnp.int32),
            jnp.zeros((cw,), jnp.int32),
        )
        return trace_entry(f"verify.verify_tile[{cv}x{cw}]", f, args)

    fam_v = bucket_family(verify_lib.bucket_size, 1024)
    fam_w = bucket_family(verify_lib.bucket_size, 4096)
    # Trace a spread of family members live to pin out_shape == (cap_v, cap_w).
    for cv, cw in [(fam_v[0], fam_w[0]), (fam_v[len(fam_v) // 2], fam_w[len(fam_w) // 2]),
                   (fam_v[-1], fam_w[-1])]:
        e = tile(cv, cw)
        if not e["errors"] and e["out_shapes"] != [[cv, cw]]:
            e["errors"].append(
                f"verify_tile({cv},{cw}) output shape {e['out_shapes']} is "
                f"not the bucket capacity [[{cv}, {cw}]]"
            )
        entries.append(e)

    # ---- the fused reduce tile: verify + on-device pair compaction -------
    # Contract: the compacted pair buffer's out-shape IS the capacity
    # bucket (static — assertion (c) rejects anything data-dependent),
    # zero f64 casts, zero collectives (compacted pairs ride the existing
    # exchange; the kernel itself never communicates).
    entries.append(trace_entry(
        "ops.verify_compact",
        functools.partial(
            kops.verify_compact, delta=1.0, metric="l1", capacity=16,
            cross=True, backend="numpy",
        ),
        (x, y, jnp.zeros((8,), jnp.int32), jnp.zeros((6,), jnp.int32),
         jnp.zeros((6,), jnp.int32), jnp.zeros((), jnp.int32)),
    ))
    e = entries[-1]
    if not e["errors"] and e["out_shapes"] != [[16, 2], [], []]:
        e["errors"].append(
            f"ops.verify_compact out shapes {e['out_shapes']} are not the "
            f"capacity-bucket contract [[16, 2], [], []] "
            f"(pairs buffer, count, n_cand)"
        )

    def ctile(cv, cw, cap):
        def f(xv, xw, vids, wids, wcells):
            return verify_lib.verify_tile_compact(
                xv, xw, vids, wids, wcells, 0,
                delta=1.0, metric="l1", backend="numpy", capacity=cap,
            )
        args = (
            jnp.zeros((cv, 4), f32), jnp.zeros((cw, 4), f32),
            jnp.zeros((cv,), jnp.int32), jnp.zeros((cw,), jnp.int32),
            jnp.zeros((cw,), jnp.int32),
        )
        return trace_entry(f"verify.verify_tile_compact[{cv}x{cw}x{cap}]", f, args)

    # Pair capacities ride the same quarter-pow2 ladder as the tile sides;
    # the engine tile's out-shape is (capacity + 1, 2) — buffer plus the
    # in-band [count, n_cand] row.
    for cv, cw, cap in [(fam_v[0], fam_w[0], 16), (fam_v[-1], fam_w[-1], 256)]:
        e = ctile(cv, cw, cap)
        if not e["errors"] and e["out_shapes"] != [[cap + 1, 2]]:
            e["errors"].append(
                f"verify_tile_compact({cv},{cw},{cap}) output shape "
                f"{e['out_shapes']} is not the capacity bucket "
                f"[[{cap + 1}, 2]]"
            )
        entries.append(e)

    # ---- the distributed stages (1-device mesh; jaxpr structure is what
    # we pin — the collective eqns are present regardless of mesh size) ----
    mesh = Mesh(np.array(jax.devices("cpu")[:1]), ("data",))
    plan = _synthetic_plan()
    N, m, p = 8, 4, 4
    data = jnp.zeros((N, m), f32)
    valid = jnp.ones((N,), f32)
    ids = jnp.arange(N, dtype=jnp.int32)

    stats_fn = dist.make_stage_stats(mesh, "data", t_cells=4, backend="numpy")
    entries.append(trace_entry("stage_stats", stats_fn, (data, valid)))

    counts_fn = dist.make_stage_counts(mesh, "data", plan, backend="numpy")
    entries.append(trace_entry("stage_counts", counts_fn, (data, valid)))

    vcfg = dist.VerifyConfig(
        cap_v=8, cap_w=8, backend="numpy", prune="pivot", delta_bound=1.01
    )
    verify_fn = dist.make_stage_verify(mesh, "data", plan, vcfg)
    entries.append(trace_entry("stage_verify", verify_fn, (data, valid, ids)))

    verify_x = dist.make_stage_verify(mesh, "data", plan, vcfg, cross=True)
    entries.append(trace_entry(
        "stage_verify_cross", verify_x, (data, valid, ids, data, valid, ids)
    ))

    pl = placement_lib.plan_placement(np.zeros(p, np.float64), 1, strategy="contiguous")
    serve_fn = dist.make_stage_serve(
        mesh, "data", plan, pl, cap_w=8, backend="numpy", prune="pivot",
        delta_bound=1.01,
    )
    fv = jnp.zeros((pl.n_slots, 8, m + plan.anchors.shape[0]), f32)
    fvi = jnp.zeros((pl.n_slots, 8), jnp.int32)
    entries.append(trace_entry("stage_serve", serve_fn, (fv, fvi, data, valid, ids)))

    # ---- the incremental cross path (ISSUE-8): ΔR×R_old in
    # ``DistIndex.insert_batch`` rides the SAME serve stage — the delta is
    # the W batch, the resident V buffers stay pinned. Traced with a
    # delta-sized batch so the contract (3 all_to_all, W side only, zero
    # V-side bytes per insert) is pinned for the streaming entry point too;
    # the [suffix] lookup maps it onto stage_serve's contracted counts.
    d_rows = jnp.zeros((4, m), f32)
    entries.append(trace_entry(
        "stage_serve[incremental]", serve_fn,
        (fv, fvi, d_rows, jnp.ones((4,), f32), jnp.arange(4, dtype=jnp.int32)),
    ))

    return entries


# ---------------------------------------------------------------------------
# Contract assembly, assertion, baseline diff
# ---------------------------------------------------------------------------


def build_contracts() -> dict:
    import jax

    from repro.core import verify as verify_lib

    entries = repo_entries()
    recompile = audit_bucket_family(verify_lib.bucket_size, 1024, 4096)
    violations: list[str] = []

    for e in entries:
        for err in e["errors"]:
            violations.append(f"{e['name']}: {err}")
        if e["f64_casts"]:
            violations.append(
                f"{e['name']}: {e['f64_casts']} convert_element_type -> "
                f"float64 equation(s) in the trace"
            )
        base = e["name"].split("[")[0]
        expected = EXPECTED_COLLECTIVES.get(e["name"], EXPECTED_COLLECTIVES.get(base, {}))
        if e["collectives"] != expected:
            violations.append(
                f"{e['name']}: collective contract violated — traced "
                f"{e['collectives'] or '{}'}, contracted {expected or '{}'}"
            )
    violations.extend(f"bucket-family: {err}" for err in recompile["errors"])

    return {
        "version": 1,
        "jax": jax.__version__,
        "entries": {e["name"]: {k: v for k, v in e.items() if k != "name"} for e in entries},
        "recompile": recompile,
        "violations": violations,
    }


def diff_against_baseline(contracts: dict, baseline_path: str) -> list[str]:
    """CI regression gate: collectives and bucket counts may not GROW past
    the committed baseline (improvements are fine and prompt a re-baseline)."""
    if not os.path.exists(baseline_path):
        return [
            f"no baseline at {baseline_path} — run `python -m spjoin_lint "
            f"--audit --write-baseline` and commit it"
        ]
    with open(baseline_path) as f:
        base = json.load(f)
    problems: list[str] = []
    for name, entry in contracts["entries"].items():
        b = base.get("entries", {}).get(name)
        if b is None:
            problems.append(
                f"{name}: new entry point not in baseline — re-baseline "
                f"deliberately with --write-baseline"
            )
            continue
        for prim, n in entry["collectives"].items():
            if n > b["collectives"].get(prim, 0):
                problems.append(
                    f"{name}: {prim} count grew {b['collectives'].get(prim, 0)} "
                    f"-> {n} vs baseline"
                )
        if entry["f64_casts"] > b.get("f64_casts", 0):
            problems.append(f"{name}: f64 casts grew vs baseline")
    rec, brec = contracts["recompile"], base.get("recompile", {})
    for k in ("v_buckets", "w_buckets"):
        if rec[k] > brec.get(k, rec[k]):
            problems.append(
                f"recompile regression: {k} grew {brec.get(k)} -> {rec[k]} — "
                f"the bucket quantizer got finer; every extra shape is an "
                f"XLA compile"
            )
    return problems


def run_audit(
    out_path: str = "runs/contracts.json",
    baseline_path: str = "tools/spjoin_lint/contracts_baseline.json",
    write_baseline: bool = False,
) -> tuple[dict, list[str]]:
    """Build contracts, write the artifact, and return (contracts, problems)."""
    contracts = build_contracts()
    problems = list(contracts["violations"])
    pathlib.Path(out_path).parent.mkdir(parents=True, exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(contracts, f, indent=2, sort_keys=True)
    if write_baseline:
        # Baseline stores the diffable surface only (no jax-version-specific
        # noise beyond what we pin).
        with open(baseline_path, "w") as f:
            json.dump(contracts, f, indent=2, sort_keys=True)
    else:
        problems.extend(diff_against_baseline(contracts, baseline_path))
    return contracts, problems
