"""Distributed SP-Join on a simulated 8-device mesh — the production path:
per-node stats, parameter broadcast, replicated Gibbs, capacity-bounded
all_to_all dispatch, Pallas-blocked verification.

    PYTHONPATH=src python examples/distributed_join.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import distributed, spjoin
from repro.data import synthetic

mesh = jax.make_mesh((8,), ("data",))
print(f"mesh: {dict(mesh.shape)} over {len(jax.devices())} devices")

data = synthetic.mixture(n=4000, m=12, n_clusters=6, skew=0.4, seed=0)

res = distributed.distributed_join(
    jnp.asarray(data), mesh=mesh, delta=6.0, metric="l1",
    k=384, p=16, n_dims=6, sampler="generative", emit_pairs=True, seed=0,
)
print(f"pairs found:        {res.pairs.shape[0]}")
print(f"verifications:      {res.n_verifications}")
print(f"dispatch overflow:  {res.overflow} (exact-fit capacity planning)")
print(f"capacity padding:   {res.capacity_padding:.2f}x "
      "(the TPU-native skew metric — lower = better pivots)")
print(f"node confidences:   {res.node_confidences.round(3)}")
print(f"gibbs accept rate:  {res.accept_rate:.2f}")

truth = spjoin.brute_force_pairs(data, 6.0, "l1")
assert np.array_equal(res.pairs, truth)
print("exactness check vs brute force: OK")

# ---- two-set R×S join: small probe set R against a large corpus S ----------
r, s = synthetic.rs_mixture(n_r=400, n_s=3000, m=12, n_clusters=6,
                            skew=0.4, shift=3.0, seed=1)
res_rs = distributed.distributed_join(
    jnp.asarray(r), s=jnp.asarray(s), mesh=mesh, delta=6.0, metric="l1",
    k=384, p=16, n_dims=6, sampler="generative", emit_pairs=True, seed=0,
)
print(f"\nR×S join |R|={r.shape[0]} x |S|={s.shape[0]}")
print(f"cross pairs found:  {res_rs.pairs.shape[0]} (i ∈ R, j ∈ S)")
print(f"verifications:      {res_rs.n_verifications}")
print(f"S-side duplication: {res_rs.duplication:.2f}x (Σ|W_h| / |S|)")

truth_rs = spjoin.brute_force_pairs(r, 6.0, "l1", s=s)
assert np.array_equal(res_rs.pairs, truth_rs)
print("R×S exactness check vs brute force: OK")
