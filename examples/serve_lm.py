"""Batched serving demo: prefill + streaming decode with KV/SSM state.

    PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b

(Equivalent to: python -m repro.launch.serve --arch <a> --reduced ...)
"""
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or ["--arch", "qwen1.5-0.5b"]) + [
    "--reduced", "--batch", "4", "--prompt-len", "16", "--gen", "24",
]
from repro.launch.serve import main

main()
