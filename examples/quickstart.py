"""Quickstart: metric similarity self-join with SP-Join in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import spjoin
from repro.data import synthetic

# 1. Some clustered vector data (3k objects, 16 dims).
data = synthetic.mixture(n=3000, m=16, n_clusters=8, spread=6.0, seed=0)

# 2. Configure the join: L2 distance, threshold delta, generative sampling
#    (Alg. 3/4) + learning-based partitioning (Alg. 6) — the paper's best arm.
cfg = spjoin.JoinConfig(
    delta=3.0, metric="l2",
    sampler="generative", partitioner="learning",
    k=512,        # pivots (cf. sampling.required_sample_size for the bound)
    p=16,         # partitions / reducers
    n_dims=8,     # target-space dimensionality
)

# 3. Join.
result = spjoin.join(data, cfg)
print(f"objects:        {len(data)}")
print(f"similar pairs:  {result.n_pairs}")
print(f"verifications:  {result.n_verifications} "
      f"({result.n_verifications / (len(data)**2):.1%} of brute force)")
print(f"node confidences: {result.node_confidences.round(3)}")
print(f"phase times: sample {result.sample_time_s:.2f}s | "
      f"map {result.map_time_s:.2f}s | verify {result.verify_time_s:.2f}s")

# 4. Verify exactness against brute force (small data only!).
truth = spjoin.brute_force_pairs(data, cfg.delta, cfg.metric)
assert np.array_equal(result.pairs, truth), "join must be exact"
print("exactness check vs brute force: OK")
