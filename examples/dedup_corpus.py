"""End-to-end data-pipeline driver: SP-Join-powered corpus dedup feeding LM
training — the paper's technique in its production seat.

    PYTHONPATH=src python examples/dedup_corpus.py

Pipeline:
  1. a noisy near-duplicate string corpus (synthetic AOL-style),
  2. q-gram profile vectorization (paper §6.2),
  3. SP-Join semantic dedup (generative sampling + learning partition),
  4. train a reduced qwen-family LM on the deduped corpus and show the
     held-out loss beats training on the duplicated corpus at equal step
     budget (duplicates waste steps).
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.core import spjoin
from repro.data import dedup, synthetic, vectorize
from repro.models import base, transformer
from repro.train import optimizer as opt_lib, train_step as ts

# ---- 1-2: corpus + vectors -------------------------------------------------
strs = synthetic.strings(1200, mutate=0.03, n_templates=64, seed=0)
prof = vectorize.qgram_profile(strs, q=2, dim=64)
print(f"corpus: {len(strs)} strings, {len(set(strs))} distinct")

# ---- 3: SP-Join dedup -------------------------------------------------------
res = dedup.dedup(prof, delta=2.0, metric="l1",
                  cfg=spjoin.JoinConfig(delta=2.0, metric="l1", k=256, p=8,
                                        n_dims=6))
kept = [s for s, k in zip(strs, res.keep_mask) if k]
print(f"dedup: kept {res.n_components}, removed {res.n_duplicates} near-dups")

# ---- 4: token stream + reduced-LM training ----------------------------------
cfg = configs.get_reduced("qwen1.5-0.5b")
CHARS = sorted(set("".join(strs)) | {"#"})
def tokenize(ss, seq_len=64):
    text = "#".join(ss)
    ids = np.array([CHARS.index(c) % cfg.vocab for c in text], np.int32)
    n = len(ids) // (seq_len + 1)
    return ids[: n * (seq_len + 1)].reshape(n, seq_len + 1)

def train_eval(corpus, steps=30, bs=8, seed=0):
    toks = tokenize(corpus)
    rng = np.random.default_rng(seed)
    params = base.init_params(jax.random.PRNGKey(seed), transformer.model_defs(cfg))
    ocfg = opt_lib.OptConfig(lr=1e-3, total_steps=steps, warmup_steps=2)
    opt = opt_lib.init_opt_state(params, ocfg)
    step = jax.jit(ts.make_train_step(cfg, ocfg, ts.StepConfig()))
    eval_step = jax.jit(ts.make_eval_step(cfg))
    held = tokenize(synthetic.strings(200, mutate=0.03, n_templates=64, seed=99))
    hb = {"tokens": jnp.asarray(held[:32, :-1]), "labels": jnp.asarray(held[:32, 1:])}
    for s in range(steps):
        idx = rng.integers(0, len(toks), bs)
        batch = {"tokens": jnp.asarray(toks[idx, :-1]),
                 "labels": jnp.asarray(toks[idx, 1:])}
        params, opt, m = step(params, opt, batch)
    return float(eval_step(params, hb)["loss"])

loss_dup = train_eval(strs)
loss_dedup = train_eval(kept)
print(f"held-out loss  duplicated corpus: {loss_dup:.4f}")
print(f"held-out loss  deduped corpus:    {loss_dedup:.4f}")
print("dedup helps" if loss_dedup <= loss_dup + 0.05 else "(noise-dominated at this scale)")
