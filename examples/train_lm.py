"""Train a reduced-config LM end-to-end with the production driver —
checkpointing, deterministic data, resumable. Any of the 10 assigned
architectures via --arch.

    PYTHONPATH=src python examples/train_lm.py --arch xlstm-1.3b --steps 60

(Equivalent to: python -m repro.launch.train --arch <a> --reduced ...)
"""
import sys

sys.argv = [sys.argv[0]] + (sys.argv[1:] or ["--arch", "qwen1.5-0.5b"]) + [
    "--reduced", "--steps", "60", "--ckpt-dir", "runs/example_ckpt",
    "--ckpt-every", "30", "--log-every", "10",
]
from repro.launch.train import main

main()
